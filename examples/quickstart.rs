//! Quickstart: fine-tune a pretrained tiny model with QuanTA on one
//! task and evaluate it — the 60-second tour of the public API.
//!
//!     make artifacts && cargo build --release
//!     cargo run --release --example quickstart
//!
//! (The first run pretrains and caches the tiny base model.)

use quanta_ft::bench::std_sizes;
use quanta_ft::coordinator::experiment::{require_artifacts, RunSpec};
use quanta_ft::coordinator::tables::{pct, score100};

fn main() {
    let Some(mut runner) = require_artifacts() else { return };

    // 1. A pretrained base model (pretrains + caches on first use).
    let base = runner.pretrained_base("tiny").unwrap();
    println!("base model: {} parameters", base.len());

    // 2. Fine-tune QuanTA (paper's method, N=4 decomposition of d=128)
    //    on the BoolQ-analog task, 2 seeds, best-checkpoint on val.
    let mut spec = RunSpec::new("tiny_quanta_n4", "boolq_syn").with_steps(120);
    spec.sizes = std_sizes();
    let result = runner.run(&spec).unwrap();

    // 3. Report, paper-style.
    println!(
        "QuanTA ({} trainable params, {} of the model): boolq_syn accuracy = {}",
        result.trainable_params,
        pct(result.trainable_percent),
        score100(result.mean("boolq_syn")),
    );

    // 4. Compare against LoRA at ~matched parameter budget.
    let mut lora = RunSpec::new("tiny_lora_r8", "boolq_syn").with_steps(120);
    lora.sizes = std_sizes();
    let lresult = runner.run(&lora).unwrap();
    println!(
        "LoRA r=8 ({} trainable params, {}): boolq_syn accuracy = {}",
        lresult.trainable_params,
        pct(lresult.trainable_percent),
        score100(lresult.mean("boolq_syn")),
    );
}
