//! Scenario example: the paper's headline experiment in miniature — a
//! method sweep on the DROP-analog (the high-intrinsic-rank task that
//! motivates QuanTA), printing F1 vs trainable-parameter count.
//!
//!     cargo run --release --example drop_sweep [--steps N]

use quanta_ft::bench::std_sizes;
use quanta_ft::coordinator::experiment::{require_artifacts, RunSpec};
use quanta_ft::coordinator::tables::{pct, score100, Table};

fn main() {
    let steps: Option<usize> = std::env::args()
        .skip_while(|a| a != "--steps")
        .nth(1)
        .and_then(|s| s.parse().ok());
    let Some(mut runner) = require_artifacts() else { return };

    let sets = [
        "tiny_lora_r8",
        "tiny_lora_r32",
        "tiny_quanta_n4",
        "tiny_quanta_n3",
        "tiny_ft",
    ];
    let mut table = Table::new(&["Method", "# Params", "%", "DROP-syn F1"]);
    for set in sets {
        let mut spec = RunSpec::new(set, "drop_syn").with_seeds(&[0, 1]);
        if let Some(st) = steps { spec = spec.with_steps(st); }
        spec.sizes = std_sizes();
        let r = runner.run(&spec).unwrap();
        table.row(vec![
            set.trim_start_matches("tiny_").to_string(),
            r.trainable_params.to_string(),
            pct(r.trainable_percent),
            score100(r.mean("drop_syn")),
        ]);
    }
    table.print();
}
