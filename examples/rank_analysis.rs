//! Scenario example: the paper's "intrinsic rank" analysis toolkit.
//!
//! 1. Pure-theory half (no artifacts needed): random QuanTA circuits vs
//!    the rank-representation bounds (Theorem 6.2), LoRA closure vs
//!    QuanTA composition openness (Theorem 6.3).
//! 2. Empirical half (needs artifacts + a trained pair): the Fig. 2
//!    subspace-similarity probe on RTE-analog vs DROP-analog updates.
//!
//!     cargo run --release --example rank_analysis [--empirical]

use quanta_ft::analysis::{render_heatmap, subspace_analysis};
use quanta_ft::coordinator::experiment::require_artifacts;
use quanta_ft::coordinator::tables::Table;
use quanta_ft::linalg::numerical_rank;
use quanta_ft::quanta::circuit::{all_pairs_structure, Circuit};
use quanta_ft::quanta::theorems::{
    check_rank_representation, circuit_with_gate_ranks, lora_product_rank,
};
use quanta_ft::util::rng::Rng;

fn main() {
    // ---- Theorem 6.2: rank representation on random circuits -----------
    println!("Theorem 6.2 (rank representation, Eq. 10) on random circuits:");
    let mut table = Table::new(&["dims", "gate ranks", "lower", "rank(chain)", "upper"]);
    let mut rng = Rng::new(7);
    for dims in [vec![4usize, 4, 4], vec![2, 4, 2, 2], vec![8, 4, 4]] {
        let structure = all_pairs_structure(dims.len());
        let ranks: Vec<usize> = structure
            .iter()
            .map(|&(m, n)| 1 + rng.below(dims[m] * dims[n]))
            .collect();
        let c = circuit_with_gate_ranks(&dims, &structure, &ranks, &mut rng).unwrap();
        let (granks, frank, bounds) = check_rank_representation(&c, 1e-6).unwrap();
        table.row(vec![
            format!("{dims:?}"),
            format!("{granks:?}"),
            bounds.lower.to_string(),
            frank.to_string(),
            bounds.upper.to_string(),
        ]);
    }
    table.print();

    // full-rank special case, driven through the cached circuit engine:
    // the plan (strides + rest-offset + gather tables) is built once and
    // reused for both the full-matrix materialization and the batched
    // chain application below.
    let dims = [4usize, 4, 4];
    let c = Circuit::random(&dims, &all_pairs_structure(3), 0.3, &mut rng).unwrap();
    let plan = c.plan().unwrap();
    let full = plan.full_matrix().unwrap();
    println!(
        "\nfull-rank gates => chain rank {} of {} (Thm 6.2 special case)",
        numerical_rank(&full, 1e-6).unwrap(),
        c.total_dim()
    );
    let d = c.total_dim();
    let batch = 8;
    let mut xs = vec![0.0f32; batch * d];
    rng.fill_normal(&mut xs, 1.0);
    let ys = plan.apply_batch(&xs, batch).unwrap();
    let mut worst = 0.0f32;
    for b in 0..batch {
        let via_full = full.matvec(&xs[b * d..(b + 1) * d]).unwrap();
        for (a, e) in ys[b * d..(b + 1) * d].iter().zip(&via_full) {
            worst = worst.max((a - e).abs());
        }
    }
    println!(
        "engine check: apply_batch({batch}) vs full-matrix matvec, max |diff| = {worst:.2e} \
         ({} gates, {} chain multiplies/vector)",
        plan.gates.len(),
        plan.apply_flops(),
    );

    // ---- Theorem 6.3 contrast: LoRA products stay low rank --------------
    let (r1, rp) = lora_product_rank(4, 32, 99).unwrap();
    println!("LoRA closure: rank(M1)={r1}, rank(M1*M2)={rp} (<= r=4 always)");

    // ---- Fig. 2 empirical probe -----------------------------------------
    if std::env::args().any(|a| a == "--empirical") {
        let Some(mut runner) = require_artifacts() else { return };
        for task in ["rte_syn", "drop_syn"] {
            let report =
                subspace_analysis(&mut runner, task, "tiny_lora_r32", "tiny_lora_r64", 4, 32, 32)
                    .unwrap();
            println!(
                "\n[{task}] mean phi {:.3}, tail phi {:.3}, effective rank {:.1}",
                report.mean_phi, report.tail_phi, report.effective_rank_r2
            );
            print!("{}", render_heatmap(&report.grid, 32));
        }
    } else {
        println!("\n(pass --empirical to run the Fig. 2 subspace probe on trained updates)");
    }
}
