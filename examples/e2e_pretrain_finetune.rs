//! End-to-end driver (DESIGN.md deliverable): proves all three layers
//! compose on a real workload.
//!
//!   1. Pretrain a transformer base model from scratch on the synthetic
//!      corpus, through the rust coordinator -> PJRT -> AOT HLO from
//!      JAX+Pallas, logging the loss curve.
//!   2. Fine-tune it two ways (QuanTA vs LoRA) on the DROP-analog.
//!   3. Evaluate both and report F1 + trainable-parameter counts.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example e2e_pretrain_finetune [--arch small] [--fresh]

use quanta_ft::bench::std_sizes;
use quanta_ft::coordinator::experiment::{require_artifacts, RunSpec};
use quanta_ft::coordinator::tables::{pct, score100, Table};
use quanta_ft::coordinator::trainer;
use quanta_ft::runtime::manifest::Manifest;
use quanta_ft::runtime::session::Session;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let arch = args
        .iter()
        .position(|a| a == "--arch")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or("tiny")
        .to_string();
    let fresh = args.iter().any(|a| a == "--fresh");
    let Some(mut runner) = require_artifacts() else { return };

    // ---- 1. pretraining ----------------------------------------------------
    let set = format!("pretrain_{arch}");
    let man = Manifest::load(&runner.artifacts_dir.join(&set)).unwrap();
    println!(
        "[e2e] pretraining '{arch}': {} params, {} steps, batch {} x seq {}",
        man.counts.model_params, man.hyper.total_steps, man.io.batch, man.io.seq_len
    );
    let ckpt_path = runner.runs_dir.join(format!("base_{arch}.bin"));
    if fresh && ckpt_path.exists() {
        std::fs::remove_file(&ckpt_path).unwrap();
    }
    if !ckpt_path.exists() {
        let base = Session::init_base(&man, 0, None).unwrap();
        let mut session = Session::load(
            &runner.client,
            &runner.artifacts_dir,
            &set,
            &base,
            &["train_step"],
        )
        .unwrap();
        let out = trainer::pretrain(&mut session, &runner.tok, 0, None).unwrap();
        println!("[e2e] pretrain loss curve (step, loss):");
        for (s, l) in &out.loss_curve {
            println!("    {s:5}  {l:.4}");
        }
        println!(
            "[e2e] pretraining took {:.1}s ({:.1} steps/s)",
            out.wallclock_s,
            out.steps_run as f64 / out.wallclock_s
        );
        quanta_ft::coordinator::checkpoint::save(&ckpt_path, &set, &out.final_theta).unwrap();
    } else {
        println!("[e2e] using cached base checkpoint {}", ckpt_path.display());
    }

    // ---- 2+3. fine-tune QuanTA vs LoRA and evaluate --------------------------
    let quanta_set = format!("{arch}_quanta_n4");
    let lora_set = format!("{arch}_lora_r8");
    let mut table = Table::new(&["Method", "# Params", "%", "DROP-syn F1", "train s/seed"]);
    for set in [quanta_set.as_str(), lora_set.as_str()] {
        let mut spec = RunSpec::new(set, "drop_syn").with_seeds(&[0]);
        spec.sizes = std_sizes();
        let r = runner.run(&spec).unwrap();
        table.row(vec![
            set.to_string(),
            r.trainable_params.to_string(),
            pct(r.trainable_percent),
            score100(r.mean("drop_syn")),
            format!("{:.1}", r.train_seconds),
        ]);
    }
    table.print();
    println!("[e2e] full pipeline (L1 Pallas kernel -> L2 JAX HLO -> L3 rust PJRT) OK");
}
