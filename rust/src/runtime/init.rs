//! Parameter initialization from manifest layouts.
//!
//! Each entry draws from a PRNG stream keyed by `(seed, entry.key)`, so
//! (a) inits are independent of layout order, and (b) entries sharing a
//! key get *identical* values — the mechanism behind QuanTA's exact
//! zero-init (trainable chain T and frozen shadow S share per-gate keys;
//! paper Eq. 8).

use crate::runtime::manifest::{InitSpec, ParamEntry};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Initialize a flat parameter vector from a layout.
///
/// `checkpoint`: optional prefix of pretrained model parameters (the
/// pretraining run's theta vector).  Entries fully inside the prefix are
/// copied verbatim; the rest (PEFT extras such as QuanTA's shadow chain)
/// are generated from their init specs.
pub fn init_layout(
    layout: &[ParamEntry],
    seed: u64,
    checkpoint: Option<&[f32]>,
) -> Result<Vec<f32>> {
    let total: usize = layout.iter().map(|e| e.size).sum();
    let mut out = vec![0.0f32; total];
    if let Some(ckpt) = checkpoint {
        // checkpoint must cover a whole prefix of entries
        let covered: usize = layout
            .iter()
            .take_while(|e| e.offset + e.size <= ckpt.len())
            .map(|e| e.size)
            .sum();
        if covered != ckpt.len() {
            return Err(Error::Manifest(format!(
                "checkpoint len {} does not align with layout prefix (covered {covered})",
                ckpt.len()
            )));
        }
        out[..ckpt.len()].copy_from_slice(ckpt);
    }
    let skip = checkpoint.map(|c| c.len()).unwrap_or(0);
    for e in layout {
        if e.offset < skip {
            continue; // came from the checkpoint
        }
        init_entry(e, seed, &mut out[e.offset..e.offset + e.size]);
    }
    Ok(out)
}

/// Initialize a single entry in place.
pub fn init_entry(e: &ParamEntry, seed: u64, out: &mut [f32]) {
    match &e.init {
        InitSpec::Zeros => out.fill(0.0),
        InitSpec::Ones => out.fill(1.0),
        InitSpec::Normal { std, key } => {
            let mut rng = Rng::stream(seed, key);
            rng.fill_normal(out, *std as f32);
        }
        InitSpec::EyeNoise { n, std, key } => {
            let mut rng = Rng::stream(seed, key);
            rng.fill_normal(out, *std as f32);
            for i in 0..*n {
                out[i * n + i] += 1.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(name: &str, size: usize, offset: usize, init: InitSpec) -> ParamEntry {
        ParamEntry { name: name.into(), shape: vec![size], offset, size, init }
    }

    #[test]
    fn shared_keys_give_identical_values() {
        let e1 = entry("t", 16, 0, InitSpec::EyeNoise { n: 4, std: 0.1, key: "g0".into() });
        let e2 = entry("s", 16, 16, InitSpec::EyeNoise { n: 4, std: 0.1, key: "g0".into() });
        let out = init_layout(&[e1, e2], 7, None).unwrap();
        assert_eq!(&out[..16], &out[16..32]);
        // and the diagonal carries the +1
        assert!((out[0] - 1.0).abs() < 0.5);
    }

    #[test]
    fn different_seeds_differ() {
        let e = entry("w", 8, 0, InitSpec::Normal { std: 1.0, key: "w".into() });
        let a = init_layout(std::slice::from_ref(&e), 1, None).unwrap();
        let b = init_layout(std::slice::from_ref(&e), 2, None).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn checkpoint_prefix_copied() {
        let e1 = entry("model", 4, 0, InitSpec::Normal { std: 1.0, key: "m".into() });
        let e2 = entry("extra", 4, 4, InitSpec::Zeros);
        let ckpt = vec![9.0f32, 8.0, 7.0, 6.0];
        let out = init_layout(&[e1, e2], 3, Some(&ckpt)).unwrap();
        assert_eq!(&out[..4], &ckpt[..]);
        assert_eq!(&out[4..], &[0.0; 4]);
    }

    #[test]
    fn misaligned_checkpoint_rejected() {
        let e1 = entry("model", 4, 0, InitSpec::Zeros);
        let ckpt = vec![1.0f32; 3];
        assert!(init_layout(std::slice::from_ref(&e1), 3, Some(&ckpt)).is_err());
    }

    #[test]
    fn ones_and_zeros() {
        let layout = [
            entry("a", 3, 0, InitSpec::Ones),
            entry("b", 2, 3, InitSpec::Zeros),
        ];
        let out = init_layout(&layout, 0, None).unwrap();
        assert_eq!(out, vec![1.0, 1.0, 1.0, 0.0, 0.0]);
    }
}
