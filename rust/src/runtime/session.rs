//! PJRT execution session for one artifact set.
//!
//! Owns the compiled executables and the device-resident frozen base
//! buffer; exposes typed calls for the four lowered graphs.  The
//! trainable state round-trips through the host each step (PJRT returns
//! one tuple buffer per call — see DESIGN.md §3); for PEFT methods this
//! is 0.01–1% of the model per step.

use std::path::Path;

use crate::runtime::pjrt::{self as xla, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::runtime::init::init_layout;
use crate::runtime::manifest::Manifest;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Trainable optimizer state held on the host between steps.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i32,
}

impl TrainState {
    pub fn new(theta: Vec<f32>) -> Self {
        let n = theta.len();
        TrainState { theta, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}

/// Timing breakdown of the last `train_step` call (perf instrumentation).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    pub upload_us: u64,
    pub execute_us: u64,
    pub download_us: u64,
}

pub struct Session {
    pub man: Manifest,
    client: PjRtClient,
    train: Option<PjRtLoadedExecutable>,
    eval: Option<PjRtLoadedExecutable>,
    logits: Option<PjRtLoadedExecutable>,
    merge: Option<PjRtLoadedExecutable>,
    base_buf: PjRtBuffer,
    pub last_timing: StepTiming,
}

fn now_us() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_micros() as u64
}

impl Session {
    /// Load a set: compile requested executables and upload the base.
    ///
    /// `kinds` selects which graphs to compile (compilation is the
    /// dominant startup cost); e.g. `&["train_step", "eval_loss"]`.
    pub fn load(
        client: &PjRtClient,
        artifacts_dir: &Path,
        set_name: &str,
        base: &[f32],
        kinds: &[&str],
    ) -> Result<Session> {
        let man = Manifest::load(&artifacts_dir.join(set_name))?;
        if base.len() != man.io.base_len {
            return Err(Error::Shape(format!(
                "{set_name}: base len {} != manifest {}",
                base.len(),
                man.io.base_len
            )));
        }
        let compile = |kind: &str| -> Result<Option<PjRtLoadedExecutable>> {
            if !kinds.contains(&kind) || !man.artifacts.contains_key(kind) {
                return Ok(None);
            }
            let path = man.artifact_path(kind)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::msg("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(Some(client.compile(&comp)?))
        };
        let train = compile("train_step")?;
        let eval = compile("eval_loss")?;
        let logits = compile("fwd_logits")?;
        let merge = compile("merge")?;
        let base_buf = client.buffer_from_host_buffer(base, &[base.len()], None)?;
        Ok(Session {
            man,
            client: client.clone(),
            train,
            eval,
            logits,
            merge,
            base_buf,
            last_timing: StepTiming::default(),
        })
    }

    /// Convenience: initialize the base vector for this set from a
    /// pretrained checkpoint (or from specs when `ckpt` is None).
    pub fn init_base(man: &Manifest, seed: u64, ckpt: Option<&[f32]>) -> Result<Vec<f32>> {
        init_layout(&man.base_layout, seed, ckpt)
    }

    /// Initialize a fresh trainable state for this set.
    pub fn init_state(&self, seed: u64) -> Result<TrainState> {
        Ok(TrainState::new(init_layout(&self.man.theta_layout, seed, None)?))
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// One optimizer step.  `tokens`: `[batch, seq+1]`, `mask`:
    /// `[batch, seq]`.  Updates `state` in place and returns the loss.
    pub fn train_step(
        &mut self,
        state: &mut TrainState,
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<f32> {
        let exe = self.train.as_ref().ok_or_else(|| Error::msg("train_step not compiled"))?;
        let io = &self.man.io;
        if tokens.len() != io.batch * (io.seq_len + 1) || mask.len() != io.batch * io.seq_len {
            return Err(Error::Shape(format!(
                "train_step: tokens {} mask {} vs batch {} seq {}",
                tokens.len(),
                mask.len(),
                io.batch,
                io.seq_len
            )));
        }
        let t0 = now_us();
        let pt = state.theta.len();
        let theta = self.buf_f32(&state.theta, &[pt])?;
        let m = self.buf_f32(&state.m, &[pt])?;
        let v = self.buf_f32(&state.v, &[pt])?;
        let step = self.buf_i32(&[state.step], &[])?;
        let toks = self.buf_i32(tokens, &[io.batch, io.seq_len + 1])?;
        let msk = self.buf_f32(mask, &[io.batch, io.seq_len])?;
        let t1 = now_us();
        let outs = exe.execute_b::<&PjRtBuffer>(&[
            &self.base_buf,
            &theta,
            &m,
            &v,
            &step,
            &toks,
            &msk,
        ])?;
        let t2 = now_us();
        let lit = outs[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != 4 {
            return Err(Error::msg(format!("train_step returned {} outputs", parts.len())));
        }
        parts[0].copy_raw_to(&mut state.theta)?;
        parts[1].copy_raw_to(&mut state.m)?;
        parts[2].copy_raw_to(&mut state.v)?;
        let loss = parts[3].get_first_element::<f32>()?;
        state.step += 1;
        let t3 = now_us();
        self.last_timing = StepTiming {
            upload_us: t1 - t0,
            execute_us: t2 - t1,
            download_us: t3 - t2,
        };
        Ok(loss)
    }

    /// Masked eval loss over one eval batch.  Returns (loss_sum, tok_count).
    pub fn eval_loss(&self, theta: &[f32], tokens: &[i32], mask: &[f32]) -> Result<(f32, f32)> {
        let exe = self.eval.as_ref().ok_or_else(|| Error::msg("eval_loss not compiled"))?;
        let io = &self.man.io;
        let th = self.buf_f32(theta, &[theta.len()])?;
        let toks = self.buf_i32(tokens, &[io.eval_batch, io.seq_len + 1])?;
        let msk = self.buf_f32(mask, &[io.eval_batch, io.seq_len])?;
        let outs = exe.execute_b::<&PjRtBuffer>(&[&self.base_buf, &th, &toks, &msk])?;
        let lit = outs[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        Ok((
            parts[0].get_first_element::<f32>()?,
            parts[1].get_first_element::<f32>()?,
        ))
    }

    /// Forward logits for an eval batch of `[eval_batch, seq]` tokens.
    /// Returns a flat `[eval_batch * seq * vocab]` vector.
    pub fn fwd_logits(&self, theta: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        let exe = self.logits.as_ref().ok_or_else(|| Error::msg("fwd_logits not compiled"))?;
        let io = &self.man.io;
        if tokens.len() != io.eval_batch * io.seq_len {
            return Err(Error::Shape(format!(
                "fwd_logits: tokens {} != {}",
                tokens.len(),
                io.eval_batch * io.seq_len
            )));
        }
        let th = self.buf_f32(theta, &[theta.len()])?;
        let toks = self.buf_i32(tokens, &[io.eval_batch, io.seq_len])?;
        let outs = exe.execute_b::<&PjRtBuffer>(&[&self.base_buf, &th, &toks])?;
        let lit = outs[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        Ok(parts[0].to_vec::<f32>()?)
    }

    /// Materialize the delta matrices of every adapted module
    /// (`[n_modules, d_out, d_in]` stacked), in `merged_modules` order.
    pub fn merge_deltas(&self, theta: &[f32]) -> Result<Vec<Tensor>> {
        let exe = self.merge.as_ref().ok_or_else(|| Error::msg("merge not compiled"))?;
        let th = self.buf_f32(theta, &[theta.len()])?;
        let outs = exe.execute_b::<&PjRtBuffer>(&[&self.base_buf, &th])?;
        let lit = outs[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        let data = parts[0].to_vec::<f32>()?;
        let shape = parts[0].array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        if dims.len() != 3 {
            return Err(Error::Shape(format!("merge output dims {dims:?}")));
        }
        let (n, d_out, d_in) = (dims[0], dims[1], dims[2]);
        let mut out = vec![];
        for k in 0..n {
            let slice = data[k * d_out * d_in..(k + 1) * d_out * d_in].to_vec();
            out.push(Tensor::from_vec(&[d_out, d_in], slice)?);
        }
        Ok(out)
    }

    /// Replace the device-resident base (e.g. after merging deltas).
    pub fn set_base(&mut self, base: &[f32]) -> Result<()> {
        if base.len() != self.man.io.base_len {
            return Err(Error::Shape("set_base: wrong length".into()));
        }
        self.base_buf = self.client.buffer_from_host_buffer(base, &[base.len()], None)?;
        Ok(())
    }
}
