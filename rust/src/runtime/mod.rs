//! L3 runtime: loads the AOT artifacts (HLO text + manifest) produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client via
//! the `xla` crate.  Python is never on this path.
//!
//! Execution model (validated empirically, DESIGN.md §3): PJRT returns
//! one *tuple* buffer per call, so trainable state round-trips through
//! the host each step while the frozen base parameters stay resident on
//! device as an input buffer.  For PEFT methods the round-trip is tiny
//! (theta is 0.01–1% of the model); for full fine-tuning it is the whole
//! model — an honest operational reason PEFT wins, which we report in
//! the perf benches.

pub mod manifest;
pub mod init;
pub mod pjrt;
pub mod session;

pub use manifest::{InitSpec, Manifest, ParamEntry};
pub use session::{Session, TrainState};
