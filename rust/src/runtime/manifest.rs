//! Artifact manifest: the L2->L3 contract emitted by `aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::Value;

/// Parameter initialization spec (mirrors `python/compile/packing.py`).
#[derive(Clone, Debug, PartialEq)]
pub enum InitSpec {
    Zeros,
    Ones,
    Normal { std: f64, key: String },
    /// identity(n) + N(0, std^2), via PRNG stream `key` — the shared key
    /// is what makes QuanTA's frozen shadow S equal the trainable T at
    /// init (paper Eq. 8).
    EyeNoise { n: usize, std: f64, key: String },
}

impl InitSpec {
    fn parse(v: &Value) -> Result<InitSpec> {
        let kind = v.req("kind")?.as_str()?;
        Ok(match kind {
            "zeros" => InitSpec::Zeros,
            "ones" => InitSpec::Ones,
            "normal" => InitSpec::Normal {
                std: v.req("std")?.as_f64()?,
                key: v.req("key")?.as_str()?.to_string(),
            },
            "eye_noise" => InitSpec::EyeNoise {
                n: v.req("n")?.as_usize()?,
                std: v.req("std")?.as_f64()?,
                key: v.req("key")?.as_str()?.to_string(),
            },
            other => return Err(Error::Manifest(format!("unknown init kind '{other}'"))),
        })
    }
}

/// One entry of a flat parameter layout.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init: InitSpec,
}

fn parse_layout(v: &Value) -> Result<Vec<ParamEntry>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(ParamEntry {
                name: e.req("name")?.as_str()?.to_string(),
                shape: e
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                offset: e.req("offset")?.as_usize()?,
                size: e.req("size")?.as_usize()?,
                init: InitSpec::parse(e.req("init")?)?,
            })
        })
        .collect()
}

/// Architecture block of the manifest.
#[derive(Clone, Debug)]
pub struct ArchInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

/// Training hyperparameters baked into the train_step HLO.
#[derive(Clone, Debug)]
pub struct HyperInfo {
    pub lr: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
}

/// IO shapes of the lowered graphs.
#[derive(Clone, Debug)]
pub struct IoInfo {
    pub batch: usize,
    pub eval_batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub base_len: usize,
    pub theta_len: usize,
}

/// Parameter-count block (paper's "# Params (%)" column).
#[derive(Clone, Debug)]
pub struct CountsInfo {
    pub model_params: usize,
    pub trainable_params: usize,
    pub trainable_percent: f64,
}

/// PEFT method descriptor.
#[derive(Clone, Debug)]
pub struct MethodInfo {
    pub name: String,
    pub modules: Vec<String>,
    pub hyper: Value,
}

/// Full manifest for one artifact set.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub dir: PathBuf,
    pub arch: ArchInfo,
    pub method: Option<MethodInfo>,
    pub hyper: HyperInfo,
    pub pretrain: bool,
    pub io: IoInfo,
    pub counts: CountsInfo,
    pub base_layout: Vec<ParamEntry>,
    pub theta_layout: Vec<ParamEntry>,
    pub merged_modules: Vec<String>,
    pub artifacts: BTreeMap<String, String>,
}

impl Manifest {
    pub fn load(set_dir: &Path) -> Result<Manifest> {
        let v = Value::parse_file(&set_dir.join("manifest.json"))?;
        let arch_v = v.req("arch")?;
        let arch = ArchInfo {
            name: arch_v.req("name")?.as_str()?.to_string(),
            vocab: arch_v.req("vocab")?.as_usize()?,
            d_model: arch_v.req("d_model")?.as_usize()?,
            n_layers: arch_v.req("n_layers")?.as_usize()?,
            n_heads: arch_v.req("n_heads")?.as_usize()?,
            d_ff: arch_v.req("d_ff")?.as_usize()?,
            seq_len: arch_v.req("seq_len")?.as_usize()?,
        };
        let hyper_v = v.req("hyper")?;
        let hyper = HyperInfo {
            lr: hyper_v.req("lr")?.as_f64()?,
            warmup_steps: hyper_v.req("warmup_steps")?.as_usize()?,
            total_steps: hyper_v.req("total_steps")?.as_usize()?,
        };
        let io_v = v.req("io")?;
        let io = IoInfo {
            batch: io_v.req("batch")?.as_usize()?,
            eval_batch: io_v.req("eval_batch")?.as_usize()?,
            seq_len: io_v.req("seq_len")?.as_usize()?,
            vocab: io_v.req("vocab")?.as_usize()?,
            base_len: io_v.req("base_len")?.as_usize()?,
            theta_len: io_v.req("theta_len")?.as_usize()?,
        };
        let counts_v = v.req("counts")?;
        let counts = CountsInfo {
            model_params: counts_v.req("model_params")?.as_usize()?,
            trainable_params: counts_v.req("trainable_params")?.as_usize()?,
            trainable_percent: counts_v.req("trainable_percent")?.as_f64()?,
        };
        let method = match v.req("method")? {
            Value::Null => None,
            m => Some(MethodInfo {
                name: m.req("name")?.as_str()?.to_string(),
                modules: m
                    .req("modules")?
                    .as_arr()?
                    .iter()
                    .map(|x| Ok(x.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
                hyper: m.req("hyper")?.clone(),
            }),
        };
        let base_layout = parse_layout(v.req("base_layout")?)?;
        let theta_layout = parse_layout(v.req("theta_layout")?)?;
        // layout sanity
        for (layout, total, who) in [
            (&base_layout, io.base_len, "base"),
            (&theta_layout, io.theta_len, "theta"),
        ] {
            let mut expect = 0usize;
            for e in layout.iter() {
                if e.offset != expect {
                    return Err(Error::Manifest(format!(
                        "{who} layout gap at '{}': offset {} != {}",
                        e.name, e.offset, expect
                    )));
                }
                let shape_size: usize = e.shape.iter().product::<usize>().max(1);
                if shape_size != e.size {
                    return Err(Error::Manifest(format!(
                        "{who} layout size mismatch at '{}'",
                        e.name
                    )));
                }
                expect += e.size;
            }
            if expect != total {
                return Err(Error::Manifest(format!(
                    "{who} layout total {expect} != {total}"
                )));
            }
        }
        let merged_modules = v
            .req("merged_modules")?
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let artifacts = v
            .req("artifacts")?
            .as_obj()?
            .iter()
            .map(|(k, val)| Ok((k.clone(), val.as_str()?.to_string())))
            .collect::<Result<_>>()?;
        Ok(Manifest {
            name: v.req("name")?.as_str()?.to_string(),
            dir: set_dir.to_path_buf(),
            arch,
            method,
            hyper,
            pretrain: v.req("pretrain")?.as_bool()?,
            io,
            counts,
            base_layout,
            theta_layout,
            merged_modules,
            artifacts,
        })
    }

    /// Absolute path of one artifact HLO file.
    pub fn artifact_path(&self, kind: &str) -> Result<PathBuf> {
        let file = self
            .artifacts
            .get(kind)
            .ok_or_else(|| Error::Manifest(format!("{}: no '{kind}' artifact", self.name)))?;
        Ok(self.dir.join(file))
    }

    /// List available set names under an artifacts directory.
    pub fn list_sets(artifacts_dir: &Path) -> Result<Vec<String>> {
        let idx = Value::parse_file(&artifacts_dir.join("index.json"))?;
        idx.req("sets")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect()
    }
}
