//! PJRT backend selection.
//!
//! With the `pjrt` feature the real `xla` crate (xla-rs) is re-exported
//! verbatim; without it this module provides inert stand-ins with the
//! same API surface so the rest of the crate compiles and tests on the
//! pure-Rust feature set.  Every stub entry point returns an error at
//! runtime — callers that guard on `PjRtClient::cpu()` (e.g.
//! `require_artifacts`) degrade to a skip message instead of failing to
//! build.

#[cfg(feature = "pjrt")]
pub use xla::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::fmt;

    /// Stand-in for `xla::Error`.
    #[derive(Debug)]
    pub struct Error(pub String);

    impl fmt::Display for Error {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    impl std::error::Error for Error {}

    fn disabled<T>() -> Result<T, Error> {
        Err(Error(
            "built without the `pjrt` feature; PJRT execution is unavailable \
             (rebuild with `--features pjrt` and a vendored xla-rs)"
                .to_string(),
        ))
    }

    #[derive(Clone, Debug)]
    pub struct PjRtClient;

    #[derive(Debug)]
    pub struct PjRtBuffer;

    #[derive(Debug)]
    pub struct PjRtLoadedExecutable;

    #[derive(Debug)]
    pub struct Literal;

    #[derive(Debug)]
    pub struct ArrayShape;

    #[derive(Debug)]
    pub struct HloModuleProto;

    #[derive(Debug)]
    pub struct XlaComputation;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            disabled()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            disabled()
        }

        pub fn buffer_from_host_buffer<T>(
            &self,
            _data: &[T],
            _dims: &[usize],
            _device: Option<usize>,
        ) -> Result<PjRtBuffer, Error> {
            disabled()
        }
    }

    impl PjRtLoadedExecutable {
        pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            disabled()
        }
    }

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            disabled()
        }
    }

    impl Literal {
        pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
            disabled()
        }

        pub fn copy_raw_to<T>(&self, _out: &mut [T]) -> Result<(), Error> {
            disabled()
        }

        pub fn get_first_element<T>(&self) -> Result<T, Error> {
            disabled()
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            disabled()
        }

        pub fn array_shape(&self) -> Result<ArrayShape, Error> {
            disabled()
        }
    }

    impl ArrayShape {
        pub fn dims(&self) -> &[i64] {
            &[]
        }
    }

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            disabled()
        }
    }

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::*;
