//! Shared bench-harness utilities (criterion is unavailable offline, so
//! benches are plain `harness = false` binaries built on this module).

use std::time::Instant;

use crate::coordinator::experiment::RunSpec;
use crate::data::tasks::Sizes;

/// Canonical bench sizes (shared across all bench binaries so cached
/// results are reused between tables that share rows).
pub fn std_sizes() -> Sizes {
    Sizes { train: 400, val: 100, test: 160 }
}

/// Fine-tuning steps: every bench runs the full LR schedule baked into
/// the artifact's train_step HLO (RunSpec steps=None), matching the
/// paper's protocol of training to schedule end and selecting the best
/// validation checkpoint.
pub fn std_steps(set: &str) -> usize {
    // informational only (examples print it); the schedule is baked.
    if set.starts_with("large") {
        250
    } else if set.starts_with("small") {
        300
    } else {
        400
    }
}

/// Canonical single-task run (single seed — the paper averages 2-4
/// seeds; on this CPU substrate we default to one and expose
/// `with_seeds` for more).
pub fn std_single(set: &str, task: &str) -> RunSpec {
    let mut spec = RunSpec::new(set, task).with_seeds(&[0]);
    spec.sizes = std_sizes();
    spec
}

/// Canonical mixed-suite run (single seed; see std_single).
pub fn std_mix(set: &str, suite: &[&str]) -> RunSpec {
    let mut spec = RunSpec::mix(set, suite).with_seeds(&[0]);
    spec.sizes = std_sizes();
    spec
}

/// Measure a closure's wallclock over `iters` runs after `warmup` runs;
/// returns per-iteration stats in microseconds.
pub struct BenchStats {
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub min_us: f64,
    pub iters: usize,
}

pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    BenchStats {
        mean_us: crate::util::stats::mean(&samples),
        p50_us: crate::util::stats::quantile(&samples, 0.5),
        p95_us: crate::util::stats::quantile(&samples, 0.95),
        min_us: samples.iter().copied().fold(f64::INFINITY, f64::min),
        iters,
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:.1}us p50 {:.1}us p95 {:.1}us min {:.1}us (n={})",
            self.mean_us, self.p50_us, self.p95_us, self.min_us, self.iters
        )
    }
}

/// Print a section banner shared by all bench binaries.
pub fn banner(id: &str, title: &str) {
    println!("\n================================================================");
    println!("{id}: {title}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let st = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(st.iters, 5);
        assert!(st.min_us <= st.mean_us);
    }
}
