//! Analysis pipelines: the paper's "intrinsic rank" probe (§3 / App. A).
//!
//! Fig. 2 methodology: fine-tune LoRA at two ranks r1 < r2 on the same
//! task, materialize the weight updates dW = B A via the merge artifact,
//! SVD both, and compute the subspace-similarity grid phi(i, j)
//! (Eq. A.1).  Low-intrinsic-rank tasks (RTE) show phi collapsing for
//! i > a few; high-intrinsic-rank tasks (DROP) keep phi high across the
//! grid.

use crate::coordinator::experiment::{RunSpec, Runner};
use crate::linalg::{effective_rank, subspace_similarity_grid};
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Result of the Fig. 2 analysis for one (task, module) pair.
#[derive(Debug)]
pub struct SubspaceReport {
    pub task: String,
    pub module: String,
    /// phi(i, j) grid, i over r1 directions, j over r2 directions.
    pub grid: Vec<Vec<f64>>,
    /// effective rank of the r2 update (soft rank measure).
    pub effective_rank_r2: f64,
    /// mean phi over the full grid — the scalar "intrinsic rank" signal.
    pub mean_phi: f64,
    /// mean phi restricted to i > k1/2 (the tail the paper highlights:
    /// ~0 for RTE, high for DROP).
    pub tail_phi: f64,
}

/// Train LoRA at two ranks on `task` and compare update subspaces for
/// the module at `module_idx` (index into manifest merged_modules).
pub fn subspace_analysis(
    runner: &mut Runner,
    task: &str,
    set_r1: &str,
    set_r2: &str,
    module_idx: usize,
    k1: usize,
    k2: usize,
) -> Result<SubspaceReport> {
    let spec1 = RunSpec::new(set_r1, task);
    let spec2 = RunSpec::new(set_r2, task);
    let (theta1, session1) = runner.run_for_theta(&spec1)?;
    let (theta2, session2) = runner.run_for_theta(&spec2)?;
    let d1 = session1.merge_deltas(&theta1)?;
    let d2 = session2.merge_deltas(&theta2)?;
    if module_idx >= d1.len() || module_idx >= d2.len() {
        return Err(Error::msg("module_idx out of range"));
    }
    let module = session1.man.merged_modules[module_idx].clone();
    report_from_deltas(task, &module, &d1[module_idx], &d2[module_idx], k1, k2)
}

/// Pure computation from two delta matrices (testable without PJRT).
pub fn report_from_deltas(
    task: &str,
    module: &str,
    dw1: &Tensor,
    dw2: &Tensor,
    k1: usize,
    k2: usize,
) -> Result<SubspaceReport> {
    let grid = subspace_similarity_grid(dw1, dw2, k1, k2)?;
    let k1 = grid.len();
    let flat: Vec<f64> = grid.iter().flatten().copied().collect();
    let mean_phi = crate::util::stats::mean(&flat);
    let tail: Vec<f64> = grid[k1 / 2..].iter().flatten().copied().collect();
    let tail_phi = crate::util::stats::mean(&tail);
    Ok(SubspaceReport {
        task: task.to_string(),
        module: module.to_string(),
        grid,
        effective_rank_r2: effective_rank(dw2)?,
        mean_phi,
        tail_phi,
    })
}

/// Render a phi grid as a coarse ASCII heatmap (rows i, cols j), the
/// terminal stand-in for Fig. 2's color plots.
pub fn render_heatmap(grid: &[Vec<f64>], max_cells: usize) -> String {
    let chars = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let k1 = grid.len();
    let k2 = grid.first().map(|r| r.len()).unwrap_or(0);
    let step1 = (k1 + max_cells - 1) / max_cells.max(1);
    let step2 = (k2 + max_cells - 1) / max_cells.max(1);
    let mut out = String::new();
    out.push_str(&format!("phi(i,j) heatmap ({k1}x{k2}), darker = higher:\n"));
    for i in (0..k1).step_by(step1.max(1)) {
        out.push_str("  ");
        for j in (0..k2).step_by(step2.max(1)) {
            let v = grid[i][j].clamp(0.0, 1.0);
            let idx = ((v * 9.0).round() as usize).min(9);
            out.push(chars[idx]);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_updates_full_phi() {
        let mut rng = Rng::new(60);
        let dw = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let r = report_from_deltas("t", "m", &dw, &dw, 8, 8).unwrap();
        assert!(r.mean_phi > 0.99, "{}", r.mean_phi);
        assert!(r.tail_phi > 0.99);
    }

    #[test]
    fn low_rank_vs_highrank_signal() {
        // dw1/dw2 sharing only a rank-2 subspace => tail phi low;
        // dw1 == dw2 full-rank => tail phi high.  The discriminator the
        // paper uses must separate these.
        let mut rng = Rng::new(61);
        let n = 16;
        let shared = Tensor::randn(&[n, 2], 1.0, &mut rng)
            .matmul(&Tensor::randn(&[2, n], 1.0, &mut rng))
            .unwrap();
        let noise1 = Tensor::randn(&[n, n], 0.05, &mut rng);
        let noise2 = Tensor::randn(&[n, n], 0.05, &mut rng);
        let dw1 = shared.add(&noise1).unwrap();
        let dw2 = shared.add(&noise2).unwrap();
        let low = report_from_deltas("low", "m", &dw1, &dw2, 8, 8).unwrap();
        let full = Tensor::randn(&[n, n], 1.0, &mut rng);
        let high = report_from_deltas("high", "m", &full, &full, 8, 8).unwrap();
        assert!(high.tail_phi > low.tail_phi + 0.2,
            "high {} vs low {}", high.tail_phi, low.tail_phi);
    }

    #[test]
    fn heatmap_renders() {
        let grid = vec![vec![0.0, 0.5], vec![1.0, 0.25]];
        let s = render_heatmap(&grid, 4);
        assert!(s.contains("@"));
    }
}
