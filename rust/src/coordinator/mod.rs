//! L3 coordinator: the fine-tuning orchestrator.
//!
//! Implements the paper's experimental protocol (App. E): pretrain the
//! base model in-repo, fine-tune with the selected PEFT method under
//! AdamW + linear LR schedule (inside the HLO), track the best
//! checkpoint on a validation split carved from train, evaluate that
//! checkpoint on held-out test suites, and aggregate over seeds.

pub mod checkpoint;
pub mod trainer;
pub mod host_trainer;
pub mod evaluator;
pub mod experiment;
pub mod tables;

pub use experiment::{RunResult, RunSpec, Runner, TrainTask};
pub use host_trainer::{finetune_host, HostTrainConfig};
pub use trainer::{FinetuneConfig, TrainOutcome};
