//! Markdown table rendering for the bench harness — every bench target
//! prints the same rows the paper's table/figure reports.

/// Simple column-aligned markdown table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:width$} |", cells[i], width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a fraction as the paper's percent style ("0.041%").
pub fn pct(p: f64) -> String {
    if p >= 10.0 {
        format!("{p:.0}%")
    } else if p >= 1.0 {
        format!("{p:.2}%")
    } else {
        format!("{p:.3}%")
    }
}

/// Format a 0..1 metric as the paper's 0..100 scale with 1 decimal.
pub fn score100(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

/// score ± std on the 0..100 scale.
pub fn score100_std(mean: f64, std: f64, n: usize) -> String {
    if n <= 1 {
        score100(mean)
    } else {
        format!("{:.1} ± {:.1}", 100.0 * mean, 100.0 * std)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Method", "Score"]);
        t.row(vec!["LoRA".into(), "54.0".into()]);
        t.row(vec!["QuanTA (Ours)".into(), "59.5".into()]);
        let s = t.render();
        assert!(s.contains("| Method "));
        assert!(s.lines().count() == 4);
        // all lines same length
        let lens: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(lens.iter().all(|&l| l == lens[0]));
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(100.0), "100%");
        assert_eq!(pct(0.041), "0.041%");
        assert_eq!(pct(2.89), "2.89%");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
