//! Test-set evaluation: option scoring ("highest probability choice",
//! paper App. H) and greedy decoding with the paper's answer-parsing
//! rules (App. D): token F1 for DROP phrases, last-number match for
//! arithmetic.


use crate::data::example::Example;
use crate::data::metrics::{clean_generation, parse_last_number, token_f1};
use crate::data::tasks::Metric;
use crate::data::vocab::{BOS, EOS, PAD, SEP};
use crate::runtime::session::Session;
use crate::util::error::{Error, Result};

/// NaN-safe argmax over f64 scores: the index of the largest value by
/// `total_cmp` with NaN entries excluded (a single NaN score must not
/// panic the comparator — `partial_cmp(..).unwrap()` did — nor hijack
/// the choice, since `total_cmp` orders NaN above +inf).  Ties keep
/// the later index, matching `Iterator::max_by` on finite inputs; an
/// all-NaN (or empty) slice falls back to index 0.
fn argmax_total_f64(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// NaN-safe argmax over f32 logits (see [`argmax_total_f64`]).
fn argmax_total_f32(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Log-softmax value of `target` within one `[vocab]` logit row.
fn logprob_of(logits_row: &[f32], target: usize) -> f64 {
    let mx = logits_row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits_row.iter().map(|&v| ((v as f64) - mx).exp()).sum::<f64>().ln() + mx;
    logits_row[target] as f64 - lse
}

/// Score one option row: sum of answer-token log-probs.  `row` is the
/// packed sequence; positions `a0..a_end` hold the answer tokens.
fn score_row(logits: &[f32], row: &[i32], a0: usize, a_end: usize, vocab: usize) -> f64 {
    let mut sum = 0.0;
    for t in (a0 - 1)..(a_end - 1) {
        let lrow = &logits[t * vocab..(t + 1) * vocab];
        sum += logprob_of(lrow, row[t + 1] as usize);
    }
    sum
}

/// Evaluate accuracy of choice tasks by option scoring.
pub fn eval_choice(session: &Session, theta: &[f32], examples: &[Example]) -> Result<f64> {
    let io = &session.man.io;
    let (eb, s, vocab) = (io.eval_batch, io.seq_len, io.vocab);
    // Flatten (example, option) pairs into rows.
    struct Row {
        ex: usize,
        opt: usize,
        tokens: Vec<i32>,
        a0: usize,
        a_end: usize,
    }
    let mut rows = vec![];
    for (ei, ex) in examples.iter().enumerate() {
        if !ex.is_choice() {
            return Err(Error::Data("eval_choice on generation example".into()));
        }
        for (oi, opt) in ex.options.iter().enumerate() {
            let mut r = vec![BOS as i32];
            r.extend(ex.prompt.iter().map(|&t| t as i32));
            r.push(SEP as i32);
            let a0 = r.len();
            r.extend(opt.iter().map(|&t| t as i32));
            let a_end = r.len();
            if r.len() > s {
                return Err(Error::Data("option row too long".into()));
            }
            r.resize(s, PAD as i32);
            rows.push(Row { ex: ei, opt: oi, tokens: r, a0, a_end });
        }
    }
    // Batched forward + scoring.
    let mut scores: Vec<Vec<f64>> = examples.iter().map(|e| vec![0.0; e.options.len()]).collect();
    let mut i = 0;
    while i < rows.len() {
        let chunk = &rows[i..(i + eb).min(rows.len())];
        let mut tokens = Vec::with_capacity(eb * s);
        for r in chunk {
            tokens.extend(&r.tokens);
        }
        // pad the batch with the last row (scores discarded)
        for _ in chunk.len()..eb {
            tokens.extend(&chunk[chunk.len() - 1].tokens);
        }
        let logits = session.fwd_logits(theta, &tokens)?;
        for (k, r) in chunk.iter().enumerate() {
            let l = &logits[k * s * vocab..(k + 1) * s * vocab];
            scores[r.ex][r.opt] = score_row(l, &r.tokens, r.a0, r.a_end, vocab);
        }
        i += eb;
    }
    let mut correct = 0usize;
    for (ei, ex) in examples.iter().enumerate() {
        let best = argmax_total_f64(&scores[ei]);
        if best == ex.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / examples.len() as f64)
}

/// Greedy-decode continuations for a slice of generation examples.
/// Returns the generated token streams (EOS-trimmed).
pub fn greedy_decode(
    session: &Session,
    theta: &[f32],
    examples: &[Example],
    max_new: usize,
) -> Result<Vec<Vec<u16>>> {
    let io = &session.man.io;
    let (eb, s, vocab) = (io.eval_batch, io.seq_len, io.vocab);
    let mut outputs: Vec<Vec<u16>> = vec![vec![]; examples.len()];
    let mut i = 0;
    while i < examples.len() {
        let chunk = &examples[i..(i + eb).min(examples.len())];
        // current sequences: BOS prompt SEP
        let mut seqs: Vec<Vec<i32>> = chunk
            .iter()
            .map(|ex| {
                let mut r = vec![BOS as i32];
                r.extend(ex.prompt.iter().map(|&t| t as i32));
                r.push(SEP as i32);
                r
            })
            .collect();
        let mut done = vec![false; chunk.len()];
        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let mut tokens = Vec::with_capacity(eb * s);
            for sq in &seqs {
                let mut row = sq.clone();
                row.truncate(s);
                row.resize(s, PAD as i32);
                tokens.extend(row);
            }
            for _ in seqs.len()..eb {
                tokens.extend(std::iter::repeat(PAD as i32).take(s));
            }
            let logits = session.fwd_logits(theta, &tokens)?;
            for (k, sq) in seqs.iter_mut().enumerate() {
                if done[k] || sq.len() >= s {
                    done[k] = true;
                    continue;
                }
                let pos = sq.len() - 1;
                let lrow = &logits[(k * s + pos) * vocab..(k * s + pos + 1) * vocab];
                let next = argmax_total_f32(lrow) as i32;
                sq.push(next);
                if next == EOS as i32 {
                    done[k] = true;
                } else {
                    outputs[i + k].push(next as u16);
                }
            }
        }
        i += eb;
    }
    Ok(outputs.into_iter().map(|o| clean_generation(&o)).collect())
}

/// Evaluate generation examples with the given metric.
pub fn eval_generation(
    session: &Session,
    theta: &[f32],
    examples: &[Example],
    metric: Metric,
    max_new: usize,
) -> Result<f64> {
    let outs = greedy_decode(session, theta, examples, max_new)?;
    let mut total = 0.0;
    for (ex, out) in examples.iter().zip(&outs) {
        total += match metric {
            Metric::F1 => token_f1(out, &ex.answer),
            Metric::Accuracy => {
                let pred = parse_last_number(out);
                let gold = parse_last_number(&ex.answer);
                if pred.is_some() && pred == gold {
                    1.0
                } else {
                    0.0
                }
            }
        };
    }
    Ok(total / examples.len() as f64)
}

/// Dispatch on example kind + metric.
pub fn evaluate(
    session: &Session,
    theta: &[f32],
    examples: &[Example],
    metric: Metric,
) -> Result<f64> {
    if examples.is_empty() {
        return Ok(f64::NAN);
    }
    if examples[0].is_choice() {
        eval_choice(session, theta, examples)
    } else {
        eval_generation(session, theta, examples, metric, 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logprob_is_normalized() {
        let logits = vec![1.0f32, 2.0, 3.0, 0.5];
        let total: f64 = (0..4).map(|t| logprob_of(&logits, t).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn logprob_prefers_larger_logit() {
        let logits = vec![0.0f32, 5.0, 1.0];
        assert!(logprob_of(&logits, 1) > logprob_of(&logits, 0));
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // regression: a NaN logit/score used to panic the evaluator's
        // `partial_cmp(..).unwrap()` comparator — and must not win the
        // argmax either
        assert_eq!(argmax_total_f32(&[1.0, f32::NAN, 3.0, 2.0]), 2);
        assert_eq!(argmax_total_f32(&[f32::NAN, 1.0]), 1);
        assert_eq!(argmax_total_f32(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax_total_f32(&[]), 0);
        assert_eq!(argmax_total_f64(&[f64::NAN, -1.0, f64::NEG_INFINITY]), 1);
        // -inf is a value, not an absence: it can still win
        assert_eq!(argmax_total_f64(&[f64::NAN, f64::NEG_INFINITY]), 1);
        // finite behavior unchanged: last max wins ties, like max_by
        assert_eq!(argmax_total_f32(&[2.0, 5.0, 5.0, 1.0]), 2);
        assert_eq!(argmax_total_f64(&[0.5, 0.25]), 0);
    }
}
