//! Artifact-free fine-tuning: Adam + gradient clipping over the
//! pure-rust gradient engine (`quanta::grad`), no PJRT required.
//!
//! Mirrors the PJRT trainer's contract (`coordinator::trainer`): train
//! on minibatches from the train split, periodically evaluate on the
//! validation split, keep the **best checkpoint on validation loss**
//! (paper App. E), optionally early-stop on patience, and return the
//! same [`TrainOutcome`] shape — so downstream reporting treats host
//! and PJRT runs uniformly.
//!
//! The loop is generic over [`TrainableModel`] × [`RegressionTask`]
//! (this PR): the trainable state is whatever flat parameter vector
//! the model exposes — a single adapter's gates, or a whole
//! transformer block's per-projection [`crate::model::AdapterSet`] —
//! and examples are whatever panel width the task declares (one hidden
//! vector, or a whole sequence).  Frozen weights stay frozen by
//! construction: the backward never produces gradients for them.

use crate::compute::pool;
use crate::coordinator::checkpoint::{self, RunMeta};
use crate::coordinator::trainer::TrainOutcome;
use crate::data::batcher::{Sampler, SamplerState};
use crate::data::synth::RegressionTask;
use crate::info;
use crate::model::TrainableModel;
use crate::util::error::{Error, Result};
use crate::util::fault;
use crate::util::rng::RngState;
use std::path::{Path, PathBuf};

/// Approximate multiply-equivalent cost of one Adam parameter update
/// (EMAs, bias correction, rsqrt) — sizes the pool chunks so only
/// genuinely large parameter vectors fan out.
const ADAM_FLOPS_PER_PARAM: usize = 16;

/// Host fine-tuning configuration (Adam hyper-parameters follow the
/// paper's App. E defaults; `clip` is the global-norm ceiling, 0
/// disables clipping).  The schedule fields default to the PR 2
/// behavior — constant `lr`, no decay, no weight decay — bit-for-bit.
#[derive(Clone, Debug)]
pub struct HostTrainConfig {
    pub seed: u64,
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Global-norm gradient clip (0 = off).
    pub clip: f32,
    /// Linear warmup from `lr/warmup_steps` to `lr` over this many
    /// steps (0 = no warmup).
    pub warmup_steps: usize,
    /// Cosine decay from `lr` to `min_lr` over this many post-warmup
    /// steps (0 = constant after warmup).
    pub lr_decay_steps: usize,
    /// Cosine floor (only meaningful with `lr_decay_steps > 0`).
    pub min_lr: f32,
    /// Decoupled (AdamW-style) weight decay coefficient (0 = off).
    pub weight_decay: f32,
    pub eval_every: usize,
    pub log_every: usize,
    /// Stop after this many evals without val improvement (None = never).
    pub patience: Option<usize>,
    /// Anomaly recovery (DESIGN.md §11): on a non-finite loss or
    /// grad-norm the trainer rolls back to the best checkpoint, resets
    /// the optimizer moments, and scales the learning rate by
    /// `anomaly_backoff`; after this many rollbacks it gives up and
    /// returns a `TrainOutcome` with `diverged = true`.  Recovery is
    /// pure detection — a run that never trips an anomaly is bitwise
    /// identical to one trained with recovery disabled.
    pub anomaly_retries: usize,
    /// LR multiplier applied at each anomaly rollback (≤ 1).
    pub anomaly_backoff: f32,
    /// Write a v4 run manifest to `snapshot_path` every this many
    /// optimizer steps (0 = periodic snapshots off).  Requires
    /// `snapshot_path`.  Snapshot cadence is bitwise inert: it changes
    /// what is durable, never the trajectory.
    pub snapshot_every: usize,
    /// Where the run manifest lives.  `Some` with `snapshot_every == 0`
    /// still writes one final manifest when the run completes.
    pub snapshot_path: Option<PathBuf>,
    /// Resume from the manifest at `snapshot_path` if one exists
    /// (missing file ⇒ fresh start, so a relaunch after a crash in the
    /// very first snapshot window still works).  The manifest's config
    /// hash must match this config — see [`config_hash`].
    pub resume: bool,
    /// Test/bench seam: return an error immediately before this
    /// 0-indexed step executes, leaving only durable snapshots behind —
    /// the in-process stand-in for a crash (the real thing,
    /// `QFT_FAULT=crash@step`, aborts the process and can only be
    /// exercised from a subprocess).  Excluded from [`config_hash`].
    pub halt_before: Option<usize>,
}

impl Default for HostTrainConfig {
    fn default() -> Self {
        HostTrainConfig {
            seed: 0,
            steps: 200,
            batch: 32,
            lr: 2e-2,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            clip: 1.0,
            warmup_steps: 0,
            lr_decay_steps: 0,
            min_lr: 0.0,
            weight_decay: 0.0,
            eval_every: 20,
            log_every: 20,
            patience: None,
            anomaly_retries: 3,
            anomaly_backoff: 0.5,
            snapshot_every: 0,
            snapshot_path: None,
            resume: false,
            halt_before: None,
        }
    }
}

/// Hash of every trajectory-shaping field of a [`HostTrainConfig`] —
/// the resume guard: a manifest written under one config refuses to
/// seed a run under a different one, because the resumed trajectory
/// could not be bitwise equal to any uninterrupted run.  Durability
/// knobs (`snapshot_every`, `snapshot_path`, `resume`, `halt_before`)
/// are deliberately excluded: they never touch the trajectory, so
/// resuming under a different snapshot cadence is legal.  Floats enter
/// as IEEE bit patterns (two configs hash equal iff the trajectories
/// they drive are bitwise equal).
pub fn config_hash(cfg: &HostTrainConfig) -> u64 {
    let s = format!(
        "qft-train-v1|{}|{}|{}|{:08x}|{:08x}|{:08x}|{:08x}|{:08x}|{}|{}|{:08x}|{:08x}|{}|{}|{}|{}|{:08x}",
        cfg.seed,
        cfg.steps,
        cfg.batch,
        cfg.lr.to_bits(),
        cfg.beta1.to_bits(),
        cfg.beta2.to_bits(),
        cfg.eps.to_bits(),
        cfg.clip.to_bits(),
        cfg.warmup_steps,
        cfg.lr_decay_steps,
        cfg.min_lr.to_bits(),
        cfg.weight_decay.to_bits(),
        cfg.eval_every,
        cfg.log_every,
        cfg.patience.map_or(-1i64, |p| p as i64),
        cfg.anomaly_retries,
        cfg.anomaly_backoff.to_bits(),
    );
    crate::util::rng::hash_str(&s)
}

/// Linear-warmup + cosine-decay learning-rate schedule (the paper's
/// App. E recipe).  `at(step)` for a 0-indexed step:
///
/// * `step < warmup`: `base · (step+1) / warmup` (ramps *to* `base` at
///   the last warmup step);
/// * then cosine from `base` to `min_lr` over `decay_steps`, clamped at
///   `min_lr` afterwards;
/// * `warmup == 0 && decay_steps == 0`: exactly `base` (no float ops —
///   the PR 2 constant-lr trajectory stays bitwise identical).
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base: f32,
    pub warmup: usize,
    pub decay_steps: usize,
    pub min_lr: f32,
}

impl LrSchedule {
    pub fn from_config(cfg: &HostTrainConfig) -> LrSchedule {
        LrSchedule {
            base: cfg.lr,
            warmup: cfg.warmup_steps,
            decay_steps: cfg.lr_decay_steps,
            min_lr: cfg.min_lr,
        }
    }

    /// Learning rate for 0-indexed `step`.
    pub fn at(&self, step: usize) -> f32 {
        if self.warmup > 0 && step < self.warmup {
            return self.base * (step + 1) as f32 / self.warmup as f32;
        }
        if self.decay_steps == 0 {
            return self.base;
        }
        let done = (step - self.warmup).min(self.decay_steps) as f32;
        let progress = done / self.decay_steps as f32;
        self.min_lr
            + 0.5 * (self.base - self.min_lr) * (1.0 + (std::f32::consts::PI * progress).cos())
    }
}

/// Adam optimizer state over a flat parameter vector (bias-corrected,
/// Kingma & Ba 2015 — the same update the train_step HLO bakes in),
/// with optional decoupled (AdamW) weight decay.  Updates are
/// elementwise, so the pooled chunk split below cannot change any bit.
pub struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
}

/// One chunk of the Adam update (shared by the serial and pooled
/// paths; `wd > 0` adds the decoupled decay term `lr·wd·p`).
#[allow(clippy::too_many_arguments)]
fn adam_chunk(
    params: &mut [f32],
    grads: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    wd: f32,
    bc1: f32,
    bc2: f32,
) {
    for ((p, g), (m, v)) in params.iter_mut().zip(grads).zip(m.iter_mut().zip(v.iter_mut())) {
        *m = beta1 * *m + (1.0 - beta1) * g;
        *v = beta2 * *v + (1.0 - beta2) * g * g;
        let mh = *m / bc1;
        let vh = *v / bc2;
        let step = lr * mh / (vh.sqrt() + eps);
        // decoupled decay (Loshchilov & Hutter): applied to the
        // parameter, not routed through the moments; guarded so wd = 0
        // reproduces the PR 2 update bit-for-bit
        *p -= if wd > 0.0 { step + lr * wd * *p } else { step };
    }
}

impl Adam {
    pub fn new(n: usize, cfg: &HostTrainConfig) -> Adam {
        Adam {
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
            lr: cfg.lr,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
        }
    }

    /// Number of update steps taken (the bias-correction exponent).
    pub fn t(&self) -> u64 {
        self.t
    }

    /// Borrow the first/second-moment EMAs (run-manifest streams).
    pub fn moments(&self) -> (&[f32], &[f32]) {
        (&self.m, &self.v)
    }

    /// Rebuild an optimizer from snapshotted moments + step count; the
    /// next [`step_at`](Adam::step_at) continues exactly where the
    /// snapshotted optimizer would have.
    pub fn restore(cfg: &HostTrainConfig, m: Vec<f32>, v: Vec<f32>, t: u64) -> Result<Adam> {
        if m.len() != v.len() {
            return Err(Error::Data(format!(
                "Adam moment length mismatch: m {} vs v {}",
                m.len(),
                v.len()
            )));
        }
        Ok(Adam {
            m,
            v,
            t,
            lr: cfg.lr,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            weight_decay: cfg.weight_decay,
        })
    }

    /// One update step at the configured base `lr`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        let lr = self.lr;
        self.step_at(params, grads, lr);
    }

    /// One update step at an explicit learning rate (the scheduled
    /// path): `params ← params − lr · (m̂ / (√v̂ + ε) + wd · params)`,
    /// parallelized over parameter chunks on the compute pool.
    pub fn step_at(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), grads.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (b1, b2, eps, wd) = (self.beta1, self.beta2, self.eps, self.weight_decay);
        let (chunk, n_chunks) = pool::chunks(params.len(), ADAM_FLOPS_PER_PARAM);
        if n_chunks <= 1 {
            adam_chunk(params, grads, &mut self.m, &mut self.v, lr, b1, b2, eps, wd, bc1, bc2);
            return;
        }
        let pc = pool::DisjointChunks::new(params, chunk);
        let mc = pool::DisjointChunks::new(&mut self.m, chunk);
        let vc = pool::DisjointChunks::new(&mut self.v, chunk);
        pool::run(n_chunks, |i| {
            // SAFETY: params/m/v are chunked identically and each chunk
            // index is claimed exactly once.
            let p = unsafe { pc.slice(i) };
            let g = &grads[i * chunk..i * chunk + p.len()];
            adam_chunk(
                p,
                g,
                unsafe { mc.slice(i) },
                unsafe { vc.slice(i) },
                lr,
                b1,
                b2,
                eps,
                wd,
                bc1,
                bc2,
            );
        });
    }
}

/// Scale `grads` so its global L2 norm is at most `max_norm`; returns
/// the pre-clip norm.  No-op when `max_norm <= 0`.
pub fn clip_global_norm(grads: &mut [f32], max_norm: f32) -> f32 {
    let norm = grads.iter().map(|g| (*g as f64).powi(2)).sum::<f64>().sqrt() as f32;
    if max_norm > 0.0 && norm > max_norm {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

/// Mean-squared error over flat panels (f64 accumulation).
pub fn mse(pred: &[f32], target: &[f32]) -> f64 {
    debug_assert_eq!(pred.len(), target.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(target)
        .map(|(p, y)| ((p - y) as f64).powi(2))
        .sum::<f64>()
        / pred.len() as f64
}

/// MSE plus its gradient w.r.t. `pred` (`2 (pred − target) / n`).
pub fn mse_grad(pred: &[f32], target: &[f32]) -> (f64, Vec<f32>) {
    let n = pred.len().max(1) as f32;
    let grad = pred.iter().zip(target).map(|(p, y)| 2.0 * (p - y) / n).collect();
    (mse(pred, target), grad)
}

/// Mean validation loss of a model on the task's val split.
pub fn val_loss_host<M: TrainableModel>(model: &M, task: &impl RegressionTask) -> Result<f64> {
    if task.n_val() == 0 {
        return Ok(f64::NAN);
    }
    let (vx, vy) = task.val_xy();
    let pred = model.forward(vx, task.n_val())?;
    Ok(mse(&pred, vy))
}

/// Names (and order) of the f32 streams a run manifest carries.
const MANIFEST_STREAMS: [&str; 4] = ["params", "best_theta", "adam_m", "adam_v"];

/// Serialize the trainer's complete live state as a v4 run manifest.
/// Everything that shapes the remaining trajectory goes in; wallclock
/// deliberately does not, so the final manifest of a resumed run is
/// byte-identical to its uninterrupted twin (CI `cmp`s them).
#[allow(clippy::too_many_arguments)]
fn write_run_manifest(
    path: &Path,
    config_hash: u64,
    steps_run: usize,
    adam: &Adam,
    sampler: &Sampler,
    params: &[f32],
    best_theta: &[f32],
    best_val: f64,
    since_best: usize,
    anomalies: usize,
    lr_scale: f32,
    loss_curve: &[(usize, f64)],
    val_curve: &[(usize, f64)],
    done: bool,
    diverged: bool,
) -> Result<()> {
    let st = sampler.state();
    let (m, v) = adam.moments();
    let meta = RunMeta {
        config_hash,
        step: steps_run,
        adam_t: adam.t(),
        steps_run,
        anomalies,
        since_best,
        done,
        diverged,
        lr_scale,
        best_val,
        rng_state: st.rng.s,
        rng_spare: st.rng.spare,
        sampler_pos: st.pos,
        sampler_order: st.order,
        loss_curve: loss_curve.to_vec(),
        val_curve: val_curve.to_vec(),
    };
    checkpoint::save_manifest(
        path,
        &meta,
        &[
            (MANIFEST_STREAMS[0], params),
            (MANIFEST_STREAMS[1], best_theta),
            (MANIFEST_STREAMS[2], m),
            (MANIFEST_STREAMS[3], v),
        ],
    )
}

/// Fine-tune a model's flat parameters on a regression task with Adam +
/// global-norm gradient clipping.  Generic over [`TrainableModel`]
/// (single adapter or the full transformer block — same Adam, LR
/// schedule, clipping, and best-checkpoint contract).  The model is
/// left at the **final** parameters; `TrainOutcome::best_theta` holds
/// the best-on-validation checkpoint (load it with
/// [`TrainableModel::set_params`]).
///
/// With `snapshot_path` set the run is crash-consistent: a v4 run
/// manifest is written every `snapshot_every` steps and at completion,
/// and `resume: true` continues from the latest one such that the
/// resumed trajectory — params, curves, RNG draws, everything — is
/// bitwise identical to the uninterrupted run (DESIGN.md §13).
pub fn finetune_host<M: TrainableModel>(
    model: &mut M,
    task: &impl RegressionTask,
    cfg: &HostTrainConfig,
) -> Result<TrainOutcome> {
    let start = std::time::Instant::now();
    let ex = model.io_len();
    if task.example_len() != ex {
        return Err(Error::Config(format!(
            "task example_len {} != model io_len {ex}",
            task.example_len()
        )));
    }
    let degenerate = cfg.batch == 0
        || cfg.steps == 0
        || task.n_train() == 0
        || cfg.eval_every == 0
        || cfg.log_every == 0;
    if degenerate {
        return Err(Error::Config(format!(
            "degenerate run: steps {} batch {} n_train {} eval_every {} log_every {}",
            cfg.steps,
            cfg.batch,
            task.n_train(),
            cfg.eval_every,
            cfg.log_every
        )));
    }
    let (train_x, train_y) = task.train_xy();
    let mut params = model.params_flat();
    let mut adam = Adam::new(params.len(), cfg);
    let sched = LrSchedule::from_config(cfg);
    let mut sampler = Sampler::new(task.n_train(), cfg.seed);
    let mut xs = vec![0.0f32; cfg.batch * ex];
    let mut ys = vec![0.0f32; cfg.batch * ex];

    let mut best_theta = params.clone();
    let mut best_val = f64::INFINITY;
    let mut loss_curve = vec![];
    let mut val_curve = vec![];
    let mut since_best = 0usize;
    let mut steps_run = 0usize;
    let mut anomalies = 0usize;
    let mut diverged = false;
    let mut lr_scale = 1.0f32;

    // ── durability (DESIGN.md §13) ────────────────────────────────────
    let cfg_hash = config_hash(cfg);
    let snap_path = cfg.snapshot_path.as_deref();
    if cfg.snapshot_every > 0 && snap_path.is_none() {
        return Err(Error::Config("snapshot_every requires snapshot_path".into()));
    }
    if cfg.resume && snap_path.is_none() {
        return Err(Error::Config("resume requires snapshot_path".into()));
    }
    let mut start_step = 0usize;
    if cfg.resume {
        let path = snap_path.unwrap();
        if path.exists() {
            let (meta, streams) = checkpoint::load_manifest(path)?;
            if meta.config_hash != cfg_hash {
                return Err(Error::Config(format!(
                    "manifest {} was written under a different HostTrainConfig \
                     (hash {:016x} vs {:016x}): a resumed trajectory could not match \
                     any uninterrupted run, refusing",
                    path.display(),
                    meta.config_hash,
                    cfg_hash
                )));
            }
            if streams.len() != MANIFEST_STREAMS.len()
                || streams.iter().zip(MANIFEST_STREAMS).any(|((n, _), want)| n != want)
            {
                return Err(Error::Data(format!(
                    "manifest {} streams {:?} != expected {MANIFEST_STREAMS:?}",
                    path.display(),
                    streams.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>()
                )));
            }
            for (name, s) in &streams {
                if s.len() != params.len() {
                    return Err(Error::Data(format!(
                        "manifest stream {name:?} holds {} params, model has {}",
                        s.len(),
                        params.len()
                    )));
                }
            }
            if meta.sampler_order.len() != task.n_train() {
                return Err(Error::Data(format!(
                    "manifest sampler order covers {} examples, task has {}",
                    meta.sampler_order.len(),
                    task.n_train()
                )));
            }
            let mut it = streams.into_iter();
            let (_, p) = it.next().unwrap();
            let (_, b) = it.next().unwrap();
            let (_, am) = it.next().unwrap();
            let (_, av) = it.next().unwrap();
            params.copy_from_slice(&p);
            model.set_params(&params)?;
            best_theta.copy_from_slice(&b);
            adam = Adam::restore(cfg, am, av, meta.adam_t)?;
            sampler = Sampler::restore(SamplerState {
                order: meta.sampler_order,
                pos: meta.sampler_pos,
                rng: RngState { s: meta.rng_state, spare: meta.rng_spare },
            });
            best_val = meta.best_val;
            since_best = meta.since_best;
            anomalies = meta.anomalies;
            lr_scale = meta.lr_scale;
            loss_curve = meta.loss_curve;
            val_curve = meta.val_curve;
            steps_run = meta.steps_run;
            diverged = meta.diverged;
            start_step = meta.step;
            if meta.done {
                // the run already finished (completion, early stop, or
                // divergence): reconstruct its outcome without training
                info!(
                    "resume: manifest {} is complete at step {steps_run}, nothing to do",
                    path.display()
                );
                return Ok(TrainOutcome {
                    best_theta,
                    best_val_loss: best_val,
                    final_theta: params,
                    loss_curve,
                    val_curve,
                    steps_run,
                    wallclock_s: start.elapsed().as_secs_f64(),
                    anomalies,
                    diverged,
                });
            }
            info!("resume: continuing from step {start_step} of {} ({})", cfg.steps, path.display());
        } else {
            info!("resume: no manifest at {}, starting fresh", path.display());
        }
    }

    for step in start_step..cfg.steps {
        // `crash@step:n` aborts the process at the top of the n-th loop
        // iteration this process executes; `halt_before` is the
        // in-process equivalent for tests (durable snapshots survive,
        // everything else is dropped on the floor)
        fault::crash_point("step");
        if cfg.halt_before == Some(step) {
            return Err(Error::Compute(format!("halted before step {step} (halt_before test seam)")));
        }
        for (slot, &i) in sampler.next_indices(cfg.batch).iter().enumerate() {
            xs[slot * ex..(slot + 1) * ex].copy_from_slice(&train_x[i * ex..(i + 1) * ex]);
            ys[slot * ex..(slot + 1) * ex].copy_from_slice(&train_y[i * ex..(i + 1) * ex]);
        }
        let (pred, tape) = model.forward_with_tape(&xs, cfg.batch)?;
        let (mut loss, dpred) = mse_grad(&pred, &ys);
        // `nan@loss:n` probe: the injected anomaly the rollback tests
        // recover from
        if crate::util::fault::armed() {
            if let Some(crate::util::fault::Fault::Nan) = crate::util::fault::probe("loss") {
                loss = f64::NAN;
            }
        }
        // parameter gradients only — the input gradient is never used here
        let mut grads = model.backward_flat(&tape, &dpred, cfg.batch)?;
        let grad_norm = clip_global_norm(&mut grads, cfg.clip);
        // same per-element scan the serving intake/quarantine paths
        // run (util::numeric): a NaN hiding in a gradient whose norm
        // still reads finite must not reach the optimizer either
        if !loss.is_finite()
            || !grad_norm.is_finite()
            || crate::util::numeric::non_finite_at(&grads).is_some()
        {
            // anomaly: never let a non-finite update touch the
            // parameters.  Roll back to the best checkpoint (the init
            // params before the first eval), drop the stale Adam
            // moments (they were computed on the diverged trajectory),
            // and back the learning rate off; give up after the
            // configured number of retries.
            anomalies += 1;
            params.copy_from_slice(&best_theta);
            model.set_params(&params)?;
            if anomalies > cfg.anomaly_retries {
                info!(
                    "host trainer diverged at step {step}: anomaly {anomalies} exceeds \
                     {} retries, giving up at the best checkpoint",
                    cfg.anomaly_retries
                );
                diverged = true;
                break;
            }
            adam = Adam::new(params.len(), cfg);
            lr_scale *= cfg.anomaly_backoff;
            info!(
                "host trainer anomaly at step {step} (loss {loss}, grad norm {grad_norm}): \
                 rolled back, lr scale now {lr_scale}"
            );
            continue;
        }
        // the guard keeps the untripped trajectory bitwise identical:
        // `lr_scale` only multiplies once an anomaly has fired
        let lr = if anomalies == 0 { sched.at(step) } else { sched.at(step) * lr_scale };
        adam.step_at(&mut params, &grads, lr);
        model.set_params(&params)?;
        steps_run = step + 1;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            loss_curve.push((step, loss));
        }
        let is_eval = (step + 1) % cfg.eval_every == 0 || step + 1 == cfg.steps;
        if is_eval && task.n_val() > 0 {
            let vl = val_loss_host(model, task)?;
            val_curve.push((step + 1, vl));
            if vl < best_val {
                best_val = vl;
                best_theta.copy_from_slice(&params);
                since_best = 0;
            } else {
                since_best += 1;
                if let Some(p) = cfg.patience {
                    if since_best >= p {
                        info!("host early stop at step {} (no val gain for {} evals)", step + 1, p);
                        break;
                    }
                }
            }
        }
        // periodic durability point: after the optimizer step (and the
        // eval that may have just improved best_theta).  The final step
        // is skipped — the post-loop write below covers it with
        // `done = true`.
        if let Some(path) = snap_path {
            if cfg.snapshot_every > 0
                && (step + 1) % cfg.snapshot_every == 0
                && step + 1 != cfg.steps
            {
                write_run_manifest(
                    path, cfg_hash, steps_run, &adam, &sampler, &params, &best_theta, best_val,
                    since_best, anomalies, lr_scale, &loss_curve, &val_curve, false, false,
                )?;
            }
        }
    }
    if !best_val.is_finite() {
        best_theta.copy_from_slice(&params);
    }
    // terminal manifest (completion, early stop, or divergence all land
    // here): `done = true` makes a later `--resume` reconstruct the
    // outcome instead of training
    if let Some(path) = snap_path {
        write_run_manifest(
            path, cfg_hash, steps_run, &adam, &sampler, &params, &best_theta, best_val,
            since_best, anomalies, lr_scale, &loss_curve, &val_curve, true, diverged,
        )?;
    }
    Ok(TrainOutcome {
        best_theta,
        best_val_loss: best_val,
        final_theta: params,
        loss_curve,
        val_curve,
        steps_run,
        wallclock_s: start.elapsed().as_secs_f64(),
        anomalies,
        diverged,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{teacher_student, SynthConfig, SynthTask};

    fn tiny_task() -> SynthTask {
        teacher_student(&SynthConfig {
            dims: vec![2, 2, 2],
            n_train: 48,
            n_val: 16,
            teacher_std: 0.3,
            noise_std: 0.0,
            alpha: 1.0,
            seed: 7,
        })
        .unwrap()
    }

    #[test]
    fn adam_descends_a_quadratic() {
        // minimize ||p - c||² — Adam must make steady progress
        let c = [3.0f32, -1.0, 0.5];
        let mut p = [0.0f32; 3];
        let cfg = HostTrainConfig { lr: 0.1, ..Default::default() };
        let mut adam = Adam::new(3, &cfg);
        let f = |p: &[f32]| -> f32 { p.iter().zip(&c).map(|(a, b)| (a - b) * (a - b)).sum() };
        let f0 = f(&p);
        for _ in 0..200 {
            let g: Vec<f32> = p.iter().zip(&c).map(|(a, b)| 2.0 * (a - b)).collect();
            adam.step(&mut p, &g);
        }
        assert!(f(&p) < 0.01 * f0, "Adam failed to descend: {} -> {}", f0, f(&p));
    }

    #[test]
    fn lr_schedule_pinned_values() {
        // warmup 10, cosine over 100 to min 0.01 — values pinned at
        // steps {0, warmup, mid, end} and past the end (mirrored by
        // train_mirror.py::lr_schedule_at with the same constants)
        let s = LrSchedule { base: 0.1, warmup: 10, decay_steps: 100, min_lr: 0.01 };
        assert!((s.at(0) - 0.01).abs() < 1e-7, "step 0: {}", s.at(0));
        assert!((s.at(9) - 0.1).abs() < 1e-7, "last warmup step: {}", s.at(9));
        assert!((s.at(10) - 0.1).abs() < 1e-7, "step warmup: {}", s.at(10));
        assert!((s.at(60) - 0.055).abs() < 1e-6, "mid decay: {}", s.at(60));
        assert!((s.at(110) - 0.01).abs() < 1e-7, "end: {}", s.at(110));
        assert!((s.at(500) - 0.01).abs() < 1e-7, "past end clamps: {}", s.at(500));
        // disabled schedule returns base exactly (bitwise PR 2 path)
        let c = LrSchedule { base: 2e-2, warmup: 0, decay_steps: 0, min_lr: 0.0 };
        assert_eq!(c.at(0), 2e-2);
        assert_eq!(c.at(12345), 2e-2);
    }

    #[test]
    fn decoupled_weight_decay_shrinks_without_gradients() {
        // zero gradients → zero Adam step, so the only motion is the
        // decoupled decay p ← p·(1 − lr·wd) per step (exactly)
        let cfg = HostTrainConfig { lr: 0.1, weight_decay: 0.5, ..Default::default() };
        let mut adam = Adam::new(2, &cfg);
        let mut p = [2.0f32, -4.0];
        let g = [0.0f32, 0.0];
        adam.step(&mut p, &g);
        assert_eq!(p, [2.0 * (1.0 - 0.1 * 0.5), -4.0 * (1.0 - 0.1 * 0.5)]);
        // wd = 0 leaves zero-grad params exactly in place
        let cfg0 = HostTrainConfig { lr: 0.1, ..Default::default() };
        let mut adam0 = Adam::new(2, &cfg0);
        let mut q = [2.0f32, -4.0];
        adam0.step(&mut q, &g);
        assert_eq!(q, [2.0, -4.0]);
    }

    #[test]
    fn scheduled_run_still_learns() {
        // warmup + cosine + mild weight decay on the tiny task must
        // still cut the loss (end-to-end wiring of the schedule path)
        let task = tiny_task();
        let mut student = task.student().unwrap();
        let init = {
            let pred = student.apply_batch(&task.train_x, task.n_train).unwrap();
            mse(&pred, &task.train_y)
        };
        let cfg = HostTrainConfig {
            steps: 120,
            batch: 16,
            warmup_steps: 10,
            lr_decay_steps: 110,
            min_lr: 1e-3,
            weight_decay: 1e-4,
            ..Default::default()
        };
        let out = finetune_host(&mut student, &task, &cfg).unwrap();
        let fin = {
            let pred = student.apply_batch(&task.train_x, task.n_train).unwrap();
            mse(&pred, &task.train_y)
        };
        assert!(fin < 0.5 * init, "scheduled run failed to learn: {init} -> {fin}");
        assert_eq!(out.steps_run, 120);
    }

    #[test]
    fn clip_preserves_direction_and_caps_norm() {
        let mut g = [3.0f32, 4.0];
        let pre = clip_global_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        assert!((g[0] - 0.6).abs() < 1e-6 && (g[1] - 0.8).abs() < 1e-6);
        let mut h = [0.3f32, 0.4];
        clip_global_norm(&mut h, 1.0);
        assert_eq!(h, [0.3, 0.4], "norms under the ceiling must pass through");
        let mut u = [3.0f32, 4.0];
        clip_global_norm(&mut u, 0.0);
        assert_eq!(u, [3.0, 4.0], "clip 0 disables clipping");
    }

    #[test]
    fn host_trainer_learns_the_teacher_delta() {
        let task = tiny_task();
        let mut student = task.student().unwrap();
        let init = {
            let pred = student.apply_batch(&task.train_x, task.n_train).unwrap();
            mse(&pred, &task.train_y)
        };
        let cfg = HostTrainConfig { steps: 120, batch: 16, eval_every: 20, ..Default::default() };
        let out = finetune_host(&mut student, &task, &cfg).unwrap();
        let fin = {
            let pred = student.apply_batch(&task.train_x, task.n_train).unwrap();
            mse(&pred, &task.train_y)
        };
        assert!(
            fin < 0.5 * init,
            "train loss did not halve: {init} -> {fin} (curve {:?})",
            out.loss_curve
        );
        assert!(out.best_val_loss.is_finite());
        assert_eq!(out.steps_run, 120);
    }

    #[test]
    fn best_checkpoint_contract_matches_pjrt_trainer() {
        // best_theta must correspond to the best recorded val loss, and
        // loading it must reproduce that loss exactly.
        let task = tiny_task();
        let mut student = task.student().unwrap();
        let cfg = HostTrainConfig { steps: 60, batch: 16, eval_every: 10, ..Default::default() };
        let out = finetune_host(&mut student, &task, &cfg).unwrap();
        let min_curve = out
            .val_curve
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(out.best_val_loss, min_curve);
        student.set_params(&out.best_theta).unwrap();
        let reloaded = val_loss_host(&student, &task).unwrap();
        assert!((reloaded - out.best_val_loss).abs() < 1e-12);
    }

    #[test]
    fn generic_trainer_drives_the_block() {
        // the same loop that trains a single adapter trains the full
        // multi-adapter transformer block through TrainableModel
        use crate::data::synth::{block_teacher_student, BlockSynthConfig};
        let task = block_teacher_student(&BlockSynthConfig {
            dims: vec![2, 2],
            n_heads: 2,
            seq: 3,
            d_ff: 8,
            n_train: 24,
            n_val: 8,
            teacher_std: 0.3,
            noise_std: 0.0,
            alpha: 1.0,
            seed: 5,
        })
        .unwrap();
        let mut student = task.student();
        let seq = student.seq();
        let init = {
            let pred = student.forward(&task.train_x, task.n_train, seq).unwrap();
            mse(&pred, &task.train_y)
        };
        let cfg = HostTrainConfig { steps: 120, batch: 8, eval_every: 20, ..Default::default() };
        let out = finetune_host(&mut student, &task, &cfg).unwrap();
        let fin = {
            let pred = student.forward(&task.train_x, task.n_train, seq).unwrap();
            mse(&pred, &task.train_y)
        };
        assert!(fin < 0.5 * init, "block failed to learn: {init} -> {fin}");
        assert!(out.best_val_loss.is_finite());
        // best-checkpoint contract holds for the block too
        student.set_params(&out.best_theta).unwrap();
        let reloaded = val_loss_host(&student, &task).unwrap();
        assert!((reloaded - out.best_val_loss).abs() < 1e-12);
    }

    #[test]
    fn config_hash_tracks_trajectory_fields_only() {
        let base = HostTrainConfig::default();
        assert_eq!(config_hash(&base), config_hash(&base.clone()));
        // every durability knob is hash-inert (resume under a different
        // snapshot cadence is legal)
        let durable = HostTrainConfig {
            snapshot_every: 50,
            snapshot_path: Some(PathBuf::from("/tmp/x.bin")),
            resume: true,
            halt_before: Some(3),
            ..base.clone()
        };
        assert_eq!(config_hash(&base), config_hash(&durable));
        // any trajectory-shaping field flips the hash
        for tweaked in [
            HostTrainConfig { seed: 1, ..base.clone() },
            HostTrainConfig { steps: 201, ..base.clone() },
            HostTrainConfig { lr: 2e-2 + 1e-6, ..base.clone() },
            HostTrainConfig { eval_every: 21, ..base.clone() },
            HostTrainConfig { patience: Some(3), ..base.clone() },
            HostTrainConfig { anomaly_backoff: 0.25, ..base.clone() },
        ] {
            assert_ne!(config_hash(&base), config_hash(&tweaked), "{tweaked:?}");
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let task = tiny_task();
        let cfg = HostTrainConfig { steps: 30, batch: 8, ..Default::default() };
        let mut s1 = task.student().unwrap();
        let mut s2 = task.student().unwrap();
        let o1 = finetune_host(&mut s1, &task, &cfg).unwrap();
        let o2 = finetune_host(&mut s2, &task, &cfg).unwrap();
        assert_eq!(o1.final_theta, o2.final_theta);
        assert_eq!(o1.loss_curve, o2.loss_curve);
    }

    #[test]
    fn anomaly_recovery_is_inert_when_untripped() {
        // recovery is pure detection: with no anomaly fired, every
        // recovery hyper-parameter must leave the trajectory bitwise
        // unchanged (the lr_scale multiply is guarded behind the first
        // anomaly)
        let task = tiny_task();
        let base = HostTrainConfig { steps: 30, batch: 8, ..Default::default() };
        let tight = HostTrainConfig { anomaly_retries: 0, anomaly_backoff: 0.01, ..base.clone() };
        let mut s1 = task.student().unwrap();
        let mut s2 = task.student().unwrap();
        let o1 = finetune_host(&mut s1, &task, &base).unwrap();
        let o2 = finetune_host(&mut s2, &task, &tight).unwrap();
        assert_eq!(o1.final_theta, o2.final_theta);
        assert_eq!(o1.loss_curve, o2.loss_curve);
        assert_eq!(o1.anomalies, 0);
        assert!(!o1.diverged);
    }
}
