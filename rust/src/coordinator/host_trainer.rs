//! Artifact-free fine-tuning: Adam + gradient clipping over the
//! pure-rust gradient engine (`quanta::grad`), no PJRT required.
//!
//! Mirrors the PJRT trainer's contract (`coordinator::trainer`): train
//! on minibatches from the train split, periodically evaluate on the
//! validation split, keep the **best checkpoint on validation loss**
//! (paper App. E), optionally early-stop on patience, and return the
//! same [`TrainOutcome`] shape — so downstream reporting treats host
//! and PJRT runs uniformly.  The trainable state is the adapter's flat
//! gate-parameter vector; the base weight stays frozen by construction
//! (the backward never produces a gradient for it).

use crate::coordinator::trainer::TrainOutcome;
use crate::data::batcher::Sampler;
use crate::data::synth::SynthTask;
use crate::info;
use crate::quanta::QuantaAdapter;
use crate::util::error::{Error, Result};

/// Host fine-tuning configuration (Adam hyper-parameters follow the
/// paper's App. E defaults; `clip` is the global-norm ceiling, 0
/// disables clipping).
#[derive(Clone, Debug)]
pub struct HostTrainConfig {
    pub seed: u64,
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Global-norm gradient clip (0 = off).
    pub clip: f32,
    pub eval_every: usize,
    pub log_every: usize,
    /// Stop after this many evals without val improvement (None = never).
    pub patience: Option<usize>,
}

impl Default for HostTrainConfig {
    fn default() -> Self {
        HostTrainConfig {
            seed: 0,
            steps: 200,
            batch: 32,
            lr: 2e-2,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            clip: 1.0,
            eval_every: 20,
            log_every: 20,
            patience: None,
        }
    }
}

/// Adam optimizer state over a flat parameter vector (bias-corrected,
/// Kingma & Ba 2015 — the same update the train_step HLO bakes in).
pub struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
}

impl Adam {
    pub fn new(n: usize, cfg: &HostTrainConfig) -> Adam {
        Adam {
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
            lr: cfg.lr,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
        }
    }

    /// One update step: `params ← params − lr · m̂ / (√v̂ + ε)`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(params.len(), grads.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            *m = self.beta1 * *m + (1.0 - self.beta1) * g;
            *v = self.beta2 * *v + (1.0 - self.beta2) * g * g;
            let mh = *m / bc1;
            let vh = *v / bc2;
            *p -= self.lr * mh / (vh.sqrt() + self.eps);
        }
    }
}

/// Scale `grads` so its global L2 norm is at most `max_norm`; returns
/// the pre-clip norm.  No-op when `max_norm <= 0`.
pub fn clip_global_norm(grads: &mut [f32], max_norm: f32) -> f32 {
    let norm = grads.iter().map(|g| (*g as f64).powi(2)).sum::<f64>().sqrt() as f32;
    if max_norm > 0.0 && norm > max_norm {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

/// Mean-squared error over flat panels (f64 accumulation).
pub fn mse(pred: &[f32], target: &[f32]) -> f64 {
    debug_assert_eq!(pred.len(), target.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(target)
        .map(|(p, y)| ((p - y) as f64).powi(2))
        .sum::<f64>()
        / pred.len() as f64
}

/// MSE plus its gradient w.r.t. `pred` (`2 (pred − target) / n`).
pub fn mse_grad(pred: &[f32], target: &[f32]) -> (f64, Vec<f32>) {
    let n = pred.len().max(1) as f32;
    let grad = pred.iter().zip(target).map(|(p, y)| 2.0 * (p - y) / n).collect();
    (mse(pred, target), grad)
}

/// Mean validation loss of the adapter on the task's val split.
pub fn val_loss_host(adapter: &QuantaAdapter, task: &SynthTask) -> Result<f64> {
    if task.n_val == 0 {
        return Ok(f64::NAN);
    }
    let pred = adapter.apply_batch(&task.val_x, task.n_val)?;
    Ok(mse(&pred, &task.val_y))
}

/// Fine-tune the adapter's circuit on a synthetic task with Adam +
/// global-norm gradient clipping.  The adapter is left at the **final**
/// parameters; `TrainOutcome::best_theta` holds the best-on-validation
/// checkpoint (load it with [`QuantaAdapter::set_params`]).
pub fn finetune_host(
    adapter: &mut QuantaAdapter,
    task: &SynthTask,
    cfg: &HostTrainConfig,
) -> Result<TrainOutcome> {
    let start = std::time::Instant::now();
    let d = adapter.d();
    if task.d != d {
        return Err(Error::Config(format!("task d {} != adapter d {d}", task.d)));
    }
    let degenerate = cfg.batch == 0
        || cfg.steps == 0
        || task.n_train == 0
        || cfg.eval_every == 0
        || cfg.log_every == 0;
    if degenerate {
        return Err(Error::Config(format!(
            "degenerate run: steps {} batch {} n_train {} eval_every {} log_every {}",
            cfg.steps, cfg.batch, task.n_train, cfg.eval_every, cfg.log_every
        )));
    }
    let mut params = adapter.params_flat();
    let mut adam = Adam::new(params.len(), cfg);
    let mut sampler = Sampler::new(task.n_train, cfg.seed);
    let mut xs = vec![0.0f32; cfg.batch * d];
    let mut ys = vec![0.0f32; cfg.batch * d];

    let mut best_theta = params.clone();
    let mut best_val = f64::INFINITY;
    let mut loss_curve = vec![];
    let mut val_curve = vec![];
    let mut since_best = 0usize;
    let mut steps_run = 0usize;

    for step in 0..cfg.steps {
        for (slot, &i) in sampler.next_indices(cfg.batch).iter().enumerate() {
            xs[slot * d..(slot + 1) * d].copy_from_slice(&task.train_x[i * d..(i + 1) * d]);
            ys[slot * d..(slot + 1) * d].copy_from_slice(&task.train_y[i * d..(i + 1) * d]);
        }
        let (pred, tape) = adapter.forward_with_tape(&xs, cfg.batch)?;
        let (loss, dpred) = mse_grad(&pred, &ys);
        // gate gradients only — the input gradient is never used here
        let mut grads = adapter.backward_gates(&tape, &dpred, cfg.batch)?;
        clip_global_norm(&mut grads, cfg.clip);
        adam.step(&mut params, &grads);
        adapter.set_params(&params)?;
        steps_run = step + 1;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            loss_curve.push((step, loss));
        }
        let is_eval = (step + 1) % cfg.eval_every == 0 || step + 1 == cfg.steps;
        if is_eval && task.n_val > 0 {
            let vl = val_loss_host(adapter, task)?;
            val_curve.push((step + 1, vl));
            if vl < best_val {
                best_val = vl;
                best_theta.copy_from_slice(&params);
                since_best = 0;
            } else {
                since_best += 1;
                if let Some(p) = cfg.patience {
                    if since_best >= p {
                        info!("host early stop at step {} (no val gain for {} evals)", step + 1, p);
                        break;
                    }
                }
            }
        }
    }
    if !best_val.is_finite() {
        best_theta.copy_from_slice(&params);
    }
    Ok(TrainOutcome {
        best_theta,
        best_val_loss: best_val,
        final_theta: params,
        loss_curve,
        val_curve,
        steps_run,
        wallclock_s: start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{teacher_student, SynthConfig};

    fn tiny_task() -> SynthTask {
        teacher_student(&SynthConfig {
            dims: vec![2, 2, 2],
            n_train: 48,
            n_val: 16,
            teacher_std: 0.3,
            noise_std: 0.0,
            alpha: 1.0,
            seed: 7,
        })
        .unwrap()
    }

    #[test]
    fn adam_descends_a_quadratic() {
        // minimize ||p - c||² — Adam must make steady progress
        let c = [3.0f32, -1.0, 0.5];
        let mut p = [0.0f32; 3];
        let cfg = HostTrainConfig { lr: 0.1, ..Default::default() };
        let mut adam = Adam::new(3, &cfg);
        let f = |p: &[f32]| -> f32 { p.iter().zip(&c).map(|(a, b)| (a - b) * (a - b)).sum() };
        let f0 = f(&p);
        for _ in 0..200 {
            let g: Vec<f32> = p.iter().zip(&c).map(|(a, b)| 2.0 * (a - b)).collect();
            adam.step(&mut p, &g);
        }
        assert!(f(&p) < 0.01 * f0, "Adam failed to descend: {} -> {}", f0, f(&p));
    }

    #[test]
    fn clip_preserves_direction_and_caps_norm() {
        let mut g = [3.0f32, 4.0];
        let pre = clip_global_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        assert!((g[0] - 0.6).abs() < 1e-6 && (g[1] - 0.8).abs() < 1e-6);
        let mut h = [0.3f32, 0.4];
        clip_global_norm(&mut h, 1.0);
        assert_eq!(h, [0.3, 0.4], "norms under the ceiling must pass through");
        let mut u = [3.0f32, 4.0];
        clip_global_norm(&mut u, 0.0);
        assert_eq!(u, [3.0, 4.0], "clip 0 disables clipping");
    }

    #[test]
    fn host_trainer_learns_the_teacher_delta() {
        let task = tiny_task();
        let mut student = task.student().unwrap();
        let init = {
            let pred = student.apply_batch(&task.train_x, task.n_train).unwrap();
            mse(&pred, &task.train_y)
        };
        let cfg = HostTrainConfig { steps: 120, batch: 16, eval_every: 20, ..Default::default() };
        let out = finetune_host(&mut student, &task, &cfg).unwrap();
        let fin = {
            let pred = student.apply_batch(&task.train_x, task.n_train).unwrap();
            mse(&pred, &task.train_y)
        };
        assert!(
            fin < 0.5 * init,
            "train loss did not halve: {init} -> {fin} (curve {:?})",
            out.loss_curve
        );
        assert!(out.best_val_loss.is_finite());
        assert_eq!(out.steps_run, 120);
    }

    #[test]
    fn best_checkpoint_contract_matches_pjrt_trainer() {
        // best_theta must correspond to the best recorded val loss, and
        // loading it must reproduce that loss exactly.
        let task = tiny_task();
        let mut student = task.student().unwrap();
        let cfg = HostTrainConfig { steps: 60, batch: 16, eval_every: 10, ..Default::default() };
        let out = finetune_host(&mut student, &task, &cfg).unwrap();
        let min_curve = out
            .val_curve
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(out.best_val_loss, min_curve);
        student.set_params(&out.best_theta).unwrap();
        let reloaded = val_loss_host(&student, &task).unwrap();
        assert!((reloaded - out.best_val_loss).abs() < 1e-12);
    }

    #[test]
    fn deterministic_across_runs() {
        let task = tiny_task();
        let cfg = HostTrainConfig { steps: 30, batch: 8, ..Default::default() };
        let mut s1 = task.student().unwrap();
        let mut s2 = task.student().unwrap();
        let o1 = finetune_host(&mut s1, &task, &cfg).unwrap();
        let o2 = finetune_host(&mut s2, &task, &cfg).unwrap();
        assert_eq!(o1.final_theta, o2.final_theta);
        assert_eq!(o1.loss_curve, o2.loss_curve);
    }
}
