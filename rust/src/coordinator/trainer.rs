//! Training loops: pretraining (full LM on the synthetic corpus) and
//! supervised fine-tuning with best-checkpoint selection on validation
//! loss (paper App. E: "we choose the best checkpoint obtained during
//! fine-tuning ... on the validation set").

use crate::data::batcher::{pack_batch, Batch, Sampler};
use crate::data::corpus;
use crate::data::example::TaskData;
use crate::data::tokenizer::Tokenizer;
use crate::info;
use crate::runtime::session::Session;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Fine-tuning loop configuration (steps default to the schedule baked
/// into the artifact's train_step HLO).
#[derive(Clone, Debug)]
pub struct FinetuneConfig {
    pub seed: u64,
    pub steps: Option<usize>,
    pub eval_every: usize,
    pub log_every: usize,
    /// stop after this many evals without val improvement (None = never)
    pub patience: Option<usize>,
}

impl Default for FinetuneConfig {
    fn default() -> Self {
        FinetuneConfig { seed: 0, steps: None, eval_every: 50, log_every: 50, patience: None }
    }
}

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub best_theta: Vec<f32>,
    pub best_val_loss: f64,
    pub final_theta: Vec<f32>,
    pub loss_curve: Vec<(usize, f64)>,
    pub val_curve: Vec<(usize, f64)>,
    pub steps_run: usize,
    pub wallclock_s: f64,
    /// Non-finite loss/grad anomalies absorbed by checkpoint rollback
    /// (host trainer's recovery, DESIGN.md §11; always 0 on the PJRT
    /// path).
    pub anomalies: usize,
    /// True when anomaly retries were exhausted and the run gave up at
    /// the best checkpoint instead of finishing its step budget.
    pub diverged: bool,
}

/// Compute mean validation loss over (up to) `max_batches` eval batches.
pub fn val_loss(session: &Session, theta: &[f32], data: &TaskData) -> Result<f64> {
    let io = &session.man.io;
    let eb = io.eval_batch;
    let examples = &data.val;
    if examples.is_empty() {
        return Ok(f64::NAN);
    }
    let mut loss_sum = 0.0f64;
    let mut tok_sum = 0.0f64;
    let mut i = 0;
    while i < examples.len() {
        let chunk: Vec<&_> = examples[i..(i + eb).min(examples.len())].iter().collect();
        let b: Batch = pack_batch(&chunk, eb, io.seq_len)?;
        // mask out the repeated tail rows so they don't bias the loss
        let mut mask = b.mask.clone();
        for r in chunk.len()..eb {
            for t in 0..io.seq_len {
                mask[r * io.seq_len + t] = 0.0;
            }
        }
        let (ls, tc) = session.eval_loss(theta, &b.tokens, &mask)?;
        loss_sum += ls as f64;
        tok_sum += tc as f64;
        i += eb;
    }
    Ok(loss_sum / tok_sum.max(1.0))
}

/// Supervised fine-tuning on a task's train split.
pub fn finetune(
    session: &mut Session,
    data: &TaskData,
    cfg: &FinetuneConfig,
) -> Result<TrainOutcome> {
    let start = std::time::Instant::now();
    let io = session.man.io.clone();
    let total_steps = cfg.steps.unwrap_or(session.man.hyper.total_steps);
    let mut state = session.init_state(cfg.seed)?;
    let mut sampler = Sampler::new(data.train.len(), cfg.seed);

    let mut best_theta = state.theta.clone();
    let mut best_val = f64::INFINITY;
    let mut loss_curve = vec![];
    let mut val_curve = vec![];
    let mut since_best = 0usize;
    let mut steps_run = 0usize;

    for step in 0..total_steps {
        let idx = sampler.next_indices(io.batch);
        let exs: Vec<&_> = idx.iter().map(|&i| &data.train[i]).collect();
        let b = pack_batch(&exs, io.batch, io.seq_len)?;
        let loss = session.train_step(&mut state, &b.tokens, &b.mask)?;
        steps_run = step + 1;
        if step % cfg.log_every == 0 || step + 1 == total_steps {
            loss_curve.push((step, loss as f64));
        }
        let is_eval = (step + 1) % cfg.eval_every == 0 || step + 1 == total_steps;
        if is_eval && !data.val.is_empty() {
            let vl = val_loss(session, &state.theta, data)?;
            val_curve.push((step + 1, vl));
            if vl < best_val {
                best_val = vl;
                best_theta.copy_from_slice(&state.theta);
                since_best = 0;
            } else {
                since_best += 1;
                if let Some(p) = cfg.patience {
                    if since_best >= p {
                        info!("early stop at step {} (no val gain for {} evals)", step + 1, p);
                        break;
                    }
                }
            }
        }
    }
    if !best_val.is_finite() {
        best_theta.copy_from_slice(&state.theta);
    }
    Ok(TrainOutcome {
        best_theta,
        best_val_loss: best_val,
        final_theta: state.theta,
        loss_curve,
        val_curve,
        steps_run,
        wallclock_s: start.elapsed().as_secs_f64(),
        anomalies: 0,
        diverged: false,
    })
}

/// Pretraining: causal LM on the synthetic corpus (all parameters
/// trainable; the artifact's base input is a dummy scalar).
pub fn pretrain(
    session: &mut Session,
    tok: &Tokenizer,
    seed: u64,
    steps: Option<usize>,
) -> Result<TrainOutcome> {
    let start = std::time::Instant::now();
    let io = session.man.io.clone();
    let total = steps.unwrap_or(session.man.hyper.total_steps);
    let mut state = session.init_state(seed)?;
    let mut rng = Rng::stream(seed, "pretrain-data");
    let mut loss_curve = vec![];
    for step in 0..total {
        let (tokens, mask) = corpus::pretrain_batch(tok, &mut rng, io.batch, io.seq_len);
        let loss = session.train_step(&mut state, &tokens, &mask)?;
        if step % 50 == 0 || step + 1 == total {
            loss_curve.push((step, loss as f64));
            info!("pretrain[{}] step {:4}/{} loss {:.4}", session.man.name, step, total, loss);
        }
    }
    Ok(TrainOutcome {
        best_theta: state.theta.clone(),
        best_val_loss: f64::NAN,
        final_theta: state.theta,
        loss_curve,
        val_curve: vec![],
        steps_run: total,
        wallclock_s: start.elapsed().as_secs_f64(),
        anomalies: 0,
        diverged: false,
    })
}
