//! Experiment runner: (artifact set x training task x seeds) -> cached,
//! aggregated metrics.  This is the layer every bench target drives; a
//! run that is already cached in `results/` is re-rendered without
//! retraining, so tables that share rows (Table 2 / F.5 / Fig. 4) reuse
//! each other's fine-tunes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::runtime::pjrt::PjRtClient;

use crate::coordinator::checkpoint;
use crate::coordinator::evaluator;
use crate::coordinator::trainer::{self, FinetuneConfig};
use crate::data::tasks::{self, Sizes};
use crate::data::tokenizer::Tokenizer;
use crate::data::TaskData;
use crate::info;
use crate::runtime::manifest::Manifest;
use crate::runtime::session::Session;
use crate::util::error::{Error, Result};
use crate::util::json::Value;
use crate::util::rng::hash_str;
use crate::util::stats;

/// What to fine-tune on.
#[derive(Clone, Debug)]
pub enum TrainTask {
    /// A single task (also evaluated on it unless eval_tasks overrides).
    Single(String),
    /// A mixed suite (commonsense_mix / math_mix protocol).
    Mix(Vec<String>),
}

impl TrainTask {
    fn cache_tag(&self) -> String {
        match self {
            TrainTask::Single(t) => t.clone(),
            TrainTask::Mix(ts) => format!("mix[{}]", ts.join("+")),
        }
    }
}

/// One experiment: an artifact set fine-tuned on a task, evaluated on
/// one or more test suites, across seeds.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub set: String,
    pub train: TrainTask,
    pub eval_tasks: Vec<String>,
    pub seeds: Vec<u64>,
    pub steps: Option<usize>,
    pub sizes: Sizes,
    pub data_seed: u64,
}

impl RunSpec {
    pub fn new(set: &str, task: &str) -> Self {
        RunSpec {
            set: set.into(),
            train: TrainTask::Single(task.into()),
            eval_tasks: vec![task.into()],
            seeds: vec![0, 1],
            steps: None,
            sizes: Sizes::default(),
            data_seed: 1234,
        }
    }

    pub fn mix(set: &str, suite: &[&str]) -> Self {
        RunSpec {
            set: set.into(),
            train: TrainTask::Mix(suite.iter().map(|s| s.to_string()).collect()),
            eval_tasks: suite.iter().map(|s| s.to_string()).collect(),
            seeds: vec![0, 1],
            steps: None,
            sizes: Sizes::default(),
            data_seed: 1234,
        }
    }

    pub fn with_seeds(mut self, seeds: &[u64]) -> Self {
        self.seeds = seeds.to_vec();
        self
    }

    pub fn with_steps(mut self, steps: usize) -> Self {
        self.steps = Some(steps);
        self
    }

    pub fn cache_key(&self) -> String {
        let blob = format!(
            "{}|{}|{:?}|{:?}|{:?}|{}-{}-{}|{}",
            self.set,
            self.train.cache_tag(),
            self.eval_tasks,
            self.seeds,
            self.steps,
            self.sizes.train,
            self.sizes.val,
            self.sizes.test,
            self.data_seed,
        );
        format!("{}_{:016x}", self.set, hash_str(&blob))
    }
}

/// Aggregated result of one RunSpec.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub spec_set: String,
    pub trainable_percent: f64,
    pub trainable_params: usize,
    /// per eval task: per-seed metric values
    pub per_task: BTreeMap<String, Vec<f64>>,
    pub train_seconds: f64,
}

impl RunResult {
    pub fn mean(&self, task: &str) -> f64 {
        stats::mean(self.per_task.get(task).map(|v| v.as_slice()).unwrap_or(&[]))
    }

    pub fn std(&self, task: &str) -> f64 {
        stats::std_dev(self.per_task.get(task).map(|v| v.as_slice()).unwrap_or(&[]))
    }

    /// Mean over tasks of per-task means (Table 3/4 "Avg." column);
    /// `skip` lists excluded tasks (AQuA rule).
    pub fn avg(&self, skip: &[&str]) -> f64 {
        let vals: Vec<f64> = self
            .per_task
            .iter()
            .filter(|(k, _)| !skip.contains(&k.as_str()))
            .map(|(_, v)| stats::mean(v))
            .collect();
        stats::mean(&vals)
    }

    fn to_json(&self) -> Value {
        let mut tasks = BTreeMap::new();
        for (k, v) in &self.per_task {
            tasks.insert(k.clone(), Value::arr_f64(v));
        }
        Value::obj(vec![
            ("set", Value::Str(self.spec_set.clone())),
            ("trainable_percent", Value::Num(self.trainable_percent)),
            ("trainable_params", Value::Num(self.trainable_params as f64)),
            ("per_task", Value::Obj(tasks)),
            ("train_seconds", Value::Num(self.train_seconds)),
        ])
    }

    fn from_json(v: &Value) -> Result<RunResult> {
        let mut per_task = BTreeMap::new();
        for (k, arr) in v.req("per_task")?.as_obj()? {
            per_task.insert(
                k.clone(),
                arr.as_arr()?.iter().map(|x| x.as_f64()).collect::<Result<_>>()?,
            );
        }
        Ok(RunResult {
            spec_set: v.req("set")?.as_str()?.to_string(),
            trainable_percent: v.req("trainable_percent")?.as_f64()?,
            trainable_params: v.req("trainable_params")?.as_usize()?,
            per_task,
            train_seconds: v.req("train_seconds")?.as_f64()?,
        })
    }
}

/// The runner: owns the PJRT client, pretrained-base cache, and result
/// cache directories.
pub struct Runner {
    pub client: PjRtClient,
    pub artifacts_dir: PathBuf,
    pub runs_dir: PathBuf,
    pub results_dir: PathBuf,
    pub tok: Tokenizer,
    base_cache: BTreeMap<String, Vec<f32>>,
}

impl Runner {
    pub fn new(root: &Path) -> Result<Runner> {
        Ok(Runner {
            client: PjRtClient::cpu()?,
            artifacts_dir: root.join("artifacts"),
            runs_dir: root.join("runs"),
            results_dir: root.join("results"),
            tok: Tokenizer::new(),
            base_cache: BTreeMap::new(),
        })
    }

    /// Repo root = CWD (the binary runs from the workspace).
    pub fn from_cwd() -> Result<Runner> {
        Runner::new(&std::env::current_dir()?)
    }

    /// Pretrained base model params for an arch (pretrain on demand,
    /// cached on disk under `runs/base_<arch>.bin`).
    pub fn pretrained_base(&mut self, arch: &str) -> Result<Vec<f32>> {
        if let Some(p) = self.base_cache.get(arch) {
            return Ok(p.clone());
        }
        let path = self.runs_dir.join(format!("base_{arch}.bin"));
        if path.exists() {
            let (_, params) = checkpoint::load(&path)?;
            self.base_cache.insert(arch.to_string(), params.clone());
            return Ok(params);
        }
        info!("pretraining base model '{arch}' (first use; cached afterwards)");
        let set = format!("pretrain_{arch}");
        let man = Manifest::load(&self.artifacts_dir.join(&set))?;
        let base = Session::init_base(&man, 0, None)?; // dummy scalar
        let mut session =
            Session::load(&self.client, &self.artifacts_dir, &set, &base, &["train_step"])?;
        let out = trainer::pretrain(&mut session, &self.tok, 0, None)?;
        checkpoint::save(&path, &set, &out.final_theta)?;
        self.base_cache.insert(arch.to_string(), out.final_theta.clone());
        Ok(out.final_theta)
    }

    /// Generate the training data for a spec.
    fn train_data(&self, spec: &RunSpec) -> Result<TaskData> {
        match &spec.train {
            TrainTask::Single(t) => tasks::generate(t, &self.tok, spec.data_seed, spec.sizes),
            TrainTask::Mix(ts) => {
                let names: Vec<&str> = ts.iter().map(|s| s.as_str()).collect();
                tasks::generate_mix(&names, &self.tok, spec.data_seed, spec.sizes)
            }
        }
    }

    /// Run (or load from cache) one experiment.
    pub fn run(&mut self, spec: &RunSpec) -> Result<RunResult> {
        let cache_path = self.results_dir.join(format!("{}.json", spec.cache_key()));
        if cache_path.exists() {
            let v = Value::parse_file(&cache_path)?;
            return RunResult::from_json(&v);
        }
        let man = Manifest::load(&self.artifacts_dir.join(&spec.set))?;
        // Bounded-capture mode: when QFT_CACHED_ONLY is set, uncached rows
        // render as NaN instead of launching a training run (used by the
        // final `cargo bench` capture so it stays within a CI-sized
        // budget; run the individual bench target to fill a row in).
        if std::env::var("QFT_CACHED_ONLY").is_ok() {
            eprintln!(
                "SKIP (QFT_CACHED_ONLY): {} on {} not cached",
                spec.set,
                spec.train.cache_tag()
            );
            let per_task = spec
                .eval_tasks
                .iter()
                .map(|t| (t.clone(), vec![f64::NAN]))
                .collect();
            return Ok(RunResult {
                spec_set: spec.set.clone(),
                trainable_percent: man.counts.trainable_percent,
                trainable_params: man.counts.trainable_params,
                per_task,
                train_seconds: 0.0,
            });
        }
        let ckpt = self.pretrained_base(&man.arch.name)?;
        let data = self.train_data(spec)?;
        let mut per_task: BTreeMap<String, Vec<f64>> = BTreeMap::new();
        let t0 = std::time::Instant::now();
        // Compile once; swap the device-resident base per seed.  The seed
        // used for the base's method extras MUST match the theta seed so
        // QuanTA's shadow chain S equals the trainable chain T at init
        // (paper Eq. 8).
        let mut session: Option<Session> = None;
        for &seed in &spec.seeds {
            let base = Session::init_base(&man, seed, Some(&ckpt))?;
            match session.as_mut() {
                None => {
                    session = Some(Session::load(
                        &self.client,
                        &self.artifacts_dir,
                        &spec.set,
                        &base,
                        &["train_step", "eval_loss", "fwd_logits"],
                    )?)
                }
                Some(s) => s.set_base(&base)?,
            }
            let session = session.as_mut().unwrap();
            let cfg = FinetuneConfig { seed, steps: spec.steps, ..Default::default() };
            let out = trainer::finetune(session, &data, &cfg)?;
            for task in &spec.eval_tasks {
                let tdata = tasks::generate(task, &self.tok, spec.data_seed, spec.sizes)?;
                let metric = tasks::metric_for(task);
                let score =
                    evaluator::evaluate(session, &out.best_theta, &tdata.test, metric)?;
                info!(
                    "run[{} seed {}] {} = {:.4} ({:.1}s train)",
                    spec.set, seed, task, score, out.wallclock_s
                );
                per_task.entry(task.clone()).or_default().push(score);
            }
        }
        let result = RunResult {
            spec_set: spec.set.clone(),
            trainable_percent: man.counts.trainable_percent,
            trainable_params: man.counts.trainable_params,
            per_task,
            train_seconds: t0.elapsed().as_secs_f64(),
        };
        std::fs::create_dir_all(&self.results_dir)?;
        std::fs::write(&cache_path, result.to_json().to_string_pretty())?;
        Ok(result)
    }

    /// Run a spec and also return the best theta of the *first* seed
    /// (used by the Fig. 2 analysis which needs the weight update).
    /// The trained theta is cached under `runs/theta_<key>.bin` so
    /// repeated analyses do not retrain.
    pub fn run_for_theta(&mut self, spec: &RunSpec) -> Result<(Vec<f32>, Session)> {
        let man = Manifest::load(&self.artifacts_dir.join(&spec.set))?;
        let ckpt = self.pretrained_base(&man.arch.name)?;
        let base = Session::init_base(&man, spec.seeds[0], Some(&ckpt))?;
        let theta_path = self.runs_dir.join(format!("theta_{}.bin", spec.cache_key()));
        if theta_path.exists() {
            let (_, theta) = checkpoint::load(&theta_path)?;
            let session = Session::load(
                &self.client,
                &self.artifacts_dir,
                &spec.set,
                &base,
                &["fwd_logits", "merge"],
            )?;
            return Ok((theta, session));
        }
        if std::env::var("QFT_CACHED_ONLY").is_ok() {
            return Err(Error::msg(format!(
                "QFT_CACHED_ONLY: trained theta for {} not cached",
                spec.set
            )));
        }
        let data = self.train_data(spec)?;
        let mut session = Session::load(
            &self.client,
            &self.artifacts_dir,
            &spec.set,
            &base,
            &["train_step", "eval_loss", "fwd_logits", "merge"],
        )?;
        let cfg = FinetuneConfig { seed: spec.seeds[0], steps: spec.steps, ..Default::default() };
        let out = trainer::finetune(&mut session, &data, &cfg)?;
        checkpoint::save(&theta_path, &spec.set, &out.best_theta)?;
        Ok((out.best_theta, session))
    }

    /// Evaluate the *base* model (no fine-tuning) on a task — the
    /// "Base" rows of Table 1.
    pub fn eval_base(&mut self, set: &str, task: &str, sizes: Sizes) -> Result<f64> {
        let man = Manifest::load(&self.artifacts_dir.join(set))?;
        let ckpt = self.pretrained_base(&man.arch.name)?;
        let base = Session::init_base(&man, 0, Some(&ckpt))?;
        let session =
            Session::load(&self.client, &self.artifacts_dir, set, &base, &["fwd_logits"])?;
        let state = session.init_state(0)?; // zero-delta theta
        let tdata = tasks::generate(task, &self.tok, 1234, sizes)?;
        evaluator::evaluate(&session, &state.theta, &tdata.test, tasks::metric_for(task))
    }
}

/// Guard for benches/examples: true when `make artifacts` has been run.
pub fn artifacts_ready(root: &Path) -> bool {
    root.join("artifacts/index.json").exists()
}

/// Standard skip message for benches when artifacts are missing.
pub fn require_artifacts() -> Option<Runner> {
    let root = std::env::current_dir().ok()?;
    if !artifacts_ready(&root) {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return None;
    }
    match Runner::new(&root) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: runner init failed: {e}");
            None
        }
    }
}
