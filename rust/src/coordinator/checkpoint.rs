//! Flat-parameter checkpoints: a small self-describing binary format
//! (magic, CRC, name, f32 payload), used for pretrained bases, best
//! fine-tuned thetas, and (v4) resumable run manifests.
//!
//! ## Format v4 (run manifest — DESIGN.md §13)
//!
//! ```text
//! magic "QFTCKPT4"  (8 bytes)
//! crc32            u32 LE   — IEEE CRC-32 over everything below
//! meta_len         u32 LE
//! meta             typed run state (see RunMeta encoding below)
//! n_streams        u32 LE   (≥ 1)
//! n_streams × {
//!   name_len       u32 LE   (≤ 4096)
//!   name           UTF-8
//!   n              u64 LE
//!   payload        n × f32 LE
//! }
//! ```
//!
//! One file = one resumable training run: the v3 named-stream section
//! carries the big f32 vectors (`params`, `best_theta`, `adam_m`,
//! `adam_v`), and the `meta` section carries every scalar the trainer
//! needs to continue **bitwise identically** — step position, Adam
//! `t`, LR/anomaly state, best-val bookkeeping, the loss/val curves,
//! the sampler's full state (epoch order + position + `Rng` words +
//! Box-Muller spare), and a [`RunMeta::config_hash`] that rejects
//! resume under a changed `HostTrainConfig`.  The meta encoding is
//! fixed-layout little-endian (floats as IEEE bits) with every count
//! validated against the bytes actually present before allocation,
//! same as the stream parsers.
//!
//! ## Format v3 (multi-stream parameter checkpoint)
//!
//! ```text
//! magic "QFTCKPT3"  (8 bytes)
//! crc32            u32 LE   — IEEE CRC-32 over everything below
//! n_streams        u32 LE   (≥ 1)
//! n_streams × {
//!   name_len       u32 LE   (≤ 4096)
//!   name           UTF-8
//!   n              u64 LE
//!   payload        n × f32 LE
//! }
//! ```
//!
//! One file, several named flat parameter vectors — a depth-N model
//! saves one stream per layer (`layer0`, `layer1`, …) so the
//! train-deep → serve round trip moves one artifact, not N.
//!
//! ## Format v2 (single stream; still written by [`save`])
//!
//! ```text
//! magic "QFTCKPT2"  (8 bytes)
//! crc32            u32 LE   — IEEE CRC-32 over everything below
//! name_len         u32 LE   (≤ 4096)
//! name             UTF-8
//! n                u64 LE
//! payload          n × f32 LE
//! ```
//!
//! Hardened per DESIGN.md §11: checkpoints are untrusted input (the
//! multi-tenant registry will load tenant-supplied adapter files), so
//! the loaders validate every length against the **actual file size
//! before allocating** — a corrupt `n` header can no longer drive an
//! unbounded `vec![0u8; n * 4]` — with checked arithmetic so `n * 4`
//! cannot overflow on 32-bit targets, and the CRC rejects silent bit
//! rot.  Both writers go through one atomic path: write to a temp file
//! in the same directory, `rename` into place, so a crash mid-save
//! never leaves a torn file where a valid checkpoint used to be (the
//! `torn-write@save` fault probe exercises exactly that crash window).
//! [`load_streams`] reads every version — v1 (`QFTCKPT1`, no CRC) and
//! v2 files surface as a single stream, v4 manifests surface as their
//! stream section — so readers are format-oblivious.
//!
//! The shared atomic writer also hosts the crash-consistency probe
//! window: [`fault::crash_point`]`("snapshot")` fires immediately
//! before and immediately after the rename, so `crash@snapshot:2k`
//! dies with only the temp file of save `k` on disk (previous
//! checkpoint intact) and `crash@snapshot:2k+1` dies the instant save
//! `k` became durable.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::fault;

const MAGIC_V1: &[u8; 8] = b"QFTCKPT1";
const MAGIC_V2: &[u8; 8] = b"QFTCKPT2";
const MAGIC_V3: &[u8; 8] = b"QFTCKPT3";
const MAGIC_V4: &[u8; 8] = b"QFTCKPT4";
const MAX_NAME_LEN: usize = 4096;
/// Minimum encoded size of one stream (`name_len` + `n` with an empty
/// name and payload) — bounds `n_streams` against the real file size
/// before the per-stream loop runs.
const MIN_STREAM_BYTES: usize = 12;

/// IEEE CRC-32 (reflected, poly 0xEDB88320), table-driven — the
/// ubiquitous gzip/PNG polynomial, implemented here because the
/// offline vendor set has no checksum crate.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Append one stream's encoding (`name_len | name | n | payload`) to
/// a CRC-covered body.
fn encode_stream(body: &mut Vec<u8>, name: &str, params: &[f32]) -> Result<()> {
    let name_bytes = name.as_bytes();
    if name_bytes.len() > MAX_NAME_LEN {
        return Err(Error::msg(format!(
            "checkpoint name is {} bytes (max {MAX_NAME_LEN})",
            name_bytes.len()
        )));
    }
    body.reserve(MIN_STREAM_BYTES + name_bytes.len() + params.len() * 4);
    body.extend_from_slice(&(name_bytes.len() as u32).to_le_bytes());
    body.extend_from_slice(name_bytes);
    body.extend_from_slice(&(params.len() as u64).to_le_bytes());
    for &v in params {
        body.extend_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

/// The single atomic write path both writers share: CRC the body,
/// write `magic | crc | body` to a temp file in the destination
/// directory, `rename` into place — the destination either keeps its
/// old contents or atomically becomes the complete new checkpoint.
fn write_atomic(path: &Path, magic: &[u8; 8], body: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let crc = crc32(body);
    let tmp = tmp_path(path);
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(magic)?;
    f.write_all(&crc.to_le_bytes())?;
    if fault::armed() {
        if let Some(fault::Fault::TornWrite) = fault::probe("save") {
            // simulate a crash mid-save: half the body reaches the temp
            // file, the rename never happens — any previous checkpoint
            // at `path` must survive untouched
            f.write_all(&body[..body.len() / 2])?;
            drop(f);
            return Err(Error::msg(format!(
                "injected fault: torn write to {}",
                tmp.display()
            )));
        }
    }
    f.write_all(body)?;
    f.sync_all()?;
    drop(f);
    // the crash-consistency save window: a `crash@snapshot` spec
    // aborts the process here (before the rename — the destination
    // still holds its previous contents) or below (after — the new
    // checkpoint just became durable); `--resume` must recover from
    // either side bitwise
    fault::crash_point("snapshot");
    std::fs::rename(&tmp, path)?;
    fault::crash_point("snapshot");
    Ok(())
}

/// Save one named flat parameter vector (format v2, atomic).
pub fn save(path: &Path, name: &str, params: &[f32]) -> Result<()> {
    let mut body = Vec::new();
    encode_stream(&mut body, name, params)?;
    write_atomic(path, MAGIC_V2, &body)
}

/// Save several named flat parameter vectors in one file (format v3,
/// atomic) — e.g. one stream per layer of a depth-N model.
pub fn save_streams(path: &Path, streams: &[(&str, &[f32])]) -> Result<()> {
    if streams.is_empty() {
        return Err(Error::msg("checkpoint must hold at least one stream"));
    }
    let mut body = Vec::new();
    body.extend_from_slice(&(streams.len() as u32).to_le_bytes());
    for (name, params) in streams {
        encode_stream(&mut body, name, params)?;
    }
    write_atomic(path, MAGIC_V3, &body)
}

/// Typed run state carried by a v4 run manifest — everything
/// `finetune_host` needs beyond the f32 streams to continue a run
/// bitwise identically (DESIGN.md §13).  Counters are `usize` for
/// caller ergonomics and encoded as `u64` LE; floats are encoded as
/// IEEE bits so the round trip is exact, NaN/±inf included.
#[derive(Clone, Debug, PartialEq)]
pub struct RunMeta {
    /// Hash of the trajectory-shaping `HostTrainConfig` fields; resume
    /// under a different config is rejected against this.
    pub config_hash: u64,
    /// Loop position to resume from (steps completed).
    pub step: usize,
    /// Adam's bias-correction step counter.
    pub adam_t: u64,
    pub steps_run: usize,
    pub anomalies: usize,
    pub since_best: usize,
    /// The run finished (completion, early stop, or divergence) —
    /// resuming a done manifest returns its outcome without training.
    pub done: bool,
    pub diverged: bool,
    pub lr_scale: f32,
    pub best_val: f64,
    /// Sampler stream: xoshiro256++ words + Box-Muller spare.
    pub rng_state: [u64; 4],
    pub rng_spare: Option<f64>,
    pub sampler_pos: usize,
    pub sampler_order: Vec<usize>,
    pub loss_curve: Vec<(usize, f64)>,
    pub val_curve: Vec<(usize, f64)>,
}

const META_FLAG_DONE: u8 = 1 << 0;
const META_FLAG_DIVERGED: u8 = 1 << 1;
const META_FLAG_SPARE: u8 = 1 << 2;

fn push_u64(body: &mut Vec<u8>, v: u64) {
    body.extend_from_slice(&v.to_le_bytes());
}

/// Encode the meta section (fixed-layout scalars, then the
/// length-prefixed sampler order and curves).
fn encode_meta(meta: &RunMeta) -> Result<Vec<u8>> {
    let mut m = Vec::new();
    push_u64(&mut m, meta.config_hash);
    push_u64(&mut m, meta.step as u64);
    push_u64(&mut m, meta.adam_t);
    push_u64(&mut m, meta.steps_run as u64);
    push_u64(&mut m, meta.anomalies as u64);
    push_u64(&mut m, meta.since_best as u64);
    let mut flags = 0u8;
    if meta.done {
        flags |= META_FLAG_DONE;
    }
    if meta.diverged {
        flags |= META_FLAG_DIVERGED;
    }
    if meta.rng_spare.is_some() {
        flags |= META_FLAG_SPARE;
    }
    m.push(flags);
    m.extend_from_slice(&meta.lr_scale.to_bits().to_le_bytes());
    push_u64(&mut m, meta.best_val.to_bits());
    for w in meta.rng_state {
        push_u64(&mut m, w);
    }
    push_u64(&mut m, meta.rng_spare.unwrap_or(0.0).to_bits());
    push_u64(&mut m, meta.sampler_pos as u64);
    push_u64(&mut m, meta.sampler_order.len() as u64);
    for &i in &meta.sampler_order {
        let i = u32::try_from(i).map_err(|_| {
            Error::Data(format!("manifest sampler index {i} exceeds the u32 encoding"))
        })?;
        m.extend_from_slice(&i.to_le_bytes());
    }
    for curve in [&meta.loss_curve, &meta.val_curve] {
        push_u64(&mut m, curve.len() as u64);
        for &(step, v) in curve {
            push_u64(&mut m, step as u64);
            push_u64(&mut m, v.to_bits());
        }
    }
    Ok(m)
}

fn take_usize(cur: &mut Cursor, what: &str) -> Result<usize> {
    let v = cur.u64()?;
    usize::try_from(v)
        .map_err(|_| Error::Data(format!("manifest {what} {v} exceeds this target's usize")))
}

/// Bound a declared element count against the bytes actually present
/// (`elem_bytes` each) before any count-sized allocation.
fn bounded_count(cur: &Cursor, n: usize, elem_bytes: usize, what: &str) -> Result<()> {
    let need = n
        .checked_mul(elem_bytes)
        .ok_or_else(|| Error::Data(format!("manifest {what} count {n} overflows")))?;
    if need > cur.remaining() {
        return Err(Error::Data(format!(
            "manifest declares {n} {what} entries ({need} bytes) but only {} are present",
            cur.remaining()
        )));
    }
    Ok(())
}

/// Decode the meta section; every length validated before allocation.
fn parse_meta(meta: &[u8]) -> Result<RunMeta> {
    let mut cur = Cursor { buf: meta, pos: 0 };
    let config_hash = cur.u64()?;
    let step = take_usize(&mut cur, "step")?;
    let adam_t = cur.u64()?;
    let steps_run = take_usize(&mut cur, "steps_run")?;
    let anomalies = take_usize(&mut cur, "anomalies")?;
    let since_best = take_usize(&mut cur, "since_best")?;
    let flags = cur.take(1)?[0];
    let lr_bits = cur.u32()?;
    let best_val = f64::from_bits(cur.u64()?);
    let mut rng_state = [0u64; 4];
    for w in &mut rng_state {
        *w = cur.u64()?;
    }
    let spare_bits = cur.u64()?;
    let rng_spare =
        if flags & META_FLAG_SPARE != 0 { Some(f64::from_bits(spare_bits)) } else { None };
    let sampler_pos = take_usize(&mut cur, "sampler_pos")?;
    let n_order = take_usize(&mut cur, "sampler_order")?;
    bounded_count(&cur, n_order, 4, "sampler_order")?;
    let mut sampler_order = Vec::with_capacity(n_order);
    for _ in 0..n_order {
        sampler_order.push(cur.u32()? as usize);
    }
    let mut curves = [Vec::new(), Vec::new()];
    for (curve, what) in curves.iter_mut().zip(["loss_curve", "val_curve"]) {
        let n = take_usize(&mut cur, what)?;
        bounded_count(&cur, n, 16, what)?;
        curve.reserve(n);
        for _ in 0..n {
            let s = take_usize(&mut cur, what)?;
            curve.push((s, f64::from_bits(cur.u64()?)));
        }
    }
    if cur.remaining() != 0 {
        return Err(Error::Data(format!(
            "manifest meta has {} trailing bytes",
            cur.remaining()
        )));
    }
    let [loss_curve, val_curve] = curves;
    Ok(RunMeta {
        config_hash,
        step,
        adam_t,
        steps_run,
        anomalies,
        since_best,
        done: flags & META_FLAG_DONE != 0,
        diverged: flags & META_FLAG_DIVERGED != 0,
        lr_scale: f32::from_bits(lr_bits),
        best_val,
        rng_state,
        rng_spare,
        sampler_pos,
        sampler_order,
        loss_curve,
        val_curve,
    })
}

/// Save a run manifest (format v4, atomic): the typed run state plus
/// the named f32 streams, one artifact per resumable run.
pub fn save_manifest(path: &Path, meta: &RunMeta, streams: &[(&str, &[f32])]) -> Result<()> {
    if streams.is_empty() {
        return Err(Error::msg("run manifest must hold at least one stream"));
    }
    let m = encode_meta(meta)?;
    let mut body = Vec::with_capacity(4 + m.len());
    body.extend_from_slice(&(m.len() as u32).to_le_bytes());
    body.extend_from_slice(&m);
    body.extend_from_slice(&(streams.len() as u32).to_le_bytes());
    for (name, params) in streams {
        encode_stream(&mut body, name, params)?;
    }
    write_atomic(path, MAGIC_V4, &body)
}

/// Split a v4 body into its meta section and its stream section, with
/// `meta_len` validated against the body before slicing.
fn split_v4_body(body: &[u8]) -> Result<(&[u8], &[u8])> {
    let mut cur = Cursor { buf: body, pos: 0 };
    let meta_len = cur.u32()? as usize;
    if meta_len > cur.remaining() {
        return Err(Error::Data(format!(
            "manifest declares {meta_len} meta bytes but only {} are present",
            cur.remaining()
        )));
    }
    let meta = cur.take(meta_len)?;
    Ok((meta, &body[cur.pos..]))
}

/// Load a v4 run manifest: the typed run state plus its named streams.
/// Rejects other versions — parameter-only checkpoints carry no run
/// state to resume from.
pub fn load_manifest(path: &Path) -> Result<(RunMeta, Vec<(String, Vec<f32>)>)> {
    let bytes = std::fs::read(path)?;
    if bytes.len() < 8 {
        return Err(Error::msg(format!("{}: not a QFT checkpoint", path.display())));
    }
    let (magic, rest) = bytes.split_at(8);
    if magic != MAGIC_V4 {
        return Err(Error::Data(format!(
            "{}: not a run manifest (v4); parameter checkpoints hold no run state",
            path.display()
        )));
    }
    let body = checked_body(path, rest)?;
    let (meta, streams) = split_v4_body(body)?;
    Ok((parse_meta(meta)?, parse_streams(streams)?))
}

/// Bounds-checked little-endian reads over an in-memory image.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let Some(end) = end else {
            return Err(Error::Data(format!(
                "checkpoint truncated: need {n} bytes at offset {}, file has {}",
                self.pos,
                self.buf.len()
            )));
        };
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Parse one stream (`name_len | name | n | payload`) with every
/// length validated against the in-memory image (== the real file
/// size) before any payload-sized allocation.  In a multi-stream body
/// more streams may follow, so the bound is `≤ remaining`, not `==`;
/// callers check for trailing garbage once all streams are read.
fn parse_stream(cur: &mut Cursor) -> Result<(String, Vec<f32>)> {
    let name_len = cur.u32()? as usize;
    if name_len > MAX_NAME_LEN {
        return Err(Error::Data(format!(
            "checkpoint name length {name_len} exceeds max {MAX_NAME_LEN}"
        )));
    }
    let name_bytes = cur.take(name_len)?;
    let name = String::from_utf8(name_bytes.to_vec())
        .map_err(|_| Error::Data("checkpoint name is not UTF-8".into()))?;
    let n = cur.u64()?;
    // validate the declared count against the bytes actually present
    // BEFORE sizing any allocation; checked u64 math so `n * 4` cannot
    // wrap (and the usize conversion cannot truncate on 32-bit)
    let payload_bytes =
        n.checked_mul(4).ok_or_else(|| Error::Data(format!("checkpoint count {n} overflows")))?;
    if payload_bytes > cur.remaining() as u64 {
        return Err(Error::Data(format!(
            "checkpoint declares {payload_bytes} payload bytes but only {} are present",
            cur.remaining()
        )));
    }
    let n = usize::try_from(n)
        .map_err(|_| Error::Data(format!("checkpoint count {n} exceeds this target's usize")))?;
    let payload = cur.take(n * 4)?;
    let mut params = vec![0.0f32; n];
    for (p, chunk) in params.iter_mut().zip(payload.chunks_exact(4)) {
        *p = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    Ok((name, params))
}

/// Parse a single-stream (v1/v2) body: one stream, no trailing bytes.
fn parse_body(body: &[u8]) -> Result<(String, Vec<f32>)> {
    let mut cur = Cursor { buf: body, pos: 0 };
    let stream = parse_stream(&mut cur)?;
    if cur.remaining() != 0 {
        return Err(Error::Data(format!(
            "checkpoint has {} trailing bytes after its stream",
            cur.remaining()
        )));
    }
    Ok(stream)
}

/// Parse a v3 body: `n_streams` then that many streams, no trailing
/// bytes.  `n_streams` is bounded by the real body size before the
/// loop (each stream encodes to at least [`MIN_STREAM_BYTES`]).
fn parse_streams(body: &[u8]) -> Result<Vec<(String, Vec<f32>)>> {
    let mut cur = Cursor { buf: body, pos: 0 };
    let n_streams = cur.u32()? as usize;
    if n_streams == 0 {
        return Err(Error::Data("checkpoint declares zero streams".into()));
    }
    let min_bytes = n_streams
        .checked_mul(MIN_STREAM_BYTES)
        .ok_or_else(|| Error::Data(format!("checkpoint stream count {n_streams} overflows")))?;
    if min_bytes > cur.remaining() {
        return Err(Error::Data(format!(
            "checkpoint declares {n_streams} streams (≥ {min_bytes} bytes) but only {} are present",
            cur.remaining()
        )));
    }
    let mut streams = Vec::with_capacity(n_streams);
    for _ in 0..n_streams {
        streams.push(parse_stream(&mut cur)?);
    }
    if cur.remaining() != 0 {
        return Err(Error::Data(format!(
            "checkpoint has {} trailing bytes after its last stream",
            cur.remaining()
        )));
    }
    Ok(streams)
}

/// Check a v2/v3 file's CRC and hand back the covered body.
fn checked_body<'a>(path: &Path, rest: &'a [u8]) -> Result<&'a [u8]> {
    if rest.len() < 4 {
        return Err(Error::Data(format!("{}: truncated before CRC", path.display())));
    }
    let (crc_bytes, body) = rest.split_at(4);
    let want = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
    let got = crc32(body);
    if got != want {
        return Err(Error::Data(format!(
            "{}: CRC mismatch (file {want:#010x}, computed {got:#010x})",
            path.display()
        )));
    }
    Ok(body)
}

/// Load a checkpoint of any version as named streams (v1/v2 files
/// surface as one stream).  Corrupt, truncated, or oversized-header
/// files are rejected with a structured error — never a panic, never
/// an allocation beyond the file's own size.
pub fn load_streams(path: &Path) -> Result<Vec<(String, Vec<f32>)>> {
    // one read bounded by the real file size; all subsequent parsing
    // is bounds-checked against it
    let bytes = std::fs::read(path)?;
    if bytes.len() < 8 {
        return Err(Error::msg(format!("{}: not a QFT checkpoint", path.display())));
    }
    let (magic, rest) = bytes.split_at(8);
    if magic == MAGIC_V4 {
        // a run manifest's stream section reads like any other
        // checkpoint (e.g. `serve --params` over a manifest's final
        // params); the run state is load_manifest's concern
        let (_meta, streams) = split_v4_body(checked_body(path, rest)?)?;
        parse_streams(streams)
    } else if magic == MAGIC_V3 {
        parse_streams(checked_body(path, rest)?)
    } else if magic == MAGIC_V2 {
        Ok(vec![parse_body(checked_body(path, rest)?)?])
    } else if magic == MAGIC_V1 {
        Ok(vec![parse_body(rest)?])
    } else {
        Err(Error::msg(format!("{}: not a QFT checkpoint", path.display())))
    }
}

/// Load a single-stream checkpoint; returns (name, params).  A v3
/// file is accepted when it holds exactly one stream; multi-stream
/// files must go through [`load_streams`].
pub fn load(path: &Path) -> Result<(String, Vec<f32>)> {
    let mut streams = load_streams(path)?;
    if streams.len() != 1 {
        return Err(Error::Data(format!(
            "{}: holds {} streams; use load_streams",
            path.display(),
            streams.len()
        )));
    }
    Ok(streams.pop().expect("len checked above"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("qft_ckpt_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn crc32_known_answer() {
        // the standard IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip() {
        let dir = tdir("roundtrip");
        let path = dir.join("a.bin");
        let params: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        save(&path, "test_model", &params).unwrap();
        let (name, loaded) = load(&path).unwrap();
        assert_eq!(name, "test_model");
        assert_eq!(loaded, params);
        // empty payload is a valid checkpoint
        let path2 = dir.join("empty.bin");
        save(&path2, "none", &[]).unwrap();
        assert_eq!(load(&path2).unwrap(), ("none".to_string(), vec![]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_over_existing_file() {
        let dir = tdir("atomic");
        let path = dir.join("a.bin");
        save(&path, "first", &[1.0, 2.0]).unwrap();
        save(&path, "second", &[3.0]).unwrap();
        assert_eq!(load(&path).unwrap(), ("second".to_string(), vec![3.0]));
        assert!(!tmp_path(&path).exists(), "temp file must not survive a successful save");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = tdir("garbage");
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, b"QFT").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_truncation_and_bit_rot() {
        let dir = tdir("corrupt");
        let path = dir.join("a.bin");
        let params: Vec<f32> = (0..64).map(|i| i as f32).collect();
        save(&path, "m", &params).unwrap();
        let good = std::fs::read(&path).unwrap();
        // truncated at every prefix boundary of interest
        for cut in [7, 11, 13, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load(&path).is_err(), "accepted a {cut}-byte prefix");
        }
        // single flipped payload bit → CRC mismatch
        let mut rot = good.clone();
        let last = rot.len() - 1;
        rot[last] ^= 0x01;
        std::fs::write(&path, &rot).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("CRC"), "bit rot not caught by CRC: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_oversized_count_without_allocating() {
        let dir = tdir("oversize");
        let path = dir.join("huge.bin");
        // a v1 header claiming u64::MAX params in a 30-byte file: the
        // pre-hardening loader computed `n * 4` (wrapping) and tried to
        // allocate it; now it must fail on the size check
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(b"hi");
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        // same header via v2 with a *valid* CRC: still rejected on size
        let body = &bytes[8..];
        let mut v2 = Vec::new();
        v2.extend_from_slice(MAGIC_V2);
        v2.extend_from_slice(&crc32(body).to_le_bytes());
        v2.extend_from_slice(body);
        std::fs::write(&path, &v2).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_legacy_v1() {
        let dir = tdir("v1");
        let path = dir.join("old.bin");
        let params = [0.5f32, -1.25, 3.0];
        // byte-for-byte what the v1 writer produced
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&5u32.to_le_bytes());
        bytes.extend_from_slice(b"old_m");
        bytes.extend_from_slice(&(params.len() as u64).to_le_bytes());
        for v in params {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let (name, loaded) = load(&path).unwrap();
        assert_eq!(name, "old_m");
        assert_eq!(loaded, params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_oversized_name() {
        let dir = tdir("name");
        let err = save(&dir.join("x.bin"), &"n".repeat(MAX_NAME_LEN + 1), &[1.0]);
        assert!(err.is_err());
        let err3 = save_streams(&dir.join("y.bin"), &[("ok", &[1.0][..]),
            (&"n".repeat(MAX_NAME_LEN + 1), &[2.0][..])]);
        assert!(err3.is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multi_stream_roundtrip_and_single_stream_compat() {
        let dir = tdir("streams");
        let path = dir.join("deep.bin");
        let layers: Vec<Vec<f32>> =
            (0..4).map(|l| (0..50).map(|i| (l * 100 + i) as f32).collect()).collect();
        let named: Vec<(String, &[f32])> =
            layers.iter().enumerate().map(|(l, p)| (format!("layer{l}"), &p[..])).collect();
        let streams: Vec<(&str, &[f32])> =
            named.iter().map(|(n, p)| (n.as_str(), *p)).collect();
        save_streams(&path, &streams).unwrap();
        let loaded = load_streams(&path).unwrap();
        assert_eq!(loaded.len(), 4);
        for (l, (name, params)) in loaded.iter().enumerate() {
            assert_eq!(name, &format!("layer{l}"));
            assert_eq!(params, &layers[l]);
        }
        // load() refuses the ambiguity of a multi-stream file...
        assert!(load(&path).is_err());
        // ...but accepts a one-stream v3, and load_streams reads v2/v1
        let single = dir.join("one.bin");
        save_streams(&single, &[("only", &[7.0, 8.0][..])]).unwrap();
        assert_eq!(load(&single).unwrap(), ("only".to_string(), vec![7.0, 8.0]));
        let v2 = dir.join("two.bin");
        save(&v2, "flat", &[1.5]).unwrap();
        assert_eq!(load_streams(&v2).unwrap(), vec![("flat".to_string(), vec![1.5])]);
        // empty stream list is rejected at save time
        assert!(save_streams(&dir.join("none.bin"), &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    fn sample_meta() -> RunMeta {
        RunMeta {
            config_hash: 0xDEAD_BEEF_CAFE_F00D,
            step: 35,
            adam_t: 33,
            steps_run: 35,
            anomalies: 2,
            since_best: 1,
            done: false,
            diverged: false,
            lr_scale: 0.25,
            best_val: 0.012_345_678_9,
            rng_state: [1, u64::MAX, 3, 0x0123_4567_89AB_CDEF],
            rng_spare: Some(-1.234_567_890_123_4),
            sampler_pos: 7,
            sampler_order: vec![4, 0, 3, 1, 2, 7, 6, 5],
            loss_curve: vec![(0, 1.5), (20, 0.5), (34, f64::NAN)],
            val_curve: vec![(20, 0.9), (35, f64::INFINITY)],
        }
    }

    /// NaN-tolerant equality (PartialEq on RunMeta is false under NaN
    /// curve entries, which the format must still round-trip exactly).
    fn meta_bits_eq(a: &RunMeta, b: &RunMeta) -> bool {
        let f64b = |x: f64| x.to_bits();
        a.config_hash == b.config_hash
            && a.step == b.step
            && a.adam_t == b.adam_t
            && a.steps_run == b.steps_run
            && a.anomalies == b.anomalies
            && a.since_best == b.since_best
            && a.done == b.done
            && a.diverged == b.diverged
            && a.lr_scale.to_bits() == b.lr_scale.to_bits()
            && f64b(a.best_val) == f64b(b.best_val)
            && a.rng_state == b.rng_state
            && a.rng_spare.map(f64b) == b.rng_spare.map(f64b)
            && a.sampler_pos == b.sampler_pos
            && a.sampler_order == b.sampler_order
            && a.loss_curve.len() == b.loss_curve.len()
            && a.loss_curve.iter().zip(&b.loss_curve).all(|(x, y)| x.0 == y.0 && f64b(x.1) == f64b(y.1))
            && a.val_curve.len() == b.val_curve.len()
            && a.val_curve.iter().zip(&b.val_curve).all(|(x, y)| x.0 == y.0 && f64b(x.1) == f64b(y.1))
    }

    #[test]
    fn manifest_roundtrip_is_exact() {
        let dir = tdir("manifest");
        let path = dir.join("run.bin");
        let meta = sample_meta();
        let params: Vec<f32> = (0..200).map(|i| (i as f32).cos()).collect();
        let m: Vec<f32> = (0..200).map(|i| i as f32 * 1e-3).collect();
        save_manifest(
            &path,
            &meta,
            &[("params", &params[..]), ("best_theta", &params[..]), ("adam_m", &m[..]), ("adam_v", &m[..])],
        )
        .unwrap();
        let (got, streams) = load_manifest(&path).unwrap();
        assert!(meta_bits_eq(&got, &meta), "meta round trip drifted:\n{got:?}\nvs\n{meta:?}");
        assert_eq!(streams.len(), 4);
        assert_eq!(streams[0], ("params".to_string(), params.clone()));
        assert_eq!(streams[3], ("adam_v".to_string(), m.clone()));
        // the done/diverged/spare flag combinations round-trip too
        let mut meta2 = sample_meta();
        meta2.done = true;
        meta2.diverged = true;
        meta2.rng_spare = None;
        meta2.sampler_order = vec![];
        meta2.val_curve = vec![];
        save_manifest(&path, &meta2, &[("params", &params[..])]).unwrap();
        let (got2, _) = load_manifest(&path).unwrap();
        assert!(meta_bits_eq(&got2, &meta2));
        // format-oblivious readers see the stream section of a manifest
        let all = load_streams(&path).unwrap();
        assert_eq!(all, vec![("params".to_string(), params.clone())]);
        // load_manifest rejects parameter-only checkpoints (no run state)
        let v2 = dir.join("flat.bin");
        save(&v2, "flat", &params).unwrap();
        let err = load_manifest(&v2).unwrap_err().to_string();
        assert!(err.contains("not a run manifest"), "{err}");
        // empty stream list rejected at save time
        assert!(save_manifest(&dir.join("none.bin"), &meta, &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_corruption_is_rejected_without_allocating() {
        let dir = tdir("manifest_corrupt");
        let path = dir.join("run.bin");
        let meta = sample_meta();
        let params: Vec<f32> = (0..64).map(|i| i as f32).collect();
        save_manifest(&path, &meta, &[("params", &params[..])]).unwrap();
        let good = std::fs::read(&path).unwrap();
        // truncation at every section boundary of interest: magic, CRC,
        // meta_len, mid-meta, n_streams, mid-payload
        for cut in [7, 11, 14, 40, good.len() - params.len() * 4 - 6, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load_manifest(&path).is_err(), "accepted a {cut}-byte prefix");
            assert!(load_streams(&path).is_err(), "load_streams accepted a {cut}-byte prefix");
        }
        // CRC flip anywhere in the covered body
        for flip in [13, 20, good.len() - 1] {
            let mut rot = good.clone();
            rot[flip] ^= 0x40;
            std::fs::write(&path, &rot).unwrap();
            let err = load_manifest(&path).unwrap_err().to_string();
            assert!(err.contains("CRC"), "flipped byte {flip} not caught by CRC: {err}");
        }
        // forged headers with VALID CRCs — the length validation itself
        // must reject, never an oversized allocation:
        let forge = |body: &[u8]| {
            let mut f = Vec::new();
            f.extend_from_slice(MAGIC_V4);
            f.extend_from_slice(&crc32(body).to_le_bytes());
            f.extend_from_slice(body);
            f
        };
        // (a) meta_len pointing past the file
        let mut b = Vec::new();
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        b.push(0);
        std::fs::write(&path, forge(&b)).unwrap();
        let err = load_manifest(&path).unwrap_err().to_string();
        assert!(err.contains("meta bytes"), "{err}");
        // (b) sampler_order count far beyond the meta section
        let good_body = &good[12..];
        let meta_len = u32::from_le_bytes([good_body[0], good_body[1], good_body[2], good_body[3]]) as usize;
        let meta_bytes = &good_body[4..4 + meta_len];
        let order_count_off = 8 * 6 + 1 + 4 + 8 + 32 + 8 + 8; // fixed prefix before n_order
        let mut forged_meta = meta_bytes.to_vec();
        forged_meta[order_count_off..order_count_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let mut b = Vec::new();
        b.extend_from_slice(&(forged_meta.len() as u32).to_le_bytes());
        b.extend_from_slice(&forged_meta);
        b.extend_from_slice(&good_body[4 + meta_len..]);
        std::fs::write(&path, forge(&b)).unwrap();
        let err = load_manifest(&path).unwrap_err().to_string();
        assert!(err.contains("sampler_order"), "{err}");
        // (c) oversize name_len in the stream section
        let mut b = Vec::new();
        b.extend_from_slice(&1u32.to_le_bytes()); // meta_len 1
        b.push(0); // "meta"
        b.extend_from_slice(&1u32.to_le_bytes()); // n_streams
        b.extend_from_slice(&(MAX_NAME_LEN as u32 + 1).to_le_bytes());
        std::fs::write(&path, forge(&b)).unwrap();
        // meta is garbage too, but the stream section must already be
        // rejected by load_streams (which never parses meta)
        assert!(load_streams(&path).unwrap_err().to_string().contains("name length"));
        // (d) stream length mismatch: declared count larger than payload
        let mut b = Vec::new();
        b.extend_from_slice(&(meta_len as u32).to_le_bytes());
        b.extend_from_slice(meta_bytes);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&6u32.to_le_bytes());
        b.extend_from_slice(b"params");
        b.extend_from_slice(&u64::MAX.to_le_bytes());
        b.extend_from_slice(&[0u8; 16]);
        std::fs::write(&path, forge(&b)).unwrap();
        let err = load_manifest(&path).unwrap_err().to_string();
        assert!(err.contains("payload"), "{err}");
        // (e) meta trailing bytes (meta_len longer than the encoding)
        let mut padded_meta = meta_bytes.to_vec();
        padded_meta.extend_from_slice(&[0u8; 3]);
        let mut b = Vec::new();
        b.extend_from_slice(&(padded_meta.len() as u32).to_le_bytes());
        b.extend_from_slice(&padded_meta);
        b.extend_from_slice(&good_body[4 + meta_len..]);
        std::fs::write(&path, forge(&b)).unwrap();
        let err = load_manifest(&path).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v3_corruption_is_rejected_without_allocating() {
        let dir = tdir("v3corrupt");
        let path = dir.join("deep.bin");
        let p: Vec<f32> = (0..32).map(|i| i as f32).collect();
        save_streams(&path, &[("a", &p[..]), ("b", &p[..])]).unwrap();
        let good = std::fs::read(&path).unwrap();
        // truncation at the magic, CRC, header, and payload boundaries
        for cut in [7, 11, 14, 20, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load_streams(&path).is_err(), "accepted a {cut}-byte prefix");
        }
        // bit rot → CRC mismatch
        let mut rot = good.clone();
        let last = rot.len() - 1;
        rot[last] ^= 0x01;
        std::fs::write(&path, &rot).unwrap();
        let err = load_streams(&path).unwrap_err().to_string();
        assert!(err.contains("CRC"), "bit rot not caught by CRC: {err}");
        // a stream-count header far beyond the file size fails on the
        // pre-loop bound, and an oversized per-stream count fails on
        // the remaining-bytes check — valid CRCs both times, so the
        // size validation itself is what rejects them
        let mut body = Vec::new();
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(b'x');
        let mut forged = Vec::new();
        forged.extend_from_slice(MAGIC_V3);
        forged.extend_from_slice(&crc32(&body).to_le_bytes());
        forged.extend_from_slice(&body);
        std::fs::write(&path, &forged).unwrap();
        assert!(load_streams(&path).is_err());
        let mut body2 = Vec::new();
        body2.extend_from_slice(&1u32.to_le_bytes());
        body2.extend_from_slice(&1u32.to_le_bytes());
        body2.push(b'x');
        body2.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut forged2 = Vec::new();
        forged2.extend_from_slice(MAGIC_V3);
        forged2.extend_from_slice(&crc32(&body2).to_le_bytes());
        forged2.extend_from_slice(&body2);
        std::fs::write(&path, &forged2).unwrap();
        assert!(load_streams(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
