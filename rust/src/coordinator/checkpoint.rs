//! Flat-parameter checkpoints: a small self-describing binary format
//! (magic, version, name, f32 payload), used for pretrained bases and
//! best fine-tuned thetas.

use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::{Error, Result};

const MAGIC: &[u8; 8] = b"QFTCKPT1";

/// Save a named flat parameter vector.
pub fn save(path: &Path, name: &str, params: &[f32]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    let name_bytes = name.as_bytes();
    f.write_all(&(name_bytes.len() as u32).to_le_bytes())?;
    f.write_all(name_bytes)?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    // bulk-write the payload
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(params.as_ptr() as *const u8, params.len() * 4)
    };
    f.write_all(bytes)?;
    Ok(())
}

/// Load a checkpoint; returns (name, params).
pub fn load(path: &Path) -> Result<(String, Vec<f32>)> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::msg(format!("{}: not a QFT checkpoint", path.display())));
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let name_len = u32::from_le_bytes(len4) as usize;
    if name_len > 4096 {
        return Err(Error::msg("checkpoint name too long"));
    }
    let mut name_bytes = vec![0u8; name_len];
    f.read_exact(&mut name_bytes)?;
    let name = String::from_utf8(name_bytes).map_err(|_| Error::msg("bad checkpoint name"))?;
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let n = u64::from_le_bytes(len8) as usize;
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)?;
    let mut params = vec![0.0f32; n];
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        params[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    Ok((name, params))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("qft_ckpt_test");
        let path = dir.join("a.bin");
        let params: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        save(&path, "test_model", &params).unwrap();
        let (name, loaded) = load(&path).unwrap();
        assert_eq!(name, "test_model");
        assert_eq!(loaded, params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("qft_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
