//! Flat-parameter checkpoints: a small self-describing binary format
//! (magic, CRC, name, f32 payload), used for pretrained bases and best
//! fine-tuned thetas.
//!
//! ## Format v3 (current multi-stream writer)
//!
//! ```text
//! magic "QFTCKPT3"  (8 bytes)
//! crc32            u32 LE   — IEEE CRC-32 over everything below
//! n_streams        u32 LE   (≥ 1)
//! n_streams × {
//!   name_len       u32 LE   (≤ 4096)
//!   name           UTF-8
//!   n              u64 LE
//!   payload        n × f32 LE
//! }
//! ```
//!
//! One file, several named flat parameter vectors — a depth-N model
//! saves one stream per layer (`layer0`, `layer1`, …) so the
//! train-deep → serve round trip moves one artifact, not N.
//!
//! ## Format v2 (single stream; still written by [`save`])
//!
//! ```text
//! magic "QFTCKPT2"  (8 bytes)
//! crc32            u32 LE   — IEEE CRC-32 over everything below
//! name_len         u32 LE   (≤ 4096)
//! name             UTF-8
//! n                u64 LE
//! payload          n × f32 LE
//! ```
//!
//! Hardened per DESIGN.md §11: checkpoints are untrusted input (the
//! multi-tenant registry will load tenant-supplied adapter files), so
//! the loaders validate every length against the **actual file size
//! before allocating** — a corrupt `n` header can no longer drive an
//! unbounded `vec![0u8; n * 4]` — with checked arithmetic so `n * 4`
//! cannot overflow on 32-bit targets, and the CRC rejects silent bit
//! rot.  Both writers go through one atomic path: write to a temp file
//! in the same directory, `rename` into place, so a crash mid-save
//! never leaves a torn file where a valid checkpoint used to be (the
//! `torn-write@save` fault probe exercises exactly that crash window).
//! [`load_streams`] reads every version — v1 (`QFTCKPT1`, no CRC) and
//! v2 files surface as a single stream — so readers are
//! format-oblivious.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::fault;

const MAGIC_V1: &[u8; 8] = b"QFTCKPT1";
const MAGIC_V2: &[u8; 8] = b"QFTCKPT2";
const MAGIC_V3: &[u8; 8] = b"QFTCKPT3";
const MAX_NAME_LEN: usize = 4096;
/// Minimum encoded size of one stream (`name_len` + `n` with an empty
/// name and payload) — bounds `n_streams` against the real file size
/// before the per-stream loop runs.
const MIN_STREAM_BYTES: usize = 12;

/// IEEE CRC-32 (reflected, poly 0xEDB88320), table-driven — the
/// ubiquitous gzip/PNG polynomial, implemented here because the
/// offline vendor set has no checksum crate.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Append one stream's encoding (`name_len | name | n | payload`) to
/// a CRC-covered body.
fn encode_stream(body: &mut Vec<u8>, name: &str, params: &[f32]) -> Result<()> {
    let name_bytes = name.as_bytes();
    if name_bytes.len() > MAX_NAME_LEN {
        return Err(Error::msg(format!(
            "checkpoint name is {} bytes (max {MAX_NAME_LEN})",
            name_bytes.len()
        )));
    }
    body.reserve(MIN_STREAM_BYTES + name_bytes.len() + params.len() * 4);
    body.extend_from_slice(&(name_bytes.len() as u32).to_le_bytes());
    body.extend_from_slice(name_bytes);
    body.extend_from_slice(&(params.len() as u64).to_le_bytes());
    for &v in params {
        body.extend_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

/// The single atomic write path both writers share: CRC the body,
/// write `magic | crc | body` to a temp file in the destination
/// directory, `rename` into place — the destination either keeps its
/// old contents or atomically becomes the complete new checkpoint.
fn write_atomic(path: &Path, magic: &[u8; 8], body: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let crc = crc32(body);
    let tmp = tmp_path(path);
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(magic)?;
    f.write_all(&crc.to_le_bytes())?;
    if fault::armed() {
        if let Some(fault::Fault::TornWrite) = fault::probe("save") {
            // simulate a crash mid-save: half the body reaches the temp
            // file, the rename never happens — any previous checkpoint
            // at `path` must survive untouched
            f.write_all(&body[..body.len() / 2])?;
            drop(f);
            return Err(Error::msg(format!(
                "injected fault: torn write to {}",
                tmp.display()
            )));
        }
    }
    f.write_all(body)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Save one named flat parameter vector (format v2, atomic).
pub fn save(path: &Path, name: &str, params: &[f32]) -> Result<()> {
    let mut body = Vec::new();
    encode_stream(&mut body, name, params)?;
    write_atomic(path, MAGIC_V2, &body)
}

/// Save several named flat parameter vectors in one file (format v3,
/// atomic) — e.g. one stream per layer of a depth-N model.
pub fn save_streams(path: &Path, streams: &[(&str, &[f32])]) -> Result<()> {
    if streams.is_empty() {
        return Err(Error::msg("checkpoint must hold at least one stream"));
    }
    let mut body = Vec::new();
    body.extend_from_slice(&(streams.len() as u32).to_le_bytes());
    for (name, params) in streams {
        encode_stream(&mut body, name, params)?;
    }
    write_atomic(path, MAGIC_V3, &body)
}

/// Bounds-checked little-endian reads over an in-memory image.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let Some(end) = end else {
            return Err(Error::Data(format!(
                "checkpoint truncated: need {n} bytes at offset {}, file has {}",
                self.pos,
                self.buf.len()
            )));
        };
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Parse one stream (`name_len | name | n | payload`) with every
/// length validated against the in-memory image (== the real file
/// size) before any payload-sized allocation.  In a multi-stream body
/// more streams may follow, so the bound is `≤ remaining`, not `==`;
/// callers check for trailing garbage once all streams are read.
fn parse_stream(cur: &mut Cursor) -> Result<(String, Vec<f32>)> {
    let name_len = cur.u32()? as usize;
    if name_len > MAX_NAME_LEN {
        return Err(Error::Data(format!(
            "checkpoint name length {name_len} exceeds max {MAX_NAME_LEN}"
        )));
    }
    let name_bytes = cur.take(name_len)?;
    let name = String::from_utf8(name_bytes.to_vec())
        .map_err(|_| Error::Data("checkpoint name is not UTF-8".into()))?;
    let n = cur.u64()?;
    // validate the declared count against the bytes actually present
    // BEFORE sizing any allocation; checked u64 math so `n * 4` cannot
    // wrap (and the usize conversion cannot truncate on 32-bit)
    let payload_bytes =
        n.checked_mul(4).ok_or_else(|| Error::Data(format!("checkpoint count {n} overflows")))?;
    if payload_bytes > cur.remaining() as u64 {
        return Err(Error::Data(format!(
            "checkpoint declares {payload_bytes} payload bytes but only {} are present",
            cur.remaining()
        )));
    }
    let n = usize::try_from(n)
        .map_err(|_| Error::Data(format!("checkpoint count {n} exceeds this target's usize")))?;
    let payload = cur.take(n * 4)?;
    let mut params = vec![0.0f32; n];
    for (p, chunk) in params.iter_mut().zip(payload.chunks_exact(4)) {
        *p = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    Ok((name, params))
}

/// Parse a single-stream (v1/v2) body: one stream, no trailing bytes.
fn parse_body(body: &[u8]) -> Result<(String, Vec<f32>)> {
    let mut cur = Cursor { buf: body, pos: 0 };
    let stream = parse_stream(&mut cur)?;
    if cur.remaining() != 0 {
        return Err(Error::Data(format!(
            "checkpoint has {} trailing bytes after its stream",
            cur.remaining()
        )));
    }
    Ok(stream)
}

/// Parse a v3 body: `n_streams` then that many streams, no trailing
/// bytes.  `n_streams` is bounded by the real body size before the
/// loop (each stream encodes to at least [`MIN_STREAM_BYTES`]).
fn parse_streams(body: &[u8]) -> Result<Vec<(String, Vec<f32>)>> {
    let mut cur = Cursor { buf: body, pos: 0 };
    let n_streams = cur.u32()? as usize;
    if n_streams == 0 {
        return Err(Error::Data("checkpoint declares zero streams".into()));
    }
    let min_bytes = n_streams
        .checked_mul(MIN_STREAM_BYTES)
        .ok_or_else(|| Error::Data(format!("checkpoint stream count {n_streams} overflows")))?;
    if min_bytes > cur.remaining() {
        return Err(Error::Data(format!(
            "checkpoint declares {n_streams} streams (≥ {min_bytes} bytes) but only {} are present",
            cur.remaining()
        )));
    }
    let mut streams = Vec::with_capacity(n_streams);
    for _ in 0..n_streams {
        streams.push(parse_stream(&mut cur)?);
    }
    if cur.remaining() != 0 {
        return Err(Error::Data(format!(
            "checkpoint has {} trailing bytes after its last stream",
            cur.remaining()
        )));
    }
    Ok(streams)
}

/// Check a v2/v3 file's CRC and hand back the covered body.
fn checked_body<'a>(path: &Path, rest: &'a [u8]) -> Result<&'a [u8]> {
    if rest.len() < 4 {
        return Err(Error::Data(format!("{}: truncated before CRC", path.display())));
    }
    let (crc_bytes, body) = rest.split_at(4);
    let want = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
    let got = crc32(body);
    if got != want {
        return Err(Error::Data(format!(
            "{}: CRC mismatch (file {want:#010x}, computed {got:#010x})",
            path.display()
        )));
    }
    Ok(body)
}

/// Load a checkpoint of any version as named streams (v1/v2 files
/// surface as one stream).  Corrupt, truncated, or oversized-header
/// files are rejected with a structured error — never a panic, never
/// an allocation beyond the file's own size.
pub fn load_streams(path: &Path) -> Result<Vec<(String, Vec<f32>)>> {
    // one read bounded by the real file size; all subsequent parsing
    // is bounds-checked against it
    let bytes = std::fs::read(path)?;
    if bytes.len() < 8 {
        return Err(Error::msg(format!("{}: not a QFT checkpoint", path.display())));
    }
    let (magic, rest) = bytes.split_at(8);
    if magic == MAGIC_V3 {
        parse_streams(checked_body(path, rest)?)
    } else if magic == MAGIC_V2 {
        Ok(vec![parse_body(checked_body(path, rest)?)?])
    } else if magic == MAGIC_V1 {
        Ok(vec![parse_body(rest)?])
    } else {
        Err(Error::msg(format!("{}: not a QFT checkpoint", path.display())))
    }
}

/// Load a single-stream checkpoint; returns (name, params).  A v3
/// file is accepted when it holds exactly one stream; multi-stream
/// files must go through [`load_streams`].
pub fn load(path: &Path) -> Result<(String, Vec<f32>)> {
    let mut streams = load_streams(path)?;
    if streams.len() != 1 {
        return Err(Error::Data(format!(
            "{}: holds {} streams; use load_streams",
            path.display(),
            streams.len()
        )));
    }
    Ok(streams.pop().expect("len checked above"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("qft_ckpt_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn crc32_known_answer() {
        // the standard IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip() {
        let dir = tdir("roundtrip");
        let path = dir.join("a.bin");
        let params: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        save(&path, "test_model", &params).unwrap();
        let (name, loaded) = load(&path).unwrap();
        assert_eq!(name, "test_model");
        assert_eq!(loaded, params);
        // empty payload is a valid checkpoint
        let path2 = dir.join("empty.bin");
        save(&path2, "none", &[]).unwrap();
        assert_eq!(load(&path2).unwrap(), ("none".to_string(), vec![]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_over_existing_file() {
        let dir = tdir("atomic");
        let path = dir.join("a.bin");
        save(&path, "first", &[1.0, 2.0]).unwrap();
        save(&path, "second", &[3.0]).unwrap();
        assert_eq!(load(&path).unwrap(), ("second".to_string(), vec![3.0]));
        assert!(!tmp_path(&path).exists(), "temp file must not survive a successful save");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = tdir("garbage");
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, b"QFT").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_truncation_and_bit_rot() {
        let dir = tdir("corrupt");
        let path = dir.join("a.bin");
        let params: Vec<f32> = (0..64).map(|i| i as f32).collect();
        save(&path, "m", &params).unwrap();
        let good = std::fs::read(&path).unwrap();
        // truncated at every prefix boundary of interest
        for cut in [7, 11, 13, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load(&path).is_err(), "accepted a {cut}-byte prefix");
        }
        // single flipped payload bit → CRC mismatch
        let mut rot = good.clone();
        let last = rot.len() - 1;
        rot[last] ^= 0x01;
        std::fs::write(&path, &rot).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("CRC"), "bit rot not caught by CRC: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_oversized_count_without_allocating() {
        let dir = tdir("oversize");
        let path = dir.join("huge.bin");
        // a v1 header claiming u64::MAX params in a 30-byte file: the
        // pre-hardening loader computed `n * 4` (wrapping) and tried to
        // allocate it; now it must fail on the size check
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(b"hi");
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        // same header via v2 with a *valid* CRC: still rejected on size
        let body = &bytes[8..];
        let mut v2 = Vec::new();
        v2.extend_from_slice(MAGIC_V2);
        v2.extend_from_slice(&crc32(body).to_le_bytes());
        v2.extend_from_slice(body);
        std::fs::write(&path, &v2).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_legacy_v1() {
        let dir = tdir("v1");
        let path = dir.join("old.bin");
        let params = [0.5f32, -1.25, 3.0];
        // byte-for-byte what the v1 writer produced
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&5u32.to_le_bytes());
        bytes.extend_from_slice(b"old_m");
        bytes.extend_from_slice(&(params.len() as u64).to_le_bytes());
        for v in params {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let (name, loaded) = load(&path).unwrap();
        assert_eq!(name, "old_m");
        assert_eq!(loaded, params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_oversized_name() {
        let dir = tdir("name");
        let err = save(&dir.join("x.bin"), &"n".repeat(MAX_NAME_LEN + 1), &[1.0]);
        assert!(err.is_err());
        let err3 = save_streams(&dir.join("y.bin"), &[("ok", &[1.0][..]),
            (&"n".repeat(MAX_NAME_LEN + 1), &[2.0][..])]);
        assert!(err3.is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multi_stream_roundtrip_and_single_stream_compat() {
        let dir = tdir("streams");
        let path = dir.join("deep.bin");
        let layers: Vec<Vec<f32>> =
            (0..4).map(|l| (0..50).map(|i| (l * 100 + i) as f32).collect()).collect();
        let named: Vec<(String, &[f32])> =
            layers.iter().enumerate().map(|(l, p)| (format!("layer{l}"), &p[..])).collect();
        let streams: Vec<(&str, &[f32])> =
            named.iter().map(|(n, p)| (n.as_str(), *p)).collect();
        save_streams(&path, &streams).unwrap();
        let loaded = load_streams(&path).unwrap();
        assert_eq!(loaded.len(), 4);
        for (l, (name, params)) in loaded.iter().enumerate() {
            assert_eq!(name, &format!("layer{l}"));
            assert_eq!(params, &layers[l]);
        }
        // load() refuses the ambiguity of a multi-stream file...
        assert!(load(&path).is_err());
        // ...but accepts a one-stream v3, and load_streams reads v2/v1
        let single = dir.join("one.bin");
        save_streams(&single, &[("only", &[7.0, 8.0][..])]).unwrap();
        assert_eq!(load(&single).unwrap(), ("only".to_string(), vec![7.0, 8.0]));
        let v2 = dir.join("two.bin");
        save(&v2, "flat", &[1.5]).unwrap();
        assert_eq!(load_streams(&v2).unwrap(), vec![("flat".to_string(), vec![1.5])]);
        // empty stream list is rejected at save time
        assert!(save_streams(&dir.join("none.bin"), &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v3_corruption_is_rejected_without_allocating() {
        let dir = tdir("v3corrupt");
        let path = dir.join("deep.bin");
        let p: Vec<f32> = (0..32).map(|i| i as f32).collect();
        save_streams(&path, &[("a", &p[..]), ("b", &p[..])]).unwrap();
        let good = std::fs::read(&path).unwrap();
        // truncation at the magic, CRC, header, and payload boundaries
        for cut in [7, 11, 14, 20, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load_streams(&path).is_err(), "accepted a {cut}-byte prefix");
        }
        // bit rot → CRC mismatch
        let mut rot = good.clone();
        let last = rot.len() - 1;
        rot[last] ^= 0x01;
        std::fs::write(&path, &rot).unwrap();
        let err = load_streams(&path).unwrap_err().to_string();
        assert!(err.contains("CRC"), "bit rot not caught by CRC: {err}");
        // a stream-count header far beyond the file size fails on the
        // pre-loop bound, and an oversized per-stream count fails on
        // the remaining-bytes check — valid CRCs both times, so the
        // size validation itself is what rejects them
        let mut body = Vec::new();
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(b'x');
        let mut forged = Vec::new();
        forged.extend_from_slice(MAGIC_V3);
        forged.extend_from_slice(&crc32(&body).to_le_bytes());
        forged.extend_from_slice(&body);
        std::fs::write(&path, &forged).unwrap();
        assert!(load_streams(&path).is_err());
        let mut body2 = Vec::new();
        body2.extend_from_slice(&1u32.to_le_bytes());
        body2.extend_from_slice(&1u32.to_le_bytes());
        body2.push(b'x');
        body2.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut forged2 = Vec::new();
        forged2.extend_from_slice(MAGIC_V3);
        forged2.extend_from_slice(&crc32(&body2).to_le_bytes());
        forged2.extend_from_slice(&body2);
        std::fs::write(&path, &forged2).unwrap();
        assert!(load_streams(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
