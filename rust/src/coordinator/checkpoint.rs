//! Flat-parameter checkpoints: a small self-describing binary format
//! (magic, CRC, name, f32 payload), used for pretrained bases and best
//! fine-tuned thetas.
//!
//! ## Format v2 (current writer)
//!
//! ```text
//! magic "QFTCKPT2"  (8 bytes)
//! crc32            u32 LE   — IEEE CRC-32 over everything below
//! name_len         u32 LE   (≤ 4096)
//! name             UTF-8
//! n                u64 LE
//! payload          n × f32 LE
//! ```
//!
//! Hardened per DESIGN.md §11: checkpoints are untrusted input (the
//! multi-tenant registry will load tenant-supplied adapter files), so
//! `load` validates every length against the **actual file size before
//! allocating** — a corrupt `n` header can no longer drive an
//! unbounded `vec![0u8; n * 4]` — with checked arithmetic so `n * 4`
//! cannot overflow on 32-bit targets, and the CRC rejects silent bit
//! rot.  `save` writes to a temp file in the same directory and
//! `rename`s it into place, so a crash mid-save never leaves a torn
//! file where a valid checkpoint used to be (the `torn-write@save`
//! fault probe exercises exactly that crash window).  v1 files
//! (`QFTCKPT1`, no CRC) remain readable with the same size validation.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::fault;

const MAGIC_V1: &[u8; 8] = b"QFTCKPT1";
const MAGIC_V2: &[u8; 8] = b"QFTCKPT2";
const MAX_NAME_LEN: usize = 4096;

/// IEEE CRC-32 (reflected, poly 0xEDB88320), table-driven — the
/// ubiquitous gzip/PNG polynomial, implemented here because the
/// offline vendor set has no checksum crate.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Save a named flat parameter vector (format v2, atomic).
pub fn save(path: &Path, name: &str, params: &[f32]) -> Result<()> {
    let name_bytes = name.as_bytes();
    if name_bytes.len() > MAX_NAME_LEN {
        return Err(Error::msg(format!(
            "checkpoint name is {} bytes (max {MAX_NAME_LEN})",
            name_bytes.len()
        )));
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    // assemble the CRC-covered body: name_len | name | n | payload
    let mut body = Vec::with_capacity(4 + name_bytes.len() + 8 + params.len() * 4);
    body.extend_from_slice(&(name_bytes.len() as u32).to_le_bytes());
    body.extend_from_slice(name_bytes);
    body.extend_from_slice(&(params.len() as u64).to_le_bytes());
    for &v in params {
        body.extend_from_slice(&v.to_le_bytes());
    }
    let crc = crc32(&body);
    // write-then-rename: the destination either keeps its old contents
    // or atomically becomes the complete new checkpoint
    let tmp = tmp_path(path);
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(MAGIC_V2)?;
    f.write_all(&crc.to_le_bytes())?;
    if fault::armed() {
        if let Some(fault::Fault::TornWrite) = fault::probe("save") {
            // simulate a crash mid-save: half the body reaches the temp
            // file, the rename never happens — any previous checkpoint
            // at `path` must survive untouched
            f.write_all(&body[..body.len() / 2])?;
            drop(f);
            return Err(Error::msg(format!(
                "injected fault: torn write to {}",
                tmp.display()
            )));
        }
    }
    f.write_all(&body)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Bounds-checked little-endian reads over an in-memory image.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let Some(end) = end else {
            return Err(Error::Data(format!(
                "checkpoint truncated: need {n} bytes at offset {}, file has {}",
                self.pos,
                self.buf.len()
            )));
        };
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Parse `name_len | name | n | payload` with every length validated
/// against the in-memory image (== the real file size) before any
/// payload-sized allocation.
fn parse_body(body: &[u8]) -> Result<(String, Vec<f32>)> {
    let mut cur = Cursor { buf: body, pos: 0 };
    let name_len = cur.u32()? as usize;
    if name_len > MAX_NAME_LEN {
        return Err(Error::Data(format!(
            "checkpoint name length {name_len} exceeds max {MAX_NAME_LEN}"
        )));
    }
    let name_bytes = cur.take(name_len)?;
    let name = String::from_utf8(name_bytes.to_vec())
        .map_err(|_| Error::Data("checkpoint name is not UTF-8".into()))?;
    let n = cur.u64()?;
    // validate the declared count against the bytes actually present
    // BEFORE sizing any allocation; checked u64 math so `n * 4` cannot
    // wrap (and the usize conversion cannot truncate on 32-bit)
    let payload_bytes =
        n.checked_mul(4).ok_or_else(|| Error::Data(format!("checkpoint count {n} overflows")))?;
    if payload_bytes != cur.remaining() as u64 {
        return Err(Error::Data(format!(
            "checkpoint declares {payload_bytes} payload bytes but {} are present",
            cur.remaining()
        )));
    }
    let n = usize::try_from(n)
        .map_err(|_| Error::Data(format!("checkpoint count {n} exceeds this target's usize")))?;
    let payload = cur.take(n * 4)?;
    let mut params = vec![0.0f32; n];
    for (p, chunk) in params.iter_mut().zip(payload.chunks_exact(4)) {
        *p = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    Ok((name, params))
}

/// Load a checkpoint (v2 or legacy v1); returns (name, params).
/// Corrupt, truncated, or oversized-header files are rejected with a
/// structured error — never a panic, never an allocation beyond the
/// file's own size.
pub fn load(path: &Path) -> Result<(String, Vec<f32>)> {
    // one read bounded by the real file size; all subsequent parsing
    // is bounds-checked against it
    let bytes = std::fs::read(path)?;
    if bytes.len() < 8 {
        return Err(Error::msg(format!("{}: not a QFT checkpoint", path.display())));
    }
    let (magic, rest) = bytes.split_at(8);
    if magic == MAGIC_V2 {
        if rest.len() < 4 {
            return Err(Error::Data(format!("{}: truncated before CRC", path.display())));
        }
        let (crc_bytes, body) = rest.split_at(4);
        let want = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
        let got = crc32(body);
        if got != want {
            return Err(Error::Data(format!(
                "{}: CRC mismatch (file {want:#010x}, computed {got:#010x})",
                path.display()
            )));
        }
        parse_body(body)
    } else if magic == MAGIC_V1 {
        parse_body(rest)
    } else {
        Err(Error::msg(format!("{}: not a QFT checkpoint", path.display())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("qft_ckpt_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn crc32_known_answer() {
        // the standard IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip() {
        let dir = tdir("roundtrip");
        let path = dir.join("a.bin");
        let params: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        save(&path, "test_model", &params).unwrap();
        let (name, loaded) = load(&path).unwrap();
        assert_eq!(name, "test_model");
        assert_eq!(loaded, params);
        // empty payload is a valid checkpoint
        let path2 = dir.join("empty.bin");
        save(&path2, "none", &[]).unwrap();
        assert_eq!(load(&path2).unwrap(), ("none".to_string(), vec![]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_over_existing_file() {
        let dir = tdir("atomic");
        let path = dir.join("a.bin");
        save(&path, "first", &[1.0, 2.0]).unwrap();
        save(&path, "second", &[3.0]).unwrap();
        assert_eq!(load(&path).unwrap(), ("second".to_string(), vec![3.0]));
        assert!(!tmp_path(&path).exists(), "temp file must not survive a successful save");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = tdir("garbage");
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, b"QFT").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_truncation_and_bit_rot() {
        let dir = tdir("corrupt");
        let path = dir.join("a.bin");
        let params: Vec<f32> = (0..64).map(|i| i as f32).collect();
        save(&path, "m", &params).unwrap();
        let good = std::fs::read(&path).unwrap();
        // truncated at every prefix boundary of interest
        for cut in [7, 11, 13, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load(&path).is_err(), "accepted a {cut}-byte prefix");
        }
        // single flipped payload bit → CRC mismatch
        let mut rot = good.clone();
        let last = rot.len() - 1;
        rot[last] ^= 0x01;
        std::fs::write(&path, &rot).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("CRC"), "bit rot not caught by CRC: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_oversized_count_without_allocating() {
        let dir = tdir("oversize");
        let path = dir.join("huge.bin");
        // a v1 header claiming u64::MAX params in a 30-byte file: the
        // pre-hardening loader computed `n * 4` (wrapping) and tried to
        // allocate it; now it must fail on the size check
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(b"hi");
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
        // same header via v2 with a *valid* CRC: still rejected on size
        let body = &bytes[8..];
        let mut v2 = Vec::new();
        v2.extend_from_slice(MAGIC_V2);
        v2.extend_from_slice(&crc32(body).to_le_bytes());
        v2.extend_from_slice(body);
        std::fs::write(&path, &v2).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_legacy_v1() {
        let dir = tdir("v1");
        let path = dir.join("old.bin");
        let params = [0.5f32, -1.25, 3.0];
        // byte-for-byte what the v1 writer produced
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&5u32.to_le_bytes());
        bytes.extend_from_slice(b"old_m");
        bytes.extend_from_slice(&(params.len() as u64).to_le_bytes());
        for v in params {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let (name, loaded) = load(&path).unwrap();
        assert_eq!(name, "old_m");
        assert_eq!(loaded, params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_oversized_name() {
        let dir = tdir("name");
        let err = save(&dir.join("x.bin"), &"n".repeat(MAX_NAME_LEN + 1), &[1.0]);
        assert!(err.is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
