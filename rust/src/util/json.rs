//! Minimal JSON: recursive-descent parser + writer.
//!
//! `serde`/`serde_json` are not in the offline vendor set, so manifests,
//! configs, and cached results go through this module.  It supports the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null) and pretty/compact serialization.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Value> {
        Value::parse(&std::fs::read_to_string(path)?)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(Error::Manifest(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(Error::Manifest(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(Error::Manifest(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => Err(Error::Manifest(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => Err(Error::Manifest(format!("expected object, got {self:?}"))),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    // -- constructors --------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(vals: &[f64]) -> Value {
        Value::Arr(vals.iter().map(|v| Value::Num(*v)).collect())
    }

    pub fn arr_f32(vals: &[f32]) -> Value {
        Value::Arr(vals.iter().map(|v| Value::Num(*v as f64)).collect())
    }

    pub fn arr_str(vals: &[String]) -> Value {
        Value::Arr(vals.iter().map(|v| Value::Str(v.clone())).collect())
    }

    // -- serialization --------------------------------------------------------
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (level + 1)));
                    }
                    v.write(out, indent, level + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * level));
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (level + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * level));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.i, message: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // note: surrogate pairs outside BMP unsupported (unused here)
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    let bytes = &self.b[start..self.i];
                    s.push_str(std::str::from_utf8(bytes).map_err(|_| self.err("bad utf8"))?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64().unwrap(), -2500.0);
        let re = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Value::parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(Value::parse("{}").unwrap(), Value::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("hello").is_err());
        assert!(Value::parse("1 2").is_err());
    }

    #[test]
    fn escapes() {
        let v = Value::Str("a\"b\\c\nd\u{1}".to_string());
        let s = v.to_string_compact();
        assert_eq!(Value::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse(r#""héllo ∑""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ∑");
    }

    #[test]
    fn integers_stay_integers() {
        let v = Value::Num(42.0);
        assert_eq!(v.to_string_compact(), "42");
    }
}
