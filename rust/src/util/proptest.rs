//! proptest-lite: a minimal property-testing harness.
//!
//! The real `proptest` crate is not in the offline vendor set; this
//! module provides what the repo's invariant tests need: seeded random
//! case generation, a fixed case budget, and first-failure reporting
//! with the generating seed so failures are reproducible.
//!
//! ```ignore
//! for_all(200, |rng| gen_matrix(rng), |m| check_rank_bounds(m));
//! ```

use crate::util::rng::Rng;

/// Run `prop` on `cases` random inputs produced by `gen`.
/// Panics with the case index + seed on the first failure.
pub fn for_all<T, G, P>(cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for_all_seeded(0xA11CE, cases, &mut gen, &mut prop);
}

/// Seeded variant (each case derives its own sub-stream so a failing
/// case can be replayed in isolation).
pub fn for_all_seeded<T, G, P>(seed: u64, cases: usize, gen: &mut G, prop: &mut P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Rng::new(seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property failed at case {case} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        for_all(
            50,
            |rng| rng.below(100),
            |&n| {
                if n < 100 {
                    Ok(())
                } else {
                    Err(format!("{n} >= 100"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        for_all(50, |rng| rng.below(10), |&n| {
            if n < 5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }
}
