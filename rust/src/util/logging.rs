//! Tiny leveled logger with wall-clock timestamps (no external crates).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let secs = t.as_secs() % 86_400;
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!(
        "[{:02}:{:02}:{:02}.{:03} {tag}] {msg}",
        secs / 3600,
        (secs / 60) % 60,
        secs % 60,
        t.subsec_millis()
    );
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}
