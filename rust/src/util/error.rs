//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    #[error("json parse error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
