//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error`/`From` impls (the `thiserror` crate is
//! not in the offline vendor set, and the crate builds dependency-free
//! by default).  The `Xla` variant wraps whichever PJRT backend is
//! compiled in — the real `xla::Error` under the `pjrt` feature, the
//! inert stub's error otherwise (see `runtime::pjrt`).

use std::fmt;

#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    Xla(crate::runtime::pjrt::Error),
    Json { offset: usize, message: String },
    Manifest(String),
    Config(String),
    Shape(String),
    Data(String),
    /// A compute job (pool chunk or kernel) panicked; the payload is
    /// the panic message.  Produced by `compute::pool::catching` so a
    /// worker panic becomes a structured error on the submitter
    /// instead of unwinding through the serving stack.
    Compute(String),
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Json { offset, message } => {
                write!(f, "json parse error at byte {offset}: {message}")
            }
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Compute(m) => write!(f, "compute fault: {m}"),
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Xla(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::runtime::pjrt::Error> for Error {
    fn from(e: crate::runtime::pjrt::Error) -> Self {
        Error::Xla(e)
    }
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
