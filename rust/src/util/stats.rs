//! Small statistics helpers used by the evaluator and bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th quantile (0..=1) by linear interpolation on a sorted copy.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (idx - lo as f64)
    }
}

/// Format mean ± std with fixed precision.
pub fn fmt_mean_std(xs: &[f64], prec: usize) -> String {
    if xs.len() <= 1 {
        format!("{:.*}", prec, mean(xs))
    } else {
        format!("{:.*} ± {:.*}", prec, mean(xs), prec, std_dev(xs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }
}
