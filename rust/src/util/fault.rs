//! Deterministic fault injection (`QFT_FAULT`).
//!
//! Every failure path in DESIGN.md §11 — a worker panic mid-GEMM, a
//! non-finite decode row, a NaN loss, a torn checkpoint write — is
//! reachable on demand, so tests and CI exercise the recovery code
//! instead of trusting it on inspection.
//!
//! Grammar (comma-separated specs in the `QFT_FAULT` env var):
//!
//! ```text
//! spec  ::= kind [ '@' site ] [ ':' count ]
//! kind  ::= 'panic' | 'nan' | 'torn-write' | 'crash' | 'oom'
//! ```
//!
//! * `site` names a probe point (`gemm`, `decode`, `loss`, `save`,
//!   `step`, `snapshot`, `alloc`); omitted ⇒ the spec matches every
//!   probing site.
//! * `count` is the 0-based probe index at which the spec fires, once
//!   (each site keeps a process-wide counter); omitted ⇒ the spec
//!   fires at **every** probe — e.g. `nan@loss` makes the trainer's
//!   loss persistently non-finite, which is how the retry-exhaustion
//!   path is driven.
//!
//! Examples: `panic@gemm:3` panics the 4th GEMM chunk executed by the
//! process; `nan@decode:7` poisons the 8th decode step's output;
//! `torn-write` truncates every checkpoint write mid-stream;
//! `oom@alloc:5` fails the 6th KV-arena page allocation as if the
//! `--kv-pages` budget were exhausted (the `CacheExhausted` quarantine
//! path, pinned in `fault_props`).
//!
//! The `crash` kind is the crash-consistency harness's kill switch: a
//! matching [`crash_point`] **aborts the process** (no unwind, no
//! destructors — the same state a `kill -9` leaves behind).  The
//! trainer probes `step` before each optimizer step, and the
//! checkpoint writer probes `snapshot` twice per save — immediately
//! before and immediately after the temp-file rename — so
//! `crash@step:7` dies between steps, `crash@snapshot:0` dies with
//! only the torn temp file on disk, and `crash@snapshot:1` dies just
//! after the first manifest became durable.  `crash-smoke` CI and
//! `resume_props` relaunch with `--resume` and pin the recovered run
//! bitwise against an uninterrupted reference.
//!
//! Probes are free when disarmed: call sites guard with [`armed`]
//! (two relaxed atomic loads) before paying the [`probe`] lock, so the
//! serve hot path carries no measurable cost in production — the
//! `serve_robustness` bench gate holds the whole validation layer
//! (this included) to ≤ 2% per decoded token.
//!
//! The env var is read once, lazily; tests that sweep faults call
//! [`reload`] after changing it (env state is process-global, so such
//! tests live in ONE `#[test]` per binary — the `pool_props`
//! convention).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

/// What an armed probe site should do to itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Panic at the probe point (pool isolation / catch_unwind path).
    Panic,
    /// Poison the probe point's output with a NaN.
    Nan,
    /// Abandon a file write partway through (atomicity path).
    TornWrite,
    /// Abort the process at the probe point (crash-consistency path):
    /// acted on only by [`crash_point`].
    Crash,
    /// Fail a KV-arena page allocation as if the page budget were
    /// exhausted (the per-request `CacheExhausted` quarantine path).
    Oom,
}

#[derive(Clone, Debug)]
struct Spec {
    kind: Fault,
    /// `None` matches any probing site.
    site: Option<String>,
    /// `None` fires at every probe; `Some(n)` fires only when the
    /// site's counter equals `n`.
    at: Option<usize>,
}

struct State {
    specs: Vec<Spec>,
    counts: HashMap<String, usize>,
}

static ARMED: AtomicBool = AtomicBool::new(false);

fn state() -> &'static Mutex<State> {
    static STATE: OnceLock<Mutex<State>> = OnceLock::new();
    STATE.get_or_init(|| {
        let specs = parse(&std::env::var("QFT_FAULT").unwrap_or_default());
        ARMED.store(!specs.is_empty(), Ordering::Relaxed);
        Mutex::new(State { specs, counts: HashMap::new() })
    })
}

fn parse(raw: &str) -> Vec<Spec> {
    let mut specs = Vec::new();
    for part in raw.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (head, at) = match part.split_once(':') {
            Some((h, n)) => match n.parse::<usize>() {
                Ok(n) => (h, Some(n)),
                Err(_) => {
                    crate::warnlog!("QFT_FAULT: bad count in {part:?}, spec ignored");
                    continue;
                }
            },
            None => (part, None),
        };
        let (kind_s, site) = match head.split_once('@') {
            Some((k, s)) => (k, Some(s.to_string())),
            None => (head, None),
        };
        let kind = match kind_s {
            "panic" => Fault::Panic,
            "nan" => Fault::Nan,
            "torn-write" => Fault::TornWrite,
            "crash" => Fault::Crash,
            "oom" => Fault::Oom,
            other => {
                crate::warnlog!("QFT_FAULT: unknown kind {other:?}, spec ignored");
                continue;
            }
        };
        specs.push(Spec { kind, site, at });
    }
    specs
}

/// Cheap hot-path guard: true iff any fault spec is loaded.
#[inline]
pub fn armed() -> bool {
    state();
    ARMED.load(Ordering::Relaxed)
}

/// Record one probe at `site` and return the fault to inject, if any.
/// Each call increments the site's process-wide counter; a spec with a
/// `count` matches exactly one probe.  Call sites act only on the
/// [`Fault`] kinds that make sense for them and ignore the rest.
pub fn probe(site: &str) -> Option<Fault> {
    if !armed() {
        return None;
    }
    let mut st = state().lock().unwrap_or_else(|p| p.into_inner());
    let n = {
        let c = st.counts.entry(site.to_string()).or_insert(0);
        let n = *c;
        *c += 1;
        n
    };
    st.specs
        .iter()
        .find(|s| {
            let site_ok = match &s.site {
                None => true,
                Some(w) => w == site,
            };
            let at_ok = match s.at {
                None => true,
                Some(at) => at == n,
            };
            site_ok && at_ok
        })
        .map(|s| s.kind)
}

/// Abort the process if a `crash` spec matches `site`.  `abort`, not
/// `panic!`: a real power cut or `kill -9` runs no unwind code either,
/// so nothing between the last durable snapshot and the crash may be
/// rescued by destructors — exactly the window the resume contract is
/// tested against.  Other fault kinds matching `site` are ignored
/// here (each call site acts only on the kinds that make sense for
/// it), but the probe still ticks the site's counter.
pub fn crash_point(site: &str) {
    if armed() {
        if let Some(Fault::Crash) = probe(site) {
            eprintln!("QFT_FAULT: injected crash at {site}");
            std::process::abort();
        }
    }
}

/// Re-read `QFT_FAULT` and reset every probe counter.  Test-sweep
/// entry point; production code never calls this.
pub fn reload() {
    let specs = parse(&std::env::var("QFT_FAULT").unwrap_or_default());
    ARMED.store(!specs.is_empty(), Ordering::Relaxed);
    let mut st = state().lock().unwrap_or_else(|p| p.into_inner());
    st.specs = specs;
    st.counts.clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    // Pure-parser tests only: arming via the env var is process-global
    // state, exercised end-to-end in `rust/tests/fault_props.rs`.

    #[test]
    fn grammar_parses() {
        let specs =
            parse("panic@gemm:3, nan@decode:7 ,torn-write,nan@loss,crash@snapshot:1,oom@alloc:5");
        assert_eq!(specs.len(), 6);
        assert_eq!(specs[5].kind, Fault::Oom);
        assert_eq!(specs[5].site.as_deref(), Some("alloc"));
        assert_eq!(specs[5].at, Some(5));
        assert_eq!(specs[4].kind, Fault::Crash);
        assert_eq!(specs[4].site.as_deref(), Some("snapshot"));
        assert_eq!(specs[4].at, Some(1));
        assert_eq!(specs[0].kind, Fault::Panic);
        assert_eq!(specs[0].site.as_deref(), Some("gemm"));
        assert_eq!(specs[0].at, Some(3));
        assert_eq!(specs[1].kind, Fault::Nan);
        assert_eq!(specs[1].at, Some(7));
        assert_eq!(specs[2].kind, Fault::TornWrite);
        assert_eq!(specs[2].site, None);
        assert_eq!(specs[2].at, None);
        assert_eq!(specs[3].kind, Fault::Nan);
        assert_eq!(specs[3].site.as_deref(), Some("loss"));
        assert_eq!(specs[3].at, None);
    }

    #[test]
    fn bad_specs_are_ignored() {
        assert!(parse("").is_empty());
        assert!(parse("  ,  ").is_empty());
        assert!(parse("explode@gemm:1").is_empty());
        assert!(parse("panic@gemm:notanumber").is_empty());
        assert_eq!(parse("junk,nan@decode:0").len(), 1);
    }
}
