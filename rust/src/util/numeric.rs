//! Small shared numeric validation helpers.
//!
//! `non_finite_at` started life inside `serve::scheduler` as the
//! per-token output validation; it is also exactly the check serving
//! intake runs on arriving prompts and the host trainer's anomaly
//! detector runs on gradients, so it lives here where all three share
//! one definition (and the `serve_robustness` bench prices the same
//! code the scheduler executes).

/// Index of the first non-finite (NaN/±inf) element of a slice, if
/// any.
pub fn non_finite_at(row: &[f32]) -> Option<usize> {
    row.iter().position(|v| !v.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_first_non_finite_element() {
        assert_eq!(non_finite_at(&[]), None);
        assert_eq!(non_finite_at(&[0.0, -1.5, 3.0e37]), None);
        assert_eq!(non_finite_at(&[0.0, f32::NAN, f32::INFINITY]), Some(1));
        assert_eq!(non_finite_at(&[f32::NEG_INFINITY]), Some(0));
    }
}
