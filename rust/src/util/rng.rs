//! Deterministic PRNG: splitmix64 seeding + xoshiro256++ core, with
//! Box-Muller normals and sampling helpers.
//!
//! Every random quantity in the system (parameter init, data generation,
//! batch shuffling) flows through this module keyed by `(global_seed,
//! stream_name)`, so runs are reproducible across machines and the
//! QuanTA shadow-chain trick (identical `S`/`T` init via a shared stream
//! key; paper Eq. 8) is exact.

/// splitmix64 — used for seeding and for hashing stream names.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// FNV-1a string hash (stable across runs; used to derive stream seeds).
pub fn hash_str(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

/// Serializable snapshot of an [`Rng`] (checkpoint v4 run manifests):
/// the four xoshiro256++ state words **plus** the cached Box-Muller
/// spare, so a restored stream continues mid-pair — dropping the spare
/// would shift every subsequent normal draw by one and break the
/// resume-bitwise contract.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive a named sub-stream: `Rng::stream(seed, "L3.wq")`.
    pub fn stream(seed: u64, name: &str) -> Self {
        Self::new(seed ^ hash_str(name).rotate_left(17))
    }

    /// Snapshot the full generator state for serialization.
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare: self.spare }
    }

    /// Rebuild a generator from a [`state`](Rng::state) snapshot; the
    /// restored stream's draw sequence continues exactly where the
    /// snapshotted one left off.
    pub fn from_state(st: RngState) -> Self {
        Rng { s: st.s, spare: st.spare }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for practical purposes
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Fill a slice with N(0, std^2) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = (self.normal() as f32) * std;
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::stream(1, "x");
        let mut b = Rng::stream(1, "y");
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn streams_reproduce() {
        let mut a = Rng::stream(7, "L0.wq");
        let mut b = Rng::stream(7, "L0.wq");
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip_continues_draw_sequence() {
        // snapshot mid-pair: an odd number of normal() calls leaves a
        // cached Box-Muller spare, which the state must carry so the
        // restored stream's next draw is the spare, not a fresh pair
        let mut a = Rng::stream(13, "resume");
        for _ in 0..7 {
            a.normal();
        }
        let st = a.state();
        assert!(st.spare.is_some(), "7 normal draws must leave a spare cached");
        let mut b = Rng::from_state(st);
        for _ in 0..64 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.uniform().to_bits(), b.uniform().to_bits());
        }
        // the state itself round-trips exactly
        assert_eq!(a.state(), b.state());
        // and a spare-less snapshot restores too
        let mut c = Rng::new(5);
        c.next_u64();
        let mut d = Rng::from_state(c.state());
        assert_eq!(c.state().spare, None);
        for _ in 0..16 {
            assert_eq!(c.normal().to_bits(), d.normal().to_bits());
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(11);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }
}
