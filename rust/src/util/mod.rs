//! Infrastructure utilities: error type, PRNG, JSON, logging, statistics,
//! and a minimal property-testing harness (external crates like `serde`,
//! `proptest`, and `criterion` are unavailable in the offline vendor set,
//! so the pieces we need are implemented and tested here).

pub mod error;
pub mod fault;
pub mod rng;
pub mod json;
pub mod logging;
pub mod numeric;
pub mod stats;
pub mod proptest;
