//! Block-paged KV arena (DESIGN.md §14): bounded-memory serving.
//!
//! The PR 5 `DecodeState` owned a grow-only contiguous `[len, d]` K/V
//! pair per request slot, so resident cache memory scaled with
//! *slots × max-len* — a slot that once served a 256-token request
//! kept 256 tokens of capacity forever, even while serving 4-token
//! ones.  This module is the standard fix (vLLM-style paged
//! attention, on the host substrate): one process-wide [`KvArena`] of
//! fixed-size pages (`QFT_KV_PAGE` tokens per page), a LIFO free-list
//! allocator, and per-request [`PageTable`]s mapping logical positions
//! to pages.  Cache memory is bounded by **tokens in flight**: a
//! retired request's pages return to the free list immediately, and a
//! bounded arena (`--kv-pages`) turns would-be OOM into a structured
//! [`CacheFull`] that the scheduler converts to
//! `ServeError::CacheExhausted` — one request quarantined, the process
//! and every other request untouched.
//!
//! ## Addressing
//!
//! Logical token `t` of a request lives in `table.pages[t / P]` at row
//! `t % P` (`P` = [`KvArena::page_tokens`]).  Page `p`'s K rows occupy
//! `arena.k[p·P·d .. (p+1)·P·d]` row-major (V likewise), so a page is
//! itself a contiguous `[P, d]` panel and attention walks a request's
//! history as a short run of contiguous segments
//! ([`KvArena::runs`]).  The segment walk feeds
//! `model::block::attn_row_segs`, which executes the *same float ops
//! in the same order* as the contiguous path — paged decode is
//! **bitwise** equal to contiguous decode at any page size
//! (`rust/tests/kv_props.rs` pins page sizes {1, 4, 16} against a
//! one-page arena and the full forward recompute, across
//! `QFT_THREADS`).
//!
//! ## Copy-on-write forking
//!
//! [`KvArena::fork`] clones a page table by bumping each page's
//! refcount — O(pages), no row copies — so speculative snapshots and
//! shared system-prompt prefixes are nearly free.  Writes stay
//! isolated lazily: [`KvArena::push`] into a tail page whose refcount
//! is > 1 first copies that page's *filled prefix* to a fresh page
//! (the only bytes ever copied), decrements the shared page, and
//! retargets the writer's table.  Full pages are only ever read, so
//! sharers never observe a writer's divergence.
//!
//! ## Exhaustion
//!
//! Allocation failure ([`CacheFull`]) is a *per-request* condition,
//! not a process fault: the failed push leaves the table unchanged
//! (the row is simply not appended), the owning `DecodeState` is
//! flagged, and the scheduler's retire sweep quarantines exactly that
//! request.  `QFT_FAULT=oom@alloc:n` forces the `n`-th page
//! allocation to fail, which is how `fault_props` drives this path
//! deterministically.

use crate::util::error::{Error, Result};

/// Default tokens per page: `QFT_KV_PAGE` if set, else 16.
pub fn default_page_tokens() -> usize {
    std::env::var("QFT_KV_PAGE")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(16)
}

/// The arena has no free page and may not grow: the request that
/// asked must be quarantined (`ServeError::CacheExhausted`), everyone
/// else keeps decoding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheFull {
    /// The arena's page budget at the time of the failure.
    pub pages: usize,
}

/// A request's logical-position → page mapping.  Owned by the
/// request's `DecodeState`; all row storage lives in the [`KvArena`].
#[derive(Clone, Debug, Default)]
pub struct PageTable {
    pages: Vec<u32>,
    len: usize,
}

impl PageTable {
    pub fn new() -> PageTable {
        PageTable::default()
    }

    /// Tokens stored (the next push lands at this logical position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pages currently mapped.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }
}

/// Process-wide paged K/V storage: `n_pages × page_tokens × d` floats
/// per side, a refcount per page, and a LIFO free list.  `max_pages`
/// of 0 means unbounded (the blob grows on demand, amortized — the
/// default for tests and single-request decode); a positive bound
/// turns exhaustion into [`CacheFull`] instead of growth.
#[derive(Clone, Debug)]
pub struct KvArena {
    d: usize,
    page_tokens: usize,
    max_pages: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Per-page refcount; 0 = free.  CoW sharing is any count > 1.
    refcnt: Vec<u32>,
    /// Free page ids, popped from the back.
    free: Vec<u32>,
    pages_in_use: usize,
    peak_pages: usize,
}

impl KvArena {
    /// Arena for width-`d` rows, `page_tokens` tokens per page,
    /// bounded at `max_pages` pages (0 = unbounded).
    pub fn new(d: usize, page_tokens: usize, max_pages: usize) -> Result<KvArena> {
        if d == 0 || page_tokens == 0 {
            return Err(Error::Config(format!(
                "kv arena: degenerate d {d} / page_tokens {page_tokens}"
            )));
        }
        Ok(KvArena {
            d,
            page_tokens,
            max_pages,
            k: Vec::new(),
            v: Vec::new(),
            refcnt: Vec::new(),
            free: Vec::new(),
            pages_in_use: 0,
            peak_pages: 0,
        })
    }

    /// Unbounded arena with the `QFT_KV_PAGE` default page size — what
    /// single-request conveniences (`decode_sequence`) build
    /// internally.
    pub fn unbounded(d: usize) -> KvArena {
        KvArena::new(d, default_page_tokens(), 0).expect("d > 0")
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Tokens per page.
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Page budget (0 = unbounded).
    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    /// Pages currently referenced by at least one table.
    pub fn pages_in_use(&self) -> usize {
        self.pages_in_use
    }

    /// High-water mark of [`KvArena::pages_in_use`] since the last
    /// [`KvArena::reset_all`].
    pub fn peak_pages(&self) -> usize {
        self.peak_pages
    }

    /// K+V bytes one page occupies.
    pub fn page_bytes(&self) -> usize {
        self.page_tokens * self.d * 2 * std::mem::size_of::<f32>()
    }

    /// Peak resident K/V bytes since the last reset — the
    /// `ServeStats::resident_kv_bytes` gauge.
    pub fn peak_resident_bytes(&self) -> usize {
        self.peak_pages * self.page_bytes()
    }

    /// Pages the backing blob has ever materialized (free or not).
    pub fn allocated_pages(&self) -> usize {
        self.refcnt.len()
    }

    fn page_elems(&self) -> usize {
        self.page_tokens * self.d
    }

    /// Claim a page: free list first, then blob growth while under the
    /// bound.  `oom@alloc:n` fault specs fail the `n`-th call here.
    fn alloc_page(&mut self) -> std::result::Result<u32, CacheFull> {
        if crate::util::fault::armed() {
            if let Some(crate::util::fault::Fault::Oom) = crate::util::fault::probe("alloc") {
                return Err(CacheFull { pages: self.max_pages });
            }
        }
        let p = match self.free.pop() {
            Some(p) => p,
            None => {
                let n = self.refcnt.len();
                if self.max_pages > 0 && n >= self.max_pages {
                    return Err(CacheFull { pages: self.max_pages });
                }
                let elems = self.page_elems();
                self.k.resize((n + 1) * elems, 0.0);
                self.v.resize((n + 1) * elems, 0.0);
                self.refcnt.push(0);
                n as u32
            }
        };
        debug_assert_eq!(self.refcnt[p as usize], 0, "allocated a live page");
        self.refcnt[p as usize] = 1;
        self.pages_in_use += 1;
        self.peak_pages = self.peak_pages.max(self.pages_in_use);
        Ok(p)
    }

    /// Drop one reference to `p`; the last reference frees it.
    fn unref_page(&mut self, p: u32) {
        let r = &mut self.refcnt[p as usize];
        debug_assert!(*r > 0, "unref of a free page");
        *r -= 1;
        if *r == 0 {
            self.free.push(p);
            self.pages_in_use -= 1;
        }
    }

    /// Append one position's K/V rows to `table`.  On [`CacheFull`]
    /// the table is left exactly as it was (no partial append).
    pub fn push(
        &mut self,
        table: &mut PageTable,
        krow: &[f32],
        vrow: &[f32],
    ) -> std::result::Result<(), CacheFull> {
        debug_assert_eq!(krow.len(), self.d);
        debug_assert_eq!(vrow.len(), self.d);
        let slot = table.len % self.page_tokens;
        if slot == 0 {
            // new tail page
            let p = self.alloc_page()?;
            table.pages.push(p);
        } else {
            // copy-on-write: appending into a shared tail page would
            // be visible to every fork, so copy the filled prefix to
            // a private page first (the only rows CoW ever copies)
            let tail = *table.pages.last().expect("slot > 0 implies a tail page");
            if self.refcnt[tail as usize] > 1 {
                let np = self.alloc_page()?;
                let elems = self.page_elems();
                let (src, dst) = (tail as usize * elems, np as usize * elems);
                let filled = slot * self.d;
                self.k.copy_within(src..src + filled, dst);
                self.v.copy_within(src..src + filled, dst);
                self.unref_page(tail);
                *table.pages.last_mut().unwrap() = np;
            }
        }
        let tail = *table.pages.last().unwrap() as usize;
        let off = tail * self.page_elems() + slot * self.d;
        self.k[off..off + self.d].copy_from_slice(krow);
        self.v[off..off + self.d].copy_from_slice(vrow);
        table.len += 1;
        Ok(())
    }

    /// Share `table`'s history: bump every page's refcount and return
    /// an independent table over the same pages.  O(pages), zero row
    /// copies; divergence is handled lazily by [`KvArena::push`]'s
    /// CoW rule.
    ///
    /// Tail-page edge cases (the seams the prefix-cache scheduler and
    /// beam/speculative forks actually hit, pinned in this module's
    /// unit tests): forking a table whose tail page is filled to
    /// exactly `page_tokens` shares *full* pages only, so **no CoW
    /// split ever occurs** — either side's next push lands at slot 0
    /// and allocates a fresh private page; forking an empty table
    /// shares nothing and the fork grows fully independently.
    pub fn fork(&mut self, table: &PageTable) -> PageTable {
        self.fork_prefix(table, table.len)
    }

    /// [`KvArena::fork`] of the first `tokens` positions only: share
    /// exactly the pages covering rows `0..tokens` (refcount bump, no
    /// copies) and return a table of length `tokens`.  Pages past the
    /// prefix stay private to `table` — the donor may keep pushing
    /// rows beyond `tokens` without ever colliding with the fork.
    ///
    /// When `tokens` is a multiple of `page_tokens` (the prefix-cache
    /// scheduler's page-granular case) every shared page is full, so
    /// the fork's next push allocates a fresh page and no CoW split is
    /// paid; a mid-page `tokens` shares the tail page too and the
    /// fork's first push CoW-copies only its `tokens % page_tokens`
    /// filled rows.
    ///
    /// # Panics
    /// Debug-asserts `tokens <= table.len()`.
    pub fn fork_prefix(&mut self, table: &PageTable, tokens: usize) -> PageTable {
        debug_assert!(
            tokens <= table.len,
            "fork_prefix: {tokens} tokens from a {}-token table",
            table.len
        );
        let n_pages = tokens.div_ceil(self.page_tokens);
        let pages: Vec<u32> = table.pages[..n_pages].to_vec();
        for &p in &pages {
            self.refcnt[p as usize] += 1;
        }
        PageTable { pages, len: tokens }
    }

    /// Return every page `table` references (refcount-driven — shared
    /// pages survive until their last holder releases) and empty the
    /// table.
    pub fn release(&mut self, table: &mut PageTable) {
        for i in 0..table.pages.len() {
            let p = table.pages[i];
            self.unref_page(p);
        }
        table.pages.clear();
        table.len = 0;
    }

    /// Forget every table and make all materialized pages free again,
    /// resetting the peak gauge.  Only valid when no live `PageTable`
    /// will be used afterwards — the scheduler calls this at the top
    /// of each `run`, where all sessions are (re)built fresh.
    pub fn reset_all(&mut self) {
        let n = self.refcnt.len();
        self.refcnt.iter_mut().for_each(|r| *r = 0);
        // descending stack so pops hand out pages in ascending order
        self.free = (0..n as u32).rev().collect();
        self.pages_in_use = 0;
        self.peak_pages = 0;
    }

    /// Contiguous `(k, v, rows)` segments covering `table`'s history
    /// in logical order — the iterator `attn_row_segs` walks twice
    /// (scores pass, V pass).  Cloning is O(1).
    pub fn runs<'a>(&'a self, table: &'a PageTable) -> PageRuns<'a> {
        PageRuns {
            k: &self.k,
            v: &self.v,
            pages: &table.pages,
            page_tokens: self.page_tokens,
            page_elems: self.page_elems(),
            remaining: table.len,
            idx: 0,
        }
    }

    /// The raw `(k, v)` page blobs — the K-cache-major storage the
    /// batched attention kernel (`serve::decode`, DESIGN.md §15)
    /// indexes directly via [`KvArena::run_offsets`], so its
    /// per-(request, page-run) work items are plain offsets instead of
    /// borrowed slices and can live in reusable scratch.
    pub(crate) fn raw_kv(&self) -> (&[f32], &[f32]) {
        (&self.k, &self.v)
    }

    /// [`KvArena::runs`] as plain indices: yields
    /// `(elem_offset, first_row, rows)` per contiguous segment of
    /// `table`, where the segment's K rows occupy
    /// `raw_kv().0[elem_offset .. elem_offset + rows·d]` (V likewise)
    /// and cover logical positions `first_row .. first_row + rows`.
    pub(crate) fn run_offsets<'a>(
        &self,
        table: &'a PageTable,
    ) -> impl Iterator<Item = (usize, usize, usize)> + 'a {
        let (pt, pe, len) = (self.page_tokens, self.page_elems(), table.len);
        table.pages.iter().enumerate().map(move |(i, &p)| {
            let t0 = i * pt;
            (p as usize * pe, t0, (len - t0).min(pt))
        })
    }

    /// Copy `table`'s K rows into one contiguous `[len, d]` panel —
    /// test/debug helper for byte-level CoW assertions.
    pub fn gather_k(&self, table: &PageTable) -> Vec<f32> {
        let mut out = Vec::with_capacity(table.len * self.d);
        for (kseg, _, rows) in self.runs(table) {
            out.extend_from_slice(&kseg[..rows * self.d]);
        }
        out
    }
}

/// Iterator over a request's K/V history as contiguous page segments:
/// yields `(k_rows, v_rows, rows_in_segment)` with rows laid out
/// `[rows, d]` row-major inside each segment.
#[derive(Clone)]
pub struct PageRuns<'a> {
    k: &'a [f32],
    v: &'a [f32],
    pages: &'a [u32],
    page_tokens: usize,
    page_elems: usize,
    remaining: usize,
    idx: usize,
}

impl<'a> Iterator for PageRuns<'a> {
    type Item = (&'a [f32], &'a [f32], usize);

    fn next(&mut self) -> Option<Self::Item> {
        if self.remaining == 0 {
            return None;
        }
        let p = self.pages[self.idx] as usize;
        self.idx += 1;
        let rows = self.remaining.min(self.page_tokens);
        self.remaining -= rows;
        let off = p * self.page_elems;
        let n = rows * (self.page_elems / self.page_tokens);
        Some((&self.k[off..off + n], &self.v[off..off + n], rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_alloc_free_and_reuse() {
        let mut a = KvArena::new(3, 2, 0).unwrap();
        let mut t = PageTable::new();
        for i in 0..5 {
            a.push(&mut t, &[i as f32; 3], &[-(i as f32); 3]).unwrap();
        }
        assert_eq!(t.len(), 5);
        assert_eq!(t.n_pages(), 3, "5 tokens at 2/page = 3 pages");
        assert_eq!(a.pages_in_use(), 3);
        assert_eq!(a.peak_pages(), 3);
        let blob = a.allocated_pages();
        a.release(&mut t);
        assert_eq!(t.len(), 0);
        assert_eq!(a.pages_in_use(), 0);
        assert_eq!(a.allocated_pages(), blob, "release keeps the blob");
        // a new request reuses freed pages, no blob growth
        let mut t2 = PageTable::new();
        for i in 0..6 {
            a.push(&mut t2, &[i as f32; 3], &[0.0; 3]).unwrap();
        }
        assert_eq!(a.allocated_pages(), blob);
        assert_eq!(a.gather_k(&t2), (0..6).flat_map(|i| [i as f32; 3]).collect::<Vec<_>>());
    }

    #[test]
    fn bounded_arena_reports_cache_full_without_corrupting_the_table() {
        let mut a = KvArena::new(2, 2, 2).unwrap(); // 4 tokens max
        let mut t = PageTable::new();
        for i in 0..4 {
            a.push(&mut t, &[i as f32; 2], &[0.0; 2]).unwrap();
        }
        let err = a.push(&mut t, &[9.0; 2], &[9.0; 2]).unwrap_err();
        assert_eq!(err, CacheFull { pages: 2 });
        assert_eq!(t.len(), 4, "failed push must not grow the table");
        assert_eq!(a.gather_k(&t).len(), 8);
        // freeing makes the same arena serve the next request
        a.release(&mut t);
        let mut t2 = PageTable::new();
        a.push(&mut t2, &[1.0; 2], &[1.0; 2]).unwrap();
    }

    #[test]
    fn fork_shares_pages_and_cow_isolates_the_writer() {
        let mut a = KvArena::new(2, 4, 0).unwrap();
        let mut w = PageTable::new();
        for i in 0..6 {
            a.push(&mut w, &[i as f32; 2], &[i as f32; 2]).unwrap();
        }
        let r = a.fork(&w);
        assert_eq!(a.pages_in_use(), 2, "fork must not copy pages");
        let before = a.gather_k(&r);
        // writer diverges: tail page (refcnt 2) is CoW-copied, full
        // page stays shared
        a.push(&mut w, &[100.0; 2], &[100.0; 2]).unwrap();
        assert_eq!(a.pages_in_use(), 3, "CoW copies exactly the tail page");
        assert_eq!(a.gather_k(&r), before, "sharer's bytes must not move");
        assert_eq!(a.gather_k(&w)[12..14], [100.0; 2]);
        // refcount-driven reclaim: releasing both frees everything
        let mut r = r;
        a.release(&mut w);
        assert!(a.pages_in_use() > 0, "sharer still holds pages");
        a.release(&mut r);
        assert_eq!(a.pages_in_use(), 0);
    }

    #[test]
    fn fork_of_exactly_full_tail_page_never_splits() {
        // the page-granular prefix-cache case: every shared page is
        // full, so NO CoW split may occur — the next push on either
        // side allocates a fresh private page and the shared bytes
        // never move
        let mut a = KvArena::new(2, 3, 0).unwrap();
        let mut parent = PageTable::new();
        for i in 0..6 {
            a.push(&mut parent, &[i as f32; 2], &[10.0 + i as f32; 2]).unwrap();
        }
        assert_eq!(parent.n_pages(), 2, "6 tokens at 3/page = 2 exactly-full pages");
        let before_k = a.gather_k(&parent);
        let child = a.fork(&parent);
        assert_eq!(a.pages_in_use(), 2, "fork allocates nothing");
        assert_eq!(child.len(), 6);
        assert_eq!(child.pages, parent.pages, "same pages, shared");
        for &p in &parent.pages {
            assert_eq!(a.refcnt[p as usize], 2, "each full page holds both references");
        }
        // child's next push: slot 0 -> fresh page on the child ONLY,
        // no filled-prefix copy (nothing to split)
        let allocs_before = a.allocated_pages();
        let mut child = child;
        a.push(&mut child, &[100.0; 2], &[100.0; 2]).unwrap();
        assert_eq!(a.pages_in_use(), 3, "one fresh page, zero CoW pages");
        assert_eq!(child.n_pages(), 3);
        assert_eq!(parent.n_pages(), 2, "parent untouched by the child's growth");
        assert_eq!(a.refcnt[child.pages[2] as usize], 1, "tail page is private");
        for &p in &parent.pages {
            assert_eq!(a.refcnt[p as usize], 2, "shared pages keep both references");
        }
        // parent's next push likewise gets its own page; bytes of the
        // shared prefix are byte-exact on both sides throughout
        a.push(&mut parent, &[200.0; 2], &[200.0; 2]).unwrap();
        assert_eq!(a.pages_in_use(), 4);
        assert_ne!(parent.pages[2], child.pages[2], "divergent tails must not alias");
        let pk = a.gather_k(&parent);
        let ck = a.gather_k(&child);
        assert_eq!(&pk[..12], &before_k[..], "parent prefix bytes moved");
        assert_eq!(&ck[..12], &before_k[..], "child prefix bytes moved");
        assert_eq!(&pk[12..], &[200.0; 2]);
        assert_eq!(&ck[12..], &[100.0; 2]);
        assert_eq!(a.allocated_pages(), allocs_before + 2, "exactly the two fresh tails");
        a.release(&mut parent);
        a.release(&mut child);
        assert_eq!(a.pages_in_use(), 0, "refcounts reclaim shared and private alike");
    }

    #[test]
    fn fork_of_empty_table_is_independent() {
        let mut a = KvArena::new(2, 2, 0).unwrap();
        let parent = PageTable::new();
        let mut child = a.fork(&parent);
        assert_eq!((child.len(), child.n_pages()), (0, 0));
        assert_eq!(a.pages_in_use(), 0, "empty fork shares nothing");
        // the fork is a fully independent table afterwards
        a.push(&mut child, &[7.0; 2], &[8.0; 2]).unwrap();
        assert_eq!(a.pages_in_use(), 1);
        assert_eq!(a.refcnt[child.pages[0] as usize], 1);
        assert_eq!(a.gather_k(&child), vec![7.0; 2]);
        assert_eq!(parent.len(), 0);
    }

    #[test]
    fn fork_prefix_shares_only_the_covered_pages() {
        let mut a = KvArena::new(2, 2, 0).unwrap();
        let mut parent = PageTable::new();
        for i in 0..7 {
            a.push(&mut parent, &[i as f32; 2], &[i as f32; 2]).unwrap();
        }
        assert_eq!(parent.n_pages(), 4);
        // page-granular prefix (4 tokens = 2 full pages): pages past
        // the prefix stay private to the parent
        let mut child = a.fork_prefix(&parent, 4);
        assert_eq!((child.len(), child.n_pages()), (4, 2));
        assert_eq!(a.pages_in_use(), 4, "prefix fork allocates nothing");
        assert_eq!(a.refcnt[parent.pages[0] as usize], 2);
        assert_eq!(a.refcnt[parent.pages[1] as usize], 2);
        assert_eq!(a.refcnt[parent.pages[2] as usize], 1, "unshared page must stay private");
        assert_eq!(a.gather_k(&child), a.gather_k(&parent)[..4 * 2]);
        // the child's next push is slot 0 on a fresh page — the
        // parent's rows 4.. are invisible to and untouched by it
        a.push(&mut child, &[50.0; 2], &[50.0; 2]).unwrap();
        assert_eq!(a.gather_k(&parent)[4 * 2..5 * 2], [4.0; 2]);
        assert_eq!(a.gather_k(&child)[4 * 2..], [50.0; 2]);
        // a mid-page prefix (3 tokens) shares the half-full page and
        // the child's first push CoW-copies exactly the filled row
        let mut mid = a.fork_prefix(&parent, 3);
        assert_eq!((mid.len(), mid.n_pages()), (3, 2));
        let in_use = a.pages_in_use();
        a.push(&mut mid, &[60.0; 2], &[60.0; 2]).unwrap();
        assert_eq!(a.pages_in_use(), in_use + 1, "CoW split pays exactly one page");
        assert_eq!(a.gather_k(&mid), vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 60.0, 60.0]);
        assert_eq!(a.gather_k(&parent)[..7 * 2], {
            let mut want = Vec::new();
            for i in 0..7 {
                want.extend_from_slice(&[i as f32; 2]);
            }
            want
        });
        a.release(&mut parent);
        a.release(&mut child);
        a.release(&mut mid);
        assert_eq!(a.pages_in_use(), 0);
    }

    #[test]
    fn run_offsets_match_runs() {
        let mut a = KvArena::new(2, 3, 0).unwrap();
        let mut t = PageTable::new();
        for i in 0..8 {
            a.push(&mut t, &[i as f32; 2], &[-(i as f32); 2]).unwrap();
        }
        let (kd, vd) = a.raw_kv();
        let offs: Vec<_> = a.run_offsets(&t).collect();
        let runs: Vec<_> = a.runs(&t).collect();
        assert_eq!(offs.len(), runs.len());
        let mut t0_want = 0;
        for ((off, t0, rows), (kseg, vseg, rrows)) in offs.iter().zip(&runs) {
            assert_eq!(rows, rrows);
            assert_eq!(*t0, t0_want);
            assert_eq!(&kd[*off..off + rows * 2], &kseg[..rows * 2]);
            assert_eq!(&vd[*off..off + rows * 2], &vseg[..rows * 2]);
            t0_want += rows;
        }
        assert_eq!(t0_want, 8);
    }

    #[test]
    fn reset_all_reclaims_everything() {
        let mut a = KvArena::new(2, 1, 0).unwrap();
        let mut t = PageTable::new();
        for _ in 0..7 {
            a.push(&mut t, &[1.0; 2], &[2.0; 2]).unwrap();
        }
        assert_eq!(a.peak_pages(), 7);
        a.reset_all();
        assert_eq!(a.pages_in_use(), 0);
        assert_eq!(a.peak_pages(), 0);
        assert_eq!(a.allocated_pages(), 7, "blob is kept for reuse");
        let mut t2 = PageTable::new();
        a.push(&mut t2, &[0.0; 2], &[0.0; 2]).unwrap();
        assert_eq!(a.allocated_pages(), 7);
    }

    #[test]
    fn degenerate_configs_rejected() {
        assert!(KvArena::new(0, 4, 0).is_err());
        assert!(KvArena::new(4, 0, 0).is_err());
    }
}
