//! KV-cache incremental decode for trained transformer blocks.
//!
//! Training evaluates a block by recomputing full causal attention over
//! the whole sequence per panel — fine for loss curves, quadratic
//! nonsense for serving: generating token `t+1` would recompute
//! projections and attention for all `t` earlier positions.  This
//! module is the standard fix: each request keeps a [`DecodeState`]
//! holding a page table over the K/V rows of every position it has
//! already processed (storage lives in the shared [`KvArena`] —
//! DESIGN.md §14), and [`ServeBlock::decode_step`] runs **one new
//! token per request** against that cache — projections and MLP over a
//! `[requests, d]` panel, attention through one K-cache-major batched
//! kernel ([`batched_attn`], DESIGN.md §15) that pools over
//! (request, page-run) pairs so every page-contiguous K/V run feeds a
//! real [`gemm::mm_rows`] panel instead of a scalar dot loop.
//!
//! Prompt admission has a batched counterpart: [`ServeBlock::prefill`]
//! pushes a whole `[rows, d]` prompt chunk through forward-shaped
//! panel GEMMs (the throughput win — one `L×d·d` multiply instead of
//! `L` one-row multiplies) and then runs the same batched attention
//! kernel with one span per position (runs clipped causally to
//! `0..=t`), so a chunked prefill is **bitwise** the row-at-a-time
//! decode of the same rows.
//!
//! All per-step allocations live in a caller-owned [`DecodeScratch`]
//! (the scheduler owns one for its whole run): `ctx`/`x1`/`scores`/
//! `prow` and the ~9 projection panels the PR 5 step allocated per
//! iteration are now grow-only buffers, bitwise inert by construction
//! (same kernels, pre-zeroed the same way).
//!
//! ## Merged vs streaming
//!
//! QuanTA's headline serving property is *zero inference overhead*
//! (paper §1): after `AdapterSet::merge_all()` the adapted projections
//! are plain dense matrices.  [`ServeBlock`] has both personalities:
//!
//! * [`ServeBlock::merged`] snapshots the merged weights — the decode
//!   hot loop is pure borrowing GEMM (`compute::gemm`) with **no
//!   circuit evaluation anywhere**;
//! * [`ServeBlock::streaming`] keeps the live adapters
//!   (`W x + α(circuit(x) − x)` through the plan-cached engine) — the
//!   reference the merged path is pinned against at `1e-5`
//!   (`rust/tests/serve_props.rs`), including the α-residual fold.
//!
//! ## Parity contract
//!
//! LN and the MLP reuse the block's own per-row bodies; attention runs
//! the batched kernel, whose float program is *derived* from
//! `model::block::attn_row_segs` rather than shared with it — the
//! zero-embedded block-diagonal Q panel makes the scores GEMM add only
//! bitwise-inert `±0.0` terms to the serial head dot, the strided
//! softmax replays the serial scale/max/exp/divide op order per
//! (query, head) column, and the per-query V GEMM accumulates page
//! runs in the serial ascending-`t2` order (see [`batched_attn`]) —
//! so a streaming decode step is **bitwise** equal to the
//! corresponding row of `TransformerBlock::forward` over the same
//! prefix, at any `QFT_THREADS`, any batch composition, and any KV
//! page size (`rust/tests/kv_props.rs`, which also sweeps forked
//! tables).  That bitwise equality (not a tolerance) is what makes the
//! scheduler's outputs independent of arrival order, batch packing,
//! and prefix-cache admission.

use crate::compute::{gemm, pool};
use crate::model::block::{layer_norm_into, mlp_panel_into};
use crate::model::TransformerBlock;
use crate::quanta::QuantaAdapter;
use crate::serve::kv::{KvArena, PageTable};
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Per-request decode state: a page table over the K/V rows of every
/// position processed so far, plus the cache-exhaustion flag.  Row
/// storage lives in the [`KvArena`] the caller routes every operation
/// through; the state itself is a few words, so thousands of sessions
/// cost only their tokens in flight.
#[derive(Clone, Debug, Default)]
pub struct DecodeState {
    pub(crate) d: usize,
    pub(crate) table: PageTable,
    /// Set when a K/V push failed on arena exhaustion: the request
    /// must be quarantined (`ServeError::CacheExhausted`); its panel
    /// rows are skipped (never read) until the scheduler retires it.
    pub(crate) failed: bool,
}

impl DecodeState {
    /// Empty state for width-`d` activations.
    pub fn new(d: usize) -> DecodeState {
        DecodeState { d, table: PageTable::new(), failed: false }
    }

    /// Positions cached so far (the next token decodes at this index).
    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    /// Whether a K/V push failed on arena exhaustion.
    pub fn failed(&self) -> bool {
        self.failed
    }

    /// Pages this request currently maps in the arena.
    pub fn n_pages(&self) -> usize {
        self.table.n_pages()
    }

    /// Forget the cached sequence and return its pages to `arena` —
    /// request slots in the scheduler are recycled through this.
    pub fn reset(&mut self, arena: &mut KvArena) {
        arena.release(&mut self.table);
        self.failed = false;
    }

    /// Copy-on-write fork: the clone shares every page (refcounts
    /// bumped, zero rows copied) and diverges lazily on its first
    /// push into a shared tail page — speculative snapshots and
    /// shared system-prompt prefixes in O(pages).
    pub fn fork(&self, arena: &mut KvArena) -> DecodeState {
        DecodeState { d: self.d, table: arena.fork(&self.table), failed: self.failed }
    }

    /// CoW fork of only the first `tokens` cached positions — the
    /// prefix-cache admission seam (`serve::scheduler`): the child
    /// shares the `⌈tokens/page_tokens⌉` pages covering the prefix
    /// (refcounts bumped, zero rows copied) and prefills its own
    /// continuation from position `tokens`.  A page-aligned `tokens`
    /// never splits; a mid-page boundary pays one CoW page copy on the
    /// child's first push.
    pub fn fork_prefix(&self, arena: &mut KvArena, tokens: usize) -> DecodeState {
        DecodeState { d: self.d, table: arena.fork_prefix(&self.table, tokens), failed: self.failed }
    }
}

/// Grow-only scratch for [`ServeBlock::decode_step`] /
/// [`ServeBlock::prefill`]: every per-iteration allocation of the
/// PR 5 step (LN outputs, Q/K/V/O panels, attention context, MLP
/// panels, the deep chaining panel) plus the batched-attention work
/// lists and score/transpose/accumulator panels, hoisted into one
/// caller-owned struct.  Buffers are cleared and re-zeroed per call —
/// same initial bytes as a fresh `vec![0.0; n]`, so reuse is bitwise
/// inert (`serve_props` pins this).
#[derive(Clone, Debug, Default)]
pub struct DecodeScratch {
    h1: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    attn: Vec<f32>,
    h2: Vec<f32>,
    mlp_u: Vec<f32>,
    mlp_a: Vec<f32>,
    mlp_m: Vec<f32>,
    /// Batched-attention work lists and panels (see [`batched_attn`]):
    /// plain indices and grow-only floats, so the scratch holds no
    /// borrows between steps.
    spans: Vec<AttnSpan>,
    items: Vec<RunItem>,
    span_starts: Vec<usize>,
    chunk_starts: Vec<usize>,
    qmat: Vec<f32>,
    score_panel: Vec<f32>,
    prow_t: Vec<f32>,
    vpanel: Vec<f32>,
    /// Layer-chaining panel for deep stacks (`serve::model`).
    pub(crate) chain: Vec<f32>,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }
}

/// Reset `buf` to `n` zeros, reusing its allocation (grow-only).
fn zeroed(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    buf.clear();
    buf.resize(n, 0.0);
    &mut buf[..]
}

/// One query row of the batched attention kernel: the query at panel
/// row `q_row` attends K/V positions `0..=t` of its own page table,
/// scoring into the `[(t+1) × n_heads]` region of the score panel at
/// element offset `panel_off` and writing its context into `ctx` row
/// `ctx_row`.  `item0..item1` index its page runs in the shared
/// [`RunItem`] list.
#[derive(Clone, Copy, Debug, Default)]
struct AttnSpan {
    q_row: usize,
    ctx_row: usize,
    t: usize,
    panel_off: usize,
    item0: usize,
    item1: usize,
}

/// One (query, page-run) work item: `rows` page-contiguous K/V rows at
/// element offset `kv_off` in the arena, covering logical positions
/// `t0..t0 + rows` of span `span` (already clipped causally to
/// `0..=t`).
#[derive(Clone, Copy, Debug, Default)]
struct RunItem {
    span: usize,
    kv_off: usize,
    t0: usize,
    rows: usize,
}

/// K-cache-major batched paged attention over `spans` (one per query
/// row) and `items` (one per (query, page-run) pair) — the serving
/// replacement for the per-(request, head) `attn_row_segs` walk, built
/// so the page-contiguous K/V layout feeds real [`gemm::mm_rows`]
/// panels while every output bit matches the serial walk (DESIGN.md
/// §15; `rust/tests/kv_props.rs` sweeps page sizes × `QFT_THREADS` ×
/// forked tables against the contiguous forward):
///
/// 1. **Q embed** (serial): each query row is zero-embedded into a
///    block-diagonal `[d × n_heads]` panel — `qmat[p][p/hd] = q[p]`,
///    zeros elsewhere — so one `K_run · qmat` GEMM scores all heads at
///    once.  The extra terms this adds to the serial per-head dot are
///    all `K[r,p] · 0.0`: for the finite K this serving stack
///    guarantees (the scheduler quarantines non-finite outputs before
///    they are fed back) those are `±0.0`, and `x + ±0.0 ≡ x` bitwise
///    for every non-zero partial sum, a leading `+0.0` chain stays
///    `+0.0`, and a zero-*sign* difference on an all-zero dot
///    collapses at `exp(±0.0 − maxv)` — exactly where scores are next
///    consumed — so the GEMM's ascending-`p` accumulation
///    ([`gemm::mm_rows`]'s contract, any `MM_KB` blocking) reproduces
///    the serial head dot bit for bit.
/// 2. **Scores** (pooled over items): each page run is one
///    `mm_rows(K_run [rows × d], qmat [d × n_heads])` into the span's
///    score region.  Items are panel-contiguous (spans ascending, runs
///    ascending within a span), so chunk boundaries are item starts
///    and [`pool::DisjointSpans`] hands each chunk its own region —
///    chunking never splits an item, so the result is
///    `QFT_THREADS`-blind.
/// 3. **Softmax** (serial, in place): per (span, head) strided column
///    of the score panel, replay the serial op order exactly — scale
///    with running max, one `exp`/denominator sweep, one divide sweep.
/// 4. **V accumulation** (pooled over spans): per span, transpose each
///    run's probability rows into a `[n_heads × rows]` panel and
///    `mm_rows` it against the run's `[rows × d]` V slab into a
///    pre-zeroed `[n_heads × d]` accumulator — ascending runs ×
///    ascending rows is precisely the serial ascending-`t2` order —
///    then *assign* (not add) the head-diagonal `[h, h·hd..]` blocks
///    into the span's `ctx` row.
#[allow(clippy::too_many_arguments)]
fn batched_attn(
    k_store: &[f32],
    v_store: &[f32],
    q: &[f32],
    d: usize,
    n_heads: usize,
    head_dim: usize,
    scale: f32,
    spans: &[AttnSpan],
    items: &[RunItem],
    span_starts: &[usize],
    chunk_starts: &mut Vec<usize>,
    qmat: &mut Vec<f32>,
    score_panel: &mut Vec<f32>,
    prow_t: &mut Vec<f32>,
    vpanel: &mut Vec<f32>,
    ctx: &mut [f32],
) {
    let n_spans = spans.len();
    if n_spans == 0 {
        return;
    }
    let last = &spans[n_spans - 1];
    let total_panel = last.panel_off + (last.t + 1) * n_heads;
    let total_rows: usize = items.iter().map(|it| it.rows).sum();

    // 1. zero-embedded block-diagonal Q panels
    let qm = zeroed(qmat, n_spans * d * n_heads);
    for (qi, s) in spans.iter().enumerate() {
        let base = qi * d * n_heads;
        let qrow = &q[s.q_row * d..(s.q_row + 1) * d];
        for (p, &qv) in qrow.iter().enumerate() {
            qm[base + p * n_heads + p / head_dim] = qv;
        }
    }
    let qm: &[f32] = qm;

    // 2. K-cache-major score GEMMs, pooled over (query, page-run)
    // items; chunk sizing is shape-only, so boundaries (and therefore
    // bits) are QFT_THREADS-invariant
    let panel = zeroed(score_panel, total_panel);
    let flops = (total_rows * d * n_heads / items.len().max(1)).max(1);
    let (chunk_items, n_chunks) = pool::chunks(items.len(), flops);
    chunk_starts.clear();
    for c in 0..n_chunks {
        let it = &items[c * chunk_items];
        chunk_starts.push(spans[it.span].panel_off + it.t0 * n_heads);
    }
    let starts: &[usize] = chunk_starts;
    let panel_spans = pool::DisjointSpans::new(panel, starts);
    pool::run(n_chunks, |c| {
        // SAFETY: each chunk index is claimed exactly once by the pool.
        let out = unsafe { panel_spans.slice(c) };
        let base = starts[c];
        let i1 = ((c + 1) * chunk_items).min(items.len());
        for it in &items[c * chunk_items..i1] {
            let o0 = spans[it.span].panel_off + it.t0 * n_heads - base;
            gemm::mm_rows(
                &k_store[it.kv_off..it.kv_off + it.rows * d],
                &qm[it.span * d * n_heads..(it.span + 1) * d * n_heads],
                &mut out[o0..o0 + it.rows * n_heads],
                d,
                n_heads,
            );
        }
    });

    // 3. serial strided softmax per (span, head) column — the serial
    // walk's scale/max, exp/denom, divide sequences verbatim
    let panel = &mut score_panel[..];
    for s in spans {
        let seg = &mut panel[s.panel_off..s.panel_off + (s.t + 1) * n_heads];
        for h in 0..n_heads {
            let mut maxv = f32::NEG_INFINITY;
            for t2 in 0..=s.t {
                let slot = &mut seg[t2 * n_heads + h];
                *slot *= scale;
                maxv = maxv.max(*slot);
            }
            let mut denom = 0.0f32;
            for t2 in 0..=s.t {
                let slot = &mut seg[t2 * n_heads + h];
                *slot = (*slot - maxv).exp();
                denom += *slot;
            }
            for t2 in 0..=s.t {
                seg[t2 * n_heads + h] /= denom;
            }
        }
    }
    let panel: &[f32] = panel;

    // 4. per-query V accumulation, pooled over spans; each span owns
    // its transpose scratch (same offsets as its score region), its
    // [n_heads × d] accumulator, and its unique ctx row
    let pt = zeroed(prow_t, total_panel);
    let vp = zeroed(vpanel, n_spans * n_heads * d);
    let vflops = (total_rows / n_spans).max(1) * n_heads * d;
    let (chunk_spans, vn_chunks) = pool::chunks(n_spans, vflops);
    let pt_spans = pool::DisjointSpans::new(pt, span_starts);
    let vp_chunks = pool::DisjointChunks::new(vp, n_heads * d);
    let ctx_rows = pool::DisjointChunks::new(ctx, d);
    pool::run(vn_chunks, |c| {
        let s1 = ((c + 1) * chunk_spans).min(n_spans);
        for qi in c * chunk_spans..s1 {
            let s = &spans[qi];
            // SAFETY: spans partition across chunks, so span index `qi`
            // — and its unique ctx row — is claimed exactly once.
            let pa_buf = unsafe { pt_spans.slice(qi) };
            let vrow_panel = unsafe { vp_chunks.slice(qi) };
            for it in &items[s.item0..s.item1] {
                let seg = &panel[s.panel_off + it.t0 * n_heads..];
                let pa = &mut pa_buf[..n_heads * it.rows];
                for h in 0..n_heads {
                    for (r, slot) in pa[h * it.rows..(h + 1) * it.rows].iter_mut().enumerate() {
                        *slot = seg[r * n_heads + h];
                    }
                }
                gemm::mm_rows(
                    pa,
                    &v_store[it.kv_off..it.kv_off + it.rows * d],
                    vrow_panel,
                    it.rows,
                    d,
                );
            }
            let crow = unsafe { ctx_rows.slice(s.ctx_row) };
            for h in 0..n_heads {
                let v0 = h * d + h * head_dim;
                crow[h * head_dim..(h + 1) * head_dim]
                    .copy_from_slice(&vrow_panel[v0..v0 + head_dim]);
            }
        }
    });
}

/// A projection in serving form: merged dense weight or live adapter.
#[derive(Clone, Debug)]
enum Projection {
    /// `Wᵀ` of the merged weight (`W + α(full − I)` folded in), stored
    /// transposed for the row-major `X · Wᵀ` GEMM.
    Merged(Tensor),
    /// The live adapter — frozen base + circuit delta through the
    /// plan-cached engine.
    Streaming(QuantaAdapter),
}

impl Projection {
    /// Apply into caller scratch (`y` reset to `rows × d` zeros here):
    /// same kernels as the allocating PR 5 path, same bits.
    fn apply_into(&self, xs: &[f32], rows: usize, d: usize, y: &mut Vec<f32>) -> Result<()> {
        let y = zeroed(y, rows * d);
        match self {
            Projection::Merged(wt) => {
                gemm::gemm_into(xs, &wt.data, y, d, d);
                Ok(())
            }
            Projection::Streaming(a) => a.apply_batch_into(xs, rows, y),
        }
    }
}

/// Immutable serving snapshot of a [`TransformerBlock`]: the frozen
/// MLP/layernorm weights plus the four projections in either merged or
/// streaming form.  Built once per deployment, shared by every request
/// (decode state lives per request, not here).
#[derive(Clone, Debug)]
pub struct ServeBlock {
    pub(crate) d: usize,
    n_heads: usize,
    head_dim: usize,
    d_ff: usize,
    wq: Projection,
    wk: Projection,
    wv: Projection,
    wo: Projection,
    w1_t: Tensor,
    b1: Vec<f32>,
    w2_t: Tensor,
    b2: Vec<f32>,
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
}

impl ServeBlock {
    /// Snapshot the frozen (non-projection) weights of `block` around
    /// the four given projections — the single construction path both
    /// deployments share.
    fn with_projections(
        block: &TransformerBlock,
        wq: Projection,
        wk: Projection,
        wv: Projection,
        wo: Projection,
    ) -> ServeBlock {
        ServeBlock {
            d: block.d,
            n_heads: block.n_heads,
            head_dim: block.head_dim,
            d_ff: block.d_ff,
            wq,
            wk,
            wv,
            wo,
            w1_t: block.w1_t.clone(),
            b1: block.b1.clone(),
            w2_t: block.w2_t.clone(),
            b2: block.b2.clone(),
            ln1_g: block.ln1_g.clone(),
            ln1_b: block.ln1_b.clone(),
            ln2_g: block.ln2_g.clone(),
            ln2_b: block.ln2_b.clone(),
        }
    }

    /// Zero-overhead deployment: every projection folded to a dense
    /// matrix via `AdapterSet::merge_all()` — the decode hot loop is
    /// pure GEMM, no circuit evaluation.
    pub fn merged(block: &TransformerBlock) -> Result<ServeBlock> {
        let mut proj = block
            .adapters
            .merge_all()?
            .into_iter()
            .map(|(_, w)| Ok(Projection::Merged(w.t()?)))
            .collect::<Result<Vec<_>>>()?;
        let wo = proj.pop().unwrap();
        let wv = proj.pop().unwrap();
        let wk = proj.pop().unwrap();
        let wq = proj.pop().unwrap();
        Ok(ServeBlock::with_projections(block, wq, wk, wv, wo))
    }

    /// Streaming deployment: the live adapters, un-merged — the parity
    /// reference for the merged path (and the apples-to-apples baseline
    /// the `serve_decode` bench prices the merge against).
    pub fn streaming(block: &TransformerBlock) -> ServeBlock {
        let a = |i: usize| Projection::Streaming(block.adapters.adapter(i).clone());
        ServeBlock::with_projections(block, a(0), a(1), a(2), a(3))
    }

    /// Activation width `d` of this block.
    pub fn d(&self) -> usize {
        self.d
    }

    /// True when every projection runs merged dense weights.
    pub fn is_merged(&self) -> bool {
        [&self.wq, &self.wk, &self.wv, &self.wo]
            .iter()
            .all(|p| matches!(p, Projection::Merged(_)))
    }

    /// Decode one new token for each of `states.len()` concurrent
    /// requests: `xs` is the row-major `[requests, d]` panel of new
    /// inputs (`xs[i]` is request `i`'s token at position
    /// `states[i].len()`), the per-request caches grow by one position
    /// in `arena`, and `out` is reset to the `[requests, d]` panel of
    /// block outputs at each request's new position.
    ///
    /// Projections and the MLP run as pooled panel GEMMs over all
    /// requests at once (`compute::gemm` / the circuit engine, both
    /// `QFT_THREADS`-invariant and per-row batch-invariant); the
    /// ragged per-request attention runs as one K-cache-major
    /// [`batched_attn`] kernel pooled over every (request, page-run)
    /// pair — bitwise the element order the full forward's serial walk
    /// uses for its final position (see the kernel's derivation
    /// notes).
    ///
    /// A state whose K/V push hits arena exhaustion is flagged
    /// ([`DecodeState::failed`]) and its attention skipped (its output
    /// row is unspecified and must not be consumed); every other row
    /// is bitwise unaffected, because no kernel under the step reads
    /// across rows.
    ///
    /// This is a fault-isolation boundary: a panic anywhere under the
    /// step (e.g. inside a pool worker's GEMM chunk) is converted to a
    /// structured [`Error::Compute`](crate::util::error::Error) on the
    /// caller via [`pool::catching`] instead of unwinding through the
    /// serving stack, and the pool remains usable for the next step.
    pub fn decode_step(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        states: &mut [&mut DecodeState],
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        pool::catching(|| self.decode_step_inner(arena, scratch, states, xs, out))?;
        // `nan@decode:n` probe: poison the panel's first element — one
        // victim request turns non-finite mid-decode, which is exactly
        // the condition the scheduler's quarantine sweep must catch
        // without disturbing the other rows.
        if crate::util::fault::armed() {
            if let Some(crate::util::fault::Fault::Nan) = crate::util::fault::probe("decode") {
                if let Some(v) = out.first_mut() {
                    *v = f32::NAN;
                }
            }
        }
        Ok(())
    }

    fn decode_step_inner(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        states: &mut [&mut DecodeState],
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let rows = states.len();
        let d = self.d;
        if xs.len() != rows * d {
            return Err(Error::Shape(format!(
                "decode_step: xs len {} != requests {rows} * d {d}",
                xs.len()
            )));
        }
        for (i, s) in states.iter().enumerate() {
            if s.d != d {
                return Err(Error::Shape(format!(
                    "decode_step: state {i} has d {}, block has d {d}",
                    s.d
                )));
            }
        }
        if arena.d() != d {
            return Err(Error::Shape(format!(
                "decode_step: arena has d {}, block has d {d}",
                arena.d()
            )));
        }
        out.clear();
        if rows == 0 {
            return Ok(());
        }
        let h1 = zeroed(&mut scratch.h1, rows * d);
        layer_norm_into(xs, &self.ln1_g, &self.ln1_b, d, h1);
        self.wq.apply_into(h1, rows, d, &mut scratch.q)?;
        self.wk.apply_into(h1, rows, d, &mut scratch.k)?;
        self.wv.apply_into(h1, rows, d, &mut scratch.v)?;
        // attention: serially append this position's K/V (keeping the
        // arena mutation order deterministic), building the batched
        // kernel's work lists — one span per live request, one item
        // per page run of its history — then run the K-cache-major
        // kernel once over the whole batch
        let (hd, scale) = (self.head_dim, 1.0 / (self.head_dim as f32).sqrt());
        let ctx = zeroed(&mut scratch.ctx, rows * d);
        scratch.spans.clear();
        scratch.items.clear();
        scratch.span_starts.clear();
        let mut panel_off = 0usize;
        for (i, state) in states.iter_mut().enumerate() {
            if state.failed {
                continue; // quarantine pending: row i is never consumed
            }
            let (krow, vrow) = (&scratch.k[i * d..(i + 1) * d], &scratch.v[i * d..(i + 1) * d]);
            if arena.push(&mut state.table, krow, vrow).is_err() {
                state.failed = true;
                continue;
            }
            let t = state.table.len() - 1;
            let item0 = scratch.items.len();
            for (kv_off, t0, run_rows) in arena.run_offsets(&state.table) {
                scratch.items.push(RunItem {
                    span: scratch.spans.len(),
                    kv_off,
                    t0,
                    rows: run_rows,
                });
            }
            scratch.span_starts.push(panel_off);
            scratch.spans.push(AttnSpan {
                q_row: i,
                ctx_row: i,
                t,
                panel_off,
                item0,
                item1: scratch.items.len(),
            });
            panel_off += (t + 1) * self.n_heads;
        }
        let (k_store, v_store) = arena.raw_kv();
        batched_attn(
            k_store,
            v_store,
            &scratch.q,
            d,
            self.n_heads,
            hd,
            scale,
            &scratch.spans,
            &scratch.items,
            &scratch.span_starts,
            &mut scratch.chunk_starts,
            &mut scratch.qmat,
            &mut scratch.score_panel,
            &mut scratch.prow_t,
            &mut scratch.vpanel,
            ctx,
        );
        self.wo.apply_into(ctx, rows, d, &mut scratch.attn)?;
        out.extend_from_slice(xs);
        for (o, &a) in out.iter_mut().zip(&scratch.attn) {
            *o += a;
        }
        let h2 = zeroed(&mut scratch.h2, rows * d);
        layer_norm_into(out, &self.ln2_g, &self.ln2_b, d, h2);
        // the block's own MLP body (mlp_panel_into is shared, like
        // attn_row_segs, so decode and forward stay
        // instruction-identical)
        let u = zeroed(&mut scratch.mlp_u, rows * self.d_ff);
        let a = zeroed(&mut scratch.mlp_a, rows * self.d_ff);
        let m = zeroed(&mut scratch.mlp_m, rows * d);
        mlp_panel_into(h2, rows, &self.w1_t, &self.b1, &self.w2_t, &self.b2, d, self.d_ff, u, a, m);
        for (o, &mv) in out.iter_mut().zip(scratch.mlp_m.iter()) {
            *o += mv;
        }
        Ok(())
    }

    /// Chunked prompt prefill for **one** request: process `rows`
    /// consecutive prompt positions in a single forward-shaped pass —
    /// LN and the Q/K/V/O/MLP panels batched over the whole chunk (the
    /// admission-throughput win), all K/V rows pushed, then the
    /// batched attention kernel with one span per position — page runs
    /// clipped causally to `0..=t`, so each position scores the same
    /// elements in the same serial-derived order as its one-row step.
    /// `out` is reset to the `[rows, d]` panel of block outputs; the
    /// chunk's last row is the request's next autoregressive input.
    ///
    /// **Bitwise** equal to feeding the same rows through
    /// [`ServeBlock::decode_step`] one at a time: every kernel under
    /// it is per-row batch-invariant, position `t` is pushed before
    /// any position ≥ `t` attends, and the attention walk is bounded
    /// to rows `0..=t` — same elements, same order
    /// (`rust/tests/serve_props.rs` pins chunk sizes against the
    /// row-at-a-time path).
    ///
    /// On arena exhaustion mid-chunk the state is flagged and the
    /// remaining positions are skipped — the caller quarantines the
    /// request without consuming `out`.
    pub fn prefill(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        state: &mut DecodeState,
        xs: &[f32],
        rows: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        pool::catching(|| self.prefill_inner(arena, scratch, state, xs, rows, out))
    }

    fn prefill_inner(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        state: &mut DecodeState,
        xs: &[f32],
        rows: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let d = self.d;
        if rows == 0 || xs.len() != rows * d {
            return Err(Error::Shape(format!(
                "prefill: xs len {} != rows {rows} * d {d}",
                xs.len()
            )));
        }
        if state.d != d || arena.d() != d {
            return Err(Error::Shape(format!(
                "prefill: state d {} / arena d {} != block d {d}",
                state.d,
                arena.d()
            )));
        }
        out.clear();
        let h1 = zeroed(&mut scratch.h1, rows * d);
        layer_norm_into(xs, &self.ln1_g, &self.ln1_b, d, h1);
        self.wq.apply_into(h1, rows, d, &mut scratch.q)?;
        self.wk.apply_into(h1, rows, d, &mut scratch.k)?;
        self.wv.apply_into(h1, rows, d, &mut scratch.v)?;
        let t0 = state.table.len();
        let (hd, scale) = (self.head_dim, 1.0 / (self.head_dim as f32).sqrt());
        let ctx = zeroed(&mut scratch.ctx, rows * d);
        if !state.failed {
            // push the whole chunk's K/V first: position t0+j only
            // ever attends rows 0..=t0+j, so pushing ahead changes no
            // read — this is what lets Q/K/V batch over the chunk
            for j in 0..rows {
                let (krow, vrow) =
                    (&scratch.k[j * d..(j + 1) * d], &scratch.v[j * d..(j + 1) * d]);
                if arena.push(&mut state.table, krow, vrow).is_err() {
                    state.failed = true;
                    break;
                }
            }
        }
        if !state.failed {
            // one span per chunk position, page runs clipped causally
            // to rows 0..=t — position t0+j scores the same elements
            // in the same order as its one-row decode step (the table
            // may open with a CoW-forked prefix; shared pages walk
            // identically to owned ones)
            scratch.spans.clear();
            scratch.items.clear();
            scratch.span_starts.clear();
            let mut panel_off = 0usize;
            for j in 0..rows {
                let t = t0 + j;
                let item0 = scratch.items.len();
                for (kv_off, r0, run_rows) in arena.run_offsets(&state.table) {
                    if r0 > t {
                        break;
                    }
                    scratch.items.push(RunItem {
                        span: scratch.spans.len(),
                        kv_off,
                        t0: r0,
                        rows: run_rows.min(t + 1 - r0),
                    });
                }
                scratch.span_starts.push(panel_off);
                scratch.spans.push(AttnSpan {
                    q_row: j,
                    ctx_row: j,
                    t,
                    panel_off,
                    item0,
                    item1: scratch.items.len(),
                });
                panel_off += (t + 1) * self.n_heads;
            }
            let (k_store, v_store) = arena.raw_kv();
            batched_attn(
                k_store,
                v_store,
                &scratch.q,
                d,
                self.n_heads,
                hd,
                scale,
                &scratch.spans,
                &scratch.items,
                &scratch.span_starts,
                &mut scratch.chunk_starts,
                &mut scratch.qmat,
                &mut scratch.score_panel,
                &mut scratch.prow_t,
                &mut scratch.vpanel,
                ctx,
            );
        }
        self.wo.apply_into(ctx, rows, d, &mut scratch.attn)?;
        out.extend_from_slice(xs);
        for (o, &a) in out.iter_mut().zip(&scratch.attn) {
            *o += a;
        }
        let h2 = zeroed(&mut scratch.h2, rows * d);
        layer_norm_into(out, &self.ln2_g, &self.ln2_b, d, h2);
        let u = zeroed(&mut scratch.mlp_u, rows * self.d_ff);
        let a = zeroed(&mut scratch.mlp_a, rows * self.d_ff);
        let m = zeroed(&mut scratch.mlp_m, rows * d);
        mlp_panel_into(h2, rows, &self.w1_t, &self.b1, &self.w2_t, &self.b2, d, self.d_ff, u, a, m);
        for (o, &mv) in out.iter_mut().zip(scratch.mlp_m.iter()) {
            *o += mv;
        }
        Ok(())
    }

    /// Decode a whole teacher-forced sequence for one request: feed
    /// `xs[t]` at position `t` and collect every position's output —
    /// the incremental counterpart of
    /// [`TransformerBlock::forward`]`(xs, 1, seq)`, against which
    /// it is pinned per position by `rust/tests/serve_props.rs`.
    /// Builds its own unbounded arena and scratch; the scheduler path
    /// routes through a shared arena instead.
    pub fn decode_sequence(&self, xs: &[f32], seq: usize) -> Result<Vec<f32>> {
        let d = self.d;
        if seq == 0 || xs.len() != seq * d {
            return Err(Error::Shape(format!(
                "decode_sequence: xs len {} != seq {seq} * d {d}",
                xs.len()
            )));
        }
        let mut arena = KvArena::unbounded(d);
        let mut scratch = DecodeScratch::new();
        let mut state = DecodeState::new(d);
        let mut out = Vec::with_capacity(seq * d);
        let mut step = Vec::new();
        for t in 0..seq {
            self.decode_step(
                &mut arena,
                &mut scratch,
                &mut [&mut state],
                &xs[t * d..(t + 1) * d],
                &mut step,
            )?;
            out.extend_from_slice(&step);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_state_pages_and_reset() {
        let mut arena = KvArena::new(4, 2, 0).unwrap();
        let mut s = DecodeState::new(4);
        assert!(s.is_empty());
        for t in 0..9 {
            arena.push(&mut s.table, &[t as f32; 4], &[-(t as f32); 4]).unwrap();
        }
        assert_eq!(s.len(), 9);
        assert_eq!(s.n_pages(), 5);
        assert_eq!(arena.pages_in_use(), 5);
        s.reset(&mut arena);
        assert_eq!(s.len(), 0);
        assert_eq!(arena.pages_in_use(), 0, "reset must return every page");
        arena.push(&mut s.table, &[1.0; 4], &[2.0; 4]).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(arena.gather_k(&s.table), vec![1.0; 4]);
    }

    #[test]
    fn decode_step_shape_errors() {
        use crate::model::BlockConfig;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(90);
        let block =
            TransformerBlock::init(&BlockConfig::standard(vec![2, 2], 2, 3), &mut rng).unwrap();
        let sb = ServeBlock::merged(&block).unwrap();
        let mut arena = KvArena::unbounded(4);
        let mut scratch = DecodeScratch::new();
        let mut out = Vec::new();
        let mut st = DecodeState::new(4);
        assert!(sb
            .decode_step(&mut arena, &mut scratch, &mut [&mut st], &[0.0; 3], &mut out)
            .is_err());
        let mut wrong = DecodeState::new(5);
        assert!(sb
            .decode_step(&mut arena, &mut scratch, &mut [&mut wrong], &[0.0; 5], &mut out)
            .is_err());
        assert!(sb.decode_sequence(&[0.0; 4], 0).is_err());
        assert!(sb.prefill(&mut arena, &mut scratch, &mut st, &[0.0; 4], 0, &mut out).is_err());
        sb.decode_step(&mut arena, &mut scratch, &mut [], &[], &mut out).unwrap();
        assert!(out.is_empty());
    }
}
