//! KV-cache incremental decode for trained transformer blocks.
//!
//! Training evaluates a block by recomputing full causal attention over
//! the whole sequence per panel — fine for loss curves, quadratic
//! nonsense for serving: generating token `t+1` would recompute
//! projections and attention for all `t` earlier positions.  This
//! module is the standard fix: each request keeps a [`DecodeState`]
//! holding a page table over the K/V rows of every position it has
//! already processed (storage lives in the shared [`KvArena`] —
//! DESIGN.md §14), and [`ServeBlock::decode_step`] runs **one new
//! token per request** against that cache — projections and MLP over a
//! `[requests, d]` panel, attention only between the new query row and
//! the cached keys/values, walked page-run by page-run.
//!
//! Prompt admission has a batched counterpart: [`ServeBlock::prefill`]
//! pushes a whole `[rows, d]` prompt chunk through forward-shaped
//! panel GEMMs (the throughput win — one `L×d·d` multiply instead of
//! `L` one-row multiplies) and then runs the same per-position
//! [`attn_row_segs`] loop over the paged history, so a chunked
//! prefill is **bitwise** the row-at-a-time decode of the same rows.
//!
//! All per-step allocations live in a caller-owned [`DecodeScratch`]
//! (the scheduler owns one for its whole run): `ctx`/`x1`/`scores`/
//! `prow` and the ~9 projection panels the PR 5 step allocated per
//! iteration are now grow-only buffers, bitwise inert by construction
//! (same kernels, pre-zeroed the same way).
//!
//! ## Merged vs streaming
//!
//! QuanTA's headline serving property is *zero inference overhead*
//! (paper §1): after `AdapterSet::merge_all()` the adapted projections
//! are plain dense matrices.  [`ServeBlock`] has both personalities:
//!
//! * [`ServeBlock::merged`] snapshots the merged weights — the decode
//!   hot loop is pure borrowing GEMM (`compute::gemm`) with **no
//!   circuit evaluation anywhere**;
//! * [`ServeBlock::streaming`] keeps the live adapters
//!   (`W x + α(circuit(x) − x)` through the plan-cached engine) — the
//!   reference the merged path is pinned against at `1e-5`
//!   (`rust/tests/serve_props.rs`), including the α-residual fold.
//!
//! ## Parity contract
//!
//! The decode step reuses the block's own per-row pieces —
//! `model::block::{layer_norm, attn_row, mlp_panel}` bodies and the
//! same borrowing GEMM / circuit engine kernels, whose per-row results
//! are batch-size-invariant by the engine's chunking contract — so a
//! streaming decode step is **bitwise** equal to the corresponding row
//! of `TransformerBlock::forward` over the same prefix, at any
//! `QFT_THREADS`, any batch composition, and any KV page size
//! (`rust/tests/kv_props.rs`).  That bitwise equality (not a
//! tolerance) is what makes the scheduler's outputs independent of
//! arrival order and batch packing.

use crate::compute::{gemm, pool};
use crate::model::block::{attn_row_segs, layer_norm_into, mlp_panel_into};
use crate::model::TransformerBlock;
use crate::quanta::QuantaAdapter;
use crate::serve::kv::{KvArena, PageTable};
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Per-request decode state: a page table over the K/V rows of every
/// position processed so far, plus the cache-exhaustion flag.  Row
/// storage lives in the [`KvArena`] the caller routes every operation
/// through; the state itself is a few words, so thousands of sessions
/// cost only their tokens in flight.
#[derive(Clone, Debug, Default)]
pub struct DecodeState {
    pub(crate) d: usize,
    pub(crate) table: PageTable,
    /// Set when a K/V push failed on arena exhaustion: the request
    /// must be quarantined (`ServeError::CacheExhausted`); its panel
    /// rows are skipped (never read) until the scheduler retires it.
    pub(crate) failed: bool,
}

impl DecodeState {
    /// Empty state for width-`d` activations.
    pub fn new(d: usize) -> DecodeState {
        DecodeState { d, table: PageTable::new(), failed: false }
    }

    /// Positions cached so far (the next token decodes at this index).
    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    /// Whether a K/V push failed on arena exhaustion.
    pub fn failed(&self) -> bool {
        self.failed
    }

    /// Pages this request currently maps in the arena.
    pub fn n_pages(&self) -> usize {
        self.table.n_pages()
    }

    /// Forget the cached sequence and return its pages to `arena` —
    /// request slots in the scheduler are recycled through this.
    pub fn reset(&mut self, arena: &mut KvArena) {
        arena.release(&mut self.table);
        self.failed = false;
    }

    /// Copy-on-write fork: the clone shares every page (refcounts
    /// bumped, zero rows copied) and diverges lazily on its first
    /// push into a shared tail page — speculative snapshots and
    /// shared system-prompt prefixes in O(pages).
    pub fn fork(&self, arena: &mut KvArena) -> DecodeState {
        DecodeState { d: self.d, table: arena.fork(&self.table), failed: self.failed }
    }
}

/// Grow-only scratch for [`ServeBlock::decode_step`] /
/// [`ServeBlock::prefill`]: every per-iteration allocation of the
/// PR 5 step (LN outputs, Q/K/V/O panels, attention context and
/// score/probability rows, MLP panels, the deep chaining panel) hoisted
/// into one caller-owned struct.  Buffers are cleared and re-zeroed
/// per call — same initial bytes as a fresh `vec![0.0; n]`, so reuse
/// is bitwise inert (`serve_props` pins this).
#[derive(Clone, Debug, Default)]
pub struct DecodeScratch {
    h1: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    attn: Vec<f32>,
    h2: Vec<f32>,
    mlp_u: Vec<f32>,
    mlp_a: Vec<f32>,
    mlp_m: Vec<f32>,
    scores: Vec<f32>,
    prow: Vec<f32>,
    /// Layer-chaining panel for deep stacks (`serve::model`).
    pub(crate) chain: Vec<f32>,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }
}

/// Reset `buf` to `n` zeros, reusing its allocation (grow-only).
fn zeroed(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    buf.clear();
    buf.resize(n, 0.0);
    &mut buf[..]
}

/// A projection in serving form: merged dense weight or live adapter.
#[derive(Clone, Debug)]
enum Projection {
    /// `Wᵀ` of the merged weight (`W + α(full − I)` folded in), stored
    /// transposed for the row-major `X · Wᵀ` GEMM.
    Merged(Tensor),
    /// The live adapter — frozen base + circuit delta through the
    /// plan-cached engine.
    Streaming(QuantaAdapter),
}

impl Projection {
    /// Apply into caller scratch (`y` reset to `rows × d` zeros here):
    /// same kernels as the allocating PR 5 path, same bits.
    fn apply_into(&self, xs: &[f32], rows: usize, d: usize, y: &mut Vec<f32>) -> Result<()> {
        let y = zeroed(y, rows * d);
        match self {
            Projection::Merged(wt) => {
                gemm::gemm_into(xs, &wt.data, y, d, d);
                Ok(())
            }
            Projection::Streaming(a) => a.apply_batch_into(xs, rows, y),
        }
    }
}

/// Immutable serving snapshot of a [`TransformerBlock`]: the frozen
/// MLP/layernorm weights plus the four projections in either merged or
/// streaming form.  Built once per deployment, shared by every request
/// (decode state lives per request, not here).
#[derive(Clone, Debug)]
pub struct ServeBlock {
    pub(crate) d: usize,
    n_heads: usize,
    head_dim: usize,
    d_ff: usize,
    wq: Projection,
    wk: Projection,
    wv: Projection,
    wo: Projection,
    w1_t: Tensor,
    b1: Vec<f32>,
    w2_t: Tensor,
    b2: Vec<f32>,
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
}

impl ServeBlock {
    /// Snapshot the frozen (non-projection) weights of `block` around
    /// the four given projections — the single construction path both
    /// deployments share.
    fn with_projections(
        block: &TransformerBlock,
        wq: Projection,
        wk: Projection,
        wv: Projection,
        wo: Projection,
    ) -> ServeBlock {
        ServeBlock {
            d: block.d,
            n_heads: block.n_heads,
            head_dim: block.head_dim,
            d_ff: block.d_ff,
            wq,
            wk,
            wv,
            wo,
            w1_t: block.w1_t.clone(),
            b1: block.b1.clone(),
            w2_t: block.w2_t.clone(),
            b2: block.b2.clone(),
            ln1_g: block.ln1_g.clone(),
            ln1_b: block.ln1_b.clone(),
            ln2_g: block.ln2_g.clone(),
            ln2_b: block.ln2_b.clone(),
        }
    }

    /// Zero-overhead deployment: every projection folded to a dense
    /// matrix via `AdapterSet::merge_all()` — the decode hot loop is
    /// pure GEMM, no circuit evaluation.
    pub fn merged(block: &TransformerBlock) -> Result<ServeBlock> {
        let mut proj = block
            .adapters
            .merge_all()?
            .into_iter()
            .map(|(_, w)| Ok(Projection::Merged(w.t()?)))
            .collect::<Result<Vec<_>>>()?;
        let wo = proj.pop().unwrap();
        let wv = proj.pop().unwrap();
        let wk = proj.pop().unwrap();
        let wq = proj.pop().unwrap();
        Ok(ServeBlock::with_projections(block, wq, wk, wv, wo))
    }

    /// Streaming deployment: the live adapters, un-merged — the parity
    /// reference for the merged path (and the apples-to-apples baseline
    /// the `serve_decode` bench prices the merge against).
    pub fn streaming(block: &TransformerBlock) -> ServeBlock {
        let a = |i: usize| Projection::Streaming(block.adapters.adapter(i).clone());
        ServeBlock::with_projections(block, a(0), a(1), a(2), a(3))
    }

    /// Activation width `d` of this block.
    pub fn d(&self) -> usize {
        self.d
    }

    /// True when every projection runs merged dense weights.
    pub fn is_merged(&self) -> bool {
        [&self.wq, &self.wk, &self.wv, &self.wo]
            .iter()
            .all(|p| matches!(p, Projection::Merged(_)))
    }

    /// Decode one new token for each of `states.len()` concurrent
    /// requests: `xs` is the row-major `[requests, d]` panel of new
    /// inputs (`xs[i]` is request `i`'s token at position
    /// `states[i].len()`), the per-request caches grow by one position
    /// in `arena`, and `out` is reset to the `[requests, d]` panel of
    /// block outputs at each request's new position.
    ///
    /// Projections and the MLP run as pooled panel GEMMs over all
    /// requests at once (`compute::gemm` / the circuit engine, both
    /// `QFT_THREADS`-invariant and per-row batch-invariant); attention
    /// is the per-request ragged part — one [`attn_row_segs`] walk per
    /// head over that request's page runs, exactly the element order
    /// the full forward uses for its final position.
    ///
    /// A state whose K/V push hits arena exhaustion is flagged
    /// ([`DecodeState::failed`]) and its attention skipped (its output
    /// row is unspecified and must not be consumed); every other row
    /// is bitwise unaffected, because no kernel under the step reads
    /// across rows.
    ///
    /// This is a fault-isolation boundary: a panic anywhere under the
    /// step (e.g. inside a pool worker's GEMM chunk) is converted to a
    /// structured [`Error::Compute`](crate::util::error::Error) on the
    /// caller via [`pool::catching`] instead of unwinding through the
    /// serving stack, and the pool remains usable for the next step.
    pub fn decode_step(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        states: &mut [&mut DecodeState],
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        pool::catching(|| self.decode_step_inner(arena, scratch, states, xs, out))?;
        // `nan@decode:n` probe: poison the panel's first element — one
        // victim request turns non-finite mid-decode, which is exactly
        // the condition the scheduler's quarantine sweep must catch
        // without disturbing the other rows.
        if crate::util::fault::armed() {
            if let Some(crate::util::fault::Fault::Nan) = crate::util::fault::probe("decode") {
                if let Some(v) = out.first_mut() {
                    *v = f32::NAN;
                }
            }
        }
        Ok(())
    }

    fn decode_step_inner(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        states: &mut [&mut DecodeState],
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let rows = states.len();
        let d = self.d;
        if xs.len() != rows * d {
            return Err(Error::Shape(format!(
                "decode_step: xs len {} != requests {rows} * d {d}",
                xs.len()
            )));
        }
        for (i, s) in states.iter().enumerate() {
            if s.d != d {
                return Err(Error::Shape(format!(
                    "decode_step: state {i} has d {}, block has d {d}",
                    s.d
                )));
            }
        }
        if arena.d() != d {
            return Err(Error::Shape(format!(
                "decode_step: arena has d {}, block has d {d}",
                arena.d()
            )));
        }
        out.clear();
        if rows == 0 {
            return Ok(());
        }
        let h1 = zeroed(&mut scratch.h1, rows * d);
        layer_norm_into(xs, &self.ln1_g, &self.ln1_b, d, h1);
        self.wq.apply_into(h1, rows, d, &mut scratch.q)?;
        self.wk.apply_into(h1, rows, d, &mut scratch.k)?;
        self.wv.apply_into(h1, rows, d, &mut scratch.v)?;
        // attention: append this position's K/V, then one attn walk per
        // head over the request's own page runs (ragged lengths — each
        // request attends over its own history only)
        let (hd, scale) = (self.head_dim, 1.0 / (self.head_dim as f32).sqrt());
        let ctx = zeroed(&mut scratch.ctx, rows * d);
        for (i, state) in states.iter_mut().enumerate() {
            if state.failed {
                continue; // quarantine pending: row i is never consumed
            }
            let (krow, vrow) = (&scratch.k[i * d..(i + 1) * d], &scratch.v[i * d..(i + 1) * d]);
            if arena.push(&mut state.table, krow, vrow).is_err() {
                state.failed = true;
                continue;
            }
            let t = state.table.len() - 1;
            if scratch.scores.len() < t + 1 {
                scratch.scores.resize(t + 1, 0.0);
                scratch.prow.resize(t + 1, 0.0);
            }
            for h in 0..self.n_heads {
                let off = h * hd;
                let qrow = &scratch.q[i * d + off..i * d + off + hd];
                attn_row_segs(
                    qrow,
                    arena.runs(&state.table),
                    d,
                    off,
                    t,
                    scale,
                    &mut scratch.scores,
                    &mut scratch.prow[..t + 1],
                    &mut ctx[i * d + off..i * d + off + hd],
                );
            }
        }
        self.wo.apply_into(ctx, rows, d, &mut scratch.attn)?;
        out.extend_from_slice(xs);
        for (o, &a) in out.iter_mut().zip(&scratch.attn) {
            *o += a;
        }
        let h2 = zeroed(&mut scratch.h2, rows * d);
        layer_norm_into(out, &self.ln2_g, &self.ln2_b, d, h2);
        // the block's own MLP body (mlp_panel_into is shared, like
        // attn_row_segs, so decode and forward stay
        // instruction-identical)
        let u = zeroed(&mut scratch.mlp_u, rows * self.d_ff);
        let a = zeroed(&mut scratch.mlp_a, rows * self.d_ff);
        let m = zeroed(&mut scratch.mlp_m, rows * d);
        mlp_panel_into(h2, rows, &self.w1_t, &self.b1, &self.w2_t, &self.b2, d, self.d_ff, u, a, m);
        for (o, &mv) in out.iter_mut().zip(scratch.mlp_m.iter()) {
            *o += mv;
        }
        Ok(())
    }

    /// Chunked prompt prefill for **one** request: process `rows`
    /// consecutive prompt positions in a single forward-shaped pass —
    /// LN and the Q/K/V/O/MLP panels batched over the whole chunk (the
    /// admission-throughput win), all K/V rows pushed, then the same
    /// per-position causal attention walk the one-row step runs.
    /// `out` is reset to the `[rows, d]` panel of block outputs; the
    /// chunk's last row is the request's next autoregressive input.
    ///
    /// **Bitwise** equal to feeding the same rows through
    /// [`ServeBlock::decode_step`] one at a time: every kernel under
    /// it is per-row batch-invariant, position `t` is pushed before
    /// any position ≥ `t` attends, and the attention walk is bounded
    /// to rows `0..=t` — same elements, same order
    /// (`rust/tests/serve_props.rs` pins chunk sizes against the
    /// row-at-a-time path).
    ///
    /// On arena exhaustion mid-chunk the state is flagged and the
    /// remaining positions are skipped — the caller quarantines the
    /// request without consuming `out`.
    pub fn prefill(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        state: &mut DecodeState,
        xs: &[f32],
        rows: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        pool::catching(|| self.prefill_inner(arena, scratch, state, xs, rows, out))
    }

    fn prefill_inner(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        state: &mut DecodeState,
        xs: &[f32],
        rows: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let d = self.d;
        if rows == 0 || xs.len() != rows * d {
            return Err(Error::Shape(format!(
                "prefill: xs len {} != rows {rows} * d {d}",
                xs.len()
            )));
        }
        if state.d != d || arena.d() != d {
            return Err(Error::Shape(format!(
                "prefill: state d {} / arena d {} != block d {d}",
                state.d,
                arena.d()
            )));
        }
        out.clear();
        let h1 = zeroed(&mut scratch.h1, rows * d);
        layer_norm_into(xs, &self.ln1_g, &self.ln1_b, d, h1);
        self.wq.apply_into(h1, rows, d, &mut scratch.q)?;
        self.wk.apply_into(h1, rows, d, &mut scratch.k)?;
        self.wv.apply_into(h1, rows, d, &mut scratch.v)?;
        let t0 = state.table.len();
        let (hd, scale) = (self.head_dim, 1.0 / (self.head_dim as f32).sqrt());
        let ctx = zeroed(&mut scratch.ctx, rows * d);
        if !state.failed {
            // push the whole chunk's K/V first: position t0+j only
            // ever attends rows 0..=t0+j, so pushing ahead changes no
            // read — this is what lets Q/K/V batch over the chunk
            for j in 0..rows {
                let (krow, vrow) =
                    (&scratch.k[j * d..(j + 1) * d], &scratch.v[j * d..(j + 1) * d]);
                if arena.push(&mut state.table, krow, vrow).is_err() {
                    state.failed = true;
                    break;
                }
            }
        }
        if !state.failed {
            let tmax = t0 + rows - 1;
            if scratch.scores.len() < tmax + 1 {
                scratch.scores.resize(tmax + 1, 0.0);
                scratch.prow.resize(tmax + 1, 0.0);
            }
            for j in 0..rows {
                let t = t0 + j;
                for h in 0..self.n_heads {
                    let off = h * hd;
                    let qrow = &scratch.q[j * d + off..j * d + off + hd];
                    attn_row_segs(
                        qrow,
                        arena.runs(&state.table),
                        d,
                        off,
                        t,
                        scale,
                        &mut scratch.scores,
                        &mut scratch.prow[..t + 1],
                        &mut ctx[j * d + off..j * d + off + hd],
                    );
                }
            }
        }
        self.wo.apply_into(ctx, rows, d, &mut scratch.attn)?;
        out.extend_from_slice(xs);
        for (o, &a) in out.iter_mut().zip(&scratch.attn) {
            *o += a;
        }
        let h2 = zeroed(&mut scratch.h2, rows * d);
        layer_norm_into(out, &self.ln2_g, &self.ln2_b, d, h2);
        let u = zeroed(&mut scratch.mlp_u, rows * self.d_ff);
        let a = zeroed(&mut scratch.mlp_a, rows * self.d_ff);
        let m = zeroed(&mut scratch.mlp_m, rows * d);
        mlp_panel_into(h2, rows, &self.w1_t, &self.b1, &self.w2_t, &self.b2, d, self.d_ff, u, a, m);
        for (o, &mv) in out.iter_mut().zip(scratch.mlp_m.iter()) {
            *o += mv;
        }
        Ok(())
    }

    /// Decode a whole teacher-forced sequence for one request: feed
    /// `xs[t]` at position `t` and collect every position's output —
    /// the incremental counterpart of
    /// [`TransformerBlock::forward`]`(xs, 1, seq)`, against which
    /// it is pinned per position by `rust/tests/serve_props.rs`.
    /// Builds its own unbounded arena and scratch; the scheduler path
    /// routes through a shared arena instead.
    pub fn decode_sequence(&self, xs: &[f32], seq: usize) -> Result<Vec<f32>> {
        let d = self.d;
        if seq == 0 || xs.len() != seq * d {
            return Err(Error::Shape(format!(
                "decode_sequence: xs len {} != seq {seq} * d {d}",
                xs.len()
            )));
        }
        let mut arena = KvArena::unbounded(d);
        let mut scratch = DecodeScratch::new();
        let mut state = DecodeState::new(d);
        let mut out = Vec::with_capacity(seq * d);
        let mut step = Vec::new();
        for t in 0..seq {
            self.decode_step(
                &mut arena,
                &mut scratch,
                &mut [&mut state],
                &xs[t * d..(t + 1) * d],
                &mut step,
            )?;
            out.extend_from_slice(&step);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_state_pages_and_reset() {
        let mut arena = KvArena::new(4, 2, 0).unwrap();
        let mut s = DecodeState::new(4);
        assert!(s.is_empty());
        for t in 0..9 {
            arena.push(&mut s.table, &[t as f32; 4], &[-(t as f32); 4]).unwrap();
        }
        assert_eq!(s.len(), 9);
        assert_eq!(s.n_pages(), 5);
        assert_eq!(arena.pages_in_use(), 5);
        s.reset(&mut arena);
        assert_eq!(s.len(), 0);
        assert_eq!(arena.pages_in_use(), 0, "reset must return every page");
        arena.push(&mut s.table, &[1.0; 4], &[2.0; 4]).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(arena.gather_k(&s.table), vec![1.0; 4]);
    }

    #[test]
    fn decode_step_shape_errors() {
        use crate::model::BlockConfig;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(90);
        let block =
            TransformerBlock::init(&BlockConfig::standard(vec![2, 2], 2, 3), &mut rng).unwrap();
        let sb = ServeBlock::merged(&block).unwrap();
        let mut arena = KvArena::unbounded(4);
        let mut scratch = DecodeScratch::new();
        let mut out = Vec::new();
        let mut st = DecodeState::new(4);
        assert!(sb
            .decode_step(&mut arena, &mut scratch, &mut [&mut st], &[0.0; 3], &mut out)
            .is_err());
        let mut wrong = DecodeState::new(5);
        assert!(sb
            .decode_step(&mut arena, &mut scratch, &mut [&mut wrong], &[0.0; 5], &mut out)
            .is_err());
        assert!(sb.decode_sequence(&[0.0; 4], 0).is_err());
        assert!(sb.prefill(&mut arena, &mut scratch, &mut st, &[0.0; 4], 0, &mut out).is_err());
        sb.decode_step(&mut arena, &mut scratch, &mut [], &[], &mut out).unwrap();
        assert!(out.is_empty());
    }
}
