//! KV-cache incremental decode for trained transformer blocks.
//!
//! Training evaluates a block by recomputing full causal attention over
//! the whole sequence per panel — fine for loss curves, quadratic
//! nonsense for serving: generating token `t+1` would recompute
//! projections and attention for all `t` earlier positions.  This
//! module is the standard fix: each request keeps a grow-only
//! [`DecodeState`] holding the K/V rows of every position it has
//! already processed, and [`ServeBlock::decode_step`] runs **one new
//! token per request** against that cache — projections and MLP over a
//! `[requests, d]` panel, attention only between the new query row and
//! the cached keys/values.
//!
//! ## Merged vs streaming
//!
//! QuanTA's headline serving property is *zero inference overhead*
//! (paper §1): after `AdapterSet::merge_all()` the adapted projections
//! are plain dense matrices.  [`ServeBlock`] has both personalities:
//!
//! * [`ServeBlock::merged`] snapshots the merged weights — the decode
//!   hot loop is pure borrowing GEMM (`compute::gemm`) with **no
//!   circuit evaluation anywhere**;
//! * [`ServeBlock::streaming`] keeps the live adapters
//!   (`W x + α(circuit(x) − x)` through the plan-cached engine) — the
//!   reference the merged path is pinned against at `1e-5`
//!   (`rust/tests/serve_props.rs`), including the α-residual fold.
//!
//! ## Parity contract
//!
//! The decode step reuses the block's own per-row pieces —
//! `model::block::{layer_norm, attn_row, mlp_panel}` and the same
//! borrowing GEMM / circuit engine kernels, whose per-row results are
//! batch-size-invariant by the engine's chunking contract — so a
//! streaming decode step is **bitwise** equal to the corresponding row
//! of `TransformerBlock::forward` over the same prefix, at any
//! `QFT_THREADS` and any batch composition.  That bitwise equality
//! (not a tolerance) is what makes the scheduler's outputs independent
//! of arrival order and batch packing.

use crate::compute::{gemm, pool};
use crate::model::block::{attn_row, layer_norm, mlp_panel};
use crate::model::TransformerBlock;
use crate::quanta::QuantaAdapter;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Per-request decode state: the K/V rows of every position processed
/// so far, plus the position counter.  Capacity is **grow-only** (amortized
/// doubling, never shrinks), so a request slot reused across many
/// requests ([`DecodeState::reset`]) stops allocating once it has seen
/// its longest sequence.
#[derive(Clone, Debug)]
pub struct DecodeState {
    d: usize,
    /// Cached key/value rows, row-major `[len, d]` prefixes of a
    /// `[cap, d]` allocation.
    k: Vec<f32>,
    v: Vec<f32>,
    len: usize,
}

impl DecodeState {
    /// Empty state for width-`d` activations.
    pub fn new(d: usize) -> DecodeState {
        DecodeState { d, k: Vec::new(), v: Vec::new(), len: 0 }
    }

    /// Empty state with room for `cap` positions pre-allocated.
    pub fn with_capacity(d: usize, cap: usize) -> DecodeState {
        DecodeState { d, k: Vec::with_capacity(cap * d), v: Vec::with_capacity(cap * d), len: 0 }
    }

    /// Positions cached so far (the next token decodes at this index).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions the current allocation can hold without growing.
    pub fn capacity(&self) -> usize {
        if self.d == 0 {
            0
        } else {
            self.k.capacity() / self.d
        }
    }

    /// Forget the cached sequence but keep the allocation — request
    /// slots in the scheduler are recycled through this.
    pub fn reset(&mut self) {
        self.k.clear();
        self.v.clear();
        self.len = 0;
    }

    /// Append one position's K/V rows (called by the decode step).
    fn push(&mut self, krow: &[f32], vrow: &[f32]) {
        debug_assert_eq!(krow.len(), self.d);
        debug_assert_eq!(vrow.len(), self.d);
        // Vec::extend doubles capacity — grow-only by construction
        self.k.extend_from_slice(krow);
        self.v.extend_from_slice(vrow);
        self.len += 1;
    }
}

/// A projection in serving form: merged dense weight or live adapter.
#[derive(Clone, Debug)]
enum Projection {
    /// `Wᵀ` of the merged weight (`W + α(full − I)` folded in), stored
    /// transposed for the row-major `X · Wᵀ` GEMM.
    Merged(Tensor),
    /// The live adapter — frozen base + circuit delta through the
    /// plan-cached engine.
    Streaming(QuantaAdapter),
}

impl Projection {
    fn apply(&self, xs: &[f32], rows: usize, d: usize) -> Result<Vec<f32>> {
        match self {
            Projection::Merged(wt) => {
                let mut y = vec![0.0f32; rows * d];
                gemm::gemm_into(xs, &wt.data, &mut y, d, d);
                Ok(y)
            }
            Projection::Streaming(a) => a.apply_batch(xs, rows),
        }
    }
}

/// Immutable serving snapshot of a [`TransformerBlock`]: the frozen
/// MLP/layernorm weights plus the four projections in either merged or
/// streaming form.  Built once per deployment, shared by every request
/// (decode state lives per request, not here).
#[derive(Clone, Debug)]
pub struct ServeBlock {
    pub(crate) d: usize,
    n_heads: usize,
    head_dim: usize,
    d_ff: usize,
    wq: Projection,
    wk: Projection,
    wv: Projection,
    wo: Projection,
    w1_t: Tensor,
    b1: Vec<f32>,
    w2_t: Tensor,
    b2: Vec<f32>,
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
}

impl ServeBlock {
    /// Snapshot the frozen (non-projection) weights of `block` around
    /// the four given projections — the single construction path both
    /// deployments share.
    fn with_projections(
        block: &TransformerBlock,
        wq: Projection,
        wk: Projection,
        wv: Projection,
        wo: Projection,
    ) -> ServeBlock {
        ServeBlock {
            d: block.d,
            n_heads: block.n_heads,
            head_dim: block.head_dim,
            d_ff: block.d_ff,
            wq,
            wk,
            wv,
            wo,
            w1_t: block.w1_t.clone(),
            b1: block.b1.clone(),
            w2_t: block.w2_t.clone(),
            b2: block.b2.clone(),
            ln1_g: block.ln1_g.clone(),
            ln1_b: block.ln1_b.clone(),
            ln2_g: block.ln2_g.clone(),
            ln2_b: block.ln2_b.clone(),
        }
    }

    /// Zero-overhead deployment: every projection folded to a dense
    /// matrix via `AdapterSet::merge_all()` — the decode hot loop is
    /// pure GEMM, no circuit evaluation.
    pub fn merged(block: &TransformerBlock) -> Result<ServeBlock> {
        let mut proj = block
            .adapters
            .merge_all()?
            .into_iter()
            .map(|(_, w)| Ok(Projection::Merged(w.t()?)))
            .collect::<Result<Vec<_>>>()?;
        let wo = proj.pop().unwrap();
        let wv = proj.pop().unwrap();
        let wk = proj.pop().unwrap();
        let wq = proj.pop().unwrap();
        Ok(ServeBlock::with_projections(block, wq, wk, wv, wo))
    }

    /// Streaming deployment: the live adapters, un-merged — the parity
    /// reference for the merged path (and the apples-to-apples baseline
    /// the `serve_decode` bench prices the merge against).
    pub fn streaming(block: &TransformerBlock) -> ServeBlock {
        let a = |i: usize| Projection::Streaming(block.adapters.adapter(i).clone());
        ServeBlock::with_projections(block, a(0), a(1), a(2), a(3))
    }

    /// Activation width `d` of this block.
    pub fn d(&self) -> usize {
        self.d
    }

    /// True when every projection runs merged dense weights.
    pub fn is_merged(&self) -> bool {
        [&self.wq, &self.wk, &self.wv, &self.wo]
            .iter()
            .all(|p| matches!(p, Projection::Merged(_)))
    }

    /// Decode one new token for each of `states.len()` concurrent
    /// requests: `xs` is the row-major `[requests, d]` panel of new
    /// inputs (`xs[i]` is request `i`'s token at position
    /// `states[i].len()`), the per-request caches grow by one position,
    /// and the returned panel holds each request's block output at its
    /// new position.
    ///
    /// Projections and the MLP run as pooled panel GEMMs over all
    /// requests at once (`compute::gemm` / the circuit engine, both
    /// `QFT_THREADS`-invariant and per-row batch-invariant); attention
    /// is the per-request ragged part — one [`attn_row`] call per head
    /// against that request's cache, exactly the loop the full forward
    /// runs for its final position.
    ///
    /// This is a fault-isolation boundary: a panic anywhere under the
    /// step (e.g. inside a pool worker's GEMM chunk) is converted to a
    /// structured [`Error::Compute`](crate::util::error::Error) on the
    /// caller via [`pool::catching`] instead of unwinding through the
    /// serving stack, and the pool remains usable for the next step.
    pub fn decode_step(&self, states: &mut [&mut DecodeState], xs: &[f32]) -> Result<Vec<f32>> {
        let mut out = pool::catching(|| self.decode_step_inner(states, xs))?;
        // `nan@decode:n` probe: poison the panel's first element — one
        // victim request turns non-finite mid-decode, which is exactly
        // the condition the scheduler's quarantine sweep must catch
        // without disturbing the other rows.
        if crate::util::fault::armed() {
            if let Some(crate::util::fault::Fault::Nan) = crate::util::fault::probe("decode") {
                if let Some(v) = out.first_mut() {
                    *v = f32::NAN;
                }
            }
        }
        Ok(out)
    }

    fn decode_step_inner(&self, states: &mut [&mut DecodeState], xs: &[f32]) -> Result<Vec<f32>> {
        let rows = states.len();
        let d = self.d;
        if xs.len() != rows * d {
            return Err(Error::Shape(format!(
                "decode_step: xs len {} != requests {rows} * d {d}",
                xs.len()
            )));
        }
        for (i, s) in states.iter().enumerate() {
            if s.d != d {
                return Err(Error::Shape(format!(
                    "decode_step: state {i} has d {}, block has d {d}",
                    s.d
                )));
            }
        }
        if rows == 0 {
            return Ok(Vec::new());
        }
        let (h1, _, _) = layer_norm(xs, &self.ln1_g, &self.ln1_b, d);
        let q = self.wq.apply(&h1, rows, d)?;
        let k = self.wk.apply(&h1, rows, d)?;
        let v = self.wv.apply(&h1, rows, d)?;
        // attention: append this position's K/V, then one attn_row per
        // head against the request's own cache (ragged lengths — each
        // request attends over its own history only)
        let (hd, scale) = (self.head_dim, 1.0 / (self.head_dim as f32).sqrt());
        let mut ctx = vec![0.0f32; rows * d];
        let mut scores: Vec<f32> = Vec::new();
        let mut prow: Vec<f32> = Vec::new();
        for (i, state) in states.iter_mut().enumerate() {
            state.push(&k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]);
            let t = state.len - 1;
            if scores.len() < t + 1 {
                scores.resize(t + 1, 0.0);
                prow.resize(t + 1, 0.0);
            }
            for h in 0..self.n_heads {
                let off = h * hd;
                let qrow = &q[i * d + off..i * d + off + hd];
                attn_row(
                    qrow,
                    &state.k,
                    &state.v,
                    d,
                    off,
                    t,
                    scale,
                    &mut scores,
                    &mut prow[..t + 1],
                    &mut ctx[i * d + off..i * d + off + hd],
                );
            }
        }
        let attn_out = self.wo.apply(&ctx, rows, d)?;
        let mut x1 = xs.to_vec();
        for (o, &a) in x1.iter_mut().zip(&attn_out) {
            *o += a;
        }
        let (h2, _, _) = layer_norm(&x1, &self.ln2_g, &self.ln2_b, d);
        // the block's own MLP body (mlp_panel is shared, like attn_row,
        // so decode and forward stay instruction-identical)
        let (m, _) =
            mlp_panel(&h2, rows, &self.w1_t, &self.b1, &self.w2_t, &self.b2, d, self.d_ff);
        for (o, &mv) in x1.iter_mut().zip(&m) {
            *o += mv;
        }
        Ok(x1)
    }

    /// Decode a whole teacher-forced sequence for one request: feed
    /// `xs[t]` at position `t` and collect every position's output —
    /// the incremental counterpart of
    /// [`TransformerBlock::forward`]`(xs, 1, seq)`, against which
    /// it is pinned per position by `rust/tests/serve_props.rs`.
    pub fn decode_sequence(&self, xs: &[f32], seq: usize) -> Result<Vec<f32>> {
        let d = self.d;
        if seq == 0 || xs.len() != seq * d {
            return Err(Error::Shape(format!(
                "decode_sequence: xs len {} != seq {seq} * d {d}",
                xs.len()
            )));
        }
        let mut state = DecodeState::with_capacity(d, seq);
        let mut out = Vec::with_capacity(seq * d);
        for t in 0..seq {
            let y = self.decode_step(&mut [&mut state], &xs[t * d..(t + 1) * d])?;
            out.extend_from_slice(&y);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_state_grow_only_and_reset() {
        let mut s = DecodeState::with_capacity(4, 2);
        assert!(s.is_empty());
        assert!(s.capacity() >= 2);
        for t in 0..9 {
            s.push(&[t as f32; 4], &[-(t as f32); 4]);
        }
        assert_eq!(s.len(), 9);
        let cap = s.capacity();
        assert!(cap >= 9);
        s.reset();
        assert_eq!(s.len(), 0);
        assert_eq!(s.capacity(), cap, "reset must keep the allocation");
        s.push(&[1.0; 4], &[2.0; 4]);
        assert_eq!(s.len(), 1);
        assert_eq!(&s.k[..4], &[1.0; 4]);
        assert_eq!(&s.v[..4], &[2.0; 4]);
    }

    #[test]
    fn decode_step_shape_errors() {
        use crate::model::BlockConfig;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(90);
        let block =
            TransformerBlock::init(&BlockConfig::standard(vec![2, 2], 2, 3), &mut rng).unwrap();
        let sb = ServeBlock::merged(&block).unwrap();
        let mut st = DecodeState::new(4);
        assert!(sb.decode_step(&mut [&mut st], &[0.0; 3]).is_err());
        let mut wrong = DecodeState::new(5);
        assert!(sb.decode_step(&mut [&mut wrong], &[0.0; 5]).is_err());
        assert!(sb.decode_sequence(&[0.0; 4], 0).is_err());
        assert_eq!(sb.decode_step(&mut [], &[]).unwrap(), Vec::<f32>::new());
    }
}
