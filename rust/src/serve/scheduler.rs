//! Continuous-batching scheduler over the KV-cache decode step.
//!
//! Many concurrent requests, ragged lengths, one token per request per
//! iteration (the Orca-style "iteration-level" schedule): every loop
//! turn the scheduler **admits** waiting requests into free slots,
//! packs each active request's next input row into one `[active, d]`
//! panel, runs a single [`ServeBlock::decode_step`] (projections + MLP
//! as pooled GEMMs over the whole panel, attention ragged per
//! request), hands each request its new output row, and **retires**
//! requests that produced their last token — freeing the slot for the
//! next waiting request *between* steps, never mid-token.
//!
//! A request is a prompt panel plus a generation count: the prompt's
//! rows are fed teacher-forced (one per iteration — prefill shares the
//! same batched step as generation), the output at the final prompt
//! position is the first generated vector, and each generated vector
//! is fed back as the next input (greedy autoregression in activation
//! space — this host model has no sampling head).
//!
//! **Determinism contract**: per-request outputs depend only on the
//! request's own prompt — never on arrival order, batch packing,
//! `max_batch`, `QFT_THREADS`, or the dispatch mode — because every
//! kernel under the step is per-row batch-invariant (the engine's
//! chunking contract) and attention reads only the request's own
//! cache.  `rust/tests/serve_props.rs` pins this **bitwise** across
//! arrival permutations, batch sizes, and thread counts.  Retired
//! [`DecodeState`]s are recycled (grow-only capacity) so a long
//! serving run stops allocating cache once slots have seen their
//! longest request.

use crate::serve::decode::{DecodeState, ServeBlock};
use crate::util::error::{Error, Result};

/// One serving request: a prompt of `prompt_len` width-`d` vectors
/// (row-major) and the number of vectors to generate after it.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    /// Caller-chosen identifier, reported back on the output.
    pub id: u64,
    /// Row-major `[prompt_len, d]` prompt panel (must be non-empty).
    pub prompt: Vec<f32>,
    /// Generated vectors to produce (≥ 1; the first is the output at
    /// the last prompt position).
    pub n_gen: usize,
}

impl ServeRequest {
    pub fn prompt_len(&self, d: usize) -> usize {
        self.prompt.len() / d.max(1)
    }
}

/// A finished request: the generated panel plus latency accounting.
#[derive(Clone, Debug)]
pub struct ServeOutput {
    pub id: u64,
    pub prompt_len: usize,
    /// Row-major `[n_gen, d]` generated vectors.
    pub generated: Vec<f32>,
    /// Scheduler iteration at which the request was admitted.
    pub admitted_at: usize,
    /// Scheduler iteration after which the request retired.
    pub finished_at: usize,
}

impl ServeOutput {
    /// Iterations the request was resident (its per-request latency in
    /// scheduler steps: queueing excluded, prefill included).
    pub fn steps_resident(&self) -> usize {
        self.finished_at - self.admitted_at
    }
}

/// Aggregate accounting for one [`BatchScheduler::run`].
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Scheduler iterations executed.
    pub steps: usize,
    /// Total decode rows processed (Σ per-step active requests) — the
    /// token-throughput numerator.
    pub tokens: usize,
    /// Peak concurrently-active requests.
    pub peak_batch: usize,
    pub wallclock_s: f64,
}

impl ServeStats {
    pub fn tokens_per_s(&self) -> f64 {
        if self.wallclock_s > 0.0 {
            self.tokens as f64 / self.wallclock_s
        } else {
            0.0
        }
    }
}

/// An admitted request mid-flight.
struct Active {
    req: ServeRequest,
    state: DecodeState,
    /// Next prompt row to feed (== prompt_len ⇒ generating).
    fed: usize,
    generated: Vec<f32>,
    admitted_at: usize,
}

/// Continuous-batching executor for one [`ServeBlock`] deployment.
pub struct BatchScheduler {
    block: ServeBlock,
    max_batch: usize,
}

impl BatchScheduler {
    /// `max_batch` caps concurrently-active requests (≥ 1).
    pub fn new(block: ServeBlock, max_batch: usize) -> Result<BatchScheduler> {
        if max_batch == 0 {
            return Err(Error::Config("scheduler: max_batch must be >= 1".into()));
        }
        Ok(BatchScheduler { block, max_batch })
    }

    pub fn block(&self) -> &ServeBlock {
        &self.block
    }

    /// Drive `requests` (admitted in the given order as slots free up)
    /// to completion; outputs are returned **sorted by id** so callers
    /// and tests compare runs independently of completion order.
    pub fn run(&self, requests: Vec<ServeRequest>) -> Result<(Vec<ServeOutput>, ServeStats)> {
        let d = self.block.d();
        for r in &requests {
            if r.prompt.is_empty() || r.prompt.len() % d != 0 {
                return Err(Error::Shape(format!(
                    "request {}: prompt len {} not a non-empty multiple of d {d}",
                    r.id,
                    r.prompt.len()
                )));
            }
            if r.n_gen == 0 {
                return Err(Error::Config(format!("request {}: n_gen must be >= 1", r.id)));
            }
        }
        let start = std::time::Instant::now();
        let mut queue = std::collections::VecDeque::from(requests);
        let mut active: Vec<Active> = Vec::new();
        let mut free_states: Vec<DecodeState> = Vec::new();
        let mut outputs = Vec::new();
        let mut stats = ServeStats::default();
        let mut xs: Vec<f32> = Vec::new();
        while !queue.is_empty() || !active.is_empty() {
            // admit into free slots, preserving arrival order
            while active.len() < self.max_batch {
                let Some(req) = queue.pop_front() else { break };
                let mut state = free_states.pop().unwrap_or_else(|| DecodeState::new(d));
                state.reset();
                active.push(Active {
                    state,
                    fed: 0,
                    generated: Vec::with_capacity(req.n_gen * d),
                    admitted_at: stats.steps,
                    req,
                });
            }
            stats.peak_batch = stats.peak_batch.max(active.len());
            // pack each active request's next input row
            xs.clear();
            for a in &active {
                if a.fed < a.req.prompt_len(d) {
                    xs.extend_from_slice(&a.req.prompt[a.fed * d..(a.fed + 1) * d]);
                } else {
                    // autoregressive: feed back the latest generated row
                    let g = a.generated.len();
                    xs.extend_from_slice(&a.generated[g - d..g]);
                }
            }
            let mut states: Vec<&mut DecodeState> =
                active.iter_mut().map(|a| &mut a.state).collect();
            let out = self.block.decode_step(&mut states, &xs)?;
            drop(states);
            stats.steps += 1;
            stats.tokens += active.len();
            // hand out rows; retire finished requests.  The panel row
            // of request `i` is `out[i*d..]` in the PRE-retire active
            // order, so the sweep drains the old vec and rebuilds the
            // survivor list — removing in place (swap_remove) would
            // silently remap later requests onto the wrong rows.
            let old = std::mem::take(&mut active);
            for (i, mut a) in old.into_iter().enumerate() {
                let row = &out[i * d..(i + 1) * d];
                a.fed += 1;
                // the output at the last prompt position is the first
                // generated vector; earlier prefill outputs are scored
                // but not part of the response
                if a.fed >= a.req.prompt_len(d) {
                    a.generated.extend_from_slice(row);
                }
                if a.generated.len() >= a.req.n_gen * d {
                    outputs.push(ServeOutput {
                        id: a.req.id,
                        prompt_len: a.req.prompt_len(d),
                        generated: a.generated,
                        admitted_at: a.admitted_at,
                        finished_at: stats.steps,
                    });
                    free_states.push(a.state);
                } else {
                    active.push(a);
                }
            }
        }
        stats.wallclock_s = start.elapsed().as_secs_f64();
        outputs.sort_by_key(|o| o.id);
        Ok((outputs, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BlockConfig, TransformerBlock};
    use crate::util::rng::Rng;

    fn tiny_serve_block(rng: &mut Rng) -> ServeBlock {
        let cfg = BlockConfig::standard(vec![2, 2], 2, 3);
        let mut block = TransformerBlock::init(&cfg, rng).unwrap();
        block.randomize_circuits(0.2, rng).unwrap();
        ServeBlock::merged(&block).unwrap()
    }

    fn mk_request(id: u64, d: usize, p_len: usize, n_gen: usize, rng: &mut Rng) -> ServeRequest {
        let mut prompt = vec![0.0f32; p_len * d];
        rng.fill_normal(&mut prompt, 1.0);
        ServeRequest { id, prompt, n_gen }
    }

    #[test]
    fn scheduler_matches_single_request_decode() {
        // a request served alone equals the same request served in a
        // crowd (per-row batch invariance, the continuous-batching
        // correctness core)
        let mut rng = Rng::new(91);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let reqs: Vec<ServeRequest> = (0..5)
            .map(|i| mk_request(i, d, 1 + (i as usize % 3), 2 + (i as usize % 4), &mut rng))
            .collect();
        let solo = BatchScheduler::new(sb.clone(), 1).unwrap();
        let crowd = BatchScheduler::new(sb, 4).unwrap();
        let (solo_out, _) = solo.run(reqs.clone()).unwrap();
        let (crowd_out, stats) = crowd.run(reqs).unwrap();
        assert_eq!(solo_out.len(), crowd_out.len());
        for (a, b) in solo_out.iter().zip(&crowd_out) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.generated, b.generated, "request {} diverged across batches", a.id);
        }
        assert!(stats.peak_batch > 1, "crowd run never actually batched");
        let want_tokens: usize = solo_out
            .iter()
            .map(|o| o.prompt_len + o.generated.len() / d - 1)
            .sum();
        assert_eq!(stats.tokens, want_tokens);
    }

    #[test]
    fn scheduler_rejects_bad_requests() {
        let mut rng = Rng::new(92);
        let sb = tiny_serve_block(&mut rng);
        let sched = BatchScheduler::new(sb.clone(), 2).unwrap();
        let bad_len = ServeRequest { id: 0, prompt: vec![0.0; 3], n_gen: 1 };
        assert!(sched.run(vec![bad_len]).is_err());
        let empty = ServeRequest { id: 1, prompt: vec![], n_gen: 1 };
        assert!(sched.run(vec![empty]).is_err());
        let no_gen = ServeRequest { id: 2, prompt: vec![0.0; 4], n_gen: 0 };
        assert!(sched.run(vec![no_gen]).is_err());
        assert!(BatchScheduler::new(sb, 0).is_err());
        let (out, stats) = sched.run(vec![]).unwrap();
        assert!(out.is_empty());
        assert_eq!(stats.steps, 0);
    }

    #[test]
    fn latency_accounting_is_consistent() {
        let mut rng = Rng::new(93);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let reqs: Vec<ServeRequest> = (0..6).map(|i| mk_request(i, d, 2, 3, &mut rng)).collect();
        let sched = BatchScheduler::new(sb, 2).unwrap();
        let (out, stats) = sched.run(reqs).unwrap();
        for o in &out {
            // prompt_len + n_gen - 1 decode steps per request
            assert_eq!(o.steps_resident(), o.prompt_len + 3 - 1, "request {}", o.id);
            assert_eq!(o.generated.len(), 3 * d);
        }
        // with max_batch 2 and 6 identical 4-step requests: 12 steps
        assert_eq!(stats.steps, 12);
        assert_eq!(stats.tokens, 24);
        assert_eq!(stats.peak_batch, 2);
    }
}
