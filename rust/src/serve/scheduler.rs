//! Continuous-batching scheduler over the paged KV decode step.
//!
//! Many concurrent requests, ragged lengths, one token per request per
//! iteration (the Orca-style "iteration-level" schedule): every loop
//! turn the scheduler **admits** waiting requests into free slots,
//! packs each *generating* request's next input row into one
//! `[active, d]` panel, runs a single [`DecodeEngine::decode_step`]
//! (projections + MLP as pooled GEMMs over the whole panel, attention
//! ragged per request), hands each request its new output row, and
//! **retires** requests that produced their last token — freeing the
//! slot for the next waiting request *between* steps, never mid-token.
//! Requests still inside their prompt are driven by **chunked
//! prefill** instead ([`DecodeEngine::prefill`]): up to
//! `prefill_chunk` prompt positions per iteration in one batched pass
//! (0 = the whole prompt at admission), bitwise equal to feeding the
//! rows one at a time but a fraction of the wallclock.
//!
//! The scheduler is generic over [`DecodeEngine`]: a single
//! [`ServeBlock`] (the default — one [`DecodeState`](crate::serve::
//! DecodeState) per slot) and a depth-N
//! [`ServeModel`](crate::serve::ServeModel) (one
//! [`SessionState`](crate::serve::SessionState) per slot) run through
//! the *same* admit/pack/step/retire loop, so every lifecycle control
//! and isolation property below applies to deep serving verbatim.
//!
//! ## Bounded cache memory (DESIGN.md §14)
//!
//! All per-request K/V history pages out of **one**
//! [`KvArena`](crate::serve::KvArena) owned by the scheduler (the
//! `Workspace`, locked once per [`BatchScheduler::run`]), together
//! with one [`DecodeScratch`](crate::serve::DecodeScratch) of reusable
//! activation buffers — the steady-state decode loop allocates
//! nothing.  Resident cache is bounded by tokens in flight (a retired
//! request's pages free immediately), and a `--kv-pages` budget turns
//! would-be OOM into a *per-request* quarantine:
//! [`ServeError::CacheExhausted`] retires exactly the request whose
//! push found the arena full, releases its pages, and every other
//! request keeps decoding bitwise unchanged.
//!
//! A request is a prompt panel plus a generation count: the prompt's
//! rows are prefilled, the output at the final prompt position is the
//! first generated vector, and each generated vector is fed back as
//! the next input (greedy autoregression in activation space — this
//! host model has no sampling head).
//!
//! ## Per-request error domains (DESIGN.md §11)
//!
//! Each request is its own failure domain.  [`ServeOutput::result`] is
//! success-or-[`ServeError`]: malformed requests (bad shape, `n_gen`
//! 0, non-finite prompt, over the token budget) are **rejected at
//! intake** and never enter the packed panel; a request whose decode
//! output turns non-finite, that outlives its step deadline, or that
//! exhausts the KV page budget is **quarantined** — retired with an
//! error at that step while the rest of the batch keeps running.  The
//! bounded intake queue sheds overload per [`ShedPolicy`] instead of
//! growing without limit.
//!
//! The key isolation invariant: **healthy requests' outputs are
//! bitwise identical to a run without the faulty ones.**  It holds by
//! construction — rejected requests never occupy a panel row, and
//! every kernel under the step is per-row batch-invariant, so a
//! quarantined row (even a NaN one: GEMM, layernorm, and attention
//! never read across rows) cannot perturb any other row's bits, and
//! neither can the re-packing after it leaves.  `rust/tests/
//! serve_props.rs` pins this against the healthy-subset run across
//! thread counts and arrival permutations.
//!
//! ## Determinism contract
//!
//! Per-request outputs depend only on the request's own prompt — never
//! on arrival order, batch packing, `max_batch`, `QFT_THREADS`, the
//! dispatch mode, the page size, or the prefill chunk — because every
//! kernel under the step is per-row batch-invariant (the engine's
//! chunking contract), attention reads only the request's own cache
//! through its page table, and paged attention executes the same
//! float ops in the same order as contiguous
//! (`model::block::attn_row_segs`).  `rust/tests/serve_props.rs` and
//! `rust/tests/kv_props.rs` pin this **bitwise** across arrival
//! permutations, batch sizes, page sizes, prefill chunks, and thread
//! counts.  (Shedding is the deliberate exception: which requests a
//! full queue sheds depends on arrival order by definition.)
//!
//! ## Prefix-cache admission (DESIGN.md §15)
//!
//! With [`ServeConfig::prefix_cache`] on, intake looks for an active
//! request whose prompt opens with the same rows (bitwise,
//! `f32::to_bits`) as the arrival's.  The shared prefix — floored to
//! whole pages, capped at the arrival's second-to-last prompt row —
//! is then *forked* ([`DecodeEngine::fork_session`] →
//! `KvArena::fork_prefix`) instead of re-prefilled: the follower's
//! session maps the donor's prefix pages by refcount and prefills
//! only its tail.  This cannot change any output bit: a K/V row is a
//! function of its own input row alone, so the donor's cached prefix
//! rows are bit-identical to the rows the follower would have
//! computed — only resident pages and prefill work drop
//! (`stats.prefix_hits` / `stats.shared_prefix_pages`).  The fork is
//! deferred until the donor has prefilled past the shared prefix
//! (same sweep under whole-prompt prefill), and falls back to a plain
//! prefill if the donor retires first.

use crate::serve::decode::{DecodeScratch, ServeBlock};
use crate::serve::kv::{self, KvArena};
use crate::serve::model::DecodeEngine;
use crate::util::error::{Error, Result};
use crate::util::numeric::non_finite_at;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// One serving request: a prompt of `prompt_len` width-`d` vectors
/// (row-major) and the number of vectors to generate after it.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    /// Caller-chosen identifier, reported back on the output.
    pub id: u64,
    /// Row-major `[prompt_len, d]` prompt panel (must be non-empty).
    pub prompt: Vec<f32>,
    /// Generated vectors to produce (≥ 1; the first is the output at
    /// the last prompt position).
    pub n_gen: usize,
}

impl ServeRequest {
    pub fn prompt_len(&self, d: usize) -> usize {
        self.prompt.len() / d.max(1)
    }
}

/// Why a request failed — its own error domain, reported per request
/// on [`ServeOutput::result`] while the rest of the batch runs on.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Rejected at intake: malformed shape or `n_gen` 0.
    Rejected(String),
    /// Rejected at intake: the prompt's flat element `at` is NaN/±inf.
    NonFinitePrompt { at: usize },
    /// Rejected at intake: `prompt_len + n_gen` exceeds the
    /// per-request token budget.
    OverBudget { tokens: usize, budget: usize },
    /// Quarantined mid-flight: the decode output at scheduler step
    /// `step` (1-based) turned non-finite.
    NonFiniteOutput { step: usize },
    /// Quarantined mid-flight: still unfinished after `limit` resident
    /// scheduler steps.
    DeadlineExceeded { limit: usize },
    /// Quarantined mid-flight: the KV arena's page budget (`pages`)
    /// was exhausted when this request tried to cache its next token.
    /// Its pages are released; every other request is bitwise
    /// unaffected.
    CacheExhausted { pages: usize },
    /// Shed by the bounded intake queue under overload.
    Shed,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Rejected(m) => write!(f, "rejected: {m}"),
            ServeError::NonFinitePrompt { at } => {
                write!(f, "rejected: non-finite prompt element at {at}")
            }
            ServeError::OverBudget { tokens, budget } => {
                write!(f, "rejected: {tokens} tokens over budget {budget}")
            }
            ServeError::NonFiniteOutput { step } => {
                write!(f, "quarantined: non-finite output at step {step}")
            }
            ServeError::DeadlineExceeded { limit } => {
                write!(f, "quarantined: deadline of {limit} steps exceeded")
            }
            ServeError::CacheExhausted { pages } => {
                write!(f, "quarantined: kv cache exhausted (page budget {pages})")
            }
            ServeError::Shed => write!(f, "shed: intake queue full"),
        }
    }
}

/// What to do when the bounded intake queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Refuse the arriving request (the queue keeps its oldest work).
    RejectNew,
    /// Drop the oldest waiting request to make room for the arrival
    /// (freshest-work-wins, e.g. when stale requests have expired
    /// client-side anyway).
    DropOldest,
}

/// Request lifecycle controls for one [`BatchScheduler`].  `0` means
/// "unlimited" for every limit, so `ServeConfig::default()` (plus a
/// `max_batch`) reproduces the unconstrained scheduler exactly.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Cap on concurrently-active requests (≥ 1).
    pub max_batch: usize,
    /// Max scheduler steps a request may stay resident before it is
    /// quarantined with [`ServeError::DeadlineExceeded`] (0 = none).
    /// With whole-prompt prefill a request needs `n_gen` resident
    /// steps; with `prefill_chunk` 1 it needs `prompt_len + n_gen − 1`.
    pub deadline_steps: usize,
    /// Max `prompt_len + n_gen` tokens per request; larger requests
    /// are rejected at intake with [`ServeError::OverBudget`] (0 =
    /// none).
    pub token_budget: usize,
    /// Bound on the intake queue; arrivals beyond it are shed per
    /// [`ShedPolicy`] (0 = unbounded).
    pub queue_cap: usize,
    /// Shed policy for a full intake queue.
    pub shed: ShedPolicy,
    /// KV arena page budget shared by every request (0 = unbounded).
    /// Exhaustion quarantines the requesting request with
    /// [`ServeError::CacheExhausted`].
    pub kv_pages: usize,
    /// Tokens per KV page (≥ 1; default `QFT_KV_PAGE` else 16).
    pub page_tokens: usize,
    /// Prompt positions prefilled per scheduler iteration: 0 = the
    /// whole remaining prompt at once (fastest), 1 = row-at-a-time
    /// (the pre-paging schedule).  Any value yields bitwise identical
    /// outputs; only wallclock and step accounting change.
    pub prefill_chunk: usize,
    /// Prefix-cache admission: admit a request whose prompt opens with
    /// an active request's rows (bitwise) by CoW-forking the shared
    /// whole pages instead of re-prefilling them.  Outputs are bitwise
    /// unchanged; resident pages and prefill rows drop (see the
    /// module-level notes).
    pub prefix_cache: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            deadline_steps: 0,
            token_budget: 0,
            queue_cap: 0,
            shed: ShedPolicy::RejectNew,
            kv_pages: 0,
            page_tokens: kv::default_page_tokens(),
            prefill_chunk: 0,
            prefix_cache: false,
        }
    }
}

/// Builder-style deviations from [`ServeConfig::default`], one method
/// per CLI flag (`--max-batch`, `--deadline`, `--token-budget`,
/// `--queue-cap`, `--shed-policy`, `--kv-pages`, `--page-size`,
/// `--prefill-chunk`, `--prefix-cache`) so config construction reads
/// the same at every site.
impl ServeConfig {
    pub fn with_max_batch(mut self, max_batch: usize) -> ServeConfig {
        self.max_batch = max_batch;
        self
    }

    pub fn with_deadline(mut self, deadline_steps: usize) -> ServeConfig {
        self.deadline_steps = deadline_steps;
        self
    }

    pub fn with_token_budget(mut self, token_budget: usize) -> ServeConfig {
        self.token_budget = token_budget;
        self
    }

    pub fn with_queue_cap(mut self, queue_cap: usize) -> ServeConfig {
        self.queue_cap = queue_cap;
        self
    }

    pub fn with_shed_policy(mut self, shed: ShedPolicy) -> ServeConfig {
        self.shed = shed;
        self
    }

    pub fn with_kv_pages(mut self, kv_pages: usize) -> ServeConfig {
        self.kv_pages = kv_pages;
        self
    }

    pub fn with_page_tokens(mut self, page_tokens: usize) -> ServeConfig {
        self.page_tokens = page_tokens;
        self
    }

    pub fn with_prefill_chunk(mut self, prefill_chunk: usize) -> ServeConfig {
        self.prefill_chunk = prefill_chunk;
        self
    }

    pub fn with_prefix_cache(mut self, prefix_cache: bool) -> ServeConfig {
        self.prefix_cache = prefix_cache;
        self
    }
}

/// A finished request: the generated panel (or the request's own
/// [`ServeError`]) plus latency accounting.
#[derive(Clone, Debug)]
pub struct ServeOutput {
    pub id: u64,
    pub prompt_len: usize,
    /// Row-major `[n_gen, d]` generated vectors, or why this request
    /// failed.  Failures are per-request: other outputs in the same
    /// run are unaffected (bitwise).
    pub result: std::result::Result<Vec<f32>, ServeError>,
    /// Scheduler iteration at which the request was admitted (0 for
    /// requests rejected or shed at intake).
    pub admitted_at: usize,
    /// Scheduler iteration after which the request retired (0 for
    /// requests rejected or shed at intake).
    pub finished_at: usize,
}

impl ServeOutput {
    /// Iterations the request was resident (its per-request latency in
    /// scheduler steps: queueing excluded, prefill included).
    pub fn steps_resident(&self) -> usize {
        self.finished_at - self.admitted_at
    }

    /// The generated panel, if the request succeeded.
    pub fn generated(&self) -> Option<&[f32]> {
        self.result.as_deref().ok()
    }

    /// The request's error, if it failed.
    pub fn error(&self) -> Option<&ServeError> {
        self.result.as_ref().err()
    }
}

/// Aggregate accounting for one [`BatchScheduler::run`].
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Scheduler iterations executed.
    pub steps: usize,
    /// Total rows processed (decode rows + prefilled prompt rows) —
    /// the token-throughput numerator.  Includes rows later
    /// quarantined.
    pub tokens: usize,
    /// Peak concurrently-active requests.
    pub peak_batch: usize,
    pub wallclock_s: f64,
    /// Requests retired with their full generated panel.
    pub completed: usize,
    /// Requests retired with a [`ServeError`] other than
    /// [`ServeError::Shed`] (rejected at intake or quarantined
    /// mid-flight).
    pub failed: usize,
    /// Requests shed by the bounded intake queue **or** by a drain.
    pub shed: usize,
    /// True iff this run was drained (graceful shutdown): admission
    /// stopped, the remaining queue was shed, in-flight requests ran to
    /// completion under their deadlines.
    pub drained: bool,
    /// Peak KV pages resident at once during the run (the `--kv-pages`
    /// budget's high-water mark).
    pub pages_in_use: usize,
    /// Peak resident K/V cache bytes during the run — the
    /// bounded-memory headline the `kv_serve` bench gates on.
    pub resident_kv_bytes: usize,
    /// Prefix-cache fork admissions: requests admitted by CoW-sharing
    /// a donor's prompt-prefix pages instead of re-prefilling them.
    pub prefix_hits: usize,
    /// Pages mapped by freshly forked sessions at fork time, summed
    /// over admissions (a shared page counts once per borrowing
    /// session) — the shared-pages row the serve CLI prints.
    pub shared_prefix_pages: usize,
}

impl ServeStats {
    pub fn tokens_per_s(&self) -> f64 {
        if self.wallclock_s > 0.0 {
            self.tokens as f64 / self.wallclock_s
        } else {
            0.0
        }
    }
}

/// An admitted request mid-flight; `S` is the engine's per-slot
/// session (one `DecodeState`, or one `SessionState` per deep slot).
struct Active<S> {
    req: ServeRequest,
    state: S,
    /// Prompt rows cached so far — prefilled or CoW-shared
    /// (== prompt_len ⇒ generating).
    fed: usize,
    generated: Vec<f32>,
    admitted_at: usize,
    /// Admission serial, stable across sweep rebuilds — how a pending
    /// fork names its donor.
    adm: u64,
    /// Deferred prefix fork: (donor admission serial, shared tokens).
    /// Resolved in the retire sweep once the donor has prefilled past
    /// the shared prefix; cleared (plain prefill) if the donor retires
    /// first.
    pending_fork: Option<(u64, usize)>,
}

/// Leading whole rows on which two row-major prompts agree bitwise
/// (`f32::to_bits` equality, so ±0.0 and NaN payloads are distinct —
/// exactly the cache-key semantics CoW page sharing needs).
fn common_prefix_rows(a: &[f32], b: &[f32], d: usize) -> usize {
    let max_rows = (a.len() / d).min(b.len() / d);
    for r in 0..max_rows {
        let (ra, rb) = (&a[r * d..(r + 1) * d], &b[r * d..(r + 1) * d]);
        if ra.iter().zip(rb).any(|(x, y)| x.to_bits() != y.to_bits()) {
            return r;
        }
    }
    max_rows
}

/// The scheduler's per-run mutable compute state: the one KV arena
/// every session pages out of, and the reusable activation scratch.
/// Behind a mutex only so `run(&self)` coexists with the `drain()`
/// latch being shared across threads — the lock is taken once per run,
/// never per step.
struct Workspace {
    arena: KvArena,
    scratch: DecodeScratch,
}

/// Continuous-batching executor for one [`DecodeEngine`] deployment —
/// a single [`ServeBlock`] by default, or a depth-N
/// [`ServeModel`](crate::serve::ServeModel).
pub struct BatchScheduler<E: DecodeEngine = ServeBlock> {
    engine: E,
    cfg: ServeConfig,
    ws: Mutex<Workspace>,
    /// Graceful-shutdown latch (DESIGN.md §13): set from a signal
    /// handler (or any thread) via [`BatchScheduler::drain`]; the run
    /// loop observes it between iterations, never mid-step.
    drain: AtomicBool,
}

impl<E: DecodeEngine> BatchScheduler<E> {
    /// `max_batch` caps concurrently-active requests (≥ 1); every
    /// other lifecycle control stays off (see [`ServeConfig`]).
    pub fn new(engine: E, max_batch: usize) -> Result<BatchScheduler<E>> {
        BatchScheduler::with_config(engine, ServeConfig::default().with_max_batch(max_batch))
    }

    /// Full lifecycle-controlled construction.
    pub fn with_config(engine: E, cfg: ServeConfig) -> Result<BatchScheduler<E>> {
        if cfg.max_batch == 0 {
            return Err(Error::Config("scheduler: max_batch must be >= 1".into()));
        }
        let arena = KvArena::new(engine.d(), cfg.page_tokens, cfg.kv_pages)?;
        let ws = Mutex::new(Workspace { arena, scratch: DecodeScratch::new() });
        Ok(BatchScheduler { engine, cfg, ws, drain: AtomicBool::new(false) })
    }

    /// Begin a graceful drain: the run loop (this thread or another)
    /// stops admitting at its next iteration boundary, sheds every
    /// still-queued request as [`ServeError::Shed`], and lets in-flight
    /// requests finish under their existing deadlines.  Idempotent;
    /// safe to call from a signal handler's notifier thread.
    pub fn drain(&self) {
        self.drain.store(true, Ordering::Relaxed);
    }

    /// Whether a drain has been requested.
    pub fn draining(&self) -> bool {
        self.drain.load(Ordering::Relaxed)
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Why `r` must not enter the packed panel, if anything.
    fn validate(&self, r: &ServeRequest, d: usize) -> Option<ServeError> {
        if r.prompt.is_empty() || r.prompt.len() % d != 0 {
            return Some(ServeError::Rejected(format!(
                "prompt len {} not a non-empty multiple of d {d}",
                r.prompt.len()
            )));
        }
        if r.n_gen == 0 {
            return Some(ServeError::Rejected("n_gen must be >= 1".into()));
        }
        let tokens = r.prompt_len(d) + r.n_gen;
        if self.cfg.token_budget > 0 && tokens > self.cfg.token_budget {
            return Some(ServeError::OverBudget { tokens, budget: self.cfg.token_budget });
        }
        if let Some(at) = non_finite_at(&r.prompt) {
            return Some(ServeError::NonFinitePrompt { at });
        }
        None
    }

    /// Best prefix-cache donor for `req` among the active requests:
    /// the first one sharing the most whole leading prompt rows
    /// (bitwise), floored to whole pages and capped at `req`'s
    /// second-to-last prompt row — the follower always computes its
    /// own final prompt output, so its first generated vector never
    /// depends on the fork.  Returns `(donor admission serial, shared
    /// tokens)`.
    fn find_donor(
        &self,
        active: &[Active<E::Session>],
        req: &ServeRequest,
        d: usize,
    ) -> Option<(u64, usize)> {
        let plen = req.prompt_len(d);
        let pt = self.cfg.page_tokens;
        let mut best: Option<(u64, usize)> = None;
        for a in active {
            let rows = common_prefix_rows(&a.req.prompt, &req.prompt, d);
            let share = rows.min(plen - 1) / pt * pt;
            if share > 0 && best.map_or(true, |(_, s)| share > s) {
                best = Some((a.adm, share));
            }
        }
        best
    }

    /// Drive `requests` (admitted in the given order as slots free up)
    /// to completion; outputs are returned **sorted by id** so callers
    /// and tests compare runs independently of completion order.
    ///
    /// Per-request failures land on [`ServeOutput::result`], never on
    /// this function's `Err` — that is reserved for deployment-level
    /// faults (a panicking compute job surfaces here as
    /// `Error::Compute`; the pool itself stays usable).
    pub fn run(&self, requests: Vec<ServeRequest>) -> Result<(Vec<ServeOutput>, ServeStats)> {
        self.run_with_drain(requests, |_| false)
    }

    /// [`run`](BatchScheduler::run) with a deterministic drain trigger
    /// for tests and benches: `drain_at(steps)` is polled at each
    /// iteration boundary (in addition to the [`drain`]
    /// (BatchScheduler::drain) latch) and starts a graceful drain the
    /// first time it returns true.  Draining changes **which** requests
    /// complete, never their bits: completed outputs are bitwise equal
    /// to the same requests' outputs in an un-drained run (per-row
    /// batch invariance — `resume_props` pins this).
    pub fn run_with_drain(
        &self,
        requests: Vec<ServeRequest>,
        drain_at: impl Fn(usize) -> bool,
    ) -> Result<(Vec<ServeOutput>, ServeStats)> {
        let d = self.engine.d();
        let start = std::time::Instant::now();
        let mut outputs = Vec::new();
        let mut stats = ServeStats::default();
        // intake: reject invalid requests (their own error domain —
        // they never touch the panel), then bound the queue
        let mut queue: std::collections::VecDeque<ServeRequest> = std::collections::VecDeque::new();
        let intake = |r: &ServeRequest, e: ServeError| ServeOutput {
            id: r.id,
            prompt_len: r.prompt_len(d),
            result: Err(e),
            admitted_at: 0,
            finished_at: 0,
        };
        for r in requests {
            if let Some(e) = self.validate(&r, d) {
                outputs.push(intake(&r, e));
                stats.failed += 1;
                continue;
            }
            if self.cfg.queue_cap > 0 && queue.len() >= self.cfg.queue_cap {
                match self.cfg.shed {
                    ShedPolicy::RejectNew => {
                        outputs.push(intake(&r, ServeError::Shed));
                        stats.shed += 1;
                        continue;
                    }
                    ShedPolicy::DropOldest => {
                        let old = queue.pop_front().expect("queue_cap > 0 and queue full");
                        outputs.push(intake(&old, ServeError::Shed));
                        stats.shed += 1;
                    }
                }
            }
            queue.push_back(r);
        }
        // one lock for the whole run; a previous run that died with an
        // Err left the arena consistent, and reset_all reclaims every
        // page regardless (sessions never outlive a run)
        let mut guard = self.ws.lock().unwrap_or_else(|p| p.into_inner());
        let ws = &mut *guard;
        ws.arena.reset_all();
        let mut active: Vec<Active<E::Session>> = Vec::new();
        let mut free_states: Vec<E::Session> = Vec::new();
        let mut adm_next: u64 = 0;
        let mut xs: Vec<f32> = Vec::new();
        let mut dec_out: Vec<f32> = Vec::new();
        let mut pre_out: Vec<f32> = Vec::new();
        let mut draining = false;
        while !queue.is_empty() || !active.is_empty() {
            // graceful drain: latch the request once, then stop
            // admitting and shed the entire waiting queue — in-flight
            // requests below keep stepping to completion (or their
            // deadline) untouched
            if !draining && (self.draining() || drain_at(stats.steps)) {
                draining = true;
                stats.drained = true;
            }
            if draining && !queue.is_empty() {
                for r in queue.drain(..) {
                    outputs.push(intake(&r, ServeError::Shed));
                    stats.shed += 1;
                }
            }
            if active.is_empty() && queue.is_empty() {
                break;
            }
            // admit into free slots, preserving arrival order; with
            // the prefix cache on, each arrival scans the actives
            // (including ones admitted just above, so groups arriving
            // together chain off their first member) for the longest
            // bitwise-shared prompt prefix
            while !draining && active.len() < self.cfg.max_batch {
                let Some(req) = queue.pop_front() else { break };
                let mut state = free_states.pop().unwrap_or_else(|| self.engine.new_session());
                self.engine.reset_session(&mut state, &mut ws.arena);
                let pending_fork =
                    if self.cfg.prefix_cache { self.find_donor(&active, &req, d) } else { None };
                active.push(Active {
                    state,
                    fed: 0,
                    generated: Vec::with_capacity(req.n_gen * d),
                    admitted_at: stats.steps,
                    adm: adm_next,
                    pending_fork,
                    req,
                });
                adm_next += 1;
            }
            stats.peak_batch = stats.peak_batch.max(active.len());
            // pack each GENERATING request's next input row (requests
            // still inside their prompt prefill below instead)
            xs.clear();
            let mut n_dec = 0usize;
            for a in &active {
                if a.fed >= a.req.prompt_len(d) {
                    let g = a.generated.len();
                    xs.extend_from_slice(&a.generated[g - d..g]);
                    n_dec += 1;
                }
            }
            dec_out.clear();
            if n_dec > 0 {
                let mut states: Vec<&mut E::Session> = active
                    .iter_mut()
                    .filter(|a| a.fed >= a.req.prompt_len(d))
                    .map(|a| &mut a.state)
                    .collect();
                let r = self.engine.decode_step(
                    &mut ws.arena,
                    &mut ws.scratch,
                    &mut states,
                    &xs,
                    &mut dec_out,
                );
                drop(states);
                r?;
            }
            stats.steps += 1;
            stats.tokens += n_dec;
            // hand out rows; retire finished requests and quarantine
            // faulty ones.  The decode panel row of the `gi`-th
            // generating request is `dec_out[gi*d..]` in the
            // PRE-retire active order, so the sweep drains the old vec
            // and rebuilds the survivor list — removing in place
            // (swap_remove) would silently remap later requests onto
            // the wrong rows.  Prefilling requests run their chunk
            // here, inside the sweep, so all retire paths share one
            // exit.
            let old = std::mem::take(&mut active);
            let mut gi = 0usize;
            for mut a in old {
                let plen = a.req.prompt_len(d);
                let finished = |a: &Active<E::Session>, result, steps: usize| ServeOutput {
                    id: a.req.id,
                    prompt_len: plen,
                    result,
                    admitted_at: a.admitted_at,
                    finished_at: steps,
                };
                let mut fork_wait = false;
                if a.fed < plen {
                    // resolve a deferred prefix fork first: once the
                    // donor (earlier in admission order, so already
                    // swept this iteration) has prefilled past the
                    // shared prefix, swap the follower's empty session
                    // for a CoW fork of the prefix pages and prefill
                    // only the tail.  A donor that retired forks
                    // nothing — plain prefill.
                    if let Some((donor_adm, share)) = a.pending_fork {
                        match active.iter().find(|o| o.adm == donor_adm) {
                            Some(donor) if donor.fed >= share => {
                                let fork =
                                    self.engine.fork_session(&donor.state, &mut ws.arena, share);
                                let mut empty = std::mem::replace(&mut a.state, fork);
                                self.engine.reset_session(&mut empty, &mut ws.arena);
                                free_states.push(empty);
                                a.fed = share;
                                a.pending_fork = None;
                                stats.prefix_hits += 1;
                                stats.shared_prefix_pages += E::session_pages(&a.state);
                            }
                            // donor still inside the shared prefix
                            // (small prefill_chunk): wait a sweep —
                            // the deadline below stays live
                            Some(_) => fork_wait = true,
                            None => a.pending_fork = None,
                        }
                    }
                }
                if fork_wait {
                    // no rows this iteration; falls through to the
                    // deadline check / survivor re-push below
                } else if a.fed < plen {
                    // chunked prefill: up to prefill_chunk prompt rows
                    // in one batched pass (0 = all remaining)
                    let left = plen - a.fed;
                    let take = match self.cfg.prefill_chunk {
                        0 => left,
                        c => c.min(left),
                    };
                    let chunk = &a.req.prompt[a.fed * d..(a.fed + take) * d];
                    self.engine.prefill(
                        &mut ws.arena,
                        &mut ws.scratch,
                        &mut a.state,
                        chunk,
                        take,
                        &mut pre_out,
                    )?;
                    a.fed += take;
                    stats.tokens += take;
                    if E::session_failed(&a.state) {
                        let pages = ws.arena.max_pages();
                        outputs.push(finished(
                            &a,
                            Err(ServeError::CacheExhausted { pages }),
                            stats.steps,
                        ));
                        stats.failed += 1;
                        self.engine.reset_session(&mut a.state, &mut ws.arena);
                        free_states.push(a.state);
                        continue;
                    }
                    if non_finite_at(&pre_out).is_some() {
                        outputs.push(finished(
                            &a,
                            Err(ServeError::NonFiniteOutput { step: stats.steps }),
                            stats.steps,
                        ));
                        stats.failed += 1;
                        self.engine.reset_session(&mut a.state, &mut ws.arena);
                        free_states.push(a.state);
                        continue;
                    }
                    if a.fed >= plen {
                        // the output at the last prompt position is
                        // the first generated vector; earlier prefill
                        // outputs are scored but not part of the
                        // response
                        a.generated.extend_from_slice(&pre_out[(take - 1) * d..take * d]);
                    }
                } else {
                    let row = &dec_out[gi * d..(gi + 1) * d];
                    gi += 1;
                    // a push that found the arena full means the row
                    // was computed without this token's cache entry:
                    // quarantine before anything feeds back
                    if E::session_failed(&a.state) {
                        let pages = ws.arena.max_pages();
                        outputs.push(finished(
                            &a,
                            Err(ServeError::CacheExhausted { pages }),
                            stats.steps,
                        ));
                        stats.failed += 1;
                        self.engine.reset_session(&mut a.state, &mut ws.arena);
                        free_states.push(a.state);
                        continue;
                    }
                    // quarantine a non-finite output immediately: the
                    // row never feeds back, and per-row kernel
                    // invariance means it never touched any other
                    // request's bits either
                    if non_finite_at(row).is_some() {
                        outputs.push(finished(
                            &a,
                            Err(ServeError::NonFiniteOutput { step: stats.steps }),
                            stats.steps,
                        ));
                        stats.failed += 1;
                        self.engine.reset_session(&mut a.state, &mut ws.arena);
                        free_states.push(a.state);
                        continue;
                    }
                    a.generated.extend_from_slice(row);
                }
                if a.generated.len() >= a.req.n_gen * d {
                    let panel = std::mem::take(&mut a.generated);
                    outputs.push(finished(&a, Ok(panel), stats.steps));
                    stats.completed += 1;
                    // release the request's pages immediately — a
                    // retired request must not hold arena budget
                    self.engine.reset_session(&mut a.state, &mut ws.arena);
                    free_states.push(a.state);
                } else if self.cfg.deadline_steps > 0
                    && stats.steps - a.admitted_at >= self.cfg.deadline_steps
                {
                    // unfinished at its deadline: quarantine (partial
                    // output is dropped — clients see an error, not a
                    // truncated panel silently posing as complete)
                    outputs.push(finished(
                        &a,
                        Err(ServeError::DeadlineExceeded { limit: self.cfg.deadline_steps }),
                        stats.steps,
                    ));
                    stats.failed += 1;
                    self.engine.reset_session(&mut a.state, &mut ws.arena);
                    free_states.push(a.state);
                } else {
                    active.push(a);
                }
            }
        }
        stats.pages_in_use = ws.arena.peak_pages();
        stats.resident_kv_bytes = ws.arena.peak_resident_bytes();
        stats.wallclock_s = start.elapsed().as_secs_f64();
        outputs.sort_by_key(|o| o.id);
        Ok((outputs, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BlockConfig, TransformerBlock};
    use crate::util::rng::Rng;

    fn tiny_serve_block(rng: &mut Rng) -> ServeBlock {
        let cfg = BlockConfig::standard(vec![2, 2], 2, 3);
        let mut block = TransformerBlock::init(&cfg, rng).unwrap();
        block.randomize_circuits(0.2, rng).unwrap();
        ServeBlock::merged(&block).unwrap()
    }

    fn mk_request(id: u64, d: usize, p_len: usize, n_gen: usize, rng: &mut Rng) -> ServeRequest {
        let mut prompt = vec![0.0f32; p_len * d];
        rng.fill_normal(&mut prompt, 1.0);
        ServeRequest { id, prompt, n_gen }
    }

    fn gen(o: &ServeOutput) -> Vec<f32> {
        o.generated().unwrap_or_else(|| panic!("request {} failed: {:?}", o.id, o.error())).to_vec()
    }

    #[test]
    fn scheduler_matches_single_request_decode() {
        // a request served alone equals the same request served in a
        // crowd (per-row batch invariance, the continuous-batching
        // correctness core)
        let mut rng = Rng::new(91);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let reqs: Vec<ServeRequest> = (0..5)
            .map(|i| mk_request(i, d, 1 + (i as usize % 3), 2 + (i as usize % 4), &mut rng))
            .collect();
        let solo = BatchScheduler::new(sb.clone(), 1).unwrap();
        let crowd = BatchScheduler::new(sb, 4).unwrap();
        let (solo_out, _) = solo.run(reqs.clone()).unwrap();
        let (crowd_out, stats) = crowd.run(reqs).unwrap();
        assert_eq!(solo_out.len(), crowd_out.len());
        for (a, b) in solo_out.iter().zip(&crowd_out) {
            assert_eq!(a.id, b.id);
            assert_eq!(gen(a), gen(b), "request {} diverged across batches", a.id);
        }
        assert!(stats.peak_batch > 1, "crowd run never actually batched");
        assert_eq!(stats.completed, 5);
        assert_eq!(stats.failed + stats.shed, 0);
        // prompt rows (prefilled) + decode rows, per request
        let want_tokens: usize = solo_out
            .iter()
            .map(|o| o.prompt_len + gen(o).len() / d - 1)
            .sum();
        assert_eq!(stats.tokens, want_tokens);
    }

    #[test]
    fn bad_requests_fail_alone_not_the_batch() {
        // one batch: malformed shapes, n_gen 0, a NaN prompt, and a
        // healthy request — the healthy one completes bitwise equal to
        // being served alone, each bad one carries its own error
        let mut rng = Rng::new(92);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let good = mk_request(9, d, 2, 3, &mut rng);
        let mut nan_prompt = mk_request(3, d, 2, 2, &mut rng);
        nan_prompt.prompt[d + 1] = f32::NAN;
        let batch = vec![
            ServeRequest { id: 0, prompt: vec![0.0; 3], n_gen: 1 },
            ServeRequest { id: 1, prompt: vec![], n_gen: 1 },
            ServeRequest { id: 2, prompt: vec![0.0; d], n_gen: 0 },
            nan_prompt,
            good.clone(),
        ];
        let sched = BatchScheduler::new(sb, 2).unwrap();
        let (out, stats) = sched.run(batch).unwrap();
        assert_eq!(out.len(), 5);
        assert!(matches!(out[0].error(), Some(ServeError::Rejected(_))));
        assert!(matches!(out[1].error(), Some(ServeError::Rejected(_))));
        assert!(matches!(out[2].error(), Some(ServeError::Rejected(_))));
        assert_eq!(out[3].error(), Some(&ServeError::NonFinitePrompt { at: d + 1 }));
        let (solo, _) = sched.run(vec![good]).unwrap();
        assert_eq!(out[4].result, solo[0].result, "healthy request perturbed by bad peers");
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.failed, 4);
        assert_eq!(stats.shed, 0);
        // config-level errors still fail construction / stay Ok-empty
        let mut rng2 = Rng::new(921);
        assert!(BatchScheduler::new(tiny_serve_block(&mut rng2), 0).is_err());
        let sched2 = BatchScheduler::new(tiny_serve_block(&mut rng2), 2).unwrap();
        let (out, stats) = sched2.run(vec![]).unwrap();
        assert!(out.is_empty());
        assert_eq!(stats.steps, 0);
    }

    #[test]
    fn deadline_and_budget_quarantine_individually() {
        let mut rng = Rng::new(93);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        // whole-prompt prefill: needs 1 + 8 - 1 = 8 resident steps;
        // deadline is 4
        let long = mk_request(0, d, 2, 8, &mut rng);
        // needs 1 + 2 - 1 = 2 steps; fits
        let short = mk_request(1, d, 2, 2, &mut rng);
        // 12 tokens > budget 10
        let fat = mk_request(2, d, 6, 6, &mut rng);
        let cfg = ServeConfig::default().with_max_batch(4).with_deadline(4).with_token_budget(10);
        let sched = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
        let (out, stats) = sched.run(vec![long, short.clone(), fat]).unwrap();
        assert_eq!(out[0].error(), Some(&ServeError::DeadlineExceeded { limit: 4 }));
        assert_eq!(out[0].steps_resident(), 4);
        assert_eq!(out[2].error(), Some(&ServeError::OverBudget { tokens: 12, budget: 10 }));
        let plain = BatchScheduler::new(sb, 4).unwrap();
        let (solo, _) = plain.run(vec![short]).unwrap();
        assert_eq!(out[1].result, solo[0].result, "survivor perturbed by quarantined peers");
        assert_eq!((stats.completed, stats.failed, stats.shed), (1, 2, 0));
    }

    #[test]
    fn page_budget_quarantines_the_exhausting_request_only() {
        // kv_pages 4 at 1 token/page: a 7-token request exhausts the
        // arena mid-decode and is quarantined; its pages free, and the
        // next request completes bitwise equal to running alone
        let mut rng = Rng::new(98);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let hog = mk_request(0, d, 2, 6, &mut rng); // wants 7 cached tokens
        let small = mk_request(1, d, 1, 2, &mut rng); // wants 2
        let cfg = ServeConfig::default()
            .with_max_batch(1)
            .with_kv_pages(4)
            .with_page_tokens(1);
        let sched = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
        let (out, stats) = sched.run(vec![hog, small.clone()]).unwrap();
        assert_eq!(out[0].error(), Some(&ServeError::CacheExhausted { pages: 4 }));
        let plain = BatchScheduler::new(sb, 1).unwrap();
        let (solo, _) = plain.run(vec![small]).unwrap();
        assert_eq!(out[1].result, solo[0].result, "survivor perturbed by the evicted hog");
        assert_eq!((stats.completed, stats.failed, stats.shed), (1, 1, 0));
        assert!(stats.pages_in_use <= 4, "budget was not enforced");
        assert_eq!(stats.resident_kv_bytes, stats.pages_in_use * d * 2 * 4);
    }

    #[test]
    fn bounded_queue_sheds_by_policy() {
        let mut rng = Rng::new(94);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let reqs: Vec<ServeRequest> = (0..5).map(|i| mk_request(i, d, 1, 2, &mut rng)).collect();
        for (shed, kept) in [
            (ShedPolicy::RejectNew, [0u64, 1]),
            (ShedPolicy::DropOldest, [3u64, 4]),
        ] {
            let cfg = ServeConfig::default()
                .with_max_batch(1)
                .with_queue_cap(2)
                .with_shed_policy(shed);
            let sched = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
            let (out, stats) = sched.run(reqs.clone()).unwrap();
            assert_eq!(stats.shed, 3, "{shed:?}");
            assert_eq!(stats.completed, 2, "{shed:?}");
            for o in &out {
                if kept.contains(&o.id) {
                    assert!(o.result.is_ok(), "{shed:?}: request {} should survive", o.id);
                } else {
                    assert_eq!(o.error(), Some(&ServeError::Shed), "{shed:?}: request {}", o.id);
                }
            }
        }
    }

    #[test]
    fn drain_sheds_queue_and_finishes_in_flight_bitwise() {
        // 6 requests through 2 slots, drain after 2 steps: the
        // admitted requests finish with bits equal to the un-drained
        // run; those still queued are shed
        let mut rng = Rng::new(96);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let reqs: Vec<ServeRequest> = (0..6).map(|i| mk_request(i, d, 2, 3, &mut rng)).collect();
        let sched = BatchScheduler::new(sb, 2).unwrap();
        let (full, _) = sched.run(reqs.clone()).unwrap();
        let (out, stats) = sched.run_with_drain(reqs.clone(), |steps| steps >= 2).unwrap();
        assert!(stats.drained);
        assert_eq!((stats.completed, stats.shed, stats.failed), (2, 4, 0));
        for o in &out {
            match o.id {
                0 | 1 => {
                    let twin = full.iter().find(|f| f.id == o.id).unwrap();
                    assert_eq!(o.result, twin.result, "drained output {} drifted", o.id);
                }
                _ => assert_eq!(o.error(), Some(&ServeError::Shed), "request {}", o.id),
            }
        }
        // deadlines still apply to in-flight requests during a drain
        let mut rng2 = Rng::new(961);
        let sb2 = tiny_serve_block(&mut rng2);
        let d2 = sb2.d();
        let long = mk_request(0, d2, 2, 8, &mut rng2); // needs 8 resident steps
        let cfg = ServeConfig::default().with_max_batch(1).with_deadline(4);
        let sched2 = BatchScheduler::with_config(sb2, cfg).unwrap();
        let (out2, st2) = sched2.run_with_drain(vec![long], |steps| steps >= 1).unwrap();
        assert!(st2.drained);
        assert_eq!(out2[0].error(), Some(&ServeError::DeadlineExceeded { limit: 4 }));
    }

    #[test]
    fn drain_latch_stops_a_run_before_any_step() {
        // the external drain() latch (the signal-handler path) observed
        // at the first iteration boundary: everything queued is shed,
        // nothing is ever admitted
        let mut rng = Rng::new(97);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let reqs: Vec<ServeRequest> = (0..4).map(|i| mk_request(i, d, 1, 2, &mut rng)).collect();
        let sched = BatchScheduler::new(sb, 2).unwrap();
        assert!(!sched.draining());
        sched.drain();
        sched.drain(); // idempotent
        assert!(sched.draining());
        let (out, stats) = sched.run(reqs).unwrap();
        assert_eq!(stats.steps, 0);
        assert!(stats.drained);
        assert_eq!(stats.shed, 4);
        assert!(out.iter().all(|o| o.error() == Some(&ServeError::Shed)));
    }

    #[test]
    fn latency_accounting_is_consistent() {
        let mut rng = Rng::new(95);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let reqs: Vec<ServeRequest> = (0..6).map(|i| mk_request(i, d, 2, 3, &mut rng)).collect();
        let sched = BatchScheduler::new(sb, 2).unwrap();
        let (out, stats) = sched.run(reqs).unwrap();
        for o in &out {
            // whole-prompt prefill (1 step) + n_gen - 1 decode steps
            assert_eq!(o.steps_resident(), 1 + 3 - 1, "request {}", o.id);
            assert_eq!(gen(o).len(), 3 * d);
        }
        // with max_batch 2 and 6 identical 3-step requests: 9 steps
        assert_eq!(stats.steps, 9);
        // tokens still count every processed row: 6 × (2 + 3 - 1)
        assert_eq!(stats.tokens, 24);
        assert_eq!(stats.peak_batch, 2);
        assert_eq!(stats.completed, 6);
        // the paged gauges are live: 2 slots × 5 tokens peak, and the
        // arena reports bytes consistently
        assert!(stats.pages_in_use > 0);
        assert_eq!(
            stats.resident_kv_bytes,
            stats.pages_in_use * sched.config().page_tokens * d * 2 * 4
        );
    }

    #[test]
    fn prefill_chunk_changes_wallclock_shape_not_bits() {
        // prefill_chunk 0 (whole prompt), 1 (row-at-a-time, the
        // pre-paging schedule), and 3 must produce identical bits for
        // every request — only step accounting may differ
        let mut rng = Rng::new(99);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let reqs: Vec<ServeRequest> =
            (0..4).map(|i| mk_request(i, d, 1 + i as usize * 2, 3, &mut rng)).collect();
        let base = BatchScheduler::with_config(
            sb.clone(),
            ServeConfig::default().with_max_batch(2).with_prefill_chunk(1),
        )
        .unwrap();
        let (base_out, base_stats) = base.run(reqs.clone()).unwrap();
        for chunk in [0usize, 3] {
            let cfg = ServeConfig::default().with_max_batch(2).with_prefill_chunk(chunk);
            let sched = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
            let (out, stats) = sched.run(reqs.clone()).unwrap();
            for (a, b) in base_out.iter().zip(&out) {
                assert_eq!(a.result, b.result, "prefill_chunk {chunk} changed request {}", a.id);
            }
            assert_eq!(stats.tokens, base_stats.tokens, "rows processed must not change");
            assert!(
                stats.steps <= base_stats.steps,
                "chunked prefill must not take more iterations than row-at-a-time"
            );
        }
    }

    #[test]
    fn prefix_cache_forks_instead_of_reprefilling() {
        // 4 requests sharing a 4-row prompt prefix (2 whole pages at
        // page_tokens 2) with unique 2-row tails: the followers must
        // fork the donor's prefix pages, skip the shared prefill rows,
        // and still produce bitwise the plain-run outputs
        let mut rng = Rng::new(101);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let mut shared = vec![0.0f32; 4 * d];
        rng.fill_normal(&mut shared, 1.0);
        let reqs: Vec<ServeRequest> = (0..4)
            .map(|i| {
                let mut prompt = shared.clone();
                let mut tail = vec![0.0f32; 2 * d];
                rng.fill_normal(&mut tail, 1.0);
                prompt.extend_from_slice(&tail);
                ServeRequest { id: i, prompt, n_gen: 3 }
            })
            .collect();
        let cfg = ServeConfig::default().with_max_batch(4).with_page_tokens(2);
        let plain = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
        let (base, base_stats) = plain.run(reqs.clone()).unwrap();
        assert_eq!(base_stats.prefix_hits, 0);
        let sched = BatchScheduler::with_config(sb, cfg.with_prefix_cache(true)).unwrap();
        let (out, stats) = sched.run(reqs).unwrap();
        assert_eq!(stats.prefix_hits, 3, "every follower must fork, not re-prefill");
        assert_eq!(stats.shared_prefix_pages, 3 * 2, "each fork maps the 2 shared pages");
        for (a, b) in base.iter().zip(&out) {
            assert_eq!(a.result, b.result, "prefix cache changed request {} bits", a.id);
        }
        assert!(
            stats.pages_in_use < base_stats.pages_in_use,
            "sharing must lower the resident-page peak ({} vs {})",
            stats.pages_in_use,
            base_stats.pages_in_use
        );
        // the 4 shared prompt rows are skipped by each of 3 followers
        assert_eq!(base_stats.tokens - stats.tokens, 3 * 4);
        assert_eq!((stats.completed, stats.failed, stats.shed), (4, 0, 0));
    }

    #[test]
    fn prefix_cache_waits_for_chunked_donors_and_survives_retires() {
        // regime 1 — prefill_chunk 1: the donor crosses the shared
        // 2-row prefix one row per sweep, so the follower must wait a
        // sweep before its fork resolves (fed 1 < share 2 at the first
        // sweep, fork at the second)
        let mut rng = Rng::new(102);
        let sb = tiny_serve_block(&mut rng);
        let d = sb.d();
        let mut shared = vec![0.0f32; 2 * d];
        rng.fill_normal(&mut shared, 1.0);
        let mk = |shared: &[f32], id: u64, n_gen: usize, rng: &mut Rng| {
            let mut prompt = shared.to_vec();
            let mut tail = vec![0.0f32; d];
            rng.fill_normal(&mut tail, 1.0);
            prompt.extend_from_slice(&tail);
            ServeRequest { id, prompt, n_gen }
        };
        let reqs = vec![mk(&shared, 0, 4, &mut rng), mk(&shared, 1, 4, &mut rng)];
        let cfg = ServeConfig::default()
            .with_max_batch(2)
            .with_page_tokens(1)
            .with_prefill_chunk(1);
        let plain = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
        let (base, _) = plain.run(reqs.clone()).unwrap();
        let sched = BatchScheduler::with_config(sb.clone(), cfg.with_prefix_cache(true)).unwrap();
        let (out, stats) = sched.run(reqs).unwrap();
        for (a, b) in base.iter().zip(&out) {
            assert_eq!(a.result, b.result, "request {} drifted under chunked forks", a.id);
        }
        assert_eq!(stats.prefix_hits, 1, "the follower must fork after waiting");
        assert_eq!((stats.completed, stats.failed), (2, 0));

        // regime 2 — the donor retires in the very sweep its follower
        // was admitted (before the follower is processed): the pending
        // fork must clear and fall back to a plain prefill.  Request 0
        // shares nothing and just occupies the second slot; donor 1
        // (n_gen 2) finishes its last decode row in the sweep that
        // admits follower 2.
        let mut rng2 = Rng::new(103);
        let mut other = vec![0.0f32; 3 * d];
        rng2.fill_normal(&mut other, 1.0);
        let occupier = ServeRequest { id: 0, prompt: other, n_gen: 1 };
        let reqs2 =
            vec![occupier, mk(&shared, 1, 2, &mut rng2), mk(&shared, 2, 2, &mut rng2)];
        let plain2 = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
        let (base2, _) = plain2.run(reqs2.clone()).unwrap();
        let sched2 = BatchScheduler::with_config(sb, cfg.with_prefix_cache(true)).unwrap();
        let (out2, stats2) = sched2.run(reqs2).unwrap();
        for (a, b) in base2.iter().zip(&out2) {
            assert_eq!(a.result, b.result, "request {} drifted after its donor retired", a.id);
        }
        assert_eq!(stats2.prefix_hits, 0, "retired donor must not be forked");
        assert_eq!((stats2.completed, stats2.failed, stats2.shed), (3, 0, 0));
    }
}
