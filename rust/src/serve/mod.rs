//! Serving subsystem (DESIGN.md §10, §14): paged-KV incremental decode
//! for trained transformer blocks, and continuous batching over many
//! concurrent requests under a bounded cache budget.
//!
//! The train→merge→serve pipeline: `quanta-ft train-block` fine-tunes
//! the per-projection circuits, `AdapterSet::merge_all()` folds them
//! into dense weights (the paper's zero-inference-overhead claim), and
//! this layer serves the merged block — [`ServeBlock`] snapshots the
//! deployment (merged GEMM fast path, or the streaming-adapter
//! reference it is pinned against), [`DecodeState`] maps a request's
//! K/V history through a [`PageTable`] into the one process-wide
//! [`KvArena`] of fixed-size pages, and [`BatchScheduler`] packs
//! ragged concurrent requests into pooled panel matmuls with
//! admit/retire between steps, prompt admission running as chunked
//! prefill.  Resident cache memory is bounded by tokens in flight
//! (`--kv-pages` makes the bound hard), and [`KvArena::fork`] shares
//! prefix pages copy-on-write.  With `--prefix-cache` the scheduler
//! drives that seam itself (DESIGN.md §15): requests whose prompts
//! share a page-aligned prefix with a resident request are admitted by
//! CoW-forking the donor's prefix pages instead of re-prefilling them,
//! and attention runs as one K-cache-major batched kernel
//! (`decode::batched_attn`) that is bitwise equal to the serial
//! reference at any batch shape, page size, or thread count.
//!
//! Requests are individually fault-isolated (DESIGN.md §11): each
//! [`ServeOutput`] carries success-or-[`ServeError`], lifecycle limits
//! (step deadline, token budget, bounded intake queue with a
//! [`ShedPolicy`], KV page budget) live on [`ServeConfig`], and
//! healthy requests' outputs stay bitwise identical to a run without
//! the faulty ones — cache exhaustion included.
//!
//! Depth-N deployments go through the same machinery: [`ServeModel`]
//! stacks per-layer [`ServeBlock`]s, [`SessionState`] bundles the
//! per-layer caches behind one request slot (all paging out of the
//! same arena), and [`BatchScheduler`] is generic over the small
//! [`DecodeEngine`] trait both deployments implement — the scheduler
//! loop, error domains, deadlines, and shed policies are depth-blind.
//!
//! Exposed on the CLI as `quanta-ft serve` (`--layers N` for deep
//! stacks; `--kv-pages`, `--page-size`, `--prefill-chunk` for the
//! cache budget; `--prefix-cache` for shared-prefix admission);
//! properties (decode ≡ full-recompute per position,
//! merged ≡ streaming at 1e-5, paged ≡ contiguous bitwise at every
//! page size, scheduler invariance under arrival order / `QFT_THREADS`
//! / dispatch mode, per-request isolation of mixed batches) live in
//! `rust/tests/serve_props.rs`, `rust/tests/kv_props.rs` and, at depth
//! N, `rust/tests/deep_props.rs`.

pub mod decode;
pub mod kv;
pub mod model;
pub mod scheduler;

pub use decode::{DecodeScratch, DecodeState, ServeBlock};
pub use kv::{default_page_tokens, CacheFull, KvArena, PageTable};
pub use model::{DecodeEngine, ServeModel, SessionState};
pub use scheduler::{
    BatchScheduler, ServeConfig, ServeError, ServeOutput, ServeRequest, ServeStats, ShedPolicy,
};
