//! Depth-N serving: a stack of per-layer [`ServeBlock`]s behind one
//! request slot, and the [`DecodeEngine`] trait that lets the
//! continuous-batching scheduler drive a single block or a deep stack
//! through the same loop.
//!
//! ## One session, N caches, one arena
//!
//! Each layer of a deep model attends over *its own* history — layer
//! `l`'s K/V rows are projections of layer `l−1`'s outputs — so a
//! request against a depth-N model needs N per-layer [`DecodeState`]s.
//! [`SessionState`] bundles them behind the single slot the scheduler
//! manages: admit/retire/recycle logic never learns about depth.  All
//! N page tables draw from the **same** [`KvArena`] (the scheduler
//! owns exactly one), so the page budget bounds total resident cache
//! across layers and requests at once.
//!
//! ## The engine trait
//!
//! [`BatchScheduler`](crate::serve::BatchScheduler) needs a handful of
//! things from whatever it drives: the activation width
//! ([`DecodeEngine::d`]), a batched one-token step
//! ([`DecodeEngine::decode_step`]), a chunked prompt admission pass
//! ([`DecodeEngine::prefill`]), whether the deployment runs merged
//! weights ([`DecodeEngine::is_merged`]) — plus session construction /
//! recycling and the cache-exhaustion flag
//! ([`DecodeEngine::session_failed`]).  [`ServeBlock`] (session = one
//! [`DecodeState`]) and [`ServeModel`] (session = one
//! [`SessionState`]) both implement it, so the PR 6 error domains,
//! deadlines, token budgets, and shed policies apply to depth-N
//! serving verbatim — same code, not same-shaped code.
//!
//! ## Parity contract, lifted
//!
//! [`ServeModel::decode_step`] is layer `0..N` of
//! [`ServeBlock::decode_step`] chained, and the deep full-recompute
//! forward ([`DeepModel::forward`]) is the per-layer block forward
//! chained, so the PR 5 bitwise decode-parity argument applies per
//! layer: streaming deep decode ≡ deep forward recompute **bitwise**,
//! and merged ≡ streaming at the usual 1e-5×scale
//! (`rust/tests/deep_props.rs`).  [`ServeModel::prefill`] is the
//! per-layer chunked prefill chained the same way.

use crate::model::DeepModel;
use crate::serve::decode::{DecodeScratch, DecodeState, ServeBlock};
use crate::serve::kv::KvArena;
use crate::util::error::{Error, Result};

/// What the continuous-batching scheduler needs from a deployment.
/// One session holds everything a single request slot must keep
/// between steps (page tables at every layer); the engine itself is
/// immutable and shared by all slots, and all K/V storage lives in
/// the caller's [`KvArena`].
pub trait DecodeEngine {
    /// Per-request state behind one scheduler slot.
    type Session;

    /// Activation width of the request rows.
    fn d(&self) -> usize;

    /// True when every projection at every layer runs merged dense
    /// weights (the zero-inference-overhead deployment).
    fn is_merged(&self) -> bool;

    /// Fresh empty session for a new slot.
    fn new_session(&self) -> Self::Session;

    /// Forget a session's history, returning its pages to `arena`
    /// (slot recycling — see [`DecodeState::reset`]).
    fn reset_session(&self, s: &mut Self::Session, arena: &mut KvArena);

    /// Whether any of the session's K/V pushes failed on arena
    /// exhaustion — the scheduler quarantines such a request with
    /// `ServeError::CacheExhausted`.
    fn session_failed(s: &Self::Session) -> bool;

    /// CoW-fork the first `tokens` cached positions of `donor` into a
    /// fresh session — the prefix-cache admission seam (and the hook
    /// beam/speculative decode rides): the pages covering the prefix
    /// are shared by refcount at every layer, zero rows are copied,
    /// and the child prefills its own continuation from position
    /// `tokens`.  `tokens` must not exceed the donor's cached length.
    fn fork_session(
        &self,
        donor: &Self::Session,
        arena: &mut KvArena,
        tokens: usize,
    ) -> Self::Session;

    /// Arena pages the session currently maps, summed over all layers
    /// — shared (forked) pages count once per mapping session, which
    /// is what the scheduler's shared-pages stat wants to expose.
    fn session_pages(s: &Self::Session) -> usize;

    /// Decode one new token for each of `sessions.len()` concurrent
    /// requests; `xs` is the row-major `[requests, d]` panel of new
    /// inputs, and `out` is reset to the panel of each request's
    /// output at its new position.
    fn decode_step(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        sessions: &mut [&mut Self::Session],
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()>;

    /// Process `rows` consecutive prompt positions of **one** request
    /// in a single batched pass; `out` is reset to the `[rows, d]`
    /// output panel (the last row is the request's first generated
    /// vector when the prompt ends here).  Bitwise equal to feeding
    /// the rows one at a time through
    /// [`decode_step`](DecodeEngine::decode_step).
    fn prefill(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        session: &mut Self::Session,
        xs: &[f32],
        rows: usize,
        out: &mut Vec<f32>,
    ) -> Result<()>;
}

impl DecodeEngine for ServeBlock {
    type Session = DecodeState;

    fn d(&self) -> usize {
        ServeBlock::d(self)
    }

    fn is_merged(&self) -> bool {
        ServeBlock::is_merged(self)
    }

    fn new_session(&self) -> DecodeState {
        DecodeState::new(ServeBlock::d(self))
    }

    fn reset_session(&self, s: &mut DecodeState, arena: &mut KvArena) {
        s.reset(arena);
    }

    fn session_failed(s: &DecodeState) -> bool {
        s.failed()
    }

    fn fork_session(&self, donor: &DecodeState, arena: &mut KvArena, tokens: usize) -> DecodeState {
        donor.fork_prefix(arena, tokens)
    }

    fn session_pages(s: &DecodeState) -> usize {
        s.n_pages()
    }

    fn decode_step(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        sessions: &mut [&mut DecodeState],
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        ServeBlock::decode_step(self, arena, scratch, sessions, xs, out)
    }

    fn prefill(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        session: &mut DecodeState,
        xs: &[f32],
        rows: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        ServeBlock::prefill(self, arena, scratch, session, xs, rows, out)
    }
}

/// Per-request state for a depth-N deployment: one [`DecodeState`]
/// per layer behind a single scheduler slot, all paging out of one
/// shared arena.
#[derive(Clone, Debug)]
pub struct SessionState {
    layers: Vec<DecodeState>,
}

impl SessionState {
    /// Empty session for a depth-`depth`, width-`d` model.
    pub fn new(d: usize, depth: usize) -> SessionState {
        SessionState { layers: (0..depth).map(|_| DecodeState::new(d)).collect() }
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Positions cached so far (every layer advances in lockstep).
    pub fn len(&self) -> usize {
        self.layers[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether any layer hit arena exhaustion mid-push.
    pub fn failed(&self) -> bool {
        self.layers.iter().any(|s| s.failed())
    }

    /// Forget every layer's cache, returning all pages to `arena`.
    pub fn reset(&mut self, arena: &mut KvArena) {
        for s in &mut self.layers {
            s.reset(arena);
        }
    }

    /// Copy-on-write fork across every layer — see
    /// [`DecodeState::fork`].
    pub fn fork(&self, arena: &mut KvArena) -> SessionState {
        SessionState { layers: self.layers.iter().map(|s| s.fork(arena)).collect() }
    }

    /// CoW fork of the first `tokens` positions at every layer — see
    /// [`DecodeState::fork_prefix`].  Layer caches advance in
    /// lockstep, so one token count covers the whole stack.
    pub fn fork_prefix(&self, arena: &mut KvArena, tokens: usize) -> SessionState {
        SessionState {
            layers: self.layers.iter().map(|s| s.fork_prefix(arena, tokens)).collect(),
        }
    }

    /// Arena pages mapped across every layer.
    pub fn n_pages(&self) -> usize {
        self.layers.iter().map(|s| s.n_pages()).sum()
    }

    pub(crate) fn layer_mut(&mut self, l: usize) -> &mut DecodeState {
        &mut self.layers[l]
    }

    #[cfg(test)]
    pub(crate) fn layer(&self, l: usize) -> &DecodeState {
        &self.layers[l]
    }
}

/// Immutable depth-N serving snapshot: one [`ServeBlock`] per layer,
/// all merged or all streaming.  Built once per deployment from a
/// trained [`DeepModel`]; per-request state lives in [`SessionState`].
#[derive(Clone, Debug)]
pub struct ServeModel {
    blocks: Vec<ServeBlock>,
}

impl ServeModel {
    /// Zero-overhead deployment: every layer's projections folded to
    /// dense matrices — the decode hot loop is pure GEMM at every
    /// depth.
    pub fn merged(model: &DeepModel) -> Result<ServeModel> {
        let blocks =
            model.layers().iter().map(ServeBlock::merged).collect::<Result<Vec<_>>>()?;
        Ok(ServeModel { blocks })
    }

    /// Streaming deployment: every layer keeps its live adapters — the
    /// parity reference for the merged stack.
    pub fn streaming(model: &DeepModel) -> ServeModel {
        ServeModel { blocks: model.layers().iter().map(ServeBlock::streaming).collect() }
    }

    pub fn depth(&self) -> usize {
        self.blocks.len()
    }

    pub fn d(&self) -> usize {
        self.blocks[0].d()
    }

    /// True when every layer runs merged dense weights.
    pub fn is_merged(&self) -> bool {
        self.blocks.iter().all(|b| b.is_merged())
    }

    fn check_sessions(&self, sessions: &[&mut SessionState]) -> Result<()> {
        for (i, s) in sessions.iter().enumerate() {
            if s.depth() != self.depth() {
                return Err(Error::Shape(format!(
                    "deep decode_step: session {i} has depth {}, model has {}",
                    s.depth(),
                    self.depth()
                )));
            }
        }
        Ok(())
    }

    /// Decode one new token for each concurrent request through the
    /// whole stack: layer `l`'s [`ServeBlock::decode_step`] consumes
    /// layer `l−1`'s output panel, and each request's session advances
    /// one position at every layer.  A session that exhausts the arena
    /// at layer `l` is flagged and skipped by every later layer (its
    /// states stop advancing); other sessions are bitwise unaffected.
    pub fn decode_step(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        sessions: &mut [&mut SessionState],
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.check_sessions(sessions)?;
        let depth = self.depth();
        scratch.chain.clear();
        scratch.chain.extend_from_slice(xs);
        for (l, blk) in self.blocks.iter().enumerate() {
            let input = std::mem::take(&mut scratch.chain);
            let r = {
                let mut layer_states: Vec<&mut DecodeState> =
                    sessions.iter_mut().map(|s| s.layer_mut(l)).collect();
                blk.decode_step(arena, scratch, &mut layer_states, &input, out)
            };
            scratch.chain = input;
            r?;
            // a layer-l exhaustion must stop the deeper layers too, or
            // the session's caches fall out of lockstep and leak pages
            for s in sessions.iter_mut() {
                if s.layers[l].failed() {
                    for deeper in &mut s.layers[l + 1..] {
                        deeper.failed = true;
                    }
                }
            }
            if l + 1 < depth {
                std::mem::swap(&mut scratch.chain, out);
            }
        }
        Ok(())
    }

    /// Chunked prompt prefill through the whole stack for one
    /// request: layer `l`'s [`ServeBlock::prefill`] consumes layer
    /// `l−1`'s chunk output panel.  Bitwise equal to row-at-a-time
    /// deep decode of the same rows, by the per-layer argument.
    pub fn prefill(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        session: &mut SessionState,
        xs: &[f32],
        rows: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if session.depth() != self.depth() {
            return Err(Error::Shape(format!(
                "deep prefill: session has depth {}, model has {}",
                session.depth(),
                self.depth()
            )));
        }
        let depth = self.depth();
        scratch.chain.clear();
        scratch.chain.extend_from_slice(xs);
        for (l, blk) in self.blocks.iter().enumerate() {
            let input = std::mem::take(&mut scratch.chain);
            let r = blk.prefill(arena, scratch, session.layer_mut(l), &input, rows, out);
            scratch.chain = input;
            r?;
            if session.layers[l].failed() {
                for deeper in &mut session.layers[l + 1..] {
                    deeper.failed = true;
                }
                return Ok(());
            }
            if l + 1 < depth {
                std::mem::swap(&mut scratch.chain, out);
            }
        }
        Ok(())
    }

    /// Decode a whole teacher-forced sequence for one request — the
    /// incremental counterpart of [`DeepModel::forward`]`(xs, 1, seq)`,
    /// pinned against it per position by `rust/tests/deep_props.rs`.
    /// Builds its own unbounded arena and scratch.
    pub fn decode_sequence(&self, xs: &[f32], seq: usize) -> Result<Vec<f32>> {
        let d = self.d();
        if seq == 0 || xs.len() != seq * d {
            return Err(Error::Shape(format!(
                "deep decode_sequence: xs len {} != seq {seq} * d {d}",
                xs.len()
            )));
        }
        let mut arena = KvArena::unbounded(d);
        let mut scratch = DecodeScratch::new();
        let mut session = SessionState::new(d, self.depth());
        let mut out = Vec::with_capacity(seq * d);
        let mut step = Vec::new();
        for t in 0..seq {
            self.decode_step(
                &mut arena,
                &mut scratch,
                &mut [&mut session],
                &xs[t * d..(t + 1) * d],
                &mut step,
            )?;
            out.extend_from_slice(&step);
        }
        Ok(out)
    }
}

impl DecodeEngine for ServeModel {
    type Session = SessionState;

    fn d(&self) -> usize {
        ServeModel::d(self)
    }

    fn is_merged(&self) -> bool {
        ServeModel::is_merged(self)
    }

    fn new_session(&self) -> SessionState {
        SessionState::new(ServeModel::d(self), self.depth())
    }

    fn reset_session(&self, s: &mut SessionState, arena: &mut KvArena) {
        s.reset(arena);
    }

    fn session_failed(s: &SessionState) -> bool {
        s.failed()
    }

    fn fork_session(
        &self,
        donor: &SessionState,
        arena: &mut KvArena,
        tokens: usize,
    ) -> SessionState {
        donor.fork_prefix(arena, tokens)
    }

    fn session_pages(s: &SessionState) -> usize {
        s.n_pages()
    }

    fn decode_step(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        sessions: &mut [&mut SessionState],
        xs: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        ServeModel::decode_step(self, arena, scratch, sessions, xs, out)
    }

    fn prefill(
        &self,
        arena: &mut KvArena,
        scratch: &mut DecodeScratch,
        session: &mut SessionState,
        xs: &[f32],
        rows: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        ServeModel::prefill(self, arena, scratch, session, xs, rows, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DeepConfig, DeepModel};

    fn tiny_deep(depth: usize, seed: u64) -> DeepModel {
        let mut m = DeepModel::init(&DeepConfig::standard(vec![2, 2], 2, 3, depth), seed).unwrap();
        m.randomize_circuits(0.2, seed).unwrap();
        m
    }

    #[test]
    fn depth_one_stack_decodes_like_the_bare_block() {
        let model = tiny_deep(1, 60);
        let sm = ServeModel::merged(&model).unwrap();
        let sb = ServeBlock::merged(model.layer(0)).unwrap();
        assert!(sm.is_merged());
        let mut rng = crate::util::rng::Rng::new(601);
        let mut xs = vec![0.0f32; 5 * model.d()];
        rng.fill_normal(&mut xs, 1.0);
        assert_eq!(
            sm.decode_sequence(&xs, 5).unwrap(),
            sb.decode_sequence(&xs, 5).unwrap(),
            "depth-1 ServeModel must be bitwise the ServeBlock path"
        );
    }

    #[test]
    fn sessions_advance_every_layer_and_shape_errors_surface() {
        let model = tiny_deep(3, 61);
        let sm = ServeModel::streaming(&model);
        assert!(!sm.is_merged());
        assert_eq!(sm.depth(), 3);
        let d = sm.d();
        let mut arena = KvArena::unbounded(d);
        let mut scratch = DecodeScratch::new();
        let mut out = Vec::new();
        let mut session = sm.new_session();
        assert!(session.is_empty());
        for t in 0..4 {
            let xs = vec![0.1 * (t as f32 + 1.0); d];
            sm.decode_step(&mut arena, &mut scratch, &mut [&mut session], &xs, &mut out).unwrap();
        }
        assert_eq!(session.len(), 4);
        for l in 0..3 {
            assert_eq!(session.layer(l).len(), 4, "layer {l} cache out of lockstep");
        }
        sm.reset_session(&mut session, &mut arena);
        assert!(session.is_empty());
        assert_eq!(arena.pages_in_use(), 0, "reset must return every layer's pages");
        // depth-mismatched session and bad panel shapes are rejected
        let mut shallow = SessionState::new(d, 2);
        let row = vec![0.0f32; d];
        assert!(sm
            .decode_step(&mut arena, &mut scratch, &mut [&mut shallow], &row, &mut out)
            .is_err());
        let mut ok = sm.new_session();
        assert!(sm
            .decode_step(&mut arena, &mut scratch, &mut [&mut ok], &[0.0; 3], &mut out)
            .is_err());
        assert!(sm.decode_sequence(&[0.0; 4], 0).is_err());
    }

    #[test]
    fn deep_prefill_matches_row_at_a_time_bitwise() {
        let model = tiny_deep(2, 62);
        let sm = ServeModel::streaming(&model);
        let d = sm.d();
        let mut rng = crate::util::rng::Rng::new(621);
        let seq = 6;
        let mut xs = vec![0.0f32; seq * d];
        rng.fill_normal(&mut xs, 1.0);
        // reference: one row per decode_step
        let reference = sm.decode_sequence(&xs, seq).unwrap();
        // chunked: whole prompt in one prefill
        let mut arena = KvArena::new(d, 4, 0).unwrap();
        let mut scratch = DecodeScratch::new();
        let mut session = sm.new_session();
        let mut out = Vec::new();
        sm.prefill(&mut arena, &mut scratch, &mut session, &xs, seq, &mut out).unwrap();
        assert_eq!(out, reference, "chunked deep prefill must be bitwise row-at-a-time");
        assert_eq!(session.len(), seq);
    }
}
