//! Subspace similarity (paper Eq. A.1):
//!
//!   phi(r1, r2, i, j) = || V1[:, :i]^T V2[:, :j] ||_F^2 / min(i, j)
//!
//! where `V1`/`V2` are the right singular vectors of two weight updates.
//! This is the paper's "intrinsic rank" probe (Fig. 2, A.1, A.2): phi
//! stays high across the grid for high-intrinsic-rank tasks (DROP) and
//! decays immediately for low-rank tasks (RTE).

use crate::linalg::svd::Svd;
use crate::tensor::Tensor;
use crate::util::error::Result;

/// phi for a single (i, j): first `i` columns of v1 vs first `j` of v2.
/// `v1`, `v2` are (n x k) matrices of right singular vectors (columns
/// ordered by descending singular value).
pub fn subspace_similarity(v1: &Tensor, v2: &Tensor, i: usize, j: usize) -> f64 {
    assert!(i >= 1 && j >= 1);
    assert_eq!(v1.shape[0], v2.shape[0]);
    let n = v1.shape[0];
    let (k1, k2) = (v1.shape[1], v2.shape[1]);
    assert!(i <= k1 && j <= k2);
    // ||V1_i^T V2_j||_F^2 = sum_{a<i, b<j} (v1_a . v2_b)^2
    let mut acc = 0.0f64;
    for a in 0..i {
        for b in 0..j {
            let mut dot = 0.0f64;
            for r in 0..n {
                dot += v1.data[r * k1 + a] as f64 * v2.data[r * k2 + b] as f64;
            }
            acc += dot * dot;
        }
    }
    acc / i.min(j) as f64
}

/// Full phi(i, j) grid (1-based i, j up to k1/k2) between the right
/// singular subspaces of two weight-update matrices.  Returns
/// `(grid[k1][k2], k1, k2)` where grid[i-1][j-1] = phi(i, j).
///
/// Computed incrementally: phi numerator at (i, j) is a 2D prefix sum of
/// squared dot products, so the full grid costs one `k1 x k2` Gram
/// matrix rather than `k1*k2` Frobenius norms.
pub fn subspace_similarity_grid(
    dw1: &Tensor,
    dw2: &Tensor,
    k1: usize,
    k2: usize,
) -> Result<Vec<Vec<f64>>> {
    let svd1 = Svd::compute(dw1)?;
    let svd2 = Svd::compute(dw2)?;
    let k1 = k1.min(svd1.v.shape[1]);
    let k2 = k2.min(svd2.v.shape[1]);
    let n = svd1.v.shape[0];
    let (c1, c2) = (svd1.v.shape[1], svd2.v.shape[1]);
    // Pre-transpose the leading singular directions into contiguous f64
    // rows (`vXt[a*n + r] = VX[r, a]`): the k1·k2 Gram dots then stream
    // two contiguous buffers instead of striding the (n, k) tensors by
    // k per element.  Accumulation stays f64 over ascending r, matching
    // `subspace_similarity` bit-for-bit.
    let mut v1t = vec![0.0f64; k1 * n];
    for a in 0..k1 {
        let row = &mut v1t[a * n..(a + 1) * n];
        for (r, slot) in row.iter_mut().enumerate() {
            *slot = svd1.v.data[r * c1 + a] as f64;
        }
    }
    let mut v2t = vec![0.0f64; k2 * n];
    for b in 0..k2 {
        let row = &mut v2t[b * n..(b + 1) * n];
        for (r, slot) in row.iter_mut().enumerate() {
            *slot = svd2.v.data[r * c2 + b] as f64;
        }
    }
    // gram[a][b] = (v1_a . v2_b)^2
    let mut gram = vec![vec![0.0f64; k2]; k1];
    for (a, row) in gram.iter_mut().enumerate() {
        let va = &v1t[a * n..(a + 1) * n];
        for (b, cell) in row.iter_mut().enumerate() {
            let vb = &v2t[b * n..(b + 1) * n];
            let mut dot = 0.0f64;
            for (x, y) in va.iter().zip(vb) {
                dot += x * y;
            }
            *cell = dot * dot;
        }
    }
    // prefix-sum -> phi
    let mut grid = vec![vec![0.0f64; k2]; k1];
    let mut prefix = vec![vec![0.0f64; k2 + 1]; k1 + 1];
    for i in 1..=k1 {
        for j in 1..=k2 {
            prefix[i][j] = gram[i - 1][j - 1] + prefix[i - 1][j] + prefix[i][j - 1]
                - prefix[i - 1][j - 1];
            grid[i - 1][j - 1] = (prefix[i][j] / i.min(j) as f64).min(1.0);
        }
    }
    Ok(grid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_subspaces_give_one() {
        let mut rng = Rng::new(20);
        let a = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let grid = subspace_similarity_grid(&a, &a, 8, 8).unwrap();
        for i in 0..8 {
            // phi(i+1, i+1) of identical subspaces = 1
            assert!((grid[i][i] - 1.0).abs() < 1e-5, "phi({},{}) = {}", i + 1, i + 1, grid[i][i]);
        }
    }

    #[test]
    fn contained_subspace_gives_one() {
        // phi(i, j) == 1 whenever one subspace contains the other
        let mut rng = Rng::new(21);
        let a = Tensor::randn(&[12, 12], 1.0, &mut rng);
        let grid = subspace_similarity_grid(&a, &a, 6, 6).unwrap();
        for i in 0..6 {
            for j in 0..6 {
                assert!(grid[i][j] <= 1.0 + 1e-9);
                if i <= j {
                    // same matrix: first i vectors always inside first j
                    assert!(grid[i.min(j)][i.max(j)] > 1.0 - 1e-5);
                }
            }
        }
    }

    #[test]
    fn orthogonal_updates_give_zero() {
        // dw1 acts on rows 0..4 of input space, dw2 on rows 8..12
        let n = 16;
        let mut dw1 = Tensor::zeros(&[n, n]);
        let mut dw2 = Tensor::zeros(&[n, n]);
        let mut rng = Rng::new(22);
        for i in 0..n {
            for j in 0..4 {
                *dw1.at2_mut(i, j) = rng.normal() as f32;
                *dw2.at2_mut(i, j + 8) = rng.normal() as f32;
            }
        }
        let grid = subspace_similarity_grid(&dw1, &dw2, 4, 4).unwrap();
        for row in &grid {
            for &v in row {
                assert!(v < 1e-6, "expected orthogonal, got {v}");
            }
        }
    }

    #[test]
    fn phi_in_unit_interval() {
        let mut rng = Rng::new(23);
        let a = Tensor::randn(&[10, 10], 1.0, &mut rng);
        let b = Tensor::randn(&[10, 10], 1.0, &mut rng);
        let grid = subspace_similarity_grid(&a, &b, 10, 10).unwrap();
        for row in &grid {
            for &v in row {
                assert!((0.0..=1.0 + 1e-9).contains(&v));
            }
        }
    }

    #[test]
    fn single_direction_matches_pointwise() {
        let mut rng = Rng::new(24);
        let a = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let b = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let s1 = Svd::compute(&a).unwrap();
        let s2 = Svd::compute(&b).unwrap();
        let grid = subspace_similarity_grid(&a, &b, 4, 4).unwrap();
        for i in 1..=4usize {
            for j in 1..=4usize {
                let direct = subspace_similarity(&s1.v, &s2.v, i, j);
                assert!((grid[i - 1][j - 1] - direct).abs() < 1e-9);
            }
        }
    }
}
