//! One-sided Jacobi SVD.
//!
//! Chosen over Golub–Kahan for implementation simplicity and excellent
//! accuracy at the sizes the analysis needs (matrices up to ~1k x 1k).
//! The algorithm orthogonalizes pairs of columns of `A` by plane
//! rotations until convergence; singular values are the resulting column
//! norms, `U` the normalized columns, `V` the accumulated rotations.

use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Thin SVD result: `a = u * diag(s) * v^T`, with `u` (m x k), `s` (k),
/// `v` (n x k), `k = min(m, n)`; singular values sorted descending.
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f64>,
    pub v: Tensor,
}

impl Svd {
    /// Compute the SVD of a 2D tensor.
    pub fn compute(a: &Tensor) -> Result<Svd> {
        if a.rank() != 2 {
            return Err(Error::Shape(format!("svd needs 2D, got {:?}", a.shape)));
        }
        let (m, n) = (a.shape[0], a.shape[1]);
        // One-sided Jacobi wants m >= n; transpose if needed and swap U/V.
        if m < n {
            let svd_t = Svd::compute(&a.t()?)?;
            return Ok(Svd { u: svd_t.v, s: svd_t.s, v: svd_t.u });
        }
        // Work in f64 on one flat column-major buffer (column j at
        // `cols[j*m .. (j+1)*m]`): the Jacobi inner loop then rotates
        // two contiguous slices instead of chasing `Vec<Vec<f64>>`
        // pointers, which vectorizes and stays cache-resident.
        let mut cols = vec![0.0f64; m * n];
        for j in 0..n {
            let col = &mut cols[j * m..(j + 1) * m];
            for (i, slot) in col.iter_mut().enumerate() {
                *slot = a.data[i * n + j] as f64;
            }
        }
        // accumulated right vectors, V column j at `v[j*n .. (j+1)*n]`
        let mut v = vec![0.0f64; n * n];
        for j in 0..n {
            v[j * n + j] = 1.0;
        }

        let eps = 1e-14;
        let max_sweeps = 60;
        for _ in 0..max_sweeps {
            let mut off = 0.0f64;
            for p in 0..n {
                for q in (p + 1)..n {
                    // q > p, so split_at_mut yields disjoint column slices
                    let (lo, hi) = cols.split_at_mut(q * m);
                    let colp = &mut lo[p * m..(p + 1) * m];
                    let colq = &mut hi[..m];
                    let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                    for (xp, xq) in colp.iter().zip(colq.iter()) {
                        app += xp * xp;
                        aqq += xq * xq;
                        apq += xp * xq;
                    }
                    if apq.abs() <= eps * (app * aqq).sqrt() + 1e-300 {
                        continue;
                    }
                    off += apq.abs();
                    // Jacobi rotation zeroing the (p,q) inner product
                    let tau = (aqq - app) / (2.0 * apq);
                    let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    let s = c * t;
                    for (xp, xq) in colp.iter_mut().zip(colq.iter_mut()) {
                        let (op, oq) = (*xp, *xq);
                        *xp = c * op - s * oq;
                        *xq = s * op + c * oq;
                    }
                    let (vlo, vhi) = v.split_at_mut(q * n);
                    let vp = &mut vlo[p * n..(p + 1) * n];
                    let vq = &mut vhi[..n];
                    for (yp, yq) in vp.iter_mut().zip(vq.iter_mut()) {
                        let (ov, oq) = (*yp, *yq);
                        *yp = c * ov - s * oq;
                        *yq = s * ov + c * oq;
                    }
                }
            }
            if off < eps {
                break;
            }
        }

        // Extract singular values (column norms) and sort descending.
        let mut order: Vec<usize> = (0..n).collect();
        let norms: Vec<f64> = (0..n)
            .map(|j| cols[j * m..(j + 1) * m].iter().map(|x| x * x).sum::<f64>().sqrt())
            .collect();
        order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

        let k = n; // thin (m >= n here)
        let mut u = Tensor::zeros(&[m, k]);
        let mut vt = Tensor::zeros(&[n, k]);
        let mut s = Vec::with_capacity(k);
        for (newj, &oldj) in order.iter().enumerate() {
            let norm = norms[oldj];
            s.push(norm);
            if norm > 1e-300 {
                let col = &cols[oldj * m..(oldj + 1) * m];
                for (i, &x) in col.iter().enumerate() {
                    u.data[i * k + newj] = (x / norm) as f32;
                }
            }
            let vcol = &v[oldj * n..(oldj + 1) * n];
            for (i, &x) in vcol.iter().enumerate() {
                vt.data[i * k + newj] = x as f32;
            }
        }
        Ok(Svd { u, s, v: vt })
    }

    /// Reconstruct `u * diag(s) * v^T` (validation).
    pub fn reconstruct(&self) -> Result<Tensor> {
        let (m, k) = (self.u.shape[0], self.u.shape[1]);

        let mut us = self.u.clone();
        for i in 0..m {
            for j in 0..k {
                us.data[i * k + j] *= self.s[j] as f32;
            }
        }
        us.matmul(&self.v.t()?)
    }
}

/// Numerical rank: singular values above `tol * s_max`.
pub fn numerical_rank(a: &Tensor, rel_tol: f64) -> Result<usize> {
    let svd = Svd::compute(a)?;
    let smax = svd.s.first().copied().unwrap_or(0.0);
    if smax <= 0.0 {
        return Ok(0);
    }
    Ok(svd.s.iter().filter(|&&s| s > rel_tol * smax).count())
}

/// Effective rank: exp(entropy of the normalized singular-value
/// distribution) — a soft rank measure used in the rank-gap analysis.
pub fn effective_rank(a: &Tensor) -> Result<f64> {
    let svd = Svd::compute(a)?;
    let total: f64 = svd.s.iter().sum();
    if total <= 0.0 {
        return Ok(0.0);
    }
    let mut h = 0.0;
    for &s in &svd.s {
        let p = s / total;
        if p > 1e-300 {
            h -= p * p.ln();
        }
    }
    Ok(h.exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reconstruct_err(a: &Tensor) -> f32 {
        let svd = Svd::compute(a).unwrap();
        let r = svd.reconstruct().unwrap();
        a.max_abs_diff(&r) / a.frobenius_norm().max(1e-6)
    }

    #[test]
    fn svd_reconstructs_random() {
        let mut rng = Rng::new(10);
        for &(m, n) in &[(8usize, 8usize), (12, 5), (5, 12), (20, 20)] {
            let a = Tensor::randn(&[m, n], 1.0, &mut rng);
            assert!(reconstruct_err(&a) < 1e-5, "({m},{n})");
        }
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut rng = Rng::new(11);
        let a = Tensor::randn(&[10, 7], 1.0, &mut rng);
        let svd = Svd::compute(&a).unwrap();
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn u_v_orthonormal() {
        let mut rng = Rng::new(12);
        let a = Tensor::randn(&[9, 6], 1.0, &mut rng);
        let svd = Svd::compute(&a).unwrap();
        let utu = svd.u.t().unwrap().matmul(&svd.u).unwrap();
        let vtv = svd.v.t().unwrap().matmul(&svd.v).unwrap();
        let i6 = Tensor::eye(6);
        assert!(utu.max_abs_diff(&i6) < 1e-5);
        assert!(vtv.max_abs_diff(&i6) < 1e-5);
    }

    #[test]
    fn rank_of_outer_products() {
        // rank-r matrix built from r outer products
        let mut rng = Rng::new(13);
        let n = 12;
        for r in [1usize, 3, 6] {
            let b = Tensor::randn(&[n, r], 1.0, &mut rng);
            let c = Tensor::randn(&[r, n], 1.0, &mut rng);
            let a = b.matmul(&c).unwrap();
            assert_eq!(numerical_rank(&a, 1e-6).unwrap(), r);
        }
    }

    #[test]
    fn rank_deficient_reconstruction() {
        // rank-3 12x9 matrix: the thin SVD must reconstruct it, report
        // (near-)zero trailing singular values, and keep U orthonormal
        // on the numerically nonzero columns.
        let mut rng = Rng::new(14);
        let b = Tensor::randn(&[12, 3], 1.0, &mut rng);
        let c = Tensor::randn(&[3, 9], 1.0, &mut rng);
        let a = b.matmul(&c).unwrap();
        let svd = Svd::compute(&a).unwrap();
        assert!(reconstruct_err(&a) < 1e-5);
        let smax = svd.s[0];
        for &s in &svd.s[3..] {
            assert!(s < 1e-8 * smax, "trailing singular value {s} vs smax {smax}");
        }
        assert_eq!(numerical_rank(&a, 1e-6).unwrap(), 3);
    }

    #[test]
    fn rank_of_identity() {
        assert_eq!(numerical_rank(&Tensor::eye(9), 1e-9).unwrap(), 9);
    }

    #[test]
    fn effective_rank_identity() {
        let er = effective_rank(&Tensor::eye(8)).unwrap();
        assert!((er - 8.0).abs() < 1e-6);
    }

    #[test]
    fn diagonal_known_values() {
        let mut a = Tensor::zeros(&[3, 3]);
        a.data[0] = 3.0;
        a.data[4] = -2.0;
        a.data[8] = 1.0;
        let svd = Svd::compute(&a).unwrap();
        assert!((svd.s[0] - 3.0).abs() < 1e-9);
        assert!((svd.s[1] - 2.0).abs() < 1e-9);
        assert!((svd.s[2] - 1.0).abs() < 1e-9);
    }
}
