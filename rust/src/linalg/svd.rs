//! One-sided Jacobi SVD.
//!
//! Chosen over Golub–Kahan for implementation simplicity and excellent
//! accuracy at the sizes the analysis needs (matrices up to ~1k x 1k).
//! The algorithm orthogonalizes pairs of columns of `A` by plane
//! rotations until convergence; singular values are the resulting column
//! norms, `U` the normalized columns, `V` the accumulated rotations.

use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Thin SVD result: `a = u * diag(s) * v^T`, with `u` (m x k), `s` (k),
/// `v` (n x k), `k = min(m, n)`; singular values sorted descending.
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f64>,
    pub v: Tensor,
}

impl Svd {
    /// Compute the SVD of a 2D tensor.
    pub fn compute(a: &Tensor) -> Result<Svd> {
        if a.rank() != 2 {
            return Err(Error::Shape(format!("svd needs 2D, got {:?}", a.shape)));
        }
        let (m, n) = (a.shape[0], a.shape[1]);
        // One-sided Jacobi wants m >= n; transpose if needed and swap U/V.
        if m < n {
            let svd_t = Svd::compute(&a.t()?)?;
            return Ok(Svd { u: svd_t.v, s: svd_t.s, v: svd_t.u });
        }
        // Work in f64, column-major columns.
        let mut cols: Vec<Vec<f64>> = (0..n)
            .map(|j| (0..m).map(|i| a.data[i * n + j] as f64).collect())
            .collect();
        let mut v = vec![vec![0.0f64; n]; n];
        for (j, row) in v.iter_mut().enumerate() {
            row[j] = 1.0;
        }

        let eps = 1e-14;
        let max_sweeps = 60;
        for _ in 0..max_sweeps {
            let mut off = 0.0f64;
            for p in 0..n {
                for q in (p + 1)..n {
                    let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                    for i in 0..m {
                        app += cols[p][i] * cols[p][i];
                        aqq += cols[q][i] * cols[q][i];
                        apq += cols[p][i] * cols[q][i];
                    }
                    if apq.abs() <= eps * (app * aqq).sqrt() + 1e-300 {
                        continue;
                    }
                    off += apq.abs();
                    // Jacobi rotation zeroing the (p,q) inner product
                    let tau = (aqq - app) / (2.0 * apq);
                    let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    let s = c * t;
                    for i in 0..m {
                        let (xp, xq) = (cols[p][i], cols[q][i]);
                        cols[p][i] = c * xp - s * xq;
                        cols[q][i] = s * xp + c * xq;
                    }
                    for i in 0..n {
                        let (vp, vq) = (v[p][i], v[q][i]);
                        v[p][i] = c * vp - s * vq;
                        v[q][i] = s * vp + c * vq;
                    }
                }
            }
            if off < eps {
                break;
            }
        }

        // Extract singular values (column norms) and sort descending.
        let mut order: Vec<usize> = (0..n).collect();
        let norms: Vec<f64> = cols
            .iter()
            .map(|c| c.iter().map(|x| x * x).sum::<f64>().sqrt())
            .collect();
        order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

        let k = n; // thin (m >= n here)
        let mut u = Tensor::zeros(&[m, k]);
        let mut vt = Tensor::zeros(&[n, k]);
        let mut s = Vec::with_capacity(k);
        for (newj, &oldj) in order.iter().enumerate() {
            let norm = norms[oldj];
            s.push(norm);
            if norm > 1e-300 {
                for i in 0..m {
                    u.data[i * k + newj] = (cols[oldj][i] / norm) as f32;
                }
            }
            for i in 0..n {
                vt.data[i * k + newj] = v[oldj][i] as f32;
            }
        }
        Ok(Svd { u, s, v: vt })
    }

    /// Reconstruct `u * diag(s) * v^T` (validation).
    pub fn reconstruct(&self) -> Result<Tensor> {
        let (m, k) = (self.u.shape[0], self.u.shape[1]);

        let mut us = self.u.clone();
        for i in 0..m {
            for j in 0..k {
                us.data[i * k + j] *= self.s[j] as f32;
            }
        }
        us.matmul(&self.v.t()?)
    }
}

/// Numerical rank: singular values above `tol * s_max`.
pub fn numerical_rank(a: &Tensor, rel_tol: f64) -> Result<usize> {
    let svd = Svd::compute(a)?;
    let smax = svd.s.first().copied().unwrap_or(0.0);
    if smax <= 0.0 {
        return Ok(0);
    }
    Ok(svd.s.iter().filter(|&&s| s > rel_tol * smax).count())
}

/// Effective rank: exp(entropy of the normalized singular-value
/// distribution) — a soft rank measure used in the rank-gap analysis.
pub fn effective_rank(a: &Tensor) -> Result<f64> {
    let svd = Svd::compute(a)?;
    let total: f64 = svd.s.iter().sum();
    if total <= 0.0 {
        return Ok(0.0);
    }
    let mut h = 0.0;
    for &s in &svd.s {
        let p = s / total;
        if p > 1e-300 {
            h -= p * p.ln();
        }
    }
    Ok(h.exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reconstruct_err(a: &Tensor) -> f32 {
        let svd = Svd::compute(a).unwrap();
        let r = svd.reconstruct().unwrap();
        a.max_abs_diff(&r) / a.frobenius_norm().max(1e-6)
    }

    #[test]
    fn svd_reconstructs_random() {
        let mut rng = Rng::new(10);
        for &(m, n) in &[(8usize, 8usize), (12, 5), (5, 12), (20, 20)] {
            let a = Tensor::randn(&[m, n], 1.0, &mut rng);
            assert!(reconstruct_err(&a) < 1e-5, "({m},{n})");
        }
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut rng = Rng::new(11);
        let a = Tensor::randn(&[10, 7], 1.0, &mut rng);
        let svd = Svd::compute(&a).unwrap();
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn u_v_orthonormal() {
        let mut rng = Rng::new(12);
        let a = Tensor::randn(&[9, 6], 1.0, &mut rng);
        let svd = Svd::compute(&a).unwrap();
        let utu = svd.u.t().unwrap().matmul(&svd.u).unwrap();
        let vtv = svd.v.t().unwrap().matmul(&svd.v).unwrap();
        let i6 = Tensor::eye(6);
        assert!(utu.max_abs_diff(&i6) < 1e-5);
        assert!(vtv.max_abs_diff(&i6) < 1e-5);
    }

    #[test]
    fn rank_of_outer_products() {
        // rank-r matrix built from r outer products
        let mut rng = Rng::new(13);
        let n = 12;
        for r in [1usize, 3, 6] {
            let b = Tensor::randn(&[n, r], 1.0, &mut rng);
            let c = Tensor::randn(&[r, n], 1.0, &mut rng);
            let a = b.matmul(&c).unwrap();
            assert_eq!(numerical_rank(&a, 1e-6).unwrap(), r);
        }
    }

    #[test]
    fn rank_of_identity() {
        assert_eq!(numerical_rank(&Tensor::eye(9), 1e-9).unwrap(), 9);
    }

    #[test]
    fn effective_rank_identity() {
        let er = effective_rank(&Tensor::eye(8)).unwrap();
        assert!((er - 8.0).abs() < 1e-6);
    }

    #[test]
    fn diagonal_known_values() {
        let mut a = Tensor::zeros(&[3, 3]);
        a.data[0] = 3.0;
        a.data[4] = -2.0;
        a.data[8] = 1.0;
        let svd = Svd::compute(&a).unwrap();
        assert!((svd.s[0] - 3.0).abs() < 1e-9);
        assert!((svd.s[1] - 2.0).abs() < 1e-9);
        assert!((svd.s[2] - 1.0).abs() < 1e-9);
    }
}
