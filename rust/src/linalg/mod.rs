//! Numerical linear algebra for the analysis pipeline: one-sided Jacobi
//! SVD, numerical rank, and the paper's subspace-similarity measure
//! (Eq. A.1).  All f64 internally for robustness.

mod svd;
mod subspace;

pub use subspace::{subspace_similarity, subspace_similarity_grid};
pub use svd::{effective_rank, numerical_rank, Svd};
