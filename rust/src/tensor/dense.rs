//! Dense row-major f32 tensor.
//!
//! The matmul kernel itself lives in `compute::gemm` (the borrowing
//! slice-in/slice-out entry shared with the block MLP, the adapter base
//! product, and the serving decode loop); [`Tensor::matmul`] is the
//! owned-tensor convenience wrapper over it.

use crate::compute::gemm;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Dense row-major tensor of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {shape:?} wants {n} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn eye(n: usize) -> Self {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} -> {shape:?}",
                self.shape
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// 2D element access.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at2_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert_eq!(self.shape.len(), 2);
        &mut self.data[i * self.shape[1] + j]
    }

    /// Matrix multiply: self [m,k] @ other [k,n] -> [m,n].
    ///
    /// Delegates to [`gemm::gemm_into`] — blocked over `k` so the
    /// active `B` panel stays cache-resident, row-chunked over the
    /// compute pool for large products (each row's accumulation order
    /// is ascending in `p` regardless of chunking, so any chunk split
    /// is bitwise identical to serial); `j` innermost vectorizes.  No
    /// zero-skip shortcut: `0 × NaN` must propagate NaN (IEEE 754),
    /// and a data-dependent branch in the inner loop defeats
    /// vectorization anyway.
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[1] != other.shape[0] {
            return Err(Error::Shape(format!(
                "matmul {:?} @ {:?}",
                self.shape, other.shape
            )));
        }
        let (m, k, n) = (self.shape[0], self.shape[1], other.shape[1]);
        let mut out = Tensor::zeros(&[m, n]);
        if m == 0 || k == 0 || n == 0 {
            return Ok(out);
        }
        gemm::gemm_into(&self.data, &other.data, &mut out.data, k, n);
        Ok(out)
    }

    /// Transpose a 2D tensor.
    pub fn t(&self) -> Result<Tensor> {
        if self.rank() != 2 {
            return Err(Error::Shape(format!("t() needs 2D, got {:?}", self.shape)));
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        Ok(out)
    }

    /// Matrix-vector: self [m,k] @ v [k] -> [m].
    pub fn matvec(&self, v: &[f32]) -> Result<Vec<f32>> {
        if self.rank() != 2 || self.shape[1] != v.len() {
            return Err(Error::Shape(format!(
                "matvec {:?} @ [{}]",
                self.shape,
                v.len()
            )));
        }
        let (m, k) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0; m];
        for i in 0..m {
            let row = &self.data[i * k..(i + 1) * k];
            out[i] = row.iter().zip(v).map(|(a, b)| a * b).sum();
        }
        Ok(out)
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!("add {:?} + {:?}", self.shape, other.shape)));
        }
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Ok(Tensor { shape: self.shape.clone(), data })
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!("sub {:?} - {:?}", self.shape, other.shape)));
        }
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Ok(Tensor { shape: self.shape.clone(), data })
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|v| v * s).collect(),
        }
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let i7 = Tensor::eye(7);
        let out = a.matmul(&i7).unwrap();
        assert!(a.max_abs_diff(&out) < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![5., 6., 7., 8.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[4, 9], 1.0, &mut rng);
        assert_eq!(a, a.t().unwrap().t().unwrap());
    }

    #[test]
    fn matmul_transpose_consistency() {
        // (A B)^T == B^T A^T
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 4], 1.0, &mut rng);
        let lhs = a.matmul(&b).unwrap().t().unwrap();
        let rhs = b.t().unwrap().matmul(&a.t().unwrap()).unwrap();
        assert!(lhs.max_abs_diff(&rhs) < 1e-5);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&[6, 3], 1.0, &mut rng);
        let v = vec![1.0, -2.0, 0.5];
        let mv = a.matvec(&v).unwrap();
        let vm = Tensor::from_vec(&[3, 1], v).unwrap();
        let mm = a.matmul(&vm).unwrap();
        for i in 0..6 {
            assert!((mv[i] - mm.data[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_propagates_nan() {
        // 0 × NaN must be NaN (the seed's `a == 0.0` skip silently
        // dropped such terms)
        let a = Tensor::from_vec(&[1, 2], vec![0.0, 0.0]).unwrap();
        let b = Tensor::from_vec(&[2, 1], vec![f32::NAN, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert!(c.data[0].is_nan());
    }

    #[test]
    fn matmul_large_parallel_matches_serial() {
        // above the parallel threshold the row-chunked path must agree
        // with the serial kernel bit-for-bit
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[160, 96], 1.0, &mut rng);
        let b = Tensor::randn(&[96, 128], 1.0, &mut rng);
        let par = a.matmul(&b).unwrap();
        let mut serial = Tensor::zeros(&[160, 128]);
        gemm::mm_rows(&a.data, &b.data, &mut serial.data, 96, 128);
        assert_eq!(par.data, serial.data);
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
        assert!(a.reshape(&[7]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![1.0]).is_err());
    }
}
