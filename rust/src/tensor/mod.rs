//! Host tensor: a shape + contiguous `Vec<f32>` with the operations the
//! analysis / reference paths need (matmul, transpose, axis moves).
//! The compute layer is shared with the pure-Rust QuanTA circuit engine
//! (`quanta::plan`), so the hot kernels (matmul, the gate GEMMs) are
//! blocked and multi-threaded — see DESIGN.md §Circuit-engine.

mod dense;

pub use dense::Tensor;

/// Worker count for the parallel kernels: `available_parallelism`,
/// overridable with `QFT_THREADS`, and clamped so tiny problems never
/// pay thread-spawn overhead (callers pass an upper bound, usually the
/// number of independent work chunks).
pub(crate) fn num_threads(max_useful: usize) -> usize {
    if max_useful <= 1 {
        return 1;
    }
    let hw = std::env::var("QFT_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    hw.min(max_useful).max(1)
}
