//! Host tensor: a shape + contiguous `Vec<f32>` with the operations the
//! analysis / reference paths need (matmul, transpose, axis moves).
//! The compute layer is shared with the pure-Rust QuanTA circuit engine
//! (`quanta::plan`): the hot kernels (matmul, the gate GEMMs) are
//! blocked and dispatched through the persistent worker pool
//! (`crate::compute::pool`) in problem-sized chunks — see DESIGN.md §6.
//!
//! The PR 1/2 per-call worker clamp (`num_threads`, "never pay
//! thread-spawn overhead") is gone: nothing here spawns threads any
//! more.  Parallel work is split into `PAR_MIN_FLOPS`-sized chunks and
//! handed to already-parked workers; `QFT_THREADS` still caps how many
//! workers participate, but — because chunk boundaries depend only on
//! the problem shape — no longer affects any result bit.

mod dense;

pub use dense::Tensor;
