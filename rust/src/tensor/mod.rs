//! Host tensor: a shape + contiguous `Vec<f32>` with the operations the
//! analysis / reference paths need (matmul, transpose, axis moves).
//! Not a performance-critical path — the heavy math runs in XLA — but
//! implemented carefully enough for the SVD/analysis pipeline.

mod dense;

pub use dense::Tensor;
