//! quanta-ft CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   list                         — show artifact sets and tasks
//!   pretrain --arch tiny         — pretrain (and cache) a base model
//!   train --set S --task T       — fine-tune one config, report metric
//!   train-host [--dims 4,4,8 …]  — artifact-free fine-tune on the pure
//!                                  rust gradient engine (synthetic task)
//!   train-block [--dims 4,4,8 --heads 4 --seq 8 …]
//!                                — fine-tune a full transformer block
//!                                  (one circuit per Q/K/V/O projection)
//!                                  on the host engine; --save-params
//!                                  writes the best checkpoint
//!   train-deep [--layers 2 …]    — fine-tune a depth-N stack of blocks
//!                                  through the same trainer;
//!                                  --save-params writes a v3 checkpoint
//!                                  (one stream per layer)
//!   serve [--layers N --params ckpt.bin …]
//!                                — KV-cache incremental-decode serving
//!                                  of a trained stack on merged weights
//!                                  (continuous batching; --requests-file
//!                                  '-' reads the request stream from
//!                                  stdin; --prefix-cache admits requests
//!                                  sharing a prompt prefix by CoW-forking
//!                                  the donor's KV pages)
//!   eval-base --set S --task T   — score the un-fine-tuned base model
//!   analyze --task T             — Fig.2 subspace-similarity analysis
//!   info --set S                 — print a manifest summary
//!
//! (Argument parsing is hand-rolled: clap is not in the offline vendor
//! set.)

use std::collections::BTreeMap;
use std::process::ExitCode;

use quanta_ft::analysis;
use quanta_ft::coordinator::experiment::{require_artifacts, RunSpec};
use quanta_ft::coordinator::tables::{pct, score100, Table};
use quanta_ft::data::tasks;
use quanta_ft::runtime::manifest::Manifest;
use quanta_ft::util::error::Result;

fn parse_args(args: &[String]) -> (Vec<String>, BTreeMap<String, String>) {
    let mut positional = vec![];
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (positional, flags)
}

fn usage() -> ExitCode {
    eprintln!(
        "usage: quanta-ft <list|info|pretrain|train|train-host|train-block|train-deep|serve\
         |eval-base|analyze> [--set S] [--task T] [--arch A] [--seeds N] [--steps N]\n\
         train-host flags: [--dims 4,4,8] [--steps N] [--batch N] [--lr F] [--seed N]\n\
                           [--n-train N] [--n-val N] [--teacher-std F] [--noise-std F]\n\
                           [--alpha F] [--clip F] [--warmup N] [--decay N] [--min-lr F]\n\
                           [--weight-decay F] [--patience N] [--eval-every N]\n\
                           [--snapshot PATH] [--snapshot-every N] [--resume]\n\
                           (--snapshot writes a crash-consistent run manifest every N\n\
                           steps, default 50; --resume continues bitwise from it)\n\
         train-block flags: train-host flags plus [--heads N] [--seq N] [--d-ff N]\n\
                           [--save-params PATH] (--batch counts sequences; --dims shapes\n\
                           each projection circuit)\n\
         train-deep flags: train-block flags plus [--layers N] (--save-params writes a\n\
                           v3 checkpoint, one named stream per layer)\n\
         serve flags:      [--dims 4,4,8] [--heads N] [--d-ff N] [--alpha F] [--seed N]\n\
                           [--layers N] [--params PATH] [--max-batch N] [--requests N]\n\
                           [--prompt-len N] [--gen-len N] [--req-seed N]\n\
                           [--requests-file PATH|-] [--deadline N] [--token-budget N]\n\
                           [--queue-cap N] [--shed-policy reject-new|drop-oldest]\n\
                           [--kv-pages N] [--page-size N] [--prefill-chunk N]\n\
                           [--prefix-cache] [--prefix-len N]\n\
                           [--streaming] [--no-verify] [--strict] (--kv-pages bounds\n\
                           resident KV cache — exhaustion quarantines the offending\n\
                           request; --page-size sets tokens per KV page; --prefix-cache\n\
                           CoW-shares full KV pages of a common prompt prefix instead of\n\
                           re-prefilling it; --prefix-len makes the first N synthetic\n\
                           prompt rows identical across requests (request-file rows may\n\
                           carry 'prefix=N' per line); stack flags must\n\
                           match the train-block/train-deep run that produced --params;\n\
                           request-file rows may end in 'nan' to inject a poisoned\n\
                           prompt; SIGTERM/ctrl-c drains gracefully — in-flight\n\
                           requests finish, the queue is shed; --strict exits nonzero\n\
                           when any request failed or was shed)"
    );
    ExitCode::FAILURE
}

/// Parse a required-typed flag with a default (`--steps 200`-style).
fn flag_or<T: std::str::FromStr>(
    flags: &BTreeMap<String, String>,
    name: &str,
    default: T,
) -> Result<T> {
    match flags.get(name) {
        None => Ok(default),
        Some(raw) => raw
            .parse::<T>()
            .map_err(|_| quanta_ft::Error::msg(format!("bad --{name} '{raw}'"))),
    }
}

/// Shared `--dims` parser (every train/serve subcommand takes the same
/// factorization flag).
fn parse_dims(flags: &BTreeMap<String, String>) -> Result<Vec<usize>> {
    flags
        .get("dims")
        .map(|s| s.as_str())
        .unwrap_or("4,4,8")
        .split(',')
        .map(|p| p.trim().parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .map_err(|_| quanta_ft::Error::msg("bad --dims (want e.g. 4,4,8)"))
}

/// Shared trainer-flag parser for `train-host`/`train-block`/
/// `train-deep`: one place wires every Adam/schedule/recovery flag —
/// and the durability flags (`--snapshot PATH` [+ `--snapshot-every N`,
/// default 50] and `--resume`) — so the three subcommands cannot
/// drift.  Only the defaults for `--steps`/`--batch` differ per
/// subcommand.
fn train_cfg_from_flags(
    flags: &BTreeMap<String, String>,
    seed: u64,
    default_steps: usize,
    default_batch: usize,
) -> Result<quanta_ft::coordinator::host_trainer::HostTrainConfig> {
    use quanta_ft::coordinator::host_trainer::HostTrainConfig;
    let snapshot_path = flags.get("snapshot").map(std::path::PathBuf::from);
    let resume = flags.contains_key("resume");
    let snapshot_every = match flags.get("snapshot-every") {
        Some(raw) => raw
            .parse::<usize>()
            .map_err(|_| quanta_ft::Error::msg(format!("bad --snapshot-every '{raw}'")))?,
        None if snapshot_path.is_some() => 50,
        None => 0,
    };
    if (resume || snapshot_every > 0) && snapshot_path.is_none() {
        return Err(quanta_ft::Error::msg(
            "--resume / --snapshot-every need --snapshot PATH (where the run manifest lives)",
        ));
    }
    Ok(HostTrainConfig {
        seed,
        steps: flag_or(flags, "steps", default_steps)?,
        batch: flag_or(flags, "batch", default_batch)?,
        lr: flag_or(flags, "lr", 2e-2)?,
        clip: flag_or(flags, "clip", 1.0)?,
        warmup_steps: flag_or(flags, "warmup", 0)?,
        lr_decay_steps: flag_or(flags, "decay", 0)?,
        min_lr: flag_or(flags, "min-lr", 0.0)?,
        weight_decay: flag_or(flags, "weight-decay", 0.0)?,
        eval_every: flag_or(flags, "eval-every", 20)?,
        patience: flags
            .get("patience")
            .map(|s| s.parse::<usize>())
            .transpose()
            .map_err(|_| quanta_ft::Error::msg("bad --patience"))?,
        snapshot_every,
        snapshot_path,
        resume,
        ..Default::default()
    })
}

/// Route SIGINT/SIGTERM into a drain latch the serve loop polls at its
/// iteration boundaries (DESIGN.md §13): first signal starts a
/// graceful drain; the handler only stores to an atomic (the only
/// async-signal-safe thing it could do).  Raw `signal(2)` FFI — std
/// already links libc, and the crate vendors no bindings.
#[cfg(unix)]
fn install_drain_handler() -> &'static std::sync::atomic::AtomicBool {
    use std::sync::atomic::{AtomicBool, Ordering};
    static DRAIN: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_signal(_sig: i32) {
        DRAIN.store(true, Ordering::Relaxed);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: on_signal is async-signal-safe (one relaxed atomic store)
    // and stays alive for the process lifetime.
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
    &DRAIN
}

#[cfg(not(unix))]
fn install_drain_handler() -> &'static std::sync::atomic::AtomicBool {
    static DRAIN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);
    &DRAIN
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_args(&args);
    let cmd = match pos.first() {
        Some(c) => c.as_str(),
        None => return usage(),
    };
    match run(cmd, &flags) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(cmd: &str, flags: &BTreeMap<String, String>) -> Result<()> {
    match cmd {
        "list" => {
            let root = std::env::current_dir()?;
            println!("artifact sets:");
            for s in Manifest::list_sets(&root.join("artifacts"))? {
                let man = Manifest::load(&root.join("artifacts").join(&s))?;
                let method =
                    man.method.as_ref().map(|m| m.name.clone()).unwrap_or("pretrain".into());
                println!(
                    "  {s:28} arch={:6} method={:8} trainable={} ({})",
                    man.arch.name,
                    method,
                    man.counts.trainable_params,
                    pct(man.counts.trainable_percent),
                );
            }
            println!("\ntasks: {}", tasks::TASKS.join(", "));
            Ok(())
        }
        "info" => {
            let set = flags.get("set").ok_or_else(|| quanta_ft::Error::msg("--set required"))?;
            let root = std::env::current_dir()?;
            let man = Manifest::load(&root.join("artifacts").join(set))?;
            println!("set:        {}", man.name);
            println!(
                "arch:       {} (d={}, layers={}, heads={}, vocab={}, seq={})",
                man.arch.name,
                man.arch.d_model,
                man.arch.n_layers,
                man.arch.n_heads,
                man.arch.vocab,
                man.arch.seq_len
            );
            if let Some(m) = &man.method {
                println!("method:     {} on {:?}", m.name, m.modules);
            } else {
                println!("method:     (pretraining)");
            }
            println!(
                "trainable:  {} / {} ({})",
                man.counts.trainable_params,
                man.counts.model_params,
                pct(man.counts.trainable_percent)
            );
            println!(
                "schedule:   lr={} warmup={} total={}",
                man.hyper.lr, man.hyper.warmup_steps, man.hyper.total_steps
            );
            println!("artifacts:  {:?}", man.artifacts.keys().collect::<Vec<_>>());
            Ok(())
        }
        "pretrain" => {
            let arch = flags.get("arch").map(|s| s.as_str()).unwrap_or("tiny");
            let mut runner =
                require_artifacts().ok_or_else(|| quanta_ft::Error::msg("no artifacts"))?;
            let base = runner.pretrained_base(arch)?;
            println!("base model '{arch}' ready: {} params", base.len());
            Ok(())
        }
        "train" => {
            let set = flags.get("set").ok_or_else(|| quanta_ft::Error::msg("--set required"))?;
            let task = flags.get("task").ok_or_else(|| quanta_ft::Error::msg("--task required"))?;
            let seeds: Vec<u64> = flags
                .get("seeds")
                .map(|s| s.parse::<u64>().unwrap_or(2))
                .map(|n| (0..n).collect())
                .unwrap_or_else(|| vec![0, 1]);
            let mut spec = if task.ends_with("_mix") {
                let suite: &[&str] = match task.as_str() {
                    "commonsense_mix" => tasks::COMMONSENSE_SUITE,
                    "math_mix" => tasks::ARITHMETIC_SUITE,
                    other => return Err(quanta_ft::Error::msg(format!("unknown mix '{other}'"))),
                };
                RunSpec::mix(set, suite)
            } else {
                RunSpec::new(set, task)
            }
            .with_seeds(&seeds);
            if let Some(steps) = flags.get("steps") {
                spec = spec
                    .with_steps(steps.parse().map_err(|_| quanta_ft::Error::msg("bad --steps"))?);
            }
            let mut runner =
                require_artifacts().ok_or_else(|| quanta_ft::Error::msg("no artifacts"))?;
            let result = runner.run(&spec)?;
            let mut t = Table::new(&["Task", "Metric", "Score (mean over seeds)"]);
            for (task, vals) in &result.per_task {
                let metric = match tasks::metric_for(task) {
                    tasks::Metric::F1 => "F1",
                    tasks::Metric::Accuracy => "Acc",
                };
                t.row(vec![
                    task.clone(),
                    metric.into(),
                    format!("{} (n={})", score100(result.mean(task)), vals.len()),
                ]);
            }
            t.print();
            println!(
                "trainable params: {} ({})",
                result.trainable_params,
                pct(result.trainable_percent)
            );
            Ok(())
        }
        "train-host" => {
            use quanta_ft::coordinator::host_trainer::{finetune_host, mse};
            use quanta_ft::data::synth::{teacher_student, SynthConfig};
            let scfg = SynthConfig {
                dims: parse_dims(flags)?,
                n_train: flag_or(flags, "n-train", 256)?,
                n_val: flag_or(flags, "n-val", 64)?,
                teacher_std: flag_or(flags, "teacher-std", 0.3)?,
                noise_std: flag_or(flags, "noise-std", 0.01)?,
                alpha: flag_or(flags, "alpha", 1.0)?,
                seed: flag_or(flags, "seed", 0)?,
            };
            let tcfg = train_cfg_from_flags(flags, scfg.seed, 200, 32)?;
            let task = teacher_student(&scfg)?;
            let mut student = task.student()?;
            println!(
                "train-host: d={} dims {:?}, {} gates, {} trainable params, {} train / {} val",
                task.d,
                task.dims,
                task.structure.len(),
                student.param_count(),
                task.n_train,
                task.n_val
            );
            let init = {
                let pred = student.apply_batch(&task.train_x, task.n_train)?;
                mse(&pred, &task.train_y)
            };
            let out = finetune_host(&mut student, &task, &tcfg)?;
            let fin = {
                let pred = student.apply_batch(&task.train_x, task.n_train)?;
                mse(&pred, &task.train_y)
            };
            let mut t = Table::new(&["metric", "value"]);
            t.row(vec!["steps run".into(), out.steps_run.to_string()]);
            t.row(vec!["train mse (init)".into(), format!("{init:.6}")]);
            t.row(vec!["train mse (final)".into(), format!("{fin:.6}")]);
            t.row(vec![
                "loss reduction".into(),
                format!("{:.1}x", init / fin.max(1e-300)),
            ]);
            t.row(vec!["best val mse".into(), format!("{:.6}", out.best_val_loss)]);
            t.row(vec!["wallclock (s)".into(), format!("{:.3}", out.wallclock_s)]);
            t.print();
            if let Some(&(step, loss)) = out.loss_curve.last() {
                println!("last logged train loss: step {step} -> {loss:.6}");
            }
            Ok(())
        }
        "train-block" => {
            use quanta_ft::coordinator::host_trainer::{finetune_host, mse};
            use quanta_ft::data::synth::{block_teacher_student, BlockSynthConfig};
            use quanta_ft::model::TrainableModel;
            let dims = parse_dims(flags)?;
            let d: usize = dims.iter().product();
            let scfg = BlockSynthConfig {
                dims,
                n_heads: flag_or(flags, "heads", 4)?,
                seq: flag_or(flags, "seq", 8)?,
                d_ff: flag_or(flags, "d-ff", 2 * d)?,
                n_train: flag_or(flags, "n-train", 64)?,
                n_val: flag_or(flags, "n-val", 16)?,
                teacher_std: flag_or(flags, "teacher-std", 0.2)?,
                noise_std: flag_or(flags, "noise-std", 0.01)?,
                alpha: flag_or(flags, "alpha", 1.0)?,
                seed: flag_or(flags, "seed", 0)?,
            };
            let tcfg = train_cfg_from_flags(flags, scfg.seed, 100, 8)?;
            let task = block_teacher_student(&scfg)?;
            let mut student = task.student();
            println!(
                "train-block: d={} heads={} seq={} d_ff={}, {} adapters ({:?}), \
                 {} trainable params, {} train / {} val sequences",
                task.d,
                scfg.n_heads,
                scfg.seq,
                scfg.d_ff,
                student.adapters().len(),
                student.adapters().names(),
                student.param_count(),
                task.n_train,
                task.n_val
            );
            let init = {
                let pred = student.forward(&task.train_x, task.n_train, task.seq)?;
                mse(&pred, &task.train_y)
            };
            let out = finetune_host(&mut student, &task, &tcfg)?;
            let fin = {
                let pred = student.forward(&task.train_x, task.n_train, task.seq)?;
                mse(&pred, &task.train_y)
            };
            let mut t = Table::new(&["metric", "value"]);
            t.row(vec!["steps run".into(), out.steps_run.to_string()]);
            t.row(vec!["train mse (init)".into(), format!("{init:.6}")]);
            t.row(vec!["train mse (final)".into(), format!("{fin:.6}")]);
            t.row(vec![
                "loss reduction".into(),
                format!("{:.1}x", init / fin.max(1e-300)),
            ]);
            t.row(vec!["best val mse".into(), format!("{:.6}", out.best_val_loss)]);
            t.row(vec!["wallclock (s)".into(), format!("{:.3}", out.wallclock_s)]);
            t.print();
            // the zero-overhead deployment: merged weights must
            // reproduce the streaming forward — 1e-5 relative to the
            // panel scale (floored at 1: at d = 128 every element is a
            // 128-term f32 dot, so the difference scales with the
            // activation magnitude).  Checked on the train split, which
            // the degenerate-run guard guarantees is non-empty (val may
            // be --n-val 0)
            let merged = student.merged()?;
            let y_stream = student.forward(&task.train_x, task.n_train, task.seq)?;
            let y_merged = merged.forward(&task.train_x, task.n_train, task.seq)?;
            let scale = y_stream.iter().fold(1.0f32, |m, v| m.max(v.abs()));
            let max_diff = y_stream
                .iter()
                .zip(&y_merged)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if max_diff >= 1e-5 * scale {
                return Err(quanta_ft::Error::msg(format!(
                    "merge_all parity violated: max |stream - merged| = {max_diff:e} \
                     at panel scale {scale:e}"
                )));
            }
            println!(
                "merged-block parity: max |stream - merged| = {max_diff:.2e} \
                 (< 1e-5 x panel scale {scale:.1})"
            );
            if let Some(path) = flags.get("save-params") {
                // best-on-validation checkpoint (== final params when
                // --n-val 0), reloadable by `quanta-ft serve --params`
                use quanta_ft::coordinator::checkpoint;
                checkpoint::save(std::path::Path::new(path), "train-block", &out.best_theta)?;
                println!("saved {} adapter params to {path}", out.best_theta.len());
            }
            Ok(())
        }
        "train-deep" => {
            use quanta_ft::coordinator::host_trainer::{finetune_host, mse};
            use quanta_ft::data::synth::{deep_teacher_student, DeepSynthConfig};
            use quanta_ft::model::TrainableModel;
            let dims = parse_dims(flags)?;
            let d: usize = dims.iter().product();
            let scfg = DeepSynthConfig {
                dims,
                n_heads: flag_or(flags, "heads", 4)?,
                seq: flag_or(flags, "seq", 8)?,
                d_ff: flag_or(flags, "d-ff", 2 * d)?,
                depth: flag_or(flags, "layers", 2)?,
                n_train: flag_or(flags, "n-train", 64)?,
                n_val: flag_or(flags, "n-val", 16)?,
                teacher_std: flag_or(flags, "teacher-std", 0.2)?,
                noise_std: flag_or(flags, "noise-std", 0.01)?,
                alpha: flag_or(flags, "alpha", 1.0)?,
                seed: flag_or(flags, "seed", 0)?,
            };
            let tcfg = train_cfg_from_flags(flags, scfg.seed, 100, 8)?;
            let task = deep_teacher_student(&scfg)?;
            let mut student = task.student();
            println!(
                "train-deep: d={} heads={} seq={} d_ff={} layers={}, \
                 {} trainable params ({} per layer), {} train / {} val sequences",
                task.d,
                scfg.n_heads,
                scfg.seq,
                scfg.d_ff,
                student.depth(),
                student.param_count(),
                student.layer(0).param_count(),
                task.n_train,
                task.n_val
            );
            let init = {
                let pred = student.forward(&task.train_x, task.n_train, task.seq)?;
                mse(&pred, &task.train_y)
            };
            let out = finetune_host(&mut student, &task, &tcfg)?;
            let fin = {
                let pred = student.forward(&task.train_x, task.n_train, task.seq)?;
                mse(&pred, &task.train_y)
            };
            let mut t = Table::new(&["metric", "value"]);
            t.row(vec!["steps run".into(), out.steps_run.to_string()]);
            t.row(vec!["train mse (init)".into(), format!("{init:.6}")]);
            t.row(vec!["train mse (final)".into(), format!("{fin:.6}")]);
            t.row(vec![
                "loss reduction".into(),
                format!("{:.1}x", init / fin.max(1e-300)),
            ]);
            t.row(vec!["best val mse".into(), format!("{:.6}", out.best_val_loss)]);
            t.row(vec!["wallclock (s)".into(), format!("{:.3}", out.wallclock_s)]);
            t.print();
            // the zero-overhead deployment at depth N: fold every
            // layer's circuits and re-check the stacked parity contract
            let merged = student.merged()?;
            let y_stream = student.forward(&task.train_x, task.n_train, task.seq)?;
            let y_merged = merged.forward(&task.train_x, task.n_train, task.seq)?;
            let scale = y_stream.iter().fold(1.0f32, |m, v| m.max(v.abs()));
            let max_diff = y_stream
                .iter()
                .zip(&y_merged)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if max_diff >= 1e-5 * scale {
                return Err(quanta_ft::Error::msg(format!(
                    "deep merge parity violated: max |stream - merged| = {max_diff:e} \
                     at panel scale {scale:e}"
                )));
            }
            println!(
                "merged-stack parity: max |stream - merged| = {max_diff:.2e} \
                 (< 1e-5 x panel scale {scale:.1})"
            );
            if let Some(path) = flags.get("save-params") {
                // checkpoint v3: one named stream per layer, reloadable
                // by `quanta-ft serve --layers N --params`
                use quanta_ft::coordinator::checkpoint;
                let names: Vec<String> =
                    (0..student.depth()).map(|l| format!("layer{l}")).collect();
                let streams: Vec<(&str, &[f32])> = names
                    .iter()
                    .enumerate()
                    .map(|(l, name)| {
                        let (lo, hi) = student.layer_span(l);
                        (name.as_str(), &out.best_theta[lo..hi])
                    })
                    .collect();
                checkpoint::save_streams(std::path::Path::new(path), &streams)?;
                println!(
                    "saved {} adapter params ({} layer streams) to {path}",
                    out.best_theta.len(),
                    streams.len()
                );
            }
            Ok(())
        }
        "serve" => serve_cmd(flags),
        "eval-base" => {
            let set = flags.get("set").ok_or_else(|| quanta_ft::Error::msg("--set required"))?;
            let task = flags.get("task").ok_or_else(|| quanta_ft::Error::msg("--task required"))?;
            let mut runner =
                require_artifacts().ok_or_else(|| quanta_ft::Error::msg("no artifacts"))?;
            let score = runner.eval_base(set, task, Default::default())?;
            println!("base model on {task}: {}", score100(score));
            Ok(())
        }
        "analyze" => {
            let task = flags.get("task").map(|s| s.as_str()).unwrap_or("drop_syn");
            let mut runner =
                require_artifacts().ok_or_else(|| quanta_ft::Error::msg("no artifacts"))?;
            let report = analysis::subspace_analysis(
                &mut runner,
                task,
                "tiny_lora_r32",
                "tiny_lora_r64",
                0,
                24,
                24,
            )?;
            println!("task={} module={}", report.task, report.module);
            println!(
                "mean phi = {:.3}, tail phi = {:.3}, effective rank(r2 dW) = {:.1}",
                report.mean_phi, report.tail_phi, report.effective_rank_r2
            );
            print!("{}", analysis::render_heatmap(&report.grid, 24));
            Ok(())
        }
        _ => {
            usage();
            Err(quanta_ft::Error::msg(format!("unknown command '{cmd}'")))
        }
    }
}

/// `quanta-ft serve`: the last leg of the train→merge→serve pipeline.
/// Rebuilds the frozen depth-N stack `train-deep` (or, at `--layers 1`,
/// `train-block`) used for `--seed` (the per-layer `block-base`
/// streams), loads the trained adapter checkpoint, folds every layer's
/// circuits into dense weights, and drives the continuous-batching
/// scheduler over a synthetic or file-driven request stream — then (by
/// default) re-serves the same requests through the *streaming*
/// adapters and enforces the 1e-5 zero-overhead parity contract.
fn serve_cmd(flags: &BTreeMap<String, String>) -> Result<()> {
    use quanta_ft::coordinator::checkpoint;
    use quanta_ft::model::{BlockConfig, DeepConfig, DeepModel, TrainableModel};
    use quanta_ft::serve::{BatchScheduler, ServeConfig, ServeModel, ServeRequest, ShedPolicy};
    use quanta_ft::util::rng::Rng;

    let dims = parse_dims(flags)?;
    let d: usize = dims.iter().product();
    let seed: u64 = flag_or(flags, "seed", 0)?;
    let depth: usize = flag_or(flags, "layers", 1)?;
    let bcfg = BlockConfig::standard(dims, flag_or(flags, "heads", 4)?, flag_or(flags, "seq", 8)?)
        .with_d_ff(flag_or(flags, "d-ff", 2 * d)?)
        .with_alpha(flag_or(flags, "alpha", 1.0)?);
    let seq = bcfg.seq;
    // the same frozen stack train-deep builds for this seed (per-layer
    // `block-base` streams; depth 1 is exactly train-block's template)
    let mut model = DeepModel::init(&DeepConfig { block: bcfg, depth }, seed)?;
    if let Some(path) = flags.get("params") {
        // v3 checkpoints carry one stream per layer; a single stream
        // (v1/v2, or a 1-stream v3) is accepted when it spans the whole
        // stack — i.e. the depth-1 train-block round trip
        let streams = checkpoint::load_streams(std::path::Path::new(path))?;
        let total: usize = streams.iter().map(|(_, p)| p.len()).sum();
        if total != model.param_count()
            || (streams.len() != 1 && streams.len() != model.depth())
        {
            return Err(quanta_ft::Error::msg(format!(
                "checkpoint has {} streams / {} params, stack wants {} layers / {} — \
                 do the serve flags match the train run?",
                streams.len(),
                total,
                model.depth(),
                model.param_count()
            )));
        }
        if streams.len() == model.depth() {
            for (l, (name, params)) in streams.iter().enumerate() {
                let (lo, hi) = model.layer_span(l);
                if params.len() != hi - lo {
                    return Err(quanta_ft::Error::msg(format!(
                        "checkpoint stream '{name}' has {} params, layer {l} wants {}",
                        params.len(),
                        hi - lo
                    )));
                }
            }
        }
        let flat: Vec<f32> = streams.iter().flat_map(|(_, p)| p.iter().copied()).collect();
        model.set_params(&flat)?;
        println!(
            "loaded checkpoint '{}': {} adapter params in {} stream(s)",
            streams[0].0,
            total,
            streams.len()
        );
    }
    println!(
        "serve: d={d} layers={} ({} trainable params behind 4 projections per layer)",
        model.depth(),
        model.param_count()
    );

    let shed = match flags.get("shed-policy").map(|s| s.as_str()) {
        None | Some("reject-new") => ShedPolicy::RejectNew,
        Some("drop-oldest") => ShedPolicy::DropOldest,
        Some(other) => {
            return Err(quanta_ft::Error::msg(format!(
                "bad --shed-policy '{other}' (want reject-new or drop-oldest)"
            )))
        }
    };
    // ServeConfig builders map 1:1 to these CLI flags
    let serve_cfg = ServeConfig::default()
        .with_max_batch(flag_or(flags, "max-batch", 8)?)
        .with_deadline(flag_or(flags, "deadline", 0)?)
        .with_token_budget(flag_or(flags, "token-budget", 0)?)
        .with_queue_cap(flag_or(flags, "queue-cap", 0)?)
        .with_shed_policy(shed)
        .with_kv_pages(flag_or(flags, "kv-pages", 0)?)
        .with_page_tokens(flag_or(flags, "page-size", quanta_ft::serve::default_page_tokens())?)
        .with_prefill_chunk(flag_or(flags, "prefill-chunk", 0)?)
        .with_prefix_cache(flags.contains_key("prefix-cache"));
    let req_seed: u64 = flag_or(flags, "req-seed", 1)?;
    let default_prefix: usize = flag_or(flags, "prefix-len", 0)?;
    // the first `prefix_len` prompt rows come from a per-seed stream
    // shared across requests, so they are bitwise identical — the
    // admission scan in the scheduler rediscovers them from the floats
    let mk = |id: u64, p_len: usize, n_gen: usize, stream_seed: u64, prefix_len: usize| {
        let shared = prefix_len.min(p_len) * d;
        let mut prompt = vec![0.0f32; p_len * d];
        Rng::stream(stream_seed, "serve-prefix").fill_normal(&mut prompt[..shared], 1.0);
        Rng::stream(stream_seed, &format!("serve-req-{id}"))
            .fill_normal(&mut prompt[shared..], 1.0);
        ServeRequest { id, prompt, n_gen }
    };
    let requests: Vec<ServeRequest> = if let Some(path) = flags.get("requests-file") {
        // one request per line: "prompt_len gen_len [seed] [prefix=N]";
        // '-' = stdin
        let text = if path == "-" {
            use std::io::Read;
            let mut s = String::new();
            std::io::stdin().read_to_string(&mut s)?;
            s
        } else {
            std::fs::read_to_string(path)?
        };
        let mut reqs = vec![];
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let bad = || {
                quanta_ft::Error::msg(format!(
                    "requests line {}: want 'prompt_len gen_len [seed] [prefix=N] [nan]', \
                     got '{line}'",
                    ln + 1
                ))
            };
            let mut fields: Vec<&str> = line.split_whitespace().collect();
            // trailing 'nan' marker: poison one prompt element to
            // exercise the per-request error domain end to end
            let poison = fields.last() == Some(&"nan");
            if poison {
                fields.pop();
            }
            // 'prefix=N': this row's first N prompt rows come from the
            // shared per-seed prefix stream (anywhere after the two
            // required fields)
            let mut prefix_len = default_prefix;
            if let Some(p) = fields.iter().position(|f| f.starts_with("prefix=")) {
                prefix_len = fields.remove(p)["prefix=".len()..].parse().map_err(|_| bad())?;
            }
            if fields.len() < 2 || fields.len() > 3 {
                return Err(bad());
            }
            let p_len: usize = fields[0].parse().map_err(|_| bad())?;
            let n_gen: usize = fields[1].parse().map_err(|_| bad())?;
            let s: u64 = match fields.get(2) {
                Some(f) => f.parse().map_err(|_| bad())?,
                None => req_seed,
            };
            let mut r = mk(reqs.len() as u64, p_len, n_gen, s, prefix_len);
            if poison {
                if let Some(v) = r.prompt.first_mut() {
                    *v = f32::NAN;
                }
            }
            reqs.push(r);
        }
        reqs
    } else {
        let n: usize = flag_or(flags, "requests", 16)?;
        let p_len: usize = flag_or(flags, "prompt-len", seq)?;
        let n_gen: usize = flag_or(flags, "gen-len", 8)?;
        (0..n as u64).map(|id| mk(id, p_len, n_gen, req_seed, default_prefix)).collect()
    };

    let streaming_only = flags.contains_key("streaming");
    let verify = !flags.contains_key("no-verify") && !streaming_only;
    let deployment = if streaming_only {
        ServeModel::streaming(&model)
    } else {
        ServeModel::merged(&model)?
    };
    // SIGTERM/ctrl-c starts a graceful drain rather than killing the
    // process mid-step: admission stops, in-flight requests finish
    // under their deadlines, the queue is shed, stats still print
    let drain_latch = install_drain_handler();
    let sched = BatchScheduler::with_config(deployment, serve_cfg)?;
    let (outputs, stats) = sched
        .run_with_drain(requests.clone(), |_| drain_latch.load(std::sync::atomic::Ordering::Relaxed))?;
    let n_req = outputs.len();
    // latency over completed requests only — rejected/shed requests
    // never became resident, quarantined ones would skew the mean
    let completed: Vec<_> = outputs.iter().filter(|o| o.result.is_ok()).collect();
    let mean_latency: f64 = completed.iter().map(|o| o.steps_resident() as f64).sum::<f64>()
        / completed.len().max(1) as f64;
    let max_latency = completed.iter().map(|o| o.steps_resident()).max().unwrap_or(0);
    let mut t = Table::new(&["metric", "value"]);
    let mode = if streaming_only { "streaming" } else { "merged" };
    t.row(vec!["mode".into(), mode.into()]);
    t.row(vec!["requests served".into(), n_req.to_string()]);
    t.row(vec!["completed".into(), stats.completed.to_string()]);
    t.row(vec!["failed".into(), stats.failed.to_string()]);
    t.row(vec!["shed".into(), stats.shed.to_string()]);
    t.row(vec!["decode steps".into(), stats.steps.to_string()]);
    t.row(vec!["tokens processed".into(), stats.tokens.to_string()]);
    t.row(vec!["peak batch".into(), stats.peak_batch.to_string()]);
    t.row(vec!["peak kv pages".into(), stats.pages_in_use.to_string()]);
    t.row(vec!["peak kv bytes".into(), stats.resident_kv_bytes.to_string()]);
    t.row(vec!["prefix fork admissions".into(), stats.prefix_hits.to_string()]);
    t.row(vec!["shared prefix pages".into(), stats.shared_prefix_pages.to_string()]);
    t.row(vec!["wallclock (s)".into(), format!("{:.3}", stats.wallclock_s)]);
    t.row(vec!["throughput (tokens/s)".into(), format!("{:.0}", stats.tokens_per_s())]);
    t.row(vec!["mean latency (steps)".into(), format!("{mean_latency:.1}")]);
    t.row(vec!["max latency (steps)".into(), max_latency.to_string()]);
    t.row(vec!["drained".into(), stats.drained.to_string()]);
    t.print();
    // per-request error domains: failures are reported, not fatal —
    // the healthy requests above completed bitwise-unaffected
    if stats.failed + stats.shed > 0 {
        let mut et = Table::new(&["request", "error"]);
        for o in outputs.iter().filter(|o| o.result.is_err()) {
            if let Some(e) = o.error() {
                et.row(vec![o.id.to_string(), e.to_string()]);
            }
        }
        et.print();
    }
    if verify {
        // the zero-overhead contract, end to end: merged serving must
        // reproduce the streaming adapter forward request for request.
        // Compare only requests that completed in BOTH runs — failed
        // requests carry errors, not panels (their variants still have
        // to agree, or one deployment dropped a request silently).
        let streamed = BatchScheduler::with_config(ServeModel::streaming(&model), serve_cfg)?;
        let (stream_out, stream_stats) = streamed.run(requests)?;
        let mut max_diff = 0.0f32;
        let mut scale = 1.0f32;
        for (m, s) in outputs.iter().zip(&stream_out) {
            if m.result.is_err() || s.result.is_err() {
                continue;
            }
            let (mg, sg) = (m.generated().unwrap_or(&[]), s.generated().unwrap_or(&[]));
            for (a, b) in mg.iter().zip(sg) {
                max_diff = max_diff.max((a - b).abs());
                scale = scale.max(b.abs());
            }
        }
        // 1e-5 relative to the generated-panel scale, floored at 1
        // (same contract as model_props / serve_props)
        if max_diff >= 1e-5 * scale {
            return Err(quanta_ft::Error::msg(format!(
                "merged-vs-streaming serving parity violated: max diff {max_diff:e} \
                 at panel scale {scale:e}"
            )));
        }
        let speedup = stream_stats.wallclock_s / stats.wallclock_s.max(1e-12);
        println!(
            "merged-vs-streaming parity: max |diff| = {max_diff:.2e} (< 1e-5 x scale \
             {scale:.1}); merged serving {speedup:.2}x over streaming"
        );
    }
    // per-request failures are normally reported, not fatal (the
    // fault-smoke job depends on exit 0); --strict flips that so
    // pipelines can gate on a clean serve
    if flags.contains_key("strict") && stats.failed + stats.shed > 0 {
        return Err(quanta_ft::Error::msg(format!(
            "--strict: {} failed and {} shed requests",
            stats.failed, stats.shed
        )));
    }
    Ok(())
}
