//! Host models built on the pure-rust QuanTA engine (DESIGN.md §9).
//!
//! The gradient engine (`quanta::grad`) trains one circuit; this layer
//! assembles circuits into *models*: [`AdapterSet`] puts any number of
//! per-projection adapters behind one flat optimizer layout with stable
//! offsets, and [`TransformerBlock`] is a minimal pre-LN transformer
//! block (frozen Q/K/V/O + MLP + layernorms, causal softmax attention)
//! whose four projections are QuanTA-adapted — the paper's
//! one-circuit-per-attention-projection fine-tuning setup, end to end
//! on the host engine.  [`DeepModel`] stacks N such blocks behind one
//! flat layer-major layout (per-layer `AdapterSet` spans via the same
//! prefix-sum scheme), so depth is a config axis rather than a new
//! code path.
//!
//! [`TrainableModel`] is the contract the host trainer
//! (`coordinator::host_trainer::finetune_host`) drives: a flat
//! parameter view, a forward that records a tape, and a backward that
//! returns gradients in the same flat layout.  The single
//! [`QuantaAdapter`] and the full block implement it, so the same Adam
//! / LR-schedule / clipping / best-checkpoint loop trains either.

pub mod adapter_set;
pub mod block;
pub mod deep;

pub use adapter_set::AdapterSet;
pub use block::{BlockConfig, BlockTape, TransformerBlock};
pub use deep::{DeepConfig, DeepModel, DeepTape};

use crate::quanta::{CircuitTape, QuantaAdapter};
use crate::util::error::Result;

/// What the host trainer needs from a model: a flat parameter vector
/// (stable layout), a tape-recording forward over `n` examples, and a
/// backward producing flat gradients in the parameter layout.  Inputs
/// and outputs are row-major panels of `n · io_len()` floats.
pub trait TrainableModel {
    /// Opaque activation record handed from forward to backward.
    type Tape;

    /// Floats per example (input and output panels share this width).
    fn io_len(&self) -> usize;

    /// Trainable parameter count (`params_flat().len()`).
    fn param_count(&self) -> usize;

    /// Flat parameter vector — the optimizer layout.
    fn params_flat(&self) -> Vec<f32>;

    /// Write a flat parameter vector back (must round-trip with
    /// [`TrainableModel::params_flat`] exactly).
    fn set_params(&mut self, flat: &[f32]) -> Result<()>;

    /// Tape-free forward over `n` examples (validation path).
    fn forward(&self, xs: &[f32], n: usize) -> Result<Vec<f32>>;

    /// Forward over `n` examples, recording the activation tape.
    fn forward_with_tape(&self, xs: &[f32], n: usize) -> Result<(Vec<f32>, Self::Tape)>;

    /// Gradient of the loss w.r.t. the flat parameters, given
    /// `∂loss/∂output` over the forward's panel.
    fn backward_flat(&self, tape: &Self::Tape, grad_out: &[f32], n: usize) -> Result<Vec<f32>>;
}

/// The single free-standing adapter is the degenerate one-projection
/// model — `finetune_host` drives it unchanged through this impl.
impl TrainableModel for QuantaAdapter {
    type Tape = CircuitTape;

    fn io_len(&self) -> usize {
        self.d()
    }

    fn param_count(&self) -> usize {
        QuantaAdapter::param_count(self)
    }

    fn params_flat(&self) -> Vec<f32> {
        QuantaAdapter::params_flat(self)
    }

    fn set_params(&mut self, flat: &[f32]) -> Result<()> {
        QuantaAdapter::set_params(self, flat)
    }

    fn forward(&self, xs: &[f32], n: usize) -> Result<Vec<f32>> {
        self.apply_batch(xs, n)
    }

    fn forward_with_tape(&self, xs: &[f32], n: usize) -> Result<(Vec<f32>, CircuitTape)> {
        QuantaAdapter::forward_with_tape(self, xs, n)
    }

    fn backward_flat(&self, tape: &CircuitTape, grad_out: &[f32], n: usize) -> Result<Vec<f32>> {
        // gate gradients only — the trainer never consumes ∂loss/∂x
        self.backward_gates(tape, grad_out, n)
    }
}
