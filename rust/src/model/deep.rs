//! Depth-N host model: a stack of pre-LN [`TransformerBlock`]s behind
//! ONE flat parameter layout — the paper's actual fine-tuning shape
//! (QuanTA adapts every layer of a deep LLaMA, not one block in
//! isolation), reduced to the host substrate.
//!
//! ## Flat layout: the `AdapterSet` scheme, one level up
//!
//! [`AdapterSet`] lays adapters out by prefix sums of per-adapter
//! param counts; [`DeepModel`] applies the *same scheme per layer* —
//! `offsets[l]` is the running sum of per-layer
//! `adapters.param_count()`, so layer `l`'s span of the flat vector is
//! `offsets[l]..offsets[l+1]` and inside that span the PR 5 layout
//! property (insertion-order/shape-randomized, guarded by
//! `rust/tests/model_props.rs`) applies verbatim.  One flat vector
//! means `finetune_host` — Adam state, clipping, best-checkpoint
//! rollback, anomaly recovery — drives a depth-N model completely
//! unchanged through [`TrainableModel`].
//!
//! ## Layer-major backward, one-gate-wide memory
//!
//! The backward walks layers in *reverse*, feeding each layer's input
//! gradient to the one below ([`TransformerBlock::backward`] returns
//! `dx` precisely for this chain).  Within each layer the adapters
//! route through the gate-sharded sweep (`backward_with_shard`, PR 4),
//! so resident gradient memory stays one-gate-wide **regardless of
//! depth**: at any instant only one layer's one gate's gradient panel
//! is live beyond the flat accumulator.
//!
//! ## Determinism and depth-1 equivalence
//!
//! Layer `l` draws its frozen bases from the named RNG stream
//! `"block-base"` (layer 0) / `"block-base-{l}"` (deeper layers), so a
//! depth-1 [`DeepModel`] is **bitwise identical** — init, forward,
//! backward — to the bare [`TransformerBlock`] path every earlier PR
//! pinned (`rust/tests/deep_props.rs` asserts this exactly).  All
//! bitwise invariants (QFT_THREADS, dispatch mode, shard-vs-bulk)
//! lift to depth N because each layer is the already-pinned block.

use crate::model::block::{BlockConfig, BlockTape, TransformerBlock};
use crate::model::TrainableModel;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Shape of a deep model: one [`BlockConfig`] shared by every layer
/// (depth and per-layer adapter structure stay orthogonal — a future
/// per-layer-structure model changes this field to a `Vec` without
/// touching the layout scheme).
#[derive(Clone, Debug)]
pub struct DeepConfig {
    /// Per-layer block shape (dims/heads/seq/d_ff/structure/alpha).
    pub block: BlockConfig,
    /// Number of stacked blocks (≥ 1).
    pub depth: usize,
}

impl DeepConfig {
    /// Paper-default per-layer shape at the given depth.
    pub fn standard(dims: Vec<usize>, n_heads: usize, seq: usize, depth: usize) -> DeepConfig {
        DeepConfig { block: BlockConfig::standard(dims, n_heads, seq), depth }
    }

    pub fn with_block(mut self, block: BlockConfig) -> DeepConfig {
        self.block = block;
        self
    }
}

/// The name of layer `l`'s base-init RNG stream.  Layer 0 keeps the
/// single-block stream name so depth-1 init is bitwise the
/// [`TransformerBlock`] path.
pub fn layer_stream(base: &str, l: usize) -> String {
    if l == 0 {
        base.to_string()
    } else {
        format!("{base}-{l}")
    }
}

/// Everything the layer-major backward needs: one [`BlockTape`] per
/// layer (each tape alone reconstructs its layer's input gradient from
/// the gradient above — no inter-layer activations are kept).
pub struct DeepTape {
    pub n_seqs: usize,
    tapes: Vec<BlockTape>,
}

/// A stack of N blocks behind one flat parameter layout.
#[derive(Clone, Debug)]
pub struct DeepModel {
    layers: Vec<TransformerBlock>,
    /// Prefix sums of per-layer param counts (`depth + 1` entries) —
    /// the `AdapterSet` offset scheme, one level up.
    offsets: Vec<usize>,
}

impl DeepModel {
    /// Fresh depth-`cfg.depth` model: every layer has random frozen
    /// bases from its own named stream (see [`layer_stream`]) and
    /// identity-initialized adapters, so the step-0 forward is exactly
    /// the frozen stack.
    pub fn init(cfg: &DeepConfig, seed: u64) -> Result<DeepModel> {
        if cfg.depth == 0 {
            return Err(Error::Config("deep: depth must be >= 1".into()));
        }
        let layers = (0..cfg.depth)
            .map(|l| {
                let mut rng = Rng::stream(seed, &layer_stream("block-base", l));
                TransformerBlock::init(&cfg.block, &mut rng)
            })
            .collect::<Result<Vec<_>>>()?;
        DeepModel::from_layers(layers)
    }

    /// Stack pre-built blocks (must agree on `d` and `seq`).
    pub fn from_layers(layers: Vec<TransformerBlock>) -> Result<DeepModel> {
        if layers.is_empty() {
            return Err(Error::Config("deep: depth must be >= 1".into()));
        }
        let (d, seq) = (layers[0].d(), layers[0].seq());
        let mut offsets = Vec::with_capacity(layers.len() + 1);
        offsets.push(0);
        for (l, blk) in layers.iter().enumerate() {
            if blk.d() != d || blk.seq() != seq {
                return Err(Error::Config(format!(
                    "deep: layer {l} shape ({}, {}) != layer 0 shape ({d}, {seq})",
                    blk.d(),
                    blk.seq()
                )));
            }
            offsets.push(offsets[l] + blk.adapters().param_count());
        }
        Ok(DeepModel { layers, offsets })
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    pub fn d(&self) -> usize {
        self.layers[0].d()
    }

    pub fn seq(&self) -> usize {
        self.layers[0].seq()
    }

    pub fn layers(&self) -> &[TransformerBlock] {
        &self.layers
    }

    pub fn layer(&self, l: usize) -> &TransformerBlock {
        &self.layers[l]
    }

    /// Layer `l`'s span of the flat parameter/gradient vector.
    pub fn layer_span(&self, l: usize) -> (usize, usize) {
        (self.offsets[l], self.offsets[l + 1])
    }

    /// Re-draw every layer's projection circuits as `eye + N(0, std²)`
    /// from per-layer teacher streams — how the deep synthetic teacher
    /// is built (depth 1 consumes exactly the single-block
    /// `"block-teacher"` stream).
    pub fn randomize_circuits(&mut self, std: f32, seed: u64) -> Result<()> {
        for (l, blk) in self.layers.iter_mut().enumerate() {
            let mut rng = Rng::stream(seed, &layer_stream("block-teacher", l));
            blk.randomize_circuits(std, &mut rng)?;
        }
        Ok(())
    }

    /// The zero-inference-overhead stack: every layer merged
    /// (`AdapterSet::merged`), same forward code path.
    pub fn merged(&self) -> Result<DeepModel> {
        let layers = self
            .layers
            .iter()
            .map(|b| b.merged())
            .collect::<Result<Vec<_>>>()?;
        DeepModel::from_layers(layers)
    }

    /// Tape-free forward over `n_seqs` sequences of arbitrary length
    /// `seq`: each layer's [`TransformerBlock::forward`] chained.
    /// This is the full-recompute serving baseline the deep decode
    /// parity test pins against, exactly as the block's own `forward`
    /// is at depth 1.
    pub fn forward(&self, xs: &[f32], n_seqs: usize, seq: usize) -> Result<Vec<f32>> {
        let mut panel = self.layers[0].forward(xs, n_seqs, seq)?;
        for blk in &self.layers[1..] {
            panel = blk.forward(&panel, n_seqs, seq)?;
        }
        Ok(panel)
    }
}

impl TrainableModel for DeepModel {
    type Tape = DeepTape;

    fn io_len(&self) -> usize {
        self.seq() * self.d()
    }

    fn param_count(&self) -> usize {
        *self.offsets.last().expect("offsets never empty")
    }

    fn params_flat(&self) -> Vec<f32> {
        let mut flat = Vec::with_capacity(self.param_count());
        for blk in &self.layers {
            flat.extend_from_slice(&blk.adapters().params_flat());
        }
        flat
    }

    fn set_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.param_count() {
            return Err(Error::Shape(format!(
                "deep set_params: got {} params, layout holds {}",
                flat.len(),
                self.param_count()
            )));
        }
        for (l, blk) in self.layers.iter_mut().enumerate() {
            let (lo, hi) = (self.offsets[l], self.offsets[l + 1]);
            blk.set_params(&flat[lo..hi])?;
        }
        Ok(())
    }

    fn forward(&self, xs: &[f32], n: usize) -> Result<Vec<f32>> {
        DeepModel::forward(self, xs, n, self.seq())
    }

    fn forward_with_tape(&self, xs: &[f32], n: usize) -> Result<(Vec<f32>, DeepTape)> {
        let mut tapes = Vec::with_capacity(self.depth());
        let (mut panel, t0) = self.layers[0].forward_with_tape(xs, n)?;
        tapes.push(t0);
        for blk in &self.layers[1..] {
            let (next, t) = blk.forward_with_tape(&panel, n)?;
            panel = next;
            tapes.push(t);
        }
        Ok((panel, DeepTape { n_seqs: n, tapes }))
    }

    /// Layer-major reverse chain: top layer first, each layer's `dx`
    /// feeding the layer below; per-layer flat gradients land in their
    /// layout spans.  Within each layer the adapter backward routes
    /// through the gate-sharded sweep, so peak gradient residency is
    /// one gate of one layer no matter the depth.
    fn backward_flat(&self, tape: &DeepTape, grad_out: &[f32], n: usize) -> Result<Vec<f32>> {
        if tape.tapes.len() != self.depth() || tape.n_seqs != n {
            return Err(Error::Shape(format!(
                "deep backward: tape for {} layers / {} seqs, model has {} / {n}",
                tape.tapes.len(),
                tape.n_seqs,
                self.depth()
            )));
        }
        let mut flat = vec![0.0f32; self.param_count()];
        let mut grad = grad_out.to_vec();
        for l in (0..self.depth()).rev() {
            let (layer_flat, dx) = self.layers[l].backward(&tape.tapes[l], &grad, n)?;
            let (lo, hi) = (self.offsets[l], self.offsets[l + 1]);
            flat[lo..hi].copy_from_slice(&layer_flat);
            grad = dx;
        }
        Ok(flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_deep(depth: usize, seed: u64) -> DeepModel {
        let cfg = DeepConfig::standard(vec![2, 2], 2, 3, depth);
        DeepModel::init(&cfg, seed).unwrap()
    }

    #[test]
    fn layout_is_layer_major_prefix_sums() {
        let model = tiny_deep(3, 70);
        let per_layer = model.layer(0).adapters().param_count();
        assert_eq!(model.param_count(), 3 * per_layer);
        for l in 0..3 {
            assert_eq!(model.layer_span(l), (l * per_layer, (l + 1) * per_layer));
        }
        // round-trip: perturb one layer's span, others' params untouched
        let mut m = model.clone();
        let mut p = m.params_flat();
        let (lo, hi) = m.layer_span(1);
        for v in &mut p[lo..hi] {
            *v += 0.25;
        }
        m.set_params(&p).unwrap();
        assert_eq!(m.params_flat(), p);
        assert_eq!(
            m.layer(0).adapters().params_flat(),
            model.layer(0).adapters().params_flat()
        );
        assert!(m.set_params(&p[1..]).is_err());
    }

    #[test]
    fn identity_init_is_the_frozen_stack_and_merge_matches() {
        let model = tiny_deep(2, 71);
        let merged = model.merged().unwrap();
        let mut rng = Rng::new(710);
        let mut xs = vec![0.0f32; 2 * model.io_len()];
        rng.fill_normal(&mut xs, 1.0);
        let y = model.forward(&xs, 2, model.seq()).unwrap();
        let ym = merged.forward(&xs, 2, merged.seq()).unwrap();
        for (a, b) in y.iter().zip(&ym) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn forward_chains_layers_exactly() {
        let mut model = tiny_deep(2, 72);
        model.randomize_circuits(0.2, 72).unwrap();
        let mut rng = Rng::new(720);
        let mut xs = vec![0.0f32; model.io_len()];
        rng.fill_normal(&mut xs, 1.0);
        let seq = model.seq();
        let y = model.forward(&xs, 1, seq).unwrap();
        let h = model.layer(0).forward(&xs, 1, seq).unwrap();
        let want = model.layer(1).forward(&h, 1, seq).unwrap();
        assert_eq!(y, want);
        // taped forward is arithmetic-identical to the tape-free one
        let (yt, tape) = model.forward_with_tape(&xs, 1).unwrap();
        assert_eq!(y, yt);
        assert_eq!(tape.n_seqs, 1);
        // backward shape sanity: one gradient per parameter
        let ones = vec![1.0f32; y.len()];
        let g = model.backward_flat(&tape, &ones, 1).unwrap();
        assert_eq!(g.len(), model.param_count());
    }

    #[test]
    fn degenerate_configs_fail() {
        let cfg = DeepConfig::standard(vec![2, 2], 2, 3, 0);
        assert!(DeepModel::init(&cfg, 0).is_err());
        assert!(DeepModel::from_layers(vec![]).is_err());
        let a = tiny_deep(1, 73);
        let cfg_b = DeepConfig::standard(vec![2, 2], 2, 5, 1);
        let b = DeepModel::init(&cfg_b, 73).unwrap();
        let mixed = vec![a.layer(0).clone(), b.layer(0).clone()];
        assert!(DeepModel::from_layers(mixed).is_err());
    }
}
