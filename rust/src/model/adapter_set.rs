//! A set of named [`QuantaAdapter`]s behind one flat optimizer layout.
//!
//! The paper fine-tunes *one circuit per attention projection*
//! (Q/K/V/O), so the unit the optimizer sees is not a single adapter
//! but a stack of them.  `AdapterSet` owns the per-projection circuits
//! and exposes them as a single parameter vector with **stable
//! offsets**: entry order is fixed at construction, each adapter's
//! span is `offsets[i] .. offsets[i+1]`, and
//! `params_flat` / `set_params` / `flat_from_parts` all agree on that
//! layout — so Adam state, checkpoints, and gradient vectors never
//! need to know which projection a parameter belongs to.
//!
//! [`AdapterSet::merge_all`] folds every trained delta into its frozen
//! base (`W + α(full − I)` per adapter, paper Eq. 7) — the
//! zero-inference-overhead deployment of the whole stack.  The merged
//! set is pinned against the streaming adapter forward at `1e-5`
//! (including the α-residual fold path) by `rust/tests/model_props.rs`.

use crate::quanta::QuantaAdapter;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Named adapters + the prefix-sum table of their parameter spans.
#[derive(Clone, Debug)]
pub struct AdapterSet {
    entries: Vec<(String, QuantaAdapter)>,
    /// `offsets[i]` is where entry `i`'s parameters start in the flat
    /// layout; `offsets.last()` is the total count.  Computed once at
    /// construction — gate structure is fixed, so the spans are stable
    /// for the life of the set.
    offsets: Vec<usize>,
}

impl AdapterSet {
    /// Build a set from `(name, adapter)` pairs; flat-layout order is
    /// the given entry order.  Names must be unique (they key
    /// [`AdapterSet::get`] and the `merge_all` output).
    pub fn new(entries: Vec<(String, QuantaAdapter)>) -> Result<AdapterSet> {
        for (i, (name, _)) in entries.iter().enumerate() {
            if entries[..i].iter().any(|(n, _)| n == name) {
                return Err(Error::Config(format!("adapter set: duplicate name '{name}'")));
            }
        }
        let mut offsets = Vec::with_capacity(entries.len() + 1);
        let mut off = 0usize;
        offsets.push(0);
        for (_, a) in &entries {
            off += a.param_count();
            offsets.push(off);
        }
        Ok(AdapterSet { entries, offsets })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entry names in flat-layout order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn get(&self, name: &str) -> Option<&QuantaAdapter> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, a)| a)
    }

    /// Adapter by flat-layout index.
    pub fn adapter(&self, idx: usize) -> &QuantaAdapter {
        &self.entries[idx].1
    }

    /// Stable parameter span `[start, end)` of entry `idx` in the flat
    /// layout.
    pub fn span(&self, idx: usize) -> (usize, usize) {
        (self.offsets[idx], self.offsets[idx + 1])
    }

    /// Total trainable parameter count (`Σ` per-adapter circuit params).
    pub fn param_count(&self) -> usize {
        *self.offsets.last().unwrap_or(&0)
    }

    /// Concatenated per-adapter parameter vectors (entry 0 first) — the
    /// optimizer layout.
    pub fn params_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for (_, a) in &self.entries {
            out.extend_from_slice(&a.params_flat());
        }
        out
    }

    /// Write a flat parameter vector back through every adapter's
    /// `set_params` (plan snapshots refresh in place; memcpy cost).
    pub fn set_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.param_count() {
            return Err(Error::Shape(format!(
                "adapter set set_params: got {} values, set has {}",
                flat.len(),
                self.param_count()
            )));
        }
        for (i, (_, a)) in self.entries.iter_mut().enumerate() {
            let (s, e) = (self.offsets[i], self.offsets[i + 1]);
            a.set_params(&flat[s..e])?;
        }
        Ok(())
    }

    /// Assemble a flat gradient vector from per-adapter parts (one
    /// `Vec` per entry, in layout order) — the backward's counterpart
    /// of [`AdapterSet::params_flat`].
    pub fn flat_from_parts(&self, parts: &[Vec<f32>]) -> Result<Vec<f32>> {
        if parts.len() != self.entries.len() {
            return Err(Error::Shape(format!(
                "adapter set: {} gradient parts for {} adapters",
                parts.len(),
                self.entries.len()
            )));
        }
        let mut out = Vec::with_capacity(self.param_count());
        for (i, p) in parts.iter().enumerate() {
            let (s, e) = self.span(i);
            if p.len() != e - s {
                return Err(Error::Shape(format!(
                    "adapter set: part {i} has {} values, span wants {}",
                    p.len(),
                    e - s
                )));
            }
            out.extend_from_slice(p);
        }
        Ok(out)
    }

    /// Fold every adapter's delta into a dense weight:
    /// `(name, W + α(full − I))` per entry, in layout order.
    pub fn merge_all(&self) -> Result<Vec<(String, Tensor)>> {
        self.entries
            .iter()
            .map(|(n, a)| Ok((n.clone(), a.merge()?)))
            .collect()
    }

    /// The merged set: every base replaced by its merged weight, every
    /// circuit reset to identity gates — so the same streaming forward
    /// code path runs the zero-overhead deployment (identity gates make
    /// the residual exactly zero).
    pub fn merged(&self) -> Result<AdapterSet> {
        let entries = self
            .entries
            .iter()
            .map(|(n, a)| {
                let structure: Vec<(usize, usize)> =
                    a.circuit().gates().iter().map(|g| (g.m, g.n)).collect();
                let merged = QuantaAdapter::identity_init(
                    a.merge()?,
                    a.circuit().dims(),
                    &structure,
                    a.alpha,
                )?;
                Ok((n.clone(), merged))
            })
            .collect::<Result<Vec<_>>>()?;
        AdapterSet::new(entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quanta::circuit::{all_pairs_structure, Circuit};
    use crate::util::rng::Rng;

    fn mk_set(rng: &mut Rng) -> AdapterSet {
        let dims = [2usize, 3];
        let structure = all_pairs_structure(2);
        let entries = ["wq", "wk", "wv", "wo"]
            .iter()
            .map(|name| {
                let c = Circuit::random(&dims, &structure, 0.3, rng).unwrap();
                let base = Tensor::randn(&[6, 6], 0.4, rng);
                (name.to_string(), QuantaAdapter::new(base, c, 0.8).unwrap())
            })
            .collect();
        AdapterSet::new(entries).unwrap()
    }

    #[test]
    fn flat_layout_roundtrip_with_stable_offsets() {
        let mut rng = Rng::new(60);
        let mut set = mk_set(&mut rng);
        assert_eq!(set.len(), 4);
        assert_eq!(set.param_count(), 4 * 36);
        for i in 0..4 {
            assert_eq!(set.span(i), (i * 36, (i + 1) * 36));
        }
        let p = set.params_flat();
        assert_eq!(p.len(), set.param_count());
        // perturb one adapter's span; only that adapter changes
        let mut p2 = p.clone();
        p2[40] += 1.0; // inside span 1 ("wk")
        set.set_params(&p2).unwrap();
        assert_eq!(set.params_flat(), p2);
        let (s1, e1) = set.span(1);
        assert_eq!(&set.adapter(0).params_flat(), &p[..36]);
        assert_eq!(&set.adapter(1).params_flat(), &p2[s1..e1]);
        // round-trip back
        set.set_params(&p).unwrap();
        assert_eq!(set.params_flat(), p);
    }

    #[test]
    fn flat_from_parts_matches_spans() {
        let mut rng = Rng::new(61);
        let set = mk_set(&mut rng);
        let parts: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; 36]).collect();
        let flat = set.flat_from_parts(&parts).unwrap();
        for i in 0..4 {
            let (s, e) = set.span(i);
            assert!(flat[s..e].iter().all(|&v| v == i as f32));
        }
        assert!(set.flat_from_parts(&parts[..3]).is_err());
        let mut bad = parts.clone();
        bad[2].pop();
        assert!(set.flat_from_parts(&bad).is_err());
    }

    #[test]
    fn merged_set_matches_streaming_forward() {
        let mut rng = Rng::new(62);
        let set = mk_set(&mut rng);
        let merged = set.merged().unwrap();
        let mut xs = vec![0.0f32; 5 * 6];
        rng.fill_normal(&mut xs, 1.0);
        for i in 0..set.len() {
            let y_stream = set.adapter(i).apply_batch(&xs, 5).unwrap();
            let y_merged = merged.adapter(i).apply_batch(&xs, 5).unwrap();
            for (a, b) in y_stream.iter().zip(&y_merged) {
                assert!((a - b).abs() < 1e-5, "adapter {i}: {a} vs {b}");
            }
        }
        // merge_all names/weights line up with merged() bases
        let weights = set.merge_all().unwrap();
        assert_eq!(
            weights.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            set.names()
        );
        for (i, (_, w)) in weights.iter().enumerate() {
            assert_eq!(&merged.adapter(i).base().data, &w.data);
        }
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut rng = Rng::new(63);
        let c = Circuit::random(&[2usize, 2], &[(0, 1)], 0.1, &mut rng).unwrap();
        let a = QuantaAdapter::new(Tensor::eye(4), c, 1.0).unwrap();
        let entries = vec![("wq".to_string(), a.clone()), ("wq".to_string(), a)];
        assert!(AdapterSet::new(entries).is_err());
    }
}
