//! A minimal pre-LN transformer block hosting per-projection QuanTA
//! circuits — the paper's headline fine-tuning target (one circuit per
//! attention projection), reduced to the smallest host model the pure
//! rust engine can train end to end.
//!
//! ```text
//! x1  = x  + O(attn(Q(h), K(h), V(h))),   h = LN1(x)
//! out = x1 + W2 · gelu(W1 · LN2(x1) + b1) + b2
//! ```
//!
//! Every base weight — the Q/K/V/O projections, the 2-layer MLP, the
//! layernorm affines — is **frozen**; the only trainable state is the
//! [`AdapterSet`] wrapping the four projections
//! (`y = W x + α (circuit(x) − x)` per projection, identity-initialized
//! so the block starts exactly at its frozen forward).  Attention is
//! causal softmax over short sequences; activations flow as row-major
//! `[n_seqs · seq, d]` panels so the adapters' batched circuit engine
//! (and its pooled, `QFT_THREADS`-invariant kernels) does all the heavy
//! lifting.  Attention/layernorm/GELU loops are serial with fixed
//! ascending accumulation order — `seq` is small by construction, and
//! serial order keeps the whole block bitwise thread-invariant.
//!
//! [`TransformerBlock::backward`] is a full hand-derived reverse pass
//! (MLP → LN2 → O-adapter → softmax attention → Q/K/V adapters → LN1)
//! returning flat gate gradients in the [`AdapterSet`] layout plus the
//! input gradient; `rust/tests/model_props.rs` checks it against
//! central finite differences through the entire block.
//!
//! The serving layer (`crate::serve`, DESIGN.md §10) reuses the exact
//! per-row pieces of this forward — [`layer_norm`], [`gelu`],
//! [`attn_row`], and the borrowing GEMM the MLP runs on — so the
//! KV-cache decode step is arithmetic-identical to this panel forward
//! row for row; [`TransformerBlock::forward`] takes the sequence
//! length explicitly, so the same entry is both the training-shape
//! forward and the arbitrary-length full-recompute forward the decode
//! parity tests and the serving baseline score against.

use crate::compute::gemm;
use crate::model::adapter_set::AdapterSet;
use crate::model::TrainableModel;
use crate::quanta::circuit::{all_pairs_structure, Circuit};
use crate::quanta::{CircuitTape, QuantaAdapter};
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Layernorm variance floor (the usual 1e-5).
const LN_EPS: f32 = 1e-5;

/// GELU tanh-approximation constants (`√(2/π)`, the cubic coefficient).
const GELU_C: f32 = 0.797_884_6;
const GELU_A: f32 = 0.044_715;

/// Shape of a block: circuit tensorization of the model width, head
/// count, sequence length, MLP width, and the shared adapter
/// hyper-parameters.
#[derive(Clone, Debug)]
pub struct BlockConfig {
    /// Tensorization of `d_model` (`d = Π dims`), shared by all four
    /// projection circuits.
    pub dims: Vec<usize>,
    pub n_heads: usize,
    /// Sequence length; one training example is a whole sequence
    /// (`seq · d` floats).
    pub seq: usize,
    /// MLP hidden width.
    pub d_ff: usize,
    /// Gate structure per projection circuit.
    pub structure: Vec<(usize, usize)>,
    /// Adapter delta scale `α`, shared by all projections.
    pub alpha: f32,
}

impl BlockConfig {
    /// The paper-default shape: all-pairs structure, `d_ff = 2 d`.
    /// Deviations compose builder-style —
    /// `BlockConfig::standard(dims, heads, seq).with_alpha(0.7)` — so
    /// call sites (and `DeepConfig`, which embeds a `BlockConfig` per
    /// layer) never churn on positional fields.
    pub fn standard(dims: Vec<usize>, n_heads: usize, seq: usize) -> BlockConfig {
        let d: usize = dims.iter().product();
        BlockConfig {
            structure: all_pairs_structure(dims.len()),
            dims,
            n_heads,
            seq,
            d_ff: 2 * d,
            alpha: 1.0,
        }
    }

    pub fn with_heads(mut self, n_heads: usize) -> BlockConfig {
        self.n_heads = n_heads;
        self
    }

    pub fn with_seq(mut self, seq: usize) -> BlockConfig {
        self.seq = seq;
        self
    }

    pub fn with_d_ff(mut self, d_ff: usize) -> BlockConfig {
        self.d_ff = d_ff;
        self
    }

    pub fn with_alpha(mut self, alpha: f32) -> BlockConfig {
        self.alpha = alpha;
        self
    }

    pub fn with_structure(mut self, structure: Vec<(usize, usize)>) -> BlockConfig {
        self.structure = structure;
        self
    }
}

/// Everything [`TransformerBlock::backward`] needs: the activations
/// entering each nonlinearity plus the four adapters' circuit tapes.
#[derive(Clone, Debug)]
pub struct BlockTape {
    pub n_seqs: usize,
    /// LN1 normalized activations + reciprocal stds.
    xhat1: Vec<f32>,
    rstd1: Vec<f32>,
    /// Per-projection circuit tapes (Q, K, V on LN1 output; O on ctx).
    tq: CircuitTape,
    tk: CircuitTape,
    tv: CircuitTape,
    t_o: CircuitTape,
    /// Projection outputs `[B, d]`.
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Softmax rows, `[n_seqs, n_heads, seq, seq]` (strictly causal:
    /// `probs[t, t'] = 0` for `t' > t`).
    probs: Vec<f32>,
    /// LN2 normalized activations + reciprocal stds.
    xhat2: Vec<f32>,
    rstd2: Vec<f32>,
    /// MLP pre-activation `[B, d_ff]` (GELU and its derivative are
    /// recomputed from it).
    u: Vec<f32>,
}

/// The host model: frozen block weights + the trainable adapter set.
/// Fields are `pub(crate)` so the serving layer (`crate::serve`) can
/// snapshot the frozen weights without a parallel accessor zoo; all
/// *mutation* still flows through [`TransformerBlock::set_params`].
#[derive(Clone, Debug)]
pub struct TransformerBlock {
    pub(crate) d: usize,
    pub(crate) n_heads: usize,
    pub(crate) head_dim: usize,
    pub(crate) seq: usize,
    pub(crate) d_ff: usize,
    /// Q/K/V/O adapters, flat-layout order `["wq","wk","wv","wo"]`.
    pub(crate) adapters: AdapterSet,
    /// MLP weights (`w1: [d_ff, d]`, `w2: [d, d_ff]`) with cached
    /// transposes for the row-major batched forward.
    pub(crate) w1: Tensor,
    pub(crate) w1_t: Tensor,
    pub(crate) b1: Vec<f32>,
    pub(crate) w2: Tensor,
    pub(crate) w2_t: Tensor,
    pub(crate) b2: Vec<f32>,
    pub(crate) ln1_g: Vec<f32>,
    pub(crate) ln1_b: Vec<f32>,
    pub(crate) ln2_g: Vec<f32>,
    pub(crate) ln2_b: Vec<f32>,
}

/// Rowwise layernorm over a `[rows, d]` panel; returns `(y, xhat,
/// rstd)` — the normalized activations and reciprocal stds feed the
/// backward.  Serial ascending sums: deterministic and thread-free.
/// `pub(crate)`: the serving decode step normalizes its one-row-per-
/// request panels through this exact function.
pub(crate) fn layer_norm(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let rows = x.len() / d;
    let mut y = vec![0.0f32; x.len()];
    let mut xhat = vec![0.0f32; x.len()];
    let mut rstd = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mean = 0.0f32;
        for &v in xr {
            mean += v;
        }
        mean /= d as f32;
        let mut var = 0.0f32;
        for &v in xr {
            var += (v - mean) * (v - mean);
        }
        var /= d as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        let xh = &mut xhat[r * d..(r + 1) * d];
        let yr = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            xh[j] = (xr[j] - mean) * rs;
            yr[j] = gamma[j] * xh[j] + beta[j];
        }
    }
    (y, xhat, rstd)
}

/// Forward-only rowwise layernorm into caller-owned scratch (`y` of
/// `x.len()`), skipping the `xhat`/`rstd` tape the backward needs.
/// The per-row arithmetic — mean, variance, `x̂ = (x − mean)·rstd`,
/// `y = γ·x̂ + β`, all serial ascending — is kept literally identical
/// to [`layer_norm`], so the serving decode path that reuses scratch
/// through this entry stays bitwise equal to one that allocates.
pub(crate) fn layer_norm_into(x: &[f32], gamma: &[f32], beta: &[f32], d: usize, y: &mut [f32]) {
    debug_assert_eq!(y.len(), x.len());
    let rows = x.len() / d;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mean = 0.0f32;
        for &v in xr {
            mean += v;
        }
        mean /= d as f32;
        let mut var = 0.0f32;
        for &v in xr {
            var += (v - mean) * (v - mean);
        }
        var /= d as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        let yr = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            let xh = (xr[j] - mean) * rs;
            yr[j] = gamma[j] * xh + beta[j];
        }
    }
}

/// Layernorm backward (frozen affine — no `γ`/`β` gradients):
/// `dx = rstd · (dŷ − mean(dŷ) − x̂ · mean(dŷ ⊙ x̂))`, `dŷ = dy ⊙ γ`.
fn layer_norm_backward(
    dy: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    gamma: &[f32],
    d: usize,
) -> Vec<f32> {
    let rows = dy.len() / d;
    let mut dx = vec![0.0f32; dy.len()];
    let mut dxh = vec![0.0f32; d];
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xh = &xhat[r * d..(r + 1) * d];
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for j in 0..d {
            dxh[j] = dyr[j] * gamma[j];
            m1 += dxh[j];
            m2 += dxh[j] * xh[j];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            dxr[j] = rstd[r] * (dxh[j] - m1 - xh[j] * m2);
        }
    }
    dx
}

/// GELU (tanh approximation) — smooth, so central finite differences
/// through the block converge cleanly.  Shared with the serving decode
/// step's MLP.
#[inline]
pub(crate) fn gelu(u: f32) -> f32 {
    let g = GELU_C * (u + GELU_A * u * u * u);
    0.5 * u * (1.0 + g.tanh())
}

#[inline]
fn gelu_prime(u: f32) -> f32 {
    let g = GELU_C * (u + GELU_A * u * u * u);
    let t = g.tanh();
    0.5 * (1.0 + t) + 0.5 * u * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * u * u)
}

/// One query row of causal softmax attention against K/V rows
/// `0..=t` of a single head: scores (ascending `t2`, max tracked) →
/// max-subtracted exp + denominator → probabilities into `prow`
/// (`len t+1`) → probability-weighted V accumulation into `crow`
/// (`len hd`, pre-zeroed).  K/V row `t2` lives at
/// `t2 · row_stride + head_off`; `scores` is caller scratch of
/// `len ≥ t+1`.
///
/// This is the *entire* data-dependent part of attention, factored out
/// as the serial float-program reference for the decode-parity
/// guarantee.  The full panel forward ([`TransformerBlock::attention`])
/// calls it directly; the KV-cache decode step runs a K-cache-major
/// batched twin (`serve::decode::batched_attn`, DESIGN.md §15) whose
/// float program is *derived* from this kernel — same multiplies, same
/// adds, same order per query row — so decode output is bitwise equal
/// to this reference, not merely close.  The body is
/// [`attn_row_segs`] over a single contiguous segment.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attn_row(
    qrow: &[f32],
    k: &[f32],
    v: &[f32],
    row_stride: usize,
    head_off: usize,
    t: usize,
    scale: f32,
    scores: &mut [f32],
    prow: &mut [f32],
    crow: &mut [f32],
) {
    let seg = std::iter::once((k, v, t + 1));
    attn_row_segs(qrow, seg, row_stride, head_off, t, scale, scores, prow, crow);
}

/// [`attn_row`] generalized over a segmented K/V history: `segs`
/// yields `(k_rows, v_rows, rows_in_segment)` contiguous chunks in
/// logical order (row `r` of a segment lives at
/// `r · row_stride + head_off`), together covering at least `t + 1`
/// rows; the iterator is walked twice (scores pass, then the V
/// accumulation) and so must be `Clone`.
///
/// The float operations and their order are *identical* to the
/// single-segment case — scores ascending with running max, one
/// exp/denominator sweep, ascending probability-weighted V adds — so
/// splitting a history across pages (`serve::kv`) changes no output
/// bit at any page size.  The batched serving kernel
/// (`serve::decode::batched_attn`) replays exactly this op order per
/// query row from pooled GEMM panels; any change to the sweep
/// structure here must be mirrored there to keep the two bitwise
/// twins.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attn_row_segs<'a, I>(
    qrow: &[f32],
    segs: I,
    row_stride: usize,
    head_off: usize,
    t: usize,
    scale: f32,
    scores: &mut [f32],
    prow: &mut [f32],
    crow: &mut [f32],
) where
    I: Iterator<Item = (&'a [f32], &'a [f32], usize)> + Clone,
{
    let hd = qrow.len();
    let mut maxv = f32::NEG_INFINITY;
    let mut t2 = 0usize;
    'score: for (kseg, _, rows) in segs.clone() {
        for r in 0..rows {
            if t2 > t {
                break 'score;
            }
            let kr = r * row_stride + head_off;
            let krow = &kseg[kr..kr + hd];
            let mut dot = 0.0f32;
            for (a, b) in qrow.iter().zip(krow) {
                dot += a * b;
            }
            scores[t2] = dot * scale;
            maxv = maxv.max(scores[t2]);
            t2 += 1;
        }
    }
    debug_assert!(t2 > t, "attn_row_segs: segments cover {t2} rows, need {}", t + 1);
    let mut denom = 0.0f32;
    for slot in scores.iter_mut().take(t + 1) {
        *slot = (*slot - maxv).exp();
        denom += *slot;
    }
    for (p, &e) in prow.iter_mut().zip(scores.iter()) {
        *p = e / denom;
    }
    let mut t2 = 0usize;
    'accum: for (_, vseg, rows) in segs {
        for r in 0..rows {
            if t2 > t {
                break 'accum;
            }
            let p = prow[t2];
            let vr = r * row_stride + head_off;
            let vrow = &vseg[vr..vr + hd];
            for (c, &vv) in crow.iter_mut().zip(vrow) {
                *c += p * vv;
            }
            t2 += 1;
        }
    }
}

/// MLP forward on a borrowed `[rows, d]` panel:
/// `gelu(h2 · W1ᵀ + b1) · W2ᵀ + b2`, returning `(m, u)` with `u` the
/// pre-activation.  Multiplies straight out of the panel
/// (`compute::gemm`) — same kernel and chunking as the old
/// owned-Tensor wrap, minus the full-panel `to_vec` copy per call.
/// Shared — like [`attn_row`] — by the block forward and the serving
/// decode step, so the two paths stay instruction-identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn mlp_panel(
    h2: &[f32],
    rows: usize,
    w1_t: &Tensor,
    b1: &[f32],
    w2_t: &Tensor,
    b2: &[f32],
    d: usize,
    d_ff: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut u = vec![0.0f32; rows * d_ff];
    let mut a = vec![0.0f32; rows * d_ff];
    let mut m = vec![0.0f32; rows * d];
    mlp_panel_into(h2, rows, w1_t, b1, w2_t, b2, d, d_ff, &mut u, &mut a, &mut m);
    (m, u)
}

/// [`mlp_panel`] into caller-owned, pre-zeroed scratch: `u` and `a`
/// of `rows × d_ff` (pre-activation and GELU), `m` of `rows × d` (the
/// output).  One body shared by the allocating wrapper and the
/// serving decode scratch path, so kernel and bit pattern are
/// identical either way.
#[allow(clippy::too_many_arguments)]
pub(crate) fn mlp_panel_into(
    h2: &[f32],
    rows: usize,
    w1_t: &Tensor,
    b1: &[f32],
    w2_t: &Tensor,
    b2: &[f32],
    d: usize,
    d_ff: usize,
    u: &mut [f32],
    a: &mut [f32],
    m: &mut [f32],
) {
    gemm::gemm_into(h2, &w1_t.data, u, d, d_ff);
    for r in 0..rows {
        let urow = &mut u[r * d_ff..(r + 1) * d_ff];
        for (uv, &b) in urow.iter_mut().zip(b1) {
            *uv += b;
        }
    }
    for (av, &uv) in a.iter_mut().zip(u.iter()) {
        *av = gelu(uv);
    }
    gemm::gemm_into(a, &w2_t.data, m, d_ff, d);
    for r in 0..rows {
        let mrow = &mut m[r * d..(r + 1) * d];
        for (mv, &b) in mrow.iter_mut().zip(b2) {
            *mv += b;
        }
    }
}

impl TransformerBlock {
    /// Fresh block with random frozen bases (scaled `1/√fan_in`) and
    /// identity-initialized adapters — the training init: the block's
    /// step-0 forward is exactly its frozen forward.
    pub fn init(cfg: &BlockConfig, rng: &mut Rng) -> Result<TransformerBlock> {
        let d: usize = cfg.dims.iter().product();
        if cfg.n_heads == 0 || d % cfg.n_heads != 0 {
            return Err(Error::Config(format!(
                "block: d {d} not divisible by n_heads {}",
                cfg.n_heads
            )));
        }
        if cfg.seq == 0 || cfg.d_ff == 0 {
            return Err(Error::Config(format!(
                "block: degenerate seq {} / d_ff {}",
                cfg.seq, cfg.d_ff
            )));
        }
        let proj_std = 1.0 / (d as f32).sqrt();
        let entries = ["wq", "wk", "wv", "wo"]
            .iter()
            .map(|name| {
                let base = Tensor::randn(&[d, d], proj_std, rng);
                let a = QuantaAdapter::identity_init(base, &cfg.dims, &cfg.structure, cfg.alpha)?;
                Ok((name.to_string(), a))
            })
            .collect::<Result<Vec<_>>>()?;
        let w1 = Tensor::randn(&[cfg.d_ff, d], proj_std, rng);
        let w2 = Tensor::randn(&[d, cfg.d_ff], 1.0 / (cfg.d_ff as f32).sqrt(), rng);
        Ok(TransformerBlock {
            d,
            n_heads: cfg.n_heads,
            head_dim: d / cfg.n_heads,
            seq: cfg.seq,
            d_ff: cfg.d_ff,
            adapters: AdapterSet::new(entries)?,
            w1_t: w1.t()?,
            w1,
            b1: vec![0.0; cfg.d_ff],
            w2_t: w2.t()?,
            w2,
            b2: vec![0.0; d],
            ln1_g: vec![1.0; d],
            ln1_b: vec![0.0; d],
            ln2_g: vec![1.0; d],
            ln2_b: vec![0.0; d],
        })
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    /// The per-projection adapter set (read-only; mutate through
    /// [`TransformerBlock::set_params`]).
    pub fn adapters(&self) -> &AdapterSet {
        &self.adapters
    }

    /// Re-draw every projection circuit as `eye + N(0, std²)` — how the
    /// synthetic teacher is built from the shared frozen bases.
    pub fn randomize_circuits(&mut self, std: f32, rng: &mut Rng) -> Result<()> {
        let mut parts = Vec::with_capacity(self.adapters.len());
        for i in 0..self.adapters.len() {
            let a = self.adapters.adapter(i);
            let structure: Vec<(usize, usize)> =
                a.circuit().gates().iter().map(|g| (g.m, g.n)).collect();
            let c = Circuit::random(a.circuit().dims(), &structure, std, rng)?;
            let mut flat = Vec::with_capacity(a.param_count());
            for g in c.gates() {
                flat.extend_from_slice(&g.mat.data);
            }
            parts.push(flat);
        }
        let flat = self.adapters.flat_from_parts(&parts)?;
        self.adapters.set_params(&flat)
    }

    /// Fold every projection delta into its frozen base
    /// (`AdapterSet::merge_all`), in flat-layout order.
    pub fn merge_all(&self) -> Result<Vec<(String, Tensor)>> {
        self.adapters.merge_all()
    }

    /// The zero-inference-overhead block: merged projection weights,
    /// identity circuits — same forward code path, pinned against the
    /// streaming forward at `1e-5` by `rust/tests/model_props.rs`.
    pub fn merged(&self) -> Result<TransformerBlock> {
        let mut out = self.clone();
        out.adapters = self.adapters.merged()?;
        Ok(out)
    }

    fn check_panel(&self, xs: &[f32], n_seqs: usize, what: &str) -> Result<usize> {
        let want = n_seqs * self.seq * self.d;
        if xs.len() != want {
            return Err(Error::Shape(format!(
                "block {what}: panel len {} != n_seqs {n_seqs} * seq {} * d {}",
                xs.len(),
                self.seq,
                self.d
            )));
        }
        Ok(n_seqs * self.seq)
    }

    /// Causal softmax attention over per-head slices of `q`/`k`/`v`
    /// (`[n_seqs · seq, d]` panels); returns `(ctx, probs)`.  The
    /// per-row work is [`attn_row`] — shared with the decode step —
    /// and `seq` is a parameter (not `self.seq`) so
    /// [`TransformerBlock::forward`] can score arbitrary lengths.
    fn attention(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n_seqs: usize,
        seq: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let (d, hd) = (self.d, self.head_dim);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut probs = vec![0.0f32; n_seqs * self.n_heads * seq * seq];
        let mut ctx = vec![0.0f32; q.len()];
        let mut scores = vec![0.0f32; seq];
        for s in 0..n_seqs {
            let base = s * seq * d;
            for h in 0..self.n_heads {
                let pbase = (s * self.n_heads + h) * seq * seq;
                for t in 0..seq {
                    let row = base + t * d + h * hd;
                    attn_row(
                        &q[row..row + hd],
                        &k[base..],
                        &v[base..],
                        d,
                        h * hd,
                        t,
                        scale,
                        &mut scores,
                        &mut probs[pbase + t * seq..pbase + t * seq + t + 1],
                        &mut ctx[row..row + hd],
                    );
                }
            }
        }
        (ctx, probs)
    }

    /// Backward through the causal softmax attention: `dctx → (dq, dk,
    /// dv)` given the taped `probs`/`q`/`k`/`v`.  Same serial loop nest
    /// as the forward, so gradients are deterministic by construction.
    fn attention_backward(&self, dctx: &[f32], tape: &BlockTape) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (d, hd, seq) = (self.d, self.head_dim, self.seq);
        let scale = 1.0 / (hd as f32).sqrt();
        let (q, k, v, probs) = (&tape.q, &tape.k, &tape.v, &tape.probs);
        let mut dq = vec![0.0f32; dctx.len()];
        let mut dk = vec![0.0f32; dctx.len()];
        let mut dv = vec![0.0f32; dctx.len()];
        let mut dp = vec![0.0f32; seq];
        for s in 0..tape.n_seqs {
            for h in 0..self.n_heads {
                let pbase = (s * self.n_heads + h) * seq * seq;
                for t in 0..seq {
                    let row = (s * seq + t) * d + h * hd;
                    let drow = &dctx[row..row + hd];
                    let prow = &probs[pbase + t * seq..pbase + t * seq + t + 1];
                    // dprobs[t2] = dctx · v(t2); dot = Σ dprobs ⊙ probs
                    let mut dot = 0.0f32;
                    for (t2, (slot, &p)) in dp.iter_mut().zip(prow).enumerate() {
                        let vr = (s * seq + t2) * d + h * hd;
                        let vrow = &v[vr..vr + hd];
                        let mut acc = 0.0f32;
                        for (a, b) in drow.iter().zip(vrow) {
                            acc += a * b;
                        }
                        *slot = acc;
                        dot += acc * p;
                    }
                    for (t2, &p) in prow.iter().enumerate() {
                        // softmax backward, with the score scale folded in
                        let ds = p * (dp[t2] - dot) * scale;
                        let kr = (s * seq + t2) * d + h * hd;
                        let qrow = &q[row..row + hd];
                        let krow = &k[kr..kr + hd];
                        let dqrow = &mut dq[row..row + hd];
                        for (g, &kv) in dqrow.iter_mut().zip(krow) {
                            *g += ds * kv;
                        }
                        let dkrow = &mut dk[kr..kr + hd];
                        for (g, &qv) in dkrow.iter_mut().zip(qrow) {
                            *g += ds * qv;
                        }
                        let dvrow = &mut dv[kr..kr + hd];
                        for (g, &dd) in dvrow.iter_mut().zip(drow) {
                            *g += p * dd;
                        }
                    }
                }
            }
        }
        (dq, dk, dv)
    }

    /// MLP forward: `gelu(h2 · W1ᵀ + b1) · W2ᵀ + b2`; returns `(m, u)`
    /// with `u` the pre-activation the backward differentiates through.
    fn mlp(&self, h2: &[f32], rows: usize) -> (Vec<f32>, Vec<f32>) {
        mlp_panel(h2, rows, &self.w1_t, &self.b1, &self.w2_t, &self.b2, self.d, self.d_ff)
    }

    /// Block forward over `n_seqs` sequences (`xs` row-major
    /// `[n_seqs · seq, d]`), recording the tape for
    /// [`TransformerBlock::backward`].
    pub fn forward_with_tape(&self, xs: &[f32], n_seqs: usize) -> Result<(Vec<f32>, BlockTape)> {
        let rows = self.check_panel(xs, n_seqs, "forward")?;
        let (h1, xhat1, rstd1) = layer_norm(xs, &self.ln1_g, &self.ln1_b, self.d);
        let (q, tq) = self.adapters.adapter(0).forward_with_tape(&h1, rows)?;
        let (k, tk) = self.adapters.adapter(1).forward_with_tape(&h1, rows)?;
        let (v, tv) = self.adapters.adapter(2).forward_with_tape(&h1, rows)?;
        let (ctx, probs) = self.attention(&q, &k, &v, n_seqs, self.seq);
        let (attn_out, t_o) = self.adapters.adapter(3).forward_with_tape(&ctx, rows)?;
        let mut x1 = xs.to_vec();
        for (o, &a) in x1.iter_mut().zip(&attn_out) {
            *o += a;
        }
        let (h2, xhat2, rstd2) = layer_norm(&x1, &self.ln2_g, &self.ln2_b, self.d);
        let (m, u) = self.mlp(&h2, rows);
        let mut out = x1; // x1 is not taped (backward rebuilds it from grad_out)
        for (o, &mv) in out.iter_mut().zip(&m) {
            *o += mv;
        }
        let tape = BlockTape {
            n_seqs,
            xhat1,
            rstd1,
            tq,
            tk,
            tv,
            t_o,
            q,
            k,
            v,
            probs,
            xhat2,
            rstd2,
            u,
        };
        Ok((out, tape))
    }

    /// Tape-free forward over `n_seqs` sequences of **arbitrary**
    /// length `seq` — identical arithmetic to
    /// [`TransformerBlock::forward_with_tape`] (the adapters' tape
    /// twins are arithmetic-identical by contract), but no activation
    /// panels are recorded or kept.  The training shape `self.seq`
    /// only constrains the taped/backward path, not the frozen
    /// arithmetic, so this single entry is both the validation/parity
    /// forward (`seq == self.seq`) and the full-recompute serving
    /// baseline: scoring a length-`t+1` prefix per generated token is
    /// what the KV-cache decode step (`serve::decode`) replaces, and
    /// what `rust/tests/serve_props.rs` pins the decode output against
    /// at every position.  (This absorbs the former `forward_len` —
    /// the one-twin-per-length API is gone.)
    pub fn forward(&self, xs: &[f32], n_seqs: usize, seq: usize) -> Result<Vec<f32>> {
        if seq == 0 || xs.len() != n_seqs * seq * self.d {
            return Err(Error::Shape(format!(
                "block forward: panel len {} != n_seqs {n_seqs} * seq {seq} * d {}",
                xs.len(),
                self.d
            )));
        }
        let rows = n_seqs * seq;
        let (h1, _, _) = layer_norm(xs, &self.ln1_g, &self.ln1_b, self.d);
        let q = self.adapters.adapter(0).apply_batch(&h1, rows)?;
        let k = self.adapters.adapter(1).apply_batch(&h1, rows)?;
        let v = self.adapters.adapter(2).apply_batch(&h1, rows)?;
        let (ctx, _) = self.attention(&q, &k, &v, n_seqs, seq);
        let attn_out = self.adapters.adapter(3).apply_batch(&ctx, rows)?;
        let mut x1 = xs.to_vec();
        for (o, &a) in x1.iter_mut().zip(&attn_out) {
            *o += a;
        }
        let (h2, _, _) = layer_norm(&x1, &self.ln2_g, &self.ln2_b, self.d);
        let (m, _) = self.mlp(&h2, rows);
        for (o, &mv) in x1.iter_mut().zip(&m) {
            *o += mv;
        }
        Ok(x1)
    }

    /// Full reverse pass: flat gate gradients (the [`AdapterSet`]
    /// layout, matching [`TransformerBlock::params_flat`]) plus the
    /// input gradient `∂loss/∂xs`.
    pub fn backward(
        &self,
        tape: &BlockTape,
        grad_out: &[f32],
        n_seqs: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let rows = self.check_panel(grad_out, n_seqs, "backward")?;
        if tape.n_seqs != n_seqs {
            return Err(Error::Shape(format!(
                "block backward: tape has {} sequences, got {n_seqs}",
                tape.n_seqs
            )));
        }
        // MLP: out = x1 + m(LN2(x1)) — borrowing GEMMs straight out of
        // grad_out / du (same kernel + chunking as the old owned wrap,
        // so the train trajectory is bitwise unchanged)
        let mut du = vec![0.0f32; rows * self.d_ff]; // da, scaled next by gelu'
        gemm::gemm_into(grad_out, &self.w2.data, &mut du, self.d, self.d_ff);
        for (g, &uv) in du.iter_mut().zip(&tape.u) {
            *g *= gelu_prime(uv);
        }
        let mut dh2 = vec![0.0f32; rows * self.d];
        gemm::gemm_into(&du, &self.w1.data, &mut dh2, self.d_ff, self.d);
        let mut dx1 = layer_norm_backward(&dh2, &tape.xhat2, &tape.rstd2, &self.ln2_g, self.d);
        for (g, &go) in dx1.iter_mut().zip(grad_out) {
            *g += go;
        }
        // attention branch: x1 = x + O(ctx)
        let g_o = self.adapters.adapter(3).backward(&tape.t_o, &dx1, rows)?;
        let (dq, dk, dv) = self.attention_backward(&g_o.input, tape);
        let g_q = self.adapters.adapter(0).backward(&tape.tq, &dq, rows)?;
        let g_k = self.adapters.adapter(1).backward(&tape.tk, &dk, rows)?;
        let g_v = self.adapters.adapter(2).backward(&tape.tv, &dv, rows)?;
        let mut dh1 = g_q.input;
        for (g, (&a, &b)) in dh1.iter_mut().zip(g_k.input.iter().zip(&g_v.input)) {
            *g += a + b;
        }
        let mut dx = layer_norm_backward(&dh1, &tape.xhat1, &tape.rstd1, &self.ln1_g, self.d);
        for (g, &a) in dx.iter_mut().zip(&dx1) {
            *g += a;
        }
        let flat = self.adapters.flat_from_parts(&[
            g_q.gates.into_iter().flatten().collect(),
            g_k.gates.into_iter().flatten().collect(),
            g_v.gates.into_iter().flatten().collect(),
            g_o.gates.into_iter().flatten().collect(),
        ])?;
        Ok((flat, dx))
    }
}

impl TrainableModel for TransformerBlock {
    type Tape = BlockTape;

    fn io_len(&self) -> usize {
        self.seq * self.d
    }

    fn param_count(&self) -> usize {
        self.adapters.param_count()
    }

    fn params_flat(&self) -> Vec<f32> {
        self.adapters.params_flat()
    }

    fn set_params(&mut self, flat: &[f32]) -> Result<()> {
        self.adapters.set_params(flat)
    }

    fn forward(&self, xs: &[f32], n: usize) -> Result<Vec<f32>> {
        self.check_panel(xs, n, "forward")?;
        TransformerBlock::forward(self, xs, n, self.seq)
    }

    fn forward_with_tape(&self, xs: &[f32], n: usize) -> Result<(Vec<f32>, BlockTape)> {
        TransformerBlock::forward_with_tape(self, xs, n)
    }

    fn backward_flat(&self, tape: &BlockTape, grad_out: &[f32], n: usize) -> Result<Vec<f32>> {
        Ok(self.backward(tape, grad_out, n)?.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_block(rng: &mut Rng) -> TransformerBlock {
        let cfg = BlockConfig::standard(vec![2, 2], 2, 3);
        TransformerBlock::init(&cfg, rng).unwrap()
    }

    #[test]
    fn identity_adapters_make_merge_exact() {
        // identity circuits ⇒ merged weights == bases and the merged
        // block's forward is bitwise the original forward
        let mut rng = Rng::new(80);
        let block = tiny_block(&mut rng);
        let merged = block.merged().unwrap();
        let mut xs = vec![0.0f32; 2 * block.io_len()];
        rng.fill_normal(&mut xs, 1.0);
        let y = block.forward(&xs, 2, block.seq()).unwrap();
        let ym = merged.forward(&xs, 2, merged.seq()).unwrap();
        for (a, b) in y.iter().zip(&ym) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn forward_is_deterministic_and_tape_free_matches() {
        let mut rng = Rng::new(81);
        let mut block = tiny_block(&mut rng);
        block.randomize_circuits(0.3, &mut rng).unwrap();
        let mut xs = vec![0.0f32; 3 * block.io_len()];
        rng.fill_normal(&mut xs, 1.0);
        let (y1, tape) = block.forward_with_tape(&xs, 3).unwrap();
        let y2 = block.forward(&xs, 3, block.seq()).unwrap();
        assert_eq!(y1, y2);
        assert_eq!(tape.probs.len(), 3 * block.n_heads() * 9);
        // causal: strictly-upper probs are exactly zero, rows sum to 1
        let seq = block.seq();
        for (si, chunk) in tape.probs.chunks(seq * seq).enumerate() {
            for t in 0..seq {
                let mut sum = 0.0f64;
                for t2 in 0..seq {
                    let p = chunk[t * seq + t2];
                    if t2 > t {
                        assert_eq!(p, 0.0, "head {si} row {t} leaks future position {t2}");
                    }
                    sum += p as f64;
                }
                assert!((sum - 1.0).abs() < 1e-5, "head {si} row {t} sums to {sum}");
            }
        }
    }

    #[test]
    fn randomized_circuits_change_output_identity_init_does_not() {
        let mut rng = Rng::new(82);
        let mut block = tiny_block(&mut rng);
        let mut xs = vec![0.0f32; 2 * block.io_len()];
        rng.fill_normal(&mut xs, 1.0);
        let y0 = block.forward(&xs, 2, block.seq()).unwrap();
        let frozen = block.merged().unwrap(); // identity merge == bases
        let yf = frozen.forward(&xs, 2, frozen.seq()).unwrap();
        for (a, b) in y0.iter().zip(&yf) {
            assert!((a - b).abs() < 1e-6, "identity init must match frozen forward");
        }
        block.randomize_circuits(0.4, &mut rng).unwrap();
        let y1 = block.forward(&xs, 2, block.seq()).unwrap();
        assert!(y0.iter().zip(&y1).any(|(a, b)| (a - b).abs() > 1e-4));
    }

    #[test]
    fn params_roundtrip_through_adapter_set() {
        let mut rng = Rng::new(83);
        let mut block = tiny_block(&mut rng);
        block.randomize_circuits(0.2, &mut rng).unwrap();
        let p = block.params_flat();
        assert_eq!(p.len(), block.param_count());
        assert_eq!(block.adapters().len(), 4);
        let mut xs = vec![0.0f32; block.io_len()];
        rng.fill_normal(&mut xs, 1.0);
        let seq = block.seq();
        let y0 = block.forward(&xs, 1, seq).unwrap();
        let mut p2 = p.clone();
        p2[0] += 0.5;
        block.set_params(&p2).unwrap();
        assert!(block
            .forward(&xs, 1, seq)
            .unwrap()
            .iter()
            .zip(&y0)
            .any(|(a, b)| (a - b).abs() > 1e-6));
        block.set_params(&p).unwrap();
        assert_eq!(block.forward(&xs, 1, seq).unwrap(), y0);
    }

    #[test]
    fn shape_errors() {
        let mut rng = Rng::new(84);
        let block = tiny_block(&mut rng);
        assert!(block.forward(&[0.0; 7], 1, block.seq()).is_err());
        assert!(block.forward(&[0.0; 7], 1, 0).is_err());
        let cfg = BlockConfig::standard(vec![2, 2], 2, 4).with_heads(3); // 4 % 3 != 0
        assert!(TransformerBlock::init(&cfg, &mut rng).is_err());
        let cfg0 = BlockConfig::standard(vec![2, 2], 2, 4).with_seq(0);
        assert!(TransformerBlock::init(&cfg0, &mut rng).is_err());
        let cff = BlockConfig::standard(vec![2, 2], 2, 4).with_d_ff(0);
        assert!(TransformerBlock::init(&cff, &mut rng).is_err());
    }
}
