//! Synthetic vector-regression tasks for the host trainer.
//!
//! Teacher–student setup: the targets are produced by a hidden
//! *teacher* adapter — the same frozen base `W` the student sees, plus
//! a random teacher circuit delta and optional observation noise:
//!
//! ```text
//! y = W x + α (C_teacher(x) − x) + ε,   ε ~ N(0, noise_std²)
//! ```
//!
//! A student initialized with identity gates starts exactly at `W x`,
//! so its initial loss is the energy of the teacher delta (plus the
//! noise floor) and training must recover the delta through the
//! gradient engine.  Every split is a deterministic function of
//! `(seed, stream)`, matching the repo's data protocol: train/val are
//! disjoint by construction.

use crate::model::{BlockConfig, DeepConfig, DeepModel, TransformerBlock};
use crate::quanta::circuit::{all_pairs_structure, Circuit};
use crate::quanta::QuantaAdapter;
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// The regression-panel view the host trainer consumes: row-major
/// `[n, example_len]` feature/target panels with disjoint train/val
/// splits.  Both the single-adapter task ([`SynthTask`], one hidden
/// vector per example) and the block task ([`BlockSynthTask`], one
/// whole sequence per example) implement it, so
/// `coordinator::host_trainer::finetune_host` drives either unchanged.
pub trait RegressionTask {
    /// Floats per example (= the model's `io_len`).
    fn example_len(&self) -> usize;
    fn n_train(&self) -> usize;
    fn n_val(&self) -> usize;
    /// `(features, targets)` of the train split.
    fn train_xy(&self) -> (&[f32], &[f32]);
    /// `(features, targets)` of the val split.
    fn val_xy(&self) -> (&[f32], &[f32]);
}

/// Generation knobs for [`teacher_student`].
#[derive(Clone, Debug)]
pub struct SynthConfig {
    /// Tensorization of the hidden dimension (`d = Π dims`).
    pub dims: Vec<usize>,
    pub n_train: usize,
    pub n_val: usize,
    /// Per-gate perturbation of the teacher (`eye + N(0, std²)`).
    pub teacher_std: f32,
    /// Observation noise on the targets (0 = noiseless).
    pub noise_std: f32,
    /// Delta scale `α`, shared by teacher and student.
    pub alpha: f32,
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            dims: vec![4, 4, 4],
            n_train: 256,
            n_val: 64,
            teacher_std: 0.3,
            noise_std: 0.01,
            alpha: 1.0,
            seed: 0,
        }
    }
}

/// A generated regression task: row-major `[n, d]` feature/target
/// panels plus the frozen base the student must keep.
#[derive(Clone, Debug)]
pub struct SynthTask {
    pub d: usize,
    pub dims: Vec<usize>,
    pub structure: Vec<(usize, usize)>,
    pub alpha: f32,
    /// Frozen base weight shared by teacher and student.
    pub base: Tensor,
    pub train_x: Vec<f32>,
    pub train_y: Vec<f32>,
    pub val_x: Vec<f32>,
    pub val_y: Vec<f32>,
    pub n_train: usize,
    pub n_val: usize,
}

impl SynthTask {
    /// Fresh student for this task: the frozen base with
    /// identity-initialized gates (zero delta at step 0).
    pub fn student(&self) -> Result<QuantaAdapter> {
        QuantaAdapter::identity_init(self.base.clone(), &self.dims, &self.structure, self.alpha)
    }
}

impl RegressionTask for SynthTask {
    fn example_len(&self) -> usize {
        self.d
    }

    fn n_train(&self) -> usize {
        self.n_train
    }

    fn n_val(&self) -> usize {
        self.n_val
    }

    fn train_xy(&self) -> (&[f32], &[f32]) {
        (&self.train_x, &self.train_y)
    }

    fn val_xy(&self) -> (&[f32], &[f32]) {
        (&self.val_x, &self.val_y)
    }
}

/// Generate a teacher–student regression task over `dims` with the
/// paper's all-pairs gate structure.
pub fn teacher_student(cfg: &SynthConfig) -> Result<SynthTask> {
    let d: usize = cfg.dims.iter().product();
    let structure = all_pairs_structure(cfg.dims.len());
    let base = Tensor::randn(
        &[d, d],
        1.0 / (d as f32).sqrt(),
        &mut Rng::stream(cfg.seed, "synth-base"),
    );
    let teacher = Circuit::random(
        &cfg.dims,
        &structure,
        cfg.teacher_std,
        &mut Rng::stream(cfg.seed, "synth-teacher"),
    )?;
    let teacher = QuantaAdapter::new(base.clone(), teacher, cfg.alpha)?;

    let mut gen_split =
        |stream_x: &str, stream_eps: &str, n: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let mut xs = vec![0.0f32; n * d];
            Rng::stream(cfg.seed, stream_x).fill_normal(&mut xs, 1.0);
            let mut ys = teacher.apply_batch(&xs, n)?;
            if cfg.noise_std > 0.0 {
                let mut eps = vec![0.0f32; n * d];
                Rng::stream(cfg.seed, stream_eps).fill_normal(&mut eps, cfg.noise_std);
                for (y, e) in ys.iter_mut().zip(&eps) {
                    *y += e;
                }
            }
            Ok((xs, ys))
        };
    let (train_x, train_y) = gen_split("synth-train-x", "synth-train-eps", cfg.n_train)?;
    let (val_x, val_y) = gen_split("synth-val-x", "synth-val-eps", cfg.n_val)?;
    Ok(SynthTask {
        d,
        dims: cfg.dims.clone(),
        structure,
        alpha: cfg.alpha,
        base,
        train_x,
        train_y,
        val_x,
        val_y,
        n_train: cfg.n_train,
        n_val: cfg.n_val,
    })
}

/// Generation knobs for [`block_teacher_student`].
#[derive(Clone, Debug)]
pub struct BlockSynthConfig {
    /// Per-projection circuit tensorization (`d = Π dims`).
    pub dims: Vec<usize>,
    pub n_heads: usize,
    pub seq: usize,
    pub d_ff: usize,
    pub n_train: usize,
    pub n_val: usize,
    /// Per-gate perturbation of the teacher circuits (`eye + N(0, std²)`).
    pub teacher_std: f32,
    /// Observation noise on the targets (0 = noiseless).
    pub noise_std: f32,
    pub alpha: f32,
    pub seed: u64,
}

impl Default for BlockSynthConfig {
    fn default() -> Self {
        BlockSynthConfig {
            dims: vec![4, 4, 8],
            n_heads: 4,
            seq: 8,
            d_ff: 256,
            n_train: 64,
            n_val: 16,
            teacher_std: 0.2,
            noise_std: 0.01,
            alpha: 1.0,
            seed: 0,
        }
    }
}

/// A sequence-level regression task: the teacher is the *same frozen
/// block* the student gets, but with every projection circuit
/// perturbed (`eye + N(0, std²)`); targets are whole teacher output
/// sequences.  The identity-initialized student therefore starts at
/// the frozen block's forward, and training must recover four circuit
/// deltas at once through attention, layernorms, and the MLP.
#[derive(Clone, Debug)]
pub struct BlockSynthTask {
    pub d: usize,
    pub seq: usize,
    /// The frozen block with identity circuits — the student template.
    pub base_block: TransformerBlock,
    pub train_x: Vec<f32>,
    pub train_y: Vec<f32>,
    pub val_x: Vec<f32>,
    pub val_y: Vec<f32>,
    pub n_train: usize,
    pub n_val: usize,
}

impl BlockSynthTask {
    /// Fresh student: the frozen base block with identity circuits.
    pub fn student(&self) -> TransformerBlock {
        self.base_block.clone()
    }
}

impl RegressionTask for BlockSynthTask {
    fn example_len(&self) -> usize {
        self.seq * self.d
    }

    fn n_train(&self) -> usize {
        self.n_train
    }

    fn n_val(&self) -> usize {
        self.n_val
    }

    fn train_xy(&self) -> (&[f32], &[f32]) {
        (&self.train_x, &self.train_y)
    }

    fn val_xy(&self) -> (&[f32], &[f32]) {
        (&self.val_x, &self.val_y)
    }
}

/// Generate a block-level teacher–student task (deterministic in
/// `(seed, stream)` like every other dataset in the repo).
pub fn block_teacher_student(cfg: &BlockSynthConfig) -> Result<BlockSynthTask> {
    let bcfg = BlockConfig::standard(cfg.dims.clone(), cfg.n_heads, cfg.seq)
        .with_d_ff(cfg.d_ff)
        .with_alpha(cfg.alpha);
    let base_block = TransformerBlock::init(&bcfg, &mut Rng::stream(cfg.seed, "block-base"))?;
    let mut teacher = base_block.clone();
    teacher.randomize_circuits(cfg.teacher_std, &mut Rng::stream(cfg.seed, "block-teacher"))?;
    let ex = cfg.seq * base_block.d();

    let mut gen_split =
        |stream_x: &str, stream_eps: &str, n: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let mut xs = vec![0.0f32; n * ex];
            Rng::stream(cfg.seed, stream_x).fill_normal(&mut xs, 1.0);
            let mut ys = teacher.forward(&xs, n, cfg.seq)?;
            if cfg.noise_std > 0.0 {
                let mut eps = vec![0.0f32; n * ex];
                Rng::stream(cfg.seed, stream_eps).fill_normal(&mut eps, cfg.noise_std);
                for (y, e) in ys.iter_mut().zip(&eps) {
                    *y += e;
                }
            }
            Ok((xs, ys))
        };
    let (train_x, train_y) = gen_split("block-train-x", "block-train-eps", cfg.n_train)?;
    let (val_x, val_y) = gen_split("block-val-x", "block-val-eps", cfg.n_val)?;
    Ok(BlockSynthTask {
        d: base_block.d(),
        seq: cfg.seq,
        base_block,
        train_x,
        train_y,
        val_x,
        val_y,
        n_train: cfg.n_train,
        n_val: cfg.n_val,
    })
}

/// Generation knobs for [`deep_teacher_student`]: the block knobs
/// plus a depth.
#[derive(Clone, Debug)]
pub struct DeepSynthConfig {
    pub dims: Vec<usize>,
    pub n_heads: usize,
    pub seq: usize,
    pub d_ff: usize,
    /// Stacked blocks in teacher and student (≥ 1).
    pub depth: usize,
    pub n_train: usize,
    pub n_val: usize,
    pub teacher_std: f32,
    pub noise_std: f32,
    pub alpha: f32,
    pub seed: u64,
}

impl Default for DeepSynthConfig {
    fn default() -> Self {
        let b = BlockSynthConfig::default();
        DeepSynthConfig {
            dims: b.dims,
            n_heads: b.n_heads,
            seq: b.seq,
            d_ff: b.d_ff,
            depth: 2,
            n_train: b.n_train,
            n_val: b.n_val,
            teacher_std: b.teacher_std,
            noise_std: b.noise_std,
            alpha: b.alpha,
            seed: b.seed,
        }
    }
}

/// The depth-N counterpart of [`BlockSynthTask`]: teacher and student
/// share frozen per-layer bases, the teacher's circuits are perturbed
/// at every layer, and targets are whole stacked-forward sequences.
#[derive(Clone, Debug)]
pub struct DeepSynthTask {
    pub d: usize,
    pub seq: usize,
    /// The frozen stack with identity circuits — the student template.
    pub base_model: DeepModel,
    pub train_x: Vec<f32>,
    pub train_y: Vec<f32>,
    pub val_x: Vec<f32>,
    pub val_y: Vec<f32>,
    pub n_train: usize,
    pub n_val: usize,
}

impl DeepSynthTask {
    /// Fresh student: the frozen stack with identity circuits.
    pub fn student(&self) -> DeepModel {
        self.base_model.clone()
    }
}

impl RegressionTask for DeepSynthTask {
    fn example_len(&self) -> usize {
        self.seq * self.d
    }

    fn n_train(&self) -> usize {
        self.n_train
    }

    fn n_val(&self) -> usize {
        self.n_val
    }

    fn train_xy(&self) -> (&[f32], &[f32]) {
        (&self.train_x, &self.train_y)
    }

    fn val_xy(&self) -> (&[f32], &[f32]) {
        (&self.val_x, &self.val_y)
    }
}

/// Generate a depth-N teacher–student task.  Base/teacher draws use
/// the per-layer streams of `model::deep::layer_stream`, and the data
/// splits use the block task's stream names, so a depth-1 deep task
/// is **bitwise identical** to [`block_teacher_student`] with the same
/// knobs — the depth-1 equivalence pin in `rust/tests/deep_props.rs`
/// extends through the data pipeline.
pub fn deep_teacher_student(cfg: &DeepSynthConfig) -> Result<DeepSynthTask> {
    let bcfg = BlockConfig::standard(cfg.dims.clone(), cfg.n_heads, cfg.seq)
        .with_d_ff(cfg.d_ff)
        .with_alpha(cfg.alpha);
    let dcfg = DeepConfig { block: bcfg, depth: cfg.depth };
    let base_model = DeepModel::init(&dcfg, cfg.seed)?;
    let mut teacher = base_model.clone();
    teacher.randomize_circuits(cfg.teacher_std, cfg.seed)?;
    let ex = cfg.seq * base_model.d();

    let mut gen_split =
        |stream_x: &str, stream_eps: &str, n: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let mut xs = vec![0.0f32; n * ex];
            Rng::stream(cfg.seed, stream_x).fill_normal(&mut xs, 1.0);
            let mut ys = teacher.forward(&xs, n, cfg.seq)?;
            if cfg.noise_std > 0.0 {
                let mut eps = vec![0.0f32; n * ex];
                Rng::stream(cfg.seed, stream_eps).fill_normal(&mut eps, cfg.noise_std);
                for (y, e) in ys.iter_mut().zip(&eps) {
                    *y += e;
                }
            }
            Ok((xs, ys))
        };
    let (train_x, train_y) = gen_split("block-train-x", "block-train-eps", cfg.n_train)?;
    let (val_x, val_y) = gen_split("block-val-x", "block-val-eps", cfg.n_val)?;
    Ok(DeepSynthTask {
        d: base_model.d(),
        seq: cfg.seq,
        base_model,
        train_x,
        train_y,
        val_x,
        val_y,
        n_train: cfg.n_train,
        n_val: cfg.n_val,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_disjoint_splits() {
        let cfg = SynthConfig { n_train: 16, n_val: 8, ..Default::default() };
        let a = teacher_student(&cfg).unwrap();
        let b = teacher_student(&cfg).unwrap();
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
        assert_eq!(a.val_y, b.val_y);
        assert_ne!(&a.train_x[..a.d], &a.val_x[..a.d], "train/val streams must differ");
        let c = teacher_student(&SynthConfig { seed: 1, ..cfg }).unwrap();
        assert_ne!(a.train_y, c.train_y, "different seeds must differ");
    }

    #[test]
    fn block_task_deterministic_and_student_starts_at_frozen_forward() {
        let cfg = BlockSynthConfig {
            dims: vec![2, 2],
            n_heads: 2,
            seq: 3,
            d_ff: 8,
            n_train: 6,
            n_val: 3,
            noise_std: 0.0,
            ..Default::default()
        };
        let a = block_teacher_student(&cfg).unwrap();
        let b = block_teacher_student(&cfg).unwrap();
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
        assert_eq!(a.example_len(), 3 * 4);
        // identity-init student predicts the frozen forward, which must
        // differ from the teacher (nonzero circuit deltas)
        let student = a.student();
        let pred = student.forward(&a.train_x, a.n_train, a.seq).unwrap();
        let mse: f64 = pred
            .iter()
            .zip(&a.train_y)
            .map(|(p, y)| ((p - y) as f64).powi(2))
            .sum::<f64>()
            / pred.len() as f64;
        assert!(mse > 1e-5, "teacher delta unexpectedly tiny: {mse}");
        let c = block_teacher_student(&BlockSynthConfig { seed: 1, ..cfg }).unwrap();
        assert_ne!(a.train_y, c.train_y, "different seeds must differ");
    }

    #[test]
    fn deep_task_deterministic_and_depth_one_matches_block_task() {
        let dcfg = DeepSynthConfig {
            dims: vec![2, 2],
            n_heads: 2,
            seq: 3,
            d_ff: 8,
            depth: 2,
            n_train: 6,
            n_val: 3,
            ..Default::default()
        };
        let a = deep_teacher_student(&dcfg).unwrap();
        let b = deep_teacher_student(&dcfg).unwrap();
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
        assert_eq!(a.base_model.depth(), 2);
        assert_eq!(a.example_len(), 3 * 4);

        // depth-1 deep task is the block task, bitwise, through data gen
        let one = deep_teacher_student(&DeepSynthConfig { depth: 1, ..dcfg.clone() }).unwrap();
        let blk = block_teacher_student(&BlockSynthConfig {
            dims: dcfg.dims.clone(),
            n_heads: dcfg.n_heads,
            seq: dcfg.seq,
            d_ff: dcfg.d_ff,
            n_train: dcfg.n_train,
            n_val: dcfg.n_val,
            teacher_std: dcfg.teacher_std,
            noise_std: dcfg.noise_std,
            alpha: dcfg.alpha,
            seed: dcfg.seed,
        })
        .unwrap();
        assert_eq!(one.train_x, blk.train_x);
        assert_eq!(one.train_y, blk.train_y, "depth-1 targets must match block task bitwise");
        assert_eq!(one.val_y, blk.val_y);

        // stacking a second layer must change the targets
        assert_ne!(a.train_y, one.train_y, "depth must matter");
    }

    #[test]
    fn student_initial_loss_is_teacher_delta_energy() {
        let cfg = SynthConfig { n_train: 32, n_val: 8, noise_std: 0.0, ..Default::default() };
        let task = teacher_student(&cfg).unwrap();
        let student = task.student().unwrap();
        let pred = student.apply_batch(&task.train_x, task.n_train).unwrap();
        // identity-init student predicts W x exactly, so the residual is
        // the (non-trivial) teacher delta
        let mse: f64 = pred
            .iter()
            .zip(&task.train_y)
            .map(|(p, y)| ((p - y) as f64).powi(2))
            .sum::<f64>()
            / pred.len() as f64;
        assert!(mse > 1e-3, "teacher delta unexpectedly tiny: {mse}");
    }
}
