//! Word-level tokenizer over the static vocabulary.  Numbers are encoded
//! digit-wise (so arithmetic answers of any magnitude stay in-vocab).

use std::collections::HashMap;

use crate::data::vocab::{self, DIGIT0, UNK};

#[derive(Clone)]
pub struct Tokenizer {
    words: Vec<String>,
    ids: HashMap<String, u16>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let words = vocab::build_words();
        let ids = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u16))
            .collect();
        Tokenizer { words, ids }
    }

    pub fn vocab_size(&self) -> usize {
        vocab::VOCAB_SIZE
    }

    /// Encode a whitespace-separated template string.  Multi-digit
    /// numbers expand into digit tokens.
    pub fn encode(&self, text: &str) -> Vec<u16> {
        let mut out = vec![];
        for word in text.split_whitespace() {
            if !word.is_empty() && word.bytes().all(|b| b.is_ascii_digit()) && word.len() > 1 {
                for b in word.bytes() {
                    out.push(DIGIT0 + (b - b'0') as u16);
                }
            } else if let Some(&id) = self.ids.get(word) {
                out.push(id);
            } else {
                out.push(UNK);
            }
        }
        out
    }

    /// Encode an integer digit-wise.
    pub fn encode_number(&self, n: u64) -> Vec<u16> {
        n.to_string()
            .bytes()
            .map(|b| DIGIT0 + (b - b'0') as u16)
            .collect()
    }

    pub fn decode(&self, tokens: &[u16]) -> String {
        tokens
            .iter()
            .map(|&t| {
                self.words
                    .get(t as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<oob>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn id(&self, word: &str) -> u16 {
        *self.ids.get(word).unwrap_or(&UNK)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::{DIGIT0, UNK};

    #[test]
    fn roundtrip_words() {
        let tok = Tokenizer::new();
        let ids = tok.encode("alice has 3 apple .");
        assert!(!ids.contains(&UNK), "{:?}", tok.decode(&ids));
        assert_eq!(tok.decode(&ids), "alice has 3 apple .");
    }

    #[test]
    fn multidigit_numbers_split() {
        let tok = Tokenizer::new();
        let ids = tok.encode("47");
        assert_eq!(ids, vec![DIGIT0 + 4, DIGIT0 + 7]);
        assert_eq!(tok.encode_number(470), vec![DIGIT0 + 4, DIGIT0 + 7, DIGIT0]);
    }

    #[test]
    fn unknown_maps_to_unk() {
        let tok = Tokenizer::new();
        assert_eq!(tok.encode("zzzzz"), vec![UNK]);
    }

    #[test]
    fn all_vocab_words_encode_to_self() {
        let tok = Tokenizer::new();
        for w in vocab::build_words().iter().skip(15) {
            // skip specials + single digits (digit handling is special)
            let ids = tok.encode(w);
            assert_eq!(ids.len(), 1, "{w}");
            assert_eq!(tok.decode(&ids), *w);
        }
    }
}
