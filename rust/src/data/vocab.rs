//! Static word-level vocabulary (512 slots) shared by the pretraining
//! corpus and every downstream task, so fine-tuning never sees
//! out-of-vocabulary tokens.

/// Special token ids.
pub const PAD: u16 = 0;
pub const BOS: u16 = 1;
pub const EOS: u16 = 2;
/// Separator between prompt and answer ("Answer:" in the paper's prompts).
pub const SEP: u16 = 3;
pub const UNK: u16 = 4;
/// Digits 0..=9 occupy ids 5..=14 (numbers are tokenized digit-wise).
pub const DIGIT0: u16 = 5;

pub const VOCAB_SIZE: usize = 512;

pub const NAMES: &[&str] = &[
    "alice", "bob", "carol", "dave", "erin", "frank", "grace", "henry",
    "ivy", "jack", "kate", "liam", "mona", "nina", "oscar", "paula",
    "quinn", "rosa", "sam", "tara", "umar", "vera", "wade", "xena",
];

pub const NOUNS: &[&str] = &[
    "apple", "pear", "book", "coin", "stone", "ball", "cup", "box",
    "key", "leaf", "shell", "ring", "card", "doll", "kite", "lamp",
    "map", "nail", "pen", "rope", "seed", "tent", "vase", "wheel",
    "cat", "dog", "bird", "fish", "horse", "mouse", "sheep", "goat",
    "table", "chair", "door", "window", "wall", "roof", "floor", "garden",
    "river", "hill", "road", "bridge", "field", "forest", "lake", "cave",
];

pub const VERBS: &[&str] = &[
    "has", "finds", "buys", "sells", "gives", "takes", "makes", "breaks",
    "sees", "hears", "holds", "drops", "lifts", "moves", "opens", "closes",
    "helped", "hurt", "praised", "blamed", "thanked", "ignored", "greeted", "pushed",
    "eats", "drinks", "reads", "writes", "draws", "paints",
];

pub const ADJS: &[&str] = &[
    "red", "blue", "green", "small", "big", "old", "new", "fast",
    "slow", "warm", "cold", "bright", "dark", "heavy", "light", "round",
    "happy", "sad", "angry", "calm", "brave", "shy", "kind", "rude",
    "clean", "dirty", "sharp", "dull", "soft", "hard",
];

pub const TOOLS: &[&str] = &[
    "scissors", "hammer", "spoon", "brush", "needle", "ladder", "bucket", "broom",
    "knife", "shovel", "towel", "sponge",
];

pub const TOOL_TASKS: &[&str] = &[
    "cut", "pound", "stir", "sweep", "sew", "climb", "carry", "dust",
    "slice", "dig", "dry", "scrub",
];

pub const EMOTIONS: &[&str] = &["grateful", "upset", "proud", "ashamed", "glad", "annoyed"];

pub const MATERIALS: &[&str] = &["metal", "wood", "glass", "cloth", "paper", "clay"];

pub const PROPS: &[&str] = &["shiny", "flammable", "fragile", "flexible", "foldable", "brittle"];

pub const FUNCTION_WORDS: &[&str] = &[
    "the", "a", "and", "then", "is", "are", "was", "in", "on", "to", "of",
    "more", "fewer", "than", "how", "many", "who", "what", "which", "most",
    "altogether", "left", "first", "second", "because", "it", "too", "does",
    "not", "fit", "into", "use", "feels", "feel", "after", "true", "false", "yes",
    "no", "option", "same", "different", "as", "plus", "minus", "times", "equals",
    "each", "all", "some", "every", "made", "can", "cannot", "so", "therefore",
    "doubles", "half", "question", "passage", "answer", "choose", "best",
    "next", "story", "ends", "with", "similar", "score", "entails", "statement",
    "correct", "about", "have", "sort", "thing", "animal", "object", "place",
];

/// Build the full vocabulary word list (index = token id).
pub fn build_words() -> Vec<String> {
    let mut words: Vec<String> = vec![
        "<pad>".into(), "<bos>".into(), "<eos>".into(), "<sep>".into(), "<unk>".into(),
    ];
    for d in 0..10 {
        words.push(d.to_string());
    }
    for group in [
        NAMES, NOUNS, VERBS, ADJS, TOOLS, TOOL_TASKS, EMOTIONS, MATERIALS, PROPS,
        FUNCTION_WORDS,
    ] {
        for w in group {
            words.push((*w).to_string());
        }
    }
    words.push(".".into());
    words.push("?".into());
    words.push(",".into());
    assert!(words.len() <= VOCAB_SIZE, "vocab overflow: {}", words.len());
    words
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_fits_and_is_unique() {
        let words = build_words();
        assert!(words.len() <= VOCAB_SIZE);
        let mut set = std::collections::HashSet::new();
        for w in &words {
            assert!(set.insert(w.clone()), "duplicate vocab word: {w}");
        }
    }

    #[test]
    fn digits_at_expected_ids() {
        let words = build_words();
        for d in 0..10u16 {
            assert_eq!(words[(DIGIT0 + d) as usize], d.to_string());
        }
    }
}
