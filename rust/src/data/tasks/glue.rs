//! GLUE-analog suites (Table F.7 columns): SST-2, MRPC, CoLA, STS-B
//! (RTE reuses `tasks::rte`).  All are scored as option tasks; STS-B's
//! 0–5 similarity becomes a 6-way digit choice (accuracy reported, as in
//! the paper's table).

use crate::data::example::TaskData;
use crate::data::tasks::{gen_splits, Sizes};
use crate::data::tokenizer::Tokenizer;
use crate::data::vocab;
use crate::data::Example;
use crate::util::rng::Rng;

/// Fixed adjective polarity: ADJS indices with positive affect.
pub const POS_ADJS: &[&str] = &["happy", "kind", "brave", "bright", "clean", "warm", "new", "calm"];
pub const NEG_ADJS: &[&str] = &["sad", "angry", "rude", "dark", "dirty", "cold", "old", "shy"];

/// SST-2 analog: sentiment of an attribute sentence.
pub fn sst2(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    let yes = vec![tok.id("yes")]; // "positive?" yes/no framing
    let no = vec![tok.id("no")];
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let noun = *rng.choose(vocab::NOUNS);
        let positive = rng.below(2) == 0;
        let (a1, a2) = if positive {
            (*rng.choose(POS_ADJS), *rng.choose(POS_ADJS))
        } else {
            (*rng.choose(NEG_ADJS), *rng.choose(NEG_ADJS))
        };
        let prompt = tok.encode(&format!(
            "the {noun} is {a1} and {a2} . question is the statement happy ?"
        ));
        Example::choice(prompt, vec![yes.clone(), no.clone()], if positive { 0 } else { 1 })
    })
}

/// MRPC analog: paraphrase detection — same content words, different
/// template vs different content.
pub fn mrpc(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    let yes = vec![tok.id("yes")];
    let no = vec![tok.id("no")];
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let noun = *rng.choose(vocab::NOUNS);
        let adj = *rng.choose(vocab::ADJS);
        let same = rng.below(2) == 0;
        let s1 = format!("the {noun} is {adj} .");
        let s2 = if same {
            // paraphrase: re-order with "a ... thing" template
            format!("a {adj} {noun} .")
        } else if rng.below(2) == 0 {
            let mut other = *rng.choose(vocab::ADJS);
            while other == adj {
                other = *rng.choose(vocab::ADJS);
            }
            format!("a {other} {noun} .")
        } else {
            let mut other = *rng.choose(vocab::NOUNS);
            while other == noun {
                other = *rng.choose(vocab::NOUNS);
            }
            format!("a {adj} {other} .")
        };
        let prompt = tok.encode(&format!("{s1} {s2} question same ?"));
        Example::choice(prompt, vec![yes.clone(), no.clone()], if same { 0 } else { 1 })
    })
}

/// CoLA analog: linguistic acceptability — canonical word order vs a
/// deterministic scramble.
pub fn cola(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    let yes = vec![tok.id("yes")];
    let no = vec![tok.id("no")];
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let noun = *rng.choose(vocab::NOUNS);
        let adj = *rng.choose(vocab::ADJS);
        let name = *rng.choose(vocab::NAMES);
        let verb = *rng.choose(&vocab::VERBS[..16]);
        let acceptable = rng.below(2) == 0;
        let sent = if acceptable {
            match rng.below(2) {
                0 => format!("the {noun} is {adj} ."),
                _ => format!("{name} {verb} the {noun} ."),
            }
        } else {
            match rng.below(3) {
                0 => format!("{adj} the is {noun} ."),
                1 => format!("the {verb} {name} {noun} ."),
                _ => format!("is {noun} {adj} the ."),
            }
        };
        let prompt = tok.encode(&format!("{sent} question correct ?"));
        Example::choice(prompt, vec![yes.clone(), no.clone()], if acceptable { 0 } else { 1 })
    })
}

/// STS-B analog: semantic similarity 0..5 = number of shared content
/// slots between two five-slot sentences, answered as a digit.
pub fn stsb(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        // five content slots: name, verb, adjective, noun, second noun
        let pick = |rng: &mut Rng| -> [&'static str; 5] {
            [
                *rng.choose(vocab::NAMES),
                *rng.choose(&vocab::VERBS[..16]),
                *rng.choose(vocab::ADJS),
                *rng.choose(&vocab::NOUNS[..24]),
                *rng.choose(&vocab::NOUNS[24..]),
            ]
        };
        let s1 = pick(rng);
        let mut s2 = s1;
        let shared = rng.range(0, 5) as usize;
        // change (5 - shared) slots
        let mut slots: Vec<usize> = (0..5).collect();
        rng.shuffle(&mut slots);
        for &slot in slots.iter().take(5 - shared) {
            loop {
                let cand = pick(rng)[slot];
                if cand != s1[slot] {
                    s2[slot] = cand;
                    break;
                }
            }
        }
        let sent =
            |s: &[&str; 5]| format!("{} {} the {} {} in the {}", s[0], s[1], s[2], s[3], s[4]);
        let prompt = tok.encode(&format!(
            "{} . {} . question similar score ?",
            sent(&s1),
            sent(&s2)
        ));
        let opts: Vec<Vec<u16>> = (0..6u64).map(|d| tok.encode_number(d)).collect();
        Example::choice(prompt, opts, shared)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sst2_polarity_consistent() {
        let tok = Tokenizer::new();
        let d = sst2(&tok, 51, Sizes { train: 60, val: 0, test: 0 });
        for ex in &d.train {
            let text = tok.decode(&ex.prompt);
            let w: Vec<&str> = text.split_whitespace().collect();
            let a1 = w[3];
            let pos = POS_ADJS.contains(&a1);
            assert_eq!(ex.correct == 0, pos, "{text}");
        }
    }

    #[test]
    fn stsb_shared_count_matches_label() {
        let tok = Tokenizer::new();
        let d = stsb(&tok, 52, Sizes { train: 60, val: 0, test: 0 });
        for ex in &d.train {
            let text = tok.decode(&ex.prompt);
            let parts: Vec<&str> = text.split(" . ").collect();
            let w1: Vec<&str> = parts[0].split_whitespace().collect();
            let w2: Vec<&str> = parts[1].split_whitespace().collect();
            // slots at positions 0,1,3,4,7 of "name verb the adj noun in the noun2"
            let idx = [0usize, 1, 3, 4, 7];
            let shared = idx.iter().filter(|&&i| w1[i] == w2[i]).count();
            assert_eq!(ex.correct, shared, "{text}");
        }
    }

    #[test]
    fn cola_unacceptable_differs_from_acceptable() {
        let tok = Tokenizer::new();
        let d = cola(&tok, 53, Sizes { train: 100, val: 0, test: 0 });
        let acc = d.train.iter().filter(|e| e.correct == 0).count();
        assert!(acc > 30 && acc < 70);
    }
}
