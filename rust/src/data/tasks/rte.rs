//! `rte_syn` — the paper's canonical *low intrinsic-rank* task (Table 1,
//! Fig. 2 left).
//!
//! Entailment verification over attribute statements: the label is a
//! near-linear readout of features the pretrained model already
//! represents (does the hypothesis restate the premise's attribute of
//! the same entity?), so a low-rank weight update suffices — mirroring
//! the paper's observation that LoRA r=64 and r=128 tie on RTE.

use crate::data::example::TaskData;
use crate::data::tasks::{gen_splits, Sizes};
use crate::data::tokenizer::Tokenizer;
use crate::data::vocab;
use crate::data::Example;
use crate::util::rng::Rng;

pub fn generate(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    let yes = vec![tok.id("yes")];
    let no = vec![tok.id("no")];
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let noun = *rng.choose(vocab::NOUNS);
        let adj = *rng.choose(vocab::ADJS);
        let entails = rng.below(2) == 0;
        let (h_noun, h_adj) = if entails {
            (noun, adj)
        } else if rng.below(2) == 0 {
            // different attribute, same entity
            let mut other = *rng.choose(vocab::ADJS);
            while other == adj {
                other = *rng.choose(vocab::ADJS);
            }
            (noun, other)
        } else {
            // same attribute, different entity
            let mut other = *rng.choose(vocab::NOUNS);
            while other == noun {
                other = *rng.choose(vocab::NOUNS);
            }
            (other, adj)
        };
        let prompt = tok.encode(&format!(
            "the {noun} is {adj} . statement the {h_noun} is {h_adj} . entails ?"
        ));
        Example::choice(
            prompt,
            vec![yes.clone(), no.clone()],
            if entails { 0 } else { 1 },
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_balanced() {
        let tok = Tokenizer::new();
        let d = generate(&tok, 11, Sizes { train: 200, val: 0, test: 0 });
        let yeses = d.train.iter().filter(|e| e.correct == 0).count();
        assert!(yeses > 60 && yeses < 140, "yes count {yeses}");
    }

    #[test]
    fn entailed_pairs_match_premise() {
        let tok = Tokenizer::new();
        let d = generate(&tok, 12, Sizes { train: 50, val: 0, test: 0 });
        for ex in &d.train {
            let text = tok.decode(&ex.prompt);
            let words: Vec<&str> = text.split_whitespace().collect();
            // "the N is A . statement the HN is HA . entails ?"
            let (n, a, hn, ha) = (words[1], words[3], words[7], words[9]);
            let entails = n == hn && a == ha;
            assert_eq!(ex.correct == 0, entails, "{text}");
        }
    }
}
