//! The eight commonsense-reasoning suites (Table 3 columns): synthetic
//! analogs of BoolQ, PIQA, SIQA, HellaSwag, WinoGrande, ARC-easy,
//! ARC-challenge, and OpenBookQA.  All are option tasks evaluated by the
//! paper's "highest probability choice" protocol (App. H).

use crate::data::example::TaskData;
use crate::data::tasks::{gen_splits, Sizes};
use crate::data::tokenizer::Tokenizer;
use crate::data::vocab;
use crate::data::Example;
use crate::util::rng::Rng;

/// BoolQ analog: yes/no verification of a stated attribute.
pub fn boolq(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    let yes = vec![tok.id("yes")];
    let no = vec![tok.id("no")];
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let noun = *rng.choose(vocab::NOUNS);
        let adj = *rng.choose(vocab::ADJS);
        let truthful = rng.below(2) == 0;
        let q_adj = if truthful {
            adj
        } else {
            let mut other = *rng.choose(vocab::ADJS);
            while other == adj {
                other = *rng.choose(vocab::ADJS);
            }
            other
        };
        let prompt =
            tok.encode(&format!("the {noun} is {adj} . question is the {noun} {q_adj} ?"));
        Example::choice(prompt, vec![yes.clone(), no.clone()], if truthful { 0 } else { 1 })
    })
}

/// PIQA analog: physical tool selection.  The tool->task mapping is seen
/// in pretraining ("use the scissors to cut ."), so the suite tests
/// physical-knowledge *recall* under a new question form.
pub fn piqa(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let i = rng.below(vocab::TOOLS.len());
        let mut j = rng.below(vocab::TOOLS.len());
        while j == i {
            j = rng.below(vocab::TOOLS.len());
        }
        let task = vocab::TOOL_TASKS[i];
        let prompt = tok.encode(&format!("question to {task} which thing is best ?"));
        let opts = vec![
            vec![tok.id(vocab::TOOLS[i])],
            vec![tok.id(vocab::TOOLS[j])],
        ];
        let correct_first = rng.below(2) == 0;
        if correct_first {
            Example::choice(prompt, opts, 0)
        } else {
            Example::choice(prompt, vec![opts[1].clone(), opts[0].clone()], 1)
        }
    })
}

/// SIQA analog: social reaction inference.  Verbs index 16..24 of VERBS
/// are social; the first four are positive, the last four negative.
pub fn siqa(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let a = *rng.choose(vocab::NAMES);
        let mut b = *rng.choose(vocab::NAMES);
        while b == a {
            b = *rng.choose(vocab::NAMES);
        }
        let positive = rng.below(2) == 0;
        let verb = if positive {
            vocab::VERBS[16 + rng.below(4)]
        } else {
            vocab::VERBS[20 + rng.below(4)]
        };
        // EMOTIONS alternate positive/negative: [grateful, upset, proud,
        // ashamed, glad, annoyed]
        let pos_emotions = [vocab::EMOTIONS[0], vocab::EMOTIONS[2], vocab::EMOTIONS[4]];
        let neg_emotions = [vocab::EMOTIONS[1], vocab::EMOTIONS[3], vocab::EMOTIONS[5]];
        let (gold, distract) = if positive {
            (*rng.choose(&pos_emotions), *rng.choose(&neg_emotions))
        } else {
            (*rng.choose(&neg_emotions), *rng.choose(&pos_emotions))
        };
        let prompt = tok.encode(&format!("{a} {verb} {b} . question how does {b} feel ?"));
        let correct_first = rng.below(2) == 0;
        let (opts, correct) = if correct_first {
            (vec![vec![tok.id(gold)], vec![tok.id(distract)]], 0)
        } else {
            (vec![vec![tok.id(distract)], vec![tok.id(gold)]], 1)
        };
        Example::choice(prompt, opts, correct)
    })
}

/// HellaSwag analog: story-continuation with 4 endings; only one is
/// numerically consistent with the story.
pub fn hellaswag(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let name = *rng.choose(vocab::NAMES);
        let noun = *rng.choose(vocab::NOUNS);
        let a = rng.range(2, 9);
        let b = rng.range(2, 9);
        let total = a + b;
        let prompt = tok.encode(&format!(
            "story {name} has {a} {noun} . {name} buys {b} more {noun} . question the story ends with ?"
        ));
        let ending = |n: i64| tok.encode(&format!("{name} has {n} {noun}"));
        // distractors: off-by-one, the difference, and a random other
        let mut wrongs = vec![total + 1, (a - b).abs().max(1), total + rng.range(2, 5)];
        wrongs.dedup();
        while wrongs.len() < 3 {
            wrongs.push(total + rng.range(5, 9));
        }
        let correct = rng.below(4);
        let mut opts = vec![];
        let mut wi = 0;
        for slot in 0..4 {
            if slot == correct {
                opts.push(ending(total));
            } else {
                opts.push(ending(wrongs[wi]));
                wi += 1;
            }
        }
        Example::choice(prompt, opts, correct)
    })
}

/// WinoGrande analog: pronoun resolution keyed on the adjective ("too
/// big" -> the contained object; "too small" -> the container).
pub fn winogrande(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let n1 = *rng.choose(&vocab::NOUNS[..24]);
        let mut n2 = *rng.choose(&vocab::NOUNS[..24]);
        while n2 == n1 {
            n2 = *rng.choose(&vocab::NOUNS[..24]);
        }
        let big = rng.below(2) == 0;
        let adj = if big { "big" } else { "small" };
        let prompt = tok.encode(&format!(
            "the {n1} does not fit into the {n2} because it is too {adj} . question what is too {adj} ?"
        ));
        let opts = vec![vec![tok.id(n1)], vec![tok.id(n2)]];
        // big => the thing that doesn't fit (n1); small => container (n2)
        Example::choice(prompt, opts, if big { 0 } else { 1 })
    })
}

/// ARC-easy analog: single-hop material recall with 4 options.
pub fn arc_easy(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let noun = *rng.choose(vocab::NOUNS);
        let mat_i = rng.below(vocab::MATERIALS.len());
        let prompt = tok.encode(&format!(
            "the {noun} is made of {} . question what is the {noun} made of ?",
            vocab::MATERIALS[mat_i]
        ));
        let correct = rng.below(4);
        let mut opts = vec![];
        let mut used = vec![mat_i];
        for slot in 0..4 {
            if slot == correct {
                opts.push(vec![tok.id(vocab::MATERIALS[mat_i])]);
            } else {
                let mut k = rng.below(vocab::MATERIALS.len());
                while used.contains(&k) {
                    k = rng.below(vocab::MATERIALS.len());
                }
                used.push(k);
                opts.push(vec![tok.id(vocab::MATERIALS[k])]);
            }
        }
        Example::choice(prompt, opts, correct)
    })
}

/// ARC-challenge analog: two-hop inference (object -> material ->
/// property); requires composing two facts from the prompt.
pub fn arc_challenge(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let noun = *rng.choose(vocab::NOUNS);
        let mat_i = rng.below(vocab::MATERIALS.len());
        let prop_i = rng.below(vocab::PROPS.len());
        let prompt = tok.encode(&format!(
            "the {noun} is made of {} . {} is {} . question the {noun} is therefore ?",
            vocab::MATERIALS[mat_i], vocab::MATERIALS[mat_i], vocab::PROPS[prop_i]
        ));
        let correct = rng.below(4);
        let mut opts = vec![];
        let mut used = vec![prop_i];
        for slot in 0..4 {
            if slot == correct {
                opts.push(vec![tok.id(vocab::PROPS[prop_i])]);
            } else {
                let mut k = rng.below(vocab::PROPS.len());
                while used.contains(&k) {
                    k = rng.below(vocab::PROPS.len());
                }
                used.push(k);
                opts.push(vec![tok.id(vocab::PROPS[k])]);
            }
        }
        Example::choice(prompt, opts, correct)
    })
}

/// OpenBookQA analog: a "book" fact plus a paraphrased which-question.
pub fn obqa(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let adj = *rng.choose(vocab::ADJS);
        let gold = *rng.choose(vocab::NOUNS);
        let prompt = tok.encode(&format!(
            "the {gold} is {adj} . question which thing is {adj} ?"
        ));
        let correct = rng.below(4);
        let mut opts = vec![];
        let mut used = vec![gold];
        for slot in 0..4 {
            if slot == correct {
                opts.push(vec![tok.id(gold)]);
            } else {
                let mut other = *rng.choose(vocab::NOUNS);
                while used.contains(&other) {
                    other = *rng.choose(vocab::NOUNS);
                }
                used.push(other);
                opts.push(vec![tok.id(other)]);
            }
        }
        Example::choice(prompt, opts, correct)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn piqa_gold_matches_pretraining_mapping() {
        let tok = Tokenizer::new();
        let d = piqa(&tok, 31, Sizes { train: 60, val: 0, test: 0 });
        for ex in &d.train {
            let text = tok.decode(&ex.prompt);
            let task = text.split_whitespace().nth(2).unwrap();
            let ti = vocab::TOOL_TASKS.iter().position(|t| *t == task).unwrap();
            let gold = tok.decode(&ex.options[ex.correct]);
            assert_eq!(gold, vocab::TOOLS[ti], "{text}");
        }
    }

    #[test]
    fn hellaswag_gold_is_consistent_sum() {
        let tok = Tokenizer::new();
        let d = hellaswag(&tok, 32, Sizes { train: 40, val: 0, test: 0 });
        for ex in &d.train {
            let text = tok.decode(&ex.prompt).replace(' ', "");
            let gold = tok.decode(&ex.options[ex.correct]).replace(' ', "");
            // extract a and b from "has{a}{noun}.{name}buys{b}more"
            // simpler: gold total must appear nowhere else in options
            let others: Vec<String> = ex
                .options
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != ex.correct)
                .map(|(_, o)| tok.decode(o).replace(' ', ""))
                .collect();
            assert!(!others.contains(&gold), "{text}: duplicate option");
        }
    }

    #[test]
    fn winogrande_key_rule() {
        let tok = Tokenizer::new();
        let d = winogrande(&tok, 33, Sizes { train: 40, val: 0, test: 0 });
        for ex in &d.train {
            let text = tok.decode(&ex.prompt);
            if text.contains("too big") {
                assert_eq!(ex.correct, 0, "{text}");
            } else {
                assert_eq!(ex.correct, 1, "{text}");
            }
        }
    }

    #[test]
    fn four_option_tasks_have_four_distinct_options() {
        let tok = Tokenizer::new();
        for gen in [hellaswag, arc_easy, arc_challenge, obqa] {
            let d = gen(&tok, 34, Sizes { train: 30, val: 0, test: 0 });
            for ex in &d.train {
                assert_eq!(ex.options.len(), 4);
                let set: std::collections::HashSet<_> =
                    ex.options.iter().map(|o| o.clone()).collect();
                assert_eq!(set.len(), 4, "duplicate options");
            }
        }
    }
}
