//! `drop_syn` — the paper's canonical *high intrinsic-rank* task
//! (Tables 1, 2, F.5; Figs. 2, 4).
//!
//! Discrete reasoning over paragraphs: passages bind entities to counted
//! quantities; questions require aggregation (sum across entities),
//! lookup, comparison (argmax), or arithmetic difference.  The answer is
//! a free-form phrase (number digits or an entity name) scored by token
//! F1, exactly the paper's DROP protocol (App. D).
//!
//! Why this is high-rank: answering requires *re-binding* the
//! representation space (entity x item x count joint reasoning), which a
//! rank-r additive update on q/v projections cannot express at small r —
//! this is verified empirically by the Fig. 2 subspace-similarity bench.

use crate::data::example::TaskData;
use crate::data::tasks::{gen_splits, Sizes};
use crate::data::tokenizer::Tokenizer;
use crate::data::vocab;
use crate::data::Example;
use crate::util::rng::Rng;

struct Entry {
    name: &'static str,
    count: i64,
    item: &'static str,
}

fn gen_passage(rng: &mut Rng) -> Vec<Entry> {
    let n_entries = rng.range(3, 4) as usize;
    // two item kinds so "altogether" questions aggregate a strict subset
    let item_a = *rng.choose(&vocab::NOUNS[..24]);
    let mut item_b = *rng.choose(&vocab::NOUNS[..24]);
    while item_b == item_a {
        item_b = *rng.choose(&vocab::NOUNS[..24]);
    }
    let mut names: Vec<&'static str> = vec![];
    let mut entries = vec![];
    for i in 0..n_entries {
        let mut name = *rng.choose(vocab::NAMES);
        while names.contains(&name) {
            name = *rng.choose(vocab::NAMES);
        }
        names.push(name);
        entries.push(Entry {
            name,
            count: rng.range(1, 19),
            item: if i % 2 == 0 { item_a } else { item_b },
        });
    }
    entries
}

pub fn generate(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let entries = gen_passage(rng);
        let mut passage = String::from("passage ");
        for e in &entries {
            passage.push_str(&format!("{} has {} {} . ", e.name, e.count, e.item));
        }
        let item = entries[rng.below(entries.len())].item;
        let with_item: Vec<&Entry> = entries.iter().filter(|e| e.item == item).collect();
        let qtype = rng.below(4);
        let (question, answer) = match qtype {
            0 => {
                // aggregation
                let total: i64 = with_item.iter().map(|e| e.count).sum();
                (
                    format!("question how many {item} altogether ?"),
                    total.to_string(),
                )
            }
            1 => {
                // lookup
                let e = with_item[rng.below(with_item.len())];
                (
                    format!("question how many {item} does {} have ?", e.name),
                    e.count.to_string(),
                )
            }
            2 => {
                // comparison (argmax, ties broken by regenerating is
                // overkill: pick max; if tie the first max is gold)
                let best = with_item.iter().max_by_key(|e| e.count).unwrap();
                (
                    format!("question who has the most {item} ?"),
                    best.name.to_string(),
                )
            }
            _ => {
                // difference between two holders of the same item (falls
                // back to lookup when only one holder exists)
                if with_item.len() >= 2 {
                    let (a, b) = (with_item[0], with_item[1]);
                    let (hi, lo) = if a.count >= b.count { (a, b) } else { (b, a) };
                    (
                        format!(
                            "question how many more {item} does {} have than {} ?",
                            hi.name, lo.name
                        ),
                        (hi.count - lo.count).to_string(),
                    )
                } else {
                    let e = with_item[0];
                    (
                        format!("question how many {item} does {} have ?", e.name),
                        e.count.to_string(),
                    )
                }
            }
        };
        let prompt = tok.encode(&format!("{passage}{question}"));
        let answer = tok.encode(&answer);
        Example::generation(prompt, answer)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation_answers_are_sums() {
        let tok = Tokenizer::new();
        let d = generate(&tok, 21, Sizes { train: 100, val: 0, test: 0 });
        let mut checked = 0;
        for ex in &d.train {
            let text = tok.decode(&ex.prompt);
            if !text.contains("altogether") {
                continue;
            }
            // parse "X has N item ." entries for the asked item
            let item = text
                .split_whitespace()
                .skip_while(|w| *w != "many")
                .nth(1)
                .unwrap()
                .to_string();
            let words: Vec<&str> = text.split_whitespace().collect();
            let mut sum: i64 = 0;
            let mut i = 0;
            while i + 3 < words.len() {
                if words[i + 1] == "has" {
                    // number is one or more digit tokens starting at i+2
                    let mut ndigits = String::new();
                    let mut j = i + 2;
                    while j < words.len()
                        && words[j].len() == 1
                        && words[j].chars().all(|c| c.is_ascii_digit())
                    {
                        ndigits.push_str(words[j]);
                        j += 1;
                    }
                    if j < words.len() && words[j] == item {
                        if let Ok(n) = ndigits.parse::<i64>() {
                            sum += n;
                        }
                    }
                }
                i += 1;
            }
            let ans = tok.decode(&ex.answer).replace(' ', "");
            assert_eq!(ans.parse::<i64>().unwrap(), sum, "{text}");
            checked += 1;
        }
        assert!(checked > 5, "too few aggregation questions: {checked}");
    }

    #[test]
    fn answers_nonempty_and_short() {
        let tok = Tokenizer::new();
        let d = generate(&tok, 22, Sizes { train: 50, val: 0, test: 0 });
        for ex in &d.train {
            assert!(!ex.answer.is_empty());
            assert!(ex.answer.len() <= 4);
            assert!(!ex.is_choice());
        }
    }
}
