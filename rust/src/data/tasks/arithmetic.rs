//! Arithmetic-reasoning suites (Table 4 columns): synthetic analogs of
//! AQuA (multiple choice), GSM8K (two-step), MAWPS (one-step), and SVAMP
//! (one-step with distractors).  Generation tasks parse the *last
//! number* of the model output, exactly the paper's protocol (App. D);
//! AQuA is excluded from the average like the paper does.

use crate::data::example::TaskData;
use crate::data::tasks::{gen_splits, Sizes};
use crate::data::tokenizer::Tokenizer;
use crate::data::vocab;
use crate::data::Example;
use crate::util::rng::Rng;

/// MAWPS analog: one-step add/subtract word problem.
pub fn mawps(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let name = *rng.choose(vocab::NAMES);
        let noun = *rng.choose(&vocab::NOUNS[..24]);
        let a = rng.range(3, 30);
        let add = rng.below(2) == 0;
        let (verb, b, ans) = if add {
            let b = rng.range(2, 20);
            ("buys", b, a + b)
        } else {
            let b = rng.range(1, a - 1);
            ("gives", b, a - b)
        };
        let prompt = tok.encode(&format!(
            "{name} has {a} {noun} . {name} {verb} {b} {noun} . question how many {noun} does {name} have ?"
        ));
        Example::generation(prompt, tok.encode_number(ans as u64))
    })
}

/// SVAMP analog: one-step problem with an irrelevant distractor entity.
pub fn svamp(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let name = *rng.choose(vocab::NAMES);
        let mut other = *rng.choose(vocab::NAMES);
        while other == name {
            other = *rng.choose(vocab::NAMES);
        }
        let noun = *rng.choose(&vocab::NOUNS[..24]);
        let mut noun2 = *rng.choose(&vocab::NOUNS[..24]);
        while noun2 == noun {
            noun2 = *rng.choose(&vocab::NOUNS[..24]);
        }
        let a = rng.range(3, 30);
        let c = rng.range(1, 30); // distractor count
        let add = rng.below(2) == 0;
        let (verb, b, ans) = if add {
            let b = rng.range(2, 20);
            ("buys", b, a + b)
        } else {
            let b = rng.range(1, a - 1);
            ("gives", b, a - b)
        };
        let prompt = tok.encode(&format!(
            "{name} has {a} {noun} . {other} has {c} {noun2} . {name} {verb} {b} {noun} . question how many {noun} does {name} have ?"
        ));
        Example::generation(prompt, tok.encode_number(ans as u64))
    })
}

/// GSM8K analog: two-step reasoning (add/subtract then add/double).
pub fn gsm(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let name = *rng.choose(vocab::NAMES);
        let noun = *rng.choose(&vocab::NOUNS[..24]);
        let a = rng.range(2, 15);
        let b = rng.range(2, 15);
        let mid = a + b;
        let (second, ans) = match rng.below(3) {
            0 => ("then it doubles .".to_string(), mid * 2),
            1 => {
                let c = rng.range(1, mid - 1);
                (format!("then {name} gives {c} {noun} ."), mid - c)
            }
            _ => {
                let c = rng.range(2, 10);
                (format!("then {name} finds {c} more {noun} ."), mid + c)
            }
        };
        let prompt = tok.encode(&format!(
            "{name} has {a} {noun} . {name} buys {b} more {noun} . {second} question how many {noun} does {name} have ?"
        ));
        Example::generation(prompt, tok.encode_number(ans as u64))
    })
}

/// AQuA analog: multiple-choice arithmetic with 5 numeric options.  Like
/// the paper's AQuA, this is hard at our scale (all models ~chance) and
/// is excluded from the Table-4 average.
pub fn aqua(tok: &Tokenizer, seed: u64, sizes: Sizes) -> TaskData {
    gen_splits(seed, sizes, |rng: &mut Rng| {
        let a = rng.range(3, 40);
        let b = rng.range(3, 40);
        let mul = rng.below(2) == 0;
        let ans = if mul { a * 2 + b } else { a + b * 2 };
        let prompt = tok.encode(&format!(
            "question {} times 2 plus {} equals ? choose the best option",
            if mul { a } else { b },
            if mul { b } else { a },
        ));
        let correct = rng.below(5);
        let mut opts = vec![];
        let mut used = vec![ans];
        for slot in 0..5 {
            if slot == correct {
                opts.push(tok.encode_number(ans as u64));
            } else {
                let mut w = ans + rng.range(-9, 9);
                while used.contains(&w) || w < 0 {
                    w = ans + rng.range(-15, 15);
                }
                used.push(w);
                opts.push(tok.encode_number(w as u64));
            }
        }
        Example::choice(prompt, opts, correct)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_nums(text: &str) -> Vec<i64> {
        let mut nums = vec![];
        let mut cur = String::new();
        for w in text.split_whitespace() {
            if w.len() == 1 && w.chars().all(|c| c.is_ascii_digit()) {
                cur.push_str(w);
            } else {
                if !cur.is_empty() {
                    nums.push(cur.parse().unwrap());
                    cur.clear();
                }
            }
        }
        if !cur.is_empty() {
            nums.push(cur.parse().unwrap());
        }
        nums
    }

    #[test]
    fn mawps_answers_correct() {
        let tok = Tokenizer::new();
        let d = mawps(&tok, 41, Sizes { train: 80, val: 0, test: 0 });
        for ex in &d.train {
            let text = tok.decode(&ex.prompt);
            let nums = parse_nums(&text);
            assert_eq!(nums.len(), 2, "{text}");
            let ans: i64 = tok.decode(&ex.answer).replace(' ', "").parse().unwrap();
            if text.contains("buys") {
                assert_eq!(ans, nums[0] + nums[1], "{text}");
            } else {
                assert_eq!(ans, nums[0] - nums[1], "{text}");
            }
            assert!(ans >= 0);
        }
    }

    #[test]
    fn gsm_two_step_correct() {
        let tok = Tokenizer::new();
        let d = gsm(&tok, 42, Sizes { train: 80, val: 0, test: 0 });
        for ex in &d.train {
            let text = tok.decode(&ex.prompt);
            let nums = parse_nums(&text);
            let ans: i64 = tok.decode(&ex.answer).replace(' ', "").parse().unwrap();
            let mid = nums[0] + nums[1];
            if text.contains("doubles") {
                assert_eq!(ans, mid * 2, "{text}");
            } else if text.contains("gives") {
                assert_eq!(ans, mid - nums[2], "{text}");
            } else {
                assert_eq!(ans, mid + nums[2], "{text}");
            }
        }
    }

    #[test]
    fn aqua_has_five_distinct_options() {
        let tok = Tokenizer::new();
        let d = aqua(&tok, 43, Sizes { train: 40, val: 0, test: 0 });
        for ex in &d.train {
            assert_eq!(ex.options.len(), 5);
            let set: std::collections::HashSet<_> = ex.options.iter().collect();
            assert_eq!(set.len(), 5);
        }
    }

    #[test]
    fn svamp_distractor_does_not_change_answer() {
        let tok = Tokenizer::new();
        let d = svamp(&tok, 44, Sizes { train: 60, val: 0, test: 0 });
        for ex in &d.train {
            let text = tok.decode(&ex.prompt);
            let nums = parse_nums(&text);
            // nums: [a, c(distractor), b]
            assert_eq!(nums.len(), 3, "{text}");
            let ans: i64 = tok.decode(&ex.answer).replace(' ', "").parse().unwrap();
            if text.contains("buys") {
                assert_eq!(ans, nums[0] + nums[2], "{text}");
            } else {
                assert_eq!(ans, nums[0] - nums[2], "{text}");
            }
        }
    }
}
