//! Downstream task generators — synthetic analogs of every dataset in
//! the paper's App. D table, sharing the pretraining vocabulary:
//!
//! | paper dataset        | analog        | module      | metric   |
//! |----------------------|---------------|-------------|----------|
//! | RTE                  | rte_syn       | rte         | accuracy |
//! | DROP                 | drop_syn      | drop        | token F1 |
//! | BoolQ..OBQA (8)      | *_syn         | commonsense | accuracy |
//! | AQuA/GSM8K/MAWPS/SVAMP| *_syn        | arithmetic  | accuracy |
//! | GLUE (5)             | *_syn         | glue        | accuracy |
//!
//! Mixed fine-tuning sets (`commonsense_mix`, `math_mix`) mirror
//! COMMONSENSE170K / MATH10K: train on the union, evaluate per-suite.

pub mod rte;
pub mod drop;
pub mod commonsense;
pub mod arithmetic;
pub mod glue;

use crate::data::example::TaskData;
use crate::data::tokenizer::Tokenizer;
use crate::data::Example;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Evaluation metric for a task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Option scoring (choice tasks) or parsed-answer match (generation).
    Accuracy,
    /// Token-overlap F1 on the generated answer (DROP protocol).
    F1,
}

/// Split sizes (train, val, test).
#[derive(Clone, Copy, Debug)]
pub struct Sizes {
    pub train: usize,
    pub val: usize,
    pub test: usize,
}

impl Default for Sizes {
    fn default() -> Self {
        Sizes { train: 400, val: 100, test: 200 }
    }
}

/// Generate disjoint splits from per-split seed streams.
pub fn gen_splits<F>(seed: u64, sizes: Sizes, mut gen_one: F) -> TaskData
where
    F: FnMut(&mut Rng) -> Example,
{
    let mut make = |stream: &str, n: usize| -> Vec<Example> {
        let mut rng = Rng::stream(seed, stream);
        (0..n).map(|_| gen_one(&mut rng)).collect()
    };
    TaskData {
        train: make("train", sizes.train),
        val: make("val", sizes.val),
        test: make("test", sizes.test),
    }
}

/// All registered task names.
pub const TASKS: &[&str] = &[
    "rte_syn", "drop_syn",
    "boolq_syn", "piqa_syn", "siqa_syn", "hellas_syn", "winog_syn",
    "arce_syn", "arcc_syn", "obqa_syn",
    "aqua_syn", "gsm_syn", "mawps_syn", "svamp_syn",
    "sst2_syn", "mrpc_syn", "cola_syn", "stsb_syn",
];

/// Commonsense suite (Table 3 columns, in paper order).
pub const COMMONSENSE_SUITE: &[&str] = &[
    "boolq_syn", "piqa_syn", "siqa_syn", "hellas_syn", "winog_syn",
    "arce_syn", "arcc_syn", "obqa_syn",
];

/// Arithmetic suite (Table 4 columns, in paper order).
pub const ARITHMETIC_SUITE: &[&str] = &["aqua_syn", "gsm_syn", "mawps_syn", "svamp_syn"];

/// GLUE suite (Table F.7 columns, in paper order).
pub const GLUE_SUITE: &[&str] = &["sst2_syn", "mrpc_syn", "cola_syn", "rte_syn", "stsb_syn"];

pub fn metric_for(task: &str) -> Metric {
    match task {
        "drop_syn" => Metric::F1,
        _ => Metric::Accuracy,
    }
}

/// Generate a task by name.
pub fn generate(task: &str, tok: &Tokenizer, seed: u64, sizes: Sizes) -> Result<TaskData> {
    Ok(match task {
        "rte_syn" => rte::generate(tok, seed, sizes),
        "drop_syn" => drop::generate(tok, seed, sizes),
        "boolq_syn" => commonsense::boolq(tok, seed, sizes),
        "piqa_syn" => commonsense::piqa(tok, seed, sizes),
        "siqa_syn" => commonsense::siqa(tok, seed, sizes),
        "hellas_syn" => commonsense::hellaswag(tok, seed, sizes),
        "winog_syn" => commonsense::winogrande(tok, seed, sizes),
        "arce_syn" => commonsense::arc_easy(tok, seed, sizes),
        "arcc_syn" => commonsense::arc_challenge(tok, seed, sizes),
        "obqa_syn" => commonsense::obqa(tok, seed, sizes),
        "aqua_syn" => arithmetic::aqua(tok, seed, sizes),
        "gsm_syn" => arithmetic::gsm(tok, seed, sizes),
        "mawps_syn" => arithmetic::mawps(tok, seed, sizes),
        "svamp_syn" => arithmetic::svamp(tok, seed, sizes),
        "sst2_syn" => glue::sst2(tok, seed, sizes),
        "mrpc_syn" => glue::mrpc(tok, seed, sizes),
        "cola_syn" => glue::cola(tok, seed, sizes),
        "stsb_syn" => glue::stsb(tok, seed, sizes),
        _ => return Err(Error::Data(format!("unknown task '{task}'"))),
    })
}

/// Mixed training set over a suite (train/val merged across tasks,
/// shuffled; per-task tests remain separate for evaluation).
pub fn generate_mix(suite: &[&str], tok: &Tokenizer, seed: u64, sizes: Sizes) -> Result<TaskData> {
    let parts: Result<Vec<TaskData>> = suite
        .iter()
        .map(|t| generate(t, tok, seed, sizes))
        .collect();
    let mut mix = TaskData::concat(parts?);
    let mut rng = Rng::stream(seed, "mix-shuffle");
    rng.shuffle(&mut mix.train);
    rng.shuffle(&mut mix.val);
    mix.test.clear(); // evaluation is per-suite
    Ok(mix)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::UNK;

    #[test]
    fn all_tasks_generate_clean_examples() {
        let tok = Tokenizer::new();
        let sizes = Sizes { train: 8, val: 4, test: 4 };
        for task in TASKS {
            let data = generate(task, &tok, 123, sizes).unwrap();
            assert_eq!(data.train.len(), 8, "{task}");
            assert_eq!(data.test.len(), 4, "{task}");
            for ex in data.train.iter().chain(&data.test) {
                assert!(!ex.prompt.is_empty(), "{task}");
                assert!(!ex.answer.is_empty(), "{task}");
                assert!(!ex.prompt.contains(&UNK), "{task}: {}", tok.decode(&ex.prompt));
                assert!(!ex.answer.contains(&UNK), "{task}: {}", tok.decode(&ex.answer));
                assert!(
                    ex.prompt.len() + ex.answer.len() <= 62,
                    "{task} too long: {} + {}",
                    ex.prompt.len(),
                    ex.answer.len()
                );
                if ex.is_choice() {
                    assert!(ex.correct < ex.options.len(), "{task}");
                    assert_eq!(ex.options[ex.correct], ex.answer, "{task}");
                }
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        let tok = Tokenizer::new();
        let sizes = Sizes { train: 4, val: 2, test: 2 };
        let a = generate("drop_syn", &tok, 5, sizes).unwrap();
        let b = generate("drop_syn", &tok, 5, sizes).unwrap();
        for (x, y) in a.train.iter().zip(&b.train) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn splits_differ() {
        let tok = Tokenizer::new();
        let sizes = Sizes { train: 20, val: 20, test: 20 };
        let d = generate("mawps_syn", &tok, 9, sizes).unwrap();
        // at least one example differs between train and test prefixes
        let same = d
            .train
            .iter()
            .zip(&d.test)
            .filter(|(a, b)| a.prompt == b.prompt)
            .count();
        assert!(same < d.train.len() / 2);
    }

    #[test]
    fn mix_shuffles_and_combines() {
        let tok = Tokenizer::new();
        let sizes = Sizes { train: 10, val: 5, test: 5 };
        let mix = generate_mix(&["boolq_syn", "piqa_syn"], &tok, 3, sizes).unwrap();
        assert_eq!(mix.train.len(), 20);
        assert!(mix.test.is_empty());
    }
}
