//! Synthetic pretraining corpus.
//!
//! The base models are pretrained in-repo on this corpus (by the same
//! rust driver, method = full training).  The mixture is designed the
//! way real LLM pretraining data is: it covers downstream *surface
//! forms* — including QA-format documents that use the literal `SEP`
//! answer marker, so answer-token embeddings (yes/no, digits, option
//! words) are trained — while the downstream *task mappings*
//! (entailment judgment, cross-entity aggregation, polarity, two-hop
//! composition, ...) never appear and must be learned at fine-tune
//! time.  This is what makes the paper's low-vs-high intrinsic-rank
//! dichotomy reproducible: tasks close to pretraining behaviour (RTE
//! analog) need low-rank touch-ups, tasks that re-bind the
//! representation space (DROP analog) need high-rank updates.

use crate::data::tokenizer::Tokenizer;
use crate::data::vocab::{self, EOS, SEP};
use crate::util::rng::Rng;

/// Generate one corpus "document" (a few sentences / one QA) as tokens.
pub fn gen_document(tok: &Tokenizer, rng: &mut Rng) -> Vec<u16> {
    // QA-format documents get double weight (they are what downstream
    // fine-tuning retargets).
    let t = match rng.below(16) {
        v @ 0..=6 => v,
        v @ 7..=10 => v,
        11 => 7 + rng.below(4),
        12 => 9, // extra equality QA (the hardest circuit to learn)
        13 => 9,
        _ => 11,
    };
    match t {
        // ---- plain statements (world knowledge surface forms) -----------
        0 => {
            // possession: "<name> has <n> <noun> ."
            tok.encode(&format!(
                "{} has {} {} .",
                rng.choose(vocab::NAMES),
                rng.range(1, 99),
                rng.choose(vocab::NOUNS)
            ))
        }
        1 => tok.encode(&format!(
            "the {} is {} .",
            rng.choose(vocab::NOUNS),
            rng.choose(vocab::ADJS)
        )),
        2 => {
            let a = rng.range(0, 99);
            let b = rng.range(0, 99);
            if rng.below(2) == 0 {
                tok.encode(&format!("{} plus {} equals {} .", a, b, a + b))
            } else {
                let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
                tok.encode(&format!("{} minus {} equals {} .", hi, lo, hi - lo))
            }
        }
        3 => tok.encode(&format!(
            "{} {} the {} {} .",
            rng.choose(vocab::NAMES),
            rng.choose(vocab::VERBS),
            rng.choose(vocab::ADJS),
            rng.choose(vocab::NOUNS)
        )),
        4 => {
            let i = rng.below(vocab::TOOLS.len());
            tok.encode(&format!("use the {} to {} .", vocab::TOOLS[i], vocab::TOOL_TASKS[i]))
        }
        5 => tok.encode(&format!(
            "the {} is made of {} .",
            rng.choose(vocab::NOUNS),
            rng.choose(vocab::MATERIALS)
        )),
        6 => tok.encode(&format!(
            "{} {} {} .",
            rng.choose(vocab::NAMES),
            rng.choose(&vocab::VERBS[16..24]),
            rng.choose(vocab::NAMES)
        )),

        // ---- QA-format documents (teach the answer format + answer-token
        //      embeddings, with mappings DIFFERENT from every downstream
        //      task) ------------------------------------------------------
        7 => {
            // attribute recall QA (open answer — the attribute is read
            // back verbatim; downstream yes/no judgment is never shown)
            let noun = *rng.choose(vocab::NOUNS);
            let adj = *rng.choose(vocab::ADJS);
            let mut doc = tok.encode(&format!(
                "the {noun} is {adj} . question what sort is the {noun} ?"
            ));
            doc.push(SEP);
            doc.extend(tok.encode(&format!("{adj} .")));
            doc
        }
        8 => {
            // count read-back QA (single entity; no aggregation)
            let name = *rng.choose(vocab::NAMES);
            let noun = *rng.choose(vocab::NOUNS);
            let k = rng.range(1, 40);
            let mut doc = tok.encode(&format!(
                "{name} has {k} {noun} . question how many {noun} ?"
            ));
            doc.push(SEP);
            doc.extend(tok.encode(&format!("{k} .")));
            doc
        }
        9 => {
            // token-identity verification QA (trains yes/no embeddings
            // and a *general* equality circuit over mixed word pools;
            // the downstream judgments — entailment, polarity,
            // acceptability — are never shown)
            let pool: &[&str] = match rng.below(4) {
                0 => vocab::NOUNS,
                1 => vocab::ADJS,
                2 => vocab::NAMES,
                _ => vocab::TOOLS,
            };
            let a = *rng.choose(pool);
            let same = rng.below(2) == 0;
            let b = if same {
                a
            } else {
                let mut other = *rng.choose(pool);
                while other == a {
                    other = *rng.choose(pool);
                }
                other
            };
            let mut doc = tok.encode(&format!("question is {a} the same as {b} ?"));
            doc.push(SEP);
            doc.extend(tok.encode(if same { "yes ." } else { "no ." }));
            doc
        }
        10 => {
            // arithmetic QA (echoes doc-type 2 in QA format; small sums
            // so digit addition is learnable at this scale)
            let a = rng.range(0, 20);
            let b = rng.range(0, 20);
            let mut doc = tok.encode(&format!("question {a} plus {b} ?"));
            doc.push(SEP);
            doc.extend(tok.encode(&format!("{} .", a + b)));
            doc
        }
        _ => {
            // counting sequence
            let a = rng.range(0, 6);
            tok.encode(&format!("{} {} {} {} .", a, a + 1, a + 2, a + 3))
        }
    }
}

/// Build a pretraining batch: `[batch, seq+1]` token rows (BOS + packed
/// documents separated by EOS) and `[batch, seq]` loss mask over
/// non-pad targets.
pub fn pretrain_batch(
    tok: &Tokenizer,
    rng: &mut Rng,
    batch: usize,
    seq: usize,
) -> (Vec<i32>, Vec<f32>) {
    let mut tokens = vec![vocab::PAD as i32; batch * (seq + 1)];
    let mut mask = vec![0.0f32; batch * seq];
    for b in 0..batch {
        let mut row = vec![vocab::BOS];
        while row.len() < seq + 1 {
            row.extend(gen_document(tok, rng));
            row.push(EOS);
        }
        row.truncate(seq + 1);
        for (i, &t) in row.iter().enumerate() {
            tokens[b * (seq + 1) + i] = t as i32;
        }
        for i in 0..seq {
            mask[b * seq + i] = 1.0; // every target position is real text
        }
    }
    (tokens, mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::UNK;

    #[test]
    fn documents_are_in_vocab() {
        let tok = Tokenizer::new();
        let mut rng = Rng::new(42);
        for _ in 0..200 {
            let doc = gen_document(&tok, &mut rng);
            assert!(!doc.is_empty());
            assert!(!doc.contains(&UNK), "OOV in: {}", tok.decode(&doc));
        }
    }

    #[test]
    fn qa_documents_contain_sep_and_answers() {
        let tok = Tokenizer::new();
        let mut rng = Rng::new(7);
        let mut saw_sep = 0;
        let mut saw_yes = 0;
        for _ in 0..500 {
            let doc = gen_document(&tok, &mut rng);
            if doc.contains(&SEP) {
                saw_sep += 1;
                // SEP must be followed by at least one answer token
                let pos = doc.iter().position(|&t| t == SEP).unwrap();
                assert!(pos + 1 < doc.len(), "SEP at end: {}", tok.decode(&doc));
            }
            if doc.contains(&tok.id("yes")) || doc.contains(&tok.id("no")) {
                saw_yes += 1;
            }
        }
        assert!(saw_sep > 100, "QA docs too rare: {saw_sep}");
        assert!(saw_yes > 20, "yes/no answers too rare: {saw_yes}");
    }

    #[test]
    fn batch_shapes() {
        let tok = Tokenizer::new();
        let mut rng = Rng::new(1);
        let (tokens, mask) = pretrain_batch(&tok, &mut rng, 4, 32);
        assert_eq!(tokens.len(), 4 * 33);
        assert_eq!(mask.len(), 4 * 32);
        assert!(mask.iter().all(|&m| m == 1.0));
    }

    #[test]
    fn deterministic_by_seed() {
        let tok = Tokenizer::new();
        let a = gen_document(&tok, &mut Rng::new(7));
        let b = gen_document(&tok, &mut Rng::new(7));
        assert_eq!(a, b);
    }
}
