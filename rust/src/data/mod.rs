//! Data pipeline: vocabulary, tokenizer, synthetic pretraining corpus,
//! the 19 downstream task generators (paper App. D analogs), batching,
//! metrics, and the vector-regression tasks ([`synth`]) driving the
//! artifact-free host trainer.
//!
//! Every dataset is a deterministic function of a seed; train/val/test
//! splits are disjoint by construction (distinct seed streams), matching
//! the paper's protocol of carving a validation set out of train and
//! never touching test for tuning (App. E).

pub mod vocab;
pub mod tokenizer;
pub mod corpus;
pub mod example;
pub mod batcher;
pub mod metrics;
pub mod synth;
pub mod tasks;

pub use example::{Example, Split, TaskData};
pub use tokenizer::Tokenizer;
