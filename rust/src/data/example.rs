//! Core dataset types shared by every task generator.

/// One supervised example.
///
/// * Generation tasks: `options` is empty; the target is `answer`.
/// * Option tasks (yes/no or multiple choice): `options` holds the
///   candidate answer token sequences and `correct` the gold index; the
///   evaluator scores each option by sequence log-probability (the
///   paper's "highest probability choice" protocol, App. H).
#[derive(Clone, Debug)]
pub struct Example {
    pub prompt: Vec<u16>,
    pub answer: Vec<u16>,
    pub options: Vec<Vec<u16>>,
    pub correct: usize,
}

impl Example {
    pub fn generation(prompt: Vec<u16>, answer: Vec<u16>) -> Self {
        Example { prompt, answer, options: vec![], correct: 0 }
    }

    pub fn choice(prompt: Vec<u16>, options: Vec<Vec<u16>>, correct: usize) -> Self {
        let answer = options[correct].clone();
        Example { prompt, answer, options, correct }
    }

    pub fn is_choice(&self) -> bool {
        !self.options.is_empty()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

/// A generated dataset with disjoint splits.
#[derive(Clone, Debug, Default)]
pub struct TaskData {
    pub train: Vec<Example>,
    pub val: Vec<Example>,
    pub test: Vec<Example>,
}

impl TaskData {
    pub fn split(&self, s: Split) -> &[Example] {
        match s {
            Split::Train => &self.train,
            Split::Val => &self.val,
            Split::Test => &self.test,
        }
    }

    /// Concatenate several datasets (mixed fine-tuning sets like the
    /// COMMONSENSE170K analog).
    pub fn concat(parts: Vec<TaskData>) -> TaskData {
        let mut out = TaskData::default();
        for p in parts {
            out.train.extend(p.train);
            out.val.extend(p.val);
            out.test.extend(p.test);
        }
        out
    }
}
