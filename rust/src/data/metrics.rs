//! Evaluation metrics, mirroring the paper's App. D protocol:
//!
//! * **token F1** for DROP-style phrase answers,
//! * **exact numeric match on the last parsed number** for free-form
//!   arithmetic answers,
//! * **accuracy** for option tasks (the option index with the highest
//!   sequence log-probability).

use crate::data::vocab::{DIGIT0, EOS, PAD, SEP};

/// Token-level F1 between predicted and gold token sequences (bag
/// overlap, DROP protocol).
pub fn token_f1(pred: &[u16], gold: &[u16]) -> f64 {
    if pred.is_empty() || gold.is_empty() {
        return if pred.is_empty() && gold.is_empty() { 1.0 } else { 0.0 };
    }
    let mut gold_counts = std::collections::HashMap::new();
    for &t in gold {
        *gold_counts.entry(t).or_insert(0usize) += 1;
    }
    let mut overlap = 0usize;
    for &t in pred {
        if let Some(c) = gold_counts.get_mut(&t) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / pred.len() as f64;
    let recall = overlap as f64 / gold.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

/// Parse the *last* number from a generated token stream (the paper's
/// arithmetic answer rule): the final maximal run of digit tokens.
pub fn parse_last_number(tokens: &[u16]) -> Option<i64> {
    let is_digit = |t: u16| (DIGIT0..DIGIT0 + 10).contains(&t);
    let mut best: Option<i64> = None;
    let mut cur: Option<i64> = None;
    for &t in tokens {
        if is_digit(t) {
            let d = (t - DIGIT0) as i64;
            cur = Some(cur.unwrap_or(0) * 10 + d);
        } else {
            if let Some(v) = cur.take() {
                best = Some(v);
            }
        }
    }
    if let Some(v) = cur {
        best = Some(v);
    }
    best
}

/// Strip generation control tokens (everything from EOS on, plus
/// PAD/SEP) from a decoded continuation.
pub fn clean_generation(tokens: &[u16]) -> Vec<u16> {
    let mut out = vec![];
    for &t in tokens {
        if t == EOS {
            break;
        }
        if t == PAD || t == SEP {
            continue;
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f1_exact_match() {
        assert_eq!(token_f1(&[1, 2, 3], &[1, 2, 3]), 1.0);
    }

    #[test]
    fn f1_no_overlap() {
        assert_eq!(token_f1(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn f1_partial() {
        // pred {1,2}, gold {2,3}: overlap 1, p=r=0.5 -> f1=0.5
        assert!((token_f1(&[1, 2], &[2, 3]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_respects_counts() {
        // pred has 2 ; gold has only one "2": overlap capped at 1
        let f1 = token_f1(&[2, 2], &[2]);
        let expect = 2.0 * 0.5 * 1.0 / 1.5;
        assert!((f1 - expect).abs() < 1e-12);
    }

    #[test]
    fn f1_order_invariant() {
        assert_eq!(token_f1(&[3, 1, 2], &[1, 2, 3]), 1.0);
    }

    #[test]
    fn parse_last_number_basic() {
        // tokens: "4" "2" noun "7" => last number is 7
        let toks = [DIGIT0 + 4, DIGIT0 + 2, 100, DIGIT0 + 7];
        assert_eq!(parse_last_number(&toks), Some(7));
    }

    #[test]
    fn parse_multidigit() {
        let toks = [100, DIGIT0 + 4, DIGIT0 + 2];
        assert_eq!(parse_last_number(&toks), Some(42));
    }

    #[test]
    fn parse_no_number() {
        assert_eq!(parse_last_number(&[100, 101]), None);
    }

    #[test]
    fn clean_stops_at_eos() {
        let toks = [10, 11, EOS, 12];
        assert_eq!(clean_generation(&toks), vec![10, 11]);
    }
}
