//! Batch assembly for training and evaluation.
//!
//! Row layout (paper-style supervised fine-tuning): `BOS prompt SEP
//! answer EOS PAD...`, with the loss mask covering exactly the target
//! positions that predict the answer tokens and the closing EOS — the
//! model is trained to produce the answer given the prompt, not to model
//! the prompt.

use crate::data::example::Example;
use crate::data::vocab::{BOS, EOS, PAD, SEP};
use crate::util::error::{Error, Result};
use crate::util::rng::{Rng, RngState};

/// A training/eval batch in the exact layout the HLO artifacts expect:
/// `tokens` is `[batch, seq+1]` i32, `mask` is `[batch, seq]` f32.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

/// Assemble one row: returns (row[seq+1], mask[seq]).
pub fn pack_example(ex: &Example, seq: usize) -> Result<(Vec<i32>, Vec<f32>)> {
    let mut row = Vec::with_capacity(seq + 1);
    row.push(BOS);
    row.extend_from_slice(&ex.prompt);
    row.push(SEP);
    let answer_start = row.len(); // first answer token position
    row.extend_from_slice(&ex.answer);
    row.push(EOS);
    if row.len() > seq + 1 {
        return Err(Error::Data(format!(
            "example too long: {} tokens > seq+1 = {}",
            row.len(),
            seq + 1
        )));
    }
    let end = row.len();
    row.resize(seq + 1, PAD);
    let mut mask = vec![0.0f32; seq];
    // target position t predicts token t+1; answer tokens + EOS live at
    // positions answer_start..end, so mask targets answer_start-1..end-1.
    for t in (answer_start - 1)..(end - 1) {
        mask[t] = 1.0;
    }
    Ok((row.into_iter().map(|t| t as i32).collect(), mask))
}

/// Pack a fixed-size batch from examples (repeats examples if fewer than
/// `batch` are given — used for the tail of an epoch).
pub fn pack_batch(examples: &[&Example], batch: usize, seq: usize) -> Result<Batch> {
    if examples.is_empty() {
        return Err(Error::Data("empty batch".into()));
    }
    let mut tokens = Vec::with_capacity(batch * (seq + 1));
    let mut mask = Vec::with_capacity(batch * seq);
    for i in 0..batch {
        let ex = examples[i % examples.len()];
        let (row, m) = pack_example(ex, seq)?;
        tokens.extend(row);
        mask.extend(m);
    }
    Ok(Batch { tokens, mask, batch, seq })
}

/// Infinite shuffled-epoch sampler over a training split.
pub struct Sampler {
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
}

/// Serializable snapshot of a [`Sampler`] (checkpoint v4 run
/// manifests): the current epoch's shuffled order, the position within
/// it, and the shuffler's [`RngState`] — everything a resumed trainer
/// needs to draw the exact index sequence an uninterrupted run would
/// have drawn.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplerState {
    pub order: Vec<usize>,
    pub pos: usize,
    pub rng: RngState,
}

impl Sampler {
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Rng::stream(seed, "sampler");
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        Sampler { order, pos: 0, rng }
    }

    /// Snapshot the sampler's full state for serialization.
    pub fn state(&self) -> SamplerState {
        SamplerState { order: self.order.clone(), pos: self.pos, rng: self.rng.state() }
    }

    /// Rebuild a sampler from a [`state`](Sampler::state) snapshot; the
    /// restored index sequence continues exactly where the snapshotted
    /// one left off (mid-epoch included).
    pub fn restore(st: SamplerState) -> Self {
        Sampler { order: st.order, pos: st.pos, rng: Rng::from_state(st.rng) }
    }

    /// Number of examples the sampler draws over.
    pub fn n_examples(&self) -> usize {
        self.order.len()
    }

    /// Next `k` example indices, reshuffling at epoch boundaries.
    pub fn next_indices(&mut self, k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            if self.pos >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.pos = 0;
            }
            out.push(self.order[self.pos]);
            self.pos += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(prompt: Vec<u16>, answer: Vec<u16>) -> Example {
        Example::generation(prompt, answer)
    }

    #[test]
    fn mask_covers_exactly_answer_targets() {
        let e = ex(vec![10, 11, 12], vec![20, 21]);
        let (row, mask) = pack_example(&e, 12).unwrap();
        // row: BOS 10 11 12 SEP 20 21 EOS PAD...
        assert_eq!(&row[..8], &[BOS as i32, 10, 11, 12, SEP as i32, 20, 21, EOS as i32]);
        // answer tokens at positions 5,6; EOS at 7 => mask targets 4,5,6
        let expect: Vec<f32> =
            (0..12).map(|t| if (4..=6).contains(&t) { 1.0 } else { 0.0 }).collect();
        assert_eq!(mask, expect);
    }

    #[test]
    fn mask_sum_equals_answer_len_plus_one() {
        let e = ex(vec![9; 7], vec![8; 3]);
        let (_, mask) = pack_example(&e, 20).unwrap();
        assert_eq!(mask.iter().sum::<f32>(), 4.0);
    }

    #[test]
    fn too_long_rejected() {
        let e = ex(vec![9; 30], vec![8; 30]);
        assert!(pack_example(&e, 32).is_err());
    }

    #[test]
    fn batch_repeats_when_short() {
        let e1 = ex(vec![1], vec![2]);
        let b = pack_batch(&[&e1], 4, 8).unwrap();
        assert_eq!(b.tokens.len(), 4 * 9);
        assert_eq!(&b.tokens[..9], &b.tokens[9..18]);
    }

    #[test]
    fn sampler_covers_epoch() {
        let mut s = Sampler::new(10, 1);
        let first: Vec<usize> = s.next_indices(10);
        let mut sorted = first.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn sampler_state_roundtrip_continues_draw_sequence() {
        // snapshot mid-epoch (7 draws into a 10-example split, batch 3
        // crosses the epoch boundary soon after): the restored sampler
        // must draw the exact sequence the original goes on to draw,
        // including the reshuffle at the boundary
        let mut a = Sampler::new(10, 42);
        a.next_indices(7);
        let st = a.state();
        assert_eq!(st.pos, 7);
        let mut b = Sampler::restore(st.clone());
        assert_eq!(b.n_examples(), 10);
        for _ in 0..20 {
            assert_eq!(a.next_indices(3), b.next_indices(3));
        }
        // a stale clone of the state restores the same sequence again
        let mut c = Sampler::restore(st);
        c.next_indices(3); // diverges from a/b's *current* position...
        let mut d = Sampler::restore(c.state());
        assert_eq!(c.next_indices(5), d.next_indices(5)); // ...but not from its own snapshot
    }
}
