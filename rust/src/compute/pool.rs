//! Persistent compute pool shared by every parallel kernel.
//!
//! PR 1/2 parallelized the hot paths with `std::thread::scope`, which
//! pays a full thread spawn + join per call — a training step crossed
//! that cost three to four times (tape forward, backward, the base
//! matmul, and potentially the optimizer).  This module replaces all of
//! those spawn sites with one fixed set of worker threads, parked on a
//! condvar and woken per parallel region.
//!
//! ## Job / chunk model
//!
//! A parallel region is `run(n_chunks, f)`: `f(i)` is called exactly
//! once for every chunk index `i < n_chunks`, by whichever thread
//! (workers or the submitter, which always participates) claims `i`
//! from a shared atomic counter.  **Chunk boundaries are a function of
//! the problem only** — [`chunks`] sizes them so each chunk carries
//! roughly [`PAR_MIN_FLOPS`] worth of work — never of the worker
//! count.  Chunks write disjoint output slices ([`DisjointChunks`])
//! and any cross-chunk reduction is performed by the caller in
//! ascending chunk order after `run` returns, so results are **bitwise
//! identical for any `QFT_THREADS`**: the thread count only changes
//! who executes a chunk, never what a chunk computes or the order
//! partial results are combined.  (The PR 2 scope-based kernels
//! derived chunk sizes from the worker count, so gate-gradient bit
//! patterns were only stable for a *fixed* `QFT_THREADS`.)
//!
//! ## Scheduling & shutdown semantics
//!
//! Submissions are serialized by a mutex (one region in flight; others
//! block — regions are short).  A region submitted from inside a pool
//! chunk (e.g. a `matmul` called by a trainer chunk) runs inline and
//! serial on the calling thread, so nesting can never deadlock.
//! Workers are spawned detached on first use and never join: they park
//! on the condvar between regions and die with the process.  A panic
//! inside a chunk is caught on the worker, the region completes, and
//! the submitter re-raises — a worker thread is never lost.
//!
//! `QFT_THREADS` caps how many workers participate per region (read at
//! submission, so tests can sweep it); `QFT_DISPATCH=spawn` routes
//! regions through a scoped-spawn dispatcher with the *same* chunk
//! claims — the PR 2 cost model on the PR 3 chunking — which is what
//! the `pool_vs_spawn` bench section measures.

use std::cell::Cell;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Work quantum: one chunk of a parallel region carries roughly this
/// many multiplies, and totals below ~one quantum run serial inline.
/// Replaces the per-call worker clamp (`tensor::num_threads`) and the
/// old 1M-multiply serial cutoffs: with parked workers the dispatch
/// cost is a condvar wake, so regions an eighth the old size are worth
/// splitting.
pub const PAR_MIN_FLOPS: usize = 1 << 17;

thread_local! {
    /// Set while this thread executes pool chunks (worker or
    /// participating submitter); nested regions run inline.
    static IN_PARALLEL: Cell<bool> = const { Cell::new(false) };
}

/// Chunk sizing for `units` independent work items of
/// `flops_per_unit` multiplies each: returns `(chunk_units,
/// n_chunks)` with chunks of `⌊PAR_MIN_FLOPS / flops_per_unit⌋` whole
/// units — i.e. *at most* about one [`PAR_MIN_FLOPS`] quantum each,
/// never fewer than one unit (so a unit wider than the quantum becomes
/// its own chunk).  Depends only on the problem shape — never on
/// thread count — which is what makes pooled results
/// `QFT_THREADS`-invariant.
pub fn chunks(units: usize, flops_per_unit: usize) -> (usize, usize) {
    if units == 0 {
        return (1, 0);
    }
    let chunk_units = (PAR_MIN_FLOPS / flops_per_unit.max(1)).clamp(1, units);
    (chunk_units, units.div_ceil(chunk_units))
}

/// Worker budget for one region: `QFT_THREADS` if set, else hardware
/// parallelism.  Only affects scheduling (who runs chunks), never
/// results.
fn target_workers() -> usize {
    std::env::var("QFT_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// `QFT_DISPATCH=spawn` selects the scoped-spawn dispatcher (PR 2 cost
/// model, identical chunk claims) — the bench baseline.
fn spawn_dispatch() -> bool {
    matches!(std::env::var("QFT_DISPATCH").as_deref(), Ok("spawn"))
}

/// Run `job(i)` once per chunk index `0..n_chunks`, in parallel when
/// the pool has workers and the region is not nested.  Returns after
/// every chunk completed.  Panics (after completion) if any chunk
/// panicked.
pub fn run<F: Fn(usize) + Sync>(n_chunks: usize, job: F) {
    if n_chunks == 0 {
        return;
    }
    let nested = IN_PARALLEL.with(|f| f.get());
    let workers = target_workers();
    if n_chunks == 1 || workers <= 1 || nested {
        for i in 0..n_chunks {
            job(i);
        }
        return;
    }
    if spawn_dispatch() {
        run_spawn(n_chunks, &job, workers);
    } else {
        global().run(n_chunks, &job, workers);
    }
}

/// Run `f`, converting a panic anywhere under it (including one
/// re-raised by [`run`] from a worker chunk) into a structured
/// [`Error::Compute`] on the calling thread.
///
/// This is the submitter-side half of the pool's panic safety: the
/// pool itself already survives a panicking chunk (caught per chunk,
/// region completes, workers stay parked — never poisoned), and this
/// wrapper keeps the unwind from propagating through a serving or
/// coordinator stack that wants `Result`s.  Fault-isolation boundaries
/// (e.g. `ServeBlock::decode_step`) wrap their bodies in it; the cost
/// when nothing panics is one `catch_unwind` frame, which is free on
/// the non-unwinding path.
pub fn catching<T>(
    f: impl FnOnce() -> crate::util::error::Result<T>,
) -> crate::util::error::Result<T> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            Err(crate::util::error::Error::Compute(msg))
        }
    }
}

/// One submitted parallel region.  `func` borrows the submitter's
/// stack; safety rests on `ComputePool::run` not returning until all
/// `n_chunks` chunks completed, and on late-waking workers bailing out
/// on the exhausted `next` counter before ever dereferencing `func`.
struct Job {
    func: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    done: AtomicUsize,
    n_chunks: usize,
    /// First caught chunk-panic payload; re-raised by the submitter
    /// with `resume_unwind` so the original message/location survive.
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}
// SAFETY: `func` points at a `Sync` closure and is only dereferenced
// while the submitting call frame is alive (see `Job` docs).
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Slot {
    epoch: u64,
    job: Option<Arc<Job>>,
    /// Workers with index < limit participate in the current epoch.
    active_limit: usize,
}

struct Shared {
    slot: Mutex<Slot>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// The process-wide pool: `available_parallelism() - 1` parked workers
/// (the submitter is the remaining lane).
struct ComputePool {
    shared: Arc<Shared>,
    submit_lock: Mutex<()>,
}

fn global() -> &'static ComputePool {
    static POOL: OnceLock<ComputePool> = OnceLock::new();
    POOL.get_or_init(ComputePool::new)
}

impl ComputePool {
    fn new() -> ComputePool {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot { epoch: 0, job: None, active_limit: 0 }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        for idx in 0..hw.saturating_sub(1) {
            let sh = shared.clone();
            std::thread::Builder::new()
                .name(format!("qft-pool-{idx}"))
                .spawn(move || worker_loop(&sh, idx))
                .expect("compute pool: worker spawn failed");
        }
        ComputePool { shared, submit_lock: Mutex::new(()) }
    }

    fn run(&self, n_chunks: usize, func: &(dyn Fn(usize) + Sync), workers: usize) {
        // recover from poisoning: the re-raise below unwinds with this
        // guard held, and the slot state it protects is always left
        // valid (job retired, counters exhausted) — later regions must
        // keep working after a caught panic
        let _submit = self.submit_lock.lock().unwrap_or_else(|p| p.into_inner());
        // SAFETY: the pointee outlives this call; `Job` is retired
        // (counter exhausted, slot cleared) before we return.
        let func = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(func)
        };
        let job = Arc::new(Job {
            func,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            n_chunks,
            panic_payload: Mutex::new(None),
        });
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.epoch += 1;
            slot.job = Some(job.clone());
            // never more executors than chunks: the submitter is one
            // lane, so at most n_chunks - 1 workers join this region
            // (a woken worker above the limit re-parks without touching
            // the job)
            slot.active_limit = (workers - 1).min(n_chunks - 1);
            self.shared.work_cv.notify_all();
        }
        execute(&self.shared, &job);
        let mut slot = self.shared.slot.lock().unwrap();
        while job.done.load(Ordering::Acquire) < n_chunks {
            slot = self.shared.done_cv.wait(slot).unwrap();
        }
        slot.job = None;
        drop(slot);
        let payload = job.panic_payload.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }
}

fn worker_loop(shared: &Shared, idx: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            while slot.epoch == seen {
                slot = shared.work_cv.wait(slot).unwrap();
            }
            seen = slot.epoch;
            if idx < slot.active_limit { slot.job.clone() } else { None }
        };
        if let Some(job) = job {
            execute(shared, &job);
        }
    }
}

/// Drain chunk indices from `job` on the current thread.
fn execute(shared: &Shared, job: &Job) {
    IN_PARALLEL.with(|f| f.set(true));
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n_chunks {
            break;
        }
        // SAFETY: the counter handed us an unclaimed chunk, so the
        // submitter is still inside `ComputePool::run` and `func` is
        // alive.
        let func = unsafe { &*job.func };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| func(i))) {
            let mut slot = job.panic_payload.lock().unwrap_or_else(|p| p.into_inner());
            slot.get_or_insert(payload);
        }
        if job.done.fetch_add(1, Ordering::Release) + 1 == job.n_chunks {
            let _slot = shared.slot.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
    IN_PARALLEL.with(|f| f.set(false));
}

/// The PR 2 cost model as a dispatcher: scoped threads spawned per
/// region, draining the same chunk counter — used by the
/// `pool_vs_spawn` bench to price the spawn overhead the pool removes.
/// Arithmetic is identical to the pooled path (same chunks, same
/// claim-any order) by construction.
fn run_spawn(n_chunks: usize, func: &(dyn Fn(usize) + Sync), workers: usize) {
    let next = AtomicUsize::new(0);
    let panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    // same panic contract as the pooled path: chunks are caught, the
    // region drains, the submitter re-raises the first payload — so
    // the IN_PARALLEL reset below always runs
    let drain = || {
        IN_PARALLEL.with(|f| f.set(true));
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n_chunks {
                break;
            }
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| func(i))) {
                let mut slot = panic_payload.lock().unwrap_or_else(|p| p.into_inner());
                slot.get_or_insert(payload);
            }
        }
        IN_PARALLEL.with(|f| f.set(false));
    };
    std::thread::scope(|s| {
        for _ in 1..workers.min(n_chunks) {
            s.spawn(&drain);
        }
        drain();
    });
    let payload = panic_payload.lock().unwrap_or_else(|p| p.into_inner()).take();
    if let Some(payload) = payload {
        std::panic::resume_unwind(payload);
    }
}

/// Hands out non-overlapping `&mut` sub-slices of one buffer by chunk
/// index, so a `Fn(usize)` pool job can write its own chunk without a
/// lock.  `slice(i)` covers `[i·chunk_len, min((i+1)·chunk_len, len))`
/// — together the chunks tile the buffer exactly.
pub struct DisjointChunks<'a, T> {
    ptr: *mut T,
    len: usize,
    chunk_len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: chunks are disjoint and each index is claimed by exactly one
// executor (the pool's chunk counter), so no two threads alias.
unsafe impl<T: Send> Send for DisjointChunks<'_, T> {}
unsafe impl<T: Send> Sync for DisjointChunks<'_, T> {}

impl<'a, T> DisjointChunks<'a, T> {
    pub fn new(data: &'a mut [T], chunk_len: usize) -> DisjointChunks<'a, T> {
        assert!(chunk_len > 0, "DisjointChunks: zero chunk length");
        DisjointChunks {
            ptr: data.as_mut_ptr(),
            len: data.len(),
            chunk_len,
            _marker: PhantomData,
        }
    }

    pub fn n_chunks(&self) -> usize {
        self.len.div_ceil(self.chunk_len)
    }

    /// Mutable view of chunk `i`.
    ///
    /// # Safety
    /// Each chunk index must be claimed by at most one live borrower —
    /// guaranteed when `i` comes from a [`run`] chunk counter and the
    /// borrow ends with the job closure.
    #[allow(clippy::mut_from_ref)] // disjointness contract documented above
    pub unsafe fn slice(&self, i: usize) -> &'a mut [T] {
        let start = i * self.chunk_len;
        debug_assert!(start < self.len, "chunk {i} out of range");
        let end = (start + self.chunk_len).min(self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), end - start)
    }
}

/// [`DisjointChunks`] with caller-chosen, non-uniform boundaries:
/// span `i` covers `[starts[i], starts[i+1])` (the last span runs to
/// the end of the buffer), so a pool job whose chunks own
/// variable-sized output regions — e.g. the batched paged-attention
/// score panels, one `(t+1) × n_heads` panel per request — can write
/// its own span without a lock.  `starts` must be ascending and begin
/// at 0; together the spans tile the buffer exactly.
pub struct DisjointSpans<'a, T> {
    ptr: *mut T,
    len: usize,
    starts: &'a [usize],
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: spans are disjoint by the ascending-starts contract and each
// index is claimed by exactly one executor (the pool's chunk counter),
// so no two threads alias.
unsafe impl<T: Send> Send for DisjointSpans<'_, T> {}
unsafe impl<T: Send> Sync for DisjointSpans<'_, T> {}

impl<'a, T> DisjointSpans<'a, T> {
    pub fn new(data: &'a mut [T], starts: &'a [usize]) -> DisjointSpans<'a, T> {
        debug_assert!(starts.first().map_or(true, |&s| s == 0), "spans must start at 0");
        debug_assert!(starts.windows(2).all(|w| w[0] <= w[1]), "span starts must ascend");
        debug_assert!(starts.last().map_or(true, |&s| s <= data.len()), "span past the buffer");
        DisjointSpans { ptr: data.as_mut_ptr(), len: data.len(), starts, _marker: PhantomData }
    }

    pub fn n_spans(&self) -> usize {
        self.starts.len()
    }

    /// Mutable view of span `i`.
    ///
    /// # Safety
    /// Each span index must be claimed by at most one live borrower —
    /// guaranteed when `i` comes from a [`run`] chunk counter and the
    /// borrow ends with the job closure.
    #[allow(clippy::mut_from_ref)] // disjointness contract documented above
    pub unsafe fn slice(&self, i: usize) -> &'a mut [T] {
        let start = self.starts[i];
        let end = self.starts.get(i + 1).copied().unwrap_or(self.len);
        debug_assert!(start <= end && end <= self.len, "span {i} out of range");
        std::slice::from_raw_parts_mut(self.ptr.add(start), end - start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_sizing_is_problem_shaped() {
        // one quantum per chunk, clamped to whole units
        assert_eq!(chunks(0, 1000), (1, 0));
        assert_eq!(chunks(10, PAR_MIN_FLOPS), (1, 10));
        assert_eq!(chunks(10, PAR_MIN_FLOPS * 2), (1, 10));
        let (cu, n) = chunks(32, 10_240);
        assert_eq!(cu, PAR_MIN_FLOPS / 10_240);
        assert_eq!(n, 32usize.div_ceil(cu));
        // tiny problems collapse to one chunk (serial inline)
        assert_eq!(chunks(4, 100), (4, 1));
    }

    #[test]
    fn run_covers_every_chunk_exactly_once() {
        let mut out = vec![0u32; 103];
        let chunked = DisjointChunks::new(&mut out, 10);
        let n = chunked.n_chunks();
        run(n, |i| {
            // SAFETY: each chunk index claimed once by the pool.
            let c = unsafe { chunked.slice(i) };
            for (k, v) in c.iter_mut().enumerate() {
                *v += (i * 10 + k) as u32 + 1;
            }
        });
        for (k, v) in out.iter().enumerate() {
            assert_eq!(*v, k as u32 + 1, "element {k} written {v} times/NE");
        }
    }

    #[test]
    fn nested_runs_execute_inline() {
        let mut out = vec![0u32; 64];
        let chunked = DisjointChunks::new(&mut out, 8);
        run(8, |i| {
            // SAFETY: disjoint per chunk index.
            let c = unsafe { chunked.slice(i) };
            let inner = std::sync::atomic::AtomicU32::new(0);
            run(4, |_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
            let add = inner.load(Ordering::Relaxed);
            for v in c.iter_mut() {
                *v = add;
            }
        });
        assert!(out.iter().all(|&v| v == 4));
    }

    #[test]
    fn spans_cover_every_element_exactly_once() {
        // ragged spans (incl. an empty one) tile the buffer exactly
        let mut out = vec![0u32; 20];
        let starts = [0usize, 3, 3, 10];
        let spans = DisjointSpans::new(&mut out, &starts);
        assert_eq!(spans.n_spans(), 4);
        run(4, |i| {
            // SAFETY: each span index claimed once by the pool.
            let s = unsafe { spans.slice(i) };
            for v in s.iter_mut() {
                *v += i as u32 + 1;
            }
        });
        let want: Vec<u32> = (0..20)
            .map(|k| if k < 3 { 1 } else if k < 10 { 3 } else { 4 })
            .collect();
        assert_eq!(out, want, "each element owned by exactly one span");
    }

    // NOTE: spawn-vs-pool dispatch equality is covered by
    // rust/tests/pool_props.rs, which owns a whole test binary so its
    // QFT_DISPATCH / QFT_THREADS env sweeps cannot race other tests —
    // do not add env-mutating tests to this (parallel) lib binary.
}
