//! Shared compute layer: the persistent worker pool and chunking
//! helpers every parallel kernel (dense matmul, the circuit engine's
//! forward/backward, the host optimizer) dispatches through.  See
//! DESIGN.md §6.

pub mod pool;
