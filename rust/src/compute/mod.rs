//! Shared compute layer: the persistent worker pool and chunking
//! helpers every parallel kernel (dense matmul, the circuit engine's
//! forward/backward, the host optimizer, the serving decode loop)
//! dispatches through, plus the borrowing GEMM entry point they share.
//! See DESIGN.md §6 (pool) and §10 (serving hot path).

pub mod gemm;
pub mod pool;
