//! Borrowing slice-in/slice-out GEMM — the pooled row kernel behind
//! `Tensor::matmul`, promoted to a public entry point so hot paths can
//! multiply straight out of activation panels without wrapping them in
//! owned `Tensor`s (`to_vec` per call).
//!
//! The ROADMAP item this closes: the transformer block's MLP/backward
//! (and the adapter's frozen-base product) each paid a full-panel copy
//! per call just to satisfy `Tensor::matmul`'s owned signature.  Both
//! now call [`gemm_into`] directly; `Tensor::matmul` itself delegates
//! here, so the three paths share one kernel, one chunking policy, and
//! therefore one bit pattern — migrating a call site cannot change any
//! output (chunk boundaries come from `pool::chunks(rows, k·n)` either
//! way, and `mm_rows` accumulates ascending in `p` regardless of the
//! split).  The serve layer's decode hot loop (`serve::decode`) is
//! built directly on this entry: merged-weight serving is nothing but
//! `gemm_into` panels.

use crate::compute::pool;

/// `k`-block width of the matmul kernel: the active `B` panel is
/// `MM_KB × n` floats, resident in L1/L2 across the row sweep.
const MM_KB: usize = 64;

/// Multiply a row panel serially: `a` is `rows × k`, `b` is `k × n`,
/// and `rows · n` products are **accumulated into** `out` (pre-zero it
/// for a plain product).  Accumulation order over `p` is ascending
/// regardless of blocking, so results match the naive i-p-j loop
/// bit-for-bit and are independent of how the caller splits `a` into
/// row chunks.
pub fn mm_rows(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    // `panic@gemm:n` probe: mm_rows is the per-chunk kernel, so a spec
    // here panics inside a pool worker's chunk — exactly the failure
    // the pool's catch_unwind + `pool::catching` contract covers.
    if crate::util::fault::armed() {
        if let Some(crate::util::fault::Fault::Panic) = crate::util::fault::probe("gemm") {
            panic!("injected fault: panic@gemm");
        }
    }
    let rows = a.len() / k;
    let mut p0 = 0;
    while p0 < k {
        let pe = (p0 + MM_KB).min(k);
        for i in 0..rows {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for p in p0..pe {
                let av = arow[p];
                let brow = &b[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        p0 = pe;
    }
}

/// Pooled row-chunked GEMM over borrowed slices:
/// `out[rows × n] += a[rows × k] · b[k × n]`, with `rows` inferred from
/// `a.len() / k`.  Row chunks are sized by `pool::chunks(rows, k·n)` —
/// identical to `Tensor::matmul`, which delegates here — so the pooled
/// split is bitwise equal to the serial kernel at any `QFT_THREADS`.
///
/// Panics (debug) on inconsistent lengths; zero-sized operands are a
/// no-op.
pub fn gemm_into(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    if k == 0 || n == 0 || a.is_empty() {
        return;
    }
    debug_assert_eq!(a.len() % k, 0, "gemm_into: a len {} not a multiple of k {k}", a.len());
    let rows = a.len() / k;
    debug_assert_eq!(b.len(), k * n, "gemm_into: b len {} != k {k} * n {n}", b.len());
    debug_assert_eq!(out.len(), rows * n, "gemm_into: out len != rows {rows} * n {n}");
    let (chunk_rows, n_chunks) = pool::chunks(rows, k * n);
    if n_chunks <= 1 {
        mm_rows(a, b, out, k, n);
        return;
    }
    let out_chunks = pool::DisjointChunks::new(out, chunk_rows * n);
    pool::run(n_chunks, |i| {
        // SAFETY: each chunk index is claimed exactly once.
        let o = unsafe { out_chunks.slice(i) };
        let rows_i = o.len() / n;
        let a0 = i * chunk_rows * k;
        mm_rows(&a[a0..a0 + rows_i * k], b, o, k, n);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn gemm_into_matches_matmul_bitwise() {
        // below and above the parallel threshold: the borrowing entry
        // must agree with the owned Tensor path bit for bit (it is the
        // same kernel on the same chunks)
        let mut rng = Rng::new(11);
        for (m, k, n) in [(3usize, 5usize, 4usize), (160, 96, 128), (1, 96, 128)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let want = a.matmul(&b).unwrap();
            let mut got = vec![0.0f32; m * n];
            gemm_into(&a.data, &b.data, &mut got, k, n);
            assert_eq!(got, want.data, "({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_into_accumulates() {
        // out += A·B: pre-seeded output must keep its prior contents
        let a = [1.0f32, 2.0]; // 2 x 1
        let b = [3.0f32]; // 1 x 1
        let mut out = [10.0f32, 20.0];
        gemm_into(&a, &b, &mut out, 1, 1);
        assert_eq!(out, [13.0, 26.0]);
    }

    #[test]
    fn gemm_into_zero_sized_is_noop() {
        let mut out: Vec<f32> = vec![];
        gemm_into(&[], &[], &mut out, 0, 4);
        gemm_into(&[], &[1.0; 8], &mut out, 2, 4);
        assert!(out.is_empty());
    }
}
