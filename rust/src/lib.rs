//! # quanta-ft — QuanTA high-rank fine-tuning, reproduced as a rust+JAX+Pallas stack
//!
//! Reproduction of *QuanTA: Efficient High-Rank Fine-Tuning of LLMs with
//! Quantum-Informed Tensor Adaptation* (NeurIPS 2024).
//!
//! Layering (see `DESIGN.md`):
//! - **L1** (build-time python): fused QuanTA chain-application Pallas kernel.
//! - **L2** (build-time python): JAX transformer + 10 PEFT methods, lowered
//!   once to HLO text under `artifacts/`.
//! - **L3** (this crate): the fine-tuning coordinator — config, data
//!   pipeline, PJRT runtime, training loop, evaluation, analysis, and the
//!   benchmark harness regenerating every paper table/figure.
//!
//! The crate also contains a *pure-rust* QuanTA reference ([`quanta`])
//! used to property-test the paper's theorems (universality, rank
//! representation, composition openness) independently of the HLO path,
//! executed through a plan-cached batched circuit engine
//! ([`quanta::plan`], DESIGN.md §4) with an analytic backward pass
//! ([`quanta::grad`]) feeding an artifact-free host trainer
//! ([`coordinator::host_trainer`], DESIGN.md §5).  On top of the
//! engine sits a host-model layer ([`model`], DESIGN.md §9): an
//! [`model::AdapterSet`] of per-projection circuits behind one flat
//! optimizer layout and a QuanTA-adapted pre-LN transformer block
//! ([`model::TransformerBlock`]), both driven by the same trainer
//! through the [`model::TrainableModel`] trait.  The serving layer
//! ([`serve`], DESIGN.md §10) deploys trained blocks behind a KV-cache
//! incremental decode and a continuous-batching scheduler, running on
//! merged weights by default — the paper's zero-inference-overhead
//! deployment, pinned against the streaming adapter forward by
//! `rust/tests/serve_props.rs`.

// Crate-wide lint policy (needless_range_loop etc.) lives in the
// `[lints]` table of rust/Cargo.toml so it covers tests, benches, and
// examples as well as the library.

pub mod util;
pub mod compute;
pub mod tensor;
pub mod linalg;
pub mod quanta;
pub mod model;
pub mod serve;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod analysis;
pub mod bench;

pub use util::error::{Error, Result};
