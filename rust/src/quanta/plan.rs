//! Plan-cached batched circuit execution engine.
//!
//! The seed implementation re-derived per-gate offset tables by scanning
//! all `d` flat indices on every `apply`, and materialized the full
//! operator by `d` sequential matvecs.  This module precomputes, once
//! per circuit, everything that depends only on the circuit *structure*:
//!
//! * row-major strides of the reshaped hidden tensor,
//! * per-gate **rest-offset tables** — the flat base offset of every
//!   multi-index over the non-gate axes, enumerated in
//!   `O(d / (d_m d_n))` by mixed-radix odometer stepping instead of an
//!   `O(d)` scan-and-filter,
//! * per-gate **gather tables** — the `d_m·d_n` offsets of the gate-axis
//!   positions relative to a rest base (row `i_m·d_n + i_n`, matching
//!   the gate matrix layout of paper Eq. 4),
//! * a snapshot of each gate matrix.
//!
//! On top of the plan, [`CircuitPlan::apply_batch`] runs the whole gate
//! chain over a panel of vectors as blocked
//! `(d_m·d_n) × (rest·batch)` GEMMs: gather a block of columns into
//! scratch, multiply by the gate matrix with a vectorizable
//! i-p-c kernel, scatter back — double-buffered scratch, zero per-gate
//! allocation.  Panels are split across threads per vector (vectors are
//! independent through the chain), so results are bitwise identical for
//! any thread count or chunking.  [`CircuitPlan::full_matrix`] drives
//! `apply_batch` over identity panels (paper Eq. 7) instead of `d`
//! sequential matvecs.

use crate::quanta::circuit::Circuit;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Column-block width of the gather/GEMM/scatter pipeline.  With the
/// largest gate of a `d=1024` all-pairs circuit (`d_m·d_n = 128`) the
/// two scratch panels occupy `2 · 128 · 64 · 4 B = 64 KiB` — inside L2.
/// Shared with the backward pass (`quanta::grad`), whose GEMMs run over
/// the same `(d_m·d_n) × (rest·batch)` column blocks.
pub(crate) const BLOCK_COLS: usize = 64;

/// Column count of one `full_matrix` identity panel (bounds peak memory
/// at `2 · PANEL_COLS · d` floats while keeping enough columns per GEMM).
const PANEL_COLS: usize = 256;

/// Serial cutoff: chains cheaper than this many multiplies
/// (`batch · d · Σ d_m d_n`, the paper §6 apply cost) run single-threaded.
pub(crate) const PAR_MIN_FLOPS: usize = 1 << 20;

/// Precomputed execution state for one gate.
#[derive(Clone, Debug)]
pub struct GatePlan {
    /// Gate axes `(m, n)` this plan was built from — kept so
    /// [`CircuitPlan::refresh_gate_mats`] can reject a circuit whose
    /// structure drifted even when the matrix sizes still match.
    pub m: usize,
    pub n: usize,
    /// Gate matrix snapshot, `(dmn, dmn)` row-major.
    pub mat: Vec<f32>,
    /// `d_m · d_n` — rows/cols of the gate matrix.
    pub dmn: usize,
    /// Flat base offset of every rest multi-index (gate axes zeroed).
    pub rest: Vec<usize>,
    /// Offset of gate row `i_m·d_n + i_n` relative to a rest base:
    /// `i_m·s_m + i_n·s_n`.
    pub gather: Vec<usize>,
}

/// Precomputed execution plan for a circuit: build once with
/// [`CircuitPlan::new`] (or [`Circuit::plan`]), reuse across any number
/// of `apply` / `apply_batch` / `full_matrix` calls.  The plan snapshots
/// the gate matrices — rebuild it after mutating the circuit.
#[derive(Clone, Debug)]
pub struct CircuitPlan {
    pub d: usize,
    pub dims: Vec<usize>,
    /// Row-major strides of the reshaped hidden tensor.
    pub strides: Vec<usize>,
    pub gates: Vec<GatePlan>,
    pub(crate) max_dmn: usize,
    /// `Σ_α d_m d_n` — per-element chain cost (paper §6).
    sum_dmn: usize,
}

/// Reusable gather/product buffers for one worker; sized for the widest
/// gate so no allocation happens inside the gate loop.  Internal to the
/// engine: workers (including the tape forward in `quanta::grad`)
/// create one via [`CircuitPlan::scratch`].
pub(crate) struct Scratch {
    gathered: Vec<f32>,
    product: Vec<f32>,
    bases: Vec<usize>,
}

/// Row-major strides for `dims`.
fn strides_of(dims: &[usize]) -> Vec<usize> {
    let n = dims.len();
    let mut s = vec![1usize; n];
    for i in (0..n.saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Enumerate the flat offsets of all multi-indices over the axes *not*
/// in `{m, n}` by mixed-radix odometer stepping — `O(d/(d_m d_n))`
/// total, never touching the other `d - d/(d_m d_n)` flat indices.
fn rest_offsets(dims: &[usize], strides: &[usize], m: usize, n: usize) -> Vec<usize> {
    let axes: Vec<usize> = (0..dims.len()).filter(|&a| a != m && a != n).collect();
    let count: usize = axes.iter().map(|&a| dims[a]).product();
    let mut out = Vec::with_capacity(count);
    let mut idx = vec![0usize; axes.len()];
    let mut flat = 0usize;
    loop {
        out.push(flat);
        // increment the odometer from the last (fastest) axis
        let mut k = axes.len();
        loop {
            if k == 0 {
                debug_assert_eq!(out.len(), count);
                return out;
            }
            k -= 1;
            let a = axes[k];
            idx[k] += 1;
            flat += strides[a];
            if idx[k] < dims[a] {
                break;
            }
            flat -= strides[a] * dims[a];
            idx[k] = 0;
        }
    }
}

impl CircuitPlan {
    pub fn new(circuit: &Circuit) -> Result<CircuitPlan> {
        let dims = circuit.dims().to_vec();
        let d: usize = dims.iter().product();
        let strides = strides_of(&dims);
        let mut gates = Vec::with_capacity(circuit.gates().len());
        for g in circuit.gates() {
            if g.m >= dims.len() || g.n >= dims.len() || g.m == g.n {
                return Err(Error::Shape(format!(
                    "plan: bad gate axes ({}, {}) for dims {dims:?}",
                    g.m, g.n
                )));
            }
            let (dm, dn) = (dims[g.m], dims[g.n]);
            let dmn = dm * dn;
            if g.mat.shape != [dmn, dmn] {
                return Err(Error::Shape(format!(
                    "plan: gate ({}, {}) matrix shape {:?}, want [{dmn}, {dmn}]",
                    g.m, g.n, g.mat.shape
                )));
            }
            let (sm, sn) = (strides[g.m], strides[g.n]);
            let mut gather = Vec::with_capacity(dmn);
            for i_m in 0..dm {
                for i_n in 0..dn {
                    gather.push(i_m * sm + i_n * sn);
                }
            }
            gates.push(GatePlan {
                m: g.m,
                n: g.n,
                mat: g.mat.data.clone(),
                dmn,
                rest: rest_offsets(&dims, &strides, g.m, g.n),
                gather,
            });
        }
        let max_dmn = gates.iter().map(|g| g.dmn).max().unwrap_or(0);
        let sum_dmn = gates.iter().map(|g| g.dmn).sum();
        Ok(CircuitPlan { d, dims, strides, gates, max_dmn, sum_dmn })
    }

    /// Fresh scratch sized for this plan's widest gate.
    pub(crate) fn scratch(&self) -> Scratch {
        Scratch {
            gathered: vec![0.0; self.max_dmn * BLOCK_COLS],
            product: vec![0.0; self.max_dmn * BLOCK_COLS],
            bases: vec![0; BLOCK_COLS],
        }
    }

    /// Multiply count of one chain application (paper §6).
    pub fn apply_flops(&self) -> usize {
        self.d * self.sum_dmn
    }

    /// Re-snapshot the gate matrices from `circuit` without rebuilding
    /// the stride/rest-offset/gather tables (which depend only on
    /// dims + gate structure).  Dims, gate count, per-gate axes, and
    /// matrix sizes are all checked, so a structurally different
    /// circuit is rejected; per-step optimizers use this to update
    /// parameters at memcpy cost instead of full plan setup.
    pub fn refresh_gate_mats(&mut self, circuit: &Circuit) -> Result<()> {
        if circuit.dims() != self.dims.as_slice() || circuit.gates().len() != self.gates.len() {
            return Err(Error::Shape(format!(
                "refresh_gate_mats: circuit ({:?}, {} gates) does not match plan ({:?}, {})",
                circuit.dims(),
                circuit.gates().len(),
                self.dims,
                self.gates.len()
            )));
        }
        for (gp, g) in self.gates.iter_mut().zip(circuit.gates()) {
            if g.m != gp.m || g.n != gp.n || g.mat.data.len() != gp.mat.len() {
                return Err(Error::Shape(format!(
                    "refresh_gate_mats: gate ({}, {}) with {} entries, plan has ({}, {}) with {}",
                    g.m,
                    g.n,
                    g.mat.data.len(),
                    gp.m,
                    gp.n,
                    gp.mat.len()
                )));
            }
            gp.mat.copy_from_slice(&g.mat.data);
        }
        Ok(())
    }

    /// Apply the chain to a single vector.
    pub fn apply(&self, x: &[f32]) -> Result<Vec<f32>> {
        self.apply_batch(x, 1)
    }

    /// Apply the chain to `batch` vectors stored row-major in `xs`
    /// (`xs[b*d .. (b+1)*d]` is vector `b`); returns the same layout.
    pub fn apply_batch(&self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        if xs.len() != batch * self.d {
            return Err(Error::Shape(format!(
                "apply_batch: xs len {} != batch {batch} * d {}",
                xs.len(),
                self.d
            )));
        }
        let mut h = xs.to_vec();
        self.apply_batch_in_place(&mut h, batch);
        Ok(h)
    }

    /// In-place variant of [`CircuitPlan::apply_batch`] (the `full_matrix`
    /// panel driver uses this to avoid a copy per panel).
    pub fn apply_batch_in_place(&self, h: &mut [f32], batch: usize) {
        debug_assert_eq!(h.len(), batch * self.d);
        if self.d == 0 || batch == 0 || self.gates.is_empty() {
            return;
        }
        let workers = if batch * self.apply_flops() < PAR_MIN_FLOPS {
            1
        } else {
            crate::tensor::num_threads(batch)
        };
        if workers <= 1 {
            let mut scratch = self.scratch();
            self.apply_chain_chunk(h, batch, &mut scratch);
            return;
        }
        // Vectors are independent through the whole chain, so the panel
        // splits into per-thread chunks of whole vectors; each worker
        // owns its scratch.  Per-vector arithmetic does not depend on
        // the chunking, so results are identical for any worker count.
        let chunk_vecs = batch.div_ceil(workers);
        std::thread::scope(|s| {
            for chunk in h.chunks_mut(chunk_vecs * self.d) {
                s.spawn(move || {
                    let cb = chunk.len() / self.d;
                    let mut scratch = self.scratch();
                    self.apply_chain_chunk(chunk, cb, &mut scratch);
                });
            }
        });
    }

    /// Run the whole gate chain over `cb` contiguous vectors.
    fn apply_chain_chunk(&self, h: &mut [f32], cb: usize, scratch: &mut Scratch) {
        for g in &self.gates {
            self.apply_gate_chunk(g, h, cb, scratch);
        }
    }

    /// One gate over `cb` vectors: blocked gather → GEMM → scatter.
    /// Columns of the implicit `(dmn) × (rest·cb)` matrix are `(vector,
    /// rest-offset)` pairs; their gate-axis footprints are disjoint, so
    /// scattering back in place is safe.
    pub(crate) fn apply_gate_chunk(
        &self,
        g: &GatePlan,
        h: &mut [f32],
        cb: usize,
        scratch: &mut Scratch,
    ) {
        let d = self.d;
        let dmn = g.dmn;
        let rest_len = g.rest.len();
        let ncols = cb * rest_len;
        let bw = BLOCK_COLS;
        let mut c0 = 0;
        while c0 < ncols {
            let w = bw.min(ncols - c0);
            // base offset of each column in this block
            for ci in 0..w {
                let col = c0 + ci;
                let b = col / rest_len;
                let r = col - b * rest_len;
                scratch.bases[ci] = b * d + g.rest[r];
            }
            let bases = &scratch.bases[..w];
            // gather: contiguous writes per row, strided reads from h
            for (k, &off) in g.gather.iter().enumerate() {
                let row = &mut scratch.gathered[k * bw..k * bw + w];
                for (slot, &base) in row.iter_mut().zip(bases) {
                    *slot = h[base + off];
                }
            }
            // GEMM: product[i, :] = Σ_p mat[i, p] · gathered[p, :]
            for i in 0..dmn {
                let orow = &mut scratch.product[i * bw..i * bw + w];
                orow.fill(0.0);
                let mrow = &g.mat[i * dmn..(i + 1) * dmn];
                for (p, &a) in mrow.iter().enumerate() {
                    let grow = &scratch.gathered[p * bw..p * bw + w];
                    for (o, &x) in orow.iter_mut().zip(grow) {
                        *o += a * x;
                    }
                }
            }
            // scatter
            for (k, &off) in g.gather.iter().enumerate() {
                let row = &scratch.product[k * bw..k * bw + w];
                for (&val, &base) in row.iter().zip(bases) {
                    h[base + off] = val;
                }
            }
            c0 += w;
        }
    }

    /// Materialize the full `(d, d)` operator (paper Eq. 7) by running
    /// `apply_batch` over identity panels — one GEMM chain per
    /// `PANEL_COLS` basis vectors instead of `d` sequential matvecs.
    pub fn full_matrix(&self) -> Result<Tensor> {
        let d = self.d;
        let mut out = Tensor::zeros(&[d, d]);
        let pw = PANEL_COLS.min(d.max(1));
        let mut panel = vec![0.0f32; pw * d];
        let mut j0 = 0;
        while j0 < d {
            let w = pw.min(d - j0);
            let p = &mut panel[..w * d];
            p.fill(0.0);
            for j in 0..w {
                p[j * d + j0 + j] = 1.0;
            }
            self.apply_batch_in_place(p, w);
            // panel row j is the chain applied to e_{j0+j} = column
            // j0+j of the full operator
            for j in 0..w {
                let row = &p[j * d..(j + 1) * d];
                for (i, &v) in row.iter().enumerate() {
                    out.data[i * d + j0 + j] = v;
                }
            }
            j0 += w;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quanta::circuit::{all_pairs_structure, Circuit};
    use crate::util::rng::Rng;

    /// Seed-style reference: per-gate offset tables by O(d) flat-index
    /// scanning, one vector at a time (the pre-engine implementation,
    /// kept as the correctness oracle).
    fn apply_reference(c: &Circuit, x: &[f32]) -> Vec<f32> {
        let dims = c.dims();
        let d: usize = dims.iter().product();
        let strides = strides_of(dims);
        let mut h = x.to_vec();
        for g in c.gates() {
            let (dm, dn) = (dims[g.m], dims[g.n]);
            let (sm, sn) = (strides[g.m], strides[g.n]);
            let mut out = vec![0.0f32; d];
            let mut rest = vec![];
            for flat in 0..d {
                if (flat / sm) % dm == 0 && (flat / sn) % dn == 0 {
                    rest.push(flat);
                }
            }
            for &base in &rest {
                for i_m in 0..dm {
                    for i_n in 0..dn {
                        let row = i_m * dn + i_n;
                        let mut acc = 0.0f32;
                        for j_m in 0..dm {
                            for j_n in 0..dn {
                                acc += g.mat.data[row * (dm * dn) + (j_m * dn + j_n)]
                                    * h[base + j_m * sm + j_n * sn];
                            }
                        }
                        out[base + i_m * sm + i_n * sn] = acc;
                    }
                }
            }
            h = out;
        }
        h
    }

    #[test]
    fn rest_offsets_match_scan() {
        for dims in [vec![2usize, 3, 2], vec![4, 4], vec![2, 2, 3, 2]] {
            let strides = strides_of(&dims);
            let d: usize = dims.iter().product();
            for m in 0..dims.len() {
                for n in 0..dims.len() {
                    if m == n {
                        continue;
                    }
                    let (dm, dn) = (dims[m], dims[n]);
                    let (sm, sn) = (strides[m], strides[n]);
                    let mut scan: Vec<usize> = (0..d)
                        .filter(|flat| (flat / sm) % dm == 0 && (flat / sn) % dn == 0)
                        .collect();
                    let mut stepped = rest_offsets(&dims, &strides, m, n);
                    scan.sort_unstable();
                    stepped.sort_unstable();
                    assert_eq!(stepped, scan, "dims {dims:?} gate ({m},{n})");
                }
            }
        }
    }

    #[test]
    fn rest_offsets_two_axis_gate_is_single_block() {
        let dims = [3usize, 4];
        let strides = strides_of(&dims);
        assert_eq!(rest_offsets(&dims, &strides, 0, 1), vec![0]);
    }

    #[test]
    fn plan_apply_matches_reference() {
        let mut rng = Rng::new(40);
        for dims in [vec![2usize, 3, 2], vec![4, 4], vec![2, 2, 2, 2]] {
            let structure = all_pairs_structure(dims.len());
            let c = Circuit::random(&dims, &structure, 0.4, &mut rng).unwrap();
            let d = c.total_dim();
            let mut x = vec![0.0f32; d];
            rng.fill_normal(&mut x, 1.0);
            let plan = CircuitPlan::new(&c).unwrap();
            let y = plan.apply(&x).unwrap();
            let y_ref = apply_reference(&c, &x);
            for (a, b) in y.iter().zip(&y_ref) {
                assert!((a - b).abs() < 1e-4, "dims {dims:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn apply_batch_matches_per_vector() {
        let mut rng = Rng::new(41);
        let dims = [2usize, 3, 4];
        let c = Circuit::random(&dims, &all_pairs_structure(3), 0.3, &mut rng).unwrap();
        let d = c.total_dim();
        let batch = 7;
        let mut xs = vec![0.0f32; batch * d];
        rng.fill_normal(&mut xs, 1.0);
        let plan = CircuitPlan::new(&c).unwrap();
        let ys = plan.apply_batch(&xs, batch).unwrap();
        for b in 0..batch {
            let y1 = plan.apply(&xs[b * d..(b + 1) * d]).unwrap();
            assert_eq!(y1, ys[b * d..(b + 1) * d].to_vec(), "vector {b}");
        }
    }

    #[test]
    fn full_matrix_matches_basis_reference() {
        let mut rng = Rng::new(42);
        let dims = [2usize, 2, 3];
        let c = Circuit::random(&dims, &all_pairs_structure(3), 0.5, &mut rng).unwrap();
        let d = c.total_dim();
        let plan = CircuitPlan::new(&c).unwrap();
        let full = plan.full_matrix().unwrap();
        let mut e = vec![0.0f32; d];
        for j in 0..d {
            e[j] = 1.0;
            let col = apply_reference(&c, &e);
            e[j] = 0.0;
            for i in 0..d {
                assert!(
                    (full.data[i * d + j] - col[i]).abs() < 1e-4,
                    "({i},{j}): {} vs {}",
                    full.data[i * d + j],
                    col[i]
                );
            }
        }
    }

    #[test]
    fn refresh_gate_mats_matches_fresh_plan() {
        let mut rng = Rng::new(44);
        let dims = [2usize, 3, 2];
        let mut c = Circuit::random(&dims, &all_pairs_structure(3), 0.4, &mut rng).unwrap();
        let mut plan = CircuitPlan::new(&c).unwrap();
        // mutate the gates, refresh in place, compare against a rebuild
        for g in c.gates_mut() {
            let sz = g.mat.shape[0];
            g.mat = Tensor::randn(&[sz, sz], 0.5, &mut rng);
        }
        plan.refresh_gate_mats(&c).unwrap();
        let fresh = CircuitPlan::new(&c).unwrap();
        let mut x = vec![0.0f32; plan.d * 3];
        rng.fill_normal(&mut x, 1.0);
        assert_eq!(plan.apply_batch(&x, 3).unwrap(), fresh.apply_batch(&x, 3).unwrap());
        // structure mismatch is rejected
        let other = Circuit::random(&[2usize, 2], &[(0, 1)], 0.1, &mut rng).unwrap();
        assert!(plan.refresh_gate_mats(&other).is_err());
        // ...including same-size gates on different axes
        let dims3 = [2usize, 2, 2];
        let c01 = Circuit::random(&dims3, &[(0, 1)], 0.2, &mut rng).unwrap();
        let c12 = Circuit::random(&dims3, &[(1, 2)], 0.2, &mut rng).unwrap();
        let mut p01 = CircuitPlan::new(&c01).unwrap();
        assert!(p01.refresh_gate_mats(&c12).is_err(), "axis drift must be rejected");
        assert!(p01.refresh_gate_mats(&c01).is_ok());
    }

    #[test]
    fn plan_reuse_is_deterministic() {
        let mut rng = Rng::new(43);
        let dims = [3usize, 2, 2];
        let c = Circuit::random(&dims, &all_pairs_structure(3), 0.4, &mut rng).unwrap();
        let d = c.total_dim();
        let mut x = vec![0.0f32; 4 * d];
        rng.fill_normal(&mut x, 1.0);
        let plan = CircuitPlan::new(&c).unwrap();
        let y1 = plan.apply_batch(&x, 4).unwrap();
        let y2 = plan.apply_batch(&x, 4).unwrap();
        assert_eq!(y1, y2, "same plan, same input, different output");
        let plan2 = CircuitPlan::new(&c).unwrap();
        assert_eq!(y1, plan2.apply_batch(&x, 4).unwrap(), "fresh plan differs");
        let f1 = plan.full_matrix().unwrap();
        let f2 = plan2.full_matrix().unwrap();
        assert_eq!(f1.data, f2.data);
    }
}
