//! Plan-cached batched circuit execution engine.
//!
//! The seed implementation re-derived per-gate offset tables by scanning
//! all `d` flat indices on every `apply`, and materialized the full
//! operator by `d` sequential matvecs.  This module precomputes, once
//! per circuit, everything that depends only on the circuit *structure*:
//!
//! * row-major strides of the reshaped hidden tensor,
//! * per-gate **rest-offset tables** — the flat base offset of every
//!   multi-index over the non-gate axes, enumerated in
//!   `O(d / (d_m d_n))` by mixed-radix odometer stepping instead of an
//!   `O(d)` scan-and-filter,
//! * per-gate **gather tables** — the offsets of the gate-axis
//!   positions relative to a rest base (row `i_m·d_n + i_n` for a plain
//!   two-axis gate, matching the gate matrix layout of paper Eq. 4),
//! * a snapshot of each gate matrix.
//!
//! **Gate fusion** (this PR): adjacent gates whose axis pairs overlap
//! are merged at plan-build time into one *fused* gate over the union
//! axes — the two matrices are embedded into the union space and
//! composed, so one gather → GEMM → scatter pass replaces two full
//! panel sweeps.  A fusion is accepted only when the union dimension
//! stays within a `max_fused_dmn` cap **and** does not increase the
//! per-element GEMM cost (`d_union ≤ d_a + d_b`), so e.g. a repeated
//! axis pair always fuses (half the GEMM work, half the passes) while
//! the all-pairs gates of a [8,8,16] circuit never do.  Each
//! [`GatePlan`] keeps its [`GateMember`] bookkeeping — embedding maps
//! and prefix/suffix products — so [`CircuitPlan::refresh_gate_mats`]
//! can recompose fused matrices from updated parameters and the
//! backward (`quanta::grad`) can *unfuse* a fused-gate gradient back to
//! per-original-gate `∂A` layout.
//!
//! On top of the plan, [`CircuitPlan::apply_batch`] runs the whole gate
//! chain over a panel of vectors as blocked
//! `(d_m·d_n) × (rest·batch)` GEMMs: gather a block of columns into
//! scratch, multiply by the gate matrix with a vectorizable
//! i-p-c kernel, scatter back — double-buffered scratch, zero per-gate
//! allocation.  Panels split into per-*chunk* runs of whole vectors
//! sized by `compute::pool::chunks` (problem-shaped, never
//! thread-count-shaped) and dispatched on the persistent worker pool,
//! so results are bitwise identical for any `QFT_THREADS`.
//! [`CircuitPlan::full_matrix`] drives `apply_batch` over identity
//! panels (paper Eq. 7) instead of `d` sequential matvecs, and
//! [`CircuitPlan::apply_batch_residual_into`] fuses the adapter's
//! `α·(circuit(x) − x)` residual into the final gate's scatter so the
//! adapter forward makes one pass instead of apply-then-axpy.

use crate::compute::pool;
use crate::quanta::circuit::Circuit;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Column-block width of the gather/GEMM/scatter pipeline.  With the
/// widest fused gate allowed by the default cap (`d_m·d_n = 64`) the
/// two scratch panels occupy `2 · 64 · 64 · 4 B = 32 KiB` — inside L2
/// (an unfused `d=1024` all-pairs gate at 128 doubles that, still
/// fine).  Shared with the backward pass (`quanta::grad`), whose GEMMs
/// run over the same `(d_m·d_n) × (rest·batch)` column blocks.
pub(crate) const BLOCK_COLS: usize = 64;

/// Column count of one `full_matrix` identity panel (bounds peak memory
/// at `2 · PANEL_COLS · d` floats while keeping enough columns per GEMM).
const PANEL_COLS: usize = 256;

/// Default cap on the fused-gate dimension `Π d_axes`: fusions above
/// this are rejected even when the GEMM-cost rule would accept them.
/// Override per plan with [`CircuitPlan::with_max_fused`] or globally
/// with `QFT_MAX_FUSED_DMN` (0 disables fusion).
pub const MAX_FUSED_DMN: usize = 64;

fn default_max_fused() -> usize {
    std::env::var("QFT_MAX_FUSED_DMN")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(MAX_FUSED_DMN)
}

/// One original circuit gate inside a (possibly fused) [`GatePlan`].
///
/// For a single-member gate the maps and factor products are empty —
/// the plan matrix *is* the gate matrix.  For a fused gate, `prow` /
/// `prest` describe how the member's matrix embeds into the fused
/// space, and `rmat` / `lmat` are the products of the *other* members'
/// embeddings applied before / after this one — exactly what the
/// backward needs to unfuse `∂F` into this member's `∂A`.
#[derive(Clone, Debug)]
pub struct GateMember {
    /// Index of the source gate in the original circuit.
    pub gate_idx: usize,
    /// Original gate axes (kept so
    /// [`CircuitPlan::refresh_gate_mats`] can reject structure drift).
    pub m: usize,
    pub n: usize,
    /// `d_m · d_n` of the original gate.
    pub dmn: usize,
    /// Fused row → member row `i_m·d_n + i_n`.
    prow: Vec<u32>,
    /// Fused row → id of the non-member union components; two fused
    /// indices are coupled by the member's matrix iff their ids match
    /// (identity-embedded elsewhere).
    prest: Vec<u32>,
    /// Prefix product `E_{i−1}···E_1` of earlier members' embeddings.
    rmat: Vec<f32>,
    /// Suffix product `E_k···E_{i+1}` of later members' embeddings.
    lmat: Vec<f32>,
}

/// Precomputed execution state for one (possibly fused) gate.
#[derive(Clone, Debug)]
pub struct GatePlan {
    /// Axes this gate acts on: the original `[m, n]` order for a
    /// single-member gate (bit-compatible with the PR 2 layout),
    /// ascending union order for a fused gate.
    pub axes: Vec<usize>,
    /// Gate matrix, `(dmn, dmn)` row-major — the member matrix itself,
    /// or the composed embedding product for a fused gate.
    pub mat: Vec<f32>,
    /// `Π_axes d_axis` — rows/cols of the gate matrix.
    pub dmn: usize,
    /// Flat base offset of every rest multi-index (gate axes zeroed).
    pub rest: Vec<usize>,
    /// Offset of gate row (mixed-radix index over `axes`) relative to a
    /// rest base.
    pub gather: Vec<usize>,
    /// The original gates composed into this plan gate (length 1 when
    /// nothing fused).
    pub members: Vec<GateMember>,
}

/// Precomputed execution plan for a circuit: build once with
/// [`CircuitPlan::new`] (or [`Circuit::plan`]), reuse across any number
/// of `apply` / `apply_batch` / `full_matrix` calls.  The plan snapshots
/// the gate matrices — rebuild it (or [`CircuitPlan::refresh_gate_mats`])
/// after mutating the circuit.
#[derive(Clone, Debug)]
pub struct CircuitPlan {
    pub d: usize,
    pub dims: Vec<usize>,
    /// Row-major strides of the reshaped hidden tensor.
    pub strides: Vec<usize>,
    /// Execution gates after fusion; `Σ members.len()` equals the
    /// original gate count.
    pub gates: Vec<GatePlan>,
    pub(crate) max_dmn: usize,
    /// `Σ_α d_m d_n` over the *fused* chain — per-element chain cost
    /// (paper §6, reduced by fusion).
    sum_dmn: usize,
}

/// Reusable gather/product buffers for one worker; sized for the widest
/// gate so no allocation happens inside the gate loop.  Internal to the
/// engine: workers (including the tape forward in `quanta::grad`)
/// borrow one via [`CircuitPlan::with_scratch`], which serves a
/// **thread-local grow-only cache** — executors stop paying an
/// alloc+memset per pool chunk (a few percent of the hot path with
/// 1-vector chunks at large `d`).  Scratch carries no cross-chunk
/// state: every buffer region is fully written before it is read
/// within a block, so reuse cannot change any output bit
/// (`rust/tests/pool_props.rs` asserts this by interleaving circuits
/// of different widths on the same workers).
pub(crate) struct Scratch {
    gathered: Vec<f32>,
    product: Vec<f32>,
    bases: Vec<usize>,
}

impl Scratch {
    fn empty() -> Scratch {
        Scratch { gathered: Vec::new(), product: Vec::new(), bases: vec![0; BLOCK_COLS] }
    }

    /// Grow-only: widen the panels to `max_dmn` gate rows if the cached
    /// buffers are narrower (never shrinks, so alternating plans don't
    /// thrash).
    fn ensure(&mut self, max_dmn: usize) {
        let need = max_dmn * BLOCK_COLS;
        if self.gathered.len() < need {
            self.gathered.resize(need, 0.0);
            self.product.resize(need, 0.0);
        }
    }
}

thread_local! {
    /// Per-executor forward scratch.  `Cell<Option<…>>` + take/put-back
    /// instead of `RefCell` so a (hypothetical) nested borrow allocates
    /// fresh rather than panicking.
    static FWD_SCRATCH: std::cell::Cell<Option<Scratch>> = const { std::cell::Cell::new(None) };
}

/// Row-major strides for `dims`.
fn strides_of(dims: &[usize]) -> Vec<usize> {
    let n = dims.len();
    let mut s = vec![1usize; n];
    for i in (0..n.saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Enumerate the flat offsets of all multi-indices over the axes *not*
/// in `excluded` by mixed-radix odometer stepping — `O(d/Π d_excl)`
/// total, never touching the other flat indices.
fn rest_offsets(dims: &[usize], strides: &[usize], excluded: &[usize]) -> Vec<usize> {
    let axes: Vec<usize> = (0..dims.len()).filter(|a| !excluded.contains(a)).collect();
    let count: usize = axes.iter().map(|&a| dims[a]).product();
    let mut out = Vec::with_capacity(count);
    let mut idx = vec![0usize; axes.len()];
    let mut flat = 0usize;
    loop {
        out.push(flat);
        // increment the odometer from the last (fastest) axis
        let mut k = axes.len();
        loop {
            if k == 0 {
                debug_assert_eq!(out.len(), count);
                return out;
            }
            k -= 1;
            let a = axes[k];
            idx[k] += 1;
            flat += strides[a];
            if idx[k] < dims[a] {
                break;
            }
            flat -= strides[a] * dims[a];
            idx[k] = 0;
        }
    }
}

/// Gather table over `axes` (first axis major): entry `r` is the flat
/// offset `Σ_j i_j · stride(axes_j)` of gate row `r`.
fn gather_table(dims: &[usize], strides: &[usize], axes: &[usize]) -> Vec<usize> {
    let sizes: Vec<usize> = axes.iter().map(|&a| dims[a]).collect();
    let count: usize = sizes.iter().product();
    let mut out = Vec::with_capacity(count);
    let mut idx = vec![0usize; axes.len()];
    for _ in 0..count {
        out.push(idx.iter().zip(axes).map(|(&i, &a)| i * strides[a]).sum());
        for j in (0..axes.len()).rev() {
            idx[j] += 1;
            if idx[j] < sizes[j] {
                break;
            }
            idx[j] = 0;
        }
    }
    out
}

/// Square row-major `A @ B` with ascending-`p` accumulation (bitwise
/// deterministic; no zero-skip so NaN propagates).
pub(crate) fn mm_small(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * n];
    for i in 0..n {
        for p in 0..n {
            let av = a[i * n + p];
            let brow = &b[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Square row-major `A @ Bᵀ`.
pub(crate) fn mm_small_abt(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            let arow = &a[i * n..(i + 1) * n];
            let brow = &b[j * n..(j + 1) * n];
            out[i * n + j] = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
        }
    }
    out
}

/// Square row-major `Aᵀ @ B`.
pub(crate) fn mm_small_atb(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * n];
    for p in 0..n {
        let arow = &a[p * n..(p + 1) * n];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

fn eye_small(n: usize) -> Vec<f32> {
    let mut e = vec![0.0f32; n * n];
    for i in 0..n {
        e[i * n + i] = 1.0;
    }
    e
}

/// Embed a member matrix into the fused space:
/// `E[r,c] = A[prow_r, prow_c]` when the non-member components match
/// (`prest_r == prest_c`), 0 otherwise.
fn embed_member(mat: &[f32], dmn: usize, prow: &[u32], prest: &[u32]) -> Vec<f32> {
    let df = prow.len();
    let mut e = vec![0.0f32; df * df];
    for r in 0..df {
        for c in 0..df {
            if prest[r] == prest[c] {
                e[r * df + c] = mat[prow[r] as usize * dmn + prow[c] as usize];
            }
        }
    }
    e
}

/// Recompose a fused gate from its members' *current* matrices in
/// `gates`: rebuild embeddings, the composed matrix
/// `F = E_k ··· E_1`, and each member's prefix/suffix products.
/// Single-member gates copy the matrix verbatim (bitwise PR 2 layout).
fn recompose_gate(gp: &mut GatePlan, gates: &[crate::quanta::circuit::Gate]) {
    if gp.members.len() == 1 {
        gp.mat.clear();
        gp.mat.extend_from_slice(&gates[gp.members[0].gate_idx].mat.data);
        return;
    }
    let df = gp.dmn;
    let embeds: Vec<Vec<f32>> = gp
        .members
        .iter()
        .map(|mem| embed_member(&gates[mem.gate_idx].mat.data, mem.dmn, &mem.prow, &mem.prest))
        .collect();
    let k = embeds.len();
    // prefix[i] = E_{i-1}···E_1 (identity for the first member)
    let mut prefix: Vec<Vec<f32>> = Vec::with_capacity(k);
    prefix.push(eye_small(df));
    for i in 1..k {
        let p = mm_small(&embeds[i - 1], &prefix[i - 1], df);
        prefix.push(p);
    }
    gp.mat = mm_small(&embeds[k - 1], &prefix[k - 1], df);
    // suffix[i] = E_k···E_{i+1} (identity for the last member)
    let mut suffix: Vec<Vec<f32>> = vec![Vec::new(); k];
    suffix[k - 1] = eye_small(df);
    for i in (0..k - 1).rev() {
        suffix[i] = mm_small(&suffix[i + 1], &embeds[i + 1], df);
    }
    for ((mem, r), l) in gp.members.iter_mut().zip(prefix).zip(suffix) {
        mem.rmat = r;
        mem.lmat = l;
    }
}

impl GatePlan {
    /// Distribute a fused-gate gradient `∂F` onto the original gates:
    /// for member `i`, `∂E_i = L_iᵀ · ∂F · R_iᵀ`, then the
    /// identity-embedded positions sum back into the member's
    /// `(dmn, dmn)` gradient (`gate_grads[gate_idx]`).  Single-member
    /// gates take `∂F` verbatim.  Deterministic: fixed iteration order,
    /// no data-dependent reduction.
    pub(crate) fn unfuse_grads(&self, dmat: Vec<f32>, gate_grads: &mut [Vec<f32>]) {
        if self.members.len() == 1 {
            gate_grads[self.members[0].gate_idx] = dmat;
            return;
        }
        let df = self.dmn;
        for mem in &self.members {
            let tmp = mm_small_abt(&dmat, &mem.rmat, df); // ∂F · R_iᵀ
            let de = mm_small_atb(&mem.lmat, &tmp, df); // L_iᵀ · (∂F R_iᵀ)
            let dst = &mut gate_grads[mem.gate_idx];
            for r in 0..df {
                for c in 0..df {
                    if mem.prest[r] == mem.prest[c] {
                        dst[mem.prow[r] as usize * mem.dmn + mem.prow[c] as usize] +=
                            de[r * df + c];
                    }
                }
            }
        }
    }
}

impl CircuitPlan {
    /// Plan with the default fusion cap ([`MAX_FUSED_DMN`], or the
    /// `QFT_MAX_FUSED_DMN` env override).
    pub fn new(circuit: &Circuit) -> Result<CircuitPlan> {
        CircuitPlan::with_max_fused(circuit, default_max_fused())
    }

    /// Plan with an explicit fusion cap (`0` disables fusion entirely —
    /// the PR 2 one-plan-gate-per-circuit-gate layout).
    pub fn with_max_fused(circuit: &Circuit, max_fused_dmn: usize) -> Result<CircuitPlan> {
        let dims = circuit.dims().to_vec();
        let d: usize = dims.iter().product();
        let strides = strides_of(&dims);
        // validate, then group adjacent gates greedily: merge when the
        // axis sets overlap, the union dimension is within the cap, and
        // the per-element GEMM cost does not grow (d_u ≤ d_a + d_b).
        let mut groups: Vec<(Vec<usize>, usize, Vec<usize>)> = Vec::new();
        for (gi, g) in circuit.gates().iter().enumerate() {
            if g.m >= dims.len() || g.n >= dims.len() || g.m == g.n {
                return Err(Error::Shape(format!(
                    "plan: bad gate axes ({}, {}) for dims {dims:?}",
                    g.m, g.n
                )));
            }
            let gdmn = dims[g.m] * dims[g.n];
            if g.mat.shape != [gdmn, gdmn] {
                return Err(Error::Shape(format!(
                    "plan: gate ({}, {}) matrix shape {:?}, want [{gdmn}, {gdmn}]",
                    g.m, g.n, g.mat.shape
                )));
            }
            if let Some((axes, dmn, members)) = groups.last_mut() {
                if axes.contains(&g.m) || axes.contains(&g.n) {
                    let mut union = axes.clone();
                    for a in [g.m, g.n] {
                        if !union.contains(&a) {
                            union.push(a);
                        }
                    }
                    union.sort_unstable();
                    let union_dmn: usize = union.iter().map(|&a| dims[a]).product();
                    if union_dmn <= max_fused_dmn && union_dmn <= *dmn + gdmn {
                        *axes = union;
                        *dmn = union_dmn;
                        members.push(gi);
                        continue;
                    }
                }
            }
            let mut set = vec![g.m, g.n];
            set.sort_unstable();
            groups.push((set, gdmn, vec![gi]));
        }

        let circuit_gates = circuit.gates();
        let mut gates = Vec::with_capacity(groups.len());
        for (union, union_dmn, member_ids) in groups {
            let gp = if member_ids.len() == 1 {
                // bit-compatible with the unfused PR 2 gate plan
                let g = &circuit_gates[member_ids[0]];
                let axes = vec![g.m, g.n];
                GatePlan {
                    gather: gather_table(&dims, &strides, &axes),
                    rest: rest_offsets(&dims, &strides, &axes),
                    mat: g.mat.data.clone(),
                    dmn: union_dmn,
                    members: vec![GateMember {
                        gate_idx: member_ids[0],
                        m: g.m,
                        n: g.n,
                        dmn: union_dmn,
                        prow: vec![],
                        prest: vec![],
                        rmat: vec![],
                        lmat: vec![],
                    }],
                    axes,
                }
            } else {
                let dims_u: Vec<usize> = union.iter().map(|&a| dims[a]).collect();
                let row_strides = strides_of(&dims_u);
                let members = member_ids
                    .into_iter()
                    .map(|gi| {
                        let g = &circuit_gates[gi];
                        let pos_m = union.iter().position(|&a| a == g.m).unwrap();
                        let pos_n = union.iter().position(|&a| a == g.n).unwrap();
                        let (dn, dmn) = (dims[g.n], dims[g.m] * dims[g.n]);
                        let mut prow = Vec::with_capacity(union_dmn);
                        let mut prest = Vec::with_capacity(union_dmn);
                        for r in 0..union_dmn {
                            let im = (r / row_strides[pos_m]) % dims_u[pos_m];
                            let i_n = (r / row_strides[pos_n]) % dims_u[pos_n];
                            let mut rid = 0usize;
                            for j in 0..union.len() {
                                if j != pos_m && j != pos_n {
                                    rid = rid * dims_u[j] + (r / row_strides[j]) % dims_u[j];
                                }
                            }
                            prow.push((im * dn + i_n) as u32);
                            prest.push(rid as u32);
                        }
                        GateMember {
                            gate_idx: gi,
                            m: g.m,
                            n: g.n,
                            dmn,
                            prow,
                            prest,
                            rmat: vec![],
                            lmat: vec![],
                        }
                    })
                    .collect();
                let mut gp = GatePlan {
                    gather: gather_table(&dims, &strides, &union),
                    rest: rest_offsets(&dims, &strides, &union),
                    mat: vec![],
                    dmn: union_dmn,
                    members,
                    axes: union,
                };
                recompose_gate(&mut gp, circuit_gates);
                gp
            };
            gates.push(gp);
        }
        let max_dmn = gates.iter().map(|g| g.dmn).max().unwrap_or(0);
        let sum_dmn = gates.iter().map(|g| g.dmn).sum();
        Ok(CircuitPlan { d, dims, strides, gates, max_dmn, sum_dmn })
    }

    /// Run `f` with this thread's cached scratch, grown (never shrunk)
    /// to this plan's widest gate.  The executor pays a pair of `Cell`
    /// moves per chunk instead of an alloc+memset.
    pub(crate) fn with_scratch<R>(&self, f: impl FnOnce(&mut Scratch) -> R) -> R {
        FWD_SCRATCH.with(|cell| {
            let mut s = cell.take().unwrap_or_else(Scratch::empty);
            s.ensure(self.max_dmn);
            let r = f(&mut s);
            cell.set(Some(s));
            r
        })
    }

    /// Multiply count of one chain application (paper §6; fused gates
    /// lower it relative to `Circuit::apply_flops`).
    pub fn apply_flops(&self) -> usize {
        self.d * self.sum_dmn
    }

    /// Number of original circuit gates behind this plan (`Σ` members).
    pub fn source_gate_count(&self) -> usize {
        self.gates.iter().map(|g| g.members.len()).sum()
    }

    /// Chunking of a `batch`-vector panel for the compute pool: whole
    /// vectors per chunk, each chunk ≥ one `PAR_MIN_FLOPS` quantum.
    /// Shared by the forward, tape forward, and backward so their chunk
    /// boundaries (and gate-gradient reduction order) always align.
    pub(crate) fn chunking(&self, batch: usize) -> (usize, usize) {
        pool::chunks(batch, self.apply_flops())
    }

    /// Re-snapshot the gate matrices from `circuit` without rebuilding
    /// the stride/rest-offset/gather tables (which depend only on
    /// dims + gate structure).  Dims, gate count, per-gate axes, and
    /// matrix sizes are all checked, so a structurally different
    /// circuit is rejected; per-step optimizers use this to update
    /// parameters at memcpy cost (plus fused-matrix recomposition where
    /// gates were fused) instead of full plan setup.
    pub fn refresh_gate_mats(&mut self, circuit: &Circuit) -> Result<()> {
        if circuit.dims() != self.dims.as_slice()
            || circuit.gates().len() != self.source_gate_count()
        {
            return Err(Error::Shape(format!(
                "refresh_gate_mats: circuit ({:?}, {} gates) does not match plan ({:?}, {})",
                circuit.dims(),
                circuit.gates().len(),
                self.dims,
                self.source_gate_count()
            )));
        }
        let gates = circuit.gates();
        for gp in &self.gates {
            for mem in &gp.members {
                let g = &gates[mem.gate_idx];
                if g.m != mem.m || g.n != mem.n || g.mat.data.len() != mem.dmn * mem.dmn {
                    return Err(Error::Shape(format!(
                        "refresh_gate_mats: gate {} is ({}, {}) with {} entries, plan member \
                         has ({}, {}) with {}",
                        mem.gate_idx,
                        g.m,
                        g.n,
                        g.mat.data.len(),
                        mem.m,
                        mem.n,
                        mem.dmn * mem.dmn
                    )));
                }
            }
        }
        for gp in &mut self.gates {
            recompose_gate(gp, gates);
        }
        Ok(())
    }

    /// Apply the chain to a single vector.
    pub fn apply(&self, x: &[f32]) -> Result<Vec<f32>> {
        self.apply_batch(x, 1)
    }

    /// Apply the chain to `batch` vectors stored row-major in `xs`
    /// (`xs[b*d .. (b+1)*d]` is vector `b`); returns the same layout.
    pub fn apply_batch(&self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        if xs.len() != batch * self.d {
            return Err(Error::Shape(format!(
                "apply_batch: xs len {} != batch {batch} * d {}",
                xs.len(),
                self.d
            )));
        }
        let mut h = xs.to_vec();
        self.apply_batch_in_place(&mut h, batch);
        Ok(h)
    }

    /// In-place variant of [`CircuitPlan::apply_batch`] (the `full_matrix`
    /// panel driver uses this to avoid a copy per panel).
    pub fn apply_batch_in_place(&self, h: &mut [f32], batch: usize) {
        debug_assert_eq!(h.len(), batch * self.d);
        if self.d == 0 || batch == 0 || self.gates.is_empty() {
            return;
        }
        let (chunk_vecs, n_chunks) = self.chunking(batch);
        if n_chunks <= 1 {
            self.with_scratch(|scratch| self.apply_chain_chunk(h, batch, scratch));
            return;
        }
        // Vectors are independent through the whole chain, so the panel
        // splits into fixed chunks of whole vectors; each executor
        // borrows its thread-local scratch.  Per-vector arithmetic does
        // not depend on the chunking, so results are identical for any
        // worker count.
        let chunks = pool::DisjointChunks::new(h, chunk_vecs * self.d);
        pool::run(n_chunks, |i| {
            // SAFETY: each chunk index is claimed exactly once.
            let chunk = unsafe { chunks.slice(i) };
            let cb = chunk.len() / self.d;
            self.with_scratch(|scratch| self.apply_chain_chunk(chunk, cb, scratch));
        });
    }

    /// Fused adapter residual: `out[p] += alpha · (chain(xs)[p] − xs[p])`
    /// over a row-major `[batch, d]` panel, with the `− x` / `·α` folded
    /// into the **final gate's scatter** — one panel pass fewer than
    /// apply-then-axpy, and no materialized circuit output.  `out`
    /// typically arrives holding the frozen-base product `W x`.
    pub fn apply_batch_residual_into(
        &self,
        xs: &[f32],
        batch: usize,
        alpha: f32,
        out: &mut [f32],
    ) -> Result<()> {
        if xs.len() != batch * self.d || out.len() != batch * self.d {
            return Err(Error::Shape(format!(
                "apply_batch_residual_into: xs {} / out {} != batch {batch} * d {}",
                xs.len(),
                out.len(),
                self.d
            )));
        }
        if self.d == 0 || batch == 0 || self.gates.is_empty() {
            return Ok(()); // empty chain is the identity: zero residual
        }
        let (chunk_vecs, n_chunks) = self.chunking(batch);
        if n_chunks <= 1 {
            self.with_scratch(|scratch| self.residual_chain_chunk(xs, out, batch, alpha, scratch));
            return Ok(());
        }
        let out_chunks = pool::DisjointChunks::new(out, chunk_vecs * self.d);
        pool::run(n_chunks, |i| {
            // SAFETY: each chunk index is claimed exactly once.
            let o = unsafe { out_chunks.slice(i) };
            let x0 = i * chunk_vecs * self.d;
            let x = &xs[x0..x0 + o.len()];
            let cb = o.len() / self.d;
            self.with_scratch(|scratch| self.residual_chain_chunk(x, o, cb, alpha, scratch));
        });
        Ok(())
    }

    /// One chunk of the residual-fused chain: gates `0..L−1` run in
    /// place on a scratch copy (skipped entirely for a single-gate
    /// chain), the final gate scatters `α(out_val − x)` into `out`.
    pub(crate) fn residual_chain_chunk(
        &self,
        x: &[f32],
        out: &mut [f32],
        cb: usize,
        alpha: f32,
        scratch: &mut Scratch,
    ) {
        let last = self.gates.len() - 1;
        if last == 0 {
            self.apply_gate_chunk_residual(&self.gates[0], x, x, out, cb, alpha, scratch);
            return;
        }
        let mut h = x.to_vec();
        for g in &self.gates[..last] {
            self.apply_gate_chunk(g, &mut h, cb, scratch);
        }
        self.apply_gate_chunk_residual(&self.gates[last], &h, x, out, cb, alpha, scratch);
    }

    /// Run the whole gate chain over `cb` contiguous vectors.
    pub(crate) fn apply_chain_chunk(&self, h: &mut [f32], cb: usize, scratch: &mut Scratch) {
        for g in &self.gates {
            self.apply_gate_chunk(g, h, cb, scratch);
        }
    }

    /// Fill the column-base table for block `[c0, c0+w)` of gate `g`
    /// (shared with the backward kernels in `quanta::grad`, so the
    /// forward, bulk backward, and sharded backward all walk the same
    /// column bases by construction).
    #[inline]
    pub(crate) fn fill_bases(&self, g: &GatePlan, c0: usize, w: usize, bases: &mut [usize]) {
        let rest_len = g.rest.len();
        for (ci, slot) in bases.iter_mut().enumerate().take(w) {
            let col = c0 + ci;
            let b = col / rest_len;
            let r = col - b * rest_len;
            *slot = b * self.d + g.rest[r];
        }
    }

    /// One gate over `cb` vectors: blocked gather → GEMM → scatter.
    /// Columns of the implicit `(dmn) × (rest·cb)` matrix are `(vector,
    /// rest-offset)` pairs; their gate-axis footprints are disjoint, so
    /// scattering back in place is safe.
    pub(crate) fn apply_gate_chunk(
        &self,
        g: &GatePlan,
        h: &mut [f32],
        cb: usize,
        scratch: &mut Scratch,
    ) {
        let dmn = g.dmn;
        let ncols = cb * g.rest.len();
        let bw = BLOCK_COLS;
        let mut c0 = 0;
        while c0 < ncols {
            let w = bw.min(ncols - c0);
            self.fill_bases(g, c0, w, &mut scratch.bases);
            let bases = &scratch.bases[..w];
            // gather: contiguous writes per row, strided reads from h
            for (k, &off) in g.gather.iter().enumerate() {
                let row = &mut scratch.gathered[k * bw..k * bw + w];
                for (slot, &base) in row.iter_mut().zip(bases) {
                    *slot = h[base + off];
                }
            }
            // GEMM: product[i, :] = Σ_p mat[i, p] · gathered[p, :]
            for i in 0..dmn {
                let orow = &mut scratch.product[i * bw..i * bw + w];
                orow.fill(0.0);
                let mrow = &g.mat[i * dmn..(i + 1) * dmn];
                for (p, &a) in mrow.iter().enumerate() {
                    let grow = &scratch.gathered[p * bw..p * bw + w];
                    for (o, &x) in orow.iter_mut().zip(grow) {
                        *o += a * x;
                    }
                }
            }
            // scatter
            for (k, &off) in g.gather.iter().enumerate() {
                let row = &scratch.product[k * bw..k * bw + w];
                for (&val, &base) in row.iter().zip(bases) {
                    h[base + off] = val;
                }
            }
            c0 += w;
        }
    }

    /// Final-gate variant: gather from `src` (the hidden state entering
    /// the last gate), and instead of scattering the product back,
    /// accumulate `alpha · (product − x)` into `out`.  The gate's
    /// `(rest × gather)` footprint tiles `[0, d)` exactly, so every
    /// output element receives its residual term exactly once.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn apply_gate_chunk_residual(
        &self,
        g: &GatePlan,
        src: &[f32],
        x: &[f32],
        out: &mut [f32],
        cb: usize,
        alpha: f32,
        scratch: &mut Scratch,
    ) {
        let dmn = g.dmn;
        let ncols = cb * g.rest.len();
        let bw = BLOCK_COLS;
        let mut c0 = 0;
        while c0 < ncols {
            let w = bw.min(ncols - c0);
            self.fill_bases(g, c0, w, &mut scratch.bases);
            let bases = &scratch.bases[..w];
            for (k, &off) in g.gather.iter().enumerate() {
                let row = &mut scratch.gathered[k * bw..k * bw + w];
                for (slot, &base) in row.iter_mut().zip(bases) {
                    *slot = src[base + off];
                }
            }
            for i in 0..dmn {
                let orow = &mut scratch.product[i * bw..i * bw + w];
                orow.fill(0.0);
                let mrow = &g.mat[i * dmn..(i + 1) * dmn];
                for (p, &a) in mrow.iter().enumerate() {
                    let grow = &scratch.gathered[p * bw..p * bw + w];
                    for (o, &xv) in orow.iter_mut().zip(grow) {
                        *o += a * xv;
                    }
                }
            }
            // residual scatter: out += α(chain_out − x)
            for (k, &off) in g.gather.iter().enumerate() {
                let row = &scratch.product[k * bw..k * bw + w];
                for (&val, &base) in row.iter().zip(bases) {
                    out[base + off] += alpha * (val - x[base + off]);
                }
            }
            c0 += w;
        }
    }

    /// Materialize the full `(d, d)` operator (paper Eq. 7) by running
    /// `apply_batch` over identity panels — one GEMM chain per
    /// `PANEL_COLS` basis vectors instead of `d` sequential matvecs.
    pub fn full_matrix(&self) -> Result<Tensor> {
        let d = self.d;
        let mut out = Tensor::zeros(&[d, d]);
        let pw = PANEL_COLS.min(d.max(1));
        let mut panel = vec![0.0f32; pw * d];
        let mut j0 = 0;
        while j0 < d {
            let w = pw.min(d - j0);
            let p = &mut panel[..w * d];
            p.fill(0.0);
            for j in 0..w {
                p[j * d + j0 + j] = 1.0;
            }
            self.apply_batch_in_place(p, w);
            // panel row j is the chain applied to e_{j0+j} = column
            // j0+j of the full operator
            for j in 0..w {
                let row = &p[j * d..(j + 1) * d];
                for (i, &v) in row.iter().enumerate() {
                    out.data[i * d + j0 + j] = v;
                }
            }
            j0 += w;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quanta::circuit::{all_pairs_structure, Circuit};
    use crate::util::rng::Rng;

    /// Seed-style reference: per-gate offset tables by O(d) flat-index
    /// scanning, one vector at a time (the pre-engine implementation,
    /// kept as the correctness oracle).
    fn apply_reference(c: &Circuit, x: &[f32]) -> Vec<f32> {
        let dims = c.dims();
        let d: usize = dims.iter().product();
        let strides = strides_of(dims);
        let mut h = x.to_vec();
        for g in c.gates() {
            let (dm, dn) = (dims[g.m], dims[g.n]);
            let (sm, sn) = (strides[g.m], strides[g.n]);
            let mut out = vec![0.0f32; d];
            let mut rest = vec![];
            for flat in 0..d {
                if (flat / sm) % dm == 0 && (flat / sn) % dn == 0 {
                    rest.push(flat);
                }
            }
            for &base in &rest {
                for i_m in 0..dm {
                    for i_n in 0..dn {
                        let row = i_m * dn + i_n;
                        let mut acc = 0.0f32;
                        for j_m in 0..dm {
                            for j_n in 0..dn {
                                acc += g.mat.data[row * (dm * dn) + (j_m * dn + j_n)]
                                    * h[base + j_m * sm + j_n * sn];
                            }
                        }
                        out[base + i_m * sm + i_n * sn] = acc;
                    }
                }
            }
            h = out;
        }
        h
    }

    #[test]
    fn rest_offsets_match_scan() {
        for dims in [vec![2usize, 3, 2], vec![4, 4], vec![2, 2, 3, 2]] {
            let strides = strides_of(&dims);
            let d: usize = dims.iter().product();
            for m in 0..dims.len() {
                for n in 0..dims.len() {
                    if m == n {
                        continue;
                    }
                    let (dm, dn) = (dims[m], dims[n]);
                    let (sm, sn) = (strides[m], strides[n]);
                    let mut scan: Vec<usize> = (0..d)
                        .filter(|flat| (flat / sm) % dm == 0 && (flat / sn) % dn == 0)
                        .collect();
                    let mut stepped = rest_offsets(&dims, &strides, &[m, n]);
                    scan.sort_unstable();
                    stepped.sort_unstable();
                    assert_eq!(stepped, scan, "dims {dims:?} gate ({m},{n})");
                }
            }
        }
    }

    #[test]
    fn rest_offsets_two_axis_gate_is_single_block() {
        let dims = [3usize, 4];
        let strides = strides_of(&dims);
        assert_eq!(rest_offsets(&dims, &strides, &[0, 1]), vec![0]);
    }

    #[test]
    fn plan_apply_matches_reference() {
        let mut rng = Rng::new(40);
        for dims in [vec![2usize, 3, 2], vec![4, 4], vec![2, 2, 2, 2]] {
            let structure = all_pairs_structure(dims.len());
            let c = Circuit::random(&dims, &structure, 0.4, &mut rng).unwrap();
            let d = c.total_dim();
            let mut x = vec![0.0f32; d];
            rng.fill_normal(&mut x, 1.0);
            let plan = CircuitPlan::new(&c).unwrap();
            let y = plan.apply(&x).unwrap();
            let y_ref = apply_reference(&c, &x);
            for (a, b) in y.iter().zip(&y_ref) {
                assert!((a - b).abs() < 1e-4, "dims {dims:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn apply_batch_matches_per_vector() {
        let mut rng = Rng::new(41);
        let dims = [2usize, 3, 4];
        let c = Circuit::random(&dims, &all_pairs_structure(3), 0.3, &mut rng).unwrap();
        let d = c.total_dim();
        let batch = 7;
        let mut xs = vec![0.0f32; batch * d];
        rng.fill_normal(&mut xs, 1.0);
        let plan = CircuitPlan::new(&c).unwrap();
        let ys = plan.apply_batch(&xs, batch).unwrap();
        for b in 0..batch {
            let y1 = plan.apply(&xs[b * d..(b + 1) * d]).unwrap();
            assert_eq!(y1, ys[b * d..(b + 1) * d].to_vec(), "vector {b}");
        }
    }

    #[test]
    fn fusion_merges_overlapping_gates_and_matches_unfused() {
        let mut rng = Rng::new(45);
        // repeated pair: two (0,1) gates on [3,2] must fuse into one
        let c = Circuit::random(&[3usize, 2], &[(0, 1), (0, 1)], 0.4, &mut rng).unwrap();
        let fused = CircuitPlan::new(&c).unwrap();
        let unfused = CircuitPlan::with_max_fused(&c, 0).unwrap();
        assert_eq!(fused.gates.len(), 1, "repeated pair must fuse");
        assert_eq!(fused.gates[0].members.len(), 2);
        assert_eq!(fused.source_gate_count(), 2);
        assert_eq!(unfused.gates.len(), 2, "cap 0 must disable fusion");
        assert!(fused.apply_flops() < unfused.apply_flops());
        let mut xs = vec![0.0f32; 5 * fused.d];
        rng.fill_normal(&mut xs, 1.0);
        let yf = fused.apply_batch(&xs, 5).unwrap();
        let yu = unfused.apply_batch(&xs, 5).unwrap();
        for (a, b) in yf.iter().zip(&yu) {
            assert!((a - b).abs() < 1e-4, "fused {a} vs unfused {b}");
        }
        // 4-axis all-pairs chain: overlapping unions fuse under the cap
        let c4 = Circuit::random(&[2usize, 2, 2, 2], &all_pairs_structure(4), 0.3, &mut rng)
            .unwrap();
        let p4 = CircuitPlan::new(&c4).unwrap();
        assert!(p4.gates.len() < 6, "expected fusion on [2,2,2,2] all-pairs");
        assert_eq!(p4.source_gate_count(), 6);
        let mut x4 = vec![0.0f32; p4.d];
        rng.fill_normal(&mut x4, 1.0);
        let got = p4.apply(&x4).unwrap();
        let want = apply_reference(&c4, &x4);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "fused {a} vs reference {b}");
        }
    }

    #[test]
    fn fusion_cost_rule_skips_expensive_unions() {
        // [4,4,8] all-pairs: every union is the whole space (d=128),
        // above both the cap and the cost rule — nothing may fuse, so
        // fusion leaves the train_smoke workload's arithmetic
        // untouched (per-step chunking still changed vs PR 2).
        let mut rng = Rng::new(46);
        let c = Circuit::random(&[4usize, 4, 8], &all_pairs_structure(3), 0.3, &mut rng).unwrap();
        let plan = CircuitPlan::new(&c).unwrap();
        assert_eq!(plan.gates.len(), 3);
        assert!(plan.gates.iter().all(|g| g.members.len() == 1));
    }

    #[test]
    fn residual_apply_matches_apply_then_axpy() {
        let mut rng = Rng::new(47);
        for dims in [vec![2usize, 3, 2], vec![3, 2], vec![2, 2, 2, 2]] {
            let structure = all_pairs_structure(dims.len());
            let c = Circuit::random(&dims, &structure, 0.3, &mut rng).unwrap();
            let plan = CircuitPlan::new(&c).unwrap();
            let d = plan.d;
            let batch = 4;
            let alpha = 0.7f32;
            let mut xs = vec![0.0f32; batch * d];
            rng.fill_normal(&mut xs, 1.0);
            let mut base = vec![0.0f32; batch * d];
            rng.fill_normal(&mut base, 1.0);
            // reference: apply, then axpy
            let cx = plan.apply_batch(&xs, batch).unwrap();
            let mut want = base.clone();
            for ((w, &cv), &xv) in want.iter_mut().zip(&cx).zip(&xs) {
                *w += alpha * (cv - xv);
            }
            let mut got = base.clone();
            plan.apply_batch_residual_into(&xs, batch, alpha, &mut got).unwrap();
            assert_eq!(got, want, "dims {dims:?}: residual fusion changed results");
        }
        // empty chain: residual must be exactly zero
        let c = Circuit::new(vec![2, 2], vec![]).unwrap();
        let plan = CircuitPlan::new(&c).unwrap();
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        let mut out = [5.0f32, 6.0, 7.0, 8.0];
        plan.apply_batch_residual_into(&xs, 1, 0.9, &mut out).unwrap();
        assert_eq!(out, [5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn full_matrix_matches_basis_reference() {
        let mut rng = Rng::new(42);
        let dims = [2usize, 2, 3];
        let c = Circuit::random(&dims, &all_pairs_structure(3), 0.5, &mut rng).unwrap();
        let d = c.total_dim();
        let plan = CircuitPlan::new(&c).unwrap();
        let full = plan.full_matrix().unwrap();
        let mut e = vec![0.0f32; d];
        for j in 0..d {
            e[j] = 1.0;
            let col = apply_reference(&c, &e);
            e[j] = 0.0;
            for i in 0..d {
                assert!(
                    (full.data[i * d + j] - col[i]).abs() < 1e-4,
                    "({i},{j}): {} vs {}",
                    full.data[i * d + j],
                    col[i]
                );
            }
        }
    }

    #[test]
    fn refresh_gate_mats_matches_fresh_plan() {
        let mut rng = Rng::new(44);
        // [2,3,2] all-pairs does not fuse; the repeated pair does — the
        // refresh path must recompose fused matrices in both cases.
        for structure in [all_pairs_structure(3), vec![(0, 1), (0, 1)]] {
            let dims = [2usize, 3, 2];
            let mut c = Circuit::random(&dims, &structure, 0.4, &mut rng).unwrap();
            let mut plan = CircuitPlan::new(&c).unwrap();
            // mutate the gates, refresh in place, compare against a rebuild
            for g in c.gates_mut() {
                let sz = g.mat.shape[0];
                g.mat = Tensor::randn(&[sz, sz], 0.5, &mut rng);
            }
            plan.refresh_gate_mats(&c).unwrap();
            let fresh = CircuitPlan::new(&c).unwrap();
            let mut x = vec![0.0f32; plan.d * 3];
            rng.fill_normal(&mut x, 1.0);
            assert_eq!(
                plan.apply_batch(&x, 3).unwrap(),
                fresh.apply_batch(&x, 3).unwrap()
            );
        }
        // structure mismatch is rejected
        let c = Circuit::random(&[2usize, 3, 2], &all_pairs_structure(3), 0.4, &mut rng).unwrap();
        let mut plan = CircuitPlan::new(&c).unwrap();
        let other = Circuit::random(&[2usize, 2], &[(0, 1)], 0.1, &mut rng).unwrap();
        assert!(plan.refresh_gate_mats(&other).is_err());
        // ...including same-size gates on different axes
        let dims3 = [2usize, 2, 2];
        let c01 = Circuit::random(&dims3, &[(0, 1)], 0.2, &mut rng).unwrap();
        let c12 = Circuit::random(&dims3, &[(1, 2)], 0.2, &mut rng).unwrap();
        let mut p01 = CircuitPlan::new(&c01).unwrap();
        assert!(p01.refresh_gate_mats(&c12).is_err(), "axis drift must be rejected");
        assert!(p01.refresh_gate_mats(&c01).is_ok());
    }

    #[test]
    fn plan_reuse_is_deterministic() {
        let mut rng = Rng::new(43);
        let dims = [3usize, 2, 2];
        let c = Circuit::random(&dims, &all_pairs_structure(3), 0.4, &mut rng).unwrap();
        let d = c.total_dim();
        let mut x = vec![0.0f32; 4 * d];
        rng.fill_normal(&mut x, 1.0);
        let plan = CircuitPlan::new(&c).unwrap();
        let y1 = plan.apply_batch(&x, 4).unwrap();
        let y2 = plan.apply_batch(&x, 4).unwrap();
        assert_eq!(y1, y2, "same plan, same input, different output");
        let plan2 = CircuitPlan::new(&c).unwrap();
        assert_eq!(y1, plan2.apply_batch(&x, 4).unwrap(), "fresh plan differs");
        let f1 = plan.full_matrix().unwrap();
        let f2 = plan2.full_matrix().unwrap();
        assert_eq!(f1.data, f2.data);
    }
}
