//! QuanTA circuits on the host: gates, chain application, full-matrix
//! materialization (paper Eq. 4–7).
//!
//! Execution is delegated to the plan-cached engine in
//! [`crate::quanta::plan`]: the convenience methods here build a
//! [`CircuitPlan`] per call, which is already `O(d/(d_m d_n))` setup per
//! gate; callers applying the same circuit repeatedly (benches, the
//! theorem property sweeps) should hold a [`Circuit::plan`] and reuse it.

use crate::quanta::plan::CircuitPlan;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// One two-axis gate: a `(d_m*d_n, d_m*d_n)` matrix acting on axes
/// `(m, n)` of the reshaped hidden vector (paper Eq. 4).
#[derive(Clone, Debug)]
pub struct Gate {
    pub m: usize,
    pub n: usize,
    pub mat: Tensor,
}

/// A QuanTA circuit: axis dimensions + ordered gates (applied first to
/// last, paper Eq. 5).
#[derive(Clone, Debug)]
pub struct Circuit {
    pub dims: Vec<usize>,
    pub gates: Vec<Gate>,
}

/// The paper's default structure (App. E.1): one gate per unordered axis
/// pair, enumerated to match `einsum_gen.all_pairs_structure` on the
/// python side.
pub fn all_pairs_structure(n_axes: usize) -> Vec<(usize, usize)> {
    let mut pairs = vec![];
    // combinations over negative indices (-1, -2, ..., -N), matching App. G
    let neg: Vec<i64> = (1..=n_axes as i64).map(|k| -k).collect();
    for a in 0..neg.len() {
        for b in (a + 1)..neg.len() {
            let m = ((neg[a] + n_axes as i64) % n_axes as i64) as usize;
            let n = ((neg[b] + n_axes as i64) % n_axes as i64) as usize;
            pairs.push((m, n));
        }
    }
    pairs
}

impl Circuit {
    /// Random circuit over `dims` with the given structure; each gate is
    /// `eye + N(0, std^2)` like the training init.
    pub fn random(
        dims: &[usize],
        structure: &[(usize, usize)],
        std: f32,
        rng: &mut Rng,
    ) -> Result<Circuit> {
        let mut gates = vec![];
        for &(m, n) in structure {
            if m >= dims.len() || n >= dims.len() || m == n {
                return Err(Error::Shape(format!("bad gate axes ({m},{n}) for dims {dims:?}")));
            }
            let sz = dims[m] * dims[n];
            let mat = Tensor::eye(sz).add(&Tensor::randn(&[sz, sz], std, rng))?;
            gates.push(Gate { m, n, mat });
        }
        Ok(Circuit { dims: dims.to_vec(), gates })
    }

    pub fn total_dim(&self) -> usize {
        self.dims.iter().product()
    }

    /// Trainable parameter count of this circuit (paper §6):
    /// `sum_alpha (d_m d_n)^2`.
    pub fn param_count(&self) -> usize {
        self.gates.iter().map(|g| g.mat.numel()).sum()
    }

    /// Multiply count of one chain application (paper §6):
    /// `d * sum_alpha d_m d_n`.
    pub fn apply_flops(&self) -> usize {
        let d = self.total_dim();
        d * self.gates.iter().map(|g| self.dims[g.m] * self.dims[g.n]).sum::<usize>()
    }

    /// Build the cached execution plan for this circuit (strides,
    /// rest-offset tables, gather tables, gate-matrix snapshots).
    pub fn plan(&self) -> Result<CircuitPlan> {
        CircuitPlan::new(self)
    }

    /// Apply the chain to a single hidden vector `x` of length `d`
    /// (paper Eq. 4/5).  Convenience wrapper; hold a [`Circuit::plan`]
    /// to amortize setup over repeated applications.
    pub fn apply(&self, x: &[f32]) -> Result<Vec<f32>> {
        self.plan()?.apply(x)
    }

    /// Apply the chain to `batch` vectors stored row-major in `xs`
    /// (`xs[b*d .. (b+1)*d]` is vector `b`), executed as blocked
    /// `(d_m·d_n) × (rest·batch)` GEMMs over parallel panel chunks.
    pub fn apply_batch(&self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        self.plan()?.apply_batch(xs, batch)
    }

    /// Materialize the full `(d, d)` operator (paper Eq. 7) by driving
    /// the batched engine over identity panels.
    pub fn full_matrix(&self) -> Result<Tensor> {
        self.plan()?.full_matrix()
    }

    /// Compose: the matrix of `self` applied after `other`
    /// (`full(self) @ full(other)`).
    pub fn compose_matrix(&self, other: &Circuit) -> Result<Tensor> {
        self.full_matrix()?.matmul(&other.full_matrix()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_pairs_counts() {
        assert_eq!(all_pairs_structure(3).len(), 3);
        assert_eq!(all_pairs_structure(4).len(), 6);
        assert_eq!(all_pairs_structure(5).len(), 10);
    }

    #[test]
    fn identity_circuit_is_identity() {
        let dims = [2usize, 3, 2];
        let structure = all_pairs_structure(3);
        let mut rng = Rng::new(1);
        let mut c = Circuit::random(&dims, &structure, 0.1, &mut rng).unwrap();
        for g in &mut c.gates {
            g.mat = Tensor::eye(g.mat.shape[0]);
        }
        let full = c.full_matrix().unwrap();
        assert!(full.max_abs_diff(&Tensor::eye(12)) < 1e-6);
    }

    #[test]
    fn apply_matches_full_matrix() {
        let dims = [2usize, 2, 3];
        let structure = all_pairs_structure(3);
        let mut rng = Rng::new(2);
        let c = Circuit::random(&dims, &structure, 0.3, &mut rng).unwrap();
        let full = c.full_matrix().unwrap();
        let mut x = vec![0.0f32; 12];
        rng.fill_normal(&mut x, 1.0);
        let y1 = c.apply(&x).unwrap();
        let y2 = full.matvec(&x).unwrap();
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn apply_batch_matches_apply() {
        let dims = [2usize, 3, 2];
        let structure = all_pairs_structure(3);
        let mut rng = Rng::new(6);
        let c = Circuit::random(&dims, &structure, 0.3, &mut rng).unwrap();
        let d = c.total_dim();
        let batch = 5;
        let mut xs = vec![0.0f32; batch * d];
        rng.fill_normal(&mut xs, 1.0);
        let ys = c.apply_batch(&xs, batch).unwrap();
        for b in 0..batch {
            let y = c.apply(&xs[b * d..(b + 1) * d]).unwrap();
            assert_eq!(y, ys[b * d..(b + 1) * d].to_vec());
        }
    }

    #[test]
    fn single_gate_two_axes_is_kron_structure() {
        // One gate on both axes of a 2-axis decomposition == the full
        // matrix itself (the KronA remark under Thm 6.1: N=2 single gate
        // covers everything).
        let dims = [3usize, 4];
        let structure = [(0usize, 1usize)];
        let mut rng = Rng::new(3);
        let c = Circuit::random(&dims, &structure, 0.5, &mut rng).unwrap();
        let full = c.full_matrix().unwrap();
        assert!(full.max_abs_diff(&c.gates[0].mat) < 1e-6);
    }

    #[test]
    fn param_and_flop_formulas() {
        // uniform case from paper §6: d_m = d^{1/N}, one gate per pair
        let dims = [4usize, 4, 4];
        let structure = all_pairs_structure(3);
        let mut rng = Rng::new(4);
        let c = Circuit::random(&dims, &structure, 0.1, &mut rng).unwrap();
        let d = 64usize;
        let n = 3usize;
        assert_eq!(c.param_count(), n * (n - 1) / 2 * 16 * 16); // N(N-1)/2 * d^{4/N}
        assert_eq!(c.apply_flops(), n * (n - 1) / 2 * d * 16); // N(N-1)/2 * d^{1+2/N}
        assert_eq!(c.plan().unwrap().apply_flops(), c.apply_flops());
    }

    #[test]
    fn gate_order_matters() {
        // non-commuting gates: T1 then T2 differs from T2 then T1
        let dims = [2usize, 2];
        let mut rng = Rng::new(5);
        let g0 = Gate { m: 0, n: 1, mat: Tensor::randn(&[4, 4], 1.0, &mut rng) };
        let g1 = Gate { m: 0, n: 1, mat: Tensor::randn(&[4, 4], 1.0, &mut rng) };
        let c01 = Circuit { dims: dims.to_vec(), gates: vec![g0.clone(), g1.clone()] };
        let c10 = Circuit { dims: dims.to_vec(), gates: vec![g1, g0] };
        let f01 = c01.full_matrix().unwrap();
        let f10 = c10.full_matrix().unwrap();
        assert!(f01.max_abs_diff(&f10) > 1e-3);
    }

    #[test]
    fn stale_plan_vs_fresh_plan() {
        // the plan snapshots gate matrices: mutating the circuit after
        // planning must not change the plan's output, and a fresh plan
        // must pick the mutation up.
        let dims = [2usize, 2];
        let mut rng = Rng::new(8);
        let mut c = Circuit::random(&dims, &[(0, 1)], 0.5, &mut rng).unwrap();
        let plan = c.plan().unwrap();
        let before = plan.full_matrix().unwrap();
        c.gates[0].mat = Tensor::eye(4);
        assert!(plan.full_matrix().unwrap().max_abs_diff(&before) < 1e-9);
        assert!(c.plan().unwrap().full_matrix().unwrap().max_abs_diff(&Tensor::eye(4)) < 1e-9);
    }
}
