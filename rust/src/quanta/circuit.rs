//! QuanTA circuits on the host: gates, chain application, full-matrix
//! materialization (paper Eq. 4–7).
//!
//! Execution is delegated to the plan-cached engine in
//! [`crate::quanta::plan`].  The circuit owns its plan: [`Circuit::plan`]
//! builds it on first use and caches it (`OnceLock<Arc<CircuitPlan>>`),
//! and every mutable path to the gates goes through
//! [`Circuit::gates_mut`], which drops the cache — so a plan can never
//! silently go stale, and repeated `apply`/`full_matrix` calls (theorem
//! sweeps, tests) no longer pay per-call setup.  Handles obtained from
//! `plan()` before a mutation keep the old snapshot, matching the
//! plan's documented copy semantics.

use std::sync::{Arc, OnceLock};

use crate::quanta::plan::CircuitPlan;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// One two-axis gate: a `(d_m*d_n, d_m*d_n)` matrix acting on axes
/// `(m, n)` of the reshaped hidden vector (paper Eq. 4).
#[derive(Clone, Debug)]
pub struct Gate {
    pub m: usize,
    pub n: usize,
    pub mat: Tensor,
}

/// A QuanTA circuit: axis dimensions + ordered gates (applied first to
/// last, paper Eq. 5).  Both fields are private so the cached execution
/// plan is invalidated exactly when the circuit changes — read with
/// [`Circuit::dims`] / [`Circuit::gates`], mutate gates through
/// [`Circuit::gates_mut`] (dims are fixed at construction).
#[derive(Clone, Debug)]
pub struct Circuit {
    dims: Vec<usize>,
    gates: Vec<Gate>,
    /// Lazily built execution plan; cleared by `gates_mut`.
    cache: OnceLock<Arc<CircuitPlan>>,
}

/// The paper's default structure (App. E.1): one gate per unordered axis
/// pair, enumerated to match `einsum_gen.all_pairs_structure` on the
/// python side.
pub fn all_pairs_structure(n_axes: usize) -> Vec<(usize, usize)> {
    let mut pairs = vec![];
    // combinations over negative indices (-1, -2, ..., -N), matching App. G
    let neg: Vec<i64> = (1..=n_axes as i64).map(|k| -k).collect();
    for a in 0..neg.len() {
        for b in (a + 1)..neg.len() {
            let m = ((neg[a] + n_axes as i64) % n_axes as i64) as usize;
            let n = ((neg[b] + n_axes as i64) % n_axes as i64) as usize;
            pairs.push((m, n));
        }
    }
    pairs
}

impl Circuit {
    /// Build a circuit from explicit gates, validating axes and matrix
    /// shapes up front (the same invariants the plan relies on).
    pub fn new(dims: Vec<usize>, gates: Vec<Gate>) -> Result<Circuit> {
        for g in &gates {
            if g.m >= dims.len() || g.n >= dims.len() || g.m == g.n {
                return Err(Error::Shape(format!(
                    "bad gate axes ({}, {}) for dims {dims:?}",
                    g.m, g.n
                )));
            }
            let sz = dims[g.m] * dims[g.n];
            if g.mat.shape != [sz, sz] {
                return Err(Error::Shape(format!(
                    "gate ({}, {}) matrix shape {:?}, want [{sz}, {sz}]",
                    g.m, g.n, g.mat.shape
                )));
            }
        }
        Ok(Circuit { dims, gates, cache: OnceLock::new() })
    }

    /// Identity circuit over `dims` with the given structure (every gate
    /// `eye` — the QuanTA training init, so the chain starts as a no-op).
    pub fn identity(dims: &[usize], structure: &[(usize, usize)]) -> Result<Circuit> {
        let gates = structure
            .iter()
            .map(|&(m, n)| {
                let sz = dims.get(m).copied().unwrap_or(0) * dims.get(n).copied().unwrap_or(0);
                Gate { m, n, mat: Tensor::eye(sz) }
            })
            .collect();
        Circuit::new(dims.to_vec(), gates)
    }

    /// Random circuit over `dims` with the given structure; each gate is
    /// `eye + N(0, std^2)` like the training init.
    pub fn random(
        dims: &[usize],
        structure: &[(usize, usize)],
        std: f32,
        rng: &mut Rng,
    ) -> Result<Circuit> {
        let mut gates = vec![];
        for &(m, n) in structure {
            if m >= dims.len() || n >= dims.len() || m == n {
                return Err(Error::Shape(format!("bad gate axes ({m},{n}) for dims {dims:?}")));
            }
            let sz = dims[m] * dims[n];
            let mat = Tensor::eye(sz).add(&Tensor::randn(&[sz, sz], std, rng))?;
            gates.push(Gate { m, n, mat });
        }
        Circuit::new(dims.to_vec(), gates)
    }

    /// Axis dimensions of the reshaped hidden tensor.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Read-only view of the gate chain.
    pub fn gates(&self) -> &[Gate] {
        &self.gates
    }

    /// Mutable access to the gate chain.  Dropping into this accessor
    /// invalidates the cached plan, so the next [`Circuit::plan`] (or
    /// `apply`/`full_matrix`) rebuilds from the mutated gates.
    pub fn gates_mut(&mut self) -> &mut Vec<Gate> {
        self.cache = OnceLock::new();
        &mut self.gates
    }

    pub fn total_dim(&self) -> usize {
        self.dims.iter().product()
    }

    /// Trainable parameter count of this circuit (paper §6):
    /// `sum_alpha (d_m d_n)^2`.
    pub fn param_count(&self) -> usize {
        self.gates.iter().map(|g| g.mat.numel()).sum()
    }

    /// Multiply count of one chain application (paper §6):
    /// `d * sum_alpha d_m d_n`.
    pub fn apply_flops(&self) -> usize {
        let d = self.total_dim();
        d * self.gates.iter().map(|g| self.dims[g.m] * self.dims[g.n]).sum::<usize>()
    }

    /// The cached execution plan for this circuit (strides, rest-offset
    /// tables, gather tables, gate-matrix snapshots), built on first use
    /// and reused until the gates are mutated through
    /// [`Circuit::gates_mut`].
    pub fn plan(&self) -> Result<Arc<CircuitPlan>> {
        if let Some(p) = self.cache.get() {
            return Ok(p.clone());
        }
        let p = Arc::new(CircuitPlan::new(self)?);
        // a racing builder may have set it first; either value is
        // equivalent (both snapshot the same gates)
        let _ = self.cache.set(p.clone());
        Ok(p)
    }

    /// Apply the chain to a single hidden vector `x` of length `d`
    /// (paper Eq. 4/5), through the cached plan.
    pub fn apply(&self, x: &[f32]) -> Result<Vec<f32>> {
        self.plan()?.apply(x)
    }

    /// Apply the chain to `batch` vectors stored row-major in `xs`
    /// (`xs[b*d .. (b+1)*d]` is vector `b`), executed as blocked
    /// `(d_m·d_n) × (rest·batch)` GEMMs over parallel panel chunks.
    pub fn apply_batch(&self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        self.plan()?.apply_batch(xs, batch)
    }

    /// Materialize the full `(d, d)` operator (paper Eq. 7) by driving
    /// the batched engine over identity panels.
    pub fn full_matrix(&self) -> Result<Tensor> {
        self.plan()?.full_matrix()
    }

    /// Compose: the matrix of `self` applied after `other`
    /// (`full(self) @ full(other)`).
    pub fn compose_matrix(&self, other: &Circuit) -> Result<Tensor> {
        self.full_matrix()?.matmul(&other.full_matrix()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_pairs_counts() {
        assert_eq!(all_pairs_structure(3).len(), 3);
        assert_eq!(all_pairs_structure(4).len(), 6);
        assert_eq!(all_pairs_structure(5).len(), 10);
    }

    #[test]
    fn identity_circuit_is_identity() {
        let dims = [2usize, 3, 2];
        let structure = all_pairs_structure(3);
        let c = Circuit::identity(&dims, &structure).unwrap();
        let full = c.full_matrix().unwrap();
        assert!(full.max_abs_diff(&Tensor::eye(12)) < 1e-6);
    }

    #[test]
    fn apply_matches_full_matrix() {
        let dims = [2usize, 2, 3];
        let structure = all_pairs_structure(3);
        let mut rng = Rng::new(2);
        let c = Circuit::random(&dims, &structure, 0.3, &mut rng).unwrap();
        let full = c.full_matrix().unwrap();
        let mut x = vec![0.0f32; 12];
        rng.fill_normal(&mut x, 1.0);
        let y1 = c.apply(&x).unwrap();
        let y2 = full.matvec(&x).unwrap();
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn apply_batch_matches_apply() {
        let dims = [2usize, 3, 2];
        let structure = all_pairs_structure(3);
        let mut rng = Rng::new(6);
        let c = Circuit::random(&dims, &structure, 0.3, &mut rng).unwrap();
        let d = c.total_dim();
        let batch = 5;
        let mut xs = vec![0.0f32; batch * d];
        rng.fill_normal(&mut xs, 1.0);
        let ys = c.apply_batch(&xs, batch).unwrap();
        for b in 0..batch {
            let y = c.apply(&xs[b * d..(b + 1) * d]).unwrap();
            assert_eq!(y, ys[b * d..(b + 1) * d].to_vec());
        }
    }

    #[test]
    fn single_gate_two_axes_is_kron_structure() {
        // One gate on both axes of a 2-axis decomposition == the full
        // matrix itself (the KronA remark under Thm 6.1: N=2 single gate
        // covers everything).
        let dims = [3usize, 4];
        let structure = [(0usize, 1usize)];
        let mut rng = Rng::new(3);
        let c = Circuit::random(&dims, &structure, 0.5, &mut rng).unwrap();
        let full = c.full_matrix().unwrap();
        assert!(full.max_abs_diff(&c.gates()[0].mat) < 1e-6);
    }

    #[test]
    fn param_and_flop_formulas() {
        // uniform case from paper §6: d_m = d^{1/N}, one gate per pair
        let dims = [4usize, 4, 4];
        let structure = all_pairs_structure(3);
        let mut rng = Rng::new(4);
        let c = Circuit::random(&dims, &structure, 0.1, &mut rng).unwrap();
        let d = 64usize;
        let n = 3usize;
        assert_eq!(c.param_count(), n * (n - 1) / 2 * 16 * 16); // N(N-1)/2 * d^{4/N}
        assert_eq!(c.apply_flops(), n * (n - 1) / 2 * d * 16); // N(N-1)/2 * d^{1+2/N}
        assert_eq!(c.plan().unwrap().apply_flops(), c.apply_flops());
    }

    #[test]
    fn gate_order_matters() {
        // non-commuting gates: T1 then T2 differs from T2 then T1
        let dims = vec![2usize, 2];
        let mut rng = Rng::new(5);
        let g0 = Gate { m: 0, n: 1, mat: Tensor::randn(&[4, 4], 1.0, &mut rng) };
        let g1 = Gate { m: 0, n: 1, mat: Tensor::randn(&[4, 4], 1.0, &mut rng) };
        let c01 = Circuit::new(dims.clone(), vec![g0.clone(), g1.clone()]).unwrap();
        let c10 = Circuit::new(dims, vec![g1, g0]).unwrap();
        let f01 = c01.full_matrix().unwrap();
        let f10 = c10.full_matrix().unwrap();
        assert!(f01.max_abs_diff(&f10) > 1e-3);
    }

    #[test]
    fn bad_gates_rejected_at_construction() {
        let eye4 = Tensor::eye(4);
        assert!(Circuit::new(vec![2, 2], vec![Gate { m: 0, n: 0, mat: eye4.clone() }]).is_err());
        assert!(Circuit::new(vec![2, 2], vec![Gate { m: 0, n: 2, mat: eye4.clone() }]).is_err());
        assert!(Circuit::new(vec![2, 3], vec![Gate { m: 0, n: 1, mat: eye4 }]).is_err());
    }

    #[test]
    fn plan_cache_reused_and_invalidated_on_mutation() {
        let dims = [2usize, 2];
        let mut rng = Rng::new(8);
        let mut c = Circuit::random(&dims, &[(0, 1)], 0.5, &mut rng).unwrap();
        let p1 = c.plan().unwrap();
        let p2 = c.plan().unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "repeated plan() must hit the cache");
        let before = p1.full_matrix().unwrap();
        // mutation through gates_mut drops the cache...
        c.gates_mut()[0].mat = Tensor::eye(4);
        let p3 = c.plan().unwrap();
        assert!(!Arc::ptr_eq(&p1, &p3), "plan cache must be invalidated by gates_mut");
        assert!(p3.full_matrix().unwrap().max_abs_diff(&Tensor::eye(4)) < 1e-9);
        // ...while previously obtained handles keep their snapshot
        assert!(p1.full_matrix().unwrap().max_abs_diff(&before) < 1e-9);
    }
}
