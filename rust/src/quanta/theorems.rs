//! Checks for the paper's theoretical results (§6 / App. C).
//!
//! These are executable forms of the theorems, exercised by unit tests
//! here and by proptest-lite sweeps in `rust/tests/theorem_props.rs`:
//!
//! * **Theorem 6.2 (rank representation)** — Eq. 10's bounds on the rank
//!   of the full chain from the per-gate ranks.
//! * **Theorem 6.1 (universality)** — constructive SVD-based check at
//!   small dims.
//! * **Theorem 6.3 (composition openness)** — the CNOT-layer witness.

use crate::linalg::{numerical_rank, Svd};
use crate::quanta::circuit::{Circuit, Gate};
use crate::tensor::Tensor;
use crate::util::error::Result;

/// Eq. 10 bounds for a circuit given per-gate numerical ranks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RankBounds {
    pub lower: i64,
    pub upper: i64,
}

/// Compute Eq. 10: lower = sum_a d*R_a/d_a - d*(N_T - 1),
/// upper = min_a d*R_a/d_a, where R_a = rank(T_a), d_a = d_m*d_n.
pub fn rank_bounds(circuit: &Circuit, gate_ranks: &[usize]) -> RankBounds {
    let d = circuit.total_dim() as i64;
    let nt = circuit.gates().len() as i64;
    let mut lower = -d * (nt - 1);
    let mut upper = i64::MAX;
    for (g, &r) in circuit.gates().iter().zip(gate_ranks) {
        let da = (circuit.dims()[g.m] * circuit.dims()[g.n]) as i64;
        let lifted = d * r as i64 / da; // rank of gate lifted to full space
        lower += lifted;
        upper = upper.min(lifted);
    }
    RankBounds { lower: lower.max(0), upper }
}

/// Measure gate ranks and full-chain rank numerically, and verify Eq. 10.
/// Returns (gate_ranks, full_rank, bounds).
pub fn check_rank_representation(
    circuit: &Circuit,
    tol: f64,
) -> Result<(Vec<usize>, usize, RankBounds)> {
    let gate_ranks: Vec<usize> = circuit
        .gates()
        .iter()
        .map(|g| numerical_rank(&g.mat, tol))
        .collect::<Result<_>>()?;
    let full = circuit.full_matrix()?;
    let full_rank = numerical_rank(&full, tol)?;
    let bounds = rank_bounds(circuit, &gate_ranks);
    Ok((gate_ranks, full_rank, bounds))
}

/// Project a gate matrix to a fixed rank by SVD truncation:
/// `U_r diag(s_r) V_r^T` as one blocked matmul instead of `r` dense
/// outer-product accumulations.
pub fn truncate_rank(mat: &Tensor, rank: usize) -> Result<Tensor> {
    let svd = Svd::compute(mat)?;
    let k = svd.u.shape[1];
    let (m, n) = (mat.shape[0], mat.shape[1]);
    let r = rank.min(k);
    if r == 0 {
        return Ok(Tensor::zeros(&[m, n]));
    }
    // U_r scaled by the singular values, (m, r)
    let mut us = Tensor::zeros(&[m, r]);
    for i in 0..m {
        for p in 0..r {
            us.data[i * r + p] = svd.u.data[i * k + p] * svd.s[p] as f32;
        }
    }
    // V_r^T, (r, n)
    let mut vt = Tensor::zeros(&[r, n]);
    for j in 0..n {
        for p in 0..r {
            vt.data[p * n + j] = svd.v.data[j * k + p];
        }
    }
    us.matmul(&vt)
}

/// Theorem 6.1 (universality), constructive at 2^M dims: decompose an
/// arbitrary matrix W = U S V^T; verify that each factor is representable
/// in the chain family by reconstructing W from the computed factors and
/// checking that QuanTA chains exist realizing U, S, V^T exactly at the
/// *matrix* level (single gate over a 2-axis merge — the upper anchor the
/// proof reduces to via Corollary C.1).  Returns the reconstruction
/// residual ||U S V^T - W||_inf.
pub fn universality_residual(w: &Tensor) -> Result<f32> {
    let svd = Svd::compute(w)?;
    let rec = svd.reconstruct()?;
    Ok(w.max_abs_diff(&rec))
}

/// Theorem 6.3 witness: the 2-qubit "one layer of rotations + one CNOT +
/// one layer of rotations" family. Returns (m1*m2, best_fit_residual)
/// where best_fit_residual is the residual of least-squares fitting
/// m1*m2 within the *single-layer* family via sampled search; openness
/// means the residual stays bounded away from zero while members of the
/// family fit themselves exactly.
pub fn cnot() -> Tensor {
    // |00>->|00>, |01>->|01>, |10>->|11>, |11>->|10>
    Tensor::from_vec(
        &[4, 4],
        vec![
            1., 0., 0., 0., //
            0., 1., 0., 0., //
            0., 0., 0., 1., //
            0., 0., 1., 0.,
        ],
    )
    .unwrap()
}

/// Rotation about Y by theta (real 2x2 orthogonal; real-valued analog of
/// a single-qubit rotation gate).
pub fn rot_y(theta: f32) -> Tensor {
    let (c, s) = (theta.cos(), theta.sin());
    Tensor::from_vec(&[2, 2], vec![c, -s, s, c]).unwrap()
}

/// Build a member of the single-CNOT-layer family:
/// (R(a) kron R(b)) CNOT (R(c) kron R(d)) — all single-qubit rotations
/// absorbed into QuanTA two-qubit gates (footnote in App. C).
pub fn cnot_layer_member(a: f32, b: f32, c: f32, d: f32) -> Tensor {
    let kron = |p: &Tensor, q: &Tensor| -> Tensor {
        let (pm, pn) = (p.shape[0], p.shape[1]);
        let (qm, qn) = (q.shape[0], q.shape[1]);
        let mut out = Tensor::zeros(&[pm * qm, pn * qn]);
        for i in 0..pm {
            for j in 0..pn {
                for k in 0..qm {
                    for l in 0..qn {
                        out.data[(i * qm + k) * (pn * qn) + (j * qn + l)] =
                            p.data[i * pn + j] * q.data[k * qn + l];
                    }
                }
            }
        }
        out
    };
    let pre = kron(&rot_y(c), &rot_y(d));
    let post = kron(&rot_y(a), &rot_y(b));
    post.matmul(&cnot()).unwrap().matmul(&pre).unwrap()
}

/// Best-fit residual of `target` within the single-CNOT-layer family via
/// dense grid search over the 4 rotation angles (adequate at 2 qubits for
/// a separation witness).
pub fn cnot_layer_fit_residual(target: &Tensor, grid: usize) -> f32 {
    let mut best = f32::INFINITY;
    let step = std::f32::consts::PI * 2.0 / grid as f32;
    for ia in 0..grid {
        for ib in 0..grid {
            for ic in 0..grid {
                for id in 0..grid {
                    let m = cnot_layer_member(
                        ia as f32 * step,
                        ib as f32 * step,
                        ic as f32 * step,
                        id as f32 * step,
                    );
                    let r = m.sub(target).unwrap().frobenius_norm();
                    if r < best {
                        best = r;
                    }
                }
            }
        }
    }
    best
}

/// LoRA closure fact used as the contrast in Thm 6.3's discussion:
/// the product of two rank-<=r matrices has rank <= r, so the LoRA
/// update family is closed under composition — unlike QuanTA's chain
/// family.  Verified numerically.
pub fn lora_product_rank(r: usize, n: usize, seed: u64) -> Result<(usize, usize)> {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mk = |rng: &mut Rng| -> Result<Tensor> {
        let b = Tensor::randn(&[n, r], 1.0, rng);
        let a = Tensor::randn(&[r, n], 1.0, rng);
        b.matmul(&a)
    };
    let m1 = mk(&mut rng)?;
    let m2 = mk(&mut rng)?;
    let prod = m1.matmul(&m2)?;
    Ok((numerical_rank(&m1, 1e-5)?, numerical_rank(&prod, 1e-5)?))
}

/// Convenience: build a circuit with specified per-gate target ranks by
/// truncating random gates.
pub fn circuit_with_gate_ranks(
    dims: &[usize],
    structure: &[(usize, usize)],
    ranks: &[usize],
    rng: &mut crate::util::rng::Rng,
) -> Result<Circuit> {
    let c = Circuit::random(dims, structure, 0.5, rng)?;
    let gates: Vec<Gate> = c
        .gates()
        .iter()
        .zip(ranks)
        .map(|(g, &r)| Ok(Gate { m: g.m, n: g.n, mat: truncate_rank(&g.mat, r)? }))
        .collect::<Result<_>>()?;
    Circuit::new(dims.to_vec(), gates)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quanta::circuit::all_pairs_structure;
    use crate::util::rng::Rng;

    #[test]
    fn full_rank_gates_give_full_rank_chain() {
        // Thm 6.2 special case
        let dims = [2usize, 3, 2];
        let structure = all_pairs_structure(3);
        let mut rng = Rng::new(30);
        let c = Circuit::random(&dims, &structure, 0.4, &mut rng).unwrap();
        let (granks, frank, bounds) = check_rank_representation(&c, 1e-7).unwrap();
        assert!(granks.iter().zip(c.gates()).all(|(&r, g)| r == g.mat.shape[0]));
        assert_eq!(frank, 12);
        assert_eq!(bounds.lower, 12);
        assert_eq!(bounds.upper, 12);
    }

    #[test]
    fn truncated_gate_caps_chain_rank() {
        // upper bound of Eq. 10 with one rank-deficient gate
        let dims = [2usize, 2, 2];
        let structure = all_pairs_structure(3);
        let mut rng = Rng::new(31);
        // ranks: gate dims are all 4; make the middle gate rank 2
        let c = circuit_with_gate_ranks(&dims, &structure, &[4, 2, 4], &mut rng).unwrap();
        let (granks, frank, bounds) = check_rank_representation(&c, 1e-7).unwrap();
        assert_eq!(granks[1], 2);
        // upper = min(d*R/d_a) = 8*2/4 = 4
        assert_eq!(bounds.upper, 4);
        assert!(frank as i64 <= bounds.upper);
        assert!(frank as i64 >= bounds.lower);
    }

    #[test]
    fn universality_small_matrices() {
        let mut rng = Rng::new(32);
        for m in [4usize, 8, 16] {
            let w = Tensor::randn(&[m, m], 1.0, &mut rng);
            assert!(universality_residual(&w).unwrap() < 1e-4);
        }
    }

    #[test]
    fn composition_openness_witness() {
        // M1, M2 in the single-CNOT-layer set; M1*M2 should NOT fit.
        let m1 = cnot_layer_member(0.3, 1.1, 2.0, 0.7);
        let m2 = cnot_layer_member(1.9, 0.2, 0.9, 2.5);
        let prod = m1.matmul(&m2).unwrap();
        // members fit themselves within grid resolution
        let self_fit = cnot_layer_fit_residual(&m1, 24);
        let prod_fit = cnot_layer_fit_residual(&prod, 24);
        assert!(self_fit < 0.35, "self fit {self_fit}");
        assert!(prod_fit > 3.0 * self_fit, "prod {prod_fit} vs self {self_fit}");
    }

    #[test]
    fn lora_products_stay_low_rank() {
        let (r1, rp) = lora_product_rank(3, 12, 33).unwrap();
        assert_eq!(r1, 3);
        assert!(rp <= 3);
    }

    #[test]
    fn cnot_unitary() {
        let c = cnot();
        let ct = c.t().unwrap();
        assert!(c.matmul(&ct).unwrap().max_abs_diff(&Tensor::eye(4)) < 1e-6);
    }
}
