//! Pure-rust QuanTA reference implementation.
//!
//! Mirrors `python/compile/kernels/ref.py` with no JAX dependency; used
//! to (a) property-test the paper's theorems (rank representation,
//! universality, composition openness) inside `cargo test`, (b) provide
//! an independent oracle for the HLO merge path, (c) compute the
//! paper's complexity formulas for reporting, and (d) — through the
//! gradient engine ([`grad`]) and the adapter wrapper ([`adapter`]) —
//! *train* QuanTA circuits natively on the host (see
//! `coordinator::host_trainer`), with no PJRT artifacts.

pub mod adapter;
pub mod circuit;
pub mod grad;
pub mod plan;
pub mod theorems;

pub use adapter::QuantaAdapter;
pub use circuit::{all_pairs_structure, Circuit, Gate};
pub use grad::{CircuitGrads, CircuitTape};
pub use plan::CircuitPlan;
pub use theorems::{rank_bounds, RankBounds};
