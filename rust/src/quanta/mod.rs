//! Pure-rust QuanTA reference implementation.
//!
//! Mirrors `python/compile/kernels/ref.py` with no JAX dependency; used
//! to (a) property-test the paper's theorems (rank representation,
//! universality, composition openness) inside `cargo test`, (b) provide
//! an independent oracle for the HLO merge path, and (c) compute the
//! paper's complexity formulas for reporting.

pub mod circuit;
pub mod plan;
pub mod theorems;

pub use circuit::{all_pairs_structure, Circuit, Gate};
pub use plan::CircuitPlan;
pub use theorems::{rank_bounds, RankBounds};
