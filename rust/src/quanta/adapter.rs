//! QuanTA adapter: a trainable circuit delta on a frozen base weight.
//!
//! The paper's fine-tuned weight is `W' = W + ΔW` with `ΔW` the
//! materialized circuit minus identity (Eq. 7–8); applied to an
//! activation this is
//!
//! ```text
//! y = W x + α · (circuit(x) − x)
//! ```
//!
//! so with identity-initialized gates the adapter starts as an exact
//! no-op on top of `W` (the QuanTA training init).  The circuit part
//! runs through the plan-cached engine without ever materializing
//! `ΔW`; [`QuantaAdapter::merge`] folds the trained delta into a dense
//! matrix once at the end — the paper's zero-inference-overhead claim.
//!
//! Gradients: `∂y/∂(circuit out) = α`, so the adapter backward scales
//! the upstream gradient by `α` and hands it to
//! [`CircuitPlan::backward`]; `W` is frozen by construction (no
//! gradient is ever computed for it).

use crate::compute::gemm;
use crate::quanta::circuit::Circuit;
use crate::quanta::grad::{CircuitGrads, CircuitTape};
use crate::quanta::plan::CircuitPlan;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// A frozen base weight plus a trainable QuanTA circuit delta.
///
/// The adapter owns a [`CircuitPlan`] built once at construction; the
/// only mutable path to the gates is [`QuantaAdapter::set_params`],
/// which refreshes the plan's gate-matrix snapshots in place
/// ([`CircuitPlan::refresh_gate_mats`]) — so per-optimizer-step
/// parameter writes cost a memcpy, never a rebuild of the
/// stride/rest-offset/gather tables.
#[derive(Clone, Debug)]
pub struct QuantaAdapter {
    /// Frozen base weight, `(d, d)` row-major.
    base: Tensor,
    /// Cached transpose of `base` (row-major batched apply is
    /// `X · Wᵀ`, so the transpose is the matmul operand).
    base_t: Tensor,
    /// Trainable circuit (private: mutating it outside `set_params`
    /// would desync the owned plan).
    circuit: Circuit,
    /// Execution plan kept in lock-step with `circuit`.
    plan: CircuitPlan,
    /// Delta scale `α` (paper's scaling hyper-parameter).
    pub alpha: f32,
}

impl QuantaAdapter {
    /// Wrap `base` with a circuit delta.  `base` must be square with
    /// side `circuit.total_dim()`.
    pub fn new(base: Tensor, circuit: Circuit, alpha: f32) -> Result<QuantaAdapter> {
        let d = circuit.total_dim();
        if base.shape != [d, d] {
            return Err(Error::Shape(format!(
                "adapter: base shape {:?}, want [{d}, {d}] from dims {:?}",
                base.shape,
                circuit.dims()
            )));
        }
        let base_t = base.t()?;
        let plan = CircuitPlan::new(&circuit)?;
        Ok(QuantaAdapter { base, base_t, circuit, plan, alpha })
    }

    /// Adapter with identity-initialized gates over `structure` — the
    /// training init: `apply_batch == base` exactly at step 0.
    pub fn identity_init(
        base: Tensor,
        dims: &[usize],
        structure: &[(usize, usize)],
        alpha: f32,
    ) -> Result<QuantaAdapter> {
        QuantaAdapter::new(base, Circuit::identity(dims, structure)?, alpha)
    }

    pub fn d(&self) -> usize {
        self.circuit.total_dim()
    }

    pub fn base(&self) -> &Tensor {
        &self.base
    }

    /// Read-only view of the trainable circuit (mutation goes through
    /// [`QuantaAdapter::set_params`], which keeps the plan in sync).
    pub fn circuit(&self) -> &Circuit {
        &self.circuit
    }

    /// Trainable parameter count (`Σ (d_m d_n)²`, paper §6).
    pub fn param_count(&self) -> usize {
        self.circuit.param_count()
    }

    /// Flatten the gate matrices into one parameter vector (gate 0
    /// row-major, then gate 1, …) — the optimizer layout, matching
    /// [`CircuitGrads::flat_gates`].
    pub fn params_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for g in self.circuit.gates() {
            out.extend_from_slice(&g.mat.data);
        }
        out
    }

    /// Write a flat parameter vector back into the gate matrices and
    /// refresh the owned plan's snapshots in place (memcpy cost, plus
    /// small-matrix recomposition where gates were fused — the plan's
    /// index tables are untouched).
    pub fn set_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.param_count() {
            return Err(Error::Shape(format!(
                "set_params: got {} values, adapter has {} parameters",
                flat.len(),
                self.param_count()
            )));
        }
        let mut off = 0;
        for g in self.circuit.gates_mut() {
            let n = g.mat.data.len();
            g.mat.data.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        self.plan.refresh_gate_mats(&self.circuit)
    }

    /// `y = W x + α (circuit(x) − x)` over a row-major `[batch, d]`
    /// panel: one pooled GEMM for the frozen base, then the circuit
    /// chain with the `α(· − x)` residual fused into the final gate's
    /// scatter ([`CircuitPlan::apply_batch_residual_into`]) — no
    /// materialized circuit output, no separate axpy pass.
    pub fn apply_batch(&self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        let mut y = vec![0.0f32; xs.len()];
        self.apply_batch_into(xs, batch, &mut y)?;
        Ok(y)
    }

    /// [`QuantaAdapter::apply_batch`] into a caller-owned buffer
    /// (overwritten, need not be pre-zeroed) — the serving decode
    /// scratch path.  Same base GEMM, same fused residual, same bits.
    pub fn apply_batch_into(&self, xs: &[f32], batch: usize, y: &mut [f32]) -> Result<()> {
        let d = self.d();
        if xs.len() != batch * d || y.len() != batch * d {
            return Err(Error::Shape(format!(
                "adapter apply: xs {} / out {} != batch {batch} * d {d}",
                xs.len(),
                y.len()
            )));
        }
        y.fill(0.0);
        gemm::gemm_into(xs, &self.base_t.data, y, d, d);
        self.plan.apply_batch_residual_into(xs, batch, self.alpha, y)
    }

    /// Forward pass that also records the circuit tape for
    /// [`QuantaAdapter::backward`] — same fused-residual single pass as
    /// [`QuantaAdapter::apply_batch`].
    pub fn forward_with_tape(&self, xs: &[f32], batch: usize) -> Result<(Vec<f32>, CircuitTape)> {
        let mut y = self.base_product(xs, batch)?;
        let tape =
            self.plan.apply_batch_with_tape_residual_into(xs, batch, self.alpha, &mut y)?;
        Ok((y, tape))
    }

    /// Gate gradients only, given `∂loss/∂y` — the training hot path.
    /// The base path and the `−α x` term carry no gate dependence, so
    /// this is the circuit backward of `α · grad_out` (whose transpose
    /// sweep is what chains gradients to earlier gates); the dense
    /// base-path input-gradient GEMM that optimizers discard is
    /// skipped.  Returns the flat optimizer layout
    /// ([`CircuitGrads::flat_gates`]: gate 0 row-major, then gate 1,
    /// …), matching [`QuantaAdapter::params_flat`]; see
    /// [`QuantaAdapter::backward`] for the full `∂loss/∂x`.
    pub fn backward_gates(
        &self,
        tape: &CircuitTape,
        grad_out: &[f32],
        batch: usize,
    ) -> Result<Vec<f32>> {
        Ok(self.circuit_backward(tape, grad_out, batch)?.flat_gates())
    }

    /// Full backward given `∂loss/∂y`: gate gradients plus the complete
    /// input gradient `∂loss/∂x = Wᵀ g + α (circuitᵀ g − g)` through
    /// all three forward terms.
    pub fn backward(
        &self,
        tape: &CircuitTape,
        grad_out: &[f32],
        batch: usize,
    ) -> Result<CircuitGrads> {
        let d = self.d();
        let mut grads = self.circuit_backward(tape, grad_out, batch)?;
        // ∂loss/∂x: Wᵀ g (base path: Y = X Wᵀ ⇒ dX = dY W) plus the
        // circuit-path input gradient minus the α·x passthrough.  The
        // borrowing GEMM multiplies straight out of `grad_out` — no
        // owned-Tensor wrap copy.
        let mut base_part = vec![0.0f32; batch * d];
        gemm::gemm_into(grad_out, &self.base.data, &mut base_part, d, d);
        for ((gi, &bp), &go) in grads.input.iter_mut().zip(&base_part).zip(grad_out) {
            *gi += bp - self.alpha * go;
        }
        Ok(grads)
    }

    /// Circuit-path backward of `α · grad_out`: gate gradients are
    /// final; `.input` holds only the circuit-path term `α circuitᵀ g`.
    fn circuit_backward(
        &self,
        tape: &CircuitTape,
        grad_out: &[f32],
        batch: usize,
    ) -> Result<CircuitGrads> {
        let d = self.d();
        if grad_out.len() != batch * d {
            return Err(Error::Shape(format!(
                "adapter backward: grad_out len {} != batch {batch} * d {d}",
                grad_out.len()
            )));
        }
        // the α factor is fused into the backward's initial gradient
        // copy — no separately allocated scaled panel
        self.plan.backward_scaled(tape, grad_out, self.alpha)
    }

    /// Fold the delta into a dense matrix: `W + α (full_matrix − I)`
    /// (paper Eq. 7 — the merged weight has zero inference overhead).
    pub fn merge(&self) -> Result<Tensor> {
        let d = self.d();
        let full = self.plan.full_matrix()?;
        let mut out = self.base.clone();
        for i in 0..d {
            for j in 0..d {
                let delta = full.data[i * d + j] - if i == j { 1.0 } else { 0.0 };
                out.data[i * d + j] += self.alpha * delta;
            }
        }
        Ok(out)
    }

    /// Frozen-base product `X · Wᵀ` (the row-major batched `W x`),
    /// multiplied straight out of the borrowed activation panel — the
    /// borrowing GEMM shares kernel and chunking with `Tensor::matmul`,
    /// so dropping the owned-Tensor wrap copy changes no bit.
    fn base_product(&self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        let d = self.d();
        if xs.len() != batch * d {
            return Err(Error::Shape(format!(
                "adapter apply: xs len {} != batch {batch} * d {d}",
                xs.len()
            )));
        }
        let mut y = vec![0.0f32; batch * d];
        gemm::gemm_into(xs, &self.base_t.data, &mut y, d, d);
        Ok(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quanta::circuit::all_pairs_structure;
    use crate::util::rng::Rng;

    fn mk_adapter(rng: &mut Rng, std: f32, alpha: f32) -> QuantaAdapter {
        let dims = [2usize, 3, 2];
        let structure = all_pairs_structure(3);
        let c = Circuit::random(&dims, &structure, std, rng).unwrap();
        let d = c.total_dim();
        let base = Tensor::randn(&[d, d], 1.0 / (d as f32).sqrt(), rng);
        QuantaAdapter::new(base, c, alpha).unwrap()
    }

    #[test]
    fn identity_init_is_exactly_base() {
        let mut rng = Rng::new(50);
        let dims = [2usize, 2, 3];
        let d = 12;
        let base = Tensor::randn(&[d, d], 0.3, &mut rng);
        let a =
            QuantaAdapter::identity_init(base.clone(), &dims, &all_pairs_structure(3), 0.7)
                .unwrap();
        let mut xs = vec![0.0f32; 4 * d];
        rng.fill_normal(&mut xs, 1.0);
        let y = a.apply_batch(&xs, 4).unwrap();
        let x_t = Tensor::from_vec(&[4, d], xs).unwrap();
        let want = x_t.matmul(&base.t().unwrap()).unwrap();
        for (got, want) in y.iter().zip(&want.data) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn merge_matches_apply() {
        let mut rng = Rng::new(51);
        let a = mk_adapter(&mut rng, 0.2, 0.6);
        let d = a.d();
        let merged = a.merge().unwrap();
        let mut xs = vec![0.0f32; 3 * d];
        rng.fill_normal(&mut xs, 1.0);
        let y = a.apply_batch(&xs, 3).unwrap();
        for b in 0..3 {
            let want = merged.matvec(&xs[b * d..(b + 1) * d]).unwrap();
            for (i, (got, want)) in y[b * d..(b + 1) * d].iter().zip(&want).enumerate() {
                assert!((got - want).abs() < 1e-5, "vector {b} elem {i}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn params_roundtrip_and_invalidate() {
        let mut rng = Rng::new(52);
        let mut a = mk_adapter(&mut rng, 0.3, 1.0);
        let p = a.params_flat();
        assert_eq!(p.len(), a.param_count());
        let d = a.d();
        let mut xs = vec![0.0f32; 2 * d];
        rng.fill_normal(&mut xs, 1.0);
        let y0 = a.apply_batch(&xs, 2).unwrap();
        // perturb one parameter; output must change (cache invalidated)
        let mut p2 = p.clone();
        p2[0] += 0.5;
        a.set_params(&p2).unwrap();
        let y1 = a.apply_batch(&xs, 2).unwrap();
        assert!(y0.iter().zip(&y1).any(|(a, b)| (a - b).abs() > 1e-6));
        // restore; output must match the original exactly
        a.set_params(&p).unwrap();
        assert_eq!(a.apply_batch(&xs, 2).unwrap(), y0);
    }

    #[test]
    fn forward_with_tape_matches_apply() {
        let mut rng = Rng::new(53);
        let a = mk_adapter(&mut rng, 0.25, 0.9);
        let d = a.d();
        let mut xs = vec![0.0f32; 5 * d];
        rng.fill_normal(&mut xs, 1.0);
        let y = a.apply_batch(&xs, 5).unwrap();
        let (yt, tape) = a.forward_with_tape(&xs, 5).unwrap();
        assert_eq!(y, yt);
        assert_eq!(tape.inputs.len(), a.circuit().gates().len());
    }

    #[test]
    fn shape_errors() {
        let mut rng = Rng::new(54);
        let c = Circuit::random(&[2, 2], &[(0, 1)], 0.1, &mut rng).unwrap();
        let bad = Tensor::zeros(&[3, 3]);
        assert!(QuantaAdapter::new(bad, c.clone(), 1.0).is_err());
        let a = QuantaAdapter::new(Tensor::eye(4), c, 1.0).unwrap();
        assert!(a.apply_batch(&[0.0; 7], 2).is_err());
        assert!(a.set_params(&[0.0; 3]).is_err());
    }
}
