//! Analytic backward pass for the plan-cached circuit engine.
//!
//! The chain `h_L = T_L(… T_1(h_0) …)` (paper Eq. 5) is linear in the
//! hidden state and linear in each gate matrix individually, so both
//! gradients have closed forms that reuse the forward plan's machinery:
//!
//! * **input gradient** — each gate application is `out = A · in` on the
//!   gathered `(d_m·d_n) × (rest·batch)` panels, so
//!   `∂loss/∂in = Aᵀ · ∂loss/∂out`: the *transpose-gate trick* (Eq. 4 is
//!   symmetric in the gate axes), run through the identical blocked
//!   gather → GEMM → scatter pipeline with `Aᵀ`, gates visited in
//!   reverse order.  No new index machinery: the same rest-offset and
//!   gather tables drive both directions.
//! * **gate gradient** — on the same panels,
//!   `∂loss/∂A = (∂loss/∂out) · inᵀ`, an outer-product GEMM of the
//!   gathered upstream-gradient panel against the gathered *forward
//!   input* panel of that gate, accumulated over all `(rest, vector)`
//!   columns.
//!
//! The chain runs over the plan's **fused** gates: the tape records one
//! `[batch, d]` snapshot per *fused* gate (fewer, wider panels than the
//! per-original-gate PR 2 tape), the reverse sweep accumulates `∂F` per
//! fused gate, and [`GatePlan::unfuse_grads`] distributes
//! `∂A_i = L_iᵀ ∂F R_iᵀ` (restricted to the identity-embedded
//! positions) back onto the original gates — so [`CircuitGrads::gates`]
//! stays indexed by *original* gate, matching the optimizer layout.
//!
//! Parallelism goes through the compute pool with **problem-shaped
//! chunks** (`CircuitPlan::chunking`, shared with the forward): input
//! gradients are per-vector and chunk-independent, and per-chunk gate
//! gradients reduce in ascending chunk order — since chunk boundaries
//! no longer depend on the worker count, all gradients are bitwise
//! identical for any `QFT_THREADS` (PR 2 only guaranteed this for a
//! fixed thread count).
//!
//! **Gate sharding** (this PR): the bulk path keeps one private
//! `Σ_α dmn²` accumulator block per chunk — all gates, all chunks,
//! live at once.  For circuits with wide (fused) gates that footprint
//! is the training-memory ceiling (`n_chunks · Σ dmn²` floats; at
//! d = 4096 all-pairs, 8 MB per gate per 32-vector batch).  When a
//! gate's `∂F` accumulator exceeds the shard threshold
//! ([`grad_shard_threshold`]: `QFT_GRAD_SHARD` env, default derived
//! from the plan shape `dmn·rest = d`, floored at [`GRAD_SHARD_MIN`]),
//! the backward switches to a **gate-major sweep**: per fused gate
//! (last to first), workers claim `(gate, column-block)` shards —
//! the same fixed vector chunks the bulk path uses — accumulate
//! worker-local `∂F` partials, and the submitter reduces them in
//! ascending shard order before a second region applies the
//! transpose-gate transform.  Only **one** gate's partials are alive
//! at a time, so arbitrarily wide gates train at full parallelism;
//! and because shard boundaries and reduction order are identical to
//! the bulk path's chunk model, sharded and unsharded backward are
//! **bitwise equal**, and both remain `QFT_THREADS`-invariant
//! (`rust/tests/model_props.rs` pins both).

use crate::compute::pool;
use crate::quanta::plan::{CircuitPlan, GatePlan, Scratch, BLOCK_COLS};
use crate::util::error::{Error, Result};

/// Floor of the derived gate-shard threshold: gates whose `∂F`
/// accumulator is at most this many entries never shard (the extra
/// per-gate region dispatch would cost more than the memory saved).
pub const GRAD_SHARD_MIN: usize = 4096;

/// Accumulator-entry threshold above which a fused gate's `∂F`
/// accumulation is sharded gate-major (see module docs).  `QFT_GRAD_SHARD`
/// overrides (`0` disables sharding); the default derives from the plan
/// shape: `dmn·rest = d` — a gate shards once its accumulator outgrows
/// one hidden vector — floored at [`GRAD_SHARD_MIN`].
pub fn grad_shard_threshold(d: usize) -> usize {
    match std::env::var("QFT_GRAD_SHARD").ok().and_then(|s| s.parse::<usize>().ok()) {
        Some(0) => usize::MAX,
        Some(v) => v,
        None => d.max(GRAD_SHARD_MIN),
    }
}

/// Per-gate forward activations recorded by
/// [`CircuitPlan::apply_batch_with_tape`]: `inputs[α]` is the hidden
/// panel *entering* fused gate `α`, row-major `[batch, d]` (so
/// `inputs[0]` is the original input panel).
#[derive(Clone, Debug)]
pub struct CircuitTape {
    pub batch: usize,
    pub inputs: Vec<Vec<f32>>,
}

/// Gradients returned by [`CircuitPlan::backward`].
#[derive(Clone, Debug)]
pub struct CircuitGrads {
    /// `∂loss/∂A_α` per **original** circuit gate, `(d_m·d_n, d_m·d_n)`
    /// row-major — the same layout as `Gate::mat` (fused-gate gradients
    /// are unfused before they land here).
    pub gates: Vec<Vec<f32>>,
    /// `∂loss/∂xs`, row-major `[batch, d]`.
    pub input: Vec<f32>,
}

impl CircuitGrads {
    /// Total number of gate-gradient entries (the trainable parameter
    /// count of the circuit).
    pub fn param_count(&self) -> usize {
        self.gates.iter().map(|g| g.len()).sum()
    }

    /// Flatten the per-gate gradients into one parameter-ordered vector
    /// (gate 0 row-major, then gate 1, …) — the layout optimizers use.
    pub fn flat_gates(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for g in &self.gates {
            out.extend_from_slice(g);
        }
        out
    }
}

impl CircuitPlan {
    /// Forward pass that records the per-fused-gate input panels needed
    /// by [`CircuitPlan::backward`].  Identical arithmetic to
    /// [`CircuitPlan::apply_batch`] (same blocked GEMMs, same chunking),
    /// plus one `[batch, d]` copy per fused gate into the tape.
    pub fn apply_batch_with_tape(
        &self,
        xs: &[f32],
        batch: usize,
    ) -> Result<(Vec<f32>, CircuitTape)> {
        if xs.len() != batch * self.d {
            return Err(Error::Shape(format!(
                "apply_batch_with_tape: xs len {} != batch {batch} * d {}",
                xs.len(),
                self.d
            )));
        }
        let mut h = xs.to_vec();
        let mut tape: Vec<Vec<f32>> =
            self.gates.iter().map(|_| vec![0.0f32; batch * self.d]).collect();
        if self.d == 0 || batch == 0 || self.gates.is_empty() {
            return Ok((h, CircuitTape { batch, inputs: tape }));
        }
        let (chunk_vecs, n_chunks) = self.chunking(batch);
        if n_chunks <= 1 {
            self.with_scratch(|scratch| {
                for (g, dst) in self.gates.iter().zip(tape.iter_mut()) {
                    dst.copy_from_slice(&h);
                    self.apply_gate_chunk(g, &mut h, batch, scratch);
                }
            });
        } else {
            let chunk_len = chunk_vecs * self.d;
            let h_chunks = pool::DisjointChunks::new(&mut h, chunk_len);
            let tape_chunks: Vec<pool::DisjointChunks<f32>> =
                tape.iter_mut().map(|t| pool::DisjointChunks::new(t, chunk_len)).collect();
            pool::run(n_chunks, |i| {
                // SAFETY: each chunk index is claimed exactly once, and
                // the per-gate tape chunks are disjoint the same way.
                let chunk = unsafe { h_chunks.slice(i) };
                let cb = chunk.len() / self.d;
                self.with_scratch(|scratch| {
                    for (g, t) in self.gates.iter().zip(&tape_chunks) {
                        let dst = unsafe { t.slice(i) };
                        dst.copy_from_slice(chunk);
                        self.apply_gate_chunk(g, chunk, cb, scratch);
                    }
                });
            });
        }
        Ok((h, CircuitTape { batch, inputs: tape }))
    }

    /// Tape forward with the adapter residual fused into the final
    /// gate's scatter: records the same tape as
    /// [`CircuitPlan::apply_batch_with_tape`], but instead of returning
    /// the circuit output it accumulates `alpha · (chain(xs) − xs)`
    /// into `out` (which typically holds the frozen-base product) — the
    /// training-forward twin of
    /// [`CircuitPlan::apply_batch_residual_into`].
    pub fn apply_batch_with_tape_residual_into(
        &self,
        xs: &[f32],
        batch: usize,
        alpha: f32,
        out: &mut [f32],
    ) -> Result<CircuitTape> {
        if xs.len() != batch * self.d || out.len() != batch * self.d {
            return Err(Error::Shape(format!(
                "apply_batch_with_tape_residual_into: xs {} / out {} != batch {batch} * d {}",
                xs.len(),
                out.len(),
                self.d
            )));
        }
        let mut tape: Vec<Vec<f32>> =
            self.gates.iter().map(|_| vec![0.0f32; batch * self.d]).collect();
        if self.d == 0 || batch == 0 || self.gates.is_empty() {
            return Ok(CircuitTape { batch, inputs: tape }); // identity: zero residual
        }
        let (chunk_vecs, n_chunks) = self.chunking(batch);
        if n_chunks <= 1 {
            self.with_scratch(|scratch| {
                self.tape_residual_chunk(xs, out, batch, alpha, &mut tape, 0, scratch)
            });
        } else {
            let chunk_len = chunk_vecs * self.d;
            let out_chunks = pool::DisjointChunks::new(out, chunk_len);
            let tape_chunks: Vec<pool::DisjointChunks<f32>> =
                tape.iter_mut().map(|t| pool::DisjointChunks::new(t, chunk_len)).collect();
            pool::run(n_chunks, |i| {
                // SAFETY: each chunk index is claimed exactly once.
                let o = unsafe { out_chunks.slice(i) };
                let x0 = i * chunk_len;
                let x = &xs[x0..x0 + o.len()];
                let cb = o.len() / self.d;
                let mut slots: Vec<&mut [f32]> =
                    tape_chunks.iter().map(|t| unsafe { t.slice(i) }).collect();
                self.with_scratch(|scratch| {
                    self.tape_residual_slots(x, o, cb, alpha, &mut slots, scratch)
                });
            });
        }
        Ok(CircuitTape { batch, inputs: tape })
    }

    /// Serial-path helper: tape into whole-panel slots.
    #[allow(clippy::too_many_arguments)]
    fn tape_residual_chunk(
        &self,
        x: &[f32],
        out: &mut [f32],
        cb: usize,
        alpha: f32,
        tape: &mut [Vec<f32>],
        off: usize,
        scratch: &mut Scratch,
    ) {
        let mut slots: Vec<&mut [f32]> =
            tape.iter_mut().map(|t| &mut t[off..off + x.len()]).collect();
        self.tape_residual_slots(x, out, cb, alpha, &mut slots, scratch);
    }

    /// One chunk of the residual tape forward: gates `0..L−1` run in
    /// place on a scratch hidden buffer (each gate's input snapshotted
    /// first), the final gate reads its taped input and scatters
    /// `α(val − x)` into `out` — the final circuit output is never
    /// materialized.
    fn tape_residual_slots(
        &self,
        x: &[f32],
        out: &mut [f32],
        cb: usize,
        alpha: f32,
        slots: &mut [&mut [f32]],
        scratch: &mut Scratch,
    ) {
        let last = self.gates.len() - 1;
        if last == 0 {
            slots[0].copy_from_slice(x);
            self.apply_gate_chunk_residual(&self.gates[0], x, x, out, cb, alpha, scratch);
            return;
        }
        let mut h = x.to_vec();
        for (gi, dst) in slots[..last].iter_mut().enumerate() {
            dst.copy_from_slice(&h);
            self.apply_gate_chunk(&self.gates[gi], &mut h, cb, scratch);
        }
        slots[last].copy_from_slice(&h);
        self.apply_gate_chunk_residual(&self.gates[last], &h, x, out, cb, alpha, scratch);
    }

    /// Backward pass: given `∂loss/∂output` over the taped panel, return
    /// `∂loss/∂A_α` for every original gate and `∂loss/∂input`.
    pub fn backward(&self, tape: &CircuitTape, grad_out: &[f32]) -> Result<CircuitGrads> {
        self.backward_scaled(tape, grad_out, 1.0)
    }

    /// Backward of `scale · grad_out` with the scaling fused into the
    /// initial gradient copy (the adapter uses this for its `α` factor
    /// — one pass instead of scale-then-copy).  Gates whose `∂F`
    /// accumulator exceeds [`grad_shard_threshold`] go through the
    /// gate-sharded sweep (bitwise-equal; see module docs).
    pub fn backward_scaled(
        &self,
        tape: &CircuitTape,
        grad_out: &[f32],
        scale: f32,
    ) -> Result<CircuitGrads> {
        self.backward_with_shard(tape, grad_out, scale, grad_shard_threshold(self.d))
    }

    /// [`CircuitPlan::backward_scaled`] with an explicit shard
    /// threshold (accumulator entries): `usize::MAX` forces the bulk
    /// all-gates-one-region path, `1` forces every gate through the
    /// sharded gate-major sweep.  Both produce bitwise-identical
    /// gradients — the explicit knob exists so tests and the
    /// `shard_sweep` bench can pin that equality and price the
    /// dispatch difference.  Panels that [`CircuitPlan::chunking`]
    /// leaves in a single chunk run the serial kernel regardless of
    /// the threshold (there is nothing to shard across one executor);
    /// coverage tests must pick shapes that actually fan out.
    pub fn backward_with_shard(
        &self,
        tape: &CircuitTape,
        grad_out: &[f32],
        scale: f32,
        shard_threshold: usize,
    ) -> Result<CircuitGrads> {
        let batch = tape.batch;
        if grad_out.len() != batch * self.d {
            return Err(Error::Shape(format!(
                "backward: grad_out len {} != batch {batch} * d {}",
                grad_out.len(),
                self.d
            )));
        }
        if tape.inputs.len() != self.gates.len() {
            return Err(Error::Shape(format!(
                "backward: tape has {} gate panels, plan has {} (fused) gates",
                tape.inputs.len(),
                self.gates.len()
            )));
        }
        for (a, t) in tape.inputs.iter().enumerate() {
            if t.len() != batch * self.d {
                return Err(Error::Shape(format!(
                    "backward: tape panel {a} len {} != batch {batch} * d {}",
                    t.len(),
                    self.d
                )));
            }
        }
        let mut g = if scale == 1.0 {
            grad_out.to_vec()
        } else {
            grad_out.iter().map(|v| v * scale).collect()
        };
        let mut gate_grads: Vec<Vec<f32>> = vec![Vec::new(); self.source_gate_count()];
        if self.d == 0 || batch == 0 || self.gates.is_empty() {
            for gp in &self.gates {
                for mem in gp.members.iter() {
                    gate_grads[mem.gate_idx] = vec![0.0f32; mem.dmn * mem.dmn];
                }
            }
            return Ok(CircuitGrads { gates: gate_grads, input: g });
        }
        // zero-init only the slots unfuse_grads accumulates into (`+=`,
        // multi-member gates); single-member slots are moved in
        // wholesale, so pre-filling them would be a wasted alloc+memset
        // per gate per step on the (dominant) unfused hot path
        for gp in &self.gates {
            if gp.members.len() > 1 {
                for mem in gp.members.iter() {
                    gate_grads[mem.gate_idx] = vec![0.0f32; mem.dmn * mem.dmn];
                }
            }
        }
        // per-fused-gate ∂F accumulators
        let mut fused_grads: Vec<Vec<f32>> =
            self.gates.iter().map(|gp| vec![0.0f32; gp.dmn * gp.dmn]).collect();
        let (chunk_vecs, n_chunks) = self.chunking(batch);
        if n_chunks <= 1 {
            self.with_grad_scratch(|scratch| {
                let tape_refs: Vec<&[f32]> = tape.inputs.iter().map(|t| t.as_slice()).collect();
                self.backward_chunk(&mut g, &tape_refs, batch, &mut fused_grads, scratch);
            });
        } else if self.gates.iter().all(|gp| gp.dmn * gp.dmn <= shard_threshold) {
            // Bulk path — vectors stay independent through the reverse
            // chain, so the input gradient uses the same fixed chunks as
            // the forward.  Fused-gate gradients sum over vectors: each
            // chunk owns a private accumulator (for every gate at once),
            // reduced afterwards in ascending chunk order — chunk
            // boundaries are problem-shaped, so the reduction (and every
            // output bit) is QFT_THREADS-invariant.
            let chunk_len = chunk_vecs * self.d;
            let mut partials: Vec<Vec<Vec<f32>>> = (0..n_chunks)
                .map(|_| self.gates.iter().map(|gp| vec![0.0f32; gp.dmn * gp.dmn]).collect())
                .collect();
            let g_chunks = pool::DisjointChunks::new(&mut g, chunk_len);
            let partial_slots = pool::DisjointChunks::new(&mut partials, 1);
            pool::run(n_chunks, |i| {
                // SAFETY: each chunk index is claimed exactly once.
                let chunk = unsafe { g_chunks.slice(i) };
                let slot = unsafe { partial_slots.slice(i) };
                let partial = &mut slot[0];
                let cb = chunk.len() / self.d;
                let tape_chunks: Vec<&[f32]> = tape
                    .inputs
                    .iter()
                    .map(|t| &t[i * chunk_len..i * chunk_len + chunk.len()])
                    .collect();
                self.with_grad_scratch(|scratch| {
                    self.backward_chunk(chunk, &tape_chunks, cb, partial, scratch)
                });
            });
            for partial in &partials {
                for (acc, p) in fused_grads.iter_mut().zip(partial) {
                    for (a, &v) in acc.iter_mut().zip(p) {
                        *a += v;
                    }
                }
            }
        } else {
            self.backward_sharded(
                &mut g,
                tape,
                chunk_vecs,
                n_chunks,
                shard_threshold,
                &mut fused_grads,
            );
        }
        // unfuse ∂F back onto the original gates (serial, deterministic)
        for (gp, dmat) in self.gates.iter().zip(fused_grads) {
            gp.unfuse_grads(dmat, &mut gate_grads);
        }
        Ok(CircuitGrads { gates: gate_grads, input: g })
    }

    /// Gate-major sharded reverse sweep (see module docs): per fused
    /// gate, last to first, accumulate `∂F` over `(gate, column-block)`
    /// shard claims — the same fixed vector chunks as the bulk path —
    /// then transform the upstream gradient in a second region.  Only
    /// one gate's worker-local partials are alive at a time; the
    /// reduction runs in ascending shard order, so every output bit
    /// matches the bulk path and is `QFT_THREADS`-invariant.
    fn backward_sharded(
        &self,
        g: &mut [f32],
        tape: &CircuitTape,
        chunk_vecs: usize,
        n_chunks: usize,
        shard_threshold: usize,
        fused_grads: &mut [Vec<f32>],
    ) {
        let chunk_len = chunk_vecs * self.d;
        for ai in (0..self.gates.len()).rev() {
            let gp = &self.gates[ai];
            let hin = &tape.inputs[ai];
            let mut partials: Vec<Vec<f32>> =
                (0..n_chunks).map(|_| vec![0.0f32; gp.dmn * gp.dmn]).collect();
            let partial_slots = pool::DisjointChunks::new(&mut partials, 1);
            if gp.dmn * gp.dmn > shard_threshold {
                // region A: ∂F shard claims; `g` is read-only here, so
                // shards share it (and the taped panel) immutably
                let g_ro: &[f32] = g;
                pool::run(n_chunks, |i| {
                    // SAFETY: each shard index is claimed exactly once.
                    let slot = unsafe { partial_slots.slice(i) };
                    let start = i * chunk_len;
                    let end = (start + chunk_len).min(g_ro.len());
                    let cb = (end - start) / self.d;
                    self.with_grad_scratch(|scratch| {
                        self.accumulate_gate_dmat_chunk(
                            gp,
                            &g_ro[start..end],
                            &hin[start..end],
                            cb,
                            &mut slot[0],
                            scratch,
                        )
                    });
                });
                // region B: transpose-gate transform, per-vector chunks
                // (chunk-independent, like the forward)
                let g_chunks = pool::DisjointChunks::new(&mut *g, chunk_len);
                pool::run(n_chunks, |i| {
                    // SAFETY: each chunk index is claimed exactly once.
                    let chunk = unsafe { g_chunks.slice(i) };
                    let cb = chunk.len() / self.d;
                    self.with_grad_scratch(|scratch| {
                        self.transform_gate_chunk(gp, chunk, cb, scratch)
                    });
                });
            } else {
                // narrow gate inside a sharded sweep: combined ∂F +
                // transform in one region — identical arithmetic to the
                // bulk path's per-chunk visit of this gate
                let g_chunks = pool::DisjointChunks::new(&mut *g, chunk_len);
                pool::run(n_chunks, |i| {
                    // SAFETY: each chunk index is claimed exactly once.
                    let chunk = unsafe { g_chunks.slice(i) };
                    let slot = unsafe { partial_slots.slice(i) };
                    let start = i * chunk_len;
                    let cb = chunk.len() / self.d;
                    self.with_grad_scratch(|scratch| {
                        self.backward_gate_chunk(
                            gp,
                            chunk,
                            &hin[start..start + chunk.len()],
                            cb,
                            &mut slot[0],
                            scratch,
                        )
                    });
                });
            }
            // fixed shard order: ascending chunk index — the same
            // reduction tree as the bulk path's per-gate sum
            for p in &partials {
                for (a, &v) in fused_grads[ai].iter_mut().zip(p) {
                    *a += v;
                }
            }
        }
    }

    /// Reverse sweep over one chunk of `cb` vectors: for fused gate `α`
    /// (last to first), accumulate `∂F_α` from the gathered
    /// upstream-gradient and forward-input panels, then transform the
    /// upstream gradient with `F_αᵀ` in place.
    fn backward_chunk(
        &self,
        g: &mut [f32],
        tape_chunks: &[&[f32]],
        cb: usize,
        fused_grads: &mut [Vec<f32>],
        scratch: &mut GradScratch,
    ) {
        for ai in (0..self.gates.len()).rev() {
            let gp = &self.gates[ai];
            self.backward_gate_chunk(gp, g, tape_chunks[ai], cb, &mut fused_grads[ai], scratch);
        }
    }

    /// One gate's backward over `cb` vectors, blocked like the forward:
    /// gather `gy` (upstream grad) and `gx` (taped forward input), then
    /// `∂F[i,p] += Σ_c gy[i,c]·gx[p,c]` (outer-product GEMM) and
    /// `g ← scatter(Fᵀ · gy)` (transpose-gate GEMM).
    fn backward_gate_chunk(
        &self,
        gp: &GatePlan,
        g: &mut [f32],
        hin: &[f32],
        cb: usize,
        dmat: &mut [f32],
        scratch: &mut GradScratch,
    ) {
        let dmn = gp.dmn;
        let ncols = cb * gp.rest.len();
        let bw = BLOCK_COLS;
        let mut c0 = 0;
        while c0 < ncols {
            let w = bw.min(ncols - c0);
            self.fill_bases(gp, c0, w, &mut scratch.bases);
            let bases = &scratch.bases[..w];
            // gather gy from the upstream gradient and gx from the
            // taped forward input (contiguous writes per gate row)
            for (k, &off) in gp.gather.iter().enumerate() {
                let gy_row = &mut scratch.gy[k * bw..k * bw + w];
                for (slot, &base) in gy_row.iter_mut().zip(bases) {
                    *slot = g[base + off];
                }
                let gx_row = &mut scratch.gx[k * bw..k * bw + w];
                for (slot, &base) in gx_row.iter_mut().zip(bases) {
                    *slot = hin[base + off];
                }
            }
            // ∂F += gy · gxᵀ over this block (i-p-c, c innermost)
            for i in 0..dmn {
                let gy_row = &scratch.gy[i * bw..i * bw + w];
                let drow = &mut dmat[i * dmn..(i + 1) * dmn];
                for (p, dv) in drow.iter_mut().enumerate() {
                    let gx_row = &scratch.gx[p * bw..p * bw + w];
                    let mut acc = 0.0f32;
                    for (a, b) in gy_row.iter().zip(gx_row) {
                        acc += a * b;
                    }
                    *dv += acc;
                }
            }
            // product = Fᵀ · gy: accumulate row i of F into every p
            // (i-p-c with c innermost so the panel sweep vectorizes)
            scratch.prod[..dmn * bw].fill(0.0);
            for i in 0..dmn {
                let gy_row = &scratch.gy[i * bw..i * bw + w];
                let arow = &gp.mat[i * dmn..(i + 1) * dmn];
                for (p, &a) in arow.iter().enumerate() {
                    let prow = &mut scratch.prod[p * bw..p * bw + w];
                    for (o, &x) in prow.iter_mut().zip(gy_row) {
                        *o += a * x;
                    }
                }
            }
            // scatter the transformed gradient back in place
            for (k, &off) in gp.gather.iter().enumerate() {
                let row = &scratch.prod[k * bw..k * bw + w];
                for (&val, &base) in row.iter().zip(bases) {
                    g[base + off] = val;
                }
            }
            c0 += w;
        }
    }

    /// The `∂F` half of [`CircuitPlan::backward_gate_chunk`]: gather
    /// `gy`/`gx` and accumulate the outer-product GEMM, leaving the
    /// upstream gradient untouched — the sharded sweep's region A.
    /// Block walk and accumulation order are identical to the combined
    /// kernel, so the split cannot change any bit.
    fn accumulate_gate_dmat_chunk(
        &self,
        gp: &GatePlan,
        g: &[f32],
        hin: &[f32],
        cb: usize,
        dmat: &mut [f32],
        scratch: &mut GradScratch,
    ) {
        let dmn = gp.dmn;
        let ncols = cb * gp.rest.len();
        let bw = BLOCK_COLS;
        let mut c0 = 0;
        while c0 < ncols {
            let w = bw.min(ncols - c0);
            self.fill_bases(gp, c0, w, &mut scratch.bases);
            let bases = &scratch.bases[..w];
            for (k, &off) in gp.gather.iter().enumerate() {
                let gy_row = &mut scratch.gy[k * bw..k * bw + w];
                for (slot, &base) in gy_row.iter_mut().zip(bases) {
                    *slot = g[base + off];
                }
                let gx_row = &mut scratch.gx[k * bw..k * bw + w];
                for (slot, &base) in gx_row.iter_mut().zip(bases) {
                    *slot = hin[base + off];
                }
            }
            for i in 0..dmn {
                let gy_row = &scratch.gy[i * bw..i * bw + w];
                let drow = &mut dmat[i * dmn..(i + 1) * dmn];
                for (p, dv) in drow.iter_mut().enumerate() {
                    let gx_row = &scratch.gx[p * bw..p * bw + w];
                    let mut acc = 0.0f32;
                    for (a, b) in gy_row.iter().zip(gx_row) {
                        acc += a * b;
                    }
                    *dv += acc;
                }
            }
            c0 += w;
        }
    }

    /// The transpose-gate half of [`CircuitPlan::backward_gate_chunk`]:
    /// `g ← scatter(Fᵀ · gather(g))` — the sharded sweep's region B.
    /// Reads the same (still untransformed) `gy` panels as region A:
    /// scatters only touch the gate's own column footprint, so the
    /// two-pass split sees exactly the values the combined kernel saw.
    fn transform_gate_chunk(
        &self,
        gp: &GatePlan,
        g: &mut [f32],
        cb: usize,
        scratch: &mut GradScratch,
    ) {
        let dmn = gp.dmn;
        let ncols = cb * gp.rest.len();
        let bw = BLOCK_COLS;
        let mut c0 = 0;
        while c0 < ncols {
            let w = bw.min(ncols - c0);
            self.fill_bases(gp, c0, w, &mut scratch.bases);
            let bases = &scratch.bases[..w];
            for (k, &off) in gp.gather.iter().enumerate() {
                let gy_row = &mut scratch.gy[k * bw..k * bw + w];
                for (slot, &base) in gy_row.iter_mut().zip(bases) {
                    *slot = g[base + off];
                }
            }
            scratch.prod[..dmn * bw].fill(0.0);
            for i in 0..dmn {
                let gy_row = &scratch.gy[i * bw..i * bw + w];
                let arow = &gp.mat[i * dmn..(i + 1) * dmn];
                for (p, &a) in arow.iter().enumerate() {
                    let prow = &mut scratch.prod[p * bw..p * bw + w];
                    for (o, &x) in prow.iter_mut().zip(gy_row) {
                        *o += a * x;
                    }
                }
            }
            for (k, &off) in gp.gather.iter().enumerate() {
                let row = &scratch.prod[k * bw..k * bw + w];
                for (&val, &base) in row.iter().zip(bases) {
                    g[base + off] = val;
                }
            }
            c0 += w;
        }
    }

    /// Run `f` with this thread's cached backward scratch, grown (never
    /// shrunk) to this plan's widest gate — the backward twin of
    /// [`CircuitPlan::with_scratch`].
    fn with_grad_scratch<R>(&self, f: impl FnOnce(&mut GradScratch) -> R) -> R {
        BWD_SCRATCH.with(|cell| {
            let mut s = cell.take().unwrap_or_else(GradScratch::empty);
            s.ensure(self.max_dmn);
            let r = f(&mut s);
            cell.set(Some(s));
            r
        })
    }
}

/// Per-worker backward buffers, sized for the plan's widest gate (same
/// no-allocation-in-the-gate-loop contract as the forward `Scratch`,
/// and the same thread-local grow-only reuse — no cross-chunk state:
/// every region read within a block is written first).
struct GradScratch {
    /// Gathered upstream-gradient panel, `(dmn, BLOCK_COLS)`.
    gy: Vec<f32>,
    /// Gathered forward-input panel, `(dmn, BLOCK_COLS)`.
    gx: Vec<f32>,
    /// `Fᵀ · gy` product panel, `(dmn, BLOCK_COLS)`.
    prod: Vec<f32>,
    bases: Vec<usize>,
}

impl GradScratch {
    fn empty() -> GradScratch {
        GradScratch {
            gy: Vec::new(),
            gx: Vec::new(),
            prod: Vec::new(),
            bases: vec![0; BLOCK_COLS],
        }
    }

    fn ensure(&mut self, max_dmn: usize) {
        let need = max_dmn * BLOCK_COLS;
        if self.gy.len() < need {
            self.gy.resize(need, 0.0);
            self.gx.resize(need, 0.0);
            self.prod.resize(need, 0.0);
        }
    }
}

thread_local! {
    /// Per-executor backward scratch (take/put-back like the forward's).
    static BWD_SCRATCH: std::cell::Cell<Option<GradScratch>> =
        const { std::cell::Cell::new(None) };
}

#[cfg(test)]
mod tests {
    use crate::quanta::circuit::{all_pairs_structure, Circuit};
    use crate::util::rng::Rng;

    /// Central finite difference of `loss(apply_batch(xs))` w.r.t. one
    /// gate entry, where `loss = Σ w ⊙ out` is linear in `out` *and* in
    /// the single perturbed entry — so a large step (`eps = 0.5`) has no
    /// truncation error and suppresses f32 rounding; the dot product
    /// accumulates in f64 for the same reason.
    fn fd_gate(c: &Circuit, xs: &[f32], batch: usize, w: &[f32], gi: usize, k: usize) -> f32 {
        let eps = 0.5f32;
        let loss = |c: &Circuit| -> f64 {
            c.plan()
                .unwrap()
                .apply_batch(xs, batch)
                .unwrap()
                .iter()
                .zip(w)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum()
        };
        let mut cp = c.clone();
        cp.gates_mut()[gi].mat.data[k] += eps;
        let mut cm = c.clone();
        cm.gates_mut()[gi].mat.data[k] -= eps;
        ((loss(&cp) - loss(&cm)) / (2.0 * eps as f64)) as f32
    }

    #[test]
    fn tape_forward_matches_plain_forward() {
        let mut rng = Rng::new(70);
        for dims in [vec![2usize, 3, 2], vec![4, 4], vec![2, 2, 2, 2]] {
            let c = Circuit::random(&dims, &all_pairs_structure(dims.len()), 0.4, &mut rng)
                .unwrap();
            let d = c.total_dim();
            let batch = 5;
            let mut xs = vec![0.0f32; batch * d];
            rng.fill_normal(&mut xs, 1.0);
            let plan = c.plan().unwrap();
            let y = plan.apply_batch(&xs, batch).unwrap();
            let (yt, tape) = plan.apply_batch_with_tape(&xs, batch).unwrap();
            assert_eq!(y, yt, "dims {dims:?}: taped forward diverged");
            // one tape panel per *fused* gate ([2,2,2,2] fuses)
            assert_eq!(tape.inputs.len(), plan.gates.len());
            assert_eq!(plan.source_gate_count(), c.gates().len());
            assert_eq!(tape.inputs[0], xs, "tape[0] must be the input panel");
        }
    }

    #[test]
    fn residual_tape_matches_plain_tape() {
        let mut rng = Rng::new(74);
        for dims in [vec![2usize, 3, 2], vec![3, 2], vec![2, 2, 2, 2]] {
            let c = Circuit::random(&dims, &all_pairs_structure(dims.len()), 0.3, &mut rng)
                .unwrap();
            let plan = c.plan().unwrap();
            let d = plan.d;
            let batch = 4;
            let alpha = 0.8f32;
            let mut xs = vec![0.0f32; batch * d];
            rng.fill_normal(&mut xs, 1.0);
            let mut base = vec![0.0f32; batch * d];
            rng.fill_normal(&mut base, 1.0);
            let (cx, tape) = plan.apply_batch_with_tape(&xs, batch).unwrap();
            let mut want = base.clone();
            for ((o, &cv), &xv) in want.iter_mut().zip(&cx).zip(&xs) {
                *o += alpha * (cv - xv);
            }
            let mut got = base.clone();
            let tape_r = plan
                .apply_batch_with_tape_residual_into(&xs, batch, alpha, &mut got)
                .unwrap();
            assert_eq!(got, want, "dims {dims:?}: residual tape output diverged");
            assert_eq!(tape_r.inputs, tape.inputs, "dims {dims:?}: tapes diverged");
        }
    }

    #[test]
    fn backward_gate_grads_match_finite_differences() {
        let mut rng = Rng::new(71);
        // [2,3,2] all-pairs stays unfused; the repeated pair fuses —
        // the unfuse path must reproduce per-original-gate FD grads.
        for (dims, structure) in [
            (vec![2usize, 3, 2], all_pairs_structure(3)),
            (vec![3usize, 2], vec![(0, 1), (0, 1)]),
        ] {
            let c = Circuit::random(&dims, &structure, 0.3, &mut rng).unwrap();
            let d = c.total_dim();
            let batch = 3;
            let mut xs = vec![0.0f32; batch * d];
            rng.fill_normal(&mut xs, 1.0);
            let mut w = vec![0.0f32; batch * d];
            rng.fill_normal(&mut w, 1.0);
            let plan = c.plan().unwrap();
            let (_, tape) = plan.apply_batch_with_tape(&xs, batch).unwrap();
            let grads = plan.backward(&tape, &w).unwrap();
            assert_eq!(grads.gates.len(), c.gates().len());
            for gi in 0..c.gates().len() {
                for k in 0..grads.gates[gi].len() {
                    let fd = fd_gate(&c, &xs, batch, &w, gi, k);
                    let an = grads.gates[gi][k];
                    let denom = fd.abs().max(an.abs()).max(1e-3);
                    assert!(
                        (fd - an).abs() / denom < 1e-3,
                        "dims {dims:?} gate {gi} entry {k}: analytic {an} vs fd {fd}"
                    );
                }
            }
        }
    }

    #[test]
    fn backward_input_grad_is_transpose_chain() {
        // loss = w·out, out = full_matrix · x per vector, so
        // ∂loss/∂x = full_matrixᵀ · w exactly.
        let mut rng = Rng::new(72);
        let dims = vec![2usize, 2, 3];
        let c = Circuit::random(&dims, &all_pairs_structure(3), 0.4, &mut rng).unwrap();
        let d = c.total_dim();
        let batch = 2;
        let mut xs = vec![0.0f32; batch * d];
        rng.fill_normal(&mut xs, 1.0);
        let mut w = vec![0.0f32; batch * d];
        rng.fill_normal(&mut w, 1.0);
        let plan = c.plan().unwrap();
        let (_, tape) = plan.apply_batch_with_tape(&xs, batch).unwrap();
        let grads = plan.backward(&tape, &w).unwrap();
        let full_t = plan.full_matrix().unwrap().t().unwrap();
        for b in 0..batch {
            let want = full_t.matvec(&w[b * d..(b + 1) * d]).unwrap();
            for (i, (got, want)) in grads.input[b * d..(b + 1) * d].iter().zip(&want).enumerate()
            {
                assert!(
                    (got - want).abs() < 1e-4,
                    "vector {b} element {i}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn backward_scaled_matches_prescaled_gradient() {
        let mut rng = Rng::new(75);
        let c = Circuit::random(&[2usize, 3, 2], &all_pairs_structure(3), 0.3, &mut rng)
            .unwrap();
        let plan = c.plan().unwrap();
        let d = plan.d;
        let batch = 3;
        let mut xs = vec![0.0f32; batch * d];
        rng.fill_normal(&mut xs, 1.0);
        let mut w = vec![0.0f32; batch * d];
        rng.fill_normal(&mut w, 1.0);
        let (_, tape) = plan.apply_batch_with_tape(&xs, batch).unwrap();
        let alpha = 0.7f32;
        let scaled: Vec<f32> = w.iter().map(|v| v * alpha).collect();
        let g1 = plan.backward(&tape, &scaled).unwrap();
        let g2 = plan.backward_scaled(&tape, &w, alpha).unwrap();
        assert_eq!(g1.input, g2.input);
        assert_eq!(g1.gates, g2.gates);
    }

    #[test]
    fn sharded_backward_matches_bulk_bitwise() {
        let mut rng = Rng::new(76);
        // [4,4,8] at batch 48 fans out to multiple pool chunks, so the
        // shard claims and the bulk chunks genuinely both run
        let c = Circuit::random(&[4usize, 4, 8], &all_pairs_structure(3), 0.3, &mut rng).unwrap();
        let plan = c.plan().unwrap();
        let d = plan.d;
        let batch = 48;
        let mut xs = vec![0.0f32; batch * d];
        rng.fill_normal(&mut xs, 1.0);
        let mut w = vec![0.0f32; batch * d];
        rng.fill_normal(&mut w, 1.0);
        // guard: a single-chunk panel would run the serial kernel on
        // both sides and the comparison below would be vacuous
        let (_, n_chunks) = plan.chunking(batch);
        assert!(n_chunks > 1, "shard test shape must fan out, got {n_chunks} chunk(s)");
        let (_, tape) = plan.apply_batch_with_tape(&xs, batch).unwrap();
        let bulk = plan.backward_with_shard(&tape, &w, 1.0, usize::MAX).unwrap();
        let sharded = plan.backward_with_shard(&tape, &w, 1.0, 1).unwrap();
        assert_eq!(bulk.gates, sharded.gates, "sharded gate grads diverged");
        assert_eq!(bulk.input, sharded.input, "sharded input grads diverged");
        // mixed sweep: only gates wider than 16·16 entries shard
        let mixed = plan.backward_with_shard(&tape, &w, 1.0, 16 * 16).unwrap();
        assert_eq!(bulk.gates, mixed.gates);
        assert_eq!(bulk.input, mixed.input);
        // the env-derived default threshold lands on the same bits
        let default = plan.backward(&tape, &w).unwrap();
        assert_eq!(bulk.gates, default.gates);
        assert_eq!(bulk.input, default.input);
    }

    #[test]
    fn backward_empty_chain_passes_gradient_through() {
        let c = Circuit::new(vec![2, 2], vec![]).unwrap();
        let plan = c.plan().unwrap();
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        let (y, tape) = plan.apply_batch_with_tape(&xs, 1).unwrap();
        assert_eq!(y.as_slice(), xs.as_slice());
        let g = [0.5f32, -1.0, 0.25, 2.0];
        let grads = plan.backward(&tape, &g).unwrap();
        assert!(grads.gates.is_empty());
        assert_eq!(grads.input.as_slice(), g.as_slice());
    }

    #[test]
    fn backward_shape_errors() {
        let mut rng = Rng::new(73);
        let c = Circuit::random(&[2, 3], &[(0, 1)], 0.3, &mut rng).unwrap();
        let plan = c.plan().unwrap();
        let xs = vec![0.0f32; 12];
        assert!(plan.apply_batch_with_tape(&xs, 3).is_err());
        let (_, tape) = plan.apply_batch_with_tape(&xs, 2).unwrap();
        assert!(plan.backward(&tape, &xs[..6]).is_err());
    }
}
