//! Analytic backward pass for the plan-cached circuit engine.
//!
//! The chain `h_L = T_L(… T_1(h_0) …)` (paper Eq. 5) is linear in the
//! hidden state and linear in each gate matrix individually, so both
//! gradients have closed forms that reuse the forward plan's machinery:
//!
//! * **input gradient** — each gate application is `out = A · in` on the
//!   gathered `(d_m·d_n) × (rest·batch)` panels, so
//!   `∂loss/∂in = Aᵀ · ∂loss/∂out`: the *transpose-gate trick* (Eq. 4 is
//!   symmetric in the gate axes), run through the identical blocked
//!   gather → GEMM → scatter pipeline with `Aᵀ`, gates visited in
//!   reverse order.  No new index machinery: the same rest-offset and
//!   gather tables drive both directions.
//! * **gate gradient** — on the same panels,
//!   `∂loss/∂A = (∂loss/∂out) · inᵀ`, an outer-product GEMM of the
//!   gathered upstream-gradient panel against the gathered *forward
//!   input* panel of that gate, accumulated over all `(rest, vector)`
//!   columns.
//!
//! The forward inputs are recorded by [`CircuitPlan::apply_batch_with_tape`]
//! into a [`CircuitTape`]: one `[batch, d]` snapshot of the hidden state
//! per gate (`N_T · batch · d` floats — the chain analog of activation
//! checkpointing at gate granularity).  [`CircuitPlan::backward`] then
//! sweeps gates in reverse with the same per-vector panel chunking as
//! the forward: input gradients are bitwise identical for any worker
//! count (per-vector arithmetic is chunk-independent); gate gradients
//! sum over vectors and are reduced in fixed chunk order, so they are
//! deterministic for a fixed worker count (`QFT_THREADS`).

use crate::quanta::plan::{CircuitPlan, GatePlan, BLOCK_COLS, PAR_MIN_FLOPS};
use crate::util::error::{Error, Result};

/// Per-gate forward activations recorded by
/// [`CircuitPlan::apply_batch_with_tape`]: `inputs[α]` is the hidden
/// panel *entering* gate `α`, row-major `[batch, d]` (so `inputs[0]` is
/// the original input panel).
#[derive(Clone, Debug)]
pub struct CircuitTape {
    pub batch: usize,
    pub inputs: Vec<Vec<f32>>,
}

/// Gradients returned by [`CircuitPlan::backward`].
#[derive(Clone, Debug)]
pub struct CircuitGrads {
    /// `∂loss/∂A_α` per gate, `(d_m·d_n, d_m·d_n)` row-major — the same
    /// layout as [`GatePlan::mat`].
    pub gates: Vec<Vec<f32>>,
    /// `∂loss/∂xs`, row-major `[batch, d]`.
    pub input: Vec<f32>,
}

impl CircuitGrads {
    /// Total number of gate-gradient entries (the trainable parameter
    /// count of the circuit).
    pub fn param_count(&self) -> usize {
        self.gates.iter().map(|g| g.len()).sum()
    }

    /// Flatten the per-gate gradients into one parameter-ordered vector
    /// (gate 0 row-major, then gate 1, …) — the layout optimizers use.
    pub fn flat_gates(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for g in &self.gates {
            out.extend_from_slice(g);
        }
        out
    }
}

impl CircuitPlan {
    /// Forward pass that records the per-gate input panels needed by
    /// [`CircuitPlan::backward`].  Identical arithmetic to
    /// [`CircuitPlan::apply_batch`] (same blocked GEMMs, same per-vector
    /// chunking), plus one `[batch, d]` copy per gate into the tape.
    pub fn apply_batch_with_tape(
        &self,
        xs: &[f32],
        batch: usize,
    ) -> Result<(Vec<f32>, CircuitTape)> {
        if xs.len() != batch * self.d {
            return Err(Error::Shape(format!(
                "apply_batch_with_tape: xs len {} != batch {batch} * d {}",
                xs.len(),
                self.d
            )));
        }
        let mut h = xs.to_vec();
        let mut tape: Vec<Vec<f32>> =
            self.gates.iter().map(|_| vec![0.0f32; batch * self.d]).collect();
        if self.d == 0 || batch == 0 || self.gates.is_empty() {
            return Ok((h, CircuitTape { batch, inputs: tape }));
        }
        let workers = self.grad_workers(batch);
        if workers <= 1 {
            let mut scratch = self.scratch();
            for (g, dst) in self.gates.iter().zip(tape.iter_mut()) {
                dst.copy_from_slice(&h);
                self.apply_gate_chunk(g, &mut h, batch, &mut scratch);
            }
        } else {
            let chunk_vecs = batch.div_ceil(workers);
            let chunk_len = chunk_vecs * self.d;
            std::thread::scope(|s| {
                let mut tape_chunks: Vec<_> =
                    tape.iter_mut().map(|t| t.chunks_mut(chunk_len)).collect();
                for chunk in h.chunks_mut(chunk_len) {
                    let mut slots: Vec<&mut [f32]> =
                        tape_chunks.iter_mut().map(|it| it.next().unwrap()).collect();
                    s.spawn(move || {
                        let cb = chunk.len() / self.d;
                        let mut scratch = self.scratch();
                        for (g, dst) in self.gates.iter().zip(slots.iter_mut()) {
                            dst.copy_from_slice(chunk);
                            self.apply_gate_chunk(g, chunk, cb, &mut scratch);
                        }
                    });
                }
            });
        }
        Ok((h, CircuitTape { batch, inputs: tape }))
    }

    /// Backward pass: given `∂loss/∂output` over the taped panel, return
    /// `∂loss/∂A_α` for every gate and `∂loss/∂input`.
    pub fn backward(&self, tape: &CircuitTape, grad_out: &[f32]) -> Result<CircuitGrads> {
        let batch = tape.batch;
        if grad_out.len() != batch * self.d {
            return Err(Error::Shape(format!(
                "backward: grad_out len {} != batch {batch} * d {}",
                grad_out.len(),
                self.d
            )));
        }
        if tape.inputs.len() != self.gates.len() {
            return Err(Error::Shape(format!(
                "backward: tape has {} gate panels, plan has {} gates",
                tape.inputs.len(),
                self.gates.len()
            )));
        }
        for (a, t) in tape.inputs.iter().enumerate() {
            if t.len() != batch * self.d {
                return Err(Error::Shape(format!(
                    "backward: tape panel {a} len {} != batch {batch} * d {}",
                    t.len(),
                    self.d
                )));
            }
        }
        let mut g = grad_out.to_vec();
        let mut gate_grads: Vec<Vec<f32>> =
            self.gates.iter().map(|gp| vec![0.0f32; gp.dmn * gp.dmn]).collect();
        if self.d == 0 || batch == 0 || self.gates.is_empty() {
            return Ok(CircuitGrads { gates: gate_grads, input: g });
        }
        let workers = self.grad_workers(batch);
        if workers <= 1 {
            let mut scratch = GradScratch::new(self);
            let tape_refs: Vec<&[f32]> = tape.inputs.iter().map(|t| t.as_slice()).collect();
            self.backward_chunk(&mut g, &tape_refs, batch, &mut gate_grads, &mut scratch);
            return Ok(CircuitGrads { gates: gate_grads, input: g });
        }
        // Vectors stay independent through the reverse chain, so the
        // input gradient uses the same per-vector chunking as the
        // forward.  Gate gradients sum over vectors: each worker
        // accumulates into a private buffer, reduced afterwards in
        // chunk order (deterministic for a fixed worker count).
        let chunk_vecs = batch.div_ceil(workers);
        let chunk_len = chunk_vecs * self.d;
        let n_chunks = g.len().div_ceil(chunk_len);
        let mut partials: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n_chunks);
        for _ in 0..n_chunks {
            partials.push(self.gates.iter().map(|gp| vec![0.0f32; gp.dmn * gp.dmn]).collect());
        }
        std::thread::scope(|s| {
            for ((ci, chunk), partial) in
                g.chunks_mut(chunk_len).enumerate().zip(partials.iter_mut())
            {
                let tape_chunks: Vec<&[f32]> = tape
                    .inputs
                    .iter()
                    .map(|t| &t[ci * chunk_len..(ci * chunk_len + chunk.len())])
                    .collect();
                s.spawn(move || {
                    let cb = chunk.len() / self.d;
                    let mut scratch = GradScratch::new(self);
                    self.backward_chunk(chunk, &tape_chunks, cb, partial, &mut scratch);
                });
            }
        });
        for partial in &partials {
            for (acc, p) in gate_grads.iter_mut().zip(partial) {
                for (a, &v) in acc.iter_mut().zip(p) {
                    *a += v;
                }
            }
        }
        Ok(CircuitGrads { gates: gate_grads, input: g })
    }

    /// Worker count shared by the tape forward and the backward sweep
    /// (the backward does ~2× the forward GEMM work per gate, but the
    /// same cutoff keeps fwd/bwd chunking — and input-grad bit
    /// patterns — aligned).
    fn grad_workers(&self, batch: usize) -> usize {
        if batch * self.apply_flops() < PAR_MIN_FLOPS {
            1
        } else {
            crate::tensor::num_threads(batch)
        }
    }

    /// Reverse sweep over one chunk of `cb` vectors: for gate `α` (last
    /// to first), accumulate `∂A_α` from the gathered upstream-gradient
    /// and forward-input panels, then transform the upstream gradient
    /// with `A_αᵀ` in place.
    fn backward_chunk(
        &self,
        g: &mut [f32],
        tape_chunks: &[&[f32]],
        cb: usize,
        gate_grads: &mut [Vec<f32>],
        scratch: &mut GradScratch,
    ) {
        for ai in (0..self.gates.len()).rev() {
            let gp = &self.gates[ai];
            self.backward_gate_chunk(gp, g, tape_chunks[ai], cb, &mut gate_grads[ai], scratch);
        }
    }

    /// One gate's backward over `cb` vectors, blocked like the forward:
    /// gather `gy` (upstream grad) and `gx` (taped forward input), then
    /// `∂A[i,p] += Σ_c gy[i,c]·gx[p,c]` (outer-product GEMM) and
    /// `g ← scatter(Aᵀ · gy)` (transpose-gate GEMM).
    fn backward_gate_chunk(
        &self,
        gp: &GatePlan,
        g: &mut [f32],
        hin: &[f32],
        cb: usize,
        dmat: &mut [f32],
        scratch: &mut GradScratch,
    ) {
        let d = self.d;
        let dmn = gp.dmn;
        let rest_len = gp.rest.len();
        let ncols = cb * rest_len;
        let bw = BLOCK_COLS;
        let mut c0 = 0;
        while c0 < ncols {
            let w = bw.min(ncols - c0);
            for ci in 0..w {
                let col = c0 + ci;
                let b = col / rest_len;
                let r = col - b * rest_len;
                scratch.bases[ci] = b * d + gp.rest[r];
            }
            let bases = &scratch.bases[..w];
            // gather gy from the upstream gradient and gx from the
            // taped forward input (contiguous writes per gate row)
            for (k, &off) in gp.gather.iter().enumerate() {
                let gy_row = &mut scratch.gy[k * bw..k * bw + w];
                for (slot, &base) in gy_row.iter_mut().zip(bases) {
                    *slot = g[base + off];
                }
                let gx_row = &mut scratch.gx[k * bw..k * bw + w];
                for (slot, &base) in gx_row.iter_mut().zip(bases) {
                    *slot = hin[base + off];
                }
            }
            // ∂A += gy · gxᵀ over this block (i-p-c, c innermost)
            for i in 0..dmn {
                let gy_row = &scratch.gy[i * bw..i * bw + w];
                let drow = &mut dmat[i * dmn..(i + 1) * dmn];
                for (p, dv) in drow.iter_mut().enumerate() {
                    let gx_row = &scratch.gx[p * bw..p * bw + w];
                    let mut acc = 0.0f32;
                    for (a, b) in gy_row.iter().zip(gx_row) {
                        acc += a * b;
                    }
                    *dv += acc;
                }
            }
            // product = Aᵀ · gy: accumulate row i of A into every p
            // (i-p-c with c innermost so the panel sweep vectorizes)
            scratch.prod[..dmn * bw].fill(0.0);
            for i in 0..dmn {
                let gy_row = &scratch.gy[i * bw..i * bw + w];
                let arow = &gp.mat[i * dmn..(i + 1) * dmn];
                for (p, &a) in arow.iter().enumerate() {
                    let prow = &mut scratch.prod[p * bw..p * bw + w];
                    for (o, &x) in prow.iter_mut().zip(gy_row) {
                        *o += a * x;
                    }
                }
            }
            // scatter the transformed gradient back in place
            for (k, &off) in gp.gather.iter().enumerate() {
                let row = &scratch.prod[k * bw..k * bw + w];
                for (&val, &base) in row.iter().zip(bases) {
                    g[base + off] = val;
                }
            }
            c0 += w;
        }
    }
}

/// Per-worker backward buffers, sized for the plan's widest gate (same
/// no-allocation-in-the-gate-loop contract as the forward `Scratch`).
struct GradScratch {
    /// Gathered upstream-gradient panel, `(dmn, BLOCK_COLS)`.
    gy: Vec<f32>,
    /// Gathered forward-input panel, `(dmn, BLOCK_COLS)`.
    gx: Vec<f32>,
    /// `Aᵀ · gy` product panel, `(dmn, BLOCK_COLS)`.
    prod: Vec<f32>,
    bases: Vec<usize>,
}

impl GradScratch {
    fn new(plan: &CircuitPlan) -> GradScratch {
        GradScratch {
            gy: vec![0.0; plan.max_dmn * BLOCK_COLS],
            gx: vec![0.0; plan.max_dmn * BLOCK_COLS],
            prod: vec![0.0; plan.max_dmn * BLOCK_COLS],
            bases: vec![0; BLOCK_COLS],
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::quanta::circuit::{all_pairs_structure, Circuit};
    use crate::util::rng::Rng;

    /// Central finite difference of `loss(apply_batch(xs))` w.r.t. one
    /// gate entry, where `loss = Σ w ⊙ out` is linear in `out` *and* in
    /// the single perturbed entry — so a large step (`eps = 0.5`) has no
    /// truncation error and suppresses f32 rounding; the dot product
    /// accumulates in f64 for the same reason.
    fn fd_gate(c: &Circuit, xs: &[f32], batch: usize, w: &[f32], gi: usize, k: usize) -> f32 {
        let eps = 0.5f32;
        let loss = |c: &Circuit| -> f64 {
            c.plan()
                .unwrap()
                .apply_batch(xs, batch)
                .unwrap()
                .iter()
                .zip(w)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum()
        };
        let mut cp = c.clone();
        cp.gates_mut()[gi].mat.data[k] += eps;
        let mut cm = c.clone();
        cm.gates_mut()[gi].mat.data[k] -= eps;
        ((loss(&cp) - loss(&cm)) / (2.0 * eps as f64)) as f32
    }

    #[test]
    fn tape_forward_matches_plain_forward() {
        let mut rng = Rng::new(70);
        for dims in [vec![2usize, 3, 2], vec![4, 4], vec![2, 2, 2, 2]] {
            let c = Circuit::random(&dims, &all_pairs_structure(dims.len()), 0.4, &mut rng)
                .unwrap();
            let d = c.total_dim();
            let batch = 5;
            let mut xs = vec![0.0f32; batch * d];
            rng.fill_normal(&mut xs, 1.0);
            let plan = c.plan().unwrap();
            let y = plan.apply_batch(&xs, batch).unwrap();
            let (yt, tape) = plan.apply_batch_with_tape(&xs, batch).unwrap();
            assert_eq!(y, yt, "dims {dims:?}: taped forward diverged");
            assert_eq!(tape.inputs.len(), c.gates().len());
            assert_eq!(tape.inputs[0], xs, "tape[0] must be the input panel");
        }
    }

    #[test]
    fn backward_gate_grads_match_finite_differences() {
        let mut rng = Rng::new(71);
        let dims = vec![2usize, 3, 2];
        let c = Circuit::random(&dims, &all_pairs_structure(3), 0.3, &mut rng).unwrap();
        let d = c.total_dim();
        let batch = 3;
        let mut xs = vec![0.0f32; batch * d];
        rng.fill_normal(&mut xs, 1.0);
        let mut w = vec![0.0f32; batch * d];
        rng.fill_normal(&mut w, 1.0);
        let plan = c.plan().unwrap();
        let (_, tape) = plan.apply_batch_with_tape(&xs, batch).unwrap();
        let grads = plan.backward(&tape, &w).unwrap();
        for gi in 0..c.gates().len() {
            for k in 0..grads.gates[gi].len() {
                let fd = fd_gate(&c, &xs, batch, &w, gi, k);
                let an = grads.gates[gi][k];
                let denom = fd.abs().max(an.abs()).max(1e-3);
                assert!(
                    (fd - an).abs() / denom < 1e-3,
                    "gate {gi} entry {k}: analytic {an} vs fd {fd}"
                );
            }
        }
    }

    #[test]
    fn backward_input_grad_is_transpose_chain() {
        // loss = w·out, out = full_matrix · x per vector, so
        // ∂loss/∂x = full_matrixᵀ · w exactly.
        let mut rng = Rng::new(72);
        let dims = vec![2usize, 2, 3];
        let c = Circuit::random(&dims, &all_pairs_structure(3), 0.4, &mut rng).unwrap();
        let d = c.total_dim();
        let batch = 2;
        let mut xs = vec![0.0f32; batch * d];
        rng.fill_normal(&mut xs, 1.0);
        let mut w = vec![0.0f32; batch * d];
        rng.fill_normal(&mut w, 1.0);
        let plan = c.plan().unwrap();
        let (_, tape) = plan.apply_batch_with_tape(&xs, batch).unwrap();
        let grads = plan.backward(&tape, &w).unwrap();
        let full_t = plan.full_matrix().unwrap().t().unwrap();
        for b in 0..batch {
            let want = full_t.matvec(&w[b * d..(b + 1) * d]).unwrap();
            for (i, (got, want)) in grads.input[b * d..(b + 1) * d].iter().zip(&want).enumerate()
            {
                assert!(
                    (got - want).abs() < 1e-4,
                    "vector {b} element {i}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn backward_empty_chain_passes_gradient_through() {
        let c = Circuit::new(vec![2, 2], vec![]).unwrap();
        let plan = c.plan().unwrap();
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        let (y, tape) = plan.apply_batch_with_tape(&xs, 1).unwrap();
        assert_eq!(y.as_slice(), xs.as_slice());
        let g = [0.5f32, -1.0, 0.25, 2.0];
        let grads = plan.backward(&tape, &g).unwrap();
        assert!(grads.gates.is_empty());
        assert_eq!(grads.input.as_slice(), g.as_slice());
    }

    #[test]
    fn backward_shape_errors() {
        let mut rng = Rng::new(73);
        let c = Circuit::random(&[2, 3], &[(0, 1)], 0.3, &mut rng).unwrap();
        let plan = c.plan().unwrap();
        let xs = vec![0.0f32; 12];
        assert!(plan.apply_batch_with_tape(&xs, 3).is_err());
        let (_, tape) = plan.apply_batch_with_tape(&xs, 2).unwrap();
        assert!(plan.backward(&tape, &xs[..6]).is_err());
    }
}
