//! Serving-subsystem properties (DESIGN.md §10).
//!
//! What is pinned, and how hard:
//!
//! * **Decode parity is bitwise, not a tolerance**: the KV-cache decode
//!   step shares the block's per-row kernels (`layer_norm`, `attn_row`,
//!   the borrowing GEMM, the circuit engine — all per-row
//!   batch-invariant by the chunking contract), so a streaming decode
//!   must equal the full-recompute forward **bit for bit** at every
//!   position, including positions past the training `seq`.
//! * **Merged serving** (`AdapterSet::merge_all()` folded to dense
//!   GEMMs — the paper's zero-inference-overhead claim) is pinned to
//!   the streaming adapter forward at `1e-5` **relative to the panel
//!   scale** (floored at 1: at d = 128 every element is a 128-term f32
//!   dot, so the raw difference scales with activation magnitude) per
//!   decoded position, α-residual fold included; against a merged
//!   *block* (identity circuits) it is again bitwise.
//! * **Scheduler invariance**: per-request outputs are independent of
//!   arrival order, `max_batch` packing, `QFT_THREADS`, and the
//!   dispatch mode — bitwise.
//! * **Per-request fault isolation** (DESIGN.md §11): a mixed batch of
//!   malformed, NaN-prompt, over-budget, deadline-exceeding, and
//!   healthy requests completes with structured per-request errors,
//!   and every healthy request's output is **bitwise identical** to
//!   serving the healthy subset alone — again across thread counts and
//!   arrival permutations.
//! * **Stats single-count**: every submitted request increments exactly
//!   one of `completed`/`failed`/`shed`, and the counters agree with
//!   the per-request results — across shed policies, page budgets,
//!   deadlines, and prefix-cache admission over the full mixed load.
//!
//! Everything lives in ONE `#[test]`: `QFT_THREADS` / `QFT_DISPATCH`
//! are process-global env state, so sweeping them from parallel test
//! threads would race, and every section here drives env-reading
//! kernels (same convention as `rust/tests/pool_props.rs`).

use quanta_ft::model::{BlockConfig, TransformerBlock};
use quanta_ft::serve::{
    BatchScheduler, ServeBlock, ServeConfig, ServeError, ServeRequest, ShedPolicy,
};
use quanta_ft::util::rng::Rng;

fn trained_block(
    seed: u64,
    dims: Vec<usize>,
    heads: usize,
    std: f32,
    alpha: f32,
) -> TransformerBlock {
    let mut rng = Rng::new(seed);
    let cfg = BlockConfig { alpha, ..BlockConfig::standard(dims, heads, 4) };
    let mut block = TransformerBlock::init(&cfg, &mut rng).unwrap();
    block.randomize_circuits(std, &mut rng).unwrap();
    block
}

/// Greedy full-recompute generation: score the whole prefix per step,
/// take the last row, feed it back — the quadratic serving baseline the
/// KV cache replaces.
fn greedy_recompute(block: &TransformerBlock, prompt: &[f32], n_gen: usize) -> Vec<f32> {
    let d = block.d();
    let mut seqv = prompt.to_vec();
    let mut out = Vec::with_capacity(n_gen * d);
    loop {
        let l = seqv.len() / d;
        let y = block.forward(&seqv, 1, l).unwrap();
        let last = &y[(l - 1) * d..l * d];
        out.extend_from_slice(last);
        if out.len() >= n_gen * d {
            return out;
        }
        seqv.extend_from_slice(last);
    }
}

/// Per-id generated panels from one scheduler run (every request is
/// expected to succeed).
fn run_scheduler(
    block: &ServeBlock,
    reqs: Vec<ServeRequest>,
    max_batch: usize,
) -> Vec<(u64, Vec<f32>)> {
    let sched = BatchScheduler::new(block.clone(), max_batch).unwrap();
    let (out, _) = sched.run(reqs).unwrap();
    out.into_iter()
        .map(|o| {
            let id = o.id;
            (id, o.result.unwrap_or_else(|e| panic!("request {id} failed: {e}")))
        })
        .collect()
}

#[test]
fn decode_parity_and_scheduler_invariance() {
    // ---- (a) teacher-forced decode parity, per position -------------
    // streaming decode ≡ full-recompute forward bitwise; merged decode
    // within 1e-5 of it (and bitwise against the merged block's own
    // full recompute).  seq = 9 exceeds the training seq (4): the
    // decode path must not care.
    for (dims, heads, alpha) in [(vec![2usize, 2], 2usize, 0.7f32), (vec![4, 4, 8], 4, 1.0)] {
        let block = trained_block(300, dims.clone(), heads, 0.25, alpha);
        let d = block.d();
        let seq = 9usize;
        let mut xs = vec![0.0f32; seq * d];
        Rng::new(301).fill_normal(&mut xs, 1.0);
        let streaming = ServeBlock::streaming(&block).decode_sequence(&xs, seq).unwrap();
        let merged = ServeBlock::merged(&block).unwrap().decode_sequence(&xs, seq).unwrap();
        let merged_block = block.merged().unwrap();
        // the 1e-5 merged-parity contract is relative to the panel
        // scale, floored at 1 (mirror-measured on these draws: 4.6e-5
        // raw at max |y| 67.7 → 6.8e-7 normalized for d = 128; 4.8e-7
        // raw for the tiny block)
        let scale = streaming.iter().fold(1.0f32, |m, v| m.max(v.abs()));
        for t in 0..seq {
            // full recompute over the length-(t+1) prefix
            let full = block.forward(&xs[..(t + 1) * d], 1, t + 1).unwrap();
            let want = &full[t * d..(t + 1) * d];
            assert_eq!(
                &streaming[t * d..(t + 1) * d],
                want,
                "dims {dims:?}: streaming decode differs from recompute at position {t}"
            );
            for (j, (a, b)) in merged[t * d..(t + 1) * d].iter().zip(want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-5 * scale,
                    "dims {dims:?}: merged decode vs streaming recompute at ({t},{j}): \
                     {a} vs {b} (panel scale {scale})"
                );
            }
            // merged decode ≡ merged block recompute, bitwise (identity
            // circuits add an exact-zero residual)
            let mfull = merged_block.forward(&xs[..(t + 1) * d], 1, t + 1).unwrap();
            assert_eq!(
                &merged[t * d..(t + 1) * d],
                &mfull[t * d..(t + 1) * d],
                "dims {dims:?}: merged decode differs from merged recompute at position {t}"
            );
        }
        // causal consistency of the baseline itself: row t of the full
        // panel equals the last row of the length-(t+1) prefix
        let panel = block.forward(&xs, 1, seq).unwrap();
        let prefix = block.forward(&xs[..5 * d], 1, 5).unwrap();
        assert_eq!(&panel[4 * d..5 * d], &prefix[4 * d..5 * d]);
    }

    // ---- (b) greedy autoregressive generation -----------------------
    // feedback decode ≡ feedback full recompute, bitwise, on both
    // deployments; merged-vs-streaming stays within 1e-5 over a short
    // feedback horizon (single-pass merge parity is ~5e-7; feedback
    // compounds it, so the horizon is kept short)
    let block = trained_block(310, vec![2, 3], 2, 0.2, 0.8);
    let d = block.d();
    let mut prompt = vec![0.0f32; 3 * d];
    Rng::new(311).fill_normal(&mut prompt, 1.0);
    let n_gen = 3;
    let req = ServeRequest { id: 0, prompt: prompt.clone(), n_gen };
    let stream_sb = ServeBlock::streaming(&block);
    let merged_sb = ServeBlock::merged(&block).unwrap();
    let g_stream = run_scheduler(&stream_sb, vec![req.clone()], 1).remove(0).1;
    let g_merged = run_scheduler(&merged_sb, vec![req], 1).remove(0).1;
    assert_eq!(
        g_stream,
        greedy_recompute(&block, &prompt, n_gen),
        "greedy streaming decode differs from greedy recompute"
    );
    assert_eq!(
        g_merged,
        greedy_recompute(&block.merged().unwrap(), &prompt, n_gen),
        "greedy merged decode differs from greedy merged recompute"
    );
    let gscale = g_stream.iter().fold(1.0f32, |m, v| m.max(v.abs()));
    for (i, (a, b)) in g_merged.iter().zip(&g_stream).enumerate() {
        assert!(
            (a - b).abs() < 1e-5 * gscale,
            "merged vs streaming generation at {i}: {a} vs {b} (scale {gscale})"
        );
    }

    // ---- (c) scheduler invariance: arrival order, packing, threads --
    // d = 128 with 16 concurrent requests fans the projection panels
    // out to multiple pool chunks, so the thread sweep is not vacuous.
    let big = trained_block(320, vec![4, 4, 8], 4, 0.2, 1.0);
    let sb = ServeBlock::merged(&big).unwrap();
    let d = big.d();
    let mut reqs = Vec::new();
    let mut rng = Rng::new(321);
    for id in 0..16u64 {
        let p_len = 1 + (id as usize % 4);
        let mut prompt = vec![0.0f32; p_len * d];
        rng.fill_normal(&mut prompt, 1.0);
        reqs.push(ServeRequest { id, prompt, n_gen: 2 + (id as usize % 3) });
    }
    std::env::set_var("QFT_THREADS", "1");
    let baseline = run_scheduler(&sb, reqs.clone(), 16);
    {
        // guard: the packed panel must actually split into >1 chunk
        let (_, n_chunks) = quanta_ft::compute::pool::chunks(16, d * d);
        assert!(n_chunks > 1, "invariance sweep is vacuously serial ({n_chunks} chunk)");
    }
    // arrival permutations and packing limits, fixed thread count
    let mut reversed = reqs.clone();
    reversed.reverse();
    let mut interleaved = reqs.clone();
    interleaved.sort_by_key(|r| (r.id % 2 == 0, r.id)); // odds first, then evens
    for (label, order) in [("reversed", reversed), ("interleaved", interleaved)] {
        for mb in [1usize, 5, 16] {
            let got = run_scheduler(&sb, order.clone(), mb);
            assert_eq!(baseline, got, "{label} arrival @ max_batch {mb} changed outputs");
        }
    }
    // thread counts and dispatch mode
    for threads in ["2", "8"] {
        std::env::set_var("QFT_THREADS", threads);
        let got = run_scheduler(&sb, reqs.clone(), 16);
        assert_eq!(baseline, got, "outputs differ at QFT_THREADS={threads}");
    }
    std::env::set_var("QFT_DISPATCH", "spawn");
    let spawned = run_scheduler(&sb, reqs.clone(), 16);
    std::env::remove_var("QFT_DISPATCH");
    std::env::remove_var("QFT_THREADS");
    assert_eq!(baseline, spawned, "spawn dispatch changed scheduler outputs");

    // streaming deployment under the same sweep (circuit-engine chunks)
    let ssb = ServeBlock::streaming(&big);
    std::env::set_var("QFT_THREADS", "1");
    let sbase = run_scheduler(&ssb, reqs.clone(), 16);
    std::env::set_var("QFT_THREADS", "8");
    let sgot = run_scheduler(&ssb, reqs, 16);
    std::env::remove_var("QFT_THREADS");
    assert_eq!(sbase, sgot, "streaming scheduler outputs differ across threads");

    // ---- (d) per-request fault isolation: mixed batch ---------------
    // malformed + NaN-prompt + over-budget + deadline-exceeding +
    // healthy requests in one batch: every healthy output must be
    // bitwise identical to serving the healthy subset alone, across
    // thread counts and arrival permutations (the §11 isolation
    // invariant), and every faulty request must carry its own error.
    let d = big.d();
    let mut rng = Rng::new(330);
    let mut healthy = Vec::new();
    for id in 0..6u64 {
        let p_len = 1 + (id as usize % 4);
        let mut prompt = vec![0.0f32; p_len * d];
        rng.fill_normal(&mut prompt, 1.0);
        // whole-prompt prefill ⇒ n_gen ≤ 4 resident steps: inside the
        // deadline
        healthy.push(ServeRequest { id, prompt, n_gen: 2 + (id as usize % 3) });
    }
    let mut faulty = Vec::new();
    faulty.push(ServeRequest { id: 200, prompt: vec![0.0; d + 1], n_gen: 1 });
    faulty.push(ServeRequest { id: 201, prompt: vec![], n_gen: 1 });
    faulty.push(ServeRequest { id: 202, prompt: vec![0.0; d], n_gen: 0 });
    let mut nan_prompt = vec![0.0f32; 2 * d];
    rng.fill_normal(&mut nan_prompt, 1.0);
    nan_prompt[d + 3] = f32::NAN;
    faulty.push(ServeRequest { id: 203, prompt: nan_prompt, n_gen: 2 });
    let mut slow = vec![0.0f32; 2 * d];
    rng.fill_normal(&mut slow, 1.0);
    // 1 + 20 − 1 = 20 resident steps > deadline 8 (tokens 22 ≤ budget)
    faulty.push(ServeRequest { id: 204, prompt: slow, n_gen: 20 });
    let mut fat = vec![0.0f32; 20 * d];
    rng.fill_normal(&mut fat, 1.0);
    // 20 + 12 = 32 tokens > budget 30
    faulty.push(ServeRequest { id: 205, prompt: fat, n_gen: 12 });
    let cfg = ServeConfig::default()
        .with_max_batch(5)
        .with_deadline(8)
        .with_token_budget(30);
    let sched = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
    std::env::set_var("QFT_THREADS", "1");
    let (healthy_only, honly_stats) = sched.run(healthy.clone()).unwrap();
    assert_eq!(honly_stats.completed, healthy.len(), "healthy subset must all complete");
    let mut mixed: Vec<ServeRequest> = healthy.iter().cloned().chain(faulty.clone()).collect();
    let mut orders = vec![mixed.clone()];
    mixed.reverse();
    orders.push(mixed.clone());
    mixed.sort_by_key(|r| (r.id % 2 == 0, r.id));
    orders.push(mixed);
    for threads in ["1", "2", "8"] {
        std::env::set_var("QFT_THREADS", threads);
        for (oi, order) in orders.iter().enumerate() {
            let (out, stats) = sched.run(order.clone()).unwrap();
            let tag = format!("threads {threads} order {oi}");
            assert_eq!(out.len(), 12, "{tag}");
            for (h, o) in healthy_only.iter().zip(&out) {
                assert_eq!(h.id, o.id, "{tag}");
                assert_eq!(
                    h.result, o.result,
                    "{tag}: healthy request {} not bitwise equal to healthy-only run",
                    h.id
                );
            }
            for o in &out[6..] {
                match o.id {
                    200 | 201 | 202 => {
                        assert!(
                            matches!(o.error(), Some(ServeError::Rejected(_))),
                            "{tag}: request {} got {:?}",
                            o.id,
                            o.result
                        );
                    }
                    203 => assert_eq!(
                        o.error(),
                        Some(&ServeError::NonFinitePrompt { at: d + 3 }),
                        "{tag}"
                    ),
                    204 => assert_eq!(
                        o.error(),
                        Some(&ServeError::DeadlineExceeded { limit: 8 }),
                        "{tag}"
                    ),
                    205 => assert_eq!(
                        o.error(),
                        Some(&ServeError::OverBudget { tokens: 32, budget: 30 }),
                        "{tag}"
                    ),
                    other => panic!("{tag}: unexpected id {other}"),
                }
            }
            assert_eq!(stats.completed, 6, "{tag}");
            assert_eq!(stats.failed, 6, "{tag}");
            assert_eq!(stats.shed, 0, "{tag}");
        }
    }
    std::env::remove_var("QFT_THREADS");

    // ---- (e) scratch reuse and prefill chunking are bitwise inert ---
    // the scheduler reuses ONE DecodeScratch (and one KV arena) across
    // every request, step, and run; a scheduler that has already
    // served a full mixed load must produce bits identical to a
    // freshly-built one, and any --prefill-chunk must match the
    // row-at-a-time schedule exactly
    // `sched` has executed 10 full mixed runs by now — its workspace
    // buffers and arena blob are thoroughly warm
    let (reused, _) = sched.run(healthy.clone()).unwrap();
    let reused: Vec<(u64, Vec<f32>)> =
        reused.into_iter().map(|o| (o.id, o.result.unwrap())).collect();
    let fresh = run_scheduler(&sb, healthy.clone(), 5);
    assert_eq!(reused, fresh, "reused workspace changed request bits");
    for chunk in [1usize, 3, 0] {
        let cfg = ServeConfig::default().with_max_batch(5).with_prefill_chunk(chunk);
        let chunked = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
        let (out, _) = chunked.run(healthy.clone()).unwrap();
        for (o, (id, want)) in out.iter().zip(&fresh) {
            assert_eq!(o.id, *id);
            assert_eq!(
                o.generated().unwrap(),
                &want[..],
                "prefill_chunk {chunk} changed request {id}"
            );
        }
    }

    // bounded intake queue: shedding is arrival-order-dependent by
    // design, so it is pinned at a fixed order — both policies keep
    // exactly `queue_cap` requests and the survivors' outputs are
    // still bitwise equal to serving them alone
    for (policy, kept) in [(ShedPolicy::RejectNew, [0u64, 1]), (ShedPolicy::DropOldest, [4u64, 5])]
    {
        let cfg = ServeConfig::default()
            .with_max_batch(1)
            .with_queue_cap(2)
            .with_shed_policy(policy);
        let bounded = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
        let (out, stats) = bounded.run(healthy.clone()).unwrap();
        assert_eq!(stats.shed, 4, "{policy:?}");
        assert_eq!(stats.completed, 2, "{policy:?}");
        for o in &out {
            if kept.contains(&o.id) {
                let solo = &healthy_only[o.id as usize];
                assert_eq!(o.result, solo.result, "{policy:?}: survivor {} perturbed", o.id);
            } else {
                assert_eq!(o.error(), Some(&ServeError::Shed), "{policy:?}: request {}", o.id);
            }
        }
    }

    // ---- (f) stats single-count invariant ---------------------------
    // one output and exactly one counter increment per submission, no
    // matter how a request leaves the system — completion, structured
    // quarantine (reject / NaN / deadline / budget / cache exhaustion),
    // or shedding — and no matter which admission path brought it in
    let mixed_all: Vec<ServeRequest> = healthy.iter().cloned().chain(faulty).collect();
    let base5 = ServeConfig::default().with_max_batch(5);
    let sweep = [
        ("baseline faults", base5.with_deadline(8).with_token_budget(30)),
        ("reject-new", base5.with_queue_cap(2).with_shed_policy(ShedPolicy::RejectNew)),
        (
            "drop-oldest",
            base5.with_deadline(8).with_queue_cap(3).with_shed_policy(ShedPolicy::DropOldest),
        ),
        ("tight pages", base5.with_page_tokens(1).with_kv_pages(6)),
        ("prefix cache", base5.with_prefix_cache(true).with_page_tokens(2).with_deadline(8)),
    ];
    for (label, cfg) in sweep {
        let s = BatchScheduler::with_config(sb.clone(), cfg).unwrap();
        let (out, stats) = s.run(mixed_all.clone()).unwrap();
        assert_eq!(out.len(), mixed_all.len(), "{label}: one output per submission");
        let ok = out.iter().filter(|o| o.result.is_ok()).count();
        let shed = out.iter().filter(|o| o.error() == Some(&ServeError::Shed)).count();
        let failed = out.len() - ok - shed;
        assert_eq!(
            (stats.completed, stats.failed, stats.shed),
            (ok, failed, shed),
            "{label}: counters disagree with per-request results"
        );
        assert_eq!(
            stats.completed + stats.failed + stats.shed,
            mixed_all.len(),
            "{label}: a submission was double-counted or dropped"
        );
    }
}
