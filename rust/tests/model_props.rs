//! Properties of the model subsystem (DESIGN.md §9): finite-difference
//! gradcheck through the full transformer block, gate-sharded vs bulk
//! backward bitwise equality, `merge_all()` parity at 1e-5, and
//! `QFT_THREADS` invariance of the block train loop.
//!
//! Everything env-dependent lives in ONE `#[test]`: `QFT_THREADS` /
//! `QFT_GRAD_SHARD` are process-global env state, so sweeping them from
//! parallel test threads would race (same convention as
//! `rust/tests/pool_props.rs`).  The layout test below touches no
//! kernels (and therefore no env reads), so it may run concurrently.

use quanta_ft::coordinator::host_trainer::{finetune_host, HostTrainConfig};
use quanta_ft::data::synth::{block_teacher_student, BlockSynthConfig};
use quanta_ft::model::{BlockConfig, TrainableModel, TransformerBlock};
use quanta_ft::util::rng::Rng;

/// Loss `Σ w ⊙ out` (f64 accumulation so finite differences of the f32
/// forward are dominated by forward rounding, not by the reduction).
fn weighted_loss(block: &TransformerBlock, xs: &[f32], n: usize, w: &[f32]) -> f64 {
    block
        .forward(xs, n, block.seq())
        .unwrap()
        .iter()
        .zip(w)
        .map(|(a, b)| (*a as f64) * (*b as f64))
        .sum()
}

fn tiny_trained_block(seed: u64, std: f32, alpha: f32) -> TransformerBlock {
    let mut rng = Rng::new(seed);
    let cfg = BlockConfig::standard(vec![2, 2], 2, 3).with_alpha(alpha);
    let mut block = TransformerBlock::init(&cfg, &mut rng).unwrap();
    block.randomize_circuits(std, &mut rng).unwrap();
    block
}

#[test]
fn flat_layout_is_stable_and_round_trips() {
    // no kernels, no env reads — safe to run next to the env sweep
    let block = tiny_trained_block(21, 0.2, 1.0);
    let set = block.adapters();
    assert_eq!(set.names(), vec!["wq", "wk", "wv", "wo"]);
    let per = set.adapter(0).param_count();
    for i in 0..set.len() {
        assert_eq!(set.span(i), (i * per, (i + 1) * per), "span {i} drifted");
    }
    let p = block.params_flat();
    assert_eq!(p.len(), set.param_count());
    let mut block2 = block.clone();
    block2.set_params(&p).unwrap();
    assert_eq!(block2.params_flat(), p, "params_flat/set_params round trip");
}

#[test]
fn adapter_set_layout_survives_any_insertion_order() {
    // Property test for the layout contract the multi-block stack will
    // lean on (ROADMAP): for ANY insertion order, name set, and mix of
    // adapter shapes, offsets are the prefix sums of the per-adapter
    // param counts in insertion order, params_flat/set_params/
    // flat_from_parts agree on those spans, and a write to one span
    // never leaks into another.  No pooled kernels — safe next to the
    // env sweep below.
    use quanta_ft::model::AdapterSet;
    use quanta_ft::quanta::circuit::{all_pairs_structure, Circuit};
    use quanta_ft::quanta::QuantaAdapter;
    use quanta_ft::tensor::Tensor;
    let mut rng = Rng::new(400);
    // three shapes with distinct param counts: 36, 48, and 64 floats
    let shapes: [&[usize]; 3] = [&[2, 3], &[2, 2, 2], &[4, 2]];
    for trial in 0..12 {
        let n = 1 + rng.below(5);
        let entries: Vec<(String, QuantaAdapter)> = (0..n)
            .map(|i| {
                let dims = shapes[rng.below(shapes.len())];
                let structure = all_pairs_structure(dims.len());
                let c = Circuit::random(dims, &structure, 0.3, &mut rng).unwrap();
                let d: usize = dims.iter().product();
                let base = Tensor::randn(&[d, d], 0.5, &mut rng);
                let a = QuantaAdapter::new(base, c, 0.9).unwrap();
                (format!("t{trial}-a{i}-{}", rng.below(1000)), a)
            })
            .collect();
        let mut set = AdapterSet::new(entries.clone()).unwrap();
        // offsets are prefix sums of insertion-order param counts
        let mut off = 0usize;
        for (i, (name, a)) in entries.iter().enumerate() {
            assert_eq!(set.span(i), (off, off + a.param_count()), "trial {trial} span {i}");
            assert_eq!(set.names()[i], name.as_str());
            off += a.param_count();
        }
        assert_eq!(set.param_count(), off);
        // params_flat / flat_from_parts / set_params agree on the spans
        let p = set.params_flat();
        assert_eq!(p.len(), off);
        let parts: Vec<Vec<f32>> = (0..n).map(|i| set.adapter(i).params_flat()).collect();
        assert_eq!(set.flat_from_parts(&parts).unwrap(), p, "trial {trial} parts disagree");
        set.set_params(&p).unwrap();
        assert_eq!(set.params_flat(), p, "trial {trial} round trip");
        // a write inside one randomly chosen span touches only it
        let j = rng.below(n);
        let (s, e) = set.span(j);
        let mut p2 = p.clone();
        p2[s] += 1.5;
        p2[e - 1] -= 0.5;
        set.set_params(&p2).unwrap();
        for i in 0..n {
            let (si, ei) = set.span(i);
            assert_eq!(
                set.adapter(i).params_flat(),
                &p2[si..ei],
                "trial {trial}: adapter {i} left its span after writing span {j}"
            );
        }
        // name-keyed lookup resolves to the same adapters
        for (i, (name, _)) in entries.iter().enumerate() {
            let by_name = set.get(name).unwrap().params_flat();
            assert_eq!(by_name, set.adapter(i).params_flat(), "trial {trial} name {name}");
        }
    }
    // duplicate names are rejected wherever the duplicate lands
    let dims = [2usize, 3];
    let c = Circuit::random(&dims, &all_pairs_structure(2), 0.2, &mut rng).unwrap();
    let a = QuantaAdapter::new(Tensor::eye(6), c, 1.0).unwrap();
    let dup = vec![
        ("x".to_string(), a.clone()),
        ("y".to_string(), a.clone()),
        ("x".to_string(), a),
    ];
    assert!(AdapterSet::new(dup).is_err());
}

#[test]
fn block_gradients_sharding_merge_and_thread_invariance() {
    // ---- (a) central-FD gradcheck through the full block ------------
    // attention softmax + layernorms + GELU MLP + all four adapters:
    // the analytic backward must match central finite differences of a
    // loss linear in the output.  f32 forward, f64 loss reduction;
    // eps = 1e-2 balances truncation against rounding.  The NumPy
    // mirror, on these exact draws, measures worst FD rel-err 2.2e-3
    // in f32 (forward rounding across the ± cancellation — the block
    // is nonlinear, so PR 2's exact-FD trick does not apply), 2.2e-7
    // in f64, and 2.5e-5 between the f32 analytic gradient and the
    // FD-certified f64 one — so the 2e-2 gate below has ~9x headroom
    // over the measurement noise, not over the gradient error.
    let block = tiny_trained_block(22, 0.3, 0.7);
    let n_seqs = 2;
    let mut rng = Rng::new(23);
    let mut xs = vec![0.0f32; n_seqs * block.io_len()];
    rng.fill_normal(&mut xs, 1.0);
    let mut w = vec![0.0f32; n_seqs * block.io_len()];
    rng.fill_normal(&mut w, 1.0);
    let (_, tape) = block.forward_with_tape(&xs, n_seqs).unwrap();
    let (flat, dx) = block.backward(&tape, &w, n_seqs).unwrap();
    assert_eq!(flat.len(), block.param_count());
    let eps = 1e-2f32;
    let p0 = block.params_flat();
    let mut bp = block.clone();
    for k in 0..p0.len() {
        let mut p = p0.clone();
        p[k] += eps;
        bp.set_params(&p).unwrap();
        let lp = weighted_loss(&bp, &xs, n_seqs, &w);
        p[k] = p0[k] - eps;
        bp.set_params(&p).unwrap();
        let lm = weighted_loss(&bp, &xs, n_seqs, &w);
        let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
        let an = flat[k];
        let denom = fd.abs().max(an.abs()).max(0.05);
        assert!(
            (fd - an).abs() / denom < 2e-2,
            "param {k}: analytic {an} vs fd {fd}"
        );
    }
    // input gradient, sampled entries
    for j in (0..xs.len()).step_by(5) {
        let mut xp = xs.clone();
        xp[j] += eps;
        let lp = weighted_loss(&block, &xp, n_seqs, &w);
        xp[j] = xs[j] - eps;
        let lm = weighted_loss(&block, &xp, n_seqs, &w);
        let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
        let denom = fd.abs().max(dx[j].abs()).max(0.05);
        assert!(
            (fd - dx[j]).abs() / denom < 2e-2,
            "input {j}: analytic {} vs fd {fd}",
            dx[j]
        );
    }

    // ---- (b) sharded vs bulk backward, bitwise, through the block ---
    // d = 128 at 32-row panels fans out to multiple pool chunks;
    // QFT_GRAD_SHARD=1 forces every projection gate through the
    // gate-major shard sweep, which must not move a single bit.
    let task = block_teacher_student(&BlockSynthConfig {
        dims: vec![4, 4, 8],
        n_heads: 4,
        seq: 8,
        d_ff: 256,
        n_train: 16,
        n_val: 4,
        teacher_std: 0.2,
        noise_std: 0.01,
        alpha: 1.0,
        seed: 7,
    })
    .unwrap();
    let mut big = task.student();
    big.randomize_circuits(0.2, &mut Rng::new(24)).unwrap();
    let bn = 4usize;
    let bxs = &task.train_x[..bn * big.io_len()];
    let mut bw = vec![0.0f32; bn * big.io_len()];
    rng.fill_normal(&mut bw, 1.0);
    // guard: the projection panels must split into >1 pool chunk, or
    // the sharded-vs-bulk comparison would be vacuously serial
    let aplan =
        quanta_ft::quanta::CircuitPlan::new(big.adapters().adapter(0).circuit()).unwrap();
    let (_, n_chunks) =
        quanta_ft::compute::pool::chunks(bn * task.seq, aplan.apply_flops());
    assert!(n_chunks > 1, "block shard test shape must fan out, got {n_chunks} chunk(s)");
    let (_, btape) = big.forward_with_tape(bxs, bn).unwrap();
    let (bulk_flat, bulk_dx) = big.backward(&btape, &bw, bn).unwrap();
    std::env::set_var("QFT_GRAD_SHARD", "1");
    let (shard_flat, shard_dx) = big.backward(&btape, &bw, bn).unwrap();
    std::env::remove_var("QFT_GRAD_SHARD");
    assert_eq!(bulk_flat, shard_flat, "sharded block gate grads diverged");
    assert_eq!(bulk_dx, shard_dx, "sharded block input grads diverged");

    // ---- (c) merge_all parity at 1e-5 (α-residual fold path) --------
    // α = 0.7 ≠ 1 exercises the α fold in both the streaming residual
    // scatter and the merged weights
    let trained = tiny_trained_block(25, 0.25, 0.7);
    let merged = trained.merged().unwrap();
    let mut mxs = vec![0.0f32; 4 * trained.io_len()];
    rng.fill_normal(&mut mxs, 1.0);
    let y_stream = trained.forward(&mxs, 4, trained.seq()).unwrap();
    let y_merged = merged.forward(&mxs, 4, merged.seq()).unwrap();
    for (i, (a, b)) in y_stream.iter().zip(&y_merged).enumerate() {
        assert!(
            (a - b).abs() < 1e-5,
            "merged-block parity violated at {i}: {a} vs {b}"
        );
    }
    // big block too (the fused-residual path at real panel widths).
    // At d = 128 every output element is a 128-term f32 dot chain, so
    // the merged-vs-streaming difference scales with the activation
    // magnitude (~35 on these draws): the 1e-5 contract is relative to
    // the panel scale, floored at 1 so it reduces to the absolute form
    // on O(1) outputs.  Mirror-measured on these exact draws:
    // max |diff| 8.5e-5 at max |y| 34.7 → 2.4e-6 normalized (4x
    // headroom under the gate; a plain absolute 1e-5 would falsely
    // fail here).
    let big_merged = big.merged().unwrap();
    let ys = big.forward(bxs, bn, big.seq()).unwrap();
    let ym = big_merged.forward(bxs, bn, big_merged.seq()).unwrap();
    let scale = ys.iter().fold(1.0f32, |m, v| m.max(v.abs()));
    for (i, (a, b)) in ys.iter().zip(&ym).enumerate() {
        assert!(
            (a - b).abs() < 1e-5 * scale,
            "big merged parity at {i}: {a} vs {b} (panel scale {scale})"
        );
    }

    // ---- (d) QFT_THREADS invariance of the block train loop ---------
    let train = |threads: Option<&str>, shard: bool| {
        match threads {
            Some(t) => std::env::set_var("QFT_THREADS", t),
            None => std::env::remove_var("QFT_THREADS"),
        }
        if shard {
            std::env::set_var("QFT_GRAD_SHARD", "1");
        }
        let mut student = task.student();
        let cfg = HostTrainConfig { steps: 5, batch: 4, eval_every: 5, ..Default::default() };
        let out = finetune_host(&mut student, &task, &cfg).unwrap();
        std::env::remove_var("QFT_GRAD_SHARD");
        (out.final_theta, out.loss_curve, out.val_curve)
    };
    let baseline = train(Some("1"), false);
    for threads in ["2", "8"] {
        let got = train(Some(threads), false);
        assert_eq!(baseline.0, got.0, "block params differ at QFT_THREADS={threads}");
        assert_eq!(baseline.1, got.1, "block loss curve differs at QFT_THREADS={threads}");
        assert_eq!(baseline.2, got.2, "block val curve differs at QFT_THREADS={threads}");
    }
    // the sharded sweep lands on the same training trajectory
    let sharded = train(Some("8"), true);
    assert_eq!(baseline.0, sharded.0, "sharded block training diverged");
    assert_eq!(baseline.1, sharded.1, "sharded block loss curve diverged");
    std::env::remove_var("QFT_THREADS");

    // ---- (e) the block actually learns --------------------------------
    // mirror-measured on these draws: 75.6 -> 19.1 (4.0x) — the 2x
    // gate below keeps 2x headroom
    let mut student = task.student();
    let init = {
        let pred = student.forward(&task.train_x, task.n_train, task.seq).unwrap();
        pred.iter()
            .zip(&task.train_y)
            .map(|(p, y)| ((p - y) as f64).powi(2))
            .sum::<f64>()
            / pred.len() as f64
    };
    let cfg = HostTrainConfig {
        steps: 80,
        batch: 8,
        eval_every: 20,
        ..Default::default()
    };
    finetune_host(&mut student, &task, &cfg).unwrap();
    let fin = {
        let pred = student.forward(&task.train_x, task.n_train, task.seq).unwrap();
        pred.iter()
            .zip(&task.train_y)
            .map(|(p, y)| ((p - y) as f64).powi(2))
            .sum::<f64>()
            / pred.len() as f64
    };
    assert!(fin < 0.5 * init, "block train smoke failed to learn: {init} -> {fin}");
}
