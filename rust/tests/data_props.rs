//! Property tests over the data pipeline, batcher, metrics, JSON, and
//! init-spec subsystems (proptest-lite).

use quanta_ft::data::batcher::{pack_batch, pack_example, Sampler};
use quanta_ft::data::metrics::{parse_last_number, token_f1};
use quanta_ft::data::tasks::{self, Sizes};
use quanta_ft::data::tokenizer::Tokenizer;
use quanta_ft::data::vocab::{DIGIT0, PAD, UNK};
use quanta_ft::data::Example;
use quanta_ft::runtime::manifest::{InitSpec, ParamEntry};
use quanta_ft::util::json::Value;
use quanta_ft::util::proptest::for_all;
use quanta_ft::util::rng::Rng;

#[test]
fn prop_every_task_every_seed_is_clean() {
    let tok = Tokenizer::new();
    let sizes = Sizes { train: 6, val: 3, test: 3 };
    for_all(
        12,
        |rng| (tasks::TASKS[rng.below(tasks::TASKS.len())], rng.next_u64()),
        |&(task, seed)| {
            let data = tasks::generate(task, &tok, seed, sizes).map_err(|e| e.to_string())?;
            for ex in data.train.iter().chain(&data.val).chain(&data.test) {
                if ex.prompt.contains(&UNK) || ex.answer.contains(&UNK) {
                    return Err(format!("{task}: OOV token (seed {seed})"));
                }
                if ex.prompt.len() + ex.answer.len() > 62 {
                    return Err(format!("{task}: too long (seed {seed})"));
                }
                if ex.is_choice() {
                    if ex.correct >= ex.options.len() {
                        return Err(format!("{task}: bad correct index"));
                    }
                    if ex.options[ex.correct] != ex.answer {
                        return Err(format!("{task}: answer != gold option"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pack_example_mask_invariants() {
    for_all(
        100,
        |rng| {
            let plen = 1 + rng.below(20);
            let alen = 1 + rng.below(6);
            let prompt: Vec<u16> = (0..plen).map(|_| 20 + rng.below(100) as u16).collect();
            let answer: Vec<u16> = (0..alen).map(|_| 20 + rng.below(100) as u16).collect();
            Example::generation(prompt, answer)
        },
        |ex| {
            let seq = 32;
            let (row, mask) = pack_example(ex, seq).map_err(|e| e.to_string())?;
            if row.len() != seq + 1 || mask.len() != seq {
                return Err("bad shapes".into());
            }
            // mask sum == answer len + 1 (EOS)
            let msum: f32 = mask.iter().sum();
            if msum as usize != ex.answer.len() + 1 {
                return Err(format!("mask sum {msum} != {}", ex.answer.len() + 1));
            }
            // masked targets are exactly the answer tokens + EOS
            for (t, &m) in mask.iter().enumerate() {
                let target = row[t + 1];
                if m == 1.0 {
                    let a0 = 1 + ex.prompt.len() + 1;
                    let rel = t + 1 - a0;
                    let expect = if rel < ex.answer.len() {
                        ex.answer[rel] as i32
                    } else {
                        quanta_ft::data::vocab::EOS as i32
                    };
                    if target != expect {
                        return Err(format!("masked target {target} != {expect}"));
                    }
                } else if t + 1 > 1 + ex.prompt.len() + 1 + ex.answer.len() + 1 {
                    // beyond EOS everything is PAD
                    if target != PAD as i32 {
                        return Err("pad region not PAD".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pack_batch_is_row_concat() {
    for_all(
        30,
        |rng| {
            let n = 1 + rng.below(5);
            (0..n)
                .map(|_| {
                    let plen = 1 + rng.below(10);
                    Example::generation(
                        (0..plen).map(|_| 30 + rng.below(50) as u16).collect(),
                        vec![40 + rng.below(20) as u16],
                    )
                })
                .collect::<Vec<_>>()
        },
        |exs| {
            let refs: Vec<&Example> = exs.iter().collect();
            let b = pack_batch(&refs, 6, 24).map_err(|e| e.to_string())?;
            for i in 0..6 {
                let (row, mask) = pack_example(&exs[i % exs.len()], 24).map_err(|e| e.to_string())?;
                if b.tokens[i * 25..(i + 1) * 25] != row[..] {
                    return Err(format!("row {i} mismatch"));
                }
                if b.mask[i * 24..(i + 1) * 24] != mask[..] {
                    return Err(format!("mask {i} mismatch"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sampler_epochs_are_permutations() {
    for_all(
        20,
        |rng| (2 + rng.below(50), rng.next_u64()),
        |&(n, seed)| {
            let mut s = Sampler::new(n, seed);
            for _ in 0..3 {
                let epoch = s.next_indices(n);
                let mut sorted = epoch.clone();
                sorted.sort_unstable();
                if sorted != (0..n).collect::<Vec<_>>() {
                    return Err("epoch is not a permutation".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_f1_bounds_and_exactness() {
    for_all(
        200,
        |rng| {
            let n1 = rng.below(6);
            let n2 = 1 + rng.below(5);
            let a: Vec<u16> = (0..n1).map(|_| rng.below(8) as u16).collect();
            let b: Vec<u16> = (0..n2).map(|_| rng.below(8) as u16).collect();
            (a, b)
        },
        |(a, b)| {
            let f = token_f1(a, b);
            if !(0.0..=1.0).contains(&f) {
                return Err(format!("f1 {f} out of bounds"));
            }
            if a == b && token_f1(a, b) < 1.0 - 1e-12 {
                return Err("exact match must give 1.0".into());
            }
            // symmetry of bag-F1
            let g = token_f1(b, a);
            if (f - g).abs() > 1e-12 {
                return Err(format!("asymmetric: {f} vs {g}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parse_last_number_roundtrip() {
    let tok = Tokenizer::new();
    for_all(
        100,
        |rng| rng.below(100_000) as u64,
        |&n| {
            let toks = tok.encode_number(n);
            match parse_last_number(&toks) {
                Some(v) if v as u64 == n => Ok(()),
                other => Err(format!("{n} parsed as {other:?}")),
            }
        },
    );
}

#[test]
fn prop_parse_last_number_takes_last() {
    for_all(
        50,
        |rng| (rng.below(99) as i64, rng.below(99) as i64),
        |&(a, b)| {
            // "a <word> b" parses to b
            let tok = Tokenizer::new();
            let mut toks = tok.encode_number(a as u64);
            toks.push(200);
            toks.extend(tok.encode_number(b as u64));
            if parse_last_number(&toks) != Some(b) {
                return Err(format!("expected {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => Value::Num((rng.range(-1_000_000, 1_000_000) as f64) / 64.0),
            3 => {
                let n = rng.below(8);
                Value::Str((0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
            }
            4 => Value::Arr((0..rng.below(4)).map(|_| gen_value(rng, depth + 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for_all(
        100,
        |rng| gen_value(rng, 0),
        |v| {
            let compact = Value::parse(&v.to_string_compact()).map_err(|e| e.to_string())?;
            let pretty = Value::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
            if &compact != v || &pretty != v {
                return Err(format!("roundtrip mismatch for {v:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_init_shared_keys_are_identical_across_layouts() {
    // The QuanTA S/T mechanism: same (seed, key) => same values, no
    // matter the entry order, offsets, or surrounding entries.
    for_all(
        50,
        |rng| (rng.next_u64(), 2 + rng.below(5)),
        |&(seed, n)| {
            let e_t = ParamEntry {
                name: "T".into(),
                shape: vec![n * n],
                offset: 0,
                size: n * n,
                init: InitSpec::EyeNoise { n, std: 0.1, key: "shared".into() },
            };
            let mut e_s = e_t.clone();
            e_s.name = "S".into();
            e_s.offset = n * n + 3;
            let filler = ParamEntry {
                name: "f".into(),
                shape: vec![3],
                offset: n * n,
                size: 3,
                init: InitSpec::Normal { std: 1.0, key: "f".into() },
            };
            let layout = vec![e_t, filler, e_s];
            let v = quanta_ft::runtime::init::init_layout(&layout, seed, None)
                .map_err(|e| e.to_string())?;
            let t = &v[0..n * n];
            let s = &v[n * n + 3..2 * (n * n) + 3];
            if t != s {
                return Err("shared-key entries differ".into());
            }
            // diagonal dominated by the +1
            for i in 0..n {
                if (t[i * n + i] - 1.0).abs() > 0.9 {
                    return Err("identity part missing".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_digits_roundtrip_through_tokenizer() {
    let tok = Tokenizer::new();
    for_all(
        50,
        |rng| rng.below(10u64 as usize) as u16,
        |&d| {
            let ids = tok.encode(&d.to_string());
            if ids != vec![DIGIT0 + d] {
                return Err(format!("digit {d} -> {ids:?}"));
            }
            Ok(())
        },
    );
}
