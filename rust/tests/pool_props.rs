//! Thread-count invariance of the pooled compute paths.
//!
//! The PR 3 pool sizes chunks by problem shape only (`PAR_MIN_FLOPS`
//! quanta), and every cross-chunk reduction happens in ascending chunk
//! order — so `QFT_THREADS` must not change ANY output bit: not the
//! forward panel, not the input/gate gradients (whose partial sums
//! depend on chunk boundaries, which are now fixed), not a whole train
//! step, not the dense matmul.
//!
//! Everything lives in ONE `#[test]`: `QFT_THREADS` is process-global
//! env state, so sweeping it from parallel test threads would race.
//! (This binary contains only this test; other test binaries are
//! separate processes.)

use quanta_ft::coordinator::host_trainer::{finetune_host, HostTrainConfig};
use quanta_ft::data::synth::{teacher_student, SynthConfig};
use quanta_ft::quanta::circuit::{all_pairs_structure, Circuit};
use quanta_ft::tensor::Tensor;
use quanta_ft::util::rng::Rng;

/// One full exercise of the pooled paths at a size that actually fans
/// out (d = 128, batch 48 → multiple chunks on the circuit paths;
/// 96×256 @ 256×128 → multiple matmul chunks).
fn run_everything() -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<(usize, f64)>) {
    let dims = vec![4usize, 4, 8];
    let mut rng = Rng::new(900);
    let c = Circuit::random(&dims, &all_pairs_structure(3), 0.3, &mut rng).unwrap();
    let plan = c.plan().unwrap();
    let d = plan.d;
    let batch = 48;
    let mut xs = vec![0.0f32; batch * d];
    rng.fill_normal(&mut xs, 1.0);
    let mut w = vec![0.0f32; batch * d];
    rng.fill_normal(&mut w, 1.0);

    let fwd = plan.apply_batch(&xs, batch).unwrap();
    let (_, tape) = plan.apply_batch_with_tape(&xs, batch).unwrap();
    let grads = plan.backward(&tape, &w).unwrap();

    let a = Tensor::randn(&[96, 256], 1.0, &mut rng);
    let b = Tensor::randn(&[256, 128], 1.0, &mut rng);
    let mm = a.matmul(&b).unwrap();

    let task = teacher_student(&SynthConfig {
        dims,
        n_train: 96,
        n_val: 16,
        teacher_std: 0.3,
        noise_std: 0.01,
        alpha: 1.0,
        seed: 3,
    })
    .unwrap();
    let mut student = task.student().unwrap();
    let cfg = HostTrainConfig { steps: 5, batch: 32, eval_every: 5, ..Default::default() };
    let out = finetune_host(&mut student, &task, &cfg).unwrap();

    (fwd, grads.flat_gates(), grads.input, mm.data, out.final_theta, out.loss_curve)
}

#[test]
fn outputs_bitwise_identical_for_any_qft_threads() {
    let baseline = {
        std::env::set_var("QFT_THREADS", "1");
        run_everything()
    };
    for threads in ["2", "8"] {
        std::env::set_var("QFT_THREADS", threads);
        let got = run_everything();
        assert_eq!(baseline.0, got.0, "apply_batch differs at QFT_THREADS={threads}");
        assert_eq!(baseline.1, got.1, "gate grads differ at QFT_THREADS={threads}");
        assert_eq!(baseline.2, got.2, "input grads differ at QFT_THREADS={threads}");
        assert_eq!(baseline.3, got.3, "matmul differs at QFT_THREADS={threads}");
        assert_eq!(baseline.4, got.4, "trained params differ at QFT_THREADS={threads}");
        assert_eq!(baseline.5, got.5, "loss curve differs at QFT_THREADS={threads}");
    }
    // spawn dispatch shares the chunk claims, so it cannot differ either
    std::env::set_var("QFT_THREADS", "8");
    std::env::set_var("QFT_DISPATCH", "spawn");
    let spawned = run_everything();
    std::env::remove_var("QFT_DISPATCH");
    std::env::remove_var("QFT_THREADS");
    assert_eq!(baseline.4, spawned.4, "spawn dispatch changed the train trajectory");
    assert_eq!(baseline.1, spawned.1, "spawn dispatch changed gate grads");

    // Thread-local scratch reuse carries no cross-chunk state: the
    // grow-only caches are fully rewritten before every read, so a
    // narrow circuit must produce identical bits before and after a
    // much wider circuit has stretched (and dirtied) every worker's
    // scratch on the same pool.
    let mut rng = Rng::new(901);
    let narrow =
        Circuit::random(&[2usize, 3, 2], &all_pairs_structure(3), 0.3, &mut rng).unwrap();
    let nplan = narrow.plan().unwrap();
    let mut nxs = vec![0.0f32; 40 * nplan.d];
    rng.fill_normal(&mut nxs, 1.0);
    let (y_fresh, tape_fresh) = nplan.apply_batch_with_tape(&nxs, 40).unwrap();
    let g_fresh = nplan.backward(&tape_fresh, &nxs).unwrap();
    // widen every executor's scratch (d = 1024, dmn 64 ≫ dmn 6)
    let wide =
        Circuit::random(&[8usize, 8, 16], &all_pairs_structure(3), 0.1, &mut rng).unwrap();
    let wplan = wide.plan().unwrap();
    let mut wxs = vec![0.0f32; 16 * wplan.d];
    rng.fill_normal(&mut wxs, 1.0);
    let (_, wtape) = wplan.apply_batch_with_tape(&wxs, 16).unwrap();
    let _ = wplan.backward(&wtape, &wxs).unwrap();
    let (y_reused, tape_reused) = nplan.apply_batch_with_tape(&nxs, 40).unwrap();
    let g_reused = nplan.backward(&tape_reused, &nxs).unwrap();
    assert_eq!(y_fresh, y_reused, "scratch reuse changed a forward bit");
    assert_eq!(g_fresh.gates, g_reused.gates, "scratch reuse changed gate grads");
    assert_eq!(g_fresh.input, g_reused.input, "scratch reuse changed input grads");
}
