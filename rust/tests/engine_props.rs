//! Property tests for the plan-cached batched circuit engine
//! (`quanta::plan`): batched execution must agree with per-vector
//! application and with the materialized operator on random circuits,
//! plan reuse must be deterministic, and the flat-buffer Jacobi SVD must
//! handle rank-deficient inputs.

use quanta_ft::linalg::{numerical_rank, Svd};
use quanta_ft::quanta::circuit::{all_pairs_structure, Circuit};
use quanta_ft::quanta::plan::CircuitPlan;
use quanta_ft::tensor::Tensor;
use quanta_ft::util::proptest::for_all;
use quanta_ft::util::rng::Rng;

/// Random circuit: 2-4 axes of dim 2-5, random non-empty gate structure
/// drawn from the all-pairs set (possibly with repeated pairs, which
/// exercises non-commuting chains).
fn gen_circuit(rng: &mut Rng) -> Circuit {
    let n_axes = 2 + rng.below(3);
    let dims: Vec<usize> = (0..n_axes).map(|_| 2 + rng.below(4)).collect();
    let all = all_pairs_structure(n_axes);
    let mut structure: Vec<(usize, usize)> = all
        .iter()
        .filter(|_| rng.below(2) == 0)
        .copied()
        .collect();
    structure.push(all[rng.below(all.len())]);
    Circuit::random(&dims, &structure, 0.4, rng).unwrap()
}

#[test]
fn prop_apply_batch_equals_per_vector_apply() {
    for_all(
        40,
        |rng| {
            let c = gen_circuit(rng);
            let d = c.total_dim();
            let batch = 1 + rng.below(6);
            let mut xs = vec![0.0f32; batch * d];
            rng.fill_normal(&mut xs, 1.0);
            (c, xs, batch)
        },
        |(c, xs, batch)| {
            let d = c.total_dim();
            let plan = c.plan().map_err(|e| e.to_string())?;
            let ys = plan.apply_batch(xs, *batch).map_err(|e| e.to_string())?;
            for b in 0..*batch {
                let y = plan.apply(&xs[b * d..(b + 1) * d]).map_err(|e| e.to_string())?;
                if y != ys[b * d..(b + 1) * d] {
                    return Err(format!("vector {b} of batch {batch} differs from apply"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_apply_batch_equals_full_matrix_matvec() {
    for_all(
        40,
        |rng| {
            let c = gen_circuit(rng);
            let d = c.total_dim();
            let batch = 1 + rng.below(4);
            let mut xs = vec![0.0f32; batch * d];
            rng.fill_normal(&mut xs, 1.0);
            (c, xs, batch)
        },
        |(c, xs, batch)| {
            let d = c.total_dim();
            let plan = c.plan().map_err(|e| e.to_string())?;
            let full = plan.full_matrix().map_err(|e| e.to_string())?;
            let ys = plan.apply_batch(xs, *batch).map_err(|e| e.to_string())?;
            for b in 0..*batch {
                let want = full.matvec(&xs[b * d..(b + 1) * d]).map_err(|e| e.to_string())?;
                for (i, (got, want)) in ys[b * d..(b + 1) * d].iter().zip(&want).enumerate() {
                    if (got - want).abs() > 1e-3 {
                        return Err(format!(
                            "dims {:?}, vector {b}, element {i}: engine {got} vs matvec {want}",
                            c.dims()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_plan_reuse_deterministic_across_calls() {
    for_all(
        30,
        |rng| {
            let c = gen_circuit(rng);
            let d = c.total_dim();
            let batch = 1 + rng.below(4);
            let mut xs = vec![0.0f32; batch * d];
            rng.fill_normal(&mut xs, 1.0);
            (c, xs, batch)
        },
        |(c, xs, batch)| {
            let plan = c.plan().map_err(|e| e.to_string())?;
            let y1 = plan.apply_batch(xs, *batch).map_err(|e| e.to_string())?;
            let y2 = plan.apply_batch(xs, *batch).map_err(|e| e.to_string())?;
            if y1 != y2 {
                return Err("same plan, same input, different output".into());
            }
            // an independently built plan must agree bit-for-bit
            let plan2 = CircuitPlan::new(c).map_err(|e| e.to_string())?;
            let y3 = plan2.apply_batch(xs, *batch).map_err(|e| e.to_string())?;
            if y1 != y3 {
                return Err("fresh plan disagrees with cached plan".into());
            }
            let f1 = plan.full_matrix().map_err(|e| e.to_string())?;
            let f2 = plan.full_matrix().map_err(|e| e.to_string())?;
            if f1.data != f2.data {
                return Err("full_matrix not deterministic under plan reuse".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_svd_rank_deficient() {
    // the flat-buffer Jacobi SVD on random rank-deficient matrices:
    // exact numerical rank, small reconstruction error, near-zero
    // trailing singular values.
    for_all(
        25,
        |rng| {
            let n = 6 + rng.below(10);
            let r = 1 + rng.below(n - 2);
            let b = Tensor::randn(&[n, r], 1.0, rng);
            let c = Tensor::randn(&[r, n], 1.0, rng);
            (b.matmul(&c).unwrap(), r)
        },
        |(a, r)| {
            let svd = Svd::compute(a).map_err(|e| e.to_string())?;
            let rec = svd.reconstruct().map_err(|e| e.to_string())?;
            let err = a.max_abs_diff(&rec) / a.frobenius_norm().max(1e-6);
            if err > 1e-4 {
                return Err(format!("reconstruction error {err}"));
            }
            let smax = svd.s[0].max(1e-300);
            for &s in &svd.s[*r..] {
                if s > 1e-6 * smax {
                    return Err(format!("trailing singular value {s} (smax {smax}, r {r})"));
                }
            }
            let nr = numerical_rank(a, 1e-6).map_err(|e| e.to_string())?;
            if nr != *r {
                return Err(format!("numerical rank {nr} != constructed rank {r}"));
            }
            Ok(())
        },
    );
}
