//! Property-based tests for the paper's theorems (§6 / App. C), via the
//! pure-rust QuanTA reference and the proptest-lite harness.

use quanta_ft::linalg::{numerical_rank, Svd};
use quanta_ft::quanta::circuit::{all_pairs_structure, Circuit};
use quanta_ft::quanta::theorems::{
    check_rank_representation, circuit_with_gate_ranks, cnot_layer_fit_residual,
    cnot_layer_member, lora_product_rank, rank_bounds, universality_residual,
};
use quanta_ft::tensor::Tensor;
use quanta_ft::util::proptest::for_all;
use quanta_ft::util::rng::Rng;

/// Random circuit generator: 2-4 axes of dim 2-4, random non-empty
/// gate structure drawn from the all-pairs set.
fn gen_circuit(rng: &mut Rng) -> Circuit {
    let n_axes = 2 + rng.below(3);
    let dims: Vec<usize> = (0..n_axes).map(|_| 2 + rng.below(3)).collect();
    let all = all_pairs_structure(n_axes);
    let mut structure: Vec<(usize, usize)> = all
        .iter()
        .filter(|_| rng.below(2) == 0)
        .copied()
        .collect();
    if structure.is_empty() {
        structure.push(all[rng.below(all.len())]);
    }
    Circuit::random(&dims, &structure, 0.4, rng).unwrap()
}

#[test]
fn prop_rank_representation_bounds_hold() {
    // Theorem 6.2 (Eq. 10) on random circuits with random gate-rank
    // truncations.
    for_all(
        60,
        |rng| {
            let c = gen_circuit(rng);
            let ranks: Vec<usize> = c
                .gates()
                .iter()
                .map(|g| 1 + rng.below(g.mat.shape[0]))
                .collect();
            let dims = c.dims().to_vec();
            let structure: Vec<(usize, usize)> = c.gates().iter().map(|g| (g.m, g.n)).collect();
            let mut r2 = Rng::new(rng.next_u64());
            circuit_with_gate_ranks(&dims, &structure, &ranks, &mut r2).unwrap()
        },
        |c| {
            let (granks, frank, bounds) =
                check_rank_representation(c, 1e-6).map_err(|e| e.to_string())?;
            let b2 = rank_bounds(c, &granks);
            if b2 != bounds {
                return Err("bounds not deterministic".into());
            }
            if (frank as i64) > bounds.upper {
                return Err(format!(
                    "rank {frank} above upper bound {} (gate ranks {granks:?}, dims {:?})",
                    bounds.upper, c.dims()
                ));
            }
            if (frank as i64) < bounds.lower {
                return Err(format!(
                    "rank {frank} below lower bound {} (gate ranks {granks:?}, dims {:?})",
                    bounds.lower, c.dims()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_full_rank_gates_full_rank_chain() {
    // Theorem 6.2 special case: all gates full rank => chain full rank.
    for_all(40, gen_circuit, |c| {
        let full = c.full_matrix().map_err(|e| e.to_string())?;
        let d = c.total_dim();
        let r = numerical_rank(&full, 1e-6).map_err(|e| e.to_string())?;
        if r != d {
            return Err(format!("full-rank chain has rank {r} < {d}"));
        }
        Ok(())
    });
}

#[test]
fn prop_apply_equals_full_matrix() {
    // Eq. 5 vs Eq. 7 consistency on random circuits + random inputs.
    for_all(
        40,
        |rng| {
            let c = gen_circuit(rng);
            let d = c.total_dim();
            let mut x = vec![0.0f32; d];
            rng.fill_normal(&mut x, 1.0);
            (c, x)
        },
        |(c, x)| {
            let y1 = c.apply(x).map_err(|e| e.to_string())?;
            let full = c.full_matrix().map_err(|e| e.to_string())?;
            let y2 = full.matvec(x).map_err(|e| e.to_string())?;
            for (a, b) in y1.iter().zip(&y2) {
                if (a - b).abs() > 1e-3 {
                    return Err(format!("apply/full mismatch: {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_universality_svd_residual() {
    // Theorem 6.1's constructive core: random matrices decompose exactly.
    for_all(
        30,
        |rng| {
            let m = [4usize, 8, 16][rng.below(3)];
            Tensor::randn(&[m, m], 1.0, rng)
        },
        |w| {
            let r = universality_residual(w).map_err(|e| e.to_string())?;
            if r > 1e-4 {
                return Err(format!("SVD residual {r}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lora_composition_closed() {
    // The contrast in Theorem 6.3's discussion: products of rank<=r
    // updates stay rank<=r (closure), for random r and sizes.
    for_all(
        30,
        |rng| (1 + rng.below(4), 8 + rng.below(8), rng.next_u64()),
        |&(r, n, seed)| {
            let (r1, rp) = lora_product_rank(r, n, seed).map_err(|e| e.to_string())?;
            if r1 > r {
                return Err(format!("factor rank {r1} > {r}"));
            }
            if rp > r {
                return Err(format!("product rank {rp} escaped the LoRA set (r={r})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quanta_composition_open() {
    // Theorem 6.3: products of random single-CNOT-layer members are
    // (generically) OUTSIDE the single-layer family, while members fit
    // themselves.  Grid-search fit at 2 qubits.
    for_all(
        6,
        |rng| {
            let angles: Vec<f32> = (0..8)
                .map(|_| (rng.uniform() * std::f64::consts::TAU) as f32)
                .collect();
            angles
        },
        |angles| {
            let m1 = cnot_layer_member(angles[0], angles[1], angles[2], angles[3]);
            let m2 = cnot_layer_member(angles[4], angles[5], angles[6], angles[7]);
            let prod = m1.matmul(&m2).map_err(|e| e.to_string())?;
            let self_fit = cnot_layer_fit_residual(&m1, 16);
            let prod_fit = cnot_layer_fit_residual(&prod, 16);
            // members fit to grid resolution; products generically do not
            if self_fit > 0.6 {
                return Err(format!("member did not fit its own family: {self_fit}"));
            }
            if prod_fit < self_fit {
                return Err(format!(
                    "product fit better than member: {prod_fit} < {self_fit}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_svd_reconstruction() {
    for_all(
        40,
        |rng| {
            let m = 3 + rng.below(14);
            let n = 3 + rng.below(14);
            Tensor::randn(&[m, n], 1.0, rng)
        },
        |a| {
            let svd = Svd::compute(a).map_err(|e| e.to_string())?;
            let rec = svd.reconstruct().map_err(|e| e.to_string())?;
            let err = a.max_abs_diff(&rec) / a.frobenius_norm().max(1e-6);
            if err > 1e-4 {
                return Err(format!("reconstruction error {err}"));
            }
            for w in svd.s.windows(2) {
                if w[0] < w[1] {
                    return Err("singular values unsorted".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_param_count_formula_uniform() {
    // paper §6: uniform axes, all pairs => N(N-1)/2 * d^{4/N} params.
    for_all(
        20,
        |rng| {
            let n = 2 + rng.below(3);
            let d_axis = 2 + rng.below(3);
            (n, d_axis, rng.next_u64())
        },
        |&(n, d_axis, seed)| {
            let dims = vec![d_axis; n];
            let structure = all_pairs_structure(n);
            let mut rng = Rng::new(seed);
            let c = Circuit::random(&dims, &structure, 0.1, &mut rng).unwrap();
            let expect = n * (n - 1) / 2 * (d_axis as u64).pow(4) as usize;
            if c.param_count() != expect {
                return Err(format!("{} != {expect}", c.param_count()));
            }
            Ok(())
        },
    );
}
