//! End-to-end host-trainer smoke: the CI `train-smoke` job runs this
//! test to prove the gradient engine *learns* on every PR.
//!
//! Task: teacher–student regression over dims [4,4,4] (d = 64, 3
//! all-pairs gates, 768 trainable parameters) with light observation
//! noise.  The identity-initialized student starts at `W x` exactly, so
//! the initial loss is the teacher-delta energy; 150 Adam steps must
//! cut the train loss by at least 2× (the acceptance gate — the
//! mirror-measured reduction is ~1e5×, so the margin is enormous) and
//! the best-on-val checkpoint must beat the initial val loss.

use quanta_ft::coordinator::host_trainer::{finetune_host, mse, val_loss_host, HostTrainConfig};
use quanta_ft::data::synth::{teacher_student, SynthConfig};

fn smoke_task() -> quanta_ft::data::synth::SynthTask {
    teacher_student(&SynthConfig {
        dims: vec![4, 4, 4],
        n_train: 128,
        n_val: 32,
        teacher_std: 0.3,
        noise_std: 0.01,
        alpha: 1.0,
        seed: 0,
    })
    .unwrap()
}

#[test]
fn host_trainer_halves_train_loss() {
    let task = smoke_task();
    let mut student = task.student().unwrap();

    let init_train = {
        let pred = student.apply_batch(&task.train_x, task.n_train).unwrap();
        mse(&pred, &task.train_y)
    };
    let init_val = val_loss_host(&student, &task).unwrap();
    assert!(init_train > 0.01, "degenerate task: initial loss {init_train}");

    let cfg = HostTrainConfig { steps: 150, batch: 32, eval_every: 25, ..Default::default() };
    let out = finetune_host(&mut student, &task, &cfg).unwrap();

    let final_train = {
        let pred = student.apply_batch(&task.train_x, task.n_train).unwrap();
        mse(&pred, &task.train_y)
    };
    println!(
        "train-smoke: train {init_train:.5} -> {final_train:.5} ({:.1}x), \
         val {init_val:.5} -> best {:.5}, {} steps in {:.2}s",
        init_train / final_train.max(1e-300),
        out.best_val_loss,
        out.steps_run,
        out.wallclock_s
    );
    assert!(
        final_train < 0.5 * init_train,
        "train loss must at least halve: {init_train} -> {final_train}"
    );
    assert!(
        out.best_val_loss < init_val,
        "best val {} must beat initial val {init_val}",
        out.best_val_loss
    );
}

#[test]
fn merged_student_reproduces_trained_adapter() {
    // after training, merge() must still equal the streaming apply —
    // the zero-inference-overhead contract survives optimization.
    let task = smoke_task();
    let mut student = task.student().unwrap();
    let cfg = HostTrainConfig { steps: 40, batch: 16, ..Default::default() };
    finetune_host(&mut student, &task, &cfg).unwrap();
    let merged = student.merge().unwrap();
    let d = task.d;
    let pred = student.apply_batch(&task.val_x[..4 * d], 4).unwrap();
    for b in 0..4 {
        let want = merged.matvec(&task.val_x[b * d..(b + 1) * d]).unwrap();
        for (got, want) in pred[b * d..(b + 1) * d].iter().zip(&want) {
            assert!((got - want).abs() < 1e-4, "{got} vs {want}");
        }
    }
}
