//! End-to-end pipeline test: the full Runner path (pretrained base ->
//! fine-tune -> evaluate) on a quick configuration.  Requires artifacts
//! AND a cached pretrained tiny base (`quanta-ft pretrain --arch tiny`,
//! or any bench run); skips otherwise to keep `cargo test` fast on a
//! fresh checkout.

use quanta_ft::coordinator::experiment::{RunSpec, Runner};
use quanta_ft::data::tasks::Sizes;

fn runner_with_base() -> Option<Runner> {
    let root = std::env::current_dir().ok()?;
    if !root.join("artifacts/index.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return None;
    }
    if !root.join("runs/base_tiny.bin").exists() {
        eprintln!("SKIP: pretrained tiny base missing (run `quanta-ft pretrain --arch tiny`)");
        return None;
    }
    Runner::new(&root).ok()
}

#[test]
fn quick_finetune_beats_chance_on_choice_task() {
    let Some(mut runner) = runner_with_base() else { return };
    let mut spec = RunSpec::new("tiny_quanta_n4", "boolq_syn").with_seeds(&[0]);
    spec.sizes = Sizes { train: 200, val: 40, test: 60 };
    spec.steps = Some(120);
    let result = runner.run(&spec).unwrap();
    let acc = result.mean("boolq_syn");
    assert!(acc > 0.55, "quanta fine-tune stuck at chance: {acc}");
}

#[test]
fn results_cache_roundtrip() {
    let Some(mut runner) = runner_with_base() else { return };
    let mut spec = RunSpec::new("tiny_lora_r8", "rte_syn").with_seeds(&[0]);
    spec.sizes = Sizes { train: 120, val: 30, test: 40 };
    spec.steps = Some(60);
    let r1 = runner.run(&spec).unwrap();
    // second call must come from the results/ cache and agree exactly
    let t0 = std::time::Instant::now();
    let r2 = runner.run(&spec).unwrap();
    assert!(t0.elapsed().as_secs_f64() < 2.0, "cache miss on identical spec");
    assert_eq!(r1.per_task, r2.per_task);
    assert_eq!(r1.trainable_params, r2.trainable_params);
}

#[test]
fn base_model_near_chance_before_finetune() {
    let Some(mut runner) = runner_with_base() else { return };
    // rte_syn is a 2-way choice; the pretrained-but-not-finetuned model
    // should sit near 50% (the Table-1 "Base" row behaviour).
    let acc = runner
        .eval_base("tiny_lora_r8", "rte_syn", Sizes { train: 10, val: 10, test: 80 })
        .unwrap();
    assert!(acc > 0.2 && acc < 0.8, "base acc {acc} implausible");
}
