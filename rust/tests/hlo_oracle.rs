//! Cross-language oracle test: the lowered HLO merge path (python L1/L2
//! through PJRT) vs the pure-rust QuanTA reference (`quanta::circuit`).
//!
//! The trainable chain T and frozen shadow S are reconstructed host-side
//! from the manifest layout, materialized with the rust reference, and
//! compared against the `merge` artifact's output — pinning the L2
//! einsum/kernels and the rust circuit semantics to each other.

use std::path::PathBuf;

use quanta_ft::quanta::circuit::{all_pairs_structure, Circuit, Gate};
use quanta_ft::runtime::manifest::Manifest;
use quanta_ft::runtime::pjrt as xla;
use quanta_ft::runtime::session::Session;
use quanta_ft::tensor::Tensor;

fn artifacts() -> Option<PathBuf> {
    let p = std::env::current_dir().unwrap().join("artifacts");
    if p.join("index.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ missing");
        None
    }
}

/// Extract the gates named `prefix.T0..` / `prefix.S0..` from a flat
/// vector using a manifest layout.
fn extract_gates(
    layout: &[quanta_ft::runtime::manifest::ParamEntry],
    flat: &[f32],
    prefix: &str,
    who: &str,
) -> Vec<Tensor> {
    let mut gates = vec![];
    for a in 0.. {
        let name = format!("{prefix}.{who}{a}");
        match layout.iter().find(|e| e.name == name) {
            Some(e) => {
                let data = flat[e.offset..e.offset + e.size].to_vec();
                gates.push(Tensor::from_vec(&e.shape, data).unwrap());
            }
            None => break,
        }
    }
    gates
}

#[test]
fn hlo_merge_matches_rust_circuit_reference() {
    let Some(dir) = artifacts() else { return };
    let client = xla::PjRtClient::cpu().unwrap();
    let man = Manifest::load(&dir.join("tiny_quanta_n4")).unwrap();
    let dims: Vec<usize> = man
        .method
        .as_ref()
        .unwrap()
        .hyper
        .req("dims")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    let structure = all_pairs_structure(dims.len());

    // random-but-reproducible base and theta (seeds must match so S = T
    // at init; then we perturb theta so the delta is nonzero)
    let pre_man = Manifest::load(&dir.join("pretrain_tiny")).unwrap();
    let ckpt = quanta_ft::runtime::init::init_layout(&pre_man.theta_layout, 21, None).unwrap();
    let base = Session::init_base(&man, 21, Some(&ckpt)).unwrap();
    let session = Session::load(&client, &dir, "tiny_quanta_n4", &base, &["merge"]).unwrap();
    let mut state = session.init_state(21).unwrap();
    let mut rng = quanta_ft::util::rng::Rng::new(99);
    for v in state.theta.iter_mut() {
        *v += 0.05 * rng.normal() as f32;
    }

    // HLO path
    let hlo_deltas = session.merge_deltas(&state.theta).unwrap();

    // rust reference path, module by module
    for (idx, module) in session.man.merged_modules.iter().enumerate() {
        let t_gates = extract_gates(&man.theta_layout, &state.theta, module, "T");
        let s_gates = extract_gates(&man.base_layout, &base, module, "S");
        assert_eq!(t_gates.len(), structure.len(), "{module}");
        assert_eq!(s_gates.len(), structure.len(), "{module}");
        let mk = |gates: Vec<Tensor>| {
            Circuit::new(
                dims.clone(),
                gates
                    .into_iter()
                    .zip(&structure)
                    .map(|(mat, &(m, n))| Gate { m, n, mat })
                    .collect(),
            )
            .unwrap()
        };
        let full_t = mk(t_gates).full_matrix().unwrap();
        let full_s = mk(s_gates).full_matrix().unwrap();
        let want = full_t.sub(&full_s).unwrap();
        let got = &hlo_deltas[idx];
        let scale = want.frobenius_norm().max(1e-6);
        let err = got.max_abs_diff(&want) / scale;
        assert!(
            err < 1e-3,
            "{module}: HLO merge vs rust reference relative error {err}"
        );
    }
}
