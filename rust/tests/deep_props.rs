//! Depth-N stack properties (DESIGN.md §12), pinned in ONE `#[test]`
//! because several sections sweep process-global env knobs
//! (`QFT_THREADS`, `QFT_GRAD_SHARD`) — the pool_props convention.
//!
//! What is pinned, and at what strength:
//!
//! - **Gradcheck at depth {1, 2, 4}**: the layer-major backward chain
//!   (top layer's `dx` feeding the layer below) against central finite
//!   differences of the stacked forward.
//! - **Depth-1 ≡ bare block, bitwise**: init draws, forward, taped
//!   forward, and backward of a depth-1 [`DeepModel`] are exactly the
//!   [`TransformerBlock`] path every earlier PR pinned — the deep API
//!   is a strict superset, not a parallel implementation.
//! - **Shard ≡ bulk, bitwise, at depth 2**: `QFT_GRAD_SHARD=1` routes
//!   every layer's adapter backward through the one-gate-wide sweep
//!   and must not move a single bit of the flat gradient.
//! - **Merged ≡ streaming at 1e-5×scale, streaming ≡ recompute
//!   bitwise**: the serving parity contracts, lifted to depth N.
//! - **Scheduler invariance at depth 2**: continuous-batched deep
//!   decode is bitwise invariant under `QFT_THREADS` {1, 2, 8} ×
//!   arrival permutations, and equals the autoregressive
//!   full-recompute forward.
//! - **Trainer invariance at depth 2**: `finetune_host` drives the
//!   stack to the same trajectory at every thread count, sharded or
//!   not.

use quanta_ft::coordinator::host_trainer::{finetune_host, HostTrainConfig};
use quanta_ft::data::synth::{deep_teacher_student, DeepSynthConfig};
use quanta_ft::model::{
    BlockConfig, DeepConfig, DeepModel, TrainableModel, TransformerBlock,
};
use quanta_ft::serve::{BatchScheduler, ServeModel, ServeRequest};
use quanta_ft::util::rng::Rng;

/// Loss `Σ w ⊙ out` (f64 accumulation — model_props convention).
fn weighted_loss(model: &DeepModel, xs: &[f32], n: usize, w: &[f32]) -> f64 {
    model
        .forward(xs, n, model.seq())
        .unwrap()
        .iter()
        .zip(w)
        .map(|(a, b)| (*a as f64) * (*b as f64))
        .sum()
}

/// Tiny trained stack: frozen bases per layer, perturbed circuits.
fn tiny_deep(depth: usize, seed: u64, std: f32) -> DeepModel {
    let cfg = DeepConfig::standard(vec![2, 2], 2, 3, depth);
    let mut model = DeepModel::init(&cfg, seed).unwrap();
    model.randomize_circuits(std, seed).unwrap();
    model
}

/// Autoregressive full-recompute reference: re-run the whole stacked
/// forward on the growing sequence each step (what the KV caches
/// replace), feeding each generated row back in.
fn greedy_recompute(model: &DeepModel, prompt: &[f32], n_gen: usize) -> Vec<f32> {
    let d = model.d();
    let mut seqv = prompt.to_vec();
    let mut out = Vec::with_capacity(n_gen * d);
    loop {
        let l = seqv.len() / d;
        let y = model.forward(&seqv, 1, l).unwrap();
        let last = &y[(l - 1) * d..l * d];
        out.extend_from_slice(last);
        if out.len() >= n_gen * d {
            return out;
        }
        seqv.extend_from_slice(last);
    }
}

#[test]
fn deep_stack_properties() {
    std::env::remove_var("QFT_THREADS");
    std::env::remove_var("QFT_GRAD_SHARD");

    // ---- (a) central-FD gradcheck at depth {1, 2, 4} ----------------
    // eps 1e-2 / tol 2e-2 relative: the model_props convention (f32
    // forward, f64 loss reduction; FD error is dominated by forward
    // rounding, and the deep chain only lengthens the f32 dot chains)
    for depth in [1usize, 2, 4] {
        let model = tiny_deep(depth, 90 + depth as u64, 0.25);
        let n = 2;
        let mut rng = Rng::new(900 + depth as u64);
        let mut xs = vec![0.0f32; n * model.io_len()];
        rng.fill_normal(&mut xs, 1.0);
        let mut w = vec![0.0f32; n * model.io_len()];
        rng.fill_normal(&mut w, 1.0);
        let (_, tape) = model.forward_with_tape(&xs, n).unwrap();
        let grad = model.backward_flat(&tape, &w, n).unwrap();
        assert_eq!(grad.len(), model.param_count());
        let theta = model.params_flat();
        let eps = 1e-2f32;
        for (i, g) in grad.iter().enumerate() {
            let mut plus = model.clone();
            let mut th = theta.clone();
            th[i] += eps;
            plus.set_params(&th).unwrap();
            let mut minus = model.clone();
            th[i] = theta[i] - eps;
            minus.set_params(&th).unwrap();
            let fd = (weighted_loss(&plus, &xs, n, &w) - weighted_loss(&minus, &xs, n, &w))
                / (2.0 * eps as f64);
            let denom = fd.abs().max((*g as f64).abs()).max(1.0);
            assert!(
                ((*g as f64 - fd) / denom).abs() < 2e-2,
                "depth {depth} gradcheck failed at param {i}: analytic {g} vs FD {fd}"
            );
        }
    }

    // ---- (b) depth-1 DeepModel ≡ bare TransformerBlock, bitwise -----
    {
        let seed = 94u64;
        let dcfg = DeepConfig::standard(vec![2, 2], 2, 3, 1);
        let mut deep = DeepModel::init(&dcfg, seed).unwrap();
        let mut block = TransformerBlock::init(
            &BlockConfig::standard(vec![2, 2], 2, 3),
            &mut Rng::stream(seed, "block-base"),
        )
        .unwrap();
        assert_eq!(deep.params_flat(), block.params_flat(), "depth-1 init diverged");
        deep.randomize_circuits(0.2, seed).unwrap();
        block.randomize_circuits(0.2, &mut Rng::stream(seed, "block-teacher")).unwrap();
        assert_eq!(deep.params_flat(), block.params_flat(), "teacher streams diverged");
        let n = 3;
        let mut rng = Rng::new(940);
        let mut xs = vec![0.0f32; n * deep.io_len()];
        rng.fill_normal(&mut xs, 1.0);
        let yd = deep.forward(&xs, n, deep.seq()).unwrap();
        let yb = block.forward(&xs, n, block.seq()).unwrap();
        assert_eq!(yd, yb, "depth-1 forward diverged");
        let (ytd, dtape) = deep.forward_with_tape(&xs, n).unwrap();
        let (ytb, btape) = block.forward_with_tape(&xs, n).unwrap();
        assert_eq!(ytd, yb, "depth-1 taped forward diverged");
        assert_eq!(ytb, yb);
        let mut w = vec![0.0f32; yd.len()];
        rng.fill_normal(&mut w, 1.0);
        let gd = deep.backward_flat(&dtape, &w, n).unwrap();
        let gb = block.backward_flat(&btape, &w, n).unwrap();
        assert_eq!(gd, gb, "depth-1 backward diverged");
    }

    // ---- (c) shard ≡ bulk, bitwise, at depth 2 and real width -------
    // d = 128 so each layer has multiple gates to sweep
    let wide = {
        let cfg = DeepConfig::standard(vec![4, 4, 8], 4, 4, 2);
        let mut m = DeepModel::init(&cfg, 95).unwrap();
        m.randomize_circuits(0.2, 95).unwrap();
        m
    };
    {
        let n = 2;
        let mut rng = Rng::new(950);
        let mut xs = vec![0.0f32; n * wide.io_len()];
        rng.fill_normal(&mut xs, 1.0);
        let mut w = vec![0.0f32; n * wide.io_len()];
        rng.fill_normal(&mut w, 1.0);
        let (_, tape) = wide.forward_with_tape(&xs, n).unwrap();
        let bulk = wide.backward_flat(&tape, &w, n).unwrap();
        std::env::set_var("QFT_GRAD_SHARD", "1");
        let shard = wide.backward_flat(&tape, &w, n).unwrap();
        std::env::remove_var("QFT_GRAD_SHARD");
        assert_eq!(bulk, shard, "deep sharded gate grads diverged");
    }

    // ---- (d) serving parity, lifted to depth N ----------------------
    // streaming decode ≡ stacked full-recompute forward bitwise at
    // every position; merged ≡ streaming at 1e-5 relative to the panel
    // scale (floored at 1 — the model_props/serve_props contract)
    for depth in [2usize, 4] {
        let model = tiny_deep(depth, 96, 0.25);
        let d = model.d();
        let seq = 7usize; // exceeds the training seq (3): decode must not care
        let mut xs = vec![0.0f32; seq * d];
        Rng::new(960 + depth as u64).fill_normal(&mut xs, 1.0);
        let streaming = ServeModel::streaming(&model).decode_sequence(&xs, seq).unwrap();
        let merged = ServeModel::merged(&model).unwrap().decode_sequence(&xs, seq).unwrap();
        let scale = streaming.iter().fold(1.0f32, |m, v| m.max(v.abs()));
        for t in 0..seq {
            let full = model.forward(&xs[..(t + 1) * d], 1, t + 1).unwrap();
            let want = &full[t * d..(t + 1) * d];
            assert_eq!(
                &streaming[t * d..(t + 1) * d],
                want,
                "depth {depth}: streaming deep decode differs from recompute at position {t}"
            );
            for (j, (a, b)) in merged[t * d..(t + 1) * d].iter().zip(want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-5 * scale,
                    "depth {depth}: merged deep decode at ({t},{j}): {a} vs {b} \
                     (panel scale {scale})"
                );
            }
        }
    }

    // ---- (e) scheduler invariance at depth 2 ------------------------
    // continuous-batched deep serving: per-request outputs are bitwise
    // invariant under QFT_THREADS × arrival order, and each equals the
    // autoregressive full-recompute reference
    {
        let model = tiny_deep(2, 97, 0.25);
        let d = model.d();
        let engine = ServeModel::streaming(&model);
        let reqs: Vec<ServeRequest> = (0..6u64)
            .map(|id| {
                let p_len = 1 + (id as usize % 3);
                let mut prompt = vec![0.0f32; p_len * d];
                Rng::stream(970, &format!("deep-req-{id}")).fill_normal(&mut prompt, 1.0);
                ServeRequest { id, prompt, n_gen: 2 + (id as usize % 4) }
            })
            .collect();
        let mut orders = vec![reqs.clone()];
        let mut rev = reqs.clone();
        rev.reverse();
        orders.push(rev);
        let mut interleaved = reqs.clone();
        interleaved.sort_by_key(|r| (r.id % 2 == 0, r.id));
        orders.push(interleaved);
        let sched = BatchScheduler::new(engine, 3).unwrap();
        let mut baseline: Option<Vec<(u64, Vec<f32>)>> = None;
        for threads in ["1", "2", "8"] {
            std::env::set_var("QFT_THREADS", threads);
            for (oi, order) in orders.iter().enumerate() {
                let (out, stats) = sched.run(order.clone()).unwrap();
                assert_eq!(stats.completed, reqs.len(), "threads {threads} order {oi}");
                let got: Vec<(u64, Vec<f32>)> =
                    out.into_iter().map(|o| (o.id, o.result.unwrap())).collect();
                match &baseline {
                    None => {
                        for (id, panel) in &got {
                            let req = reqs.iter().find(|r| r.id == *id).unwrap();
                            assert_eq!(
                                panel,
                                &greedy_recompute(&model, &req.prompt, req.n_gen),
                                "request {id}: batched deep decode differs from recompute"
                            );
                        }
                        baseline = Some(got);
                    }
                    Some(b) => assert_eq!(
                        b, &got,
                        "threads {threads} order {oi}: deep serving not invariant"
                    ),
                }
            }
        }
        std::env::remove_var("QFT_THREADS");
    }

    // ---- (f) trainer invariance at depth 2 --------------------------
    // finetune_host drives the stack through TrainableModel unchanged;
    // the trajectory is bitwise thread- and shard-invariant
    {
        let task = deep_teacher_student(&DeepSynthConfig {
            dims: vec![2, 2],
            n_heads: 2,
            seq: 3,
            d_ff: 8,
            depth: 2,
            n_train: 8,
            n_val: 4,
            noise_std: 0.0,
            ..Default::default()
        })
        .unwrap();
        let train = |threads: &str, shard: bool| {
            std::env::set_var("QFT_THREADS", threads);
            if shard {
                std::env::set_var("QFT_GRAD_SHARD", "1");
            }
            let mut student = task.student();
            let cfg = HostTrainConfig { steps: 5, batch: 4, eval_every: 5, ..Default::default() };
            let out = finetune_host(&mut student, &task, &cfg).unwrap();
            std::env::remove_var("QFT_GRAD_SHARD");
            (out.final_theta, out.loss_curve)
        };
        let baseline = train("1", false);
        for threads in ["2", "8"] {
            let got = train(threads, false);
            assert_eq!(baseline.0, got.0, "deep params differ at QFT_THREADS={threads}");
            assert_eq!(baseline.1, got.1, "deep loss curve differs at QFT_THREADS={threads}");
        }
        let sharded = train("8", true);
        assert_eq!(baseline.0, sharded.0, "sharded deep training diverged");
        assert_eq!(baseline.1, sharded.1, "sharded deep loss curve diverged");
        std::env::remove_var("QFT_THREADS");
    }
}
