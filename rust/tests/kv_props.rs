//! Paged-KV arena properties (DESIGN.md §14).
//!
//! What is pinned, and how hard:
//!
//! * **Paged decode is bitwise page-size-blind**: the same decode over
//!   the same rows must produce identical bits at every page size —
//!   including the degenerate 1-token page (a page boundary between
//!   every position) — and at every `QFT_THREADS`, because
//!   `attn_row_segs` walks page runs in position order with the same
//!   serial accumulation the contiguous walk uses.  Streaming decode
//!   through the arena is additionally pinned bitwise against the
//!   block's full-recompute forward, so paging cannot drift from the
//!   training semantics either.
//! * **Allocator discipline**: a bounded arena fails the
//!   `max_pages + 1`-th allocation with a structured [`CacheFull`]
//!   that leaves the requesting table untouched, release returns every
//!   page, and reuse reads back the new bytes exactly (pages are fully
//!   overwritten before any read).
//! * **CoW fork isolation**: a fork shares all pages (zero rows
//!   copied, refcounts bumped); the first push into a shared tail page
//!   copies only the filled prefix, after which parent and fork
//!   diverge freely while the shared full pages stay shared.
//!   Releasing both sides returns the arena to zero pages in use.
//! * **Scheduler page budget**: a `--kv-pages` budget quarantines
//!   exactly the request that exhausts it (`CacheExhausted`), leaves
//!   the survivors bitwise unchanged, and reclaims retired requests'
//!   pages for requests admitted later in the same run.
//! * **Forked-table decode parity**: a child forked from a donor's
//!   prefix (`DecodeState::fork_prefix`) and continued with its own
//!   rows is bitwise equal to an unshared request that prefilled the
//!   same tokens — at every page size × `QFT_THREADS`, alone or
//!   batch-packed next to its still-decoding donor, with zero pages
//!   copied at fork time.
//! * **Prefix-cache admission**: `--prefix-cache` runs end to end
//!   through the scheduler — shared-prefix requests fork instead of
//!   re-prefilling, outputs stay bitwise equal to the plain run, and
//!   peak resident pages drop.
//!
//! Everything lives in ONE `#[test]`: `QFT_THREADS` is process-global
//! env state, so sweeping it from parallel test threads would race
//! (the `pool_props` convention).

use quanta_ft::model::{BlockConfig, TransformerBlock};
use quanta_ft::serve::{
    BatchScheduler, CacheFull, DecodeScratch, DecodeState, KvArena, PageTable, ServeBlock,
    ServeConfig, ServeError, ServeOutput, ServeRequest,
};
use quanta_ft::util::rng::Rng;

fn trained_block(seed: u64, dims: Vec<usize>, heads: usize) -> TransformerBlock {
    let mut rng = Rng::new(seed);
    let cfg = BlockConfig::standard(dims, heads, 4);
    let mut block = TransformerBlock::init(&cfg, &mut rng).unwrap();
    block.randomize_circuits(0.25, &mut rng).unwrap();
    block
}

/// Teacher-forced decode of `xs` through an arena with the given page
/// size, one position per step — the paged counterpart of
/// `TransformerBlock::forward`'s per-position rows.
fn paged_decode(sb: &ServeBlock, xs: &[f32], seq: usize, page_tokens: usize) -> Vec<f32> {
    let d = sb.d();
    let mut arena = KvArena::new(d, page_tokens, 0).unwrap();
    let mut scratch = DecodeScratch::new();
    let mut state = DecodeState::new(d);
    let mut out = Vec::with_capacity(seq * d);
    let mut step = Vec::new();
    for t in 0..seq {
        let row = &xs[t * d..(t + 1) * d];
        sb.decode_step(&mut arena, &mut scratch, &mut [&mut state], row, &mut step).unwrap();
        out.extend_from_slice(&step);
    }
    assert_eq!(state.len(), seq);
    assert_eq!(state.n_pages(), seq.div_ceil(page_tokens));
    out
}

#[test]
fn paged_kv_properties() {
    // ---- (a) allocator discipline -----------------------------------
    let d = 4usize;
    {
        let mut arena = KvArena::new(d, 2, 3).unwrap();
        let mut t1 = PageTable::new();
        for i in 0..6 {
            arena.push(&mut t1, &[i as f32; 4], &[-(i as f32); 4]).unwrap();
        }
        assert_eq!(arena.pages_in_use(), 3);
        // page 4 would exceed the bound: structured failure, table intact
        let mut t2 = PageTable::new();
        let err = arena.push(&mut t2, &[9.0; 4], &[9.0; 4]).unwrap_err();
        assert_eq!(err, CacheFull { pages: 3 });
        assert_eq!(t2.len(), 0, "failed push must leave the table untouched");
        assert_eq!(t1.len(), 6, "failed push must not disturb other tables");
        // release returns every page; the next sequence reuses them
        // byte-exactly (pages are overwritten before any read)
        arena.release(&mut t1);
        assert_eq!(arena.pages_in_use(), 0);
        for i in 0..5 {
            arena.push(&mut t2, &[10.0 + i as f32; 4], &[0.5; 4]).unwrap();
        }
        let want: Vec<f32> = (0..5).flat_map(|i| vec![10.0 + i as f32; 4]).collect();
        assert_eq!(arena.gather_k(&t2), want, "reused pages must read back the new bytes");
        assert_eq!(arena.allocated_pages(), 3, "bounded arena never grows past its budget");
    }

    // ---- (b) CoW fork isolation + refcount reclaim ------------------
    {
        let mut arena = KvArena::new(d, 2, 0).unwrap();
        let mut parent = PageTable::new();
        for i in 0..5 {
            arena.push(&mut parent, &[i as f32; 4], &[i as f32 + 0.5; 4]).unwrap();
        }
        let before = arena.gather_k(&parent);
        let mut fork = arena.fork(&parent);
        assert_eq!(arena.pages_in_use(), 3, "fork copies zero pages up front");
        assert_eq!(arena.gather_k(&fork), before);
        // fork's first push lands in the shared half-full tail page:
        // CoW copies the one filled row, then the sides diverge
        arena.push(&mut fork, &[100.0; 4], &[100.0; 4]).unwrap();
        arena.push(&mut parent, &[200.0; 4], &[200.0; 4]).unwrap();
        assert_eq!(arena.pages_in_use(), 4, "CoW split pays exactly one page");
        let pk = arena.gather_k(&parent);
        let fk = arena.gather_k(&fork);
        assert_eq!(&pk[..5 * 4], &before[..], "parent prefix perturbed by fork's write");
        assert_eq!(&fk[..5 * 4], &before[..], "fork prefix perturbed by parent's write");
        assert_eq!(&pk[5 * 4..], &[200.0; 4], "parent tail wrong after divergence");
        assert_eq!(&fk[5 * 4..], &[100.0; 4], "fork tail wrong after divergence");
        arena.release(&mut fork);
        assert_eq!(arena.pages_in_use(), 3, "shared pages must survive one side's release");
        assert_eq!(arena.gather_k(&parent)[..5 * 4], before[..]);
        arena.release(&mut parent);
        assert_eq!(arena.pages_in_use(), 0, "refcounts must reclaim every page");
    }

    // ---- (c) paged ≡ contiguous, bitwise, across page sizes × threads
    // the contiguous reference is a one-page arena (page_tokens = seq:
    // a single run, exactly the pre-§14 layout); every smaller page
    // size and every QFT_THREADS must reproduce it bit for bit, and
    // streaming decode must stay bitwise on the forward recompute
    let block = trained_block(400, vec![4, 4, 8], 4);
    let dm = block.d();
    let seq = 13usize; // not a multiple of any swept page size
    let mut xs = vec![0.0f32; seq * dm];
    Rng::new(401).fill_normal(&mut xs, 1.0);
    let streaming = ServeBlock::streaming(&block);
    let merged = ServeBlock::merged(&block).unwrap();
    std::env::set_var("QFT_THREADS", "1");
    let full = block.forward(&xs, 1, seq).unwrap();
    let ref_streaming = paged_decode(&streaming, &xs, seq, seq);
    let ref_merged = paged_decode(&merged, &xs, seq, seq);
    assert_eq!(ref_streaming, full, "contiguous streaming decode drifted from forward");
    for threads in ["1", "2", "8"] {
        std::env::set_var("QFT_THREADS", threads);
        for page_tokens in [1usize, 4, 16] {
            let got_s = paged_decode(&streaming, &xs, seq, page_tokens);
            let got_m = paged_decode(&merged, &xs, seq, page_tokens);
            assert_eq!(
                got_s, ref_streaming,
                "streaming decode differs at page_tokens={page_tokens} QFT_THREADS={threads}"
            );
            assert_eq!(
                got_m, ref_merged,
                "merged decode differs at page_tokens={page_tokens} QFT_THREADS={threads}"
            );
        }
    }
    std::env::remove_var("QFT_THREADS");

    // ---- (d) scheduler page budget: quarantine + reclaim ------------
    // 8 one-token pages, max_batch 2.  The hog (2 + 8 − 1 = 9 cached
    // positions) exceeds the budget even alone and dies CacheExhausted
    // on its 9th push; the short requests (3 pages each) fit alongside
    // it — id 2 only because id 1's retirement returned its pages —
    // and must finish bitwise equal to an unbounded run.
    let mk = |id: u64, p_len: usize, n_gen: usize, seed: u64| {
        let mut prompt = vec![0.0f32; p_len * dm];
        Rng::new(seed).fill_normal(&mut prompt, 1.0);
        ServeRequest { id, prompt, n_gen }
    };
    let reqs = vec![mk(0, 2, 8, 410), mk(1, 2, 2, 411), mk(2, 2, 2, 412)];
    let free_cfg = ServeConfig::default().with_max_batch(2).with_page_tokens(1);
    let free = BatchScheduler::with_config(merged.clone(), free_cfg).unwrap();
    let (unbounded, _) = free.run(reqs.clone()).unwrap();
    let tight = BatchScheduler::with_config(merged.clone(), free_cfg.with_kv_pages(8)).unwrap();
    let (bounded, stats) = tight.run(reqs).unwrap();
    assert_eq!((stats.completed, stats.failed, stats.shed), (2, 1, 0));
    let by_id =
        |outs: &[ServeOutput], id: u64| outs.iter().find(|o| o.id == id).unwrap().result.clone();
    assert_eq!(
        by_id(&bounded, 0).unwrap_err(),
        ServeError::CacheExhausted { pages: 8 },
        "the hog must die on the page budget"
    );
    for id in [1, 2] {
        assert_eq!(
            by_id(&bounded, id),
            by_id(&unbounded, id),
            "request {id} perturbed by a peer's cache exhaustion"
        );
    }
    assert_eq!(stats.pages_in_use, 8, "peak pages must saturate exactly at the budget");

    // ---- (e) forked-table decode parity, across page sizes × threads
    // the donor decodes all 13 rows of xs; a child forked at 8 shared
    // rows continues with zs's tail, batch-packed NEXT TO the donor —
    // and must be bitwise equal to an unshared request that prefilled
    // zs from scratch.  K/V rows depend only on their own input row,
    // so the donor's cached prefix is bit-identical to what the child
    // would have written.
    let shared_rows = 8usize;
    let mut ys = vec![0.0f32; seq * dm];
    Rng::new(402).fill_normal(&mut ys, 1.0);
    let mut zs = xs[..shared_rows * dm].to_vec();
    zs.extend_from_slice(&ys[shared_rows * dm..]);
    for threads in ["1", "2", "8"] {
        std::env::set_var("QFT_THREADS", threads);
        for page_tokens in [1usize, 4, 16] {
            let want = paged_decode(&merged, &zs, seq, page_tokens);
            let mut arena = KvArena::new(dm, page_tokens, 0).unwrap();
            let mut scratch = DecodeScratch::new();
            let mut donor = DecodeState::new(dm);
            let mut step = Vec::new();
            for t in 0..seq {
                merged
                    .decode_step(
                        &mut arena,
                        &mut scratch,
                        &mut [&mut donor],
                        &xs[t * dm..(t + 1) * dm],
                        &mut step,
                    )
                    .unwrap();
            }
            let pages_before = arena.pages_in_use();
            let mut child = donor.fork_prefix(&mut arena, shared_rows);
            assert_eq!(
                arena.pages_in_use(),
                pages_before,
                "fork_prefix must share pages, not copy them (page_tokens={page_tokens})"
            );
            let mut got = Vec::new();
            let mut rows = vec![0.0f32; 2 * dm];
            for t in shared_rows..seq {
                // donor keeps decoding fresh rows in slot 0; the child's
                // output (slot 1) must not see it
                rows[..dm].copy_from_slice(&ys[(t - shared_rows) * dm..(t - shared_rows + 1) * dm]);
                rows[dm..].copy_from_slice(&zs[t * dm..(t + 1) * dm]);
                merged
                    .decode_step(
                        &mut arena,
                        &mut scratch,
                        &mut [&mut donor, &mut child],
                        &rows,
                        &mut step,
                    )
                    .unwrap();
                got.extend_from_slice(&step[dm..]);
            }
            assert_eq!(
                got,
                &want[shared_rows * dm..],
                "forked decode differs from unshared at page_tokens={page_tokens} \
                 QFT_THREADS={threads}"
            );
        }
    }

    // ---- (f) prefix-cache admission end to end through the scheduler
    // 4 requests, 6 shared + 2 unique prompt rows, n_gen 4: with
    // --prefix-cache the followers fork instead of re-prefilling; bits
    // must match the plain run while peak resident pages drop
    let mut shared_p = vec![0.0f32; 6 * dm];
    Rng::new(420).fill_normal(&mut shared_p, 1.0);
    let mkp = |id: u64, seed: u64| {
        let mut prompt = shared_p.clone();
        let mut tail = vec![0.0f32; 2 * dm];
        Rng::new(seed).fill_normal(&mut tail, 1.0);
        prompt.extend_from_slice(&tail);
        ServeRequest { id, prompt, n_gen: 4 }
    };
    let preqs: Vec<ServeRequest> = (0..4).map(|i| mkp(i, 430 + i)).collect();
    for threads in ["1", "8"] {
        std::env::set_var("QFT_THREADS", threads);
        for page_tokens in [1usize, 4] {
            let cfg = ServeConfig::default().with_max_batch(4).with_page_tokens(page_tokens);
            let plain = BatchScheduler::with_config(merged.clone(), cfg).unwrap();
            let (base, base_stats) = plain.run(preqs.clone()).unwrap();
            let caching =
                BatchScheduler::with_config(merged.clone(), cfg.with_prefix_cache(true)).unwrap();
            let (out, stats) = caching.run(preqs.clone()).unwrap();
            for (a, b) in base.iter().zip(&out) {
                assert_eq!(
                    a.result, b.result,
                    "request {} drifted under --prefix-cache at page_tokens={page_tokens} \
                     QFT_THREADS={threads}",
                    a.id
                );
            }
            assert_eq!((stats.completed, stats.failed, stats.shed), (4, 0, 0));
            assert_eq!(stats.prefix_hits, 3, "every follower must fork off the first request");
            assert!(
                stats.pages_in_use < base_stats.pages_in_use,
                "prefix sharing must reduce peak pages ({} vs {} at page_tokens={page_tokens})",
                stats.pages_in_use,
                base_stats.pages_in_use
            );
        }
    }
    std::env::remove_var("QFT_THREADS");
}
