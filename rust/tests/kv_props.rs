//! Paged-KV arena properties (DESIGN.md §14).
//!
//! What is pinned, and how hard:
//!
//! * **Paged decode is bitwise page-size-blind**: the same decode over
//!   the same rows must produce identical bits at every page size —
//!   including the degenerate 1-token page (a page boundary between
//!   every position) — and at every `QFT_THREADS`, because
//!   `attn_row_segs` walks page runs in position order with the same
//!   serial accumulation the contiguous walk uses.  Streaming decode
//!   through the arena is additionally pinned bitwise against the
//!   block's full-recompute forward, so paging cannot drift from the
//!   training semantics either.
//! * **Allocator discipline**: a bounded arena fails the
//!   `max_pages + 1`-th allocation with a structured [`CacheFull`]
//!   that leaves the requesting table untouched, release returns every
//!   page, and reuse reads back the new bytes exactly (pages are fully
//!   overwritten before any read).
//! * **CoW fork isolation**: a fork shares all pages (zero rows
//!   copied, refcounts bumped); the first push into a shared tail page
//!   copies only the filled prefix, after which parent and fork
//!   diverge freely while the shared full pages stay shared.
//!   Releasing both sides returns the arena to zero pages in use.
//! * **Scheduler page budget**: a `--kv-pages` budget quarantines
//!   exactly the request that exhausts it (`CacheExhausted`), leaves
//!   the survivors bitwise unchanged, and reclaims retired requests'
//!   pages for requests admitted later in the same run.
//!
//! Everything lives in ONE `#[test]`: `QFT_THREADS` is process-global
//! env state, so sweeping it from parallel test threads would race
//! (the `pool_props` convention).

use quanta_ft::model::{BlockConfig, TransformerBlock};
use quanta_ft::serve::{
    BatchScheduler, CacheFull, DecodeScratch, DecodeState, KvArena, PageTable, ServeBlock,
    ServeConfig, ServeError, ServeOutput, ServeRequest,
};
use quanta_ft::util::rng::Rng;

fn trained_block(seed: u64, dims: Vec<usize>, heads: usize) -> TransformerBlock {
    let mut rng = Rng::new(seed);
    let cfg = BlockConfig::standard(dims, heads, 4);
    let mut block = TransformerBlock::init(&cfg, &mut rng).unwrap();
    block.randomize_circuits(0.25, &mut rng).unwrap();
    block
}

/// Teacher-forced decode of `xs` through an arena with the given page
/// size, one position per step — the paged counterpart of
/// `TransformerBlock::forward`'s per-position rows.
fn paged_decode(sb: &ServeBlock, xs: &[f32], seq: usize, page_tokens: usize) -> Vec<f32> {
    let d = sb.d();
    let mut arena = KvArena::new(d, page_tokens, 0).unwrap();
    let mut scratch = DecodeScratch::new();
    let mut state = DecodeState::new(d);
    let mut out = Vec::with_capacity(seq * d);
    let mut step = Vec::new();
    for t in 0..seq {
        let row = &xs[t * d..(t + 1) * d];
        sb.decode_step(&mut arena, &mut scratch, &mut [&mut state], row, &mut step).unwrap();
        out.extend_from_slice(&step);
    }
    assert_eq!(state.len(), seq);
    assert_eq!(state.n_pages(), seq.div_ceil(page_tokens));
    out
}

#[test]
fn paged_kv_properties() {
    // ---- (a) allocator discipline -----------------------------------
    let d = 4usize;
    {
        let mut arena = KvArena::new(d, 2, 3).unwrap();
        let mut t1 = PageTable::new();
        for i in 0..6 {
            arena.push(&mut t1, &[i as f32; 4], &[-(i as f32); 4]).unwrap();
        }
        assert_eq!(arena.pages_in_use(), 3);
        // page 4 would exceed the bound: structured failure, table intact
        let mut t2 = PageTable::new();
        let err = arena.push(&mut t2, &[9.0; 4], &[9.0; 4]).unwrap_err();
        assert_eq!(err, CacheFull { pages: 3 });
        assert_eq!(t2.len(), 0, "failed push must leave the table untouched");
        assert_eq!(t1.len(), 6, "failed push must not disturb other tables");
        // release returns every page; the next sequence reuses them
        // byte-exactly (pages are overwritten before any read)
        arena.release(&mut t1);
        assert_eq!(arena.pages_in_use(), 0);
        for i in 0..5 {
            arena.push(&mut t2, &[10.0 + i as f32; 4], &[0.5; 4]).unwrap();
        }
        let want: Vec<f32> = (0..5).flat_map(|i| vec![10.0 + i as f32; 4]).collect();
        assert_eq!(arena.gather_k(&t2), want, "reused pages must read back the new bytes");
        assert_eq!(arena.allocated_pages(), 3, "bounded arena never grows past its budget");
    }

    // ---- (b) CoW fork isolation + refcount reclaim ------------------
    {
        let mut arena = KvArena::new(d, 2, 0).unwrap();
        let mut parent = PageTable::new();
        for i in 0..5 {
            arena.push(&mut parent, &[i as f32; 4], &[i as f32 + 0.5; 4]).unwrap();
        }
        let before = arena.gather_k(&parent);
        let mut fork = arena.fork(&parent);
        assert_eq!(arena.pages_in_use(), 3, "fork copies zero pages up front");
        assert_eq!(arena.gather_k(&fork), before);
        // fork's first push lands in the shared half-full tail page:
        // CoW copies the one filled row, then the sides diverge
        arena.push(&mut fork, &[100.0; 4], &[100.0; 4]).unwrap();
        arena.push(&mut parent, &[200.0; 4], &[200.0; 4]).unwrap();
        assert_eq!(arena.pages_in_use(), 4, "CoW split pays exactly one page");
        let pk = arena.gather_k(&parent);
        let fk = arena.gather_k(&fork);
        assert_eq!(&pk[..5 * 4], &before[..], "parent prefix perturbed by fork's write");
        assert_eq!(&fk[..5 * 4], &before[..], "fork prefix perturbed by parent's write");
        assert_eq!(&pk[5 * 4..], &[200.0; 4], "parent tail wrong after divergence");
        assert_eq!(&fk[5 * 4..], &[100.0; 4], "fork tail wrong after divergence");
        arena.release(&mut fork);
        assert_eq!(arena.pages_in_use(), 3, "shared pages must survive one side's release");
        assert_eq!(arena.gather_k(&parent)[..5 * 4], before[..]);
        arena.release(&mut parent);
        assert_eq!(arena.pages_in_use(), 0, "refcounts must reclaim every page");
    }

    // ---- (c) paged ≡ contiguous, bitwise, across page sizes × threads
    // the contiguous reference is a one-page arena (page_tokens = seq:
    // a single run, exactly the pre-§14 layout); every smaller page
    // size and every QFT_THREADS must reproduce it bit for bit, and
    // streaming decode must stay bitwise on the forward recompute
    let block = trained_block(400, vec![4, 4, 8], 4);
    let dm = block.d();
    let seq = 13usize; // not a multiple of any swept page size
    let mut xs = vec![0.0f32; seq * dm];
    Rng::new(401).fill_normal(&mut xs, 1.0);
    let streaming = ServeBlock::streaming(&block);
    let merged = ServeBlock::merged(&block).unwrap();
    std::env::set_var("QFT_THREADS", "1");
    let full = block.forward(&xs, 1, seq).unwrap();
    let ref_streaming = paged_decode(&streaming, &xs, seq, seq);
    let ref_merged = paged_decode(&merged, &xs, seq, seq);
    assert_eq!(ref_streaming, full, "contiguous streaming decode drifted from forward");
    for threads in ["1", "2", "8"] {
        std::env::set_var("QFT_THREADS", threads);
        for page_tokens in [1usize, 4, 16] {
            let got_s = paged_decode(&streaming, &xs, seq, page_tokens);
            let got_m = paged_decode(&merged, &xs, seq, page_tokens);
            assert_eq!(
                got_s, ref_streaming,
                "streaming decode differs at page_tokens={page_tokens} QFT_THREADS={threads}"
            );
            assert_eq!(
                got_m, ref_merged,
                "merged decode differs at page_tokens={page_tokens} QFT_THREADS={threads}"
            );
        }
    }
    std::env::remove_var("QFT_THREADS");

    // ---- (d) scheduler page budget: quarantine + reclaim ------------
    // 8 one-token pages, max_batch 2.  The hog (2 + 8 − 1 = 9 cached
    // positions) exceeds the budget even alone and dies CacheExhausted
    // on its 9th push; the short requests (3 pages each) fit alongside
    // it — id 2 only because id 1's retirement returned its pages —
    // and must finish bitwise equal to an unbounded run.
    let mk = |id: u64, p_len: usize, n_gen: usize, seed: u64| {
        let mut prompt = vec![0.0f32; p_len * dm];
        Rng::new(seed).fill_normal(&mut prompt, 1.0);
        ServeRequest { id, prompt, n_gen }
    };
    let reqs = vec![mk(0, 2, 8, 410), mk(1, 2, 2, 411), mk(2, 2, 2, 412)];
    let free_cfg = ServeConfig::default().with_max_batch(2).with_page_tokens(1);
    let free = BatchScheduler::with_config(merged.clone(), free_cfg).unwrap();
    let (unbounded, _) = free.run(reqs.clone()).unwrap();
    let tight = BatchScheduler::with_config(merged.clone(), free_cfg.with_kv_pages(8)).unwrap();
    let (bounded, stats) = tight.run(reqs).unwrap();
    assert_eq!((stats.completed, stats.failed, stats.shed), (2, 1, 0));
    let by_id =
        |outs: &[ServeOutput], id: u64| outs.iter().find(|o| o.id == id).unwrap().result.clone();
    assert_eq!(
        by_id(&bounded, 0).unwrap_err(),
        ServeError::CacheExhausted { pages: 8 },
        "the hog must die on the page budget"
    );
    for id in [1, 2] {
        assert_eq!(
            by_id(&bounded, id),
            by_id(&unbounded, id),
            "request {id} perturbed by a peer's cache exhaustion"
        );
    }
    assert_eq!(stats.pages_in_use, 8, "peak pages must saturate exactly at the budget");
}
