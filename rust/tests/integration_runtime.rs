//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts` to have been run; they skip (pass
//! trivially with a SKIP note) when `artifacts/` is absent so that
//! `cargo test` works on a fresh checkout.

use std::path::PathBuf;

use quanta_ft::coordinator::trainer::{self, FinetuneConfig};
use quanta_ft::data::tasks::{self, Sizes};
use quanta_ft::data::tokenizer::Tokenizer;
use quanta_ft::data::corpus;
use quanta_ft::linalg::numerical_rank;
use quanta_ft::runtime::manifest::Manifest;
use quanta_ft::runtime::pjrt as xla;
use quanta_ft::runtime::session::Session;
use quanta_ft::util::rng::Rng;

fn root() -> PathBuf {
    std::env::current_dir().unwrap()
}

fn artifacts() -> Option<PathBuf> {
    let p = root().join("artifacts");
    if p.join("index.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ missing");
        None
    }
}

fn client() -> xla::PjRtClient {
    xla::PjRtClient::cpu().unwrap()
}

#[test]
fn manifests_all_load_and_validate() {
    let Some(dir) = artifacts() else { return };
    let sets = Manifest::list_sets(&dir).unwrap();
    assert!(sets.len() >= 30, "expected full registry, got {}", sets.len());
    for s in &sets {
        let man = Manifest::load(&dir.join(s)).unwrap();
        assert_eq!(&man.name, s);
        assert!(man.io.theta_len > 0);
        assert!(man.artifacts.contains_key("train_step"), "{s}");
        // PEFT sets must be parameter-efficient
        if let Some(m) = &man.method {
            // QuanTA configs must be extremely parameter-efficient; other
            // PEFT baselines just have to stay below full fine-tuning.
            if m.name == "quanta" {
                assert!(
                    man.counts.trainable_percent < 5.0,
                    "{s}: {}%",
                    man.counts.trainable_percent
                );
            } else if m.name != "ft" {
                assert!(
                    man.counts.trainable_percent < 60.0,
                    "{s}: {}%",
                    man.counts.trainable_percent
                );
            }
        }
    }
}

#[test]
fn pretrain_step_reduces_loss() {
    let Some(dir) = artifacts() else { return };
    let c = client();
    let man = Manifest::load(&dir.join("pretrain_tiny")).unwrap();
    let base = Session::init_base(&man, 0, None).unwrap();
    let mut session = Session::load(&c, &dir, "pretrain_tiny", &base, &["train_step"]).unwrap();
    let tok = Tokenizer::new();
    let mut rng = Rng::new(0);
    let mut state = session.init_state(0).unwrap();
    let io = session.man.io.clone();
    let mut losses = vec![];
    for _ in 0..30 {
        let (tokens, mask) = corpus::pretrain_batch(&tok, &mut rng, io.batch, io.seq_len);
        let loss = session.train_step(&mut state, &tokens, &mask).unwrap();
        assert!(loss.is_finite(), "loss diverged");
        losses.push(loss);
    }
    // loss at init ~ ln(512) ~ 6.24; must drop measurably in 30 steps
    assert!(losses[0] > 5.0, "initial loss {} too low", losses[0]);
    let late: f32 = losses[25..].iter().sum::<f32>() / 5.0;
    assert!(late < losses[0] - 0.5, "no learning: first {} late {}", losses[0], late);
}

#[test]
fn quanta_zero_init_matches_base_logits() {
    // The QuanTA-adapted model at init must equal the frozen model
    // (paper Eq. 8): verify through the *compiled HLO* by comparing
    // fwd_logits of the adapted set at theta0 with the raw base model's
    // logits through the FT set at zero delta.
    let Some(dir) = artifacts() else { return };
    let c = client();
    // base params: random-init model (no pretraining needed for identity check)
    let man_q = Manifest::load(&dir.join("tiny_quanta_n4")).unwrap();
    let man_ft = Manifest::load(&dir.join("tiny_ft")).unwrap();
    let model_len = man_q.counts.model_params;
    let pre_man = Manifest::load(&dir.join("pretrain_tiny")).unwrap();
    let model_ckpt = {
        // pretrain base is a dummy scalar; its theta layout is the model
        let theta = quanta_ft::runtime::init::init_layout(&pre_man.theta_layout, 3, None).unwrap();
        assert_eq!(theta.len(), model_len);
        theta
    };
    let base_q = Session::init_base(&man_q, 7, Some(&model_ckpt)).unwrap();
    let base_ft = Session::init_base(&man_ft, 7, Some(&model_ckpt)).unwrap();
    let sq = Session::load(&c, &dir, "tiny_quanta_n4", &base_q, &["fwd_logits"]).unwrap();
    let sf = Session::load(&c, &dir, "tiny_ft", &base_ft, &["fwd_logits"]).unwrap();
    let theta_q = sq.init_state(7).unwrap().theta;
    let theta_ft = sf.init_state(7).unwrap().theta; // zeros (FT delta)
    assert!(theta_ft.iter().all(|&v| v == 0.0));
    let io = sq.man.io.clone();
    let mut rng = Rng::new(9);
    let tokens: Vec<i32> = (0..io.eval_batch * io.seq_len)
        .map(|_| rng.range(5, 300) as i32)
        .collect();
    let lq = sq.fwd_logits(&theta_q, &tokens).unwrap();
    let lf = sf.fwd_logits(&theta_ft, &tokens).unwrap();
    let max_diff = lq
        .iter()
        .zip(&lf)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-3, "zero-init violated: max logit diff {max_diff}");
}

#[test]
fn merge_deltas_zero_at_init_and_nonzero_after_training() {
    let Some(dir) = artifacts() else { return };
    let c = client();
    let man = Manifest::load(&dir.join("tiny_quanta_n4")).unwrap();
    let pre_man = Manifest::load(&dir.join("pretrain_tiny")).unwrap();
    let ckpt = quanta_ft::runtime::init::init_layout(&pre_man.theta_layout, 5, None).unwrap();
    let base = Session::init_base(&man, 11, Some(&ckpt)).unwrap();
    let mut session = Session::load(
        &c,
        &dir,
        "tiny_quanta_n4",
        &base,
        &["train_step", "eval_loss", "merge"],
    )
    .unwrap();
    let state0 = session.init_state(11).unwrap();
    let deltas0 = session.merge_deltas(&state0.theta).unwrap();
    assert_eq!(deltas0.len(), session.man.merged_modules.len());
    for d in &deltas0 {
        assert!(d.frobenius_norm() < 1e-4, "delta at init not ~0: {}", d.frobenius_norm());
    }
    // few steps of fine-tuning on drop_syn -> deltas move and are HIGH RANK
    let tok = Tokenizer::new();
    let sizes = Sizes { train: 64, val: 8, test: 8 };
    let data = tasks::generate("drop_syn", &tok, 77, sizes).unwrap();
    let cfg = FinetuneConfig { seed: 11, steps: Some(20), eval_every: 1000, ..Default::default() };
    let out = trainer::finetune(&mut session, &data, &cfg).unwrap();
    let deltas = session.merge_deltas(&out.final_theta).unwrap();
    let d0 = &deltas[0];
    assert!(d0.frobenius_norm() > 1e-4, "delta did not move");
    // Theorem 6.2 in action through the whole stack: the QuanTA update
    // of a (128,128) matrix should have rank >> any small LoRA r.
    let rank = numerical_rank(d0, 1e-4).unwrap();
    assert!(rank > 32, "QuanTA update rank {rank} unexpectedly low");
}

#[test]
fn finetune_improves_val_loss() {
    let Some(dir) = artifacts() else { return };
    let c = client();
    let man = Manifest::load(&dir.join("tiny_lora_r8")).unwrap();
    let pre_man = Manifest::load(&dir.join("pretrain_tiny")).unwrap();
    let ckpt = quanta_ft::runtime::init::init_layout(&pre_man.theta_layout, 5, None).unwrap();
    let base = Session::init_base(&man, 5, Some(&ckpt)).unwrap();
    let mut session = Session::load(
        &c,
        &dir,
        "tiny_lora_r8",
        &base,
        &["train_step", "eval_loss"],
    )
    .unwrap();
    let tok = Tokenizer::new();
    let sizes = Sizes { train: 64, val: 16, test: 8 };
    let data = tasks::generate("rte_syn", &tok, 88, sizes).unwrap();
    let state0 = session.init_state(0).unwrap();
    let vl0 = trainer::val_loss(&session, &state0.theta, &data).unwrap();
    let cfg = FinetuneConfig { seed: 0, steps: Some(40), eval_every: 20, ..Default::default() };
    let out = trainer::finetune(&mut session, &data, &cfg).unwrap();
    let vl1 = trainer::val_loss(&session, &out.best_theta, &data).unwrap();
    assert!(vl1 < vl0, "val loss did not improve: {vl0} -> {vl1}");
}
